"""Crash-point fault-injection harness for the streaming engine.

Not a test module (no ``test_`` prefix — pytest does not collect it):
``tests/test_faultinject.py`` drives it over every registered crash point.

The harness plays the role of the *client + supervisor* pair around a
crash-consistent :class:`~repro.service.FraudService`:

1. drive a WAL-enabled service over an event stream (optionally with a
   mid-stream model hot-swap and a mid-stream checkpoint) with one
   :mod:`repro.utils.crashpoint` boundary armed;
2. when the simulated crash fires, abandon the dead service object —
   exactly what a process kill does to in-memory state — keeping only the
   responses that were already *delivered* to the client;
3. restore a brand-new service from the durable directory
   (``FraudService.restore`` = latest checkpoint + WAL-suffix replay);
4. resume the feed from ``ingester.num_events`` — the number of events the
   restored state has durably applied — re-issuing the hot-swap if the
   crash ate its WAL record;
5. merge delivered + replayed + resumed responses, asserting that any
   duplicate delivery of the same order is *bit-identical* (the
   exactly-once guarantee as seen by an idempotent consumer).

The resulting score map and KV-store bytes are compared against an
uninterrupted run by the callers — bit-identical or bust.
"""
from __future__ import annotations

import numpy as np

from repro.service import FraudService
from repro.utils import crashpoint
from repro.utils.crashpoint import SimulatedCrash


def store_contents(store) -> dict:
    """key -> (embedding bytes, model version) for every entry, every shard.

    Goes through the public ``shard_items()`` surface so it works for both
    the in-process :class:`~repro.serve.kvstore.KVStore` and the
    process-backend :class:`~repro.stream.procpool.ProcStoreView` (whose
    shards live in worker processes).  Stamps are wall-clock and excluded —
    parity is value bytes + versions."""
    return {
        k: (np.asarray(v).tobytes(), mv)
        for shard in store.shard_items() for k, v, _ver, _st, mv in shard
    }


def drive(svc, events, start=0, *, swap=None, checkpoint_at=None, out=None):
    """Feed ``events[start:]`` through ``svc.submit`` and drain.

    ``swap=(index, params, version)`` hot-swaps the model right after
    submitting ``events[index]``; ``checkpoint_at=index`` writes a durable
    checkpoint right after that event.  Responses are appended to ``out``
    *as they are delivered* so a crash mid-drive loses only undelivered
    ones — exactly the client's view of a real process kill.
    """
    responses = out if out is not None else []
    for i in range(start, len(events)):
        responses.extend(svc.submit(events[i]))
        if swap is not None and i == swap[0]:
            svc.load_model(swap[1], version=swap[2])
        if checkpoint_at is not None and i == checkpoint_at:
            svc.checkpoint()
    responses.extend(svc.drain())
    return responses


def merge_responses(merged: dict, responses) -> dict:
    """Fold responses into ``order_id -> (score, model_version)``.

    A duplicate delivery (a response handed out both before the crash and
    again by replay) must agree bit-for-bit — at-least-once delivery with
    an idempotent consumer is only sound when re-deliveries are identical.
    """
    for r in responses:
        if not r.admitted:
            continue
        oid = r.request.tag.order_id
        val = (r.score, r.model_version)
        if oid in merged and merged[oid] != val:
            raise AssertionError(
                f"duplicate delivery disagrees for order {oid}: "
                f"{merged[oid]} vs {val}")
        merged[oid] = val
    return merged


def run_uninterrupted(make_service, events, *, swap=None):
    """The oracle: same feed, no WAL, no crash.  Returns (scores, store)."""
    svc = make_service()
    responses = drive(svc, events, swap=swap)
    return merge_responses({}, responses), store_contents(svc.store)


def run_with_crash(make_service, events, root, point, hit=1, *,
                   swap=None, checkpoint_at=None):
    """Crash at the ``hit``-th firing of ``point``, restore, resume.

    Returns a dict with the merged ``scores``, final ``store`` contents,
    the :class:`SimulatedCrash` that fired (``None`` if the stream finished
    first), the resume index, and ``recovery`` (``svc.last_recovery``).
    """
    svc = make_service().enable_wal(root)
    delivered: list = []
    crashed = None
    crashpoint.arm(point, hit=hit)
    try:
        drive(svc, events, swap=swap, checkpoint_at=checkpoint_at,
              out=delivered)
    except SimulatedCrash as exc:
        crashed = exc
    finally:
        crashpoint.disarm()
    # the dead service object is abandoned here, like the process it models

    svc2 = FraudService.restore(root)
    merged = merge_responses({}, delivered)
    merge_responses(merged, svc2.last_recovery["responses"])

    resume = svc2.engine.ingester.num_events
    if swap is not None and resume > swap[0] \
            and svc2.model_version < swap[2]:
        # the crash ate the un-logged half of the hot-swap: the supervisor
        # re-issues it (load_model is idempotent at the same version)
        svc2.load_model(swap[1], version=swap[2])
    resumed = drive(
        svc2, events, start=resume,
        swap=swap if (swap is not None and resume <= swap[0]) else None,
        checkpoint_at=checkpoint_at
        if (checkpoint_at is not None and resume <= checkpoint_at) else None)
    merge_responses(merged, resumed)

    return {
        "scores": merged,
        "store": store_contents(svc2.store),
        "service": svc2,
        "crashed": crashed,
        "resume": resume,
        "recovery": svc2.last_recovery,
    }
