"""Data pipeline: static graph -> partition -> per-community DDS -> padded
device batches, plus the paper's time-based 80/10/10 split.

ClusterGCN-flavor training (paper §3.2): cross-community edges are dropped,
each community becomes one fixed-shape ``PaddedGraph`` batch.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.dds import StaticGraph, build_dds
from repro.core.graph import PaddedGraph, pad_graph
from repro.core.partition import partition_transactions
from repro.utils.padding import pad_to_multiple


@dataclass
class CommunityBatch:
    graph: PaddedGraph
    global_order_ids: np.ndarray   # [num_local_orders] -> static order id
    dds: object                    # DDSGraph (host-side bookkeeping)
    global_entity_ids: np.ndarray | None = None  # local entity -> static id


def make_split_masks(order_snapshot: np.ndarray, fracs=(0.8, 0.1, 0.1)):
    """Paper §4.2: first 80% of snapshots train, next 10% val, last 10% test.

    Split is on *snapshot boundaries* weighted by order counts.  Returns an
    int array [num_orders] with 0=train, 1=val, 2=test.
    """
    assert abs(sum(fracs) - 1.0) < 1e-6
    snaps = np.sort(np.unique(order_snapshot))
    counts = np.asarray([(order_snapshot == s).sum() for s in snaps], np.float64)
    cum = np.cumsum(counts) / counts.sum()
    t_train = snaps[np.searchsorted(cum, fracs[0])] if cum.size else 0
    t_val = snaps[min(np.searchsorted(cum, fracs[0] + fracs[1]), snaps.size - 1)] if cum.size else 0
    split = np.zeros(order_snapshot.shape[0], np.int32)
    split[order_snapshot > t_train] = 1
    split[order_snapshot > t_val] = 2
    return split


def standardize_features(features: np.ndarray, train_mask: np.ndarray):
    """Z-score features using train-split statistics only (no test leakage)."""
    mu = features[train_mask].mean(0, keepdims=True)
    sd = features[train_mask].std(0, keepdims=True) + 1e-6
    return ((features - mu) / sd).astype(np.float32), (mu, sd)


def build_communities(
    static: StaticGraph,
    community_size: int = 256,
    max_deg: int = 32,
    entity_history: str = "all",
    max_history: int | None = 8,
    min_orders: int = 4,
    seed: int = 0,
) -> list[CommunityBatch]:
    """Partition the static graph and build one padded DDS graph per community."""
    comm = partition_transactions(
        static.num_orders,
        static.num_entities,
        static.edges,
        community_size=community_size,
        seed=seed,
    )
    order_comm = comm[: static.num_orders]
    entity_comm = comm[static.num_orders :]

    batches: list[CommunityBatch] = []
    raw = []
    for c in np.unique(comm):
        local_orders = np.nonzero(order_comm == c)[0]
        local_entities = np.nonzero(entity_comm == c)[0]
        if local_orders.size < min_orders:
            continue
        # ClusterGCN: keep only intra-community edges (vectorized)
        keep = (order_comm[static.edges[:, 0]] == c) & (
            entity_comm[static.edges[:, 1]] == c
        )
        kept = static.edges[keep]
        if kept.size == 0:
            continue
        o_lut = np.full(static.num_orders, -1, np.int64)
        o_lut[local_orders] = np.arange(local_orders.size)
        e_lut = np.full(static.num_entities, -1, np.int64)
        e_lut[local_entities] = np.arange(local_entities.size)
        sub_edges = np.stack([o_lut[kept[:, 0]], e_lut[kept[:, 1]]], axis=1)
        sub = StaticGraph(
            num_orders=local_orders.size,
            num_entities=local_entities.size,
            edges=sub_edges,
            order_snapshot=static.order_snapshot[local_orders],
            order_features=static.order_features[local_orders],
            labels=static.labels[local_orders],
            entity_type=None
            if static.entity_type is None
            else static.entity_type[local_entities],
            num_snapshots=static.num_snapshots,
        )
        dds = build_dds(sub, entity_history=entity_history, max_history=max_history)
        raw.append((dds, local_orders, local_entities))

    if not raw:
        return batches
    budget = pad_to_multiple(max(d.coo.num_nodes for d, _, _ in raw), 8)
    for dds, local_orders, local_entities in raw:
        pg = pad_graph(dds.coo, num_nodes=budget, max_deg=max_deg)
        batches.append(CommunityBatch(graph=pg, global_order_ids=local_orders,
                                      dds=dds, global_entity_ids=local_entities))
    return batches


def apply_split_to_batches(batches: list[CommunityBatch], split: np.ndarray, which: int):
    """Return batches whose ``label_mask`` keeps only orders in split ``which``.

    The graph topology is unchanged (all history is visible); only the
    supervision mask moves — matching the paper, where partition runs on the
    whole static graph while train/val/test are snapshot ranges.
    """
    out = []
    for b in batches:
        g = b.graph
        mask = np.zeros(g.num_nodes, np.float32)
        order_rows = np.arange(b.global_order_ids.size)
        sel = split[b.global_order_ids] == which
        mask[order_rows[sel]] = 1.0
        out.append(
            CommunityBatch(
                graph=g._replace(label_mask=g.label_mask * mask),
                global_order_ids=b.global_order_ids,
                dds=b.dds,
            )
        )
    return out
