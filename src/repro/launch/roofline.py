"""Roofline term extraction from compiled dry-run artifacts.

    compute term    = HLO_FLOPs / (chips * 197e12)          [bf16 peak]
    memory term     = HLO_bytes / (chips * 819e9)           [HBM]
    collective term = collective_bytes / (chips * 50e9)     [ICI link]

HLO_FLOPs / bytes come from ``compiled.cost_analysis()``.  Collective bytes
are NOT in cost_analysis: we parse the post-SPMD HLO text and sum the output
operand sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops (all-reduce weighted 2x for the ring's
reduce-scatter + all-gather phases).  Collective bytes in the SPMD module
are *per-shard* quantities, matching the per-chip denominator.

MODEL_FLOPS = 6·N·D for training (fwd+bwd), 2·N·D for inference, with N =
active params; the ratio MODEL_FLOPS/HLO_FLOPs measures how much compiled
compute is useful (remat, padding and masked-attention waste lower it).
"""
from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\(?[^=]*?\)?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
    re.MULTILINE,
)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective-type output bytes summed over the module (one shard)."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        # async pairs: count the -start, skip the -done (same tensor)
        line = m.group(0)
        if "-done(" in line:
            continue
        out[kind] += _shape_bytes(shape_str)
        counts[kind] += 1
    return {"bytes": out, "counts": counts}


@dataclass
class RooflineRecord:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_gflops: float               # total across chips
    hlo_gbytes: float               # total across chips
    coll_gbytes_per_chip: float     # weighted, per shard
    coll_detail: dict
    t_compute: float                # seconds
    t_memory: float
    t_collective: float
    bottleneck: str
    model_gflops: float
    useful_ratio: float
    bytes_per_device: float | None = None
    note: str = ""

    def to_json(self):
        return json.dumps(asdict(self), indent=1)


def model_flops(cfg, shape) -> float:
    """6·N_active·D (train) / 2·N_active·D (inference) with D = processed
    tokens; decode processes global_batch tokens per step."""
    n = active_param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch   # decode: one token per sequence


def active_param_count(cfg) -> float:
    """Active (per-token) parameter count from the logical config."""
    d, nl = cfg.d_model, cfg.num_layers
    v = cfg.vocab_size
    emb = 2 * v * d                     # embed + head
    if cfg.arch_type == "ssm":
        di, n_s, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
        per = d * (2 * di + 2 * n_s + h) + di * d
        return emb + nl * per
    attn = d * (cfg.num_heads + 2 * cfg.num_kv_heads) * cfg.head_dim \
        + cfg.num_heads * cfg.head_dim * d
    if cfg.ffn_type == "swiglu":
        ffn = 3 * d * cfg.d_ff
    else:
        ffn = 2 * d * cfg.d_ff
    if cfg.arch_type == "moe":
        ffn = cfg.experts_per_token * ffn + d * cfg.num_experts
    per = attn + ffn
    if cfg.arch_type == "hybrid":
        di, n_s, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
        mamba_per = d * (2 * di + 2 * n_s + h) + di * d
        n_attn = cfg.num_layers // cfg.attn_every
        return emb + nl * mamba_per + n_attn * per
    if cfg.arch_type == "vlm":
        return emb + nl * per            # cross layers ~ self layers in size
    if cfg.arch_type == "audio":
        dec_per = per + attn            # + cross attention
        return emb + nl * per + nl * dec_per
    return emb + nl * per


def analyze(cfg, shape, mesh_name: str, chips: int, cost: dict, hlo_text: str,
            memory_stats=None, note: str = "", coll_override=None) -> RooflineRecord:
    # cost_analysis of an SPMD-partitioned module reports the PER-SHARD
    # program: flops/bytes below are per chip already.
    flops = float(cost.get("flops", 0.0))
    bts = float(cost.get("bytes accessed", 0.0))
    coll = coll_override if coll_override is not None else collective_bytes(hlo_text)
    weighted = sum(
        (2 if k == "all-reduce" else 1) * v for k, v in coll["bytes"].items()
    )
    t_comp = flops / PEAK_FLOPS_BF16
    t_mem = bts / HBM_BW
    t_coll = weighted / ICI_BW           # per-shard bytes over one chip's link
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    total_flops = flops * chips
    return RooflineRecord(
        arch=cfg.name,
        shape=shape.name,
        mesh=mesh_name,
        chips=chips,
        hlo_gflops=total_flops / 1e9,
        hlo_gbytes=bts * chips / 1e9,
        coll_gbytes_per_chip=weighted / 1e9,
        coll_detail=coll,
        t_compute=t_comp,
        t_memory=t_mem,
        t_collective=t_coll,
        bottleneck=bottleneck,
        model_gflops=mf / 1e9,
        useful_ratio=(mf / total_flops) if total_flops else 0.0,
        bytes_per_device=memory_stats,
        note=note,
    )
