"""Micro-batching scheduler — coalesces concurrent score requests.

The speed layer's stage-2 call is a tiny jitted kernel; dispatch overhead
dominates per-request scoring.  The scheduler queues requests and flushes
them as one fixed-shape batch when either trigger fires:

* **size** — the queue reaches ``max_batch``;
* **deadline** — the oldest queued request has waited ``max_wait_s``
  (virtual seconds), bounding tail latency under light traffic.

Flushed batches are right-padded up to the next power-of-two bucket
(1, 2, 4, ..., max_batch) so the jit cache holds O(log max_batch) shapes
forever — no recompiles under arbitrary traffic, the classic serving-engine
shape-bucketing trick.  Padding rows carry zero features and empty key
lists; their scores are sliced off before results are returned, so batched
scores are bit-identical to unbatched ones (tested).
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np


@dataclass
class ScoreRequest:
    features: np.ndarray          # [F]
    entity_keys: list             # [(entity, t_e)]
    arrival: float                # virtual arrival time (s)
    tag: object = None            # caller-opaque id (e.g. CheckoutEvent)


@dataclass
class ScoredResult:
    request: ScoreRequest
    score: float
    staleness: int                # max snapshot-staleness over served slots
    queued_s: float               # arrival -> flush trigger (virtual)
    service_s: float              # batch compute wall time (shared)
    batch_size: int               # real requests in the flush


def bucket_size(n: int, max_batch: int) -> int:
    """Next power-of-two >= n, capped at max_batch."""
    b = 1
    while b < n and b < max_batch:
        b *= 2
    return min(b, max_batch)


class MicroBatcher:
    """Queue + flush policy for speed-layer micro-batches.

    ``score_fn(features [B, F], key_lists) -> (probs [B], staleness [B])``
    is supplied by the engine; the batcher owns only queueing policy:
    ``submit(request, now)`` enqueues and size-flushes at ``max_batch``,
    ``poll(now)`` deadline-flushes once the oldest request has waited
    ``max_wait_s``, and ``flush(now)`` drains unconditionally.  Flushes are
    right-padded to the next power-of-two bucket (``bucket_size``) so the
    jit cache holds O(log max_batch) shapes.
    """

    def __init__(self, score_fn, max_batch: int = 16, max_wait_s: float = 0.005):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.score_fn = score_fn
        self.max_batch = int(max_batch)
        self.max_wait_s = float(max_wait_s)
        self._queue: list[ScoreRequest] = []
        self.stats = {"flushes": 0, "size_flushes": 0, "deadline_flushes": 0,
                      "requests": 0, "padded_rows": 0}

    def __len__(self) -> int:
        return len(self._queue)

    @property
    def oldest_arrival(self) -> float | None:
        return self._queue[0].arrival if self._queue else None

    def deadline(self) -> float | None:
        """Virtual time at which the current queue must flush."""
        return None if not self._queue else self._queue[0].arrival + self.max_wait_s

    # ------------------------------------------------------------------ queue
    def submit(self, request: ScoreRequest, now: float) -> list[ScoredResult]:
        """Enqueue; flush immediately if the size trigger fires."""
        self._queue.append(request)
        self.stats["requests"] += 1
        if len(self._queue) >= self.max_batch:
            self.stats["size_flushes"] += 1
            return self.flush(now)
        return []

    def poll(self, now: float) -> list[ScoredResult]:
        """Deadline trigger: flush if the oldest request exceeded max_wait.

        The flush is timestamped *at the deadline* (a real engine's timer
        fires then), not at ``now`` — otherwise a request's recorded queue
        wait would stretch to the next arrival under light traffic."""
        dl = self.deadline()
        if dl is not None and now >= dl:
            self.stats["deadline_flushes"] += 1
            return self.flush(dl)
        return []

    # ------------------------------------------------------------------ flush
    def flush(self, now: float) -> list[ScoredResult]:
        """Score everything queued as one padded fixed-shape batch."""
        if not self._queue:
            return []
        batch, self._queue = self._queue[: self.max_batch], self._queue[self.max_batch:]
        n = len(batch)
        b = bucket_size(n, self.max_batch)
        feat_dim = batch[0].features.shape[0]
        feats = np.zeros((b, feat_dim), np.float32)
        key_lists: list[list] = [[] for _ in range(b)]
        for i, r in enumerate(batch):
            feats[i] = r.features
            key_lists[i] = list(r.entity_keys)
        self.stats["padded_rows"] += b - n

        t0 = time.perf_counter()
        probs, staleness = self.score_fn(feats, key_lists)
        service = time.perf_counter() - t0

        self.stats["flushes"] += 1
        return [
            ScoredResult(
                request=r,
                score=float(probs[i]),
                staleness=int(staleness[i]),
                queued_s=max(0.0, now - r.arrival),
                service_s=service,
                batch_size=n,
            )
            for i, r in enumerate(batch)
        ]
