"""Checkout event model + replay-stream construction.

A :class:`CheckoutEvent` is the unit the streaming engine consumes: one
checkout with its linked entities, raw features, and a (virtual) arrival
time.  ``events_from_static`` turns any :class:`~repro.core.dds.StaticGraph`
(e.g. the synthetic generator's output) into an event-time-ordered stream
with Poisson arrivals — the replay harness and benchmarks drive the engine
with it.

Arrival times are *virtual seconds*: the replay harness advances a virtual
clock, so queueing behavior (micro-batch flush deadlines, wait times) is
deterministic and independent of host speed, while jit service times are
measured on the real clock.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.dds import StaticGraph


@dataclass(frozen=True)
class CheckoutEvent:
    """One checkout on the wire: the unit of streaming ingest and scoring.

    ``entities`` may be raw ids (homogeneous) or type-tagged ids
    (``core.hetero.tag_entity``) — both travel as plain ints through the
    WAL and checkpoints."""

    order_id: int             # id in the source static graph (-1 for live traffic)
    snapshot: int             # event-time snapshot index (paper: one day)
    entities: tuple           # linked global entity ids, in entity-type order
    features: np.ndarray      # [F] raw checkout features
    label: float              # ground truth (evaluation only — never an input)
    arrival: float            # virtual arrival time, seconds


def order_event_tuples(g: StaticGraph):
    """Yield (order_id, snapshot, entities, features, label) in event-time
    order (stable by static order id within a snapshot).

    Entity order per checkout preserves the static edge order, so a DDS
    graph built incrementally from this stream is bit-identical to the batch
    build on the same transactions.
    """
    ents_of: dict[int, list[int]] = {}
    for o, e in g.edges:
        ents_of.setdefault(int(o), []).append(int(e))
    for o in np.argsort(g.order_snapshot, kind="stable"):
        o = int(o)
        yield (o, int(g.order_snapshot[o]), tuple(ents_of.get(o, ())),
               g.order_features[o], float(g.labels[o]))


def events_from_static(
    g: StaticGraph,
    rate_per_s: float = 200.0,
    seed: int = 0,
) -> list[CheckoutEvent]:
    """Replay stream: the static graph's checkouts in event-time order with
    Poisson inter-arrival gaps at ``rate_per_s`` events/second."""
    rng = np.random.default_rng(seed)
    events = []
    now = 0.0
    for o, t, ents, feats, label in order_event_tuples(g):
        now += float(rng.exponential(1.0 / rate_per_s))
        events.append(CheckoutEvent(order_id=o, snapshot=t, entities=ents,
                                    features=feats, label=label, arrival=now))
    return events
