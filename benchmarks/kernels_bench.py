"""Kernel micro-benchmarks.

On this CPU container the Pallas kernels execute in interpret mode (a
correctness vehicle, not a perf number), so the timed path is the XLA
reference implementation; for each kernel we also report its arithmetic
intensity and the projected v5e time from the roofline model — the number
the Pallas kernel is designed to approach on hardware.
"""
from __future__ import annotations

import time

import numpy as np


def _time(fn, *args, iters=20):
    import jax

    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6          # us


def run_kernel_bench():
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops
    from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16

    rng = np.random.default_rng(0)
    rows = []

    # csr_spmm: community-scale graph aggregation
    n, deg, h = 1024, 24, 128
    x = jnp.asarray(rng.normal(size=(n, h)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, n, (n, deg)), jnp.int32)
    w = jnp.asarray(rng.uniform(size=(n, deg)), jnp.float32)
    ref = jax.jit(ops.csr_spmm_ref)
    us = _time(ref, x, idx, w)
    flops = 2 * n * deg * h
    bytes_ = (n * h + n * deg * (4 + 4) / 4 + n * h) * 4
    rows.append(("csr_spmm_1024x24x128", us, flops, bytes_))

    # edge_softmax
    ref = jax.jit(ops.edge_softmax_agg_ref)
    ss = jnp.asarray(rng.normal(size=n), jnp.float32)
    sd = jnp.asarray(rng.normal(size=n), jnp.float32)
    m = jnp.ones((n, deg), jnp.float32)
    eb = jnp.zeros((n, deg), jnp.float32)
    us = _time(ref, x, ss, sd, idx, m, eb)
    rows.append(("edge_softmax_1024x24x128", us, 2 * n * deg * h + 6 * n * deg, bytes_))

    # flash attention prefill tile (the XLA blockwise path it replaces)
    from repro.models.common import blockwise_attention
    b, hq, hkv, s, dh = 1, 8, 2, 2048, 128
    q = jnp.asarray(rng.normal(size=(b, hq, s, dh)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, dh)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, dh)), jnp.bfloat16)
    f = jax.jit(lambda q, k, v: blockwise_attention(q, k, v, causal=True, block_k=512))
    us = _time(f, q, k, v, iters=5)
    flops = 2 * 2 * b * hq * s * s * dh // 2          # causal half
    rows.append((f"blockwise_attn_{s}", us, flops, b * (hq + 2 * hkv) * s * dh * 2))

    # gqa decode against a 32k cache
    s = 32768
    q1 = jnp.asarray(rng.normal(size=(b, hq, dh)), jnp.bfloat16)
    kc = jnp.asarray(rng.normal(size=(b, hkv, s, dh)), jnp.bfloat16)
    vc = jnp.asarray(rng.normal(size=(b, hkv, s, dh)), jnp.bfloat16)
    f = jax.jit(lambda q, k, v: ops.gqa_decode_ref(q, k, v))
    us = _time(f, q1, kc, vc, iters=5)
    rows.append((f"gqa_decode_{s}", us, 2 * 2 * b * hq * s * dh,
                 b * 2 * hkv * s * dh * 2))

    # ssd chunked scan
    from repro.kernels.ref import ssd_chunked_ref
    b2, s2, hh, p, nst = 2, 2048, 8, 64, 64
    xs = jnp.asarray(rng.normal(size=(b2, s2, hh, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (b2, s2, hh)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.5, 2, hh), jnp.float32)
    bm = jnp.asarray(rng.normal(size=(b2, s2, nst)), jnp.float32)
    cm = jnp.asarray(rng.normal(size=(b2, s2, nst)), jnp.float32)
    f = jax.jit(lambda *t: ssd_chunked_ref(*t, chunk=128))
    us = _time(f, xs, dt, a, bm, cm, iters=3)
    q = 128
    flops = b2 * s2 * hh * (2 * q * nst + 2 * q * p + 4 * nst * p)
    rows.append((f"ssd_chunk_{s2}", us, flops, xs.size * 4 * 3))

    out = []
    for name, us, flops, bytes_ in rows:
        v5e_us = max(flops / PEAK_FLOPS_BF16, bytes_ / HBM_BW) * 1e6
        out.append({
            "name": name, "us_per_call_cpu_xla": us,
            "gflops": flops / 1e9,
            "arith_intensity": flops / max(bytes_, 1),
            "v5e_roofline_us": v5e_us,
        })
    return out


def main():
    rows = run_kernel_bench()
    print("\n# Kernel micro-bench (XLA ref timed on CPU; v5e roofline projected)")
    print(f"{'name':<26} {'us/call(cpu)':>12} {'GFLOP':>8} {'AI':>8} {'v5e_us':>9}")
    for r in rows:
        print(f"{r['name']:<26} {r['us_per_call_cpu_xla']:>12.1f} "
              f"{r['gflops']:>8.2f} {r['arith_intensity']:>8.1f} "
              f"{r['v5e_roofline_us']:>9.2f}")
    return rows


if __name__ == "__main__":
    main()
