"""Heterogeneous multi-entity-type coverage: the tag scheme, typed DDS
towers, untyped bit-parity, the typed Pallas stage-2 path, the KV
keyspace guard, the hybrid GNN->GBDT head, typed-key WAL/checkpoint
round-trips, and the BENCH_hetero schema gates.

The load-bearing invariant throughout: ``entity_types=()`` (the default)
must stay bit-identical to the homogeneous stack — heterogeneity is an
opt-in extension, never a silent behavior change.
"""
import json
import os
import sys

import jax
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.core import (ENTITY_TYPE_NAMES, LNNConfig, lnn_init,
                        lnn_stage2_embed, lnn_stage2_online)
from repro.core.hetero import (MAX_TYPE_CODE, entity_type_of, is_typed,
                               strip_type, tag_entity, type_code_of,
                               type_codes_array)
from repro.core.partition import IncrementalPartitioner
from repro.data.attacks import ATTACK_NAMES, AttackConfig, generate_attack_stream
from repro.models.hybrid import (HybridModel, is_hybrid_checkpoint,
                                 load_hybrid, save_hybrid, train_hybrid)
from repro.serve.kvstore import KVStore, entity_shard, pack_key
from repro.service import FraudService, ModelSection, ServiceConfig
from repro.stream.events import CheckoutEvent
from tools.check_bench_schema import check_hetero

_TINY = AttackConfig(num_buyers=25, num_merchants=6, num_rings=2,
                     ring_size=4, ring_pool=2, num_bursts=1, burst_orders=6,
                     num_bin_runs=1, bin_cards=5, num_snapshots=6)


def _typed_cfg(**kw):
    base = dict(num_gnn_layers=2, hidden_dim=8, mlp_dims=(8,), feat_dim=4,
                entity_types=ENTITY_TYPE_NAMES)
    base.update(kw)
    return LNNConfig(**base)


def _service(cfg, params, max_batch=4):
    sc = ServiceConfig(mode="streaming",
                       model=ModelSection.from_lnn_config(cfg),
                       ).replace(engine={"max_batch": max_batch})
    return FraudService(sc, params).build()


# ------------------------------------------------------------- tag scheme
def test_tag_roundtrip_all_types():
    for code, name in enumerate(ENTITY_TYPE_NAMES):
        e = tag_entity(12345, code)
        assert is_typed(e)
        assert type_code_of(e) == code
        assert entity_type_of(e) == name
        assert strip_type(e) == 12345
    # distinct types on the same raw id live in disjoint keyspaces
    tagged = [tag_entity(7, c) for c in range(len(ENTITY_TYPE_NAMES))]
    assert len(set(tagged)) == len(tagged)


def test_untagged_ids_are_detectable():
    for raw in (0, 1, 7, 2**40 - 1):
        assert not is_typed(raw)
        assert type_code_of(raw) == -1
    codes = type_codes_array(np.asarray([tag_entity(3, 1), 5, tag_entity(0, 3)]))
    assert codes.tolist() == [1, -1, 3]


def test_tag_bounds_rejected():
    with pytest.raises(ValueError):
        tag_entity(1, MAX_TYPE_CODE + 1)
    with pytest.raises(ValueError):
        tag_entity(-1, 0)
    with pytest.raises(ValueError):
        tag_entity(2**40, 0)  # raw id must fit under the type field


# ------------------------------------------- KV keyspace guard (satellite)
def test_pack_key_rejects_untagged_when_heterogeneous():
    tagged = tag_entity(9, 2)
    assert pack_key(tagged, 3, require_typed=True) == pack_key(tagged, 3)
    with pytest.raises(ValueError, match="no type tag"):
        pack_key(9, 3, require_typed=True)
    with pytest.raises(ValueError, match="no type tag"):
        entity_shard(9, 4, require_typed=True)


def test_kvstore_require_typed_guards_reads_and_writes():
    store = KVStore(dim=2, num_shards=2, require_typed=True)
    ok = tag_entity(4, 0)
    store.put(pack_key(ok, 1, require_typed=True), np.zeros(2), version=1)
    with pytest.raises(ValueError, match="no type tag"):
        store.put(pack_key(4, 1), np.zeros(2), version=1)
    with pytest.raises(ValueError, match="no type tag"):
        store.lookup_batch_versioned([[(4, 1)]], k_max=2)
    # untyped stores keep accepting raw ids — opt-in only
    KVStore(dim=2).put(pack_key(4, 1), np.zeros(2), version=1)


# ------------------------------------------------- untyped bit-parity gate
def test_untyped_init_is_bit_identical_under_typed_config():
    """Adding entity_types must not perturb a single shared parameter leaf
    (typed extras draw from a folded-in key, not the shared split)."""
    rng = jax.random.PRNGKey(7)
    p_plain = lnn_init(rng, _typed_cfg(entity_types=()))
    p_typed = lnn_init(rng, _typed_cfg())
    assert "typed" in p_typed and "typed" not in p_plain
    for path, leaf in jax.tree_util.tree_leaves_with_path(p_plain):
        other = p_typed
        for k in path:
            other = other[getattr(k, "key", getattr(k, "idx", None))]
        assert np.array_equal(np.asarray(leaf), np.asarray(other)), path
    tw = p_typed["typed"]["tower_w"]
    assert tw.shape[0] == len(ENTITY_TYPE_NAMES)


def test_all_untagged_slots_match_untyped_scores_bitwise():
    """slot_type all -1 routes every slot around the towers: the typed
    params must reproduce the untyped forward bit-for-bit."""
    rng = jax.random.PRNGKey(0)
    cfg_t, cfg_p = _typed_cfg(), _typed_cfg(entity_types=())
    p_t, p_p = lnn_init(rng, cfg_t), lnn_init(rng, cfg_p)
    B, K = 5, 3
    emb = np.asarray(jax.random.normal(jax.random.PRNGKey(1), (B, K, 8)))
    mask = np.ones((B, K), np.float32)
    feats = np.asarray(jax.random.normal(jax.random.PRNGKey(2), (B, 4)))
    st = np.full((B, K), -1, np.int32)
    out_t = lnn_stage2_online(p_t, cfg_t, emb, mask, feats, slot_type=st)
    out_p = lnn_stage2_online(p_p, cfg_p, emb, mask, feats)
    np.testing.assert_array_equal(np.asarray(out_t), np.asarray(out_p))


@pytest.mark.parametrize("gnn", ["gcn", "sage", "gat"])
def test_typed_pallas_matches_unfused(gnn):
    rng = jax.random.PRNGKey(3)
    cfg = _typed_cfg(gnn_type=gnn)
    cfg_pl = _typed_cfg(gnn_type=gnn, use_pallas=True)
    params = lnn_init(rng, cfg)
    B, K = 6, 4
    emb = np.asarray(jax.random.normal(jax.random.PRNGKey(4), (B, K, 8)),
                     np.float32)
    mask = (np.arange(K) < 3).astype(np.float32) * np.ones((B, K), np.float32)
    feats = np.asarray(jax.random.normal(jax.random.PRNGKey(5), (B, 4)),
                       np.float32)
    st = np.asarray(jax.random.randint(jax.random.PRNGKey(6), (B, K), 0, 4),
                    np.int32)
    ref = np.asarray(lnn_stage2_online(params, cfg, emb, mask, feats,
                                       slot_type=st))
    fused = np.asarray(lnn_stage2_online(params, cfg_pl, emb, mask, feats,
                                         slot_type=st))
    np.testing.assert_allclose(fused, ref, atol=2e-6, rtol=2e-6)
    # towers must actually fire: typed slots change the score
    ref_plain = np.asarray(lnn_stage2_online(
        params, cfg, emb, mask, feats,
        slot_type=np.full((B, K), -1, np.int32)))
    assert not np.array_equal(ref, ref_plain)


# ---------------------------------------------------- typed DDS + workload
def test_attack_stream_is_fully_typed_and_labeled():
    events, patterns = generate_attack_stream(_TINY)
    assert len(events) == len(patterns)
    assert set(patterns) <= {"legit", *ATTACK_NAMES}
    for a in ATTACK_NAMES:
        assert (patterns == a).sum() > 0, f"no {a} orders generated"
    snaps = [ev.snapshot for ev in events]
    assert snaps == sorted(snaps)
    arr = [ev.arrival for ev in events]
    assert all(b > a for a, b in zip(arr, arr[1:]))
    for ev, pat in zip(events, patterns):
        assert len(ev.entities) == 4
        assert [entity_type_of(e) for e in ev.entities] == list(ENTITY_TYPE_NAMES)
        assert (ev.label == 1.0) == (pat != "legit")


def test_dds_tower_codes_follow_entity_types():
    from repro.core.dds import IncrementalDDSBuilder

    events, _ = generate_attack_stream(_TINY)
    b = IncrementalDDSBuilder(feat_dim=events[0].features.shape[0])
    for ev in events[:40]:
        b.add_order(ev.entities, ev.snapshot, ev.features, ev.label)
    g = b.build()
    tower = g.coo.tower
    assert tower is not None
    n_ord = 2 * g.num_orders
    # order + shadow nodes bypass the towers; entity nodes carry their code
    assert (tower[:n_ord] == -1).all()
    ent_codes = tower[n_ord:]
    assert ((ent_codes >= 0) & (ent_codes < len(ENTITY_TYPE_NAMES))).all()
    for (ent, _t), nid in g.entity_snap_ids.items():
        assert tower[nid] == type_code_of(int(ent))


def test_type_histogram_reads_community_composition():
    part = IncrementalPartitioner()
    ring = [tag_entity(i, 0) for i in range(3)]       # 3 buyers
    dev, pay = tag_entity(0, 2), tag_entity(0, 3)     # shared device+token
    for buyer in ring:
        part.add_order((buyer, dev, pay))
    hist = part.type_histogram(ring[0])
    assert hist == {"buyer": 3, "device": 1, "payment": 1}
    part2 = IncrementalPartitioner()
    part2.add_order((1, 2, 3))
    assert part2.type_histogram(1) == {"untyped": 3}


# ------------------------------------------------------ hybrid GNN -> GBDT
def test_hybrid_train_save_load_roundtrip(tmp_path):
    rng = jax.random.PRNGKey(1)
    cfg = _typed_cfg()
    params = lnn_init(rng, cfg)
    n, dim = 64, cfg.hidden_dim + cfg.feat_dim
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(2), (n, dim)),
                   np.float32)
    y = (x[:, 0] > 0).astype(np.float64)
    hy = train_hybrid(params, cfg, x, y)
    assert isinstance(hy, HybridModel)
    ref = hy.gbdt.predict_proba(x.astype(np.float64))
    path = str(tmp_path / "hybrid.npz")
    save_hybrid(path, hy)
    assert is_hybrid_checkpoint(path)
    back = load_hybrid(path, params, cfg)
    np.testing.assert_array_equal(
        back.gbdt.predict_proba(x.astype(np.float64)), ref)
    for a, b in zip(jax.tree_util.tree_leaves(hy.lnn_params),
                    jax.tree_util.tree_leaves(back.lnn_params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_plain_checkpoint_is_not_hybrid(tmp_path):
    from repro.train.checkpoint import save_checkpoint

    params = lnn_init(jax.random.PRNGKey(0), _typed_cfg())
    path = str(tmp_path / "plain.npz")
    save_checkpoint(path, params)
    assert not is_hybrid_checkpoint(path)


# ------------------------------------- typed-key WAL/checkpoint round-trip
def test_typed_wal_checkpoint_restore_bit_identical(tmp_path):
    """Typed entity ids survive the WAL event codec and checkpointing: a
    restored service must score probe traffic bit-identically — with the
    active version being the hybrid registered before the crash."""
    events, _ = generate_attack_stream(_TINY)
    cfg = _typed_cfg(feat_dim=events[0].features.shape[0])
    params = lnn_init(jax.random.PRNGKey(0), cfg)
    svc = _service(cfg, params)
    svc.enable_wal(str(tmp_path))
    half = len(events) // 2
    svc.replay(events[:half])

    # register + activate a hybrid mid-stream (persisted via save_hybrid)
    eng = svc.engine
    done = events[:half]
    key_lists = [eng.ingester.builder.entity_keys(ev.entities, ev.snapshot)
                 for ev in done]
    emb, mask, _ = svc.store.lookup_batch_versioned(
        key_lists, svc.config.engine.k_max)
    st = eng.pool.workers[0].scorer._slot_types(key_lists)
    feats = np.stack([ev.features for ev in done]).astype(np.float32)
    x = np.asarray(lnn_stage2_embed(params, cfg, emb, mask, feats,
                                    slot_type=st), np.float32)
    hy = train_hybrid(params, cfg, x,
                      np.asarray([ev.label for ev in done]))
    svc.activate_model(svc.register_model(hy, version=1))
    svc.checkpoint()
    svc.replay(events[half:], warmup=False)

    restored = FraudService.restore(str(tmp_path))
    assert restored.model_version == 1
    assert isinstance(restored._models[1], HybridModel)
    probes = [CheckoutEvent(order_id=90_000 + i,
                            snapshot=_TINY.num_snapshots,
                            entities=ev.entities, features=ev.features,
                            label=ev.label,
                            arrival=events[-1].arrival + 1.0 + i)
              for i, ev in enumerate(events[-6:])]
    s1 = svc.replay(probes, warmup=False).scores_by_order()
    s2 = restored.replay(probes, warmup=False).scores_by_order()
    assert set(s1) == set(s2) and all(s2[o] == s1[o] for o in s1)
    svc.close()
    restored.close()


def test_typed_engine_rejects_untagged_mixins():
    """A heterogeneous service's refresh path must reject an untagged id
    at the KV boundary instead of silently co-sharding it."""
    cfg = _typed_cfg(feat_dim=3)
    svc = _service(cfg, lnn_init(jax.random.PRNGKey(0), cfg))
    assert svc.store.require_typed
    with pytest.raises(ValueError, match="no type tag"):
        svc.store.put(pack_key(5, 0), np.zeros(cfg.hidden_dim), version=0)
    svc.close()


# ----------------------------------------------- BENCH_hetero schema gates
def _hetero_record() -> dict:
    budgets = {f"budget_{b}": {a: 0.5 for a in ATTACK_NAMES}
               for b in ("0.02", "0.05")}
    return {
        "n_events": 100,
        "config": {"num_buyers": 10, "num_merchants": 3, "num_rings": 1,
                   "num_bursts": 1, "num_bin_runs": 1, "num_snapshots": 4,
                   "entity_types": list(ENTITY_TYPE_NAMES),
                   "hidden_dim": 8, "gbdt_trees": 5, "train_frac": 0.6},
        "attacks": {"ring": 5, "burst": 4, "bin_test": 3, "legit": 88},
        "test_events": 40, "test_fraud": 6,
        "recall": {m: json.loads(json.dumps(budgets))
                   for m in ("mlp_raw", "gbdt_raw", "hybrid")},
        "auc": {"mlp_raw": 0.7, "gbdt_raw": 0.71, "hybrid": 0.72},
        "gates": {"hybrid_beats_mlp_on_rings": True,
                  "typed_replay_parity": True},
    }


def test_hetero_schema_accepts_valid_record():
    assert check_hetero(_hetero_record()) == []


@pytest.mark.parametrize("gate", ["hybrid_beats_mlp_on_rings",
                                  "typed_replay_parity"])
def test_hetero_schema_gates_must_be_true(gate):
    rec = _hetero_record()
    rec["gates"][gate] = False
    assert any(gate in e for e in check_hetero(rec))


def test_hetero_schema_requires_per_attack_recall():
    rec = _hetero_record()
    del rec["recall"]["hybrid"]["budget_0.02"]["ring"]
    assert any("ring" in e for e in check_hetero(rec))
