"""Assigned-architecture registry.

Each module defines ``CONFIG`` (exact published configuration, source cited)
and the registry maps ``--arch <id>`` to it.  ``reduced()`` variants feed the
CPU smoke tests; ``with_padding(model_axis)`` feeds the sharded dry-run.
"""
from __future__ import annotations

import importlib

ARCH_IDS = [
    "mamba2_370m",
    "granite_3_2b",
    "llama_3_2_vision_90b",
    "yi_34b",
    "phi3_5_moe",
    "olmo_1b",
    "zamba2_1_2b",
    "seamless_m4t_medium",
    "mixtral_8x22b",
    "qwen1_5_32b",
]

# canonical CLI names (dashes) -> module names
CLI_ALIASES = {
    "mamba2-370m": "mamba2_370m",
    "granite-3-2b": "granite_3_2b",
    "llama-3.2-vision-90b": "llama_3_2_vision_90b",
    "yi-34b": "yi_34b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe",
    "olmo-1b": "olmo_1b",
    "zamba2-1.2b": "zamba2_1_2b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "mixtral-8x22b": "mixtral_8x22b",
    "qwen1.5-32b": "qwen1_5_32b",
}


def get_config(arch: str):
    mod_name = CLI_ALIASES.get(arch, arch.replace("-", "_").replace(".", "_"))
    if mod_name not in ARCH_IDS and mod_name != "lnn_fraud":
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(CLI_ALIASES)}")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs():
    return {aid: get_config(aid) for aid in ARCH_IDS}
