"""Benchmark harness — one section per paper table/figure + framework extras.

  table3    paper Table 3 (MLP / LGB / LNN-GAT / LNN-GCN, ROC-AUC + AP)
  latency   paper claim 3 (lambda 1-hop KV inference vs monolithic GNN)
  streaming serving-engine replay (throughput, p50/p95/p99, staleness curve)
  stage2    fused vs unfused speed-layer scoring per micro-batch bucket
  kernels   Pallas-kernel micro-bench (XLA ref timing + v5e roofline projection)
  roofline  aggregated dry-run roofline table (if dry-run records exist)

Prints ``name,us_per_call,derived`` CSV at the end for machine consumption.
"""
from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    csv_rows = [("name", "us_per_call", "derived")]
    os.makedirs("experiments", exist_ok=True)

    from benchmarks.table3 import main as table3_main
    seeds = (0, 1, 2) if os.environ.get("BENCH_FULL") else (0, 1)
    table = table3_main(seeds=seeds)
    json.dump(table, open("experiments/table3.json", "w"), indent=1)
    for name, r in table.items():
        csv_rows.append((f"table3/{name.replace(' ', '')}/auc",
                         f"{r['train_seconds']*1e6:.0f}", f"{r['roc_auc_mean']:.4f}"))
        csv_rows.append((f"table3/{name.replace(' ', '')}/ap",
                         f"{r['train_seconds']*1e6:.0f}", f"{r['ap_mean']:.4f}"))

    from benchmarks.latency import main as latency_main
    lat = latency_main()
    json.dump(lat, open("experiments/latency.json", "w"), indent=1)
    csv_rows.append(("latency/lambda_single", f"{lat['lambda_ms_per_request']*1e3:.1f}",
                     f"speedup={lat['speedup_single']:.1f}x"))
    csv_rows.append(("latency/lambda_batched", f"{lat['lambda_batched_ms_per_request']*1e3:.1f}",
                     f"speedup={lat['speedup_batched']:.1f}x"))
    csv_rows.append(("latency/monolithic", f"{lat['monolithic_ms_per_request']*1e3:.1f}", ""))

    from benchmarks.streaming_bench import main as streaming_main
    stream = streaming_main()   # writes experiments/BENCH_streaming.json
    for bs, t in stream["throughput"].items():
        csv_rows.append((f"streaming/throughput_{bs}", f"{t['us_per_event']:.1f}",
                         f"{t['events_per_s']:.0f}eps"))
    csv_rows.append(("streaming/microbatch_speedup", "",
                     f"{stream['microbatch_speedup']:.1f}x"))
    for load, l in stream["latency"].items():
        csv_rows.append((f"streaming/{load}/p99", f"{l['p99']*1e3:.0f}",
                         f"p50={l['p50']:.2f}ms,p99={l['p99']:.2f}ms"))

    from benchmarks.stage2_bench import main as stage2_main
    s2 = stage2_main()   # writes experiments/BENCH_stage2.json
    for bs, r in s2["per_batch"].items():
        csv_rows.append((f"stage2/fused_b{bs}", f"{r['fused_us']:.1f}",
                         f"speedup={r['speedup']:.2f}x"))

    from benchmarks.kernels_bench import main as kernels_main
    ker = kernels_main()
    json.dump(ker, open("experiments/kernels.json", "w"), indent=1)
    for r in ker:
        csv_rows.append((f"kernel/{r['name']}", f"{r['us_per_call_cpu_xla']:.1f}",
                         f"v5e_roofline_us={r['v5e_roofline_us']:.2f}"))

    from benchmarks.roofline_table import load_records
    recs = load_records("single")
    ok = [r for r in recs if r.get("status") == "ok"]
    if ok:
        print(f"\n# Roofline: {len(ok)} dry-run records (see EXPERIMENTS.md §Roofline)")
        for r in ok[:5]:
            csv_rows.append((f"roofline/{r['arch']}/{r['shape']}",
                             f"{max(r['t_compute'], r['t_memory'], r['t_collective'])*1e6:.0f}",
                             r["bottleneck"]))

    print("\n# CSV")
    for row in csv_rows:
        print(",".join(str(c) for c in row))


if __name__ == '__main__':
    main()
