"""Crash-consistent checkpoint/restore of the full streaming state, plus the
append-only write-ahead event log — ``repro.stream.checkpoint``.

A production Lambda deployment must restart without losing the stream or
double-scoring a checkout.  The engine's state is entirely deterministic
given the event sequence (virtual-clock scheduling, pow2 bucket padding,
host-side sigmoid — see ``repro.stream.engine``), which makes recovery a
pure state problem:

* :class:`WriteAheadLog` — one JSON line per state-changing action
  (``submit`` / ``ingest`` events, ``model`` hot-swaps), each carrying a
  monotonic sequence number and a CRC-32.  Appends are written **before**
  the action is applied (write-ahead), so a crash between append and apply
  is repaired by replay, never lost.  A torn tail (crash mid-append) is
  detected by CRC/JSON damage and truncated on open; damage *followed by
  valid records* is real corruption and raises.  Features round-trip as
  base64 of the raw little-endian float32 bytes — bit-exact, no decimal
  detour.

* :func:`write_checkpoint` / :func:`read_checkpoint` /
  :func:`apply_checkpoint` — a versioned snapshot of everything the engine
  owns: the accumulated order log the :class:`IncrementalDDSBuilder` and
  :class:`IncrementalPartitioner` are deterministically rebuilt from
  (replaying ``add_order`` reproduces their internal state exactly — the
  builder's own materialization-parity guarantee), the dirty
  ``(entity, t)`` set and open snapshot, every KV shard **in LRU order**
  with version / stamp / model-version metadata, every worker's queued
  requests and the reorder buffer's held results (field-exact, including
  submission seqnos), the refresh driver's cadence counters, and the
  service's lifecycle/admission/accounting scalars.  Checkpoints are
  written to a temp directory and committed by one atomic rename —
  ``manifest.json`` is written last, so a directory that scans as a
  checkpoint is always complete.

Restore = build the service from the manifest's config + model registry,
``apply_checkpoint``, then replay the WAL suffix (``seq > applied_seq``)
through the ordinary ``submit``/``ingest``/``load_model`` paths exactly
once.  Determinism does the rest: scores and KV bytes after
crash-restore-replay are bit-identical to an uninterrupted run
(``tests/test_faultinject.py`` proves this at every registered crash
point, for N=1 and N=4 workers, including mid-stream hot-swap).

The driving wrappers live on the facade: ``FraudService.enable_wal`` /
``.checkpoint()`` / ``FraudService.restore(root)``; the gateway exposes
``POST /admin/checkpoint`` and restores on boot.  See docs/checkpointing.md.
"""
from __future__ import annotations

import base64
import json
import os
import shutil
import tempfile
import zlib

import numpy as np

from repro.service.types import ScoreRequest, ScoreResponse
from repro.stream.events import CheckoutEvent
from repro.utils import crashpoint

#: bumped on any incompatible change to the manifest / state.npz layout
CHECKPOINT_FORMAT = 1

_WAL_NAME = "wal.jsonl"
_CKPT_DIR = "checkpoints"
_CKPT_PREFIX = "ckpt-"


class CheckpointError(RuntimeError):
    """Unrecoverable damage in a WAL or checkpoint artifact."""


# --------------------------------------------------------------------- events
def encode_event(event: CheckoutEvent) -> dict:
    """JSON-able payload for one checkout; features as base64 of the raw
    float32 little-endian bytes (bit-exact round-trip; floats themselves
    ride on JSON's shortest-repr round-trip, which is also exact)."""
    feats = np.ascontiguousarray(np.asarray(event.features, np.float32))
    return {
        "order_id": int(event.order_id),
        "snapshot": int(event.snapshot),
        "entities": [int(e) for e in event.entities],
        "features": base64.b64encode(feats.astype("<f4").tobytes()).decode("ascii"),
        "label": float(event.label),
        "arrival": float(event.arrival),
    }


def decode_event(record: dict) -> CheckoutEvent:
    """Inverse of :func:`encode_event` — rebuild the event from one WAL
    JSON record (features decode little-endian f32, platform-independent)."""
    feats = np.frombuffer(
        base64.b64decode(record["features"]), dtype="<f4"
    ).astype(np.float32)
    return CheckoutEvent(
        order_id=int(record["order_id"]),
        snapshot=int(record["snapshot"]),
        entities=tuple(int(e) for e in record["entities"]),
        features=feats,
        label=float(record["label"]),
        arrival=float(record["arrival"]),
    )


def _crc(payload: dict) -> int:
    return zlib.crc32(
        json.dumps(payload, sort_keys=True, separators=(",", ":")).encode()
    )


# ------------------------------------------------------------------------ WAL
class WriteAheadLog:
    """Append-only JSON-lines log with monotonic seqnos and per-line CRC.

    Record kinds: ``submit`` / ``ingest`` (one checkout event each, see
    :func:`encode_event`) and ``model`` (a hot-swap: the parameter file is
    persisted *before* its record is appended, so a logged swap is always
    replayable).  ``fsync=True`` forces each append to stable storage; the
    default flushes to the OS, which is durable against process death (the
    failure the fault-injection harness models) but not power loss.
    """

    def __init__(self, path: str, fsync: bool = False):
        self.path = path
        self.fsync = bool(fsync)
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        self.first_seq = 0   # seq of the first on-disk record (post-compaction)
        self.last_seq = 0    # highest durable seq; append() hands out last_seq+1
        # reader pins: pin_id -> after_seq.  A pin at ``s`` promises its
        # holder every record with seq > s stays readable, so compaction may
        # never drop past min(pins) (see compact()).  Open training taps
        # (repro.learn.tap) hold one pin each at their scan cursor.
        self._pins: dict[int, int] = {}
        self._next_pin = 1
        self._recover_tail()
        self._f = open(path, "a", encoding="utf-8")

    # ------------------------------------------------------------- open/scan
    def _validate_line(self, line: str, prev_seq: int | None) -> dict:
        rec = json.loads(line)
        crc = rec.pop("crc")
        if crc != _crc(rec):
            raise CheckpointError("crc mismatch")
        if prev_seq is not None and rec["seq"] != prev_seq + 1:
            raise CheckpointError(
                f"seq gap: {rec['seq']} after {prev_seq}")
        return rec

    def _recover_tail(self) -> None:
        """Scan the log; truncate a torn final record, raise on interior
        damage (a bad line *followed by* parseable records)."""
        if not os.path.exists(self.path):
            return
        good_end = 0
        bad_at: int | None = None
        prev = None
        with open(self.path, "rb") as f:
            offset = 0
            for raw in f:
                nxt = offset + len(raw)
                try:
                    # past the first damaged line, continuity vs ``prev`` is
                    # meaningless — validate standalone so a healthy record
                    # after the damage is still recognized as one
                    rec = self._validate_line(
                        raw.decode("utf-8"), prev if bad_at is None else None)
                except (CheckpointError, ValueError, KeyError, UnicodeDecodeError):
                    if bad_at is None:
                        bad_at = offset
                    offset = nxt
                    continue
                if bad_at is not None:
                    raise CheckpointError(
                        f"{self.path}: damaged record at byte {bad_at} is "
                        "followed by valid records — interior corruption, "
                        "not a torn tail")
                if prev is None:
                    self.first_seq = int(rec["seq"])
                prev = int(rec["seq"])
                good_end = nxt
                offset = nxt
        if prev is not None:
            self.last_seq = prev
        if bad_at is not None:
            with open(self.path, "r+b") as f:
                f.truncate(good_end)

    def scan(self, after_seq: int = 0):
        """Yield decoded records with ``seq > after_seq``, in order (reads
        the file fresh — safe to call on a log another handle appends to)."""
        if not os.path.exists(self.path):
            return
        prev = None
        with open(self.path, encoding="utf-8") as f:
            for line in f:
                rec = self._validate_line(line, prev)
                prev = int(rec["seq"])
                if rec["seq"] > after_seq:
                    yield rec

    # ---------------------------------------------------------------- append
    def _append(self, record: dict) -> int:
        seq = self.last_seq + 1
        record = {"seq": seq, **record}
        record["crc"] = _crc(record)
        line = json.dumps(record, separators=(",", ":")) + "\n"
        crashpoint.fire("wal.append.before")
        self._f.write(line)
        self._f.flush()
        if self.fsync:
            os.fsync(self._f.fileno())
        self.last_seq = seq
        crashpoint.fire("wal.append.after")
        return seq

    def append_event(self, kind: str, event: CheckoutEvent) -> int:
        """Log one checkout before it is applied.  Returns its seq."""
        if kind not in ("submit", "ingest"):
            raise ValueError(f"unknown event record kind {kind!r}")
        return self._append({"kind": kind, **encode_event(event)})

    def append_model(self, version: int, path: str) -> int:
        """Log a hot-swap to ``version`` whose params live at WAL-root
        relative ``path`` (already persisted — write params, THEN log)."""
        return self._append({"kind": "model", "version": int(version),
                             "path": str(path)})

    def append_drain(self, now: float | None) -> int:
        """Log a mid-stream drain barrier — it force-flushes every queue,
        which changes flush composition, so replay must reproduce it."""
        return self._append({"kind": "drain",
                             "now": None if now is None else float(now)})

    # ------------------------------------------------------------ reader pins
    def pin(self, after_seq: int) -> int:
        """Register a reader pin: records with ``seq > after_seq`` are
        protected from :meth:`compact` until the pin is moved past them or
        released.  Returns the pin id.

        This closes the WAL-compaction vs. reader race: a checkpoint's
        ``compact(applied_seq)`` used to delete records a concurrently-open
        training tap had not consumed yet; with the tap holding a pin at
        its cursor, compaction is clamped to what every open reader has
        already read (``tests/test_learn.py::test_compact_respects_pins``).
        """
        pin_id = self._next_pin
        self._next_pin += 1
        self._pins[pin_id] = int(after_seq)
        return pin_id

    def move_pin(self, pin_id: int, after_seq: int) -> None:
        """Advance a pin to a new cursor (monotonic: moving a pin backwards
        would retro-claim records compaction may already have dropped)."""
        cur = self._pins.get(pin_id)
        if cur is None:
            raise KeyError(f"unknown WAL pin {pin_id}")
        if after_seq < cur:
            raise ValueError(
                f"pin {pin_id} may only advance (at {cur}, got {after_seq})")
        self._pins[pin_id] = int(after_seq)

    def unpin(self, pin_id: int) -> None:
        """Release a reader pin (idempotent)."""
        self._pins.pop(pin_id, None)

    def min_pinned(self) -> int | None:
        """The most conservative pin cursor (None = no open readers)."""
        return min(self._pins.values()) if self._pins else None

    # --------------------------------------------------------------- compact
    def compact(self, upto_seq: int) -> int:
        """Atomically drop records with ``seq <= upto_seq`` (they are covered
        by a checkpoint).  Returns the number of records dropped.

        Open reader pins clamp the drop: a pin at ``s`` keeps every record
        with ``seq > s``, so the effective bound is
        ``min(upto_seq, min_pinned())`` — compaction behind a lagging
        training tap is deferred, never destructive."""
        floor = self.min_pinned()
        if floor is not None:
            upto_seq = min(int(upto_seq), floor)
        keep = list(self.scan(after_seq=int(upto_seq)))
        total = sum(1 for _ in self.scan())
        dropped = total - len(keep)
        if dropped <= 0:
            return 0
        d = os.path.dirname(os.path.abspath(self.path))
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".wal.tmp")
        try:
            with os.fdopen(fd, "w", encoding="utf-8") as f:
                for rec in keep:
                    rec = dict(rec)
                    rec["crc"] = _crc(rec)
                    f.write(json.dumps(rec, separators=(",", ":")) + "\n")
                f.flush()
                os.fsync(f.fileno())
            self._f.close()
            os.replace(tmp, self.path)
        finally:
            if os.path.exists(tmp):
                os.remove(tmp)
        self._f = open(self.path, "a", encoding="utf-8")
        self.first_seq = keep[0]["seq"] if keep else self.last_seq + 1
        return dropped

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()


# ----------------------------------------------------------- state snapshots
def _ragged(seqs, dtype=np.int64):
    """(flat, offsets[len+1]) encoding of a list of int sequences."""
    offsets = np.zeros(len(seqs) + 1, np.int64)
    flat: list = []
    for i, s in enumerate(seqs):
        flat.extend(s)
        offsets[i + 1] = len(flat)
    return np.asarray(flat, dtype), offsets


def _unragged(flat, offsets):
    return [flat[offsets[i]:offsets[i + 1]] for i in range(len(offsets) - 1)]


def _snapshot_requests(requests, results, feat_dim: int) -> dict:
    """Field-exact arrays for queued ScoreRequests + reorder-held
    ScoreResponses.  ``requests`` is [(worker_id, req)] in per-worker queue
    order; ``results`` is the held responses sorted by seq (location -1)."""
    rows = [(w, r) for w, r in requests] + [(-1, r.request) for r in results]
    t = len(rows)
    arr = {
        "rq_location": np.asarray([w for w, _ in rows], np.int64),
        "rq_seq": np.asarray([r.seq for _, r in rows], np.int64),
        "rq_arrival": np.asarray([r.arrival for _, r in rows], np.float64),
        "rq_order_id": np.asarray(
            [r.tag.order_id for _, r in rows], np.int64),
        "rq_snapshot": np.asarray(
            [r.tag.snapshot for _, r in rows], np.int64),
        "rq_label": np.asarray([r.tag.label for _, r in rows], np.float64),
        "rq_features": (np.stack([r.features for _, r in rows])
                        if rows else np.zeros((0, feat_dim), np.float32)),
    }
    arr["rq_ent_flat"], arr["rq_ent_off"] = _ragged(
        [r.tag.entities for _, r in rows])
    key_flat, key_off = _ragged(
        [[c for pair in r.entity_keys for c in pair] for _, r in rows])
    arr["rq_key_flat"], arr["rq_key_off"] = key_flat.reshape(-1, 2), key_off
    arr["rs_score"] = np.asarray([r.score for r in results], np.float64)
    arr["rs_staleness"] = np.asarray([r.staleness for r in results], np.int64)
    arr["rs_queued"] = np.asarray([r.queued_s for r in results], np.float64)
    arr["rs_service"] = np.asarray([r.service_s for r in results], np.float64)
    arr["rs_batch"] = np.asarray([r.batch_size for r in results], np.int64)
    arr["rs_worker"] = np.asarray([r.worker for r in results], np.int64)
    arr["rs_model_version"] = np.asarray(
        [r.model_version for r in results], np.int64)
    assert len(arr["rq_seq"]) == t
    return arr


def _rebuild_requests(arr):
    """Inverse of :func:`_snapshot_requests` — [(location, ScoreRequest)]
    plus the held ScoreResponses in saved order."""
    ents = _unragged(arr["rq_ent_flat"], arr["rq_ent_off"])
    key_off = arr["rq_key_off"] // 2
    keys = _unragged(arr["rq_key_flat"], key_off)
    out = []
    for i in range(len(arr["rq_seq"])):
        feats = np.ascontiguousarray(arr["rq_features"][i], np.float32)
        ev = CheckoutEvent(
            order_id=int(arr["rq_order_id"][i]),
            snapshot=int(arr["rq_snapshot"][i]),
            entities=tuple(int(e) for e in ents[i]),
            features=feats,
            label=float(arr["rq_label"][i]),
            arrival=float(arr["rq_arrival"][i]),
        )
        req = ScoreRequest(
            features=feats,
            entity_keys=[(int(e), int(s)) for e, s in keys[i]],
            arrival=float(arr["rq_arrival"][i]),
            tag=ev, seq=int(arr["rq_seq"][i]),
        )
        out.append((int(arr["rq_location"][i]), req))
    held = []
    j = 0
    for loc, req in out:
        if loc != -1:
            continue
        held.append(ScoreResponse(
            request=req,
            score=float(arr["rs_score"][j]),
            staleness=int(arr["rs_staleness"][j]),
            queued_s=float(arr["rs_queued"][j]),
            service_s=float(arr["rs_service"][j]),
            batch_size=int(arr["rs_batch"][j]),
            worker=int(arr["rs_worker"][j]),
            model_version=int(arr["rs_model_version"][j]),
        ))
        j += 1
    return [(loc, req) for loc, req in out if loc != -1], held


def snapshot_state(service, applied_seq: int) -> tuple[dict, dict]:
    """(manifest, arrays) capturing the full streaming state of a built
    ``FraudService`` (mode='streaming').  Call with the refresh driver
    drained — an in-flight async stage-1 is mid-effect by definition and
    has no consistent snapshot."""
    eng = service.engine
    ing, store, pool, refr = (eng.ingester, eng.store, eng.pool,
                              eng.refresher)
    b = ing.builder

    arrays: dict = {
        "order_snapshot": np.asarray(b._order_snapshot, np.int64),
        "order_features": (np.stack(b._order_features)
                           if b._order_features
                           else np.zeros((0, b.feat_dim), np.float32)),
        "order_labels": np.asarray(b._labels, np.float64),
    }
    arrays["order_ent_flat"], arrays["order_ent_off"] = _ragged(
        b._order_entities)
    dirty = sorted(ing._dirty)
    arrays["dirty_pairs"] = np.asarray(dirty, np.int64).reshape(-1, 2)

    # KV shards in iteration (= LRU) order, with shard boundaries: restore
    # must reproduce eviction order, not just contents.  shard_items() is
    # polymorphic — the process backend's store proxy quiesces each shard
    # process and collects its state through SNAPSHOT frames, so one code
    # path checkpoints both backends bit-identically.
    shards = store.shard_items()
    items: list = [it for shard in shards for it in shard]
    shard_off = [0]
    for shard in shards:
        shard_off.append(shard_off[-1] + len(shard))
    arrays["kv_keys"] = np.asarray([it[0] for it in items], np.int64)
    arrays["kv_values"] = (np.stack([it[1] for it in items])
                           if items else np.zeros((0, store.dim), np.float32))
    arrays["kv_versions"] = np.asarray([it[2] for it in items], np.int64)
    arrays["kv_stamps"] = np.asarray([it[3] for it in items], np.float64)
    arrays["kv_model_versions"] = np.asarray(
        [it[4] for it in items], np.int64)
    arrays["kv_shard_off"] = np.asarray(shard_off, np.int64)

    queued = [(w.wid, r) for w in pool.workers
              for r in list(w.batcher._queue)]
    held = [pool._reorder._held[s] for s in sorted(pool._reorder._held)]
    arrays.update(_snapshot_requests(queued, held, b.feat_dim))

    refr_stats = dict(refr.stats)
    refr_stats["budget_history"] = list(refr_stats["budget_history"])
    refr_stats["per_shard_written"] = {
        str(k): v for k, v in refr_stats["per_shard_written"].items()}

    manifest = {
        "format": CHECKPOINT_FORMAT,
        "applied_seq": int(applied_seq),
        "config": service.config.to_dict(),
        "state": service.state,
        "model_version": int(service.model_version),
        "models": {str(v): f"models/v{v}.npz"
                   for v in service.model_versions()},
        "model_swaps": service._model_swaps,
        "last_good": service._last_good,
        "acct": dict(service._acct),
        "scores_by_version": {
            str(k): v for k, v in service._scores_by_version.items()},
        "shadow": service._shadow,
        "shadow_acc": service._shadow_acc,
        "events_logged": ing.num_events,
        "ingester": {"open_snapshot": ing._open_snapshot,
                     "stats": dict(ing.stats)},
        "store": {"stats": dict(store.stats)},
        "refresher": {"version": refr.version,
                      "model_version": refr.model_version,
                      "windows_since_refresh": refr._windows_since_refresh,
                      "stats": refr_stats},
        "pool": {
            "seq": pool._seq,
            "router_epoch": pool.router.epoch,
            "pool_stats": dict(pool.pool_stats),
            "reorder_next": pool._reorder._next,
            "reorder_max_held": pool._reorder.max_held,
            "workers": [
                {"busy_until": w.busy_until, "stamp_floor": w.stamp_floor,
                 "stats": dict(w.stats),
                 "batcher_stats": dict(w.batcher.stats)}
                for w in pool.workers
            ],
        },
    }
    scaler = getattr(service, "_autoscaler", None)
    if scaler is not None:
        # hysteresis counters + rolling depth window: WAL-replayed traffic
        # must reproduce every scale decision exactly
        manifest["autoscaler"] = scaler.state_dict()
    return manifest, arrays


def apply_checkpoint(service, manifest: dict, arrays: dict) -> None:
    """Impose a snapshot onto a freshly-built ``FraudService`` whose config
    and model registry already match the manifest (``FraudService.restore``
    arranges that).  The DDS builder and partitioner are rebuilt by
    replaying ``add_order`` over the saved order log — deterministic and
    exact — rather than pickling their internals; everything else is
    restored field by field."""
    if manifest.get("format") != CHECKPOINT_FORMAT:
        raise CheckpointError(
            f"checkpoint format {manifest.get('format')} != "
            f"{CHECKPOINT_FORMAT}")
    eng = service.engine
    ing, store, pool, refr = (eng.ingester, eng.store, eng.pool,
                              eng.refresher)

    # an autoscaled pool may have checkpointed at a different worker count
    # than the freshly-built config default: reshard (workers + router +
    # entity-affine store shards together) before any state is imposed
    if len(manifest["pool"]["workers"]) != len(pool.workers):
        pool.reshard(len(manifest["pool"]["workers"]))

    # --- ingester: replay the order log through the builder + partitioner
    ents = _unragged(arrays["order_ent_flat"], arrays["order_ent_off"])
    for i in range(len(arrays["order_snapshot"])):
        entities = [int(e) for e in ents[i]]
        ing.builder.add_order(
            entities, int(arrays["order_snapshot"][i]),
            np.ascontiguousarray(arrays["order_features"][i], np.float32),
            float(arrays["order_labels"][i]))
        ing.partitioner.add_order(entities)
    ing._open_snapshot = int(manifest["ingester"]["open_snapshot"])
    ing._dirty = {(int(e), int(t)) for e, t in arrays["dirty_pairs"]}
    ing.stats.update(manifest["ingester"]["stats"])

    # --- KV store: per-shard insertion order IS the LRU order.
    # load_items/restore_stats are polymorphic — the process backend's
    # store proxy ships each shard's slice to its owner process.
    shard_off = arrays["kv_shard_off"]
    if len(shard_off) - 1 != store.num_shards:
        raise CheckpointError(
            f"checkpoint has {len(shard_off) - 1} KV shards, store has "
            f"{store.num_shards}")
    store.load_items([
        [(int(arrays["kv_keys"][i]),
          np.ascontiguousarray(arrays["kv_values"][i], np.float32),
          int(arrays["kv_versions"][i]),
          float(arrays["kv_stamps"][i]),
          int(arrays["kv_model_versions"][i]))
         for i in range(int(shard_off[s]), int(shard_off[s + 1]))]
        for s in range(len(shard_off) - 1)
    ])
    store.restore_stats(manifest["store"]["stats"])

    # --- refresh driver cadence + counters
    rm = manifest["refresher"]
    refr.version = int(rm["version"])
    refr.model_version = int(rm["model_version"])
    refr._windows_since_refresh = int(rm["windows_since_refresh"])
    stats = dict(rm["stats"])
    stats["per_shard_written"] = {
        int(k): v for k, v in stats["per_shard_written"].items()}
    hist = refr.stats["budget_history"]
    hist.clear()
    hist.extend(stats.pop("budget_history"))
    stats["budget_history"] = hist
    refr.stats.update(stats)

    # --- worker pool: queues, occupancy, reorder buffer
    pm = manifest["pool"]
    queued, held = _rebuild_requests(arrays)
    for loc, req in queued:
        pool.workers[loc].batcher._queue.append(req)
    for wm, w in zip(pm["workers"], pool.workers):
        w.busy_until = float(wm["busy_until"])
        w.stamp_floor = float(wm["stamp_floor"])
        w.stats.update(wm["stats"])
        w.batcher.stats.update(wm["batcher_stats"])
    pool._seq = int(pm["seq"])
    pool.router._epoch = int(pm["router_epoch"])
    pool.pool_stats.update(pm["pool_stats"])
    pool._reorder._next = int(pm["reorder_next"])
    pool._reorder.max_held = int(pm["reorder_max_held"])
    for r in held:
        pool._reorder._held[r.request.seq] = r

    # --- service scalars
    service._acct.update(manifest["acct"])
    service._scores_by_version = {
        int(k): v for k, v in manifest["scores_by_version"].items()}
    service._model_swaps = int(manifest["model_swaps"])
    lg = manifest.get("last_good")
    service._last_good = None if lg is None else int(lg)
    service._shadow = manifest["shadow"]
    service._shadow_acc = float(manifest["shadow_acc"])
    service._state = manifest["state"]

    scaler = getattr(service, "_autoscaler", None)
    if scaler is not None and manifest.get("autoscaler") is not None:
        scaler.load_state(manifest["autoscaler"])


# -------------------------------------------------------------- disk layout
def checkpoint_dir(root: str, applied_seq: int) -> str:
    """Directory one checkpoint occupies under ``root`` — named by the
    zero-padded WAL sequence it covers, so lexical order is replay order."""
    return os.path.join(root, _CKPT_DIR, f"{_CKPT_PREFIX}{applied_seq:012d}")


def write_checkpoint(root: str, service, applied_seq: int) -> str:
    """Atomically write one checkpoint under ``root/checkpoints/``.

    Layout: ``ckpt-{seq:012d}/`` holding ``state.npz`` + ``manifest.json``,
    staged in a ``.tmp`` sibling and committed by a single directory
    rename — recovery only ever sees complete checkpoints (the
    ``checkpoint.mid`` crash point dies between payload and commit, and the
    fault-injection sweep proves the torn stage directory is ignored)."""
    crashpoint.fire("checkpoint.before")
    manifest, arrays = snapshot_state(service, applied_seq)
    final = checkpoint_dir(root, applied_seq)
    if os.path.isdir(final):      # same applied_seq == identical state
        crashpoint.fire("checkpoint.after")
        return final
    tmp = final + ".tmp"
    if os.path.isdir(tmp):        # stage leftover from an earlier crash
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    with open(os.path.join(tmp, "state.npz"), "wb") as f:
        np.savez(f, **arrays)
        f.flush()
        os.fsync(f.fileno())
    crashpoint.fire("checkpoint.mid")
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, final)
    crashpoint.fire("checkpoint.after")
    return final


def list_checkpoints(root: str) -> list[str]:
    """Committed checkpoint directories under ``root``, ascending by seq
    (stage ``.tmp`` leftovers and malformed names are ignored)."""
    d = os.path.join(root, _CKPT_DIR)
    if not os.path.isdir(d):
        return []
    out = []
    for name in os.listdir(d):
        if not name.startswith(_CKPT_PREFIX) or name.endswith(".tmp"):
            continue
        try:
            seq = int(name[len(_CKPT_PREFIX):])
        except ValueError:
            continue
        path = os.path.join(d, name)
        if os.path.isfile(os.path.join(path, "manifest.json")):
            out.append((seq, path))
    return [p for _, p in sorted(out)]


def latest_checkpoint(root: str) -> str | None:
    """The newest committed checkpoint under ``root``, or None."""
    found = list_checkpoints(root)
    return found[-1] if found else None


def prune_checkpoints(root: str, keep_last: int) -> list[str]:
    """Delete all but the newest ``keep_last`` committed checkpoints under
    ``root`` (retention for scheduled checkpointing — a long training run
    would otherwise grow ``checkpoints/`` without bound).  Returns the
    removed directories, oldest first."""
    if keep_last < 1:
        raise ValueError("prune_checkpoints keep_last must be >= 1")
    found = list_checkpoints(root)
    doomed = found[:-keep_last] if len(found) > keep_last else []
    for path in doomed:
        shutil.rmtree(path)
    return doomed


def read_checkpoint(path: str) -> tuple[dict, dict]:
    """(manifest, arrays) from one committed checkpoint directory."""
    try:
        with open(os.path.join(path, "manifest.json"), encoding="utf-8") as f:
            manifest = json.load(f)
    except (OSError, ValueError) as exc:
        raise CheckpointError(f"unreadable checkpoint manifest at {path}: "
                              f"{exc}") from exc
    with np.load(os.path.join(path, "state.npz")) as data:
        arrays = {k: data[k] for k in data.files}
    return manifest, arrays


def wal_path(root: str) -> str:
    """The write-ahead log file under a recovery root."""
    return os.path.join(root, _WAL_NAME)


__all__ = [
    "CHECKPOINT_FORMAT",
    "CheckpointError",
    "WriteAheadLog",
    "apply_checkpoint",
    "checkpoint_dir",
    "decode_event",
    "encode_event",
    "latest_checkpoint",
    "list_checkpoints",
    "prune_checkpoints",
    "read_checkpoint",
    "snapshot_state",
    "wal_path",
    "write_checkpoint",
]
