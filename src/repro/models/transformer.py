"""Unified composable model covering all six assigned architecture families.

A config compiles to a *block program*: an ordered list of groups, each a
homogeneous stack of layers executed with ``lax.scan`` over stacked params
(keeps the HLO size independent of depth — essential for the 80 dry-run
compiles on one CPU core).  Heterogeneous archs nest structure inside a
group's body:

  dense/moe   [('decoder', L)]
  ssm         [('mamba', L)]
  hybrid      [('zamba_super', L // k)] + [('mamba', L % k)]   (shared attn)
  vlm         [('vlm_super', L // k)]      (k-1 self layers + 1 cross layer)
  audio       encoder [('enc', L)] + decoder [('dec', L)]

Entry points: ``init_params``, ``forward_train`` (loss), ``prefill``
(logits + cache), ``decode_step`` (one token), ``init_cache``.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.attention import attn_apply, attn_decode, attn_init
from repro.models.common import (
    dense_init,
    ffn_apply,
    ffn_init,
    layernorm_nonparametric,
    rmsnorm,
    softmax_cross_entropy,
)
from repro.models.config import ArchConfig
from repro.dist.sharding import shard_hint
from repro.models.mamba import mamba_apply, mamba_decode, mamba_init
from repro.models.moe import moe_apply, moe_init


# ---------------------------------------------------------------------------
# block program
# ---------------------------------------------------------------------------

def build_program(cfg: ArchConfig) -> list[tuple[str, int]]:
    if cfg.arch_type in ("dense", "moe"):
        return [("decoder", cfg.num_layers)]
    if cfg.arch_type == "ssm":
        return [("mamba", cfg.num_layers)]
    if cfg.arch_type == "hybrid":
        k = cfg.attn_every
        n_super, tail = divmod(cfg.num_layers, k)
        prog = [("zamba_super", n_super)]
        if tail:
            prog.append(("mamba", tail))
        return prog
    if cfg.arch_type == "vlm":
        k = cfg.cross_attn_every
        assert cfg.num_layers % k == 0, "vlm layers must tile into superblocks"
        return [("vlm_super", cfg.num_layers // k)]
    if cfg.arch_type == "audio":
        return [("enc", cfg.num_layers), ("dec", cfg.num_layers)]
    raise ValueError(cfg.arch_type)


def _norm(cfg, x, scale):
    if cfg.nonparametric_ln:
        return layernorm_nonparametric(x)
    return rmsnorm(x, scale)


def scan_or_unroll(f, init, xs, unroll: bool):
    """lax.scan, or a python loop over the leading axis when ``unroll``.

    The unrolled path exists for the dry-run cost extrapolation: XLA's
    HloCostAnalysis visits a while-loop body once regardless of trip count,
    so FLOP/byte/collective accounting is only exact on loop-free HLO.
    """
    if not unroll:
        return jax.lax.scan(f, init, xs)
    n = jax.tree_util.tree_leaves(xs)[0].shape[0]
    carry = init
    outs = []
    for i in range(n):
        sl = jax.tree_util.tree_map(lambda x: x[i], xs)
        carry, out = f(carry, sl)
        outs.append(out)
    if outs and outs[0] is not None:
        stacked = jax.tree_util.tree_map(lambda *ys: jnp.stack(ys), *outs)
    else:
        stacked = None
    return carry, stacked


# ---------------------------------------------------------------------------
# per-group layer init
# ---------------------------------------------------------------------------

def _decoder_layer_init(rng, cfg):
    ks = jax.random.split(rng, 3)
    p = {
        "ln1": jnp.zeros((cfg.d_model,), jnp.dtype(cfg.dtype)),
        "ln2": jnp.zeros((cfg.d_model,), jnp.dtype(cfg.dtype)),
        "attn": attn_init(ks[0], cfg),
    }
    if cfg.arch_type == "moe":
        p["moe"] = moe_init(ks[1], cfg)
    else:
        p["ffn"] = ffn_init(ks[1], cfg.d_model, cfg.d_ff, cfg.ffn_type, jnp.dtype(cfg.dtype))
    return p


def _cross_layer_init(rng, cfg):
    ks = jax.random.split(rng, 3)
    return {
        "ln1": jnp.zeros((cfg.d_model,), jnp.dtype(cfg.dtype)),
        "ln2": jnp.zeros((cfg.d_model,), jnp.dtype(cfg.dtype)),
        "attn": attn_init(ks[0], cfg, cross=True),
        "ffn": ffn_init(ks[1], cfg.d_model, cfg.d_ff, cfg.ffn_type, jnp.dtype(cfg.dtype)),
        "gate": jnp.full((1,), 0.1, jnp.float32),   # mllama-style cross gate
    }


def _dec_layer_init(rng, cfg):
    """Audio decoder layer: self-attn + cross-attn + ffn."""
    ks = jax.random.split(rng, 4)
    return {
        "ln1": jnp.zeros((cfg.d_model,), jnp.dtype(cfg.dtype)),
        "ln_x": jnp.zeros((cfg.d_model,), jnp.dtype(cfg.dtype)),
        "ln2": jnp.zeros((cfg.d_model,), jnp.dtype(cfg.dtype)),
        "self": attn_init(ks[0], cfg),
        "cross": attn_init(ks[1], cfg, cross=True),
        "ffn": ffn_init(ks[2], cfg.d_model, cfg.d_ff, cfg.ffn_type, jnp.dtype(cfg.dtype)),
    }


def _stack(init_fn, rng, n, *args):
    keys = jax.random.split(rng, max(n, 1))
    layers = [init_fn(keys[i], *args) for i in range(n)]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *layers)


def init_params(rng, cfg: ArchConfig):
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 12)
    v = cfg.physical_vocab
    params = {
        "embed": dense_init(ks[0], (v, cfg.d_model), dtype, scale=0.02),
        "head": dense_init(ks[1], (cfg.d_model, v), dtype),
        "final_ln": jnp.zeros((cfg.d_model,), dtype),
        "groups": {},
    }
    for gi, (gname, n) in enumerate(build_program(cfg)):
        sub = jax.random.fold_in(ks[2], gi)
        if gname == "decoder":
            params["groups"][gname] = _stack(_decoder_layer_init, sub, n, cfg)
        elif gname == "mamba":
            params["groups"][gname] = _stack(mamba_init, sub, n, cfg)
        elif gname == "zamba_super":
            params["groups"][gname] = {
                "mamba": _stack(
                    lambda r, c: _stack(mamba_init, r, cfg.attn_every, c), sub, n, cfg
                ),
            }
            params["shared_attn"] = _decoder_layer_init(ks[3], cfg)
        elif gname == "vlm_super":
            params["groups"][gname] = {
                "self": _stack(
                    lambda r, c: _stack(_decoder_layer_init, r,
                                        cfg.cross_attn_every - 1, c),
                    sub, n, cfg,
                ),
                "cross": _stack(_cross_layer_init, sub, n, cfg),
            }
        elif gname == "enc":
            params["groups"][gname] = _stack(_decoder_layer_init, sub, n, cfg)
        elif gname == "dec":
            params["groups"][gname] = _stack(_dec_layer_init, sub, n, cfg)
    return params


# ---------------------------------------------------------------------------
# full-sequence bodies (train / prefill).  Each returns (h, cache_slice).
# ---------------------------------------------------------------------------

def _decoder_block(p, cfg, h, *, want_cache, attn_impl="blockwise"):
    a_in = _norm(cfg, h, p["ln1"])
    a_out, (k, v) = attn_apply(p["attn"], cfg, a_in, attn_impl=attn_impl)
    h = h + a_out
    f_in = _norm(cfg, h, p["ln2"])
    aux = jnp.zeros((), jnp.float32)
    if cfg.arch_type == "moe" and "moe" in p:
        b, s, d = f_in.shape
        y, aux = moe_apply(p["moe"], cfg, f_in.reshape(b * s, d))
        h = h + y.reshape(b, s, d)
    else:
        h = h + ffn_apply(p["ffn"], f_in, cfg.ffn_type)
    h = shard_hint(h, "act")
    cache = {"k": k, "v": v} if want_cache else None
    return h, cache, aux


def _cross_block(p, cfg, h, memory, *, want_cache):
    a_in = _norm(cfg, h, p["ln1"])
    a_out, (k, v) = attn_apply(p["attn"], cfg, a_in, kv_x=memory, causal=False,
                               use_rope=False)
    h = h + jnp.tanh(p["gate"]).astype(h.dtype) * a_out
    f_in = _norm(cfg, h, p["ln2"])
    h = h + ffn_apply(p["ffn"], f_in, cfg.ffn_type)
    cache = {"k": k, "v": v} if want_cache else None
    return h, cache


def _dec_block(p, cfg, h, memory, *, want_cache):
    a_in = _norm(cfg, h, p["ln1"])
    a_out, (k, v) = attn_apply(p["self"], cfg, a_in)
    h = h + a_out
    x_in = _norm(cfg, h, p["ln_x"])
    x_out, (kx, vx) = attn_apply(p["cross"], cfg, x_in, kv_x=memory, causal=False,
                                 use_rope=False)
    h = h + x_out
    f_in = _norm(cfg, h, p["ln2"])
    h = shard_hint(h + ffn_apply(p["ffn"], f_in, cfg.ffn_type), "act")
    cache = (
        {"self": {"k": k, "v": v}, "cross": {"k": kx, "v": vx}} if want_cache else None
    )
    return h, cache


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def _run_groups(params, cfg: ArchConfig, h, extra, *, want_cache, use_remat,
                attn_impl="blockwise", use_pallas=False, unroll=False):
    """Run the block program.  Returns (h, caches, aux_sum)."""
    caches = {}
    aux_total = jnp.zeros((), jnp.float32)

    def scan_group(body, h, stacked):
        fn = jax.checkpoint(body) if use_remat else body
        (h, aux), out = scan_or_unroll(fn, (h, jnp.zeros((), jnp.float32)),
                                       stacked, unroll)
        return h, out, aux

    for gname, n in build_program(cfg):
        if gname not in params["groups"]:
            continue  # e.g. 'enc' handled separately by _encode for audio
        gp = params["groups"][gname]
        if gname == "decoder" or gname == "enc":
            def body(carry, p, _g=gname):
                h, aux = carry
                if _g == "enc":
                    a_in = _norm(cfg, h, p["ln1"])
                    a_out, kv = attn_apply(p["attn"], cfg, a_in, causal=False)
                    h2 = h + a_out
                    f_in = _norm(cfg, h2, p["ln2"])
                    h2 = h2 + ffn_apply(p["ffn"], f_in, cfg.ffn_type)
                    return (h2, aux), None
                h2, cache, aux_l = _decoder_block(p, cfg, h, want_cache=want_cache,
                                                  attn_impl=attn_impl)
                return (h2, aux + aux_l), cache
            h, out, aux = scan_group(body, h, gp)
            aux_total += aux
            if want_cache and gname == "decoder":
                caches[gname] = out
        elif gname == "mamba":
            def body(carry, p):
                h, aux = carry
                m_in = rmsnorm(h)
                y, st = mamba_apply(p, cfg, m_in, use_pallas=use_pallas,
                                    return_state=want_cache)
                return (shard_hint(h + y, "act"), aux), st
            h, out, _ = scan_group(body, h, gp)
            if want_cache:
                caches[gname] = out
        elif gname == "zamba_super":
            shared = params["shared_attn"]
            def body(carry, p):
                h, aux = carry
                def mbody(c2, mp):
                    h2, _ = c2
                    y, st = mamba_apply(mp, cfg, rmsnorm(h2),
                                        use_pallas=use_pallas,
                                        return_state=want_cache)
                    return (shard_hint(h2 + y, "act"), jnp.zeros((), jnp.float32)), st
                (h, _), mstates = scan_or_unroll(mbody, (h, aux), p["mamba"], unroll)
                h, cache, _ = _decoder_block(shared, cfg, h, want_cache=want_cache,
                                             attn_impl=attn_impl)
                return (h, aux), {"mamba": mstates, "attn": cache} if want_cache else None
            h, out, _ = scan_group(body, h, gp)
            if want_cache:
                caches[gname] = out
        elif gname == "vlm_super":
            vision = extra["vision"]
            def body(carry, p):
                h, aux = carry
                def sbody(c2, sp):
                    h2, _ = c2
                    h3, cache, _ = _decoder_block(sp, cfg, h2, want_cache=want_cache,
                                                  attn_impl=attn_impl)
                    return (h3, jnp.zeros((), jnp.float32)), cache
                (h, _), scache = scan_or_unroll(sbody, (h, aux), p["self"], unroll)
                h, xcache = _cross_block(p["cross"], cfg, h, vision,
                                         want_cache=want_cache)
                return (h, aux), {"self": scache, "cross": xcache} if want_cache else None
            h, out, _ = scan_group(body, h, gp)
            if want_cache:
                caches[gname] = out
        elif gname == "dec":
            memory = extra["memory"]
            def body(carry, p):
                h, aux = carry
                h2, cache = _dec_block(p, cfg, h, memory, want_cache=want_cache)
                return (h2, aux), cache
            h, out, _ = scan_group(body, h, gp)
            if want_cache:
                caches[gname] = out
    return h, caches, aux_total


def _encode(params, cfg, frames, use_remat, unroll=False):
    """Audio encoder over precomputed frame embeddings (frontend stub)."""
    h = frames.astype(jnp.dtype(cfg.dtype))
    gp = params["groups"]["enc"]

    def body(carry, p):
        h, aux = carry
        a_in = _norm(cfg, h, p["ln1"])
        a_out, _ = attn_apply(p["attn"], cfg, a_in, causal=False)
        h = h + a_out
        f_in = _norm(cfg, h, p["ln2"])
        h = h + ffn_apply(p["ffn"], f_in, cfg.ffn_type)
        return (h, aux), None

    fn = jax.checkpoint(body) if use_remat else body
    (h, _), _ = scan_or_unroll(fn, (h, jnp.zeros((), jnp.float32)), gp, unroll)
    return h


def forward(params, cfg: ArchConfig, tokens, extra=None, *, want_cache=False,
            use_remat=False, attn_impl="blockwise", use_pallas=False,
            unroll=False):
    """tokens: [B, S] int32.  extra: {'vision': [B,Tv,d]} | {'frames': [B,Sf,d]}.

    Returns (logits [B, S, Vphys], caches, aux)."""
    extra = extra or {}
    h = shard_hint(jnp.take(params["embed"], tokens, axis=0), "act")
    if cfg.arch_type == "audio":
        memory = _encode(params, cfg, extra["frames"], use_remat, unroll)
        extra = dict(extra, memory=memory)
        # skip the 'enc' group inside _run_groups for the decoder pass
        dec_params = {"groups": {"dec": params["groups"]["dec"]}}
        h, caches, aux = _run_groups(dec_params, cfg, h, extra,
                                     want_cache=want_cache, use_remat=use_remat,
                                     attn_impl=attn_impl, use_pallas=use_pallas,
                                     unroll=unroll)
        if want_cache:
            caches["enc_memory"] = memory
    else:
        h, caches, aux = _run_groups(params, cfg, h, extra, want_cache=want_cache,
                                     use_remat=use_remat, attn_impl=attn_impl,
                                     use_pallas=use_pallas, unroll=unroll)
    h = _norm(cfg, h, params["final_ln"])
    logits = shard_hint(h @ params["head"], "logits")
    return logits, caches, aux


def forward_train(params, cfg: ArchConfig, batch, *, use_remat=True,
                  attn_impl="blockwise", use_pallas=False, aux_weight=0.01,
                  unroll=False):
    """Causal LM loss.  batch: {'tokens', 'labels', [extras]}; labels==-1 masked.
    The vocab-padding columns are masked out of the softmax."""
    extra = {k: v for k, v in batch.items() if k not in ("tokens", "labels")}
    logits, _, aux = forward(params, cfg, batch["tokens"], extra,
                             use_remat=use_remat, attn_impl=attn_impl,
                             use_pallas=use_pallas, unroll=unroll)
    if cfg.physical_vocab != cfg.vocab_size:
        pad_mask = jnp.arange(cfg.physical_vocab) >= cfg.vocab_size
        logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    loss = softmax_cross_entropy(logits, jnp.maximum(labels, 0), mask)
    return loss + aux_weight * aux


def prefill(params, cfg: ArchConfig, tokens, max_len: int, extra=None,
            attn_impl: str = "blockwise", use_pallas: bool = False,
            unroll: bool = False):
    """Process a prompt and build a decode cache of capacity ``max_len``.

    Returns (last_logits [B, Vphys], caches).  This is the transformer
    analogue of the paper's *batch layer*: the expensive precompute whose
    output (KV cache / SSM state) the cheap per-token speed layer consumes.
    """
    extra = extra or {}
    b, s = tokens.shape
    logits, fwd_caches, _ = forward(params, cfg, tokens, extra, want_cache=True,
                                    use_remat=False, attn_impl=attn_impl,
                                    use_pallas=use_pallas, unroll=unroll)
    extra_shapes = {}
    if "vision" in extra:
        extra_shapes["vision_len"] = extra["vision"].shape[1]
    if "frames" in extra:
        extra_shapes["memory_len"] = extra["frames"].shape[1]
    full = init_cache(cfg, b, max_len, extra_shapes)

    if cfg.arch_type == "audio":
        # encoder memory K/V were cached per dec layer already; drop the raw copy
        fwd_caches = {k: v for k, v in fwd_caches.items() if k != "enc_memory"}

    def merge(dst, src):
        if dst.shape == src.shape:
            return src.astype(dst.dtype)
        # attention K/V: embed [.., S, Dh] into [.., max_len, Dh] at offset 0
        assert dst.ndim == src.ndim and dst.shape[-1] == src.shape[-1], (
            dst.shape, src.shape)
        start = (0,) * dst.ndim
        return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype), start)

    merged = {"pos": jnp.asarray(s, jnp.int32)}
    for gname in fwd_caches:
        merged[gname] = jax.tree_util.tree_map(merge, full[gname], fwd_caches[gname])
    return logits[:, -1], merged


# ---------------------------------------------------------------------------
# decode: cache init + single-token step
# ---------------------------------------------------------------------------

def _attn_cache_zeros(cfg, batch, max_len, dtype):
    hkv, dh = cfg.physical_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, hkv, max_len, dh), dtype),
        "v": jnp.zeros((batch, hkv, max_len, dh), dtype),
    }


def init_cache(cfg: ArchConfig, batch: int, max_len: int, extra_shapes=None):
    """Zero-initialized decode cache matching ``decode_step``'s expectations.

    ``extra_shapes``: {'vision_len': int} / {'memory_len': int} for cross
    caches.  For dry-run specs use ``jax.eval_shape(init_cache, ...)``.

    With ``cfg.ring_kv_cache`` (sliding-window archs) the self-attention
    caches are ring buffers of ``window`` slots: the oldest position is
    overwritten, bounding decode memory by O(window) instead of O(max_len).
    """
    dtype = jnp.dtype(cfg.dtype)
    if cfg.ring_kv_cache and cfg.window:
        max_len = min(max_len, cfg.window)
    di, n = cfg.d_inner, cfg.ssm_state
    conv_w = di + 2 * n
    caches = {"pos": jnp.zeros((), jnp.int32)}
    extra_shapes = extra_shapes or {}

    def mamba_state():
        return {
            "conv": jnp.zeros((batch, cfg.conv_kernel - 1, conv_w), dtype),
            "ssm": jnp.zeros((batch, cfg.ssm_heads, n, cfg.ssm_head_dim), jnp.float32),
        }

    def stack_n(make, n_):
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs),
                                      *[make() for _ in range(max(n_, 1))])

    for gname, n_layers in build_program(cfg):
        if gname in ("decoder",):
            caches[gname] = stack_n(lambda: _attn_cache_zeros(cfg, batch, max_len, dtype),
                                    n_layers)
        elif gname == "mamba":
            caches[gname] = stack_n(mamba_state, n_layers)
        elif gname == "zamba_super":
            caches[gname] = stack_n(
                lambda: {
                    "mamba": stack_n(mamba_state, cfg.attn_every),
                    "attn": _attn_cache_zeros(cfg, batch, max_len, dtype),
                },
                n_layers,
            )
        elif gname == "vlm_super":
            tv = extra_shapes.get("vision_len", cfg.num_vision_tokens)
            caches[gname] = stack_n(
                lambda: {
                    "self": stack_n(
                        lambda: _attn_cache_zeros(cfg, batch, max_len, dtype),
                        cfg.cross_attn_every - 1,
                    ),
                    "cross": {
                        "k": jnp.zeros((batch, cfg.physical_kv_heads, tv, cfg.head_dim), dtype),
                        "v": jnp.zeros((batch, cfg.physical_kv_heads, tv, cfg.head_dim), dtype),
                    },
                },
                n_layers,
            )
        elif gname == "dec":
            ml = extra_shapes.get("memory_len", 1024)
            caches[gname] = stack_n(
                lambda: {
                    "self": _attn_cache_zeros(cfg, batch, max_len, dtype),
                    "cross": {
                        "k": jnp.zeros((batch, cfg.physical_kv_heads, ml, cfg.head_dim), dtype),
                        "v": jnp.zeros((batch, cfg.physical_kv_heads, ml, cfg.head_dim), dtype),
                    },
                },
                n_layers,
            )
        # 'enc' has no decode-time cache
    return caches


def _decoder_block_decode(p, cfg, h, cache, pos):
    a_in = _norm(cfg, h, p["ln1"])
    a_out, cache = attn_decode(p["attn"], cfg, a_in, cache, pos)
    h = h + a_out
    f_in = _norm(cfg, h, p["ln2"])
    if cfg.arch_type == "moe" and "moe" in p:
        b = f_in.shape[0]
        y, _ = moe_apply(p["moe"], cfg, f_in.reshape(b, -1), full_capacity=True)
        h = h + y.reshape(b, 1, -1)
    else:
        h = h + ffn_apply(p["ffn"], f_in, cfg.ffn_type)
    return shard_hint(h, "act"), cache


def decode_step(params, cfg: ArchConfig, token, caches, unroll: bool = False):
    """One decode step.  token: [B] int32.  Returns (logits [B, Vphys], caches)."""
    pos = caches["pos"]
    h = shard_hint(jnp.take(params["embed"], token[:, None], axis=0), "act")
    new_caches = dict(caches)

    for gname, n in build_program(cfg):
        gp = params["groups"].get(gname)
        if gname == "enc":
            continue
        cstack = caches[gname]
        if gname == "decoder":
            def body(h, xs):
                p, c = xs
                h, c = _decoder_block_decode(p, cfg, h, c, pos)
                return h, c
            h, new_caches[gname] = scan_or_unroll(body, h, (gp, cstack), unroll)
        elif gname == "mamba":
            def body(h, xs):
                p, c = xs
                y, c = mamba_decode(p, cfg, rmsnorm(h), c)
                return h + y, c
            h, new_caches[gname] = scan_or_unroll(body, h, (gp, cstack), unroll)
        elif gname == "zamba_super":
            shared = params["shared_attn"]
            def body(h, xs):
                p, c = xs
                def mb(h2, xs2):
                    mp, mc = xs2
                    y, mc = mamba_decode(mp, cfg, rmsnorm(h2), mc)
                    return h2 + y, mc
                h, mcache = scan_or_unroll(mb, h, (p["mamba"], c["mamba"]), unroll)
                h, acache = _decoder_block_decode(shared, cfg, h, c["attn"], pos)
                return h, {"mamba": mcache, "attn": acache}
            h, new_caches[gname] = scan_or_unroll(body, h, (gp, cstack), unroll)
        elif gname == "vlm_super":
            def body(h, xs):
                p, c = xs
                def sb(h2, xs2):
                    sp, sc = xs2
                    h2, sc = _decoder_block_decode(sp, cfg, h2, sc, pos)
                    return h2, sc
                h, scache = scan_or_unroll(sb, h, (p["self"], c["self"]), unroll)
                a_in = _norm(cfg, h, p["cross"]["ln1"])
                a_out, _ = attn_decode(p["cross"]["attn"], cfg, a_in,
                                       c["cross"], pos, cross=True)
                h = h + jnp.tanh(p["cross"]["gate"]).astype(h.dtype) * a_out
                f_in = _norm(cfg, h, p["cross"]["ln2"])
                h = h + ffn_apply(p["cross"]["ffn"], f_in, cfg.ffn_type)
                return h, {"self": scache, "cross": c["cross"]}
            h, new_caches[gname] = scan_or_unroll(body, h, (gp, cstack), unroll)
        elif gname == "dec":
            def body(h, xs):
                p, c = xs
                a_in = _norm(cfg, h, p["ln1"])
                a_out, sc = attn_decode(p["self"], cfg, a_in, c["self"], pos)
                h = h + a_out
                x_in = _norm(cfg, h, p["ln_x"])
                x_out, _ = attn_decode(p["cross"], cfg, x_in, c["cross"], pos,
                                       cross=True)
                h = h + x_out
                f_in = _norm(cfg, h, p["ln2"])
                h = h + ffn_apply(p["ffn"], f_in, cfg.ffn_type)
                return h, {"self": sc, "cross": c["cross"]}
            h, new_caches[gname] = scan_or_unroll(body, h, (gp, cstack), unroll)

    h = _norm(cfg, h, params["final_ln"])
    logits = shard_hint(h @ params["head"], "logits")[:, 0]
    new_caches["pos"] = pos + 1
    return logits, new_caches
