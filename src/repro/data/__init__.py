from repro.data.attacks import ATTACK_NAMES, AttackConfig, generate_attack_stream
from repro.data.synth import SynthConfig, generate_event_stream, generate_transactions
from repro.data.pipeline import build_communities, make_split_masks

__all__ = ["SynthConfig", "generate_event_stream", "generate_transactions",
           "build_communities", "make_split_masks",
           "ATTACK_NAMES", "AttackConfig", "generate_attack_stream"]
