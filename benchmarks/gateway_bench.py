"""Gateway benchmark — open-loop Poisson load through the full network path.

Boots a real :class:`repro.gateway.FraudGateway` (stdlib HTTP server) over a
streaming ``FraudService`` on an ephemeral port and drives it with a
**threaded client pool**: every checkout event is dispatched at its Poisson
arrival time on the wall clock (open loop — senders do not wait for earlier
responses before the next arrival is due), so queueing at the gateway is
real, not an artifact of a closed-loop client.  Scenarios:

* **nominal** — offered load the service absorbs: client-observed
  p50/p95/p99 wall latency and throughput through socket + JSON + scoring;
* **shed** — overload against ``admission.policy="shed"`` with a depth cap:
  the overflow must come back as **HTTP 429** (+ ``Retry-After``), measured
  as a shed rate;
* **block** — the same overload against ``policy="block"`` with a tiny
  ``block_max_wait_s``: timed-out stalls must come back as **HTTP 503**;
* **canary** — a deliberately perturbed shadow version at fraction 1.0 must
  trip the divergence alert, scraped back out of ``GET /metrics``.

The 429/503/alert observations are recorded as boolean **gates** in
``experiments/BENCH_gateway.json`` and enforced by
``tools/check_bench_schema.py`` — backpressure reaching the socket is an
invariant here, not a statistic.

Run:  PYTHONPATH=src python benchmarks/gateway_bench.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import threading
import time
import urllib.error
import urllib.request

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def _percentiles_ms(lat_s: list) -> dict:
    if not lat_s:
        return {"p50": 0.0, "p95": 0.0, "p99": 0.0, "mean": 0.0}
    a = np.asarray(lat_s, np.float64) * 1e3
    p50, p95, p99 = np.percentile(a, (50, 95, 99))
    return {"p50": float(p50), "p95": float(p95), "p99": float(p99),
            "mean": float(a.mean())}


def _post(url: str, body: dict, timeout: float = 30.0):
    req = urllib.request.Request(
        url, data=json.dumps(body).encode(),
        headers={"Content-Type": "application/json"})
    try:
        with urllib.request.urlopen(req, timeout=timeout) as r:
            return r.status, json.loads(r.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


def _ev_json(ev) -> dict:
    return {"order_id": ev.order_id, "snapshot": ev.snapshot,
            "entities": list(ev.entities), "features": ev.features.tolist(),
            "arrival": ev.arrival}


def _boot_gateway(params, cfg, *, admission: dict | None = None,
                  max_batch: int = 8):
    from repro.gateway import FraudGateway
    from repro.service import FraudService, ModelSection, ServiceConfig

    sc = ServiceConfig(
        mode="streaming", model=ModelSection.from_lnn_config(cfg),
    ).replace(engine={"max_batch": max_batch},
              admission=admission or {})
    svc = FraudService(sc, params=params).build().warmup()
    return FraudGateway(svc).start()


def drive_open_loop(url: str, events, rate_per_s: float,
                    num_clients: int = 8) -> dict:
    """Fire one ``POST /v1/score`` per event at Poisson arrival times on the
    wall clock, spread round-robin over ``num_clients`` sender threads.

    Each sender sleeps until its next event's scheduled send time and posts
    regardless of earlier responses (open loop, bounded only by the pool
    size); client-observed wall latency and the status-code mix come back
    per event."""
    rng = np.random.default_rng(0)
    send_at = np.cumsum(rng.exponential(1.0 / rate_per_s, size=len(events)))
    # pin every event to snapshot 0: the graph rejects event-time
    # regressions, and concurrent senders would otherwise race snapshots
    # backwards into 400s — this bench measures the HTTP/backpressure path,
    # not window semantics
    bodies = [{"event": {**_ev_json(ev), "snapshot": 0}} for ev in events]
    lat_s: list = []
    codes: dict[int, int] = {}
    lock = threading.Lock()
    t0 = time.perf_counter() + 0.05   # common epoch, senders already running

    def sender(idx: int):
        my_lat, my_codes = [], {}
        for i in range(idx, len(events), num_clients):
            delay = t0 + send_at[i] - time.perf_counter()
            if delay > 0:
                time.sleep(delay)
            t_send = time.perf_counter()
            status, _ = _post(url + "/v1/score", bodies[i])
            my_lat.append(time.perf_counter() - t_send)
            my_codes[status] = my_codes.get(status, 0) + 1
        with lock:
            lat_s.extend(my_lat)
            for c, n in my_codes.items():
                codes[c] = codes.get(c, 0) + n

    threads = [threading.Thread(target=sender, args=(k,))
               for k in range(num_clients)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    _post(url + "/admin/drain", {})
    return {
        "sent": len(events), "wall_s": wall,
        "throughput_eps": len(events) / wall,
        "latency_ms": _percentiles_ms(lat_s),
        "status_counts": {str(c): n for c, n in sorted(codes.items())},
        "ok": codes.get(200, 0),
        "rejected_429": codes.get(429, 0),
        "rejected_503": codes.get(503, 0),
    }


def run_gateway_bench(num_users: int = 150, num_rings: int = 4,
                      num_clients: int = 8, nominal_rate: float = 300.0,
                      overload_rate: float = 5000.0, seed: int = 0) -> dict:
    import jax

    from repro.core import LNNConfig, lnn_init
    from repro.data import SynthConfig, generate_event_stream

    events, g, _ = generate_event_stream(
        SynthConfig(num_users=num_users, num_rings=num_rings,
                    feature_noise=0.8, seed=seed),
        rate_per_s=400.0,
    )
    cfg = LNNConfig(num_gnn_layers=2, hidden_dim=32,
                    feat_dim=g.order_features.shape[1])
    params = lnn_init(jax.random.PRNGKey(seed), cfg)
    out: dict = {
        "n_events": len(events),
        "config": {"num_clients": num_clients, "nominal_rate": nominal_rate,
                   "overload_rate": overload_rate,
                   "hidden_dim": cfg.hidden_dim},
        "scenarios": {},
    }

    # -- nominal: the service absorbs the offered load; measure wire latency
    gw = _boot_gateway(params, cfg)
    try:
        out["scenarios"]["nominal"] = drive_open_loop(
            gw.url, events, nominal_rate, num_clients)
    finally:
        gw.close()

    # -- shed overload: depth-capped shed policy must reach the socket as 429
    gw = _boot_gateway(
        params, cfg, max_batch=32,
        admission={"max_queue_depth": 4, "policy": "shed"})
    try:
        out["scenarios"]["shed"] = drive_open_loop(
            gw.url, events, overload_rate, num_clients)
    finally:
        gw.close()
    shed = out["scenarios"]["shed"]
    shed["shed_rate"] = shed["rejected_429"] / max(1, shed["sent"])

    # -- block overload: timed-out bounded stalls must reach the socket as 503
    gw = _boot_gateway(
        params, cfg, max_batch=32,
        admission={"max_queue_depth": 4, "policy": "block",
                   "block_max_wait_s": 0.0})
    try:
        out["scenarios"]["block"] = drive_open_loop(
            gw.url, events, overload_rate, num_clients)
    finally:
        gw.close()

    # -- canary: a perturbed shadow version must trip the divergence alert,
    #    and the alert must be visible in the scraped /metrics text
    gw = _boot_gateway(params, cfg)
    try:
        _post(gw.url + "/admin/model",
              {"role": "canary", "from_version": 0, "perturb_scale": 2.0,
               "version": 9, "fraction": 1.0, "threshold": 0.05})
        for ev in events[: min(80, len(events))]:
            _post(gw.url + "/v1/score", {"event": _ev_json(ev)})
        _post(gw.url + "/admin/drain", {})
        with urllib.request.urlopen(gw.url + "/metrics", timeout=30) as r:
            metrics_text = r.read().decode()
        sh = gw.service.shadow_stats()
        out["canary"] = {
            "sampled": sh["sampled"], "alerts": sh["alerts"],
            "divergence_max": sh["divergence_max"],
            "alert_in_metrics":
                "repro_shadow_alert_active 1" in metrics_text.splitlines(),
        }
    finally:
        gw.close()

    # backpressure-at-the-socket gates (schema-enforced, not advisory)
    out["gates"] = {
        "shed_maps_to_429": shed["rejected_429"] > 0,
        "block_maps_to_503": out["scenarios"]["block"]["rejected_503"] > 0,
        "divergence_alert": bool(out["canary"]["alerts"] > 0
                                 and out["canary"]["alert_in_metrics"]),
    }
    return out


def main(smoke: bool = False) -> dict:
    if smoke:
        r = run_gateway_bench(num_users=50, num_rings=2, num_clients=4,
                              nominal_rate=400.0, overload_rate=4000.0)
    else:
        r = run_gateway_bench()

    print("\n# HTTP gateway (open-loop Poisson load, threaded client pool)")
    for name, s in r["scenarios"].items():
        pct = s["latency_ms"]
        print(f"  {name}: {s['sent']} sent @ {s['throughput_eps']:.0f} req/s "
              f"wall | p50={pct['p50']:.2f}ms p95={pct['p95']:.2f}ms "
              f"p99={pct['p99']:.2f}ms | 200={s['ok']} "
              f"429={s['rejected_429']} 503={s['rejected_503']}")
    c = r["canary"]
    print(f"  canary: sampled={c['sampled']} alerts={c['alerts']} "
          f"max_divergence={c['divergence_max']:.3f} "
          f"alert_in_metrics={c['alert_in_metrics']}")
    print(f"  gates: {r['gates']}")

    outdir = os.path.join("experiments", "smoke") if smoke else "experiments"
    os.makedirs(outdir, exist_ok=True)
    with open(os.path.join(outdir, "BENCH_gateway.json"), "w") as f:
        json.dump(r, f, indent=1)
    return r


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI smoke (seconds, not minutes)")
    main(smoke=ap.parse_args().smoke)
