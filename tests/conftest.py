import os
import sys

# NOTE: deliberately no XLA_FLAGS here — tests must see the real 1-CPU
# backend; only launch/dryrun.py creates the 512 placeholder devices.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import pytest


@pytest.fixture(scope="session")
def small_fraud_dataset():
    """A small synthetic fraud graph shared across tests."""
    from repro.data import SynthConfig, generate_transactions, make_split_masks
    from repro.data.pipeline import standardize_features

    cfg = SynthConfig(num_users=150, num_rings=4, feature_noise=0.8, seed=7)
    g, etypes = generate_transactions(cfg)
    split = make_split_masks(g.order_snapshot)
    feats, _ = standardize_features(g.order_features, split == 0)
    g.order_features = feats
    return g, etypes, split


@pytest.fixture(scope="session")
def small_communities(small_fraud_dataset):
    from repro.data import build_communities

    g, _, _ = small_fraud_dataset
    return build_communities(g, community_size=128, max_deg=16)


# --------------------------------------------------------------- skip audit
# Skips must not silently accumulate: every skip needs a reason on this
# allowlist, and CI's tier-1 job sets REPRO_FORBID_SKIPS=1 (hypothesis is
# installed there via requirements-ci.txt) so even the allowlisted reason
# turns into a hard failure — a test that skips in CI is a broken gate.
ALLOWED_SKIP_REASONS = frozenset({
    "hypothesis not installed",
})


def _allowed_skip_reasons() -> frozenset:
    if os.environ.get("REPRO_FORBID_SKIPS"):
        return frozenset()
    return ALLOWED_SKIP_REASONS


def _skip_reason(report) -> str:
    lr = report.longrepr
    msg = lr[2] if isinstance(lr, tuple) and len(lr) == 3 else str(lr)
    return msg.split("Skipped: ", 1)[-1].strip().strip("'\"()")


def _violation(kind: str, nodeid: str, reason: str) -> str:
    return (
        f"disallowed {kind} skip in {nodeid}: {reason!r} — every skip must "
        "carry a reason from ALLOWED_SKIP_REASONS in tests/conftest.py (and "
        "CI forbids skips entirely via REPRO_FORBID_SKIPS=1); fix the test "
        "or allowlist the reason explicitly")


#: collection-time violations (module-level importorskip); reported at the
#: end of the session because collect reports are categorized by the
#: terminal plugin before a conftest hook can rewrite their outcome
_collect_violations: list = []


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_makereport(item, call):
    outcome = yield
    report = outcome.get_result()
    if report.skipped:
        reason = _skip_reason(report)
        if reason not in _allowed_skip_reasons():
            report.outcome = "failed"
            report.longrepr = _violation("test", report.nodeid, reason)


def pytest_collectreport(report):
    # module-level pytest.importorskip skips at collection, producing no
    # per-test reports — audit those here so they can't hide either
    if report.skipped:
        reason = _skip_reason(report)
        if reason not in _allowed_skip_reasons():
            _collect_violations.append(
                _violation("collection", report.nodeid, reason))


def pytest_terminal_summary(terminalreporter):
    if _collect_violations:
        terminalreporter.section("disallowed collection skips", "-", red=True)
        for msg in _collect_violations:
            terminalreporter.line(msg)


def pytest_sessionfinish(session):
    if _collect_violations and session.exitstatus == 0:
        session.exitstatus = 1
