"""Continuous-learning demo: the full closed loop over the wire.

Boots a :class:`FraudGateway` with the learn plane enabled
(``learn.enabled`` + ``gateway.checkpoint_dir``) and drives a drifting
named-attack stream through it, entirely via HTTP:

  1. SERVE + TAP   — every ``POST /v1/score`` commits to the WAL; the
                     attached :class:`ContinuousLearner` taps committed
                     suffixes into labeled training examples;
  2. FINE-TUNE     — ``POST /admin/train`` ticks the learner: rolling-
                     window fine-tune of the LNN (+ hybrid GBDT refit),
                     candidate registered and shadow-scored on live
                     traffic;
  3. PROMOTE       — the candidate activates only after beating the
                     incumbent on shadow recall@budget by the configured
                     margin (decisions stream back in the train response);
  4. DRIFT         — mid-stream the ring signature changes shape; the
                     loop re-learns it from tapped traffic;
  5. ROLLBACK      — a deliberately-perturbed clone is hot-swapped in as
                     primary; the last-good shadow trips the divergence
                     alert and ``gateway.auto_rollback`` restores the
                     previous version — visible in ``GET /metrics`` as
                     ``repro_service_rollbacks_total``.

Run:  PYTHONPATH=src python examples/continuous_learning.py [--smoke]
"""
import argparse
import json
import os
import shutil
import sys
import tempfile
import urllib.error
import urllib.request

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.core import lnn_init
from repro.core.hetero import ENTITY_TYPE_NAMES
from repro.data.attacks import AttackConfig
from repro.gateway import serve_gateway
from repro.learn import drifting_attack_stream
from repro.service import ServiceConfig


def post(url: str, body: dict):
    req = urllib.request.Request(
        url, data=json.dumps(body).encode(),
        headers={"Content-Type": "application/json"})
    try:
        with urllib.request.urlopen(req, timeout=60) as r:
            return r.status, json.loads(r.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


def get(url: str):
    with urllib.request.urlopen(url, timeout=60) as r:
        return r.status, r.read().decode()


def ev_json(ev) -> dict:
    return {"order_id": ev.order_id, "snapshot": ev.snapshot,
            "entities": list(ev.entities), "features": ev.features.tolist(),
            "label": float(ev.label), "arrival": ev.arrival}


def main(smoke: bool = False):
    acfg = AttackConfig(num_buyers=50 if smoke else 100,
                        num_rings=3 if smoke else 5,
                        ring_size=5 if smoke else 6,
                        num_snapshots=8 if smoke else 12,
                        num_bursts=1, num_bin_runs=1, seed=0)
    events, patterns, split = drifting_attack_stream(acfg, rate_per_s=500.0)
    print(f"drifting stream: {len(events)} events, ring signature shifts "
          f"at index {split}")

    scratch = tempfile.mkdtemp(prefix="learn_demo_")
    config = ServiceConfig.from_dict({
        "mode": "streaming",
        "model": {"num_gnn_layers": 2, "hidden_dim": 16,
                  "feat_dim": int(events[0].features.shape[0]),
                  "mlp_dims": [16], "entity_types": list(ENTITY_TYPE_NAMES)},
        "engine": {"num_workers": 1, "max_batch": 8, "k_max": 4},
        "gateway": {"checkpoint_dir": os.path.join(scratch, "wal"),
                    "checkpoint_every_windows": 8, "checkpoint_keep_last": 3,
                    "auto_rollback": True},
        "learn": {"enabled": True, "min_window": 32, "max_window": 192,
                  "stride": 32, "steps": 6 if smoke else 12, "lr": 1e-2,
                  "head": "hybrid", "gbdt_trees": 10 if smoke else 20,
                  "min_eval": 16, "min_eval_pos": 2, "eval_max": 64,
                  "promote_margin": 0.0},
    })
    params = lnn_init(jax.random.PRNGKey(0), config.to_lnn_config())

    print("\n== boot: gateway with the learn plane attached ==")
    gw = serve_gateway(config, params)
    print(f"   {gw.url}  (WAL + auto-checkpoint + ContinuousLearner)")

    print("\n== serve + tap + train: one pass over the drifting stream ==")
    decisions = []
    for i, ev in enumerate(events):
        status, body = post(gw.url + "/v1/score", {"event": ev_json(ev)})
        assert status == 200, body
        if (i + 1) % 16 == 0:
            status, tick = post(gw.url + "/admin/train", {})
            assert status == 200, tick
            if tick.get("decision"):
                d = tick["decision"]
                decisions.append(d)
                print(f"   event {i:>4}: {d['action']:<8} "
                      f"candidate=v{d.get('candidate')} "
                      f"(state={tick['state']}, "
                      f"active=v{tick['model_version']})")

    status, stats = get(gw.url + "/v1/learn/stats")
    stats = json.loads(stats)
    print(f"\n== GET /v1/learn/stats ==")
    print(f"   state={stats['state']} fires={stats['trainer']['fires']} "
          f"tapped={stats['tap']['examples']} "
          f"promotions={stats['promotion']['promoted']} "
          f"rejections={stats['promotion']['rejected']}")
    promoted = [d for d in decisions if d["action"] == "promote"]
    assert promoted, "the loop should have promoted at least one fine-tune"

    print("\n== injected regression: perturbed clone as primary ==")
    svc = gw.service
    good = svc.model_version
    status, body = post(gw.url + "/admin/model",
                        {"role": "primary", "from_version": good,
                         "perturb_scale": 3.0})
    bad = body["model_version"]
    # canary shadow: the displaced good version re-scores all traffic;
    # with auto_rollback on, a sticky divergence alert restores it
    post(gw.url + "/admin/model",
         {"role": "canary", "version": good, "fraction": 1.0,
          "threshold": 0.05})
    for ev in events[-48:]:
        e = ev_json(ev)
        e["order_id"] += 5_000_000   # fresh ids: re-scored, not deduped
        e["snapshot"] = events[-1].snapshot
        post(gw.url + "/v1/score", {"event": e})
    post(gw.url + "/admin/drain", {})
    _, metrics = get(gw.url + "/metrics")
    wanted = ("repro_service_rollbacks_total", "repro_service_model_version",
              "repro_learn_promotions_total", "repro_learn_fires_total",
              "repro_shadow_alerts_total")
    for line in metrics.splitlines():
        if line.startswith(wanted):
            print(f"   {line}")
    restored = svc.model_version
    print(f"   v{bad} (perturbed) -> auto-rollback -> v{restored} "
          f"(rolled_back={restored == good})")
    assert restored == good, "auto-rollback should restore the last-good"

    rollbacks = svc.stats().rollbacks
    gw.close()
    shutil.rmtree(scratch)
    print(f"\ndone — promoted {len(promoted)} fine-tune(s), "
          f"{rollbacks} rollback(s), gateway closed")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for the CI learn-smoke job")
    main(smoke=ap.parse_args().smoke)
