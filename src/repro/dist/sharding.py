"""Sharding policy — the single place mesh-axis decisions live.

Two mechanisms, both *divisibility-safe* via :func:`resolve_spec` (a mesh
axis is silently dropped — replicated — when it does not divide the array
dimension, so every config compiles on every mesh factorization):

* **Entry shardings** (``param_sharding`` / ``batch_sharding`` /
  ``cache_sharding``) — NamedShardings attached at the jit boundary by the
  step builders in ``launch/steps.py``.
* **In-body hints** (``shard_hint`` / ``shard_spec``) — with-sharding
  constraints inside the traced function.  They are no-ops until a step
  builder calls :func:`enable_sharding_hints` with the active mesh, so the
  model code stays runnable un-sharded (unit tests, CPU smoke runs).

Layout policy:

* train:  FSDP (params shard the penultimate dim over ``data``) + TP
  (last dim over ``model``); optimizer moments inherit (steps.py).
* serve:  TP only — the last dim shards over ``model``, everything else is
  replicated so decode never all-gathers weights across ``data``.
* serve_ws (weight-stationary decode): weights keep the *train* layout and
  the decode batch shards over the ``model`` axis instead — steps.py flips
  the batch axes through ``enable_sharding_hints(mesh, batch_axes=...)``.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# key-space sharding (host-side): the hash the KV store and the speed-layer
# worker router share, so "the worker that owns an entity's KV shard" is a
# well-defined statement (see serve/kvstore.py and stream/workers.py)
# ---------------------------------------------------------------------------

_MASK64 = (1 << 64) - 1


def splitmix64(x: int) -> int:
    """Full splitmix64 avalanche — uniform over arbitrary integer keys."""
    x = (int(x) + 0x9E3779B97F4A7C15) & _MASK64
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & _MASK64
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & _MASK64
    return x ^ (x >> 31)


def stable_shard(key: int, num_shards: int) -> int:
    """Deterministic shard of ``key`` over ``num_shards`` buckets."""
    return (splitmix64(key) >> 32) % num_shards


def rendezvous_shard(key: int, num_shards: int) -> int:
    """Highest-random-weight (rendezvous) shard of ``key``.

    Unlike modulo placement, growing ``num_shards`` by one moves only
    ~1/(n+1) of the keys — and every moved key lands on the *new* shard,
    never migrating between surviving shards.  That minimal-movement
    property is what lets the speed-layer worker pool reshard explicitly
    (``ShardRouter.reshard``) without invalidating most workers' warm
    state.  O(num_shards) per lookup; shard counts here are small.
    """
    if num_shards < 1:
        raise ValueError("num_shards must be >= 1")
    k = int(key)
    best, best_w = 0, -1
    for s in range(num_shards):
        w = splitmix64(k ^ splitmix64(s))
        if w > best_w:
            best, best_w = s, w
    return best

# v5e per-chip HBM; used by the serve_auto heuristic (_fits_tp_only)
HBM_BYTES_PER_CHIP = 16e9
_HBM_HEADROOM = 0.6       # leave room for activations / cache / workspace

# Active-mesh context for in-body hints.  A plain module dict (not a
# threading.local): the step builders set it synchronously before tracing,
# and trace-time reads happen on the same thread.
_HINT_CTX: dict = {"mesh": None, "batch_axes": None}

_DEFAULT_BATCH_AXES = ("pod", "data")


def enable_sharding_hints(mesh, batch_axes=None) -> None:
    """Arm ``shard_hint``/``shard_spec`` with ``mesh``.

    ``batch_axes`` overrides which mesh axes the batch dimension shards
    over (the weight-stationary decode layout passes ``("model",)``);
    ``None`` restores the default data-parallel axes.
    """
    _HINT_CTX["mesh"] = mesh
    _HINT_CTX["batch_axes"] = tuple(batch_axes) if batch_axes else None


def _batch_axes(mesh) -> tuple:
    """Mesh axes the global batch shards over, in mesh order."""
    if _HINT_CTX["batch_axes"] is not None:
        return tuple(a for a in _HINT_CTX["batch_axes"] if a in mesh.axis_names)
    return tuple(a for a in mesh.axis_names if a in _DEFAULT_BATCH_AXES)


def model_axis_size() -> int:
    mesh = _HINT_CTX["mesh"]
    if mesh is None or "model" not in mesh.axis_names:
        return 1
    return int(mesh.shape["model"])


# ---------------------------------------------------------------------------
# divisibility-safe spec resolution
# ---------------------------------------------------------------------------

def _axes_size(mesh, entry) -> int | None:
    """Product of the named mesh axes; None when any axis is absent from
    the mesh (the spec entry must then be dropped, not crash)."""
    axes = entry if isinstance(entry, tuple) else (entry,)
    size = 1
    for a in axes:
        if a not in mesh.axis_names:
            return None
        size *= int(mesh.shape[a])
    return size


def resolve_spec(mesh, shape, spec: P) -> P:
    """Align ``spec`` to the trailing dims of ``shape`` and drop (replicate)
    every entry whose mesh axes are absent or whose product does not divide
    the dimension.

    Leading stack dims (e.g. the layer axis of a stacked cache) get ``None``
    padding, so one spec written for a single layer's array also applies to
    the [L, ...] stacked version.
    """
    entries = list(spec)
    if len(entries) > len(shape):
        # spec written for a higher-rank array: keep the trailing entries
        entries = entries[len(entries) - len(shape):]
    offset = len(shape) - len(entries)
    out = [None] * offset
    for dim, entry in zip(shape[offset:], entries):
        size = None if entry is None else _axes_size(mesh, entry)
        if size is not None and int(dim) % size == 0:
            out.append(entry)
        else:
            out.append(None)
    return P(*out)


def _constraint(x, spec: P):
    mesh = _HINT_CTX["mesh"]
    if mesh is None:
        return x
    resolved = resolve_spec(mesh, x.shape, spec)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, resolved))


# ---------------------------------------------------------------------------
# in-body hints
# ---------------------------------------------------------------------------

def shard_hint(x, kind: str):
    """Annotate an activation inside a traced function.

    kinds: ``'act'`` — [B, T, d] residual-stream activations, batch over the
    data axes, feature dim replicated (TP keeps weights sharded instead);
    ``'logits'`` — [B, T, V], vocab shards over ``model`` (the head matmul's
    natural output layout, avoids an all-gather before the softmax).
    """
    mesh = _HINT_CTX["mesh"]
    if mesh is None:
        return x
    b = _batch_axes(mesh)
    batch = b if len(b) != 1 else b[0]
    if kind == "act":
        spec = P(*([batch] + [None] * (x.ndim - 1)))
    elif kind == "logits":
        spec = P(*([batch] + [None] * (x.ndim - 2) + ["model"]))
    else:
        raise ValueError(f"unknown hint kind {kind!r}")
    return _constraint(x, spec)


def shard_spec(x, *axes):
    """Explicit per-dim constraint; ``'dp'`` expands to the batch axes."""
    mesh = _HINT_CTX["mesh"]
    if mesh is None:
        return x
    entries = []
    for a in axes:
        if a == "dp":
            b = _batch_axes(mesh)
            entries.append(b if len(b) != 1 else (b[0] if b else None))
        else:
            entries.append(a)
    return _constraint(x, P(*entries))


# ---------------------------------------------------------------------------
# entry shardings (jit boundary)
# ---------------------------------------------------------------------------

def _leaf_bytes(leaf) -> int:
    return int(np.prod(leaf.shape)) * jax.dtypes.canonicalize_dtype(leaf.dtype).itemsize


def _fits_tp_only(mesh, params_spec) -> bool:
    """True when TP-only replication of the weights fits per-chip HBM —
    the serve_auto resolver uses this to pick the decode weight layout."""
    total = sum(_leaf_bytes(leaf) for leaf in jax.tree_util.tree_leaves(params_spec))
    mdl = int(mesh.shape.get("model", 1)) if hasattr(mesh.shape, "get") else 1
    return total / max(mdl, 1) <= _HBM_HEADROOM * HBM_BYTES_PER_CHIP


def param_sharding(mesh, params_spec, mode: str = "train"):
    """NamedSharding tree for a parameter pytree.

    ``'train'``: FSDP+TP — penultimate dim over ``data``, last over
    ``model``.  ``'serve'``/``'serve_tp'``: TP only (last dim over
    ``model``), replicated over ``data``.  Vectors and scalars replicate.
    """
    data_axes = tuple(a for a in mesh.axis_names if a in _DEFAULT_BATCH_AXES)
    data = data_axes if len(data_axes) != 1 else data_axes[0]

    def one(leaf):
        if leaf.ndim < 2:
            spec = P()
        elif mode == "train":
            spec = P(*([None] * (leaf.ndim - 2) + [data, "model"]))
        else:
            spec = P(*([None] * (leaf.ndim - 1) + ["model"]))
        return NamedSharding(mesh, resolve_spec(mesh, leaf.shape, spec))

    return jax.tree_util.tree_map(one, params_spec)


def batch_sharding(mesh, batch_spec):
    """Shard the leading (batch) dim of every input leaf over the batch axes."""
    b = _batch_axes(mesh)
    batch = b if len(b) != 1 else b[0]

    def one(leaf):
        if leaf.ndim == 0:
            spec = P()
        else:
            spec = P(*([batch] + [None] * (leaf.ndim - 1)))
        return NamedSharding(mesh, resolve_spec(mesh, leaf.shape, spec))

    return jax.tree_util.tree_map(one, batch_spec)


def cache_sharding(mesh, cache_spec):
    """Decode-cache shardings.  Cache leaves are layer-stacked
    ([L, B, ...]) so the batch dim is axis 1; scalars (``pos``) replicate."""
    b = _batch_axes(mesh)
    batch = b if len(b) != 1 else b[0]

    def one(leaf):
        if leaf.ndim <= 1:
            spec = P()
        else:
            spec = P(*([None, batch] + [None] * (leaf.ndim - 2)))
        return NamedSharding(mesh, resolve_spec(mesh, leaf.shape, spec))

    return jax.tree_util.tree_map(one, cache_spec)
