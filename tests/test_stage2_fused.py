"""Fused Pallas stage-2 scoring vs the jnp online path (interpret mode).

The fused kernel is the speed-layer hot path: parity here is the
correctness oracle the serving engine and the stage-2 benchmark rely on.
Sweeps every micro-batch bucket size 1..max_batch (incl. odd, non-pow2
sizes the direct API accepts), all three GNN types, all-masked-neighbor
rows (cold entities), alternative tower/MLP depths, and multi-block grids.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import LNNConfig, lnn_init, lnn_order_tower, lnn_stage2_online
from repro.kernels.ops import stage2_score
from repro.kernels.stage2_score import flatten_stage2_params, stage2_score_pallas

RNG = np.random.default_rng(7)
GNN_TYPES = ["gcn", "gat", "sage"]


def _cfg(gnn_type, **kw):
    kw.setdefault("num_gnn_layers", 3)
    kw.setdefault("hidden_dim", 32)
    kw.setdefault("feat_dim", 8)
    return LNNConfig(gnn_type=gnn_type, **kw)


def _inputs(b, k, cfg, all_masked_rows=()):
    mask = (RNG.uniform(size=(b, k)) < 0.7).astype(np.float32)
    for i in all_masked_rows:
        mask[i] = 0.0
    # zero rows where masked — exactly what KVStore.lookup_batch returns
    emb = RNG.normal(size=(b, k, cfg.hidden_dim)).astype(np.float32) * mask[:, :, None]
    feats = RNG.normal(size=(b, cfg.feat_dim)).astype(np.float32)
    return jnp.asarray(emb), jnp.asarray(mask), jnp.asarray(feats)


def _ref(params, cfg, emb, mask, feats):
    tower = lnn_order_tower(params, cfg, feats)
    return np.asarray(lnn_stage2_online(params, cfg, emb, mask, feats, tower))


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("gnn_type", GNN_TYPES)
@pytest.mark.parametrize("b", [1, 2, 3, 5, 8, 13, 16])
def test_fused_matches_online_across_batch_sizes(gnn_type, b):
    cfg = _cfg(gnn_type)
    params = lnn_init(jax.random.PRNGKey(1), cfg)
    emb, mask, feats = _inputs(b, 8, cfg, all_masked_rows=(0,) if b > 2 else ())
    out = np.asarray(stage2_score(params, gnn_type, emb, mask, feats))
    np.testing.assert_allclose(out, _ref(params, cfg, emb, mask, feats),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("gnn_type", GNN_TYPES)
def test_fused_all_rows_masked(gnn_type):
    """Cold start: every entity slot empty (zero mask) must score finitely
    and match the jnp path — orders without history still get a logit."""
    cfg = _cfg(gnn_type)
    params = lnn_init(jax.random.PRNGKey(2), cfg)
    b, k = 4, 8
    emb = jnp.zeros((b, k, cfg.hidden_dim), jnp.float32)
    mask = jnp.zeros((b, k), jnp.float32)
    feats = jnp.asarray(RNG.normal(size=(b, cfg.feat_dim)).astype(np.float32))
    out = np.asarray(stage2_score(params, gnn_type, emb, mask, feats))
    assert np.all(np.isfinite(out))
    np.testing.assert_allclose(out, _ref(params, cfg, emb, mask, feats),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("gnn_type", GNN_TYPES)
@pytest.mark.parametrize("layers,mlp_dims", [(2, (16,)), (4, (64, 32, 16))])
def test_fused_alternative_depths(gnn_type, layers, mlp_dims):
    """Tower depth (num_gnn_layers-1) and MLP depth unroll at trace time —
    both must track the config, not just the defaults."""
    cfg = _cfg(gnn_type, num_gnn_layers=layers, mlp_dims=mlp_dims)
    params = lnn_init(jax.random.PRNGKey(3), cfg)
    emb, mask, feats = _inputs(6, 4, cfg, all_masked_rows=(1,))
    out = np.asarray(stage2_score(params, gnn_type, emb, mask, feats))
    np.testing.assert_allclose(out, _ref(params, cfg, emb, mask, feats),
                               atol=1e-5, rtol=1e-5)


def test_fused_multi_block_grid():
    """block_b < B forces a multi-step grid incl. a ragged final block."""
    cfg = _cfg("gcn")
    params = lnn_init(jax.random.PRNGKey(4), cfg)
    emb, mask, feats = _inputs(13, 8, cfg, all_masked_rows=(12,))
    flat = flatten_stage2_params(params, "gcn")
    out = np.asarray(stage2_score_pallas(emb, mask, feats, flat, gnn_type="gcn",
                                         block_b=4, interpret=True))
    np.testing.assert_allclose(out, _ref(params, cfg, emb, mask, feats),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("gnn_type", GNN_TYPES)
def test_use_pallas_flag_routes_to_fused(gnn_type):
    """LNNConfig.use_pallas swaps lnn_stage2_online onto the fused kernel;
    a caller-supplied order_h is ignored there (the kernel recomputes the
    tower), which is exact because the tower is a pure function of feats."""
    cfg = _cfg(gnn_type)
    cfg_p = dataclasses.replace(cfg, use_pallas=True)
    params = lnn_init(jax.random.PRNGKey(5), cfg)
    emb, mask, feats = _inputs(8, 8, cfg)
    ref = _ref(params, cfg, emb, mask, feats)
    out = np.asarray(lnn_stage2_online(params, cfg_p, emb, mask, feats))
    np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-5)
    # order_h omitted on the jnp path recomputes the tower too
    out2 = np.asarray(lnn_stage2_online(params, cfg, emb, mask, feats))
    np.testing.assert_allclose(out2, ref, atol=1e-6)
