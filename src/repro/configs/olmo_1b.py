"""olmo-1b — dense decoder with non-parametric LayerNorm [arXiv:2402.00838].

16L, d_model=2048, 16 heads (head_dim 128), kv=16 (MHA), d_ff=8192,
vocab=50304.  OLMo's LN carries no learnable scale/bias.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="olmo-1b",
    arch_type="dense",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=8192,
    vocab_size=50304,
    nonparametric_ln=True,
    source="[arXiv:2402.00838]",
)
