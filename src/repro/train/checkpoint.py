"""Checkpointing: save/restore arbitrary pytrees to a single ``.npz`` file.

Layout: leaves are flattened with '/'-joined key paths as npz keys; the
treedef is reconstructed from the example pytree passed to ``load_checkpoint``
(the standard "restore into like-structured template" convention, same as
orbax's restore_args, without the dependency).
"""
from __future__ import annotations

import os
import tempfile

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            elif hasattr(p, "name"):
                parts.append(str(p.name))
            else:
                parts.append(str(p))
        out["/".join(parts)] = np.asarray(leaf)
    return out


def save_checkpoint(path: str, tree, step: int | None = None) -> str:
    """Atomically write pytree ``tree`` to ``path`` (.npz)."""
    os.makedirs(os.path.dirname(os.path.abspath(path)) or ".", exist_ok=True)
    payload = _flatten_with_paths(tree)
    if step is not None:
        payload["__step__"] = np.asarray(step, np.int64)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)), suffix=".tmp")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **payload)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)
    return path


def load_checkpoint(path: str, like):
    """Restore a pytree saved by ``save_checkpoint`` into the structure of ``like``.

    Returns (tree, step) where step is None if absent.
    """
    with np.load(path) as data:
        step = int(data["__step__"]) if "__step__" in data else None
        restored_flat = []
        paths_leaves = jax.tree_util.tree_flatten_with_path(like)
        for path, leaf in paths_leaves[0]:
            parts = []
            for p in path:
                if hasattr(p, "key"):
                    parts.append(str(p.key))
                elif hasattr(p, "idx"):
                    parts.append(str(p.idx))
                elif hasattr(p, "name"):
                    parts.append(str(p.name))
                else:
                    parts.append(str(p))
            key = "/".join(parts)
            if key not in data:
                raise KeyError(f"checkpoint {path!r} missing key {key!r}")
            arr = data[key]
            want_shape = tuple(getattr(leaf, "shape", arr.shape))
            if tuple(arr.shape) != want_shape:
                raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {want_shape}")
            restored_flat.append(arr)
        tree = jax.tree_util.tree_unflatten(paths_leaves[1], restored_flat)
    return tree, step
