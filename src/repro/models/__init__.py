from repro.models.config import INPUT_SHAPES, ArchConfig, InputShape
from repro.models.hybrid import (
    HybridModel,
    is_hybrid_checkpoint,
    load_hybrid,
    save_hybrid,
    train_hybrid,
)
from repro.models.transformer import (
    decode_step,
    forward,
    forward_train,
    init_cache,
    init_params,
)

__all__ = [
    "INPUT_SHAPES",
    "ArchConfig",
    "InputShape",
    "HybridModel",
    "is_hybrid_checkpoint",
    "load_hybrid",
    "save_hybrid",
    "train_hybrid",
    "decode_step",
    "forward",
    "forward_train",
    "init_cache",
    "init_params",
]
