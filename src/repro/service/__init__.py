"""``repro.service`` — the one typed serving API.

* :class:`ServiceConfig` (+ its sections) — a single serializable config
  tree subsuming ``LNNConfig`` + ``EngineConfig`` + KV-store kwargs, with
  JSON round-trip and unknown-key rejection;
* :class:`ScoreRequest` / :class:`ScoreResponse` / :class:`ServiceStats` —
  the typed request/response vocabulary shared by every path;
* :class:`FraudService` — one facade with an explicit lifecycle
  (``build -> warmup -> serve -> drain -> close``), ``mode="batch"`` or
  ``mode="streaming"``, versioned model hot-swap, and admission control.

See ``docs/serving_api.md`` for the lifecycle diagram and the migration
table from the legacy entry points.

Exports resolve lazily (PEP 562): ``repro.service.types`` stays importable
from ``repro.stream``/``repro.serve`` leaf modules without a cycle, and
importing just the config machinery doesn't drag the whole engine in.
"""
from __future__ import annotations

__all__ = [
    "AdmissionSection",
    "EngineSection",
    "FraudService",
    "LearnSection",
    "ModelSection",
    "RefreshSection",
    "ScoreRequest",
    "ScoreResponse",
    "ServiceConfig",
    "ServiceLifecycleError",
    "ServiceStats",
    "StoreSection",
    "build_service",
]

_HOMES = {
    "AdmissionSection": "repro.service.config",
    "EngineSection": "repro.service.config",
    "LearnSection": "repro.service.config",
    "ModelSection": "repro.service.config",
    "RefreshSection": "repro.service.config",
    "ServiceConfig": "repro.service.config",
    "StoreSection": "repro.service.config",
    "ScoreRequest": "repro.service.types",
    "ScoreResponse": "repro.service.types",
    "ServiceStats": "repro.service.types",
    "FraudService": "repro.service.service",
    "ServiceLifecycleError": "repro.service.service",
    "build_service": "repro.service.service",
}


def __getattr__(name: str):
    home = _HOMES.get(name)
    if home is None:
        raise AttributeError(f"module 'repro.service' has no attribute {name!r}")
    import importlib

    value = getattr(importlib.import_module(home), name)
    globals()[name] = value    # cache: next access skips __getattr__
    return value


def __dir__():
    return sorted(set(globals()) | set(__all__))
