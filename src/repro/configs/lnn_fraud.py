"""The paper's own model: LNN on DDS graphs (fraud detection).

Not part of the transformer zoo; exposes the LNNConfig used by the paper
reproduction benchmarks and examples, plus the canonical ``ServiceConfig``
serving artifacts built on it (``repro.service``).
"""
from repro.core.lnn import LNNConfig
from repro.service import ModelSection, ServiceConfig

CONFIG = LNNConfig(
    gnn_type="gcn",
    num_gnn_layers=3,
    hidden_dim=64,
    mlp_dims=(64, 32),
    feat_dim=48,          # 12 raw + 36 GBDT-encoded (paper §4.2 encoding)
    pos_weight=3.0,
)

# the one serving artifact benches/examples derive from (`.replace(...)`
# for local overrides): same model, streaming Lambda loop, exact refresh
SERVICE = ServiceConfig(
    mode="streaming",
    model=ModelSection.from_lnn_config(CONFIG),
)

# offline batch/speed split over a static store (the old LambdaPipeline)
SERVICE_BATCH = SERVICE.replace(mode="batch")
