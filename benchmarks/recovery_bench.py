"""Recovery benchmark — what crash consistency costs and what it buys.

Measures the three prices of the ``repro.stream.checkpoint`` layer:

* **checkpoint write latency** — wall seconds for one atomic checkpoint of
  the full streaming state (plus its on-disk size);
* **restore latency vs log length** — a crash is simulated at several
  stream positions by snapshotting the durable directory and restoring
  from the copy: replaying a longer WAL suffix must cost proportionally
  more, which is exactly the cost a checkpoint bounds;
* **checkpoint payoff** — restore-from-checkpoint vs genesis restore at
  the same stream position (the replay suffix collapses to ~0 records).

And one **gate**: after the final crash-restore, resuming the feed must
produce bit-identical scores and KV bytes vs an uninterrupted oracle —
recorded as ``gates.recovery_bit_identical`` in
``experiments/BENCH_recovery.json`` and enforced by
``tools/check_bench_schema.py``.  A recovery bench whose recovery is wrong
measures nothing.

Run:  PYTHONPATH=src python benchmarks/recovery_bench.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def _dir_bytes(path: str) -> int:
    total = 0
    for base, _, files in os.walk(path):
        total += sum(os.path.getsize(os.path.join(base, f)) for f in files)
    return total


def _restore_from_copy(root: str, scratch: str):
    """Copy the durable dir (the crash leaves it frozen) and restore from
    the copy, so the live service can keep appending to the original."""
    from repro.service import FraudService

    snap = tempfile.mkdtemp(dir=scratch)
    shutil.rmtree(snap)
    shutil.copytree(root, snap)
    t0 = time.perf_counter()
    svc = FraudService.restore(snap)
    dt = time.perf_counter() - t0
    return svc, dt, snap


def run_recovery_bench(*, num_users=40, num_rings=2, n_events=60,
                       num_workers=1, max_batch=4, seed=3) -> dict:
    import jax

    from repro.core import LNNConfig, lnn_init
    from repro.data import SynthConfig, generate_event_stream
    from repro.service import FraudService, ModelSection, ServiceConfig

    events, g, _ = generate_event_stream(
        SynthConfig(num_users=num_users, num_rings=num_rings,
                    feature_noise=0.8, seed=seed),
        rate_per_s=500.0)
    events = events[:n_events]
    n_events = len(events)
    cfg = LNNConfig(num_gnn_layers=2, hidden_dim=16,
                    feat_dim=g.order_features.shape[1], mlp_dims=(16,))
    params = lnn_init(jax.random.PRNGKey(0), cfg)
    sc = ServiceConfig(
        mode="streaming", model=ModelSection.from_lnn_config(cfg),
    ).replace(engine={"num_workers": num_workers, "max_batch": max_batch})

    def build():
        return FraudService(sc, params=params).build()

    # --- the oracle: uninterrupted, no WAL
    oracle = build()
    oracle_resp = []
    for ev in events:
        oracle_resp.extend(oracle.submit(ev))
    oracle_resp.extend(oracle.drain())
    oracle_scores = {r.request.tag.order_id: r.score
                     for r in oracle_resp if r.admitted}
    oracle_store = {k: (e.value.tobytes(), e.model_version)
                    for shard in oracle.store._shards
                    for k, e in shard.items()}

    scratch = tempfile.mkdtemp(prefix="bench_recovery_")
    root = os.path.join(scratch, "wal")
    svc = build().enable_wal(root)

    # --- replay-suffix cost vs log length (no checkpoint yet)
    marks = sorted({max(1, n_events // 4), n_events // 2,
                    (3 * n_events) // 4, n_events})
    curve = []
    checkpoint_rec = None
    ckpt_at = n_events // 2
    delivered = []   # the client's view: responses handed out pre-crash
    for i, ev in enumerate(events):
        delivered.extend(svc.submit(ev))
        pos = i + 1
        if pos in marks:
            restored, dt, snap = _restore_from_copy(root, scratch)
            curve.append({
                "events_fed": pos,
                "log_records": int(svc.applied_seq),
                "replayed_records":
                    int(restored.last_recovery["replayed_records"]),
                "restore_s": dt,
            })
            shutil.rmtree(snap)
        if pos == ckpt_at:
            t0 = time.perf_counter()
            path = svc.checkpoint()
            write_s = time.perf_counter() - t0
            checkpoint_rec = {
                "write_s": write_s,
                "size_bytes": _dir_bytes(path),
                "applied_seq": int(svc.applied_seq),
            }

    # --- checkpoint payoff at end-of-stream: suffix replay vs full replay
    with_ckpt, with_ckpt_s, snap1 = _restore_from_copy(root, scratch)
    replayed_with = int(with_ckpt.last_recovery["replayed_records"])
    # drop the committed checkpoints from a copy -> genesis restore
    genesis_root = tempfile.mkdtemp(dir=scratch)
    shutil.rmtree(genesis_root)
    shutil.copytree(root, genesis_root)
    shutil.rmtree(os.path.join(genesis_root, "checkpoints"))
    t0 = time.perf_counter()
    from repro.service import FraudService as _FS
    genesis = _FS.restore(genesis_root)
    genesis_s = time.perf_counter() - t0
    replayed_genesis = int(genesis.last_recovery["replayed_records"])

    # --- the gate: resume the restored run to completion, compare
    resumed = with_ckpt
    resume_at = resumed.engine.ingester.num_events
    tail = []
    for ev in events[resume_at:]:
        tail.extend(resumed.submit(ev))
    tail.extend(resumed.drain())
    # exactly-once means replay does NOT re-deliver what the client already
    # has — merge pre-crash deliveries with replayed + resumed responses,
    # requiring any overlap to agree bit-for-bit
    rec_resp = delivered + list(resumed.last_recovery["responses"]) + tail
    rec_scores: dict = {}
    duplicates_agree = True
    for r in rec_resp:
        if not r.admitted:
            continue
        oid = r.request.tag.order_id
        if oid in rec_scores and rec_scores[oid] != r.score:
            duplicates_agree = False
        rec_scores[oid] = r.score
    rec_store = {k: (e.value.tobytes(), e.model_version)
                 for shard in resumed.store._shards
                 for k, e in shard.items()}
    # scores delivered before the simulated crash are a subset of the
    # oracle's by construction; the gate compares everything recoverable
    bit_identical = (
        duplicates_agree
        and rec_scores == oracle_scores
        and rec_store == oracle_store)

    shutil.rmtree(scratch)
    return {
        "n_events": n_events,
        "config": {"num_workers": num_workers, "max_batch": max_batch,
                   "checkpoint_at": ckpt_at, "hidden_dim": 16},
        "checkpoint": checkpoint_rec,
        "replay_curve": curve,
        "restore": {
            "with_checkpoint_s": with_ckpt_s,
            "genesis_s": genesis_s,
            "replayed_with_checkpoint": replayed_with,
            "replayed_genesis": replayed_genesis,
        },
        "gates": {"recovery_bit_identical": bool(bit_identical)},
    }


def main(smoke: bool = False) -> dict:
    if smoke:
        r = run_recovery_bench(n_events=48)
    else:
        r = run_recovery_bench(num_users=120, num_rings=4, n_events=300)

    ck = r["checkpoint"]
    rs = r["restore"]
    print("\n# Crash recovery (checkpoint write / restore latency, "
          "replay-suffix cost)")
    print(f"  checkpoint: write={ck['write_s']*1e3:.1f}ms "
          f"size={ck['size_bytes']/1024:.1f}KiB "
          f"@seq={ck['applied_seq']}")
    for p in r["replay_curve"]:
        print(f"  restore@{p['events_fed']:>4} events: "
              f"{p['restore_s']*1e3:7.1f}ms "
              f"(replayed {p['replayed_records']} records)")
    print(f"  end-of-stream: with_checkpoint={rs['with_checkpoint_s']*1e3:.1f}ms "
          f"(replayed {rs['replayed_with_checkpoint']}) vs "
          f"genesis={rs['genesis_s']*1e3:.1f}ms "
          f"(replayed {rs['replayed_genesis']})")
    print(f"  gates: {r['gates']}")

    outdir = os.path.join("experiments", "smoke") if smoke else "experiments"
    os.makedirs(outdir, exist_ok=True)
    with open(os.path.join(outdir, "BENCH_recovery.json"), "w") as f:
        json.dump(r, f, indent=1)
    return r


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI smoke (seconds, not minutes)")
    main(smoke=ap.parse_args().smoke)
