"""LNN (Lambda Neural Network) correctness: the two-stage split must equal
the monolithic forward — the paper's deployment-correctness claim."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    LNNConfig,
    lnn_forward,
    lnn_init,
    lnn_loss,
    lnn_order_tower,
    lnn_stage1,
    lnn_stage2_batch,
    lnn_stage2_online,
)

GNN_TYPES = ["gcn", "gat", "sage"]


@pytest.fixture(scope="module", params=GNN_TYPES)
def lnn_setup(request, small_communities):
    feat_dim = small_communities[0].graph.features.shape[1]
    cfg = LNNConfig(gnn_type=request.param, num_gnn_layers=3, hidden_dim=32,
                    feat_dim=feat_dim)
    params = lnn_init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_forward_is_stage2_of_stage1(lnn_setup, small_communities):
    cfg, params = lnn_setup
    for b in small_communities[:3]:
        h = lnn_stage1(params, cfg, b.graph)
        np.testing.assert_allclose(
            np.asarray(lnn_forward(params, cfg, b.graph)),
            np.asarray(lnn_stage2_batch(params, cfg, h, b.graph)),
            atol=1e-6,
        )


def test_order_tower_matches_stage1(lnn_setup, small_communities):
    """An order's stage-1 state must be recomputable from raw features alone
    (final-hop edges are excluded from stage 1) — otherwise online serving
    would need intermediate graph states that are not in the KV store."""
    cfg, params = lnn_setup
    for b in small_communities[:3]:
        n_orders = b.global_order_ids.size
        h = lnn_stage1(params, cfg, b.graph)
        tower = lnn_order_tower(params, cfg, b.graph.features[:n_orders])
        np.testing.assert_allclose(np.asarray(tower), np.asarray(h[:n_orders]),
                                   atol=1e-6)


def test_online_path_matches_batch_path(lnn_setup, small_communities):
    cfg, params = lnn_setup
    for b in small_communities[:3]:
        n_orders = b.global_order_ids.size
        h = np.asarray(lnn_stage1(params, cfg, b.graph))
        full = np.asarray(lnn_stage2_batch(params, cfg, jnp.asarray(h), b.graph))
        K = int(b.graph.max_deg)
        emb = np.zeros((n_orders, K, cfg.hidden_dim), np.float32)
        msk = np.zeros((n_orders, K), np.float32)
        for o, hops in b.dds.last_hop.items():
            for j, (_, _, nid) in enumerate(hops[:K]):
                emb[o, j] = h[nid]
                msk[o, j] = 1.0
        tower = lnn_order_tower(params, cfg, b.graph.features[:n_orders])
        online = lnn_stage2_online(params, cfg, jnp.asarray(emb), jnp.asarray(msk),
                                   b.graph.features[:n_orders], tower)
        np.testing.assert_allclose(np.asarray(online), full[:n_orders], atol=1e-5)


def test_loss_finite_and_grads_flow(lnn_setup, small_communities):
    cfg, params = lnn_setup
    b = small_communities[0]
    loss, grads = jax.value_and_grad(lnn_loss)(params, cfg, b.graph)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.abs(g).sum()) for g in jax.tree_util.tree_leaves(grads))
    assert gnorm > 0, "no gradient signal"
    assert np.isfinite(gnorm)


def test_padding_rows_do_not_affect_scores(lnn_setup, small_communities):
    """Growing the node padding budget must not change any real node's score."""
    from repro.core.graph import pad_graph

    cfg, params = lnn_setup
    b = small_communities[0]
    n_real = b.dds.coo.num_nodes
    g1 = pad_graph(b.dds.coo, num_nodes=n_real + 8, max_deg=b.graph.max_deg)
    g2 = pad_graph(b.dds.coo, num_nodes=n_real + 64, max_deg=b.graph.max_deg)
    s1 = np.asarray(lnn_forward(params, cfg, g1))[:n_real]
    s2 = np.asarray(lnn_forward(params, cfg, g2))[:n_real]
    np.testing.assert_allclose(s1, s2, atol=1e-6)
