"""Typed serving-API datatypes — ``repro.service.types``.

One request/response vocabulary for every serving path.  The batch
pipeline's ``{'features': ..., 'entity_keys': ...}`` dicts and the
streaming engine's private request class used to be two incompatible
spellings of the same thing; both now speak :class:`ScoreRequest` /
:class:`ScoreResponse` (``repro.stream.microbatch`` re-exports them under
its historical names ``ScoreRequest`` / ``ScoredResult``).

This module is a dependency leaf — numpy only — so ``repro.serve`` and
``repro.stream`` can both import it without cycles.
"""
from __future__ import annotations

from dataclasses import dataclass, field, fields

import numpy as np


@dataclass
class ScoreRequest:
    """One checkout to score.

    ``features`` are the raw order features ([F] float32); ``entity_keys``
    the exact ``(entity, t_e)`` KV keys of its final-hop in-edges (empty =
    cold start).  ``arrival`` is the virtual arrival time the streaming
    scheduler queues on; batch-mode callers may leave it 0.  ``tag`` is a
    caller-opaque id (the engine stores the :class:`CheckoutEvent` there);
    ``seq`` is the pool's submission-order reorder key.
    """

    features: np.ndarray          # [F]
    entity_keys: list             # [(entity, t_e)]
    arrival: float = 0.0          # virtual arrival time (s)
    tag: object = None            # caller-opaque id (e.g. CheckoutEvent)
    seq: int = -1                 # submission order (pool reorder key)

    @classmethod
    def from_legacy(cls, r: "ScoreRequest | dict") -> "ScoreRequest":
        """Accept the pre-`repro.service` dict spelling."""
        if isinstance(r, ScoreRequest):
            return r
        return cls(features=np.asarray(r["features"], np.float32),
                   entity_keys=list(r["entity_keys"]),
                   arrival=float(r.get("arrival", 0.0)))


@dataclass
class ScoreResponse:
    """One scored (or shed) checkout.

    ``model_version`` is the parameter version whose jit cache scored the
    flush (hot-swap observability); ``admitted=False`` marks a request the
    admission controller shed — its ``score`` is NaN and it never entered a
    micro-batch.
    """

    request: ScoreRequest
    score: float
    staleness: int = -1           # max snapshot-staleness over served slots
    queued_s: float = 0.0         # arrival -> flush trigger (virtual)
    service_s: float = 0.0        # batch compute wall time (shared)
    batch_size: int = 1           # real requests in the flush
    worker: int = 0               # speed-layer worker that scored the flush
    model_version: int = 0        # param version whose jit cache scored it
    admitted: bool = True         # False = shed by admission control


@dataclass
class ServiceStats:
    """One structured snapshot of a :class:`~repro.service.FraudService`.

    Everything a dashboard needs: lifecycle state, admission accounting,
    model-registry state, per-version score counts, canary/shadow divergence
    state, micro-batch/flush counters, batch-layer refresh counters, and
    KV-store internals.  ``to_dict``/``from_dict`` round-trip losslessly
    through JSON — the gateway's ``/v1/stats`` body and ``/metrics`` render
    are both derived from this ONE snapshot (no ad-hoc dicts), so every
    counter that exists here exists on the wire
    (``tests/test_service.py::test_service_stats_json_roundtrip``).
    """

    mode: str = ""                          # "batch" | "streaming"
    state: str = ""                         # lifecycle state
    model_version: int = 0                  # active param version
    model_versions: tuple = ()              # every registered version
    model_swaps: int = 0                    # load_model calls after build
    requests: int = 0                       # offered to the service
    scored: int = 0                         # responses actually scored
    shed: int = 0                           # rejected by admission (policy=shed)
    blocked: int = 0                        # stalled by admission (policy=block)
    block_timeouts: int = 0                 # block stalls that timed out -> shed
    queue_depth: int = 0                    # queued right now (streaming)
    queue_depth_peak: int = 0               # high-water mark since build
    in_flight_peak: int = 0                 # busy-worker high-water mark
    flushes: int = 0
    refreshes: int = 0
    entities_written: int = 0
    model_stale_reads: int = 0              # KV hits stamped by an older model
    store_size: int = 0
    rollbacks: int = 0                      # rollback_model() calls since build
    last_good_version: int | None = None    # rollback target (None = no target)
    scores_by_version: dict = field(default_factory=dict)  # version -> scored
    shadow: dict = field(default_factory=dict)   # canary/shadow divergence state
    store_stats: dict = field(default_factory=dict)
    # one tear-free per-worker snapshot (WorkerPool.worker_summary rows:
    # queue depth, flushes, steals, restarts, liveness) — the gateway's
    # repro_worker_* metric families render from THIS list, never from a
    # second racy read of the pool
    workers: list = field(default_factory=list)
    extra: dict = field(default_factory=dict)

    def to_dict(self) -> dict:
        """JSON-safe flatten.  ``scores_by_version`` keys become strings
        (JSON object keys always are); ``from_dict`` restores them to ints,
        so ``from_dict(json.loads(json.dumps(to_dict())))`` is lossless."""
        d = dict(self.__dict__)
        d["model_versions"] = list(self.model_versions)
        d["scores_by_version"] = {
            str(k): v for k, v in self.scores_by_version.items()
        }
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "ServiceStats":
        """Inverse of :meth:`to_dict` (e.g. to re-type a ``/v1/stats`` body).
        Unknown keys are rejected — a drifted producer fails loudly."""
        names = {f.name for f in fields(cls)}
        unknown = sorted(set(d) - names)
        if unknown:
            raise ValueError(
                f"unknown key(s) {unknown} in ServiceStats dict — "
                f"valid keys: {sorted(names)}")
        d = dict(d)
        if "model_versions" in d:
            d["model_versions"] = tuple(d["model_versions"])
        if "scores_by_version" in d:
            d["scores_by_version"] = {
                int(k): v for k, v in d["scores_by_version"].items()
            }
        return cls(**d)
