"""Graph partition — paper §3.2 'Graph Partition'.

The paper partitions the months-long static transaction graph with
Power Iteration Clustering (PIC, Lin & Cohen 2010 — expected partition size
~1e6) and then refines with METIS (Karypis & Kumar) to communities of ~1024
nodes ("the business understanding for a gang of fraudsters"), training in
ClusterGCN flavor on the mini-communities.

Here both stages are implemented directly (no Spark / metis binding):

* ``power_iteration_clustering`` — the PIC algorithm on the normalized
  affinity matrix of the *order-entity bipartite* graph projected to a
  symmetric adjacency; early-stops on the acceleration criterion from the
  paper and 1-D k-means clusters the resulting pseudo-eigenvector.
* ``refine_partition`` — METIS-style size-balanced refinement: connected
  components inside each PIC cluster, then BFS-grown chunks capped at the
  target community size (greedy multilevel coarsening is overkill at our
  synthetic scale; BFS growth preserves locality, which is what ClusterGCN
  needs).
"""
from __future__ import annotations

import numpy as np


def _csr_from_edges(num_nodes: int, src: np.ndarray, dst: np.ndarray):
    """Symmetric CSR adjacency (indices only) from an undirected edge list."""
    s = np.concatenate([src, dst])
    d = np.concatenate([dst, src])
    order = np.argsort(s, kind="stable")
    s, d = s[order], d[order]
    indptr = np.zeros(num_nodes + 1, np.int64)
    np.add.at(indptr, s + 1, 1)
    indptr = np.cumsum(indptr)
    return indptr, d


def power_iteration_clustering(
    num_nodes: int,
    src: np.ndarray,
    dst: np.ndarray,
    num_clusters: int,
    max_iter: int = 50,
    tol: float = 1e-5,
    seed: int = 0,
) -> np.ndarray:
    """PIC (Lin & Cohen 2010): truncated power iteration of W = D^-1 A.

    Returns an int cluster id per node.  Isolated nodes go to cluster 0.
    """
    indptr, indices = _csr_from_edges(num_nodes, src, dst)
    deg = np.diff(indptr).astype(np.float64)
    inv_deg = np.where(deg > 0, 1.0 / np.maximum(deg, 1), 0.0)

    rng = np.random.default_rng(seed)
    v = rng.uniform(0.0, 1.0, num_nodes)
    v /= np.abs(v).sum()

    prev_delta = None
    for _ in range(max_iter):
        # v_new = D^-1 A v  (row-normalized affinity)
        acc = np.zeros(num_nodes)
        # segment sum: acc[i] = sum_j in nbr(i) v[j]
        np.add.at(acc, np.repeat(np.arange(num_nodes), np.diff(indptr)), v[indices])
        v_new = acc * inv_deg
        norm = np.abs(v_new).sum()
        if norm == 0:
            break
        v_new /= norm
        delta = np.abs(v_new - v).max()
        v = v_new
        # acceleration-based early stop (Lin & Cohen §3)
        if prev_delta is not None and abs(prev_delta - delta) < tol / num_nodes:
            break
        prev_delta = delta

    return _kmeans_1d(v, num_clusters, seed=seed)


def _kmeans_1d(x: np.ndarray, k: int, iters: int = 50, seed: int = 0) -> np.ndarray:
    """1-D k-means on the PIC pseudo-eigenvector (exact assignment step)."""
    k = max(1, min(k, np.unique(x).size))
    # init centers at quantiles — deterministic and robust for 1-D
    centers = np.quantile(x, np.linspace(0, 1, k))
    for _ in range(iters):
        assign = np.argmin(np.abs(x[:, None] - centers[None, :]), axis=1)
        new_centers = centers.copy()
        for c in range(k):
            m = assign == c
            if m.any():
                new_centers[c] = x[m].mean()
        if np.allclose(new_centers, centers):
            break
        centers = new_centers
    return np.argmin(np.abs(x[:, None] - centers[None, :]), axis=1).astype(np.int32)


def _connected_components(nodes: np.ndarray, indptr, indices) -> list:
    """Connected components restricted to ``nodes`` (BFS)."""
    nodeset = set(nodes.tolist())
    seen = set()
    comps = []
    for start in nodes.tolist():
        if start in seen:
            continue
        comp = []
        stack = [start]
        seen.add(start)
        while stack:
            u = stack.pop()
            comp.append(u)
            for w in indices[indptr[u] : indptr[u + 1]].tolist():
                if w in nodeset and w not in seen:
                    seen.add(w)
                    stack.append(w)
        comps.append(np.asarray(comp, np.int64))
    return comps


def refine_partition(
    num_nodes: int,
    src: np.ndarray,
    dst: np.ndarray,
    coarse: np.ndarray,
    target_size: int = 1024,
) -> np.ndarray:
    """METIS-style refinement: split each coarse cluster into connected,
    BFS-local chunks of at most ``target_size`` nodes; merge tiny chunks
    greedily up to the target.  Returns a community id per node.
    """
    indptr, indices = _csr_from_edges(num_nodes, src, dst)
    community = np.full(num_nodes, -1, np.int64)
    next_id = 0
    for c in np.unique(coarse):
        nodes = np.nonzero(coarse == c)[0]
        pending: list[np.ndarray] = []
        for comp in _connected_components(nodes, indptr, indices):
            if comp.size <= target_size:
                pending.append(comp)
                continue
            # BFS-grow chunks of target_size to keep locality
            compset = set(comp.tolist())
            seen: set = set()
            for s0 in comp.tolist():
                if s0 in seen:
                    continue
                chunk = []
                queue = [s0]
                seen.add(s0)
                while queue and len(chunk) < target_size:
                    u = queue.pop(0)
                    chunk.append(u)
                    for w in indices[indptr[u] : indptr[u + 1]].tolist():
                        if w in compset and w not in seen:
                            seen.add(w)
                            queue.append(w)
                # anything left in queue returns to the pool via outer loop
                for leftover in queue:
                    seen.discard(leftover)
                pending.append(np.asarray(chunk, np.int64))
        # greedy first-fit merge of small chunks
        pending.sort(key=len, reverse=True)
        merged: list[list] = []
        for chunk in pending:
            placed = False
            for m in merged:
                if len(m) + chunk.size <= target_size:
                    m.extend(chunk.tolist())
                    placed = True
                    break
            if not placed:
                merged.append(chunk.tolist())
        for m in merged:
            community[np.asarray(m, np.int64)] = next_id
            next_id += 1
    # isolated / untouched nodes -> own community buckets of target_size
    rest = np.nonzero(community < 0)[0]
    for i in range(0, rest.size, target_size):
        community[rest[i : i + target_size]] = next_id
        next_id += 1
    return community


def partition_transactions(
    num_orders: int,
    num_entities: int,
    edges: np.ndarray,
    pic_cluster_size: int = 1_000_000,
    community_size: int = 1024,
    seed: int = 0,
) -> np.ndarray:
    """End-to-end partition of the static bipartite graph (paper pipeline).

    Nodes 0..num_orders are orders; entities follow.  Returns a community id
    for every static node; DDS construction then runs per community.
    """
    n = num_orders + num_entities
    src = edges[:, 0].astype(np.int64)
    dst = edges[:, 1].astype(np.int64) + num_orders
    n_pic = max(1, n // max(pic_cluster_size, 1))
    coarse = (
        power_iteration_clustering(n, src, dst, n_pic, seed=seed)
        if n_pic > 1
        else np.zeros(n, np.int32)
    )
    return refine_partition(n, src, dst, coarse, target_size=community_size)
