"""Streaming serving engine: incremental DDS equivalence, micro-batch flush
policy, and the headline stage-equivalence claim — micro-batched speed-layer
scores match the monolithic ``lnn_forward`` on the same event stream."""
import jax
import numpy as np
import pytest

from repro.core import LNNConfig, lnn_forward, lnn_init
from repro.core.dds import IncrementalDDSBuilder, build_dds, check_no_future_leak
from repro.core.graph import pad_graph
from repro.data import SynthConfig, generate_event_stream
from repro.stream import (
    CheckoutEvent,
    EngineConfig,
    MicroBatcher,
    ScoreRequest,
    StreamingEngine,
)


@pytest.fixture(scope="module")
def stream_world():
    events, g, split = generate_event_stream(
        SynthConfig(num_users=80, num_rings=3, feature_noise=0.8, seed=5),
        rate_per_s=500.0,
    )
    cfg = LNNConfig(num_gnn_layers=3, hidden_dim=32,
                    feat_dim=g.order_features.shape[1])
    params = lnn_init(jax.random.PRNGKey(0), cfg)
    return events, g, cfg, params


# ------------------------------------------------------- incremental DDS
@pytest.mark.parametrize("history,max_history",
                         [("all", None), ("all", 4), ("consecutive", None)])
def test_incremental_dds_matches_batch_build(stream_world, history, max_history):
    """The streaming ingest path must produce the exact padded graph the
    offline ``build_dds`` produces on the same transactions."""
    events, g, _, _ = stream_world
    b = IncrementalDDSBuilder(g.order_features.shape[1], history, max_history)
    for ev in events:
        b.add_order(ev.entities, ev.snapshot, ev.features, ev.label)
    inc = b.build()
    check_no_future_leak(inc)
    ref = build_dds(b.to_static(), history, max_history)
    pg_i = pad_graph(inc.coo, max_deg=16)
    pg_r = pad_graph(ref.coo, max_deg=16)
    for f in pg_i._fields:
        np.testing.assert_array_equal(getattr(pg_i, f), getattr(pg_r, f))
    assert inc.entity_snap_ids == ref.entity_snap_ids
    assert inc.last_hop == ref.last_hop


def test_incremental_builder_rejects_event_time_regression():
    b = IncrementalDDSBuilder(feat_dim=2)
    b.add_order([1], 3, np.zeros(2))
    with pytest.raises(ValueError):
        b.add_order([1], 2, np.zeros(2))


def test_entity_keys_strictly_past():
    b = IncrementalDDSBuilder(feat_dim=2)
    b.add_order([7], 1, np.zeros(2))
    b.add_order([7], 3, np.zeros(2))
    # same-snapshot activity never feeds the key list (no leak)
    assert b.entity_keys([7], 3) == [(7, 1)]
    assert b.entity_keys([7], 4) == [(7, 3)]
    assert b.entity_keys([7], 1) == []
    assert b.entity_keys([99], 5) == []     # cold entity


# ------------------------------------------------------- micro-batcher
def _const_score_fn(feats, key_lists):
    return np.full(feats.shape[0], 0.5), np.zeros(feats.shape[0], np.int32)


def _req(arrival, feat_dim=4):
    return ScoreRequest(features=np.zeros(feat_dim, np.float32),
                        entity_keys=[], arrival=arrival)


def test_microbatch_size_trigger():
    mb = MicroBatcher(_const_score_fn, max_batch=4, max_wait_s=10.0)
    out = []
    for i in range(3):
        out += mb.submit(_req(arrival=0.001 * i), now=0.001 * i)
    assert out == [] and len(mb) == 3
    out += mb.submit(_req(arrival=0.003), now=0.003)
    assert len(out) == 4 and len(mb) == 0
    assert mb.stats["size_flushes"] == 1
    assert all(r.batch_size == 4 for r in out)


def test_microbatch_deadline_trigger():
    mb = MicroBatcher(_const_score_fn, max_batch=64, max_wait_s=0.005)
    mb.submit(_req(arrival=1.000), now=1.000)
    assert mb.poll(now=1.004) == []                 # deadline not reached
    out = mb.poll(now=1.0051)
    assert len(out) == 1
    assert mb.stats["deadline_flushes"] == 1
    # flush is stamped at the deadline (timer semantics), so the recorded
    # wait is exactly max_wait even though the poll came later
    assert out[0].queued_s == pytest.approx(0.005)


def test_microbatch_padding_matches_unpadded_scores(stream_world):
    """Bucket padding must not perturb real rows' scores."""
    events, g, cfg, params = stream_world
    eng = StreamingEngine(params, cfg, EngineConfig(max_batch=8))
    eng.warmup()
    # fill the store so lookups return real embeddings
    for ev in events:
        eng.submit(ev)
    eng.flush()
    reqs = [r for r in (eng.ingester.builder.entity_keys(ev.entities, ev.snapshot)
                        for ev in events[-5:])]
    feats = np.stack([ev.features for ev in events[-5:]]).astype(np.float32)
    # batch of 5 pads to bucket 8; score one-by-one (bucket 1) as reference
    p5, _ = eng._score_batch(feats, reqs)
    p1 = np.concatenate(
        [eng._score_batch(feats[i:i + 1], [reqs[i]])[0] for i in range(5)]
    )
    np.testing.assert_allclose(p5, p1, atol=1e-6)


# ------------------------------------------- engine: the headline claim
def test_streaming_scores_match_monolithic_forward(stream_world):
    """Acceptance: replay ingest -> refresh -> micro-batched scoring equals
    the monolithic full-graph ``lnn_forward`` on the same events (fp tol)."""
    events, g, cfg, params = stream_world
    eng = StreamingEngine(params, cfg,
                          EngineConfig(max_batch=8, refresh_every=1, max_deg=32))
    report = eng.replay(events)
    assert len(report.results) == len(events)

    pg = pad_graph(eng.ingester.materialize().coo, max_deg=32)
    full = np.asarray(jax.nn.sigmoid(
        jax.jit(lambda p, gg: lnn_forward(p, cfg, gg))(params, pg)
    ))
    scores = report.scores_by_order()
    # builder order id == position in the event stream (arrival order)
    err = max(
        abs(scores[ev.order_id] - full[i]) for i, ev in enumerate(events)
    )
    assert err < 1e-4, err
    # refresh-every-window keeps the speed layer perfectly fresh
    assert report.staleness_summary()["max"] == 0
    assert eng.store.stats["misses"] == 0


def test_streaming_staleness_grows_with_refresh_interval(stream_world):
    events, g, cfg, params = stream_world
    fresh = StreamingEngine(params, cfg, EngineConfig(max_batch=8, refresh_every=1))
    lazy = StreamingEngine(params, cfg, EngineConfig(max_batch=8, refresh_every=6))
    s_fresh = fresh.replay(events).staleness_summary()
    s_lazy = lazy.replay(events).staleness_summary()
    assert s_fresh["stale_frac"] == 0.0
    assert s_lazy["stale_frac"] > 0.0
    assert lazy.refresher.stats["refreshes"] < fresh.refresher.stats["refreshes"]


def test_async_refresh_drains_and_scores_everything(stream_world):
    events, g, cfg, params = stream_world
    eng = StreamingEngine(params, cfg,
                          EngineConfig(max_batch=8, async_refresh=True))
    report = eng.replay(events)
    assert len(report.results) == len(events)
    assert eng.refresher.stats["refreshes"] > 0


# ------------------------------------------------ refresh driver (regressions)
def _tiny_driver(refresh_every=1, async_mode=False, seed=0):
    from repro.serve.kvstore import KVStore
    from repro.stream.ingest import StreamIngester
    from repro.stream.refresh import RefreshDriver

    cfg = LNNConfig(num_gnn_layers=2, hidden_dim=8, feat_dim=4)
    params = lnn_init(jax.random.PRNGKey(seed), cfg)
    ing = StreamIngester(4)
    store = KVStore(cfg.hidden_dim)
    drv = RefreshDriver(params, cfg, store, ing,
                        refresh_every=refresh_every, async_mode=async_mode)
    return drv, ing, store, cfg


def _tiny_event(snapshot, entity=1, arrival=0.0):
    return CheckoutEvent(order_id=-1, snapshot=snapshot, entities=(entity,),
                         features=np.zeros(4, np.float32), label=0.0,
                         arrival=arrival)


def test_async_refresh_inflight_list_stays_bounded():
    """Regression: completed futures must be pruned on every window-close
    hook — before, ``_inflight`` grew by one per refresh until ``drain()``,
    an unbounded leak over an unbounded stream."""
    from concurrent.futures import wait

    drv, ing, _, _ = _tiny_driver(async_mode=True)
    rounds = 6
    for t in range(rounds):
        res = ing.ingest(_tiny_event(t, entity=t % 3))
        drv.on_windows_closed(res.closed_window)
        wait(drv._inflight)          # every submitted refresh completes...
    assert drv.stats["refreshes"] >= rounds - 2
    # ...so at most the one submitted after the last prune remains tracked
    assert len(drv._inflight) <= 1
    drv.drain()
    assert drv._inflight == []


def test_refresh_cadence_carries_sparse_window_remainder():
    """Regression: a sparse snapshot jump (+5 windows, refresh_every=2) used
    to reset the counter to 0, silently swallowing the overshoot; the
    remainder must carry so long-run cadence stays refresh_every."""
    drv, _, _, _ = _tiny_driver(refresh_every=2)
    assert drv.on_windows_closed((0, 4)) is True       # +5 -> fires
    assert drv._windows_since_refresh == 1             # 5 % 2 carried
    assert drv.on_windows_closed((5, 5)) is True       # 1 + 1 -> fires
    assert drv.on_windows_closed((6, 6)) is False      # 0 + 1 -> waits
    assert drv.on_windows_closed((7, 7)) is True


def test_sync_refresh_snapshots_model_before_graph():
    """Regression: sync ``refresh()`` must capture (params, model_version)
    as one pair under the lock BEFORE snapshotting the graph — a hot-swap
    landing mid-snapshot may not retag the already-started refresh."""
    drv, ing, store, cfg = _tiny_driver()
    params_b = lnn_init(jax.random.PRNGKey(9), cfg)
    ing.ingest(_tiny_event(0))
    ing.ingest(_tiny_event(1))                          # closes window 0

    orig = drv._snapshot_graph

    def hook(up_to):
        drv.set_model(params_b, 7)                      # swap mid-snapshot
        return orig(up_to)

    drv._snapshot_graph = hook
    out = drv.refresh(0)
    assert out["entities_written"] == 1
    entries = [e for shard in store._shards for e in shard.values()]
    # old pair throughout: pre-swap version stamp AND pre-swap params
    assert all(e.model_version == 0 for e in entries)
    ref_drv, ref_ing, ref_store, _ = _tiny_driver()
    ref_ing.ingest(_tiny_event(0))
    ref_ing.ingest(_tiny_event(1))
    ref_drv.refresh(0)
    ref = [e for shard in ref_store._shards for e in shard.values()]
    np.testing.assert_array_equal(entries[0].value, ref[0].value)


def test_microbatcher_default_clock_is_monotonic_and_injectable():
    """Deadline scheduling runs on an injectable monotonic clock when the
    caller supplies no ``now`` — never the NTP-steppable wall clock."""
    import time as _time

    t = {"now": 100.0}
    mb = MicroBatcher(_const_score_fn, max_batch=8, max_wait_s=0.005,
                      clock=lambda: t["now"])
    mb.submit(_req(arrival=t["now"]))          # no explicit now: clock used
    assert mb.poll() == []                     # deadline not reached
    t["now"] += 0.004
    assert mb.poll() == []
    t["now"] += 0.002                          # past deadline
    out = mb.poll()
    assert len(out) == 1 and mb.stats["deadline_flushes"] == 1
    assert out[0].queued_s == pytest.approx(0.005)
    assert MicroBatcher(_const_score_fn).clock is _time.monotonic


def test_streaming_fused_stage2_matches_unfused(stream_world):
    """Flipping ``LNNConfig.use_pallas`` swaps the speed layer onto the fused
    Pallas stage-2 kernel (interpret mode on CPU); every replayed score must
    be identical to the unfused engine's, across all bucket shapes."""
    import dataclasses

    events, g, cfg, params = stream_world
    evs = events[:60]
    ref = StreamingEngine(params, cfg, EngineConfig(max_batch=8))
    s_ref = ref.replay(evs).scores_by_order()
    fused = StreamingEngine(params, dataclasses.replace(cfg, use_pallas=True),
                            EngineConfig(max_batch=8))
    s_fused = fused.replay(evs).scores_by_order()
    assert set(s_fused) == set(s_ref)
    err = max(abs(s_fused[o] - s_ref[o]) for o in s_ref)
    assert err < 1e-5, err


# ------------------------------------------------ flush/drain race (regression)
def test_deadline_flush_racing_concurrent_drain_is_empty_noop():
    """A deadline flush may race a concurrent drain of the same queue (work
    stealing, another thread's flush).  The loser must emit nothing: no
    zero-row score_fn call, no phantom deadline_flushes count."""
    calls = []

    def score_fn(feats, key_lists):
        calls.append(feats.shape[0])
        return np.full(feats.shape[0], 0.5), np.zeros(feats.shape[0], np.int32)

    mb = MicroBatcher(score_fn, max_batch=8, max_wait_s=0.005)
    mb.submit(_req(arrival=1.0), now=1.0)
    dl = mb.deadline()
    assert dl == pytest.approx(1.005)               # trigger armed...
    stolen = mb.take(1)                             # ...queue drained under it
    assert len(stolen) == 1
    out = mb.flush(dl)                  # the armed trigger fires on empty queue
    assert out == []
    assert calls == []                              # score_fn never saw 0 rows
    assert mb.stats["deadline_flushes"] == 0
    assert mb.stats["flushes"] == 0
    assert mb.stats["empty_flushes"] == 1
    assert mb.poll(now=2.0) == []                   # re-poll: nothing queued
    # the queue still works afterwards
    out = mb.submit(_req(arrival=3.0), now=3.0) + mb.poll(now=3.1)
    assert len(out) == 1 and mb.stats["deadline_flushes"] == 1


# ------------------------------------------- multi-worker replay parity
@pytest.mark.parametrize("backend", ["inline", "process"])
@pytest.mark.parametrize("num_workers", [1, 2, 4])
def test_replay_parity_nworkers_bit_identical(stream_world, num_workers,
                                              backend):
    """Acceptance: N-worker WorkerPool scores are BIT-identical to the
    single-worker StreamingEngine for N in {1, 2, 4} — same events, same
    refresh cadence, arbitrary per-worker flush interleavings — for BOTH
    the inline backend and the process backend (each worker a real OS
    process owning its KV shard)."""
    events, g, cfg, params = stream_world
    ref = StreamingEngine(params, cfg, EngineConfig(max_batch=8))
    s_ref = ref.replay(events).scores_by_order()
    eng = StreamingEngine(params, cfg,
                          EngineConfig(max_batch=8, num_workers=num_workers,
                                       backend=backend))
    try:
        rep = eng.replay(events)
    finally:
        eng.close()
    s = rep.scores_by_order()
    assert set(s) == set(s_ref)
    assert all(s[o] == s_ref[o] for o in s_ref), \
        max(abs(s[o] - s_ref[o]) for o in s_ref)
    if num_workers > 1:
        # the queue really sharded: more than one worker served traffic
        served = [w for w in rep.summary()["workers"] if w["requests"] > 0]
        assert len(served) > 1


def test_replay_parity_under_randomized_flush_interleavings(stream_world):
    """Bit-parity must hold for ANY flush interleaving: randomize every
    knob that changes when and how flushes fire (deadline, batch size,
    virtual service occupancy, stealing) and replay against the
    single-worker reference."""
    events, g, cfg, params = stream_world
    evs = events[:150]
    ref = StreamingEngine(params, cfg, EngineConfig(max_batch=8))
    s_ref = ref.replay(evs).scores_by_order()
    rng = np.random.default_rng(0)
    for trial in range(4):
        ecfg = EngineConfig(
            num_workers=int(rng.integers(2, 5)),
            max_batch=int(rng.choice([4, 8, 16])),
            max_wait_s=float(rng.choice([0.001, 0.005, 0.02])),
            service_model_s=float(rng.choice([0.0, 0.01, 0.05])),
            steal_threshold=int(rng.choice([6, 10])),
        )
        s = StreamingEngine(params, cfg, ecfg).replay(evs).scores_by_order()
        assert set(s) == set(s_ref)
        assert all(s[o] == s_ref[o] for o in s_ref), (trial, ecfg)


def test_multiworker_results_arrive_in_submission_order(stream_world):
    events, g, cfg, params = stream_world
    eng = StreamingEngine(params, cfg, EngineConfig(max_batch=8, num_workers=4))
    rep = eng.replay(events[:120])
    seqs = [r.request.seq for r in rep.results]
    assert seqs == sorted(seqs) == list(range(len(seqs)))


def test_multiworker_work_stealing_preserves_scores(stream_world):
    """Drive a slow-worker scenario (virtual service model) so shards back
    up and stealing engages; scores must still match the reference."""
    events, g, cfg, params = stream_world
    evs = events[:150]
    ref = StreamingEngine(params, cfg, EngineConfig(max_batch=8))
    s_ref = ref.replay(evs).scores_by_order()
    eng = StreamingEngine(params, cfg, EngineConfig(
        max_batch=8, num_workers=4, service_model_s=0.05, steal_threshold=10))
    rep = eng.replay(evs)
    s = rep.scores_by_order()
    assert eng.pool.pool_stats["steals"] > 0
    assert all(s[o] == s_ref[o] for o in s_ref)
    # stolen requests really were served off their affine worker
    off_affine = [r for r in rep.results
                  if r.worker != eng.pool.router.route(r.request.entity_keys)]
    assert 0 < len(off_affine) <= eng.pool.pool_stats["stolen_requests"]


def test_live_pool_reshard_preserves_scores_and_affinity(stream_world):
    """Resharding a live pool mid-stream (drain -> router+store+workers
    migrate together) keeps scores bit-identical and the affinity contract
    intact; resharding the router alone is caught, never silent."""
    events, g, cfg, params = stream_world
    ref = StreamingEngine(params, cfg, EngineConfig(max_batch=8))
    s_ref = ref.replay(events).scores_by_order()

    eng = StreamingEngine(params, cfg, EngineConfig(max_batch=8, num_workers=2))
    eng.warmup()
    results = []
    half = len(events) // 2
    for ev in events[:half]:
        results.extend(eng.submit(ev))
    results.extend(eng.pool.reshard(4))       # drained under the old topology
    assert eng.pool.num_workers == 4 and len(eng.pool.workers) == 4
    assert eng.store.num_shards == 4          # store migrated with the router
    from repro.serve.kvstore import pack_key
    for ent in range(50):
        assert (eng.store.shard_of(pack_key(ent, 0))
                == eng.pool.router.worker_of(ent))
    for ev in events[half:]:
        results.extend(eng.submit(ev))
    results.extend(eng.flush())
    scores = {r.request.tag.order_id: r.score for r in results}
    assert set(scores) == set(s_ref)
    assert all(scores[o] == s_ref[o] for o in s_ref)

    # router resharded out from under the pool -> loud failure, not silence
    # (both directions: grown past the pool and shrunk below it)
    for n0, n1 in ((2, 8), (4, 2)):
        bad = StreamingEngine(params, cfg,
                              EngineConfig(max_batch=8, num_workers=n0))
        bad.pool.router.reshard(n1)
        with pytest.raises(RuntimeError, match="WorkerPool.reshard"):
            bad.submit(events[0])


def test_engine_cold_start_scores_without_history():
    """First-ever events (empty store, no history) must score, not crash."""
    cfg = LNNConfig(num_gnn_layers=2, hidden_dim=16, feat_dim=4)
    params = lnn_init(jax.random.PRNGKey(1), cfg)
    eng = StreamingEngine(params, cfg, EngineConfig(max_batch=2, max_wait_s=0.001))
    evs = [CheckoutEvent(order_id=i, snapshot=0, entities=(i, 100 + i),
                         features=np.zeros(4, np.float32), label=0.0,
                         arrival=0.001 * i) for i in range(3)]
    out = []
    for ev in evs:
        out += eng.submit(ev)
    out += eng.flush()
    assert len(out) == 3
    assert all(np.isfinite(r.score) for r in out)
    assert all(r.staleness == -1 for r in out)      # nothing served from KV
