"""Continuous-learning benchmark — recall recovery on a drifting stream.

The learn plane's reason to exist, measured: a named-attack stream whose
ring fraud *changes shape mid-stream* (``repro.learn.drift``: new feature
signature, disjoint entity linkage) is replayed through a streaming
:class:`~repro.service.FraudService` with the full loop attached —
WAL tap → rolling-window fine-tunes → shadow-gated promotion.  The bench
records the **recall-recovery curve** (ring recall@budget per stream
segment, with the serving model version at each point) and two gates:

* ``finetuned_recovers_recall`` — ring recall over phase-B traffic served
  by a post-drift fine-tune beats the frozen pre-drift model's phase-B
  ring recall by ``min_lift`` (the drop-and-recover shape the paper's
  retrain loop exists for), AND a shadow-gated promotion actually
  happened after the drift;
* ``promotion_shadow_gated`` — that promotion carried at least
  ``min_eval`` labeled shadow samples and beat the incumbent by the
  configured margin on live traffic, AND an injected post-promotion
  regression (a perturbed clone hot-swapped in) auto-rolled back to
  last-good through the shared rollback path.

Writes ``experiments/BENCH_learning.json``
(``tools/check_bench_schema.py`` enforces the gates).

Run:  PYTHONPATH=src python benchmarks/learning_bench.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

#: review budget for every recall figure in this bench
BUDGET = 0.15


def _ring_recall(rows, budget: float = BUDGET) -> float:
    """Ring recall@budget over (is_ring, score) rows — the fraction of
    ring orders surfaced in the top-``budget`` fraction by score."""
    import numpy as np

    from repro.learn import recall_at_budget

    if not rows:
        return float("nan")
    flags = np.asarray([r[0] for r in rows], np.float64)
    scores = np.asarray([r[1] for r in rows], np.float64)
    return recall_at_budget(flags, scores, budget)


def run_learning_bench(*, num_buyers=100, num_rings=5, ring_size=6,
                       num_snapshots=12, steps=15, min_window=48,
                       max_window=256, stride=48, min_eval=32,
                       promote_margin=0.01, min_lift=0.10,
                       step_every=16, regression_tail=40,
                       seed=0) -> dict:
    import jax
    import numpy as np

    from repro.core import lnn_init
    from repro.core.hetero import ENTITY_TYPE_NAMES
    from repro.data.attacks import AttackConfig
    from repro.learn import ContinuousLearner, drifting_attack_stream
    from repro.learn.promote import PromotionController
    from repro.service import FraudService, ServiceConfig

    acfg = AttackConfig(num_buyers=num_buyers, num_rings=num_rings,
                        ring_size=ring_size, num_snapshots=num_snapshots,
                        num_bursts=1, num_bin_runs=1, seed=seed)
    events, patterns, split = drifting_attack_stream(acfg, rate_per_s=500.0)
    pattern_of = {ev.order_id: p for ev, p in zip(events, patterns)}

    sc = ServiceConfig.from_dict({
        "mode": "streaming",
        "model": {"num_gnn_layers": 2, "hidden_dim": 16,
                  "feat_dim": int(events[0].features.shape[0]),
                  "mlp_dims": [16], "entity_types": list(ENTITY_TYPE_NAMES)},
        "engine": {"num_workers": 1, "max_batch": 8, "k_max": 4},
        "learn": {"enabled": True, "min_window": min_window,
                  "max_window": max_window, "stride": stride,
                  "steps": steps, "lr": 1e-2, "optimizer": "adam",
                  "head": "hybrid", "gbdt_trees": 20,
                  "min_eval": min_eval, "min_eval_pos": 3,
                  "eval_budget": BUDGET, "eval_max": 96,
                  "promote_margin": promote_margin,
                  "rollback_margin": 0.10, "watch_min_eval": 48},
    })
    params0 = lnn_init(jax.random.PRNGKey(seed), sc.to_lnn_config())
    scratch = tempfile.mkdtemp(prefix="bench_learning_")
    svc = FraudService(sc, params=params0).build()
    svc.enable_wal(os.path.join(scratch, "wal"))
    svc.enable_auto_checkpoint(every_windows=4, keep_last=3)
    learner = ContinuousLearner(svc)

    # ---- the live loop: serve + shadow-observe + learn, one pass ----------
    main_events = events[:-regression_tail]
    tail_events = events[-regression_tail:]
    rows: list = []         # (is_ring, label, score, version) per response
    decisions: list = []    # (event_index, decision dict)
    for i, ev in enumerate(main_events):
        out = svc.submit(ev)
        svc.shadow_observe(out)
        for r in out:
            if r.admitted:
                tag = r.request.tag
                rows.append((float(pattern_of[tag.order_id] == "ring"),
                             float(tag.label), float(r.score),
                             int(r.model_version)))
            else:
                rows.append(None)   # hold index alignment for shed rows
        if (i + 1) % step_every == 0:
            s = learner.step()
            if s["decision"]:
                decisions.append((i, s["decision"]))
    for r in svc.drain():
        if r.admitted:
            tag = r.request.tag
            rows.append((float(pattern_of[tag.order_id] == "ring"),
                         float(tag.label), float(r.score),
                         int(r.model_version)))
    s = learner.step()
    if s["decision"]:
        decisions.append((len(main_events) - 1, s["decision"]))
    rows = [r for r in rows if r is not None]

    # ---- recall-recovery evidence -----------------------------------------
    v0 = 0
    promotions = [(i, d) for i, d in decisions if d.get("action") == "promote"]
    post_drift = [(i, d) for i, d in promotions if i >= split]
    # frozen = phase-B responses still scored by the pre-drift incumbent;
    # recovered = phase-B responses scored by any post-drift promotee
    pre_drift_versions = {v0} | {
        d["candidate"] for i, d in promotions if i < split}
    b_rows = [r for r in rows[split:]]
    frozen = [(r[0], r[2]) for r in b_rows if r[3] in pre_drift_versions]
    recovered = [(r[0], r[2]) for r in b_rows if r[3] not in pre_drift_versions]
    frozen_recall = _ring_recall(frozen)
    recovered_recall = _ring_recall(recovered)

    # per-segment curve for the JSON record (dashboards, eyeballs)
    seg = 64
    curve = []
    for s0 in range(0, len(rows), seg):
        chunk = rows[s0:s0 + seg]
        versions = sorted({r[3] for r in chunk})
        curve.append({
            "start": s0, "n": len(chunk),
            "phase": "A" if s0 + len(chunk) <= split else "B",
            "model_versions": versions,
            "ring_recall": _ring_recall([(r[0], r[2]) for r in chunk]),
            "fraud_recall": _ring_recall([(r[1], r[2]) for r in chunk]),
        })

    recovers = (not np.isnan(frozen_recall) and not np.isnan(recovered_recall)
                and recovered_recall >= frozen_recall + min_lift
                and len(post_drift) > 0)

    # ---- injected post-promotion regression → auto-rollback ---------------
    promoted_v = svc.model_version
    bad_v = svc.register_perturbed(promoted_v, scale=3.0, seed=seed)
    svc.activate_model(bad_v)           # promoted_v becomes last-good
    svc.enable_shadow(promoted_v, fraction=1.0, threshold=0.25,
                      collect_eval=96, role="last_good")
    watcher = PromotionController.attach(svc, watch_min_eval=8,
                                         rollback_margin=0.10)
    rollback_decision = None
    for ev in tail_events:
        out = svc.submit(ev)
        svc.shadow_observe(out)
        d = watcher.step()
        if d is not None:
            rollback_decision = d
            break
    svc.drain()
    rolled_back = (svc.stats().rollbacks >= 1
                   and svc.model_version == promoted_v)

    gated = bool(post_drift) and all(
        d["n_eval"] >= min_eval
        and d["candidate_recall"] >= d["incumbent_recall"] + promote_margin
        for _, d in post_drift[-1:])
    learn_stats = learner.stats()
    learner.close()
    svc.close()
    shutil.rmtree(scratch)

    return {
        "n_events": len(events), "split": int(split),
        "budget": BUDGET, "min_lift": min_lift,
        "config": {"steps": steps, "min_window": min_window,
                   "max_window": max_window, "stride": stride,
                   "head": "hybrid", "min_eval": min_eval,
                   "promote_margin": promote_margin},
        "frozen_ring_recall": float(frozen_recall),
        "recovered_ring_recall": float(recovered_recall),
        "recall_curve": curve,
        "promotions": [
            {"event_index": int(i), **{k: v for k, v in d.items()}}
            for i, d in promotions],
        "learn": {"fires": learn_stats["fires"],
                  "tap": learn_stats["tap"],
                  "promotion": learn_stats["promotion"]},
        "regression": {"bad_version": int(bad_v),
                       "restored_version": int(promoted_v),
                       "rollback": rollback_decision,
                       "rolled_back": bool(rolled_back)},
        "gates": {
            "finetuned_recovers_recall": bool(recovers),
            "promotion_shadow_gated": bool(gated and rolled_back),
        },
    }


def main(smoke: bool = False) -> dict:
    if smoke:
        r = run_learning_bench(num_buyers=80, num_rings=4, steps=12,
                               min_window=48, stride=48)
    else:
        r = run_learning_bench(num_buyers=160, num_rings=6, ring_size=8,
                               num_snapshots=16, steps=25)

    print("\n# Continuous learning (drifting attack stream)")
    print(f"  events={r['n_events']} drift@{r['split']} "
          f"budget={r['budget']:.2f}")
    print(f"  ring recall on phase B: frozen={r['frozen_ring_recall']:.3f} "
          f"-> recovered={r['recovered_ring_recall']:.3f} "
          f"(min lift {r['min_lift']:.2f})")
    for p in r["promotions"]:
        print(f"  promote@{p['event_index']:>4}: v{p['candidate']} over "
              f"v{p['incumbent']} "
              f"({p['candidate_recall']:.3f} vs {p['incumbent_recall']:.3f}, "
              f"n={p['n_eval']})")
    reg = r["regression"]
    print(f"  regression: v{reg['bad_version']} injected -> rolled_back="
          f"{reg['rolled_back']} (restored v{reg['restored_version']})")
    print(f"  gates: {r['gates']}")

    outdir = os.path.join("experiments", "smoke") if smoke else "experiments"
    os.makedirs(outdir, exist_ok=True)
    with open(os.path.join(outdir, "BENCH_learning.json"), "w") as f:
        json.dump(r, f, indent=1)
    return r


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI smoke (seconds, not minutes)")
    main(smoke=ap.parse_args().smoke)
