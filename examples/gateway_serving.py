"""HTTP gateway demo: the serving API on a real socket (``repro.gateway``).

Boots a :class:`FraudGateway` over a streaming ``FraudService`` on an
ephemeral localhost port — stdlib HTTP server, no dependencies — then walks
the whole operational surface from a plain ``urllib`` client:

  1. SCORE        — ``POST /v1/score`` one checkout event at a time;
  2. HOT-SWAP     — ``POST /admin/model`` activates an identical-weights
                    clone mid-stream; responses carry the version stamp;
  3. CANARY       — a deliberately perturbed shadow version scores a
                    sampled fraction off the response path; the divergence
                    alert surfaces in ``GET /metrics`` (Prometheus text);
  4. BACKPRESSURE — overload against a depth-capped shed policy comes back
                    as HTTP 429 + ``Retry-After`` at the socket;
  5. DRAIN        — ``POST /admin/drain`` flushes the speed layer and flips
                    ``/healthz`` to 503 (load balancers stop routing here).

Run:  PYTHONPATH=src python examples/gateway_serving.py
"""
import json
import os
import sys
import urllib.error
import urllib.request

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.core import LNNConfig, lnn_init
from repro.data import SynthConfig, generate_event_stream
from repro.gateway import serve_gateway
from repro.service import ModelSection, ServiceConfig


def post(url: str, body: dict):
    req = urllib.request.Request(
        url, data=json.dumps(body).encode(),
        headers={"Content-Type": "application/json"})
    try:
        with urllib.request.urlopen(req, timeout=30) as r:
            return r.status, json.loads(r.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


def get(url: str):
    try:
        with urllib.request.urlopen(url, timeout=30) as r:
            return r.status, r.read().decode()
    except urllib.error.HTTPError as e:
        return e.code, e.read().decode()


def ev_json(ev, snapshot=None) -> dict:
    return {"order_id": ev.order_id,
            "snapshot": ev.snapshot if snapshot is None else snapshot,
            "entities": list(ev.entities), "features": ev.features.tolist(),
            "arrival": ev.arrival}


def main():
    events, g, _ = generate_event_stream(
        SynthConfig(num_users=80, num_rings=3, feature_noise=0.8, seed=3),
        rate_per_s=300.0,
    )
    cfg = LNNConfig(num_gnn_layers=2, hidden_dim=32,
                    feat_dim=g.order_features.shape[1])
    params = lnn_init(jax.random.PRNGKey(0), cfg)
    config = ServiceConfig(
        mode="streaming", model=ModelSection.from_lnn_config(cfg),
    ).replace(engine={"max_batch": 8})

    print("== boot: serve_gateway() on an ephemeral port ==")
    gw = serve_gateway(config, params)
    print(f"   {gw.url}  (stdlib ThreadingHTTPServer, keep-alive HTTP/1.1)")
    status, body = get(gw.url + "/healthz")
    print(f"   GET /healthz -> {status} {body.strip()}")

    half = len(events) // 2
    print(f"\n== scoring {half} checkout events over the wire ==")
    scored = 0
    for ev in events[:half]:
        status, body = post(gw.url + "/v1/score", {"event": ev_json(ev)})
        assert status == 200, body
        scored += body["scored"]
    print(f"   {scored} scored so far (micro-batches ride later responses)")

    print("\n== hot-swap: activate an identical-weights clone as v1 ==")
    status, body = post(gw.url + "/admin/model",
                        {"role": "primary", "from_version": 0,
                         "perturb_scale": 0.0, "version": 1})
    print(f"   POST /admin/model -> {status} "
          f"active=v{body['model_version']} registry={body['model_versions']}")
    versions = set()
    for ev in events[half:]:
        status, body = post(gw.url + "/v1/score", {"event": ev_json(ev)})
        versions |= {r["model_version"] for r in body["results"]}
    print(f"   versions stamped on post-swap responses: {sorted(versions)}")

    print("\n== canary: perturbed shadow at fraction 1.0 must alert ==")
    status, body = post(gw.url + "/admin/model",
                        {"role": "canary", "from_version": 1,
                         "perturb_scale": 2.0, "version": 9,
                         "fraction": 1.0, "threshold": 0.05})
    print(f"   enabled shadow v9: {body['shadow']}")
    for ev in events[:40]:
        post(gw.url + "/v1/score", {"event": {**ev_json(ev, snapshot=9999),
                                              "order_id": 10_000 + ev.order_id}})
    post(gw.url + "/admin/drain", {})
    _, metrics = get(gw.url + "/metrics")
    wanted = ("repro_shadow_sampled_total", "repro_shadow_divergence_max",
              "repro_shadow_alerts_total", "repro_shadow_alert_active")
    for line in metrics.splitlines():
        if line.startswith(wanted):
            print(f"   {line}")
    status, body = get(gw.url + "/healthz")
    print(f"   after drain: GET /healthz -> {status} (stop routing here)")
    gw.close()

    print("\n== backpressure: shed policy reaches the socket as 429 ==")
    gw = serve_gateway(
        config.replace(engine={"max_batch": 32},
                       admission={"max_queue_depth": 4, "policy": "shed"}),
        params)
    codes: dict = {}
    for ev in events:
        status, body = post(gw.url + "/v1/score",
                            {"event": {**ev_json(ev), "snapshot": 0}})
        codes[status] = codes.get(status, 0) + 1
    print(f"   status mix under a depth-4 cap: {codes} "
          f"(429 bodies carry Retry-After)")
    gw.close()
    print("\ndone — gateway closed cleanly")


if __name__ == "__main__":
    main()
