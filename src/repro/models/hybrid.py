"""Hybrid GNN -> GBDT risk head (paper §4.2's "LNN + LGB" composition).

The paper feeds *encoded* features into downstream learners; the hybrid
head runs that composition at serving time in the opposite direction: the
(frozen) LNN produces its pre-MLP stage-2 embedding ``[g_out ; feats]``
for each request, and a histogram-GBDT booster (``baselines/gbdt.py``, the
LightGBM stand-in) replaces the MLP as the final risk scorer.  Trees over
the learned graph embedding pick up axis-aligned interactions the small
MLP head misses — on the named-attack workload this is the
``hybrid_beats_mlp_on_rings`` gate in ``BENCH_hetero.json``.

Serving contract: a :class:`HybridModel` registers with
:class:`~repro.service.service.FraudService` as an ordinary model version.
The GNN embedding runs through the same fused path as the MLP head (one
jit dispatch via :func:`~repro.core.lnn.lnn_stage2_embed`); the booster
scores on host — numpy, element-deterministic, so replay parity holds
exactly like the MLP path's host-side sigmoid.

Persistence piggybacks on the ``.npz`` checkpoint format
(``train/checkpoint.py``): LNN leaves save under their usual key paths, the
booster's flat arrays save under a ``__gbdt__/...`` namespace, and a
``__hybrid__`` marker key lets :func:`is_hybrid_checkpoint` route restores.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import numpy as np

from repro.baselines.gbdt import GBDTConfig, GBDTModel, _Tree, train_gbdt
from repro.core.lnn import LNNConfig, lnn_stage2_embed
from repro.train.checkpoint import load_checkpoint, save_checkpoint


@dataclass
class HybridModel:
    """Frozen LNN embedding + GBDT booster over ``[g_out ; feats]``.

    ``lnn_params`` is the full ``lnn_init`` pytree (stage-1 refresh uses it
    unchanged — the hybrid head only replaces online stage-2's MLP).
    """

    lnn_params: dict
    cfg: LNNConfig
    gbdt: GBDTModel

    def embed(self, entity_emb, emb_mask, order_feats, slot_type=None):
        """Pre-MLP stage-2 embedding ``[B, H+F]`` (host numpy, f32)."""
        x = lnn_stage2_embed(self.lnn_params, self.cfg, entity_emb, emb_mask,
                             order_feats, slot_type=slot_type)
        return np.asarray(x, np.float32)

    def score(self, entity_emb, emb_mask, order_feats, slot_type=None):
        """Fraud probability per row — embedding dispatch + host booster."""
        return self.gbdt.predict_proba(
            self.embed(entity_emb, emb_mask, order_feats, slot_type=slot_type))


def train_hybrid(lnn_params, cfg: LNNConfig, embeddings: np.ndarray,
                 labels: np.ndarray, gbdt_cfg: GBDTConfig | None = None,
                 x_val: np.ndarray | None = None,
                 y_val: np.ndarray | None = None) -> HybridModel:
    """Fit the booster on pre-computed stage-2 embeddings (LNN stays frozen).

    ``embeddings`` are :meth:`HybridModel.embed` outputs (or
    ``lnn_stage2_embed`` directly) for the training split.
    """
    gbdt = train_gbdt(np.asarray(embeddings, np.float64),
                      np.asarray(labels, np.float64),
                      cfg=gbdt_cfg or GBDTConfig(),
                      x_val=x_val, y_val=y_val)
    return HybridModel(lnn_params=lnn_params, cfg=cfg, gbdt=gbdt)


# --------------------------------------------------------------- persistence

def _gbdt_payload(gbdt: GBDTModel) -> dict:
    """Flatten a booster into npz-able arrays under the __gbdt__ namespace."""
    out = {
        "__hybrid__": np.asarray(1, np.int64),
        "__gbdt__/base_score": np.asarray(gbdt.base_score, np.float64),
        "__gbdt__/n_trees": np.asarray(len(gbdt.trees), np.int64),
        "__gbdt__/n_features": np.asarray(len(gbdt.bin_edges), np.int64),
        "__gbdt__/cfg": np.asarray([
            gbdt.cfg.num_trees, gbdt.cfg.max_depth, gbdt.cfg.num_bins,
        ], np.int64),
        "__gbdt__/cfg_f": np.asarray([
            gbdt.cfg.learning_rate, gbdt.cfg.min_child_weight,
            gbdt.cfg.reg_lambda, gbdt.cfg.min_gain,
        ], np.float64),
    }
    for j, edges in enumerate(gbdt.bin_edges):
        out[f"__gbdt__/edges/{j}"] = np.asarray(edges, np.float64)
    for i, t in enumerate(gbdt.trees):
        out[f"__gbdt__/tree/{i}/feature"] = t.feature
        out[f"__gbdt__/tree/{i}/threshold_bin"] = t.threshold_bin
        out[f"__gbdt__/tree/{i}/left"] = t.left
        out[f"__gbdt__/tree/{i}/right"] = t.right
        out[f"__gbdt__/tree/{i}/value"] = t.value
    return out


def _gbdt_from_payload(data) -> GBDTModel:
    ci = data["__gbdt__/cfg"]
    cf = data["__gbdt__/cfg_f"]
    cfg = GBDTConfig(num_trees=int(ci[0]), max_depth=int(ci[1]),
                     num_bins=int(ci[2]), learning_rate=float(cf[0]),
                     min_child_weight=float(cf[1]), reg_lambda=float(cf[2]),
                     min_gain=float(cf[3]))
    gbdt = GBDTModel(cfg=cfg, base_score=float(data["__gbdt__/base_score"]))
    for j in range(int(data["__gbdt__/n_features"])):
        gbdt.bin_edges.append(np.asarray(data[f"__gbdt__/edges/{j}"]))
    for i in range(int(data["__gbdt__/n_trees"])):
        gbdt.trees.append(_Tree(
            feature=np.asarray(data[f"__gbdt__/tree/{i}/feature"]),
            threshold_bin=np.asarray(data[f"__gbdt__/tree/{i}/threshold_bin"]),
            left=np.asarray(data[f"__gbdt__/tree/{i}/left"]),
            right=np.asarray(data[f"__gbdt__/tree/{i}/right"]),
            value=np.asarray(data[f"__gbdt__/tree/{i}/value"]),
        ))
    return gbdt


def save_hybrid(path: str, model: HybridModel) -> str:
    """Atomically write a hybrid model to ``path`` (.npz) — LNN leaves under
    their checkpoint key paths plus the ``__gbdt__`` namespace."""
    save_checkpoint(path, model.lnn_params)
    # re-write with the booster payload merged in (save_checkpoint owns the
    # atomic-replace dance; one extra read-modify-write keeps it simple)
    with np.load(path) as data:
        payload = {k: data[k] for k in data.files}
    payload.update(_gbdt_payload(model.gbdt))
    import os
    import tempfile
    fd, tmp = tempfile.mkstemp(
        dir=os.path.dirname(os.path.abspath(path)), suffix=".tmp")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **payload)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)
    return path


def is_hybrid_checkpoint(path: str) -> bool:
    """True when ``path`` is a :func:`save_hybrid` artifact (``__hybrid__``
    marker present), False for a plain LNN checkpoint."""
    with np.load(path) as data:
        return "__hybrid__" in data.files


def load_hybrid(path: str, like_lnn_params, cfg: LNNConfig) -> HybridModel:
    """Restore a hybrid model; ``like_lnn_params`` is the ``lnn_init``
    template used by ``load_checkpoint`` to rebuild the LNN pytree."""
    lnn_params, _ = load_checkpoint(path, like_lnn_params)
    lnn_params = jax.tree_util.tree_map(np.asarray, lnn_params)
    with np.load(path) as data:
        gbdt = _gbdt_from_payload(data)
    return HybridModel(lnn_params=lnn_params, cfg=cfg, gbdt=gbdt)


__all__ = [
    "HybridModel", "train_hybrid", "save_hybrid", "load_hybrid",
    "is_hybrid_checkpoint",
]
