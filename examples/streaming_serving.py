"""Streaming serving demo: the Lambda loop closed end-to-end.

Replays a synthetic checkout stream through the real-time engine:

  1. INGEST       — each event extends the DDS graph incrementally
                    (no-future-leak invariant held at every prefix);
  2. BATCH LAYER  — the refresh driver re-runs LNN stage 1 when snapshot
                    windows close, pushing versioned entity embeddings into
                    the sharded KV store;
  3. SPEED LAYER  — concurrent checkouts coalesce into fixed-shape
                    micro-batches (size- and deadline-triggered flushes) and
                    score through one jitted stage-2 call;
  4. proves the streamed micro-batched scores equal the monolithic
    ``lnn_forward`` over the final graph, then shows the staleness
    trade-off when the batch layer refreshes lazily.

Run:  PYTHONPATH=src python examples/streaming_serving.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.core import LNNConfig, lnn_forward
from repro.core.graph import pad_graph
from repro.data import SynthConfig, build_communities, generate_event_stream
from repro.stream import EngineConfig, StreamingEngine
from repro.train.loop import train_lnn


def main():
    events, g, split = generate_event_stream(
        SynthConfig(num_users=300, num_rings=5, feature_noise=0.8, seed=1),
        rate_per_s=300.0,
    )
    cfg = LNNConfig(num_gnn_layers=3, hidden_dim=64,
                    feat_dim=g.order_features.shape[1], pos_weight=3.0)

    print("== training a small LNN (offline, on the historical graph) ==")
    comm = build_communities(g, community_size=256, max_deg=24)
    res = train_lnn(comm, split, cfg, epochs=15, patience=5)

    print(f"\n== replaying {len(events)} checkout events through the engine ==")
    eng = StreamingEngine(res.params, cfg, EngineConfig(
        max_batch=16, max_wait_s=0.005, refresh_every=1, store_shards=4))
    report = eng.replay(events)
    s = report.summary()
    print(f"   scored {s['scored']} checkouts in {s['flushes']} micro-batches "
          f"(mean batch {s['mean_batch']:.1f}; "
          f"{s['size_flushes']} size / {s['deadline_flushes']} deadline flushes)")
    print(f"   latency p50={s['latency_ms']['p50']:.2f}ms "
          f"p95={s['latency_ms']['p95']:.2f}ms p99={s['latency_ms']['p99']:.2f}ms "
          f"(mean service {s['mean_service_ms']:.2f}ms)")
    print(f"   batch layer: {s['refreshes']} refreshes wrote "
          f"{s['entities_written']} versioned embeddings -> "
          f"store size {s['store_size']}")
    risky = sum(1 for r in report.results if r.score > 0.5)
    print(f"   {risky} checkouts flagged risky")

    print("\n== correctness: streamed scores == monolithic forward ==")
    pg = pad_graph(eng.ingester.materialize().coo, max_deg=32)
    full = np.asarray(jax.nn.sigmoid(
        jax.jit(lambda p, gg: lnn_forward(p, cfg, gg))(res.params, pg)))
    scores = report.scores_by_order()
    err = max(abs(scores[ev.order_id] - full[i]) for i, ev in enumerate(events))
    print(f"   max |streamed - monolithic| = {err:.2e}")

    print("\n== staleness: refreshing every 6 windows instead of every 1 ==")
    lazy = StreamingEngine(res.params, cfg, EngineConfig(
        max_batch=16, refresh_every=6))
    lazy_rep = lazy.replay(events)
    st = lazy_rep.staleness_summary()
    print(f"   {lazy.refresher.stats['refreshes']} refreshes "
          f"(vs {s['refreshes']}); stale lookups: {st['stale_frac']:.0%}, "
          f"mean staleness {st['mean']:.2f} snapshots, max {st['max']}")
    print(f"   KV fallback stats: {lazy.store.stats['stale_hits']} stale hits, "
          f"{lazy.store.stats['misses']} cold misses")

    print("\n== multi-worker speed layer: 4 key-affine workers ==")
    mw = StreamingEngine(res.params, cfg, EngineConfig(
        max_batch=16, num_workers=4, service_model_s=0.004,
        steal_threshold=24))
    mw_rep = mw.replay(events)
    ms = mw_rep.summary()
    mw_scores = mw_rep.scores_by_order()
    per_worker = [w["requests"] for w in ms["workers"]]
    print(f"   requests per worker: {per_worker} "
          f"({ms['steals']} steals, {ms['stolen_requests']} requests stolen)")
    print(f"   latency p50={ms['latency_ms']['p50']:.2f}ms "
          f"p99={ms['latency_ms']['p99']:.2f}ms under a 4ms virtual "
          f"service cost per flush")
    bit_identical = all(mw_scores[o] == scores[o] for o in scores)
    print(f"   scores bit-identical to the single-worker engine: "
          f"{bit_identical}")


if __name__ == "__main__":
    main()
