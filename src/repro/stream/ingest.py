"""Event-time streaming ingester — extends the DDS graph as checkouts arrive.

Wraps :class:`repro.core.dds.IncrementalDDSBuilder` with the window
bookkeeping the Lambda loop needs:

* tracks the **open snapshot** (events still arriving) vs **closed
  snapshots** (event time moved past them — their DDS in-neighborhoods are
  final, per the no-future-leak invariant, so the batch layer may refresh
  their embeddings exactly once);
* answers the speed-layer question per event: the exact ``(entity, t_e)``
  KV keys that feed this checkout's final-hop edges;
* marks touched entities **dirty** so the refresh driver knows which
  embeddings the next batch run must (re)write;
* maintains the **community assignment** (connected components of the
  order↔entity graph, ``core.partition.IncrementalPartitioner``) alongside
  the dirty pairs, so the community-local refresh driver can materialize
  and recompute only the components that actually changed — O(dirty
  communities) batch-layer work per refresh instead of O(total stream).

The ingester never runs the model — it is pure host-side graph state, cheap
enough to sit on the hot path (O(K·history) per event).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.dds import DDSGraph, IncrementalDDSBuilder
from repro.core.partition import IncrementalPartitioner
from repro.stream.events import CheckoutEvent
from repro.utils import crashpoint


@dataclass
class IngestResult:
    """Per-event ingest outcome handed to the engine."""

    order_id: int                   # builder-local order id (arrival order)
    entity_keys: list               # [(entity, t_e)] exact speed-layer keys
    # (first, last) snapshot range this event's arrival closed, or None.
    # Kept as bounds, never materialized: sparse snapshot indices (e.g.
    # epoch hours) would make an explicit range huge
    closed_window: tuple | None = None


class StreamIngester:
    """Event-at-a-time DDS growth: feeds each :class:`CheckoutEvent` to the
    incremental builder, tracks the open snapshot window, marks dirty
    ``(entity, t)`` pairs for the refresh driver, and maintains the
    incremental community partition."""

    def __init__(
        self,
        feat_dim: int,
        entity_history: str = "all",
        max_history: int | None = 8,
    ):
        self.builder = IncrementalDDSBuilder(
            feat_dim, entity_history=entity_history, max_history=max_history
        )
        self._open_snapshot = -1
        self._dirty: set = set()          # (entity, t) pairs awaiting refresh
        self.partitioner = IncrementalPartitioner()
        self.stats = {"events": 0, "windows_closed": 0}

    @property
    def open_snapshot(self) -> int:
        return self._open_snapshot

    @property
    def num_events(self) -> int:
        return self.stats["events"]

    def ingest(self, event: CheckoutEvent) -> IngestResult:
        """Consume one checkout: compute its speed-layer keys, extend the
        DDS graph, and report any snapshot windows the arrival closed."""
        crashpoint.fire("ingest.before")
        t = int(event.snapshot)
        closed = None
        if t > self._open_snapshot:
            if self._open_snapshot >= 0:
                closed = (self._open_snapshot, t - 1)
                self.stats["windows_closed"] += t - self._open_snapshot
            self._open_snapshot = t
        # keys BEFORE this event activates (entity, t): strictly-past only
        keys = self.builder.entity_keys(event.entities, t)
        o = self.builder.add_order(event.entities, t, event.features, event.label)
        self.partitioner.add_order(event.entities)
        for ent in event.entities:
            self._dirty.add((int(ent), t))
        self.stats["events"] += 1
        crashpoint.fire("ingest.after")
        return IngestResult(order_id=o, entity_keys=keys, closed_window=closed)

    # ---------------------------------------------------------------- refresh
    def take_refreshable(self, up_to_snapshot: int) -> list:
        """Drain dirty (entity, t) pairs with ``t <= up_to_snapshot`` — the
        embeddings whose in-neighborhoods are final and must be (re)written
        by the next batch-layer run.  Pairs in still-open snapshots stay
        pending."""
        ready = [p for p in self._dirty if p[1] <= up_to_snapshot]
        self._dirty.difference_update(ready)
        return sorted(ready)

    def take_refreshable_by_community(self, up_to_snapshot: int) -> list:
        """Like :meth:`take_refreshable`, but grouped by the dirty pairs'
        current communities: ``[(community_id, sorted_pairs)]`` ascending by
        community id.  Community ids are resolved at drain time (they are
        canonical-not-stable under merges, see ``IncrementalPartitioner``)."""
        groups: dict[int, list] = {}
        for pair in self.take_refreshable(up_to_snapshot):
            groups.setdefault(self.partitioner.community_of(pair[0]), []).append(pair)
        return [(c, groups[c]) for c in sorted(groups)]

    @property
    def dirty_count(self) -> int:
        return len(self._dirty)

    @property
    def dirty_communities(self) -> list:
        """Communities containing at least one dirty pair (resolved now)."""
        return sorted({self.partitioner.community_of(p[0]) for p in self._dirty})

    def community_members(self, community: int) -> list:
        return self.partitioner.members(community)

    def community_node_count(self, community: int) -> int:
        """Exact DDS node count of one community's subgraph: two nodes per
        absorbed order (effective + shadow) plus its (entity, t) pairs —
        the budget-packing estimate for community-local refresh."""
        pairs = sum(len(self.builder._active.get(e, ()))
                    for e in self.partitioner.members(community))
        return 2 * self.partitioner.order_count(community) + pairs

    def materialize(self) -> DDSGraph:
        """The accumulated DDS graph (batch-layer input)."""
        return self.builder.build()

    def materialize_communities(self, communities) -> DDSGraph:
        """The DDS subgraph of a union of communities — the community-local
        batch-layer input (`O(touched)`, never `O(total stream)`)."""
        ents: set = set()
        for c in communities:
            ents.update(self.partitioner.members(c))
        return self.builder.build_subgraph(ents)
