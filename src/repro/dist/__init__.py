from repro.dist.sharding import (
    batch_sharding,
    cache_sharding,
    enable_sharding_hints,
    model_axis_size,
    param_sharding,
    rendezvous_shard,
    resolve_spec,
    shard_hint,
    shard_spec,
    splitmix64,
    stable_shard,
)

__all__ = [
    "batch_sharding",
    "cache_sharding",
    "enable_sharding_hints",
    "model_axis_size",
    "param_sharding",
    "rendezvous_shard",
    "resolve_spec",
    "shard_hint",
    "shard_spec",
    "splitmix64",
    "stable_shard",
]
