"""ClusterGCN-style community training loop for the LNN (paper §4.2).

Trains end-to-end (stage1 ∘ stage2) over per-community padded DDS graphs,
with snapshot-based train/val/test masks and early stopping on validation
average precision — matching the paper's protocol ("middle 10% used as
validation set for early stopping").
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import PaddedGraph
from repro.core.lnn import LNNConfig, lnn_forward, lnn_init, lnn_loss
from repro.train.metrics import average_precision, roc_auc
from repro.train.optim import adamw, cosine_schedule


@dataclass
class TrainResult:
    params: object
    history: list
    best_epoch: int


def _masked_loss(params, cfg, graph: PaddedGraph, mask):
    g = graph._replace(label_mask=mask)
    return lnn_loss(params, cfg, g)


def collect_scores(params, cfg: LNNConfig, batches, split, which: int, forward_jit):
    """Gather (y_true, y_score) for orders in split ``which`` across batches."""
    ys, ss = [], []
    for b in batches:
        logits = np.asarray(forward_jit(params, b.graph))
        n_orders = b.global_order_ids.size
        sel = split[b.global_order_ids] == which
        if sel.any():
            ys.append(np.asarray(b.graph.label[:n_orders])[sel])
            ss.append(logits[:n_orders][sel])
    if not ys:
        return np.zeros(0), np.zeros(0)
    return np.concatenate(ys), np.concatenate(ss)


def train_lnn(
    batches,
    split: np.ndarray,
    cfg: LNNConfig,
    epochs: int = 60,
    lr: float = 3e-3,
    patience: int = 8,
    seed: int = 0,
    verbose: bool = False,
) -> TrainResult:
    params = lnn_init(jax.random.PRNGKey(seed), cfg)
    init_fn, update_fn = adamw(
        cosine_schedule(lr, total_steps=epochs * max(len(batches), 1), warmup_steps=10),
        weight_decay=1e-4,
    )
    state = init_fn(params)

    # per-batch train masks (precomputed host-side)
    train_masks = []
    for b in batches:
        m = np.zeros(b.graph.num_nodes, np.float32)
        sel = split[b.global_order_ids] == 0
        m[np.arange(b.global_order_ids.size)[sel]] = 1.0
        train_masks.append(jnp.asarray(m * np.asarray(b.graph.label_mask)))

    @jax.jit
    def step(params, state, graph, mask):
        loss, grads = jax.value_and_grad(_masked_loss)(params, cfg, graph, mask)
        params, state, aux = update_fn(grads, state, params)
        return params, state, loss

    forward_jit = jax.jit(lambda p, g: lnn_forward(p, cfg, g))

    rng = np.random.default_rng(seed)
    best_ap, best_params, best_epoch, stall = -1.0, params, 0, 0
    history = []
    for epoch in range(epochs):
        order = rng.permutation(len(batches))
        tot = 0.0
        for i in order:
            if float(train_masks[i].sum()) == 0:
                continue
            params, state, loss = step(params, state, batches[i].graph, train_masks[i])
            tot += float(loss)
        yv, sv = collect_scores(params, cfg, batches, split, 1, forward_jit)
        ap = average_precision(yv, sv) if yv.size and 0 < yv.sum() < yv.size else 0.0
        history.append({"epoch": epoch, "train_loss": tot / max(len(batches), 1), "val_ap": ap})
        if verbose:
            print(f"epoch {epoch}: loss={history[-1]['train_loss']:.4f} val_ap={ap:.4f}")
        if ap > best_ap + 1e-5:
            best_ap, best_params, best_epoch, stall = ap, params, epoch, 0
        else:
            stall += 1
            if stall >= patience:
                break
    return TrainResult(params=best_params, history=history, best_epoch=best_epoch)


def evaluate_lnn(params, cfg: LNNConfig, batches, split, which: int = 2) -> dict:
    forward_jit = jax.jit(lambda p, g: lnn_forward(p, cfg, g))
    y, s = collect_scores(params, cfg, batches, split, which, forward_jit)
    return {
        "roc_auc": roc_auc(y, s),
        "average_precision": average_precision(y, s),
        "n": int(y.size),
        "pos": int(y.sum()),
    }
