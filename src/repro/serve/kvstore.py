"""Key-value embedding store — the paper's "distributed key-value store"
(production would be Couchbase/Redis; here an in-memory store with an
npz-backed persistence path and the same access pattern: batched point
lookups by entity key).

Keys are (entity_id, snapshot) pairs packed into int64; values are stage-1
entity embeddings.  ``lookup_batch`` returns a dense [B, K, H] tensor plus
mask — exactly the speed-layer input.

Serving-engine upgrades on top of the plain dict store:

* **shard-by-key** — entries hash over ``num_shards`` independent shards
  (the access pattern a real distributed KV imposes; eviction is per shard);
* **versioned puts** — every entry carries the batch-layer refresh version
  that wrote it, so the speed layer can report embedding staleness;
* **TTL / LRU eviction** — bounded memory under unbounded streams: a
  ``capacity`` cap evicts least-recently-used entries per shard, an optional
  ``ttl_seconds`` expires entries lazily on read;
* **snapshot fallback** — ``lookup_batch_versioned`` serves the freshest
  available snapshot ≤ the requested one when the exact key is missing
  (the batch layer hasn't caught up yet), reporting per-slot staleness in
  snapshots — the Lambda trade-off made measurable.
"""
from __future__ import annotations

import threading
import time
from bisect import bisect_right
from collections import OrderedDict

import numpy as np

from repro.core.hetero import is_typed
from repro.dist.sharding import rendezvous_shard, stable_shard
from repro.utils import crashpoint

SNAPSHOT_BITS = 20
MAX_SNAPSHOT = (1 << SNAPSHOT_BITS) - 1
MAX_ENTITY = (1 << (63 - SNAPSHOT_BITS)) - 1


def _reject_untagged(entity: int) -> None:
    """Raise for an untagged entity id reaching a heterogeneous keyspace.

    With ``require_typed`` set, a legacy (untagged) id must fail loudly:
    silently admitting it would collapse buyer and device ids into one
    keyspace (identical raw ids shard — and collide — together)."""
    if not is_typed(entity):
        raise ValueError(
            f"entity id {int(entity)} carries no type tag but this keyspace "
            "is heterogeneous (require_typed=True) — tag ids with "
            "repro.core.hetero.tag_entity to keep per-type keyspaces disjoint")


def pack_key(entity: int, snapshot: int, require_typed: bool = False) -> int:
    """Pack (entity, snapshot) into one int64: entity << 20 | snapshot.

    Guards the packing domain — out-of-range inputs used to alias other
    entities' keys silently (e.g. snapshot 2^20 bled into entity bits).
    ``require_typed`` additionally rejects entity ids without a
    :mod:`repro.core.hetero` type tag (heterogeneous keyspaces).
    """
    e, t = int(entity), int(snapshot)
    if not 0 <= t <= MAX_SNAPSHOT:
        raise ValueError(f"snapshot {t} outside [0, {MAX_SNAPSHOT}] — would collide")
    if not 0 <= e <= MAX_ENTITY:
        raise ValueError(f"entity {e} outside [0, {MAX_ENTITY}] — would collide")
    if require_typed:
        _reject_untagged(e)
    return (e << SNAPSHOT_BITS) | t


def unpack_key(key: int) -> tuple[int, int]:
    """Inverse of :func:`pack_key`: ``(entity, snapshot)`` from one int64."""
    return int(key) >> SNAPSHOT_BITS, int(key) & MAX_SNAPSHOT


def entity_shard(entity: int, num_shards: int,
                 require_typed: bool = False) -> int:
    """Shard an *entity* (all its snapshots together) over ``num_shards``.

    Rendezvous placement over the entity id — the same function the
    speed-layer :class:`~repro.stream.workers.ShardRouter` uses, so a store
    built with ``shard_by_entity=True`` and ``num_shards == num_workers``
    puts every snapshot of an entity on exactly the worker that scores its
    requests (key-affinity, see docs/streaming.md).  ``require_typed``
    rejects untagged ids — sharding them would silently collapse per-type
    keyspaces (see :func:`pack_key`).
    """
    if require_typed:
        _reject_untagged(entity)
    return rendezvous_shard(int(entity), num_shards)


class _Entry:
    __slots__ = ("value", "version", "stamp", "model_version")

    def __init__(self, value, version, stamp, model_version=0):
        self.value = value
        self.version = version
        self.stamp = stamp
        # which parameter version computed this embedding: a hot-swapped
        # model makes pre-swap embeddings detectably stale (see
        # lookup_batch_versioned's expected_model_version)
        self.model_version = model_version


class KVStore:
    """In-memory sharded KV store for stage-1 entity embeddings.

    ``capacity``: max total entries (None = unbounded); enforced per shard
    with LRU order (gets refresh recency).  ``ttl_seconds``: entries older
    than this expire lazily on access.  ``clock``: injectable time source
    for deterministic TTL tests.  ``require_typed``: heterogeneous mode —
    every write or versioned read whose entity id lacks a
    :mod:`repro.core.hetero` type tag raises instead of silently sharing
    the untyped keyspace.
    """

    def __init__(
        self,
        dim: int,
        capacity: int | None = None,
        ttl_seconds: float | None = None,
        num_shards: int = 1,
        clock=time.time,
        shard_by_entity: bool = False,
        require_typed: bool = False,
    ):
        if num_shards < 1:
            raise ValueError("num_shards must be >= 1")
        self.dim = dim
        self.capacity = capacity
        self.ttl_seconds = ttl_seconds
        self.num_shards = num_shards
        self.shard_by_entity = shard_by_entity
        self.require_typed = bool(require_typed)
        self._clock = clock
        self._shards: list[OrderedDict[int, _Entry]] = [
            OrderedDict() for _ in range(num_shards)
        ]
        # per-entity sorted snapshot index, for the fallback lookup
        self._snaps: dict[int, list[int]] = {}
        # one coarse lock: the async refresh driver writes from a worker
        # thread while the speed layer reads (reads also mutate — LRU
        # touch, lazy TTL expiry), and the snapshot index must stay
        # consistent with the shards.  RLock: batched reads call get().
        self._lock = threading.RLock()
        self.stats = {"puts": 0, "gets": 0, "misses": 0,
                      "evictions": 0, "expired": 0, "stale_hits": 0,
                      "model_stale_reads": 0}

    # ---------------------------------------------------------------- shards
    def shard_of(self, key: int) -> int:
        """Shard index for a packed (entity, snapshot) key.

        Default: splitmix avalanche over the whole key, so consecutive
        snapshots spread shards (load balance).  ``shard_by_entity=True``
        switches to rendezvous placement over the entity bits alone, so all
        snapshots of an entity co-locate — the layout the multi-worker
        speed layer needs for key-affine routing (workers own whole
        entities, not scattered snapshots)."""
        if self.shard_by_entity:
            return entity_shard(int(key) >> SNAPSHOT_BITS, self.num_shards,
                                require_typed=self.require_typed)
        if self.require_typed:
            _reject_untagged(int(key) >> SNAPSHOT_BITS)
        return stable_shard(key, self.num_shards)

    def reshard(self, num_shards: int) -> None:
        """Re-place every entry under a new shard count (entity-affine or
        key-spread, per the store's mode).  O(total entries) — the explicit
        migration a real cluster would run; ``WorkerPool.reshard`` calls
        this so worker ownership and shard layout change together.
        Per-shard LRU recency is preserved within each old shard."""
        if num_shards < 1:
            raise ValueError("num_shards must be >= 1")
        with self._lock:
            entries = [(k, e) for shard in self._shards for k, e in shard.items()]
            self.num_shards = int(num_shards)
            self._shards = [OrderedDict() for _ in range(num_shards)]
            for k, e in entries:
                self._shards[self.shard_of(k)][k] = e

    def _index_add(self, key: int):
        ent, t = unpack_key(key)
        snaps = self._snaps.setdefault(ent, [])
        i = bisect_right(snaps, t)
        if not (i > 0 and snaps[i - 1] == t):
            snaps.insert(i, t)

    def _index_drop(self, key: int):
        ent, t = unpack_key(key)
        snaps = self._snaps.get(ent)
        if snaps is None:
            return
        i = bisect_right(snaps, t) - 1
        if i >= 0 and snaps[i] == t:
            snaps.pop(i)
            if not snaps:
                del self._snaps[ent]

    # ----------------------------------------------------------------- write
    def put(self, key: int, value: np.ndarray, version: int = 0,
            model_version: int = 0):
        key = int(key)
        with self._lock:
            shard = self._shards[self.shard_of(key)]
            shard[key] = _Entry(np.asarray(value, np.float32), int(version),
                                self._clock(), int(model_version))
            shard.move_to_end(key)
            self._index_add(key)
            self.stats["puts"] += 1
            if self.capacity is not None:
                # per-shard LRU cap (a distributed store can only evict locally)
                cap = max(1, self.capacity // self.num_shards)
                while len(shard) > cap:
                    old_key, _ = shard.popitem(last=False)
                    self._index_drop(old_key)
                    self.stats["evictions"] += 1

    def put_batch(self, keys, values, version: int = 0,
                  model_version: int = 0, stamp: float | None = None) -> int:
        """Write many (key, value) pairs under ONE lock acquisition and one
        clock read — the batch-layer refresh path.  Per-entry ``put`` pays
        lock + clock + eviction scan per embedding; a refresh writing
        thousands of entities amortizes all three here (eviction runs once
        per touched shard at the end).  Returns the number written.

        ``stamp`` overrides the clock read: a shard process applies puts
        with the stamp the parent recorded at the logical write, so TTL
        ages and checkpointed stamps stay identical to the inline store.
        """
        keys = [int(k) for k in keys]
        version, model_version = int(version), int(model_version)
        crashpoint.fire("kv.put_batch.before")
        with self._lock:
            stamp = self._clock() if stamp is None else float(stamp)
            touched = set()
            for k, v in zip(keys, values):
                s = self.shard_of(k)
                shard = self._shards[s]
                shard[k] = _Entry(np.asarray(v, np.float32), version, stamp,
                                  model_version)
                shard.move_to_end(k)
                self._index_add(k)
                touched.add(s)
            self.stats["puts"] += len(keys)
            if self.capacity is not None:
                cap = max(1, self.capacity // self.num_shards)
                for s in touched:
                    shard = self._shards[s]
                    while len(shard) > cap:
                        old_key, _ = shard.popitem(last=False)
                        self._index_drop(old_key)
                        self.stats["evictions"] += 1
        crashpoint.fire("kv.put_batch.after")
        return len(keys)

    # ------------------------------------------------------------------ read
    def _entry(self, key: int, touch: bool = True) -> _Entry | None:
        key = int(key)
        with self._lock:
            shard = self._shards[self.shard_of(key)]
            e = shard.get(key)
            if e is None:
                return None
            if (self.ttl_seconds is not None
                    and self._clock() - e.stamp > self.ttl_seconds):
                del shard[key]
                self._index_drop(key)
                self.stats["expired"] += 1
                return None
            if touch:
                shard.move_to_end(key)
            return e

    def get(self, key: int):
        self.stats["gets"] += 1
        e = self._entry(key)
        if e is None:
            self.stats["misses"] += 1
            return None
        return e.value

    def get_entry(self, key: int) -> tuple[np.ndarray, int, float] | None:
        """(value, version, stamp) or None."""
        e = self._entry(key)
        return None if e is None else (e.value, e.version, e.stamp)

    def version_of(self, key: int) -> int | None:
        e = self._entry(key, touch=False)
        return None if e is None else e.version

    def latest_snapshot(self, entity: int, t_max: int) -> int | None:
        """Freshest stored snapshot of ``entity`` that is <= ``t_max``."""
        with self._lock:
            snaps = self._snaps.get(int(entity))
            if not snaps:
                return None
            i = bisect_right(snaps, int(t_max)) - 1
            return snaps[i] if i >= 0 else None

    # --------------------------------------------------------------- batched
    def lookup_batch(self, key_lists: list, k_max: int):
        """key_lists: per request, a list of entity keys (<= k_max used).

        Returns (emb [B, K, H] float32, mask [B, K]) with zero rows for
        missing keys — cold entities contribute nothing, matching the DDS
        semantics for orders without history."""
        b = len(key_lists)
        emb = np.zeros((b, k_max, self.dim), np.float32)
        mask = np.zeros((b, k_max), np.float32)
        for i, keys in enumerate(key_lists):
            for j, key in enumerate(keys[:k_max]):
                v = self.get(key)
                if v is not None:
                    emb[i, j] = v
                    mask[i, j] = 1.0
        return emb, mask

    def lookup_batch_versioned(self, entity_t_lists: list, k_max: int,
                               expected_model_version: int | None = None):
        """Speed-layer lookup with snapshot fallback.

        ``entity_t_lists``: per request, a list of ``(entity, t_e)`` pairs.
        When the exact ``(entity, t_e)`` key is absent (batch layer behind),
        the freshest stored snapshot <= t_e is served instead and the slot's
        staleness is ``t_e - t_found`` snapshots; truly cold entities stay
        masked with staleness -1.

        ``expected_model_version``: when given, every served slot whose
        embedding was written by a *different* parameter version counts in
        ``stats["model_stale_reads"]`` — after a hot-swap, reads of
        pre-swap embeddings are detectable, not silent.

        Returns (emb [B, K, H], mask [B, K], staleness [B, K] int32).
        """
        b = len(entity_t_lists)
        emb = np.zeros((b, k_max, self.dim), np.float32)
        mask = np.zeros((b, k_max), np.float32)
        stale = np.full((b, k_max), -1, np.int32)
        with self._lock:
            self._lookup_versioned_into(entity_t_lists, k_max, emb, mask,
                                        stale, expected_model_version)
        return emb, mask, stale

    def _lookup_versioned_into(self, entity_t_lists, k_max, emb, mask, stale,
                               expected_model_version=None):
        for i, pairs in enumerate(entity_t_lists):
            for j, (ent, t_e) in enumerate(pairs[:k_max]):
                v, s = self._lookup_one(ent, t_e, expected_model_version)
                if v is not None:
                    emb[i, j] = v
                    mask[i, j] = 1.0
                    stale[i, j] = s

    def _lookup_one(self, ent, t_e, expected_model_version=None):
        """One slot of the versioned lookup: ``(value | None, staleness)``
        with all the side effects of the batched path (get/miss/stale/LRU
        counters).  The per-pair primitive both the inline lookup and a
        shard process's owner-side READ protocol are built on — counter
        sums and recency stay identical whichever side serves the slot.
        Callers hold ``_lock``."""
        if self.require_typed:
            _reject_untagged(ent)
        self.stats["gets"] += 1
        t_found = self.latest_snapshot(ent, t_e)
        if t_found is None:
            self.stats["misses"] += 1
            return None, -1
        e = self._entry(pack_key(ent, t_found))
        if e is None:  # expired between index and read
            self.stats["misses"] += 1
            return None, -1
        if t_found != t_e:
            self.stats["stale_hits"] += 1
        if (expected_model_version is not None
                and e.model_version != expected_model_version):
            self.stats["model_stale_reads"] += 1
        return e.value, int(t_e) - int(t_found)

    def lookup_versioned_one(self, ent: int, t_e: int,
                             expected_model_version: int | None = None):
        """Locked single-slot lookup (cross-shard owner reads)."""
        with self._lock:
            return self._lookup_one(ent, t_e, expected_model_version)

    def __len__(self):
        with self._lock:
            return sum(len(s) for s in self._shards)

    def keys(self):
        with self._lock:
            return [k for shard in self._shards for k in shard.keys()]

    # ------------------------------------------------------- state transfer
    def shard_items(self) -> list[list[tuple]]:
        """Per-shard ``(key, value, version, stamp, model_version)`` tuples
        in LRU order (oldest first) — the exact state a checkpoint snapshot
        or a shard-process SNAPSHOT reply must carry.  Values are the live
        arrays; callers serialize, they must not mutate."""
        with self._lock:
            return [[(k, e.value, e.version, e.stamp, e.model_version)
                     for k, e in shard.items()]
                    for shard in self._shards]

    def load_items(self, shards_items: list[list[tuple]]) -> None:
        """Install per-shard entries exactly as :meth:`shard_items` reported
        them (restore path): shard placement, LRU order, and entry fields
        are taken verbatim — no re-hash, no eviction, no stat counting."""
        if len(shards_items) != self.num_shards:
            raise ValueError(
                f"load_items got {len(shards_items)} shards for a "
                f"{self.num_shards}-shard store")
        with self._lock:
            for s, items in enumerate(shards_items):
                shard = self._shards[s]
                for k, v, ver, stamp, mv in items:
                    k = int(k)
                    shard[k] = _Entry(np.asarray(v, np.float32), int(ver),
                                      float(stamp), int(mv))
                    self._index_add(k)

    def restore_stats(self, stats: dict) -> None:
        """Overwrite counters from a checkpoint manifest."""
        self.stats.update(stats)

    # ------------------------------------------------------------- persistence
    def save(self, path: str):
        with self._lock:
            items = [(k, e) for shard in self._shards for k, e in shard.items()]
        keys = np.asarray([k for k, _ in items], np.int64)
        vals = (
            np.stack([e.value for _, e in items])
            if items
            else np.zeros((0, self.dim), np.float32)
        )
        versions = np.asarray([e.version for _, e in items], np.int64)
        stamps = np.asarray([e.stamp for _, e in items], np.float64)
        model_versions = np.asarray([e.model_version for _, e in items], np.int64)
        np.savez(path, keys=keys, values=vals.astype(np.float32),
                 versions=versions, stamps=stamps,
                 model_versions=model_versions, dim=self.dim)

    @classmethod
    def load(cls, path: str, **kwargs) -> "KVStore":
        with np.load(path) as data:
            store = cls(int(data["dim"]), **kwargs)
            n = len(data["keys"])
            versions = data["versions"] if "versions" in data else np.zeros(n, np.int64)
            stamps = data["stamps"] if "stamps" in data else None
            model_versions = (data["model_versions"] if "model_versions" in data
                              else np.zeros(n, np.int64))
            values = data["values"].astype(np.float32)
            for i, (k, v, ver) in enumerate(zip(data["keys"], values, versions)):
                k = int(k)
                store.put(k, v, int(ver), model_version=int(model_versions[i]))
                if stamps is not None:
                    # restore the original write time: TTL must keep counting
                    # from the real put, not restart at load
                    e = store._shards[store.shard_of(k)].get(k)
                    if e is not None:
                        e.stamp = float(stamps[i])
            store.stats["puts"] = 0
        return store
