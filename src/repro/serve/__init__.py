"""``repro.serve`` — KV store + the offline batch/speed Lambda split.

``LambdaPipeline`` is a deprecation shim: new code constructs a
``repro.service.FraudService`` with ``mode="batch"`` (see
docs/serving_api.md); ``BatchLayer``/``SpeedLayer`` remain the real layers
the facade wraps."""
from repro.serve.kvstore import KVStore
from repro.serve.lambda_pipeline import (
    BatchLayer,
    LambdaPipeline,
    SpeedLayer,
    history_requests,
    split_equivalence_check,
)

__all__ = [
    "BatchLayer",
    "KVStore",
    "LambdaPipeline",
    "SpeedLayer",
    "history_requests",
    "split_equivalence_check",
]
