"""HTTP serving gateway over :class:`~repro.service.FraudService` —
``repro.gateway.server``.

The wire protocol the serving facade was missing: a dependency-free
(stdlib ``http.server`` + JSON) front-end exposing

===========================  ====================================================
``POST /v1/score``           score checkout events (streaming mode) or typed
                             requests (batch mode), single or batch bodies
``POST /v1/ingest``          ingest events into the DDS/batch layer WITHOUT
                             scoring (backfill, non-checkout entity activity)
``GET  /healthz``            lifecycle-aware liveness (503 once draining)
``GET  /v1/stats``           the full ``ServiceStats`` snapshot + gateway
                             telemetry, JSON
``GET  /metrics``            Prometheus text format, rendered from the SAME
                             ``ServiceStats`` snapshot as ``/v1/stats``
``POST /admin/model``        hot-swap the primary model version, register a
                             perturbed clone, or (re)configure the canary
``POST /admin/drain``        finish outstanding work, take the gateway out of
                             rotation (healthz goes 503)
``POST /admin/checkpoint``   write a durable checkpoint of the full streaming
                             state (requires ``gateway.checkpoint_dir``);
                             ``{"compact": true}`` also truncates the WAL
``POST /admin/train``        tick the continuous-learning loop (tap → rolling
                             fine-tune → shadow-gated promotion); requires an
                             attached :class:`~repro.learn.ContinuousLearner`
``GET  /v1/learn/stats``     the learn-plane snapshot: tap cursor/pending,
                             trainer window state, promotion state machine
===========================  ====================================================

**Backpressure at the socket.**  Admission control stops being an
accounting fiction here: a shed request (``admission.policy="shed"``)
returns ``429 Too Many Requests`` with a ``Retry-After`` hint; a block
stall that exceeds ``admission.block_max_wait_s`` returns
``503 Service Unavailable``.  The caller — not a silent queue — absorbs
the overload.

**Canary/shadow scoring.**  ``POST /admin/model`` with ``role="canary"``
enables :meth:`FraudService.enable_shadow`: a sampled fraction of admitted
traffic is re-scored under the canary version *after* the HTTP response
bytes are flushed to the socket (off the response path), and the
|primary − shadow| divergence counters/alert surface in ``/metrics`` and
``/v1/stats``.

**Canary auto-rollback.**  With ``gateway.auto_rollback`` enabled, a
sticky shadow-divergence alert observed after shadow scoring triggers
:meth:`FraudService.rollback_model` — automatic ``activate_model`` back
to the last-good version (``repro_service_rollbacks_total`` counts it) —
instead of page-only alerting.  Only ``canary``-role shadows arm the
trigger; the learn plane's ``candidate``/``last_good`` shadows belong to
the promotion controller.  The controller's rollback path
(``repro.learn.promote``) goes through the same service method, so the
counter and ``last_rollback`` record are shared.

Every touch of the wrapped ``FraudService`` happens under one gateway
RLock — the facade itself is single-threaded by design, the gateway is the
concurrency boundary.  See ``docs/gateway.md`` for curl examples.
"""
from __future__ import annotations

import json
import math
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

import numpy as np

from repro.gateway.telemetry import MetricsRegistry
from repro.service import FraudService, ServiceLifecycleError
from repro.service.config import GatewaySection
from repro.stream.events import CheckoutEvent

#: service lifecycle states /healthz reports ready for traffic
_HEALTHY_STATES = ("built", "ready", "serving")


# ----------------------------------------------------------- wire (de)coding
def event_from_json(d: dict) -> CheckoutEvent:
    """JSON body -> :class:`CheckoutEvent` (the ``/v1/score`` and
    ``/v1/ingest`` streaming-mode unit)."""
    if "features" not in d:
        raise ValueError("event needs a 'features' array")
    return CheckoutEvent(
        order_id=int(d.get("order_id", -1)),
        snapshot=int(d.get("snapshot", 0)),
        entities=tuple(int(e) for e in d.get("entities", ())),
        features=np.asarray(d["features"], np.float32),
        label=float(d.get("label", 0.0)),
        arrival=float(d.get("arrival", 0.0)),
    )


def request_from_json(d: dict) -> dict:
    """JSON body -> the batch-mode score-request dict
    (``FraudService.score`` re-types it via ``ScoreRequest.from_legacy``)."""
    if "features" not in d:
        raise ValueError("request needs a 'features' array")
    return {
        "features": np.asarray(d["features"], np.float32),
        "entity_keys": [(int(e), int(t)) for e, t in d.get("entity_keys", [])],
        "arrival": float(d.get("arrival", 0.0)),
    }


def response_to_json(r) -> dict:
    """``ScoreResponse`` -> JSON-safe dict.  Shed responses carry
    ``score=None`` (their in-process score is NaN, which JSON lacks);
    admitted scores serialize via Python's shortest-round-trip float repr,
    so the wire value parses back bit-identical to the in-process float."""
    tag = r.request.tag
    return {
        "order_id": getattr(tag, "order_id", None),
        "score": None if math.isnan(r.score) else float(r.score),
        "admitted": bool(r.admitted),
        "model_version": int(r.model_version),
        "staleness": int(r.staleness),
        "queued_s": float(r.queued_s),
        "service_s": float(r.service_s),
        "batch_size": int(r.batch_size),
        "worker": int(r.worker),
    }


# -------------------------------------------------- /metrics from ONE snapshot
#: ServiceStats.to_dict() scalar -> (metric name, TYPE); counters follow the
#: Prometheus ``_total`` convention, point-in-time values are gauges
_SERVICE_SCALARS = [
    ("model_version", "repro_service_model_version", "gauge"),
    ("model_swaps", "repro_service_model_swaps_total", "counter"),
    ("requests", "repro_service_requests_total", "counter"),
    ("scored", "repro_service_scored_total", "counter"),
    ("shed", "repro_service_shed_total", "counter"),
    ("blocked", "repro_service_blocked_total", "counter"),
    ("block_timeouts", "repro_service_block_timeouts_total", "counter"),
    ("queue_depth", "repro_service_queue_depth", "gauge"),
    ("queue_depth_peak", "repro_service_queue_depth_peak", "gauge"),
    ("in_flight_peak", "repro_service_in_flight_peak", "gauge"),
    ("flushes", "repro_service_flushes_total", "counter"),
    ("refreshes", "repro_service_refreshes_total", "counter"),
    ("entities_written", "repro_service_entities_written_total", "counter"),
    ("model_stale_reads", "repro_service_model_stale_reads_total", "counter"),
    ("store_size", "repro_service_store_size", "gauge"),
    ("rollbacks", "repro_service_rollbacks_total", "counter"),
    ("last_good_version", "repro_service_last_good_version", "gauge"),
]

_SHADOW_SCALARS = [
    ("version", "repro_shadow_model_version", "gauge"),
    ("fraction", "repro_shadow_fraction", "gauge"),
    ("threshold", "repro_shadow_divergence_threshold", "gauge"),
    ("sampled", "repro_shadow_sampled_total", "counter"),
    ("divergence_sum", "repro_shadow_divergence_sum", "counter"),
    ("divergence_max", "repro_shadow_divergence_max", "gauge"),
    ("last_divergence", "repro_shadow_last_divergence", "gauge"),
    ("alerts", "repro_shadow_alerts_total", "counter"),
    ("alert_active", "repro_shadow_alert_active", "gauge"),
]


def service_metric_lines(snap: dict) -> list[str]:
    """Render the service half of ``GET /metrics`` from a
    ``ServiceStats.to_dict()`` snapshot — the same object ``/v1/stats``
    returns, so the two surfaces can never disagree."""
    lines = [
        "# HELP repro_service_info service mode and lifecycle state",
        "# TYPE repro_service_info gauge",
        f'repro_service_info{{mode="{snap.get("mode", "")}",'
        f'state="{snap.get("state", "")}"}} 1',
    ]

    def emit(name: str, kind: str, value, labels: str = "") -> None:
        lines.append(f"# TYPE {name} {kind}")
        v = float(value)
        lines.append(f"{name}{labels} {int(v) if v.is_integer() else repr(v)}")

    for key, name, kind in _SERVICE_SCALARS:
        if snap.get(key) is not None:   # last_good_version is None-able
            emit(name, kind, snap[key])
    by_version = snap.get("scores_by_version") or {}
    if by_version:
        lines.append("# HELP repro_service_scores_total scored responses "
                     "per model version")
        lines.append("# TYPE repro_service_scores_total counter")
        for v, n in sorted(by_version.items(), key=lambda kv: int(kv[0])):
            lines.append(
                f'repro_service_scores_total{{model_version="{v}"}} {n}')
    shadow = snap.get("shadow") or {}
    for key, name, kind in _SHADOW_SCALARS:
        if key in shadow:
            emit(name, kind, shadow[key])
    for key, value in sorted((snap.get("store_stats") or {}).items()):
        if isinstance(value, (int, float)) and not isinstance(value, bool):
            emit(f"repro_store_{key}_total", "counter", value)
    # per-worker families from the ONE tear-free ServiceStats.workers
    # snapshot (never a second racy pool read)
    workers = snap.get("workers") or []
    if workers:
        for name, kind, key in (
                ("repro_worker_queue_depth", "gauge", "queue_depth"),
                ("repro_worker_flushes_total", "counter", "flushes"),
                ("repro_worker_restarts_total", "counter", "restarts")):
            lines.append(f"# TYPE {name} {kind}")
            for w in workers:
                v = float(w.get(key, 0))
                lines.append(
                    f'{name}{{worker="{w.get("worker", 0)}"}} '
                    f"{int(v) if v.is_integer() else repr(v)}")
        lines.append("# TYPE repro_worker_steals_total counter")
        for w in workers:
            wid = w.get("worker", 0)
            for direction, key in (("in", "stolen_in"), ("out", "stolen_out")):
                lines.append(
                    f'repro_worker_steals_total{{worker="{wid}",'
                    f'direction="{direction}"}} {int(w.get(key, 0))}')
        lines.append("# TYPE repro_worker_alive gauge")
        for w in workers:
            lines.append(
                f'repro_worker_alive{{worker="{w.get("worker", 0)}"}} '
                f"{1 if w.get('alive', True) else 0}")
    return lines


#: learn-plane snapshot key paths -> metric name/TYPE (see learn_metric_lines)
_LEARN_SCALARS = [
    (("fires",), "repro_learn_fires_total", "counter"),
    (("tap", "examples"), "repro_learn_examples_total", "counter"),
    (("tap", "pending"), "repro_learn_label_pending", "gauge"),
    (("tap", "label_joins"), "repro_learn_label_joins_total", "counter"),
    (("tap", "cursor"), "repro_learn_tap_cursor", "gauge"),
    (("promotion", "submitted"), "repro_learn_candidates_total", "counter"),
    (("promotion", "promoted"), "repro_learn_promotions_total", "counter"),
    (("promotion", "rejected"), "repro_learn_rejections_total", "counter"),
    (("promotion", "rollbacks"), "repro_learn_rollbacks_total", "counter"),
]


def learn_metric_lines(stats: dict) -> list[str]:
    """Render the learn-plane half of ``GET /metrics`` from a
    :meth:`~repro.learn.ContinuousLearner.stats` snapshot — the same
    object ``GET /v1/learn/stats`` returns."""
    lines = [
        "# HELP repro_learn_info promotion state machine phase",
        "# TYPE repro_learn_info gauge",
        f'repro_learn_info{{state="{stats.get("state", "")}"}} 1',
    ]
    for path, name, kind in _LEARN_SCALARS:
        node = stats
        for k in path:
            node = node.get(k) if isinstance(node, dict) else None
            if node is None:
                break
        if node is None:
            continue
        v = float(node)
        lines.append(f"# TYPE {name} {kind}")
        lines.append(f"{name} {int(v) if v.is_integer() else repr(v)}")
    return lines


class GatewayError(Exception):
    """A handler-level failure with an HTTP status (rendered as JSON)."""

    def __init__(self, status: int, message: str):
        super().__init__(message)
        self.status = status


class FraudGateway:
    """The HTTP front-end over one :class:`FraudService`.

    ``start()`` binds ``config.gateway.host:port`` (port 0 = ephemeral; see
    :attr:`port`) and serves on a daemon thread pool (one thread per
    connection — ``ThreadingHTTPServer``); ``close()`` shuts the socket
    down and joins the serve thread.  Usable as a context manager.

    The service must already be ``build()``-ed; ``warmup()`` beforehand
    keeps jit compiles off the first request's latency.

    ``learner``: an optional :class:`~repro.learn.ContinuousLearner`
    bound to the same service — enables ``POST /admin/train`` and
    ``GET /v1/learn/stats`` (``serve_gateway`` attaches one when
    ``config.learn.enabled``).
    """

    def __init__(self, service: FraudService, config: GatewaySection | None = None,
                 learner=None):
        self.service = service
        self.learner = learner
        self.config = config or service.config.gateway
        self.lock = threading.RLock()
        self.draining = False
        self._httpd: ThreadingHTTPServer | None = None
        self._thread: threading.Thread | None = None
        m = self.metrics = MetricsRegistry()
        self.http_requests = m.counter(
            "gateway_http_requests_total",
            "HTTP requests by endpoint and status code",
            labelnames=("endpoint", "code"))
        self.http_seconds = m.histogram(
            "gateway_http_request_seconds",
            "wall time spent in the handler, per endpoint",
            buckets=self.config.latency_buckets, labelnames=("endpoint",))
        self.scores_total = m.counter(
            "gateway_scores_total",
            "scored responses delivered over the wire, per model version",
            labelnames=("model_version",))
        self.score_seconds = m.histogram(
            "gateway_score_latency_seconds",
            "per-response score latency (queue wait + service time)",
            buckets=self.config.latency_buckets)

    # ------------------------------------------------------------- lifecycle
    def start(self) -> "FraudGateway":
        if self._httpd is not None:
            raise RuntimeError("gateway already started")
        if self.service.state not in _HEALTHY_STATES:
            raise RuntimeError(
                f"gateway needs a built service (state is "
                f"{self.service.state!r}); call build()/warmup() first")
        self._httpd = ThreadingHTTPServer(
            (self.config.host, self.config.port), _Handler)
        self._httpd.daemon_threads = True
        self._httpd.gateway = self
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, kwargs={"poll_interval": 0.05},
            name="fraud-gateway", daemon=True)
        self._thread.start()
        return self

    @property
    def port(self) -> int:
        """The bound port (the kernel's pick when configured port was 0)."""
        if self._httpd is None:
            raise RuntimeError("gateway not started")
        return self._httpd.server_address[1]

    @property
    def url(self) -> str:
        return f"http://{self.config.host}:{self.port}"

    def close(self) -> None:
        """Stop accepting connections and join the serve thread
        (idempotent).  The wrapped service is left open — callers own its
        lifecycle."""
        if self._httpd is None:
            return
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
        self._httpd, self._thread = None, None

    def __enter__(self) -> "FraudGateway":
        return self.start() if self._httpd is None else self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------- endpoints
    # each handle_* returns (status, payload, headers, shadow_batch); the
    # HTTP layer writes the response FIRST, then feeds shadow_batch to the
    # canary scorer — shadow work never sits on the response path
    def handle_score(self, body: dict):
        if self.draining:
            raise GatewayError(503, "gateway is draining")
        svc = self.service
        if svc.mode == "streaming":
            items, single = self._body_items(body, "event", "events")
            events = [event_from_json(d) for d in items]
            with self.lock:
                results: list = []
                for ev in events:
                    results.extend(svc.submit(ev))
                pending = len(svc.engine.pool)
        else:
            items, single = self._body_items(body, "request", "requests")
            reqs = [request_from_json(d) for d in items]
            with self.lock:
                results = svc.score(reqs)
                pending = 0
        scored = [r for r in results if r.admitted]
        shed = [r for r in results if not r.admitted]
        for r in scored:
            self.scores_total.inc(model_version=r.model_version)
            self.score_seconds.observe(r.queued_s + r.service_s)
        status, headers = 200, {}
        if shed:
            # admission rejections map to socket-level backpressure: shed
            # policy -> 429 (come back later), a timed-out block stall ->
            # 503 (the service is saturated, not just this caller)
            status = 429 if svc.config.admission.policy == "shed" else 503
            headers["Retry-After"] = f"{self.config.retry_after_s:.3f}"
        payload = {
            "results": [response_to_json(r) for r in results],
            "scored": len(scored), "shed": len(shed), "pending": pending,
            "model_version": svc.model_version,
        }
        if single and not results:
            payload["note"] = "queued; results ride a later response or drain"
        return status, payload, headers, scored

    def handle_ingest(self, body: dict):
        if self.draining:
            raise GatewayError(503, "gateway is draining")
        svc = self.service
        if svc.mode != "streaming":
            raise GatewayError(
                400, "ingest without scoring requires mode='streaming'")
        items, _ = self._body_items(body, "event", "events")
        events = [event_from_json(d) for d in items]
        with self.lock:
            for ev in events:
                svc.ingest(ev)
            refreshes = svc.engine.refresher.stats["refreshes"]
        return 200, {"ingested": len(events), "refreshes": refreshes}, {}, None

    def handle_health(self):
        with self.lock:
            state = self.service.state
            version = self.service.model_version
            dead = 0
            eng = self.service.engine
            if self.service.mode == "streaming" and eng is not None:
                # process backend: a dead shard owner means requests routed
                # to it would stall until its heartbeat restart — report
                # not-ready rather than serve into the gap (inline workers
                # are in-process and always "alive")
                dead = sum(1 for row in eng.pool.worker_summary()
                           if not row.get("alive", True))
        ok = (not self.draining) and state in _HEALTHY_STATES and dead == 0
        payload = {"status": "ok" if ok else "unavailable", "state": state,
                   "draining": self.draining, "model_version": version,
                   "dead_workers": dead}
        return (200 if ok else 503), payload, {}, None

    def handle_stats(self):
        with self.lock:
            snap = self.service.stats().to_dict()
        gw = {"draining": self.draining, "metrics": self.metrics.snapshot()}
        return 200, {"service": snap, "gateway": gw}, {}, None

    def handle_metrics(self):
        with self.lock:
            snap = self.service.stats().to_dict()
            learn = self.learner.stats() if self.learner is not None else None
        lines = service_metric_lines(snap)
        if learn is not None:
            lines += learn_metric_lines(learn)
        text = "\n".join(lines) + "\n" + self.metrics.render()
        return 200, text, {"Content-Type": "text/plain; version=0.0.4"}, None

    def handle_learn_stats(self):
        if self.learner is None:
            raise GatewayError(409, "no continuous learner attached — boot "
                                    "with config.learn.enabled=true")
        with self.lock:
            return 200, self.learner.stats(), {}, None

    def handle_admin_train(self, body: dict):
        """One learn tick on demand: poll the WAL tap, fine-tune if the
        rolling window advanced (``{"force": true}`` fires regardless),
        and step the promotion state machine."""
        if self.learner is None:
            raise GatewayError(409, "no continuous learner attached — boot "
                                    "with config.learn.enabled=true")
        if not isinstance(body, dict):
            raise GatewayError(400, "body must be a JSON object")
        force = bool(body.get("force", False))
        now = body.get("now")
        with self.lock:
            out = self.learner.step(
                now=None if now is None else float(now), force=force)
            out["state"] = self.learner.controller.state
            out["model_version"] = self.service.model_version
        return 200, out, {}, None

    def handle_admin_model(self, body: dict):
        svc, role = self.service, body.get("role", "primary")
        if role not in ("primary", "canary"):
            raise GatewayError(400, f"unknown role {role!r} "
                                    "(expected 'primary' or 'canary')")
        with self.lock:
            try:
                version = body.get("version")
                if "from_version" in body:
                    version = svc.register_perturbed(
                        int(body["from_version"]),
                        float(body.get("perturb_scale", 0.0)),
                        seed=int(body.get("seed", 0)),
                        version=version)
                if role == "primary":
                    if version is None:
                        raise GatewayError(
                            400, "role='primary' needs 'version' (or "
                                 "'from_version' to register one)")
                    active = svc.activate_model(int(version))
                    payload = {"role": "primary", "model_version": active,
                               "model_versions": list(svc.model_versions())}
                elif version is None:
                    svc.disable_shadow()
                    payload = {"role": "canary", "enabled": False}
                else:
                    snap = svc.enable_shadow(
                        int(version),
                        fraction=body.get("fraction"),
                        threshold=body.get("threshold"))
                    payload = {"role": "canary", "enabled": True,
                               "shadow": snap}
            except KeyError as exc:
                raise GatewayError(400, str(exc.args[0])) from exc
        return 200, payload, {}, None

    def handle_admin_checkpoint(self, body: dict):
        if not isinstance(body, dict):
            raise GatewayError(400, "body must be a JSON object")
        compact = bool(body.get("compact", False))
        with self.lock:
            try:
                path = self.service.checkpoint(compact=compact)
            except ServiceLifecycleError as exc:
                # no WAL / wrong lifecycle state: a client error, not a 500
                raise GatewayError(409, str(exc)) from exc
            applied = self.service.applied_seq
        return 200, {"checkpoint": path, "applied_seq": applied,
                     "compacted": compact}, {}, None

    def handle_admin_drain(self):
        with self.lock:
            results = self.service.drain()
            self.draining = True
            state = self.service.state
        for r in results:
            self.scores_total.inc(model_version=r.model_version)
            self.score_seconds.observe(r.queued_s + r.service_s)
        return 200, {
            "drained": len(results), "state": state,
            "results": [response_to_json(r) for r in results],
        }, {}, results

    def shadow_after(self, responses: list) -> None:
        """Feed delivered responses to the canary — called by the HTTP
        layer strictly after the response bytes hit the socket.

        With ``gateway.auto_rollback`` enabled, a sticky divergence alert
        raised by this batch triggers the shared rollback path
        (:meth:`FraudService.rollback_model`) when a last-good version
        exists — the swap is immediate, not page-and-wait.  Only
        ``canary``-role shadows arm this: a ``candidate`` shadow is a
        fine-tune that is *expected* to diverge (that's the promotion
        signal), and ``last_good`` watches belong to the
        :class:`~repro.learn.PromotionController`'s own rollback logic."""
        if not responses:
            return
        with self.lock:
            self.service.shadow_observe(responses)
            sh = self.service.shadow_stats()
            if (self.config.auto_rollback
                    and sh.get("role") == "canary"
                    and sh.get("alert_active")
                    and self.service.last_good_version is not None):
                self.service.rollback_model(
                    "gateway auto-rollback: shadow divergence alert")

    @staticmethod
    def _body_items(body: dict, one: str, many: str):
        """Accept ``{one: {...}}`` or ``{many: [...]}`` -> (items, single)."""
        if not isinstance(body, dict):
            raise GatewayError(400, "body must be a JSON object")
        if one in body:
            return [body[one]], True
        if many in body:
            items = body[many]
            if not isinstance(items, list):
                raise GatewayError(400, f"'{many}' must be a list")
            return items, False
        raise GatewayError(400, f"body needs '{one}' or '{many}'")


class _Handler(BaseHTTPRequestHandler):
    """HTTP plumbing only — routing, body limits, JSON framing.  All
    semantics live on :class:`FraudGateway`."""

    protocol_version = "HTTP/1.1"   # keep-alive: bench clients reuse sockets
    _GET = {"/healthz": "handle_health", "/v1/stats": "handle_stats",
            "/v1/learn/stats": "handle_learn_stats",
            "/metrics": "handle_metrics"}
    _POST = {"/v1/score": "handle_score", "/v1/ingest": "handle_ingest",
             "/admin/model": "handle_admin_model",
             "/admin/drain": "handle_admin_drain",
             "/admin/checkpoint": "handle_admin_checkpoint",
             "/admin/train": "handle_admin_train"}

    @property
    def gateway(self) -> FraudGateway:
        return self.server.gateway

    def log_message(self, *args) -> None:   # quiet: telemetry, not stderr
        pass

    def _endpoint(self, table: dict) -> str | None:
        path = self.path.split("?", 1)[0]
        return path if path in table else None

    def do_GET(self) -> None:
        self._dispatch(self._GET, needs_body=False)

    def do_POST(self) -> None:
        self._dispatch(self._POST, needs_body=True)

    def _dispatch(self, table: dict, needs_body: bool) -> None:
        t0 = time.perf_counter()
        endpoint = self._endpoint(table)
        if endpoint is None:
            self._reply("(404)", 404, {"error": f"no such endpoint {self.path!r}"},
                        {}, t0)
            return
        gw, shadow_batch = self.gateway, None
        try:
            if needs_body:
                body = self._read_json()
                args = () if endpoint.startswith("/admin/drain") else (body,)
            else:
                args = ()
            handler = getattr(gw, table[endpoint])
            status, payload, headers, shadow_batch = handler(*args)
        except GatewayError as exc:
            status, payload, headers = exc.status, {"error": str(exc)}, {}
        except (ValueError, TypeError) as exc:
            status, payload, headers = 400, {"error": str(exc)}, {}
        except Exception as exc:   # noqa: BLE001 — the server must not die
            status, payload, headers = 500, {
                "error": f"{type(exc).__name__}: {exc}"}, {}
        self._reply(endpoint, status, payload, headers, t0)
        # canary work happens AFTER the response is on the wire
        if shadow_batch:
            gw.shadow_after(shadow_batch)

    def _read_json(self) -> dict:
        length = int(self.headers.get("Content-Length") or 0)
        if length > self.gateway.config.max_body_bytes:
            raise GatewayError(
                413, f"body of {length} bytes exceeds max_body_bytes="
                     f"{self.gateway.config.max_body_bytes}")
        raw = self.rfile.read(length) if length else b""
        if not raw:
            return {}
        try:
            return json.loads(raw)
        except json.JSONDecodeError as exc:
            raise GatewayError(400, f"invalid JSON body: {exc}") from exc

    def _reply(self, endpoint: str, status: int, payload, headers: dict,
               t0: float) -> None:
        if isinstance(payload, str):
            data = payload.encode()
            ctype = headers.pop("Content-Type", "text/plain")
        else:
            data = json.dumps(payload).encode()
            ctype = headers.pop("Content-Type", "application/json")
        try:
            self.send_response(status)
            self.send_header("Content-Type", ctype)
            self.send_header("Content-Length", str(len(data)))
            for k, v in headers.items():
                self.send_header(k, v)
            self.end_headers()
            self.wfile.write(data)
            self.wfile.flush()
        except (BrokenPipeError, ConnectionResetError):
            pass   # client went away; telemetry still records the attempt
        gw = self.gateway
        gw.http_requests.inc(endpoint=endpoint, code=str(status))
        gw.http_seconds.observe(time.perf_counter() - t0, endpoint=endpoint)


def serve_gateway(config, params, *, warmup: bool = True) -> FraudGateway:
    """One-liner boot: build a :class:`FraudService` from ``config`` +
    ``params``, optionally warm it up, and start the HTTP gateway on
    ``config.gateway``.  Returns the started gateway (``gateway.service``
    reaches the facade; close with ``gateway.close()``).

    With ``gateway.checkpoint_dir`` set the boot is crash-consistent: if
    the directory already holds durable state (a ``service.json`` written
    by a previous ``enable_wal``), the service is *restored* from its
    latest checkpoint + WAL suffix instead of built fresh — ``params`` is
    ignored on that path because the restored model registry is
    authoritative.  A fresh directory gets a fresh build with the
    write-ahead log enabled under it.
    """
    import os

    from repro.service import build_service
    from repro.service.config import ServiceConfig

    if isinstance(config, dict):
        config = ServiceConfig.from_dict(config)
    root = config.gateway.checkpoint_dir
    if root and os.path.exists(os.path.join(root, "service.json")):
        svc = FraudService.restore(root)
        if warmup and svc.state in ("built", "ready"):
            svc.warmup()
    else:
        svc = build_service(config, params, warmup=warmup)
        if root:
            svc.enable_wal(root)
    gw = config.gateway
    if svc.wal is not None and (gw.checkpoint_every_s is not None
                                or gw.checkpoint_every_windows is not None):
        # scheduled checkpointing is process-local cadence state — re-armed
        # on every boot, including restores
        svc.enable_auto_checkpoint(
            every_s=gw.checkpoint_every_s,
            every_windows=gw.checkpoint_every_windows,
            keep_last=gw.checkpoint_keep_last)
    learner = None
    if config.learn.enabled:
        if svc.wal is None:
            raise ValueError(
                "learn.enabled=true requires gateway.checkpoint_dir — the "
                "continuous learner taps the write-ahead log")
        from repro.learn import ContinuousLearner

        learner = ContinuousLearner(svc)
    return FraudGateway(svc, learner=learner).start()


__all__ = ["FraudGateway", "GatewayError", "learn_metric_lines",
           "serve_gateway", "event_from_json", "request_from_json",
           "response_to_json", "service_metric_lines"]
