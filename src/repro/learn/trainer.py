"""Rolling-window fine-tunes over tap examples (Morpheus-DFP-style).

The trainer holds a bounded buffer of :class:`~repro.learn.tap.TrainingExample`
rows and advances a **rolling window**: once at least ``min_window``
examples are buffered (and ``stride`` new ones since the last fire), it
trains on the newest ``max_window`` examples — deduplicated by order id,
keep-latest, so re-scored orders and label-log corrections supersede
their earlier copies — and records the fire so the next one waits for
another stride of fresh data.

A fine-tune warm-starts from the incumbent's parameters and runs a few
steps of locally-implemented SGD/Adam (no optax) on
:func:`~repro.core.lnn.lnn_loss` over the *window-local* DDS graph: the
window's examples are replayed through a fresh
:class:`~repro.core.dds.IncrementalDDSBuilder`, materialized, and padded
to a power-of-two node budget (bounded jit recompiles, same trick as the
batch-layer refresher).  With ``head="hybrid"`` the tuned stage-1/2
parameters are then frozen and the PR-8 GBDT head is refit on the
window's pre-MLP embeddings (:func:`~repro.models.hybrid.train_hybrid`),
yielding a :class:`~repro.models.hybrid.HybridModel` candidate.
"""
from __future__ import annotations

import os
import shutil
import tempfile
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dds import IncrementalDDSBuilder
from repro.core.graph import pad_graph
from repro.core.hetero import type_code_of
from repro.core.lnn import LNNConfig, lnn_loss, lnn_stage1, lnn_stage2_embed

__all__ = ["FineTuneResult", "RollingWindowTrainer", "WindowPolicy",
           "adam", "sgd"]


# ---------------------------------------------------------------- optimizers
def sgd(lr: float = 1e-2, momentum: float = 0.0):
    """Plain (heavy-ball) SGD as an ``(init_fn, update_fn)`` pair —
    ``update_fn(grads, state, params) -> (new_params, new_state)``.
    Local implementation, no optax (mirrors ``repro.train.optim``)."""

    def init_fn(params):
        return jax.tree_util.tree_map(jnp.zeros_like, params)

    def update_fn(grads, state, params):
        vel = jax.tree_util.tree_map(
            lambda v, g: momentum * v + g, state, grads)
        new = jax.tree_util.tree_map(lambda p, v: p - lr * v, params, vel)
        return new, vel

    return init_fn, update_fn


def adam(lr: float = 1e-3, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8):
    """Adam as an ``(init_fn, update_fn)`` pair (bias-corrected moments;
    local implementation, no optax)."""

    def init_fn(params):
        z = jax.tree_util.tree_map(jnp.zeros_like, params)
        return {"step": jnp.zeros((), jnp.int32), "mu": z, "nu": z}

    def update_fn(grads, state, params):
        step = state["step"] + 1
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g, state["mu"], grads)
        nu = jax.tree_util.tree_map(
            lambda n, g: b2 * n + (1 - b2) * g * g, state["nu"], grads)
        c1 = 1 - b1 ** step.astype(jnp.float32)
        c2 = 1 - b2 ** step.astype(jnp.float32)
        new = jax.tree_util.tree_map(
            lambda p, m, n: p - lr * (m / c1) / (jnp.sqrt(n / c2) + eps),
            params, mu, nu)
        return new, {"step": step, "mu": mu, "nu": nu}

    return init_fn, update_fn


_OPTIMIZERS = {"sgd": sgd, "adam": adam}


# -------------------------------------------------------------------- policy
@dataclass(frozen=True)
class WindowPolicy:
    """Rolling-window advance policy: fire on ``min_window`` buffered +
    ``stride`` fresh, train on the newest ``max_window`` (``dedup`` =
    keep-latest per order id)."""

    min_window: int = 32
    max_window: int = 256
    stride: int = 32
    dedup: bool = True

    def __post_init__(self):
        if self.min_window < 1:
            raise ValueError("min_window must be >= 1")
        if self.max_window < self.min_window:
            raise ValueError("max_window must be >= min_window")
        if not (1 <= self.stride <= self.max_window):
            raise ValueError("stride must be in [1, max_window]")


@dataclass
class FineTuneResult:
    """One fine-tune outcome: the candidate model plus its training trace."""

    params: dict                 # tuned LNN pytree
    model: object                # what to register: params, or a HybridModel
    head: str                    # 'mlp' | 'hybrid'
    window: int                  # examples actually trained on (post-dedup)
    steps: int
    losses: list                 # per-step lnn_loss values (python floats)


def _pow2_at_least(n: int, floor: int = 64) -> int:
    p = floor
    while p < n:
        p *= 2
    return p


# ------------------------------------------------- pure fine-tune primitives
# Module-level so the inline path and the dedicated trainer process run the
# EXACT same code — in-process fine-tunes are bit-identical to inline ones
# (same ops, same host, same XLA), which the parity tests assert.
def _materialize_window(cfg: LNNConfig, rows: list, *, entity_history: str,
                        max_history, max_deg: int):
    """Window rows ``(snapshot, arrival, entities, features, label)`` →
    window-local DDS graph, padded to pow2 nodes (receptive cones are
    window-local by design: the rolling window IS the context the
    fine-tune sees, matching its serving horizon)."""
    b = IncrementalDDSBuilder(
        feat_dim=cfg.feat_dim, entity_history=entity_history,
        max_history=max_history)
    for snap, _arr, entities, features, label in sorted(
            rows, key=lambda r: (r[0], r[1])):
        b.add_order(entities, snap, features, label)
    dds = b.build()
    pg = pad_graph(dds.coo,
                   num_nodes=_pow2_at_least(dds.coo.num_nodes),
                   max_deg=max_deg)
    return dds, pg


def _fine_tune(params, cfg: LNNConfig, pg, optimizer: str, lr: float,
               steps: int):
    """A few steps of the local optimizer on ``lnn_loss`` over the window
    graph; returns ``(tuned_params, losses)``."""
    init_fn, update_fn = _OPTIMIZERS[optimizer](lr)
    loss_grad = jax.jit(jax.value_and_grad(
        lambda p, g: lnn_loss(p, cfg, g)))
    opt = init_fn(params)
    losses = []
    for _ in range(steps):
        loss, grads = loss_grad(params, pg)
        params, opt = update_fn(grads, opt, params)
        losses.append(float(loss))
    return params, losses


def _train_child_main(conn, spec: dict) -> None:
    """Entry point of the dedicated fine-tune process (spawn start method).

    The window ships as an ``.npz`` blob (flat entity array + offsets for
    the ragged cone lists) and the warm start as a params checkpoint; the
    tuned candidate travels back the same way — an npz file the parent
    loads and feeds into the ordinary registration/promotion path.  Only
    the loss trace crosses the pipe."""
    try:
        from repro.core.lnn import lnn_init
        from repro.train.checkpoint import load_checkpoint, save_checkpoint

        cfg = spec["cfg"]
        blob = np.load(spec["window_path"])
        feats = blob["features"]
        labels = blob["labels"]
        snaps = blob["snapshots"]
        arrivals = blob["arrivals"]
        ent_flat, ent_off = blob["ent_flat"], blob["ent_off"]
        rows = [
            (int(snaps[i]), float(arrivals[i]),
             tuple(int(e) for e in ent_flat[ent_off[i]:ent_off[i + 1]]),
             feats[i], float(labels[i]))
            for i in range(len(labels))
        ]
        template = lnn_init(jax.random.PRNGKey(0), cfg)
        warm = load_checkpoint(spec["warm_path"], template)[0]
        _dds, pg = _materialize_window(
            cfg, rows, entity_history=spec["entity_history"],
            max_history=spec["max_history"], max_deg=spec["max_deg"])
        tuned, losses = _fine_tune(
            warm, cfg, pg, spec["optimizer"], spec["lr"], spec["steps"])
        save_checkpoint(spec["out_path"], tuned)
        conn.send(("ok", losses))
    except Exception as e:   # noqa: BLE001 — the parent re-raises
        try:
            conn.send(("error", f"{type(e).__name__}: {e}"))
        except OSError:
            pass
    finally:
        conn.close()


# ------------------------------------------------------------------- trainer
class RollingWindowTrainer:
    """Accumulate tap examples; fine-tune on rolling windows.

    ``k_max``/``max_deg`` come from the serving engine so the window graph
    is padded the same way the batch layer pads — the candidate sees
    exactly the serving geometry.
    """

    def __init__(self, cfg: LNNConfig, policy: WindowPolicy | None = None, *,
                 optimizer: str = "adam", lr: float = 5e-3, steps: int = 40,
                 head: str = "mlp", gbdt_trees: int = 25, k_max: int = 8,
                 max_deg: int = 32, entity_history: str = "all",
                 max_history: int | None = None, in_process: bool = False):
        if optimizer not in _OPTIMIZERS:
            raise ValueError(f"optimizer must be one of {sorted(_OPTIMIZERS)}")
        if head not in ("mlp", "hybrid"):
            raise ValueError("head must be 'mlp' or 'hybrid'")
        if steps < 1:
            raise ValueError("steps must be >= 1")
        self.cfg = cfg
        self.policy = policy if policy is not None else WindowPolicy()
        self.optimizer, self.lr, self.steps = optimizer, float(lr), int(steps)
        self.head, self.gbdt_trees = head, int(gbdt_trees)
        self.k_max, self.max_deg = int(k_max), int(max_deg)
        self.entity_history, self.max_history = entity_history, max_history
        # in_process=True runs each fine-tune in a dedicated spawn()ed
        # process (off the serving GIL); the GBDT head refit stays in the
        # parent — the booster isn't an npz-serializable pytree
        self.in_process = bool(in_process)
        self._buffer: list = []
        self._since_fire: int | None = None   # None = never fired
        self.stats = {"examples": 0, "fires": 0, "last_window": 0,
                      "last_loss": None}

    # -------------------------------------------------------------- buffering
    def add(self, example) -> None:
        """Buffer one tap example (arrival order)."""
        self._buffer.append(example)
        if self._since_fire is not None:
            self._since_fire += 1
        self.stats["examples"] += 1
        # bound memory: the policy can never look past max_window examples,
        # except that dedup needs slack for superseded duplicates
        cap = 4 * self.policy.max_window
        if len(self._buffer) > cap:
            del self._buffer[: len(self._buffer) - cap]

    def extend(self, examples) -> None:
        """Buffer many tap examples."""
        for ex in examples:
            self.add(ex)

    def ready(self) -> bool:
        """True when the rolling window should advance: enough buffered,
        and a full stride of fresh examples since the last fire."""
        if len(self._buffer) < self.policy.min_window:
            return False
        return self._since_fire is None \
            or self._since_fire >= self.policy.stride

    def _window(self) -> list:
        """The newest ``max_window`` examples, deduped keep-latest."""
        ex = self._buffer
        if self.policy.dedup:
            latest: dict[tuple, object] = {}
            for e in ex:     # later entries overwrite earlier (keep-latest)
                latest[(e.order_id, e.seq if e.order_id < 0 else -1)] = e
            ex = list(latest.values())
        return ex[-self.policy.max_window:]

    # ----------------------------------------------------------------- train
    def train(self, params) -> FineTuneResult:
        """Fine-tune ``params`` on the current window; marks the fire."""
        window = self._window()
        if not window:
            raise ValueError("train() with an empty window")
        self._since_fire = 0
        self.stats["fires"] += 1
        self.stats["last_window"] = len(window)

        if self.in_process:
            params, losses = self._train_in_process(params, window)
            dds = pg = None
        else:
            dds, pg = self._materialize(window)
            params, losses = _fine_tune(
                params, self.cfg, pg, self.optimizer, self.lr, self.steps)
        self.stats["last_loss"] = losses[-1]

        model = params
        if self.head == "hybrid":
            if pg is None:
                # GBDT refit runs in the parent either way; rebuild the
                # (deterministic) window graph the child built for itself
                dds, pg = self._materialize(window)
            model = self._fit_hybrid(params, window, dds, pg)
        return FineTuneResult(params=params, model=model, head=self.head,
                              window=len(window), steps=self.steps,
                              losses=losses)

    def _materialize(self, window):
        """Window examples → window-local DDS graph (see
        :func:`_materialize_window`)."""
        rows = [(e.snapshot, e.arrival, e.entities, e.features, e.label)
                for e in window]
        return _materialize_window(
            self.cfg, rows, entity_history=self.entity_history,
            max_history=self.max_history, max_deg=self.max_deg)

    def _train_in_process(self, params, window):
        """Run one fine-tune in a dedicated spawn()ed process.

        Window examples ship as an npz blob (features/labels/snapshots/
        arrivals + flat entities with offsets), the warm start as a params
        checkpoint; the tuned candidate comes back as an npz the parent
        loads into the warm start's pytree structure.  A child that dies
        or reports an error raises — the trainer never silently falls back
        to a stale candidate."""
        from multiprocessing import get_context

        from repro.train.checkpoint import load_checkpoint, save_checkpoint

        tmp = tempfile.mkdtemp(prefix="repro-finetune-")
        try:
            warm_path = os.path.join(tmp, "warm.npz")
            out_path = os.path.join(tmp, "tuned.npz")
            window_path = os.path.join(tmp, "window.npz")
            save_checkpoint(warm_path, params)
            ent_flat: list[int] = []
            ent_off = [0]
            for e in window:
                ent_flat.extend(int(x) for x in e.entities)
                ent_off.append(len(ent_flat))
            np.savez(
                window_path,
                features=np.stack([np.asarray(e.features, np.float32)
                                   for e in window]),
                labels=np.asarray([e.label for e in window], np.float32),
                snapshots=np.asarray([e.snapshot for e in window], np.int64),
                arrivals=np.asarray([e.arrival for e in window], np.float64),
                ent_flat=np.asarray(ent_flat, np.int64),
                ent_off=np.asarray(ent_off, np.int64))
            spec = {
                "cfg": self.cfg, "window_path": window_path,
                "warm_path": warm_path, "out_path": out_path,
                "optimizer": self.optimizer, "lr": self.lr,
                "steps": self.steps, "max_deg": self.max_deg,
                "entity_history": self.entity_history,
                "max_history": self.max_history,
            }
            ctx = get_context("spawn")
            parent_conn, child_conn = ctx.Pipe()
            proc = ctx.Process(target=_train_child_main,
                               args=(child_conn, spec),
                               name="repro-finetune", daemon=True)
            proc.start()
            child_conn.close()
            try:
                status, payload = parent_conn.recv()
            except EOFError:
                raise RuntimeError(
                    "fine-tune process died before returning a result")
            finally:
                proc.join()
                parent_conn.close()
            if status != "ok":
                raise RuntimeError(f"fine-tune process failed: {payload}")
            tuned = load_checkpoint(out_path, params)[0]
            return tuned, payload
        finally:
            shutil.rmtree(tmp, ignore_errors=True)

    def _fit_hybrid(self, params, window, dds, pg):
        """Refit the GBDT head on the tuned-then-frozen embedding: stage-1
        over the window graph, each order's final-hop cone gathered into
        the online [B, K, H] layout, then ``train_hybrid`` on the pre-MLP
        stage-2 embeddings."""
        from repro.baselines.gbdt import GBDTConfig
        from repro.models.hybrid import train_hybrid

        h = np.asarray(lnn_stage1(params, self.cfg, pg), np.float32)
        n_ord = dds.num_orders
        hid = h.shape[-1]
        ent = np.zeros((n_ord, self.k_max, hid), np.float32)
        mask = np.zeros((n_ord, self.k_max), np.float32)
        slot = np.full((n_ord, self.k_max), -1, np.int32)
        typed = bool(self.cfg.entity_types)
        for o in range(n_ord):
            for k, (e, _t, nid) in enumerate(dds.last_hop.get(o, [])[: self.k_max]):
                ent[o, k] = h[nid]
                mask[o, k] = 1.0
                if typed:
                    slot[o, k] = type_code_of(e)
        feats = np.asarray(pg.features[:n_ord], np.float32)
        emb = np.asarray(lnn_stage2_embed(
            params, self.cfg, ent, mask, feats,
            slot_type=slot if typed else None), np.float32)
        labels = np.asarray(pg.label[:n_ord], np.float32)
        return train_hybrid(params, self.cfg, emb, labels,
                            gbdt_cfg=GBDTConfig(num_trees=self.gbdt_trees))
