"""Pytree helpers used across training, checkpointing and the dry-run."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_size(tree) -> int:
    """Total number of elements in a pytree of arrays/ShapeDtypeStructs."""
    leaves = jax.tree_util.tree_leaves(tree)
    total = 0
    for leaf in leaves:
        shape = getattr(leaf, "shape", ())
        n = 1
        for d in shape:
            n *= int(d)
        total += n
    return total


def tree_bytes(tree) -> int:
    """Total bytes of a pytree of arrays/ShapeDtypeStructs."""
    leaves = jax.tree_util.tree_leaves(tree)
    total = 0
    for leaf in leaves:
        shape = getattr(leaf, "shape", ())
        n = 1
        for d in shape:
            n *= int(d)
        total += n * jnp.dtype(getattr(leaf, "dtype", jnp.float32)).itemsize
    return total


def tree_zeros_like(tree):
    return jax.tree_util.tree_map(lambda x: jnp.zeros(x.shape, x.dtype), tree)


def tree_map_with_path(fn, tree):
    """Map ``fn(path_str, leaf)`` over a pytree; path is '/'-joined keys."""

    def _fmt(path):
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        return "/".join(parts)

    return jax.tree_util.tree_map_with_path(lambda p, x: fn(_fmt(p), x), tree)
