"""`repro.gateway`: HTTP wire protocol over FraudService — end-to-end wire
parity with in-process scoring (N=1/N=4, mid-stream hot-swap), socket-level
backpressure (429/503), canary/shadow divergence alerting, Prometheus
telemetry, and concurrent hot-swap under threaded load."""
import json
import math
import threading
import time
import urllib.error
import urllib.request

import jax
import pytest

from repro.core import LNNConfig, lnn_init
from repro.data import SynthConfig, generate_event_stream
from repro.gateway import FraudGateway, MetricsRegistry, serve_gateway
from repro.service import FraudService, ModelSection, ServiceConfig


@pytest.fixture(scope="module")
def gateway_world():
    events, g, _ = generate_event_stream(
        SynthConfig(num_users=60, num_rings=3, feature_noise=0.8, seed=7),
        rate_per_s=500.0,
    )
    cfg = LNNConfig(num_gnn_layers=2, hidden_dim=16,
                    feat_dim=g.order_features.shape[1])
    params = lnn_init(jax.random.PRNGKey(0), cfg)
    sc = ServiceConfig(model=ModelSection.from_lnn_config(cfg)).replace(
        engine={"max_batch": 8})
    return events, cfg, params, sc


class Client:
    """Tiny JSON-over-HTTP helper; never raises on HTTP error status."""

    def __init__(self, url: str):
        self.url = url

    def _do(self, req):
        try:
            with urllib.request.urlopen(req, timeout=30) as r:
                return r.status, dict(r.headers), r.read()
        except urllib.error.HTTPError as e:
            return e.code, dict(e.headers), e.read()

    def get(self, path: str):
        status, headers, raw = self._do(self.url + path)
        return status, headers, json.loads(raw)

    def get_text(self, path: str):
        status, _, raw = self._do(self.url + path)
        return status, raw.decode()

    def post(self, path: str, body, raw: bytes | None = None):
        data = raw if raw is not None else json.dumps(body).encode()
        req = urllib.request.Request(
            self.url + path, data=data,
            headers={"Content-Type": "application/json"})
        status, headers, out = self._do(req)
        return status, headers, json.loads(out)


def _ev_json(ev) -> dict:
    return {"order_id": ev.order_id, "snapshot": ev.snapshot,
            "entities": list(ev.entities), "features": ev.features.tolist(),
            "arrival": ev.arrival}


def _boot(sc, params, **overrides):
    """Build + start a gateway on an ephemeral port; returns (gateway, client)."""
    svc = FraudService(sc.replace(**overrides) if overrides else sc,
                       params=params).build()
    gw = FraudGateway(svc).start()
    return gw, Client(gw.url)


# ------------------------------------------------------------- telemetry unit
def test_telemetry_counter_gauge_histogram():
    m = MetricsRegistry()
    c = m.counter("reqs_total", "requests", labelnames=("code",))
    g = m.gauge("depth", "queue depth")
    h = m.histogram("lat_seconds", "latency", buckets=(0.01, 0.1, 1.0))
    c.inc(code="200")
    c.inc(2, code="429")
    g.set(7)
    for v in (0.005, 0.05, 0.5, 5.0):
        h.observe(v)
    assert c.value(code="429") == 2
    with pytest.raises(ValueError, match="only go up"):
        c.inc(-1, code="200")
    with pytest.raises(ValueError, match="expected labels"):
        c.inc(status="200")
    text = m.render()
    assert 'reqs_total{code="429"} 2' in text
    assert "# TYPE lat_seconds histogram" in text
    # cumulative le-buckets + the +Inf terminal
    assert 'lat_seconds_bucket{le="0.01"} 1' in text
    assert 'lat_seconds_bucket{le="1"} 3' in text
    assert 'lat_seconds_bucket{le="+Inf"} 4' in text
    assert "lat_seconds_count 4" in text
    # snapshot mirrors render (one source of truth for /v1/stats)
    snap = m.snapshot()
    assert snap["reqs_total"] == {"200": 1, "429": 2}
    assert snap["lat_seconds"][""]["count"] == 4
    with pytest.raises(ValueError, match="already registered"):
        m.counter("reqs_total", "dup")


def test_telemetry_label_escaping():
    m = MetricsRegistry()
    c = m.counter("odd_total", "odd labels", labelnames=("path",))
    c.inc(path='a"b\\c\nd')
    assert r'odd_total{path="a\"b\\c\nd"} 1' in m.render()


# ------------------------------------------------------- wire parity (tentpole)
@pytest.mark.parametrize("num_workers", [1, 4])
def test_wire_parity_with_in_process_scoring(gateway_world, num_workers):
    """Acceptance: POST /v1/score over a real socket is bit-identical to
    in-process FraudService scoring on the same replay stream, including a
    mid-stream hot-swap to an identical-weights clone (version bump visible,
    score bits unchanged)."""
    events, cfg, params, sc = gateway_world
    sc = sc.replace(engine={"max_batch": 8, "num_workers": num_workers})

    # in-process reference: same submit loop, same mid-stream swap
    ref = FraudService(sc, params=params).build().warmup()
    half = len(events) // 2
    ref_out = []
    for ev in events[:half]:
        ref_out.extend(ref.submit(ev))
    clone = ref.register_perturbed(0, 0.0, version=1)
    ref.activate_model(clone)
    for ev in events[half:]:
        ref_out.extend(ref.submit(ev))
    ref_out.extend(ref.drain())
    ref_scores = {r.request.tag.order_id: (r.score, r.model_version)
                  for r in ref_out}

    svc = FraudService(sc, params=params).build().warmup()
    with FraudGateway(svc) as gw:
        cl = Client(gw.url)
        wire: dict[int, tuple] = {}

        def collect(body):
            for r in body["results"]:
                wire[r["order_id"]] = (r["score"], r["model_version"])

        for ev in events[:half]:
            status, _, body = cl.post("/v1/score", {"event": _ev_json(ev)})
            assert status == 200
            collect(body)
        status, _, body = cl.post(
            "/admin/model",
            {"role": "primary", "from_version": 0, "perturb_scale": 0.0,
             "version": 1})
        assert status == 200 and body["model_version"] == 1
        for ev in events[half:]:
            status, _, body = cl.post("/v1/score", {"event": _ev_json(ev)})
            assert status == 200
            collect(body)
        status, _, body = cl.post("/admin/drain", {})
        assert status == 200
        collect(body)

    assert set(wire) == set(ref_scores)
    for oid, (score, version) in ref_scores.items():
        w_score, w_version = wire[oid]
        # JSON floats use shortest-round-trip repr: bit-identical on the wire
        assert w_score == score, oid
        assert w_version == version, oid
    versions = {v for _, v in wire.values()}
    assert versions == {0, 1}   # both sides of the swap actually served


def test_batch_mode_over_the_wire(small_communities):
    from repro.serve import history_requests

    feat_dim = small_communities[0].graph.features.shape[1]
    cfg = LNNConfig(num_gnn_layers=2, hidden_dim=16, feat_dim=feat_dim)
    params = lnn_init(jax.random.PRNGKey(0), cfg)
    sc = ServiceConfig(mode="batch", model=ModelSection.from_lnn_config(cfg))

    ref = FraudService(sc, params=params).build()
    ref.refresh(small_communities)
    requests = history_requests(small_communities)[:12]
    ref_scores = [r.score for r in ref.score(requests)]

    svc = FraudService(sc, params=params, store=ref.store).build()
    with FraudGateway(svc) as gw:
        cl = Client(gw.url)
        req_json = [{"features": r.features.tolist(),
                     "entity_keys": [list(k) for k in r.entity_keys]}
                    for r in requests]
        # batch body
        status, _, body = cl.post("/v1/score", {"requests": req_json})
        assert status == 200 and body["scored"] == len(requests)
        assert [r["score"] for r in body["results"]] == ref_scores
        # single body
        status, _, body = cl.post("/v1/score", {"request": req_json[0]})
        assert status == 200 and body["results"][0]["score"] == ref_scores[0]


# -------------------------------------------------------- socket backpressure
def test_shed_admission_maps_to_429_with_retry_after(gateway_world):
    events, cfg, params, sc = gateway_world
    gw, cl = _boot(
        sc, params,
        engine={"max_batch": 64, "max_wait_s": 1e9},
        admission={"max_queue_depth": 1, "policy": "shed"},
        gateway={"retry_after_s": 0.25})
    with gw:
        status, _, body = cl.post("/v1/score", {"event": _ev_json(events[0])})
        assert status == 200      # first fills the queue, nothing shed
        for ev in events[1:3]:    # queue full now: shed -> 429
            status, headers, body = cl.post("/v1/score", {"event": _ev_json(ev)})
            assert status == 429
            assert headers["Retry-After"] == "0.250"
            shed = [r for r in body["results"] if not r["admitted"]]
            assert len(shed) == 1 and shed[0]["score"] is None
        st = gw.service.stats()
        assert st.shed == 2 and st.block_timeouts == 0


def test_block_timeout_maps_to_503(gateway_world):
    """A block-policy stall that exhausts admission.block_max_wait_s sheds
    the request and surfaces as 503 (service saturated), not 429."""
    events, cfg, params, sc = gateway_world
    gw, cl = _boot(
        sc, params,
        engine={"max_batch": 64, "max_wait_s": 1e9},
        admission={"max_queue_depth": 1, "policy": "block",
                   "block_max_wait_s": 0.0})
    with gw:
        status, _, _ = cl.post("/v1/score", {"event": _ev_json(events[0])})
        assert status == 200
        status, _, body = cl.post("/v1/score", {"event": _ev_json(events[1])})
        assert status == 503
        assert [r["admitted"] for r in body["results"]] == [False]
        st = gw.service.stats()
        assert st.block_timeouts == 1 and st.shed == 1


# ------------------------------------------------------------ canary / shadow
def test_perturbed_canary_trips_divergence_alert(gateway_world):
    """Acceptance: a deliberately perturbed canary version must raise the
    divergence alert, visible in /metrics and /v1/stats."""
    events, cfg, params, sc = gateway_world
    gw, cl = _boot(sc, params)
    with gw:
        status, _, body = cl.post(
            "/admin/model",
            {"role": "canary", "from_version": 0, "perturb_scale": 2.0,
             "version": 9, "fraction": 1.0, "threshold": 0.05})
        assert status == 200 and body["enabled"]
        for ev in events[:40]:
            cl.post("/v1/score", {"event": _ev_json(ev)})
        cl.post("/admin/drain", {})
        _, _, stats = cl.get("/v1/stats")
        sh = stats["service"]["shadow"]
        assert sh["version"] == 9 and sh["sampled"] > 0
        assert sh["alerts"] > 0 and sh["alert_active"] is True
        assert sh["divergence_max"] > 0.05
        _, text = cl.get_text("/metrics")
        lines = text.splitlines()
        assert "repro_shadow_alert_active 1" in lines
        assert f"repro_shadow_alerts_total {sh['alerts']}" in lines


def test_identical_weights_canary_never_alerts(gateway_world):
    """The shadow path replicates the speed layer's numerics (same pow2
    bucket padding, host f64 sigmoid): an identical-weights canary diverges
    by exactly 0.0 in streaming mode, so the alert stays quiet."""
    events, cfg, params, sc = gateway_world
    gw, cl = _boot(sc, params)
    with gw:
        status, _, body = cl.post(
            "/admin/model",
            {"role": "canary", "from_version": 0, "perturb_scale": 0.0,
             "version": 5, "fraction": 1.0, "threshold": 1e-12})
        assert status == 200
        for ev in events[:60]:
            cl.post("/v1/score", {"event": _ev_json(ev)})
        cl.post("/admin/drain", {})
        sh = gw.service.shadow_stats()
        assert sh["sampled"] > 0
        assert sh["divergence_max"] == 0.0 and sh["alerts"] == 0
        # canary off again: shadow block disappears from the snapshot
        status, _, body = cl.post("/admin/model", {"role": "canary"})
        assert status == 200 and body["enabled"] is False
        assert gw.service.shadow_stats() == {}


# --------------------------------------------- concurrent hot-swap under load
def test_concurrent_hot_swap_under_load(gateway_world):
    """Request threads hammer /v1/score while an admin thread flips the
    primary between two identical-weight versions with a fraction-1.0
    identical-weights canary on: every response must carry a registered
    model_version, shadow counters must never tear (divergence stays exactly
    0.0), and per-version score counts must sum to the scored total."""
    events, cfg, params, sc = gateway_world
    gw, cl = _boot(sc, params, engine={"max_batch": 4})
    with gw:
        cl.post("/admin/model",
                {"role": "primary", "from_version": 0, "perturb_scale": 0.0,
                 "version": 1})
        cl.post("/admin/model",
                {"role": "canary", "from_version": 0, "perturb_scale": 0.0,
                 "version": 5, "fraction": 1.0, "threshold": 1e-12})
        n_threads, per_thread = 4, 25
        seen_versions: set[int] = set()
        errors: list = []

        def pump(tid: int):
            # pin every event to snapshot 0: the graph rejects event-time
            # regressions, and four interleaved senders would otherwise race
            # snapshots backwards — this test is about counter integrity
            # under swap churn, not window semantics
            mine = Client(gw.url)
            for ev in events[tid * per_thread:(tid + 1) * per_thread]:
                status, _, body = mine.post(
                    "/v1/score", {"event": {**_ev_json(ev), "snapshot": 0}})
                if status != 200:
                    errors.append((tid, status, body))
                    return
                for r in body["results"]:
                    seen_versions.add(r["model_version"])

        def flip():
            admin = Client(gw.url)
            for i in range(10):
                status, _, body = admin.post(
                    "/admin/model", {"role": "primary", "version": i % 2})
                if status != 200:
                    errors.append(("admin", status, body))
                    return

        threads = [threading.Thread(target=pump, args=(t,))
                   for t in range(n_threads)]
        threads.append(threading.Thread(target=flip))
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
        assert not errors, errors
        status, _, body = cl.post("/admin/drain", {})
        assert status == 200
        for r in body["results"]:
            seen_versions.add(r["model_version"])

        # shadow scoring runs strictly AFTER response bytes are flushed, so
        # the drain response can return before its batch is shadow-observed:
        # wait for the off-path work to catch up before asserting totals
        deadline = time.monotonic() + 10.0
        while time.monotonic() < deadline:
            st = gw.service.stats()
            if st.shadow.get("sampled") == st.scored:
                break
            time.sleep(0.01)
        assert seen_versions <= {0, 1}
        assert st.requests == n_threads * per_thread
        assert st.scored == st.requests          # nothing lost under churn
        assert sum(st.scores_by_version.values()) == st.scored
        sh = st.shadow
        # identical weights on every version: divergence can never tear to
        # a nonzero value, and each sampled response was observed exactly once
        assert sh["sampled"] == st.scored
        assert sh["divergence_max"] == 0.0 and sh["alerts"] == 0


# ------------------------------------------------- lifecycle + plumbing + ops
def test_healthz_drain_lifecycle(gateway_world):
    events, cfg, params, sc = gateway_world
    gw, cl = _boot(sc, params)
    with gw:
        status, _, body = cl.get("/healthz")
        assert status == 200 and body["status"] == "ok"
        cl.post("/v1/score", {"event": _ev_json(events[0])})
        status, _, body = cl.post("/admin/drain", {})
        assert status == 200 and body["state"] == "drained"
        status, _, body = cl.get("/healthz")
        assert status == 503 and body["draining"] is True
        status, _, body = cl.post("/v1/score", {"event": _ev_json(events[1])})
        assert status == 503


def test_ingest_endpoint_feeds_batch_layer_only(gateway_world):
    events, cfg, params, sc = gateway_world
    gw, cl = _boot(sc, params)
    with gw:
        evs = [_ev_json(ev) for ev in events[:20]]
        status, _, body = cl.post("/v1/ingest", {"events": evs})
        assert status == 200 and body["ingested"] == 20
        st = gw.service.stats()
        # ingest grows the DDS/refresh pipeline but offers no score traffic
        assert st.requests == 0 and st.scored == 0
        assert st.refreshes >= 1 or gw.service.engine.ingester.dirty_count > 0


def test_stats_and_metrics_render_from_one_snapshot(gateway_world):
    events, cfg, params, sc = gateway_world
    gw, cl = _boot(sc, params)
    with gw:
        for ev in events[:30]:
            cl.post("/v1/score", {"event": _ev_json(ev)})
        cl.post("/admin/drain", {})
        _, _, stats = cl.get("/v1/stats")
        svc_stats = stats["service"]
        _, text = cl.get_text("/metrics")
        lines = text.splitlines()
        # every service scalar in /metrics equals the /v1/stats value
        assert f"repro_service_requests_total {svc_stats['requests']}" in lines
        assert f"repro_service_scored_total {svc_stats['scored']}" in lines
        assert f"repro_service_store_size {svc_stats['store_size']}" in lines
        for v, n in svc_stats["scores_by_version"].items():
            assert f'repro_service_scores_total{{model_version="{v}"}} {n}' in lines
        # gateway-side telemetry made it out too, with the served endpoints
        assert any(ln.startswith("gateway_http_requests_total{") for ln in lines)
        assert 'endpoint="/v1/score"' in text
        gw_block = stats["gateway"]["metrics"]
        score_http = sum(
            n for k, n in gw_block["gateway_http_requests_total"].items()
            if k.startswith("/v1/score"))
        assert score_http == 30
        # /v1/stats body re-types through ServiceStats.from_dict losslessly
        from repro.service import ServiceStats
        st = ServiceStats.from_dict(svc_stats)
        assert st.to_dict() == svc_stats


def test_http_error_paths(gateway_world):
    events, cfg, params, sc = gateway_world
    gw, cl = _boot(sc, params, gateway={"max_body_bytes": 2048})
    with gw:
        status, _, body = cl.get("/nope")
        assert status == 404
        status, _, body = cl.post("/v1/score", None, raw=b"{not json")
        assert status == 400 and "invalid JSON" in body["error"]
        status, _, body = cl.post("/v1/score", {"wrong": 1})
        assert status == 400 and "'event' or 'events'" in body["error"]
        status, _, body = cl.post("/v1/score", {"event": {"entities": []}})
        assert status == 400 and "features" in body["error"]
        big = {"event": {"features": [0.0] * 4096}}
        status, _, body = cl.post("/v1/score", big)
        assert status == 413
        status, _, body = cl.post("/admin/model", {"role": "shadowy"})
        assert status == 400
        status, _, body = cl.post("/admin/model",
                                  {"role": "primary", "version": 77})
        assert status == 400 and "not registered" in body["error"]
        # ingest needs streaming mode
        feat_dim = events[0].features.shape[0]
        bc = ServiceConfig(mode="batch",
                           model=ModelSection.from_lnn_config(cfg))
        bsvc = FraudService(bc, params=params).build()
        with FraudGateway(bsvc) as bgw:
            status, _, body = Client(bgw.url).post(
                "/v1/ingest", {"event": _ev_json(events[0])})
            assert status == 400 and "streaming" in body["error"]
        assert feat_dim == cfg.feat_dim


def test_serve_gateway_one_liner(gateway_world):
    events, cfg, params, sc = gateway_world
    gw = serve_gateway(sc, params, warmup=False)
    try:
        assert gw.port > 0
        cl = Client(gw.url)
        status, _, body = cl.post("/v1/score", {"event": _ev_json(events[0])})
        assert status == 200
        status, _, body = cl.get("/healthz")
        assert status == 200
    finally:
        gw.close()
        gw.close()   # idempotent
    with pytest.raises(RuntimeError, match="not started"):
        gw.port   # noqa: B018 — the property raise IS the assertion


def test_gateway_requires_built_service(gateway_world):
    _, _, params, sc = gateway_world
    svc = FraudService(sc, params=params)   # created, never built
    with pytest.raises(RuntimeError, match="built service"):
        FraudGateway(svc).start()


def test_score_response_nan_is_null_on_the_wire(gateway_world):
    """JSON has no NaN: shed responses carry score=None and the JSON body
    must parse with the strict stdlib parser (no Infinity/NaN literals)."""
    events, cfg, params, sc = gateway_world
    gw, cl = _boot(
        sc, params,
        engine={"max_batch": 64, "max_wait_s": 1e9},
        admission={"max_queue_depth": 1, "policy": "shed"})
    with gw:
        cl.post("/v1/score", {"event": _ev_json(events[0])})
        status, _, body = cl.post("/v1/score", {"event": _ev_json(events[1])})
        raw = json.dumps(body)
        parsed = json.loads(raw, parse_constant=lambda c: pytest.fail(
            f"non-strict JSON constant {c} on the wire"))
        assert parsed["results"][0]["score"] is None
        assert "NaN" not in raw and not any(
            isinstance(r["score"], float) and math.isnan(r["score"])
            for r in parsed["results"])
