"""jax version compat for Pallas TPU kernels.

jax < 0.5 names the Mosaic compiler-params struct ``TPUCompilerParams``;
newer releases renamed it ``CompilerParams``.  Single alias here so every
kernel stays importable on both.
"""
from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
