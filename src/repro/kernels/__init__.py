"""Pallas TPU kernels for the perf-critical compute layers.

Layout (one module per kernel + shared wrappers/oracles):
  <name>.py   pl.pallas_call + explicit BlockSpec VMEM tiling
  ops.py      jit'd public wrappers (interpret=True off-TPU)
  ref.py      pure-jnp oracles — the semantic ground truth for tests
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
