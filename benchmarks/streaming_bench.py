"""Streaming serving benchmark — throughput, latency tails, staleness curves.

Drives synthetic checkout streams through the full engine
(ingest -> async-able batch refresh -> micro-batched speed layer) and reports:

* **throughput** (closed loop): events/s with micro-batching (batch >= 8)
  vs per-request scoring (max_batch=1) — the amortization win of coalescing
  concurrent traffic into one fixed-shape jit call;
* **latency** (open loop): p50/p95/p99 of queue-wait + service under
  Poisson arrivals, for several offered loads;
* **staleness vs accuracy**: ROC-AUC of the streamed scores as the batch
  layer's refresh cadence stretches — the Lambda trade-off quantified.

Run:  PYTHONPATH=src python benchmarks/streaming_bench.py
JSON lands in experiments/BENCH_streaming.json (also wired into
benchmarks/run.py).
"""
from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def _fresh_engine(params, cfg, **kw):
    from repro.stream import EngineConfig, StreamingEngine

    return StreamingEngine(params, cfg, EngineConfig(**kw))


def run_streaming_bench(
    num_users: int = 250,
    num_rings: int = 6,
    batch_sizes=(1, 8, 16),
    loads_per_s=(100.0, 400.0),
    refresh_intervals=(1, 4, 10),
    train_epochs: int = 12,
    seed: int = 0,
) -> dict:
    import jax

    from repro.core import LNNConfig, lnn_init
    from repro.data import SynthConfig, build_communities, generate_event_stream
    from repro.train.metrics import roc_auc

    scfg = SynthConfig(num_users=num_users, num_rings=num_rings,
                       feature_noise=0.8, seed=seed)
    events, g, split = generate_event_stream(scfg, rate_per_s=400.0)
    cfg = LNNConfig(num_gnn_layers=3, hidden_dim=64,
                    feat_dim=g.order_features.shape[1], pos_weight=3.0)
    if train_epochs:
        # a briefly-trained model makes the staleness-vs-accuracy curve
        # meaningful (random embeddings carry no freshness signal)
        from repro.train.loop import train_lnn

        comm = build_communities(g, community_size=256, max_deg=24)
        params = train_lnn(comm, split, cfg, epochs=train_epochs,
                           patience=train_epochs, seed=seed).params
    else:
        params = lnn_init(jax.random.PRNGKey(seed), cfg)
    out: dict = {"n_events": len(events), "config": {
        "num_users": num_users, "num_rings": num_rings, "hidden_dim": cfg.hidden_dim,
    }}

    # ---- throughput: closed loop (arrivals never throttle the engine) ------
    # one ingest+refresh pass populates the store; scoring is then re-driven
    # back-to-back per batch size so only the speed-layer path is timed.
    eng = _fresh_engine(params, cfg, max_batch=max(batch_sizes), refresh_every=1)
    eng.replay(events)
    key_lists = [eng.ingester.builder.entity_keys(ev.entities, ev.snapshot)
                 for ev in events]
    feats = np.stack([ev.features for ev in events]).astype(np.float32)

    eng.warmup()          # compile every pow2 bucket once, off the clock
    thr = {}
    for bs in batch_sizes:
        t0 = time.perf_counter()
        for i in range(0, len(events), bs):
            chunk_f, chunk_k = feats[i:i + bs], key_lists[i:i + bs]
            n = len(chunk_k)
            if n < bs:   # tail: pad to the warmed bucket like the batcher does
                from repro.stream.microbatch import bucket_size

                b = bucket_size(n, bs)
                chunk_f = np.concatenate(
                    [chunk_f, np.zeros((b - n, feats.shape[1]), np.float32)]
                )
                chunk_k = chunk_k + [[] for _ in range(b - n)]
            eng._score_batch(chunk_f, chunk_k)
        dt = time.perf_counter() - t0
        thr[f"batch_{bs}"] = {
            "events_per_s": len(events) / dt,
            "us_per_event": dt / len(events) * 1e6,
        }
    out["throughput"] = thr
    base = thr["batch_1"]["events_per_s"]
    best_bs = max(b for b in batch_sizes if b >= 8) if any(
        b >= 8 for b in batch_sizes) else max(batch_sizes)
    out["microbatch_speedup"] = thr[f"batch_{best_bs}"]["events_per_s"] / base

    # ---- latency under Poisson load (open loop, full engine) ---------------
    lat = {}
    for rate in loads_per_s:
        evs, _, _ = generate_event_stream(scfg, rate_per_s=rate)
        e = _fresh_engine(params, cfg, max_batch=16, max_wait_s=0.005,
                          refresh_every=1)
        rep = e.replay(evs)
        s = rep.summary()
        lat[f"load_{int(rate)}eps"] = {
            **s["latency_ms"],
            "mean_ms": s["mean_latency_ms"],
            "mean_batch": s["mean_batch"],
            "size_flushes": s["size_flushes"],
            "deadline_flushes": s["deadline_flushes"],
        }
    out["latency"] = lat

    # ---- staleness vs accuracy ---------------------------------------------
    labels = np.asarray([ev.label for ev in events])
    curve = []
    for every in refresh_intervals:
        e = _fresh_engine(params, cfg, max_batch=16, refresh_every=every)
        rep = e.replay(events)
        scores_by_order = rep.scores_by_order()
        scores = np.asarray([scores_by_order[ev.order_id] for ev in events])
        point = {
            "refresh_every": every,
            "refreshes": e.refresher.stats["refreshes"],
            "staleness_mean": rep.staleness_summary()["mean"],
            "stale_frac": rep.staleness_summary()["stale_frac"],
            "kv_misses": e.store.stats["misses"],
        }
        if 0 < labels.sum() < labels.size:
            point["roc_auc"] = roc_auc(labels, scores)
        curve.append(point)
    out["staleness_curve"] = curve
    return out


def main() -> dict:
    r = run_streaming_bench()
    print("\n# Streaming serving engine")
    for bs, t in r["throughput"].items():
        print(f"  throughput/{bs}: {t['events_per_s']:.0f} events/s "
              f"({t['us_per_event']:.0f} us/event)")
    print(f"  micro-batch speedup (batch>=8 vs per-request): "
          f"{r['microbatch_speedup']:.1f}x")
    for load, l in r["latency"].items():
        print(f"  latency/{load}: p50={l['p50']:.2f}ms p95={l['p95']:.2f}ms "
              f"p99={l['p99']:.2f}ms (mean batch {l['mean_batch']:.1f})")
    for p in r["staleness_curve"]:
        auc = f" auc={p['roc_auc']:.4f}" if "roc_auc" in p else ""
        print(f"  staleness/refresh_every={p['refresh_every']}: "
              f"mean={p['staleness_mean']:.2f} snapshots, "
              f"stale_frac={p['stale_frac']:.2f}{auc}")
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/BENCH_streaming.json", "w") as f:
        json.dump(r, f, indent=1)
    return r


if __name__ == "__main__":
    main()
