"""The Lambda architecture (paper §3.3): batch layer + speed layer.

* :class:`BatchLayer` — periodically refreshes entity embeddings: runs LNN
  stage 1 over every community DDS graph (a pjit-able batch job) and writes
  the ``entity_{t-e}`` embeddings into the KV store.
* :class:`SpeedLayer` — online transaction-risk inference: per checkout
  request, fetch the linked entities' embeddings by key (ONE key-value
  lookup per entity — no graph traversal) and run the one-layer-GNN + MLP
  stage-2 scorer.
* :class:`LambdaPipeline` — wires both; ``score_equivalence_check`` proves
  the two-stage path reproduces the monolithic full-graph forward exactly
  (the paper's correctness argument for deploying the split).
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lnn import (
    LNNConfig,
    lnn_forward,
    lnn_stage1,
    lnn_stage2_online,
)
from repro.serve.kvstore import KVStore, pack_key


@dataclass
class BatchLayer:
    """Periodic batch-layer refresh: ``refresh(batches)`` runs jitted LNN
    stage 1 over each community's padded graph and writes every
    ``(entity, t)`` snapshot embedding into ``store`` under its packed key.

    ``batches`` are community batches (``b.graph`` PaddedGraph + ``b.dds``
    build record) as produced by ``repro.data.build_communities``.
    """

    params: object
    cfg: LNNConfig
    store: KVStore

    def __post_init__(self):
        self._stage1 = jax.jit(lambda p, g: lnn_stage1(p, self.cfg, g))

    def refresh(self, batches) -> dict:
        """Run stage 1 over all communities, push entity embeddings to the KV
        store.  Returns refresh stats (the paper's 'periodical inference')."""
        t0 = time.time()
        n_written = 0
        for b in batches:
            h = np.asarray(self._stage1(self.params, b.graph))
            # write every entity-snapshot vertex: key = (global entity, t)
            for (ent, t), nid in b.dds.entity_snap_ids.items():
                self.store.put(pack_key(self._global_entity(b, ent), t), h[nid])
                n_written += 1
        return {"entities_written": n_written, "seconds": time.time() - t0,
                "store_size": len(self.store)}

    @staticmethod
    def _global_entity(b, local_ent: int) -> int:
        # communities keep a local->global entity map when built from a
        # partition; fall back to local ids for single-community graphs
        m = getattr(b, "global_entity_ids", None)
        return int(m[local_ent]) if m is not None else int(local_ent)


@dataclass
class SpeedLayer:
    """Online transaction-risk scorer: ``score(requests)`` maps a list of
    ``{'features': [F], 'entity_keys': [(entity, t_e), ...]}`` dicts to
    fraud probabilities via at most ``k_max`` KV lookups per request plus a
    single stage-2 dispatch.

    The whole online compute (order tower + masked aggregation + last GNN
    layer + MLP head) is one jitted call of ``lnn_stage2_online``; with
    ``cfg.use_pallas`` that call is the fused ``kernels.stage2_score``
    Pallas launch.
    """

    params: object
    cfg: LNNConfig
    store: KVStore
    k_max: int = 8

    def __post_init__(self):
        self._stage2 = jax.jit(
            lambda p, emb, mask, feats: lnn_stage2_online(
                p, self.cfg, emb, mask, feats
            )
        )

    def score(self, requests: list) -> np.ndarray:
        """requests: [{'features': [F], 'entity_keys': [(ent, t_e), ...]}].

        Returns fraud probabilities.  This is the checkout-approval hot path:
        K key-value lookups + one fused jit call; no graph database."""
        feats = jnp.asarray(np.stack([r["features"] for r in requests]))
        key_lists = [
            [pack_key(e, t) for (e, t) in r["entity_keys"]] for r in requests
        ]
        emb, mask = self.store.lookup_batch(key_lists, self.k_max)
        logits = self._stage2(self.params, jnp.asarray(emb), jnp.asarray(mask),
                              feats)
        return np.asarray(jax.nn.sigmoid(logits))


@dataclass
class LambdaPipeline:
    """Both Lambda halves wired over one shared ``KVStore``: ``refresh``
    delegates to the :class:`BatchLayer`, ``score`` to the
    :class:`SpeedLayer`, and ``score_equivalence_check`` replays every
    order with history through the real store to bound the two-stage vs
    monolithic score gap.
    """

    params: object
    cfg: LNNConfig
    k_max: int = 8
    store: KVStore = None

    def __post_init__(self):
        if self.store is None:
            self.store = KVStore(self.cfg.hidden_dim)
        self.batch_layer = BatchLayer(self.params, self.cfg, self.store)
        self.speed_layer = SpeedLayer(self.params, self.cfg, self.store, self.k_max)

    def refresh(self, batches):
        return self.batch_layer.refresh(batches)

    def score(self, requests):
        return self.speed_layer.score(requests)

    # ------------------------------------------------------------------ checks
    def score_equivalence_check(self, batches, atol=1e-4) -> float:
        """Max |two-stage online score - monolithic forward score| over all
        orders with history.  Proves the lambda split exact end-to-end
        (through the real KV store, not in-memory shortcuts)."""
        fwd = jax.jit(lambda p, g: lnn_forward(p, self.cfg, g))
        worst = 0.0
        for b in batches:
            full = np.asarray(jax.nn.sigmoid(fwd(self.params, b.graph)))
            requests, rows = [], []
            for o, hops in b.dds.last_hop.items():
                keys = [(BatchLayer._global_entity(b, ent), t) for ent, t, _ in hops]
                requests.append({
                    "features": np.asarray(b.graph.features[o]),
                    "entity_keys": keys,
                })
                rows.append(o)
            if not requests:
                continue
            online = self.score(requests)
            worst = max(worst, float(np.abs(online - full[rows]).max()))
        if worst > atol:
            raise AssertionError(f"lambda split mismatch: {worst} > {atol}")
        return worst
