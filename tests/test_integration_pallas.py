"""Integration: models executed THROUGH the Pallas kernels (interpret mode)
must match their XLA reference paths — covers the kernels in situ, not just
in isolation."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import LNNConfig, lnn_forward, lnn_init
from repro.models import forward, init_params

RNG = np.random.default_rng(11)


@pytest.mark.parametrize("gnn_type", ["gcn", "gat", "sage"])
def test_lnn_pallas_path_matches_xla(gnn_type, small_communities):
    """GNN layers routed through csr_spmm / edge_softmax Pallas kernels."""
    feat_dim = small_communities[0].graph.features.shape[1]
    cfg_x = LNNConfig(gnn_type=gnn_type, num_gnn_layers=3, hidden_dim=32,
                      feat_dim=feat_dim, use_pallas=False)
    cfg_p = dataclasses.replace(cfg_x, use_pallas=True)
    params = lnn_init(jax.random.PRNGKey(0), cfg_x)
    g = small_communities[0].graph
    out_x = np.asarray(lnn_forward(params, cfg_x, g))
    out_p = np.asarray(lnn_forward(params, cfg_p, g))
    np.testing.assert_allclose(out_p, out_x, atol=2e-4, rtol=2e-4)


def test_mamba_pallas_path_matches_xla():
    """Mamba2 block routed through the ssd_scan Pallas kernel (S % 128 == 0)."""
    cfg = get_config("mamba2-370m").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    tokens = jnp.asarray(RNG.integers(0, cfg.vocab_size, (1, 128)), jnp.int32)
    out_x, _, _ = forward(params, cfg, tokens, use_remat=False, use_pallas=False)
    out_p, _, _ = forward(params, cfg, tokens, use_remat=False, use_pallas=True)
    scale = float(jnp.abs(out_x).max())
    np.testing.assert_allclose(np.asarray(out_p) / scale, np.asarray(out_x) / scale,
                               atol=5e-4)


def test_zamba_pallas_path_matches_xla():
    cfg = get_config("zamba2-1.2b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    tokens = jnp.asarray(RNG.integers(0, cfg.vocab_size, (1, 128)), jnp.int32)
    out_x, _, _ = forward(params, cfg, tokens, use_remat=False, use_pallas=False)
    out_p, _, _ = forward(params, cfg, tokens, use_remat=False, use_pallas=True)
    scale = float(jnp.abs(out_x).max())
    np.testing.assert_allclose(np.asarray(out_p) / scale, np.asarray(out_x) / scale,
                               atol=5e-4)
