"""Dry-run demo: lower one (arch x shape) pair on the 256-chip production
mesh and print its roofline terms — without any TPU attached.

Run:  PYTHONPATH=src python examples/dryrun_demo.py [--arch granite-3-2b]
      (takes ~1 min: three XLA compiles on the 512-placeholder-device CPU)
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    args = ap.parse_args()

    # dryrun module sets XLA_FLAGS before importing jax — import it first
    from repro.launch import dryrun

    rec = dryrun.run_one(args.arch, args.shape, args.mesh, save=False)
    if rec.get("status") != "ok":
        print(rec)
        return
    print("\n== roofline summary ==")
    print(f"  arch x shape:   {rec['arch']} x {rec['shape']} ({rec['chips']} chips)")
    print(f"  compute term:   {rec['t_compute']*1e3:8.2f} ms")
    print(f"  memory term:    {rec['t_memory']*1e3:8.2f} ms")
    print(f"  collective:     {rec['t_collective']*1e3:8.2f} ms")
    print(f"  bottleneck:     {rec['bottleneck']}")
    print(f"  useful compute: {rec['useful_ratio']:.2f} of HLO FLOPs")
    print(f"  collectives:    {rec['coll_detail']['counts']}")


if __name__ == "__main__":
    main()
