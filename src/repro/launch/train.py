"""Training launcher.

Two modes:
  * ``--paper``: train the paper's LNN fraud model on the synthetic
    transaction graph (the end-to-end driver — a few hundred community
    steps on CPU).
  * ``--arch <id>``: train a reduced transformer-zoo config with the same
    sharded train_step used by the dry-run, on a 1x1 host mesh (CPU) or the
    production mesh (TPU).

Checkpoints land under ``checkpoints/``.
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np


def train_paper(args):
    import jax

    from repro.baselines import GBDTConfig, train_gbdt
    from repro.core import LNNConfig
    from repro.data import (SynthConfig, build_communities,
                            generate_transactions, make_split_masks)
    from repro.data.pipeline import standardize_features
    from repro.train.checkpoint import save_checkpoint
    from repro.train.loop import evaluate_lnn, train_lnn

    scfg = SynthConfig(num_users=args.users, num_rings=args.rings,
                       feature_noise=0.8, seed=args.seed)
    g, _ = generate_transactions(scfg)
    split = make_split_masks(g.order_snapshot)
    feats, _ = standardize_features(g.order_features, split == 0)

    gbdt = train_gbdt(feats[split == 0], g.labels[split == 0], GBDTConfig(),
                      feats[split == 1], g.labels[split == 1])
    enc = np.concatenate([feats, gbdt.leaf_value_features(feats)], 1)
    mu, sd = enc[split == 0].mean(0), enc[split == 0].std(0) + 1e-6
    g.order_features = ((enc - mu) / sd).astype(np.float32)

    batches = build_communities(g, community_size=256, max_deg=24, seed=args.seed)
    cfg = LNNConfig(gnn_type=args.gnn, num_gnn_layers=3, hidden_dim=64,
                    feat_dim=g.order_features.shape[1], pos_weight=3.0)
    print(f"training LNN({args.gnn}) on {len(batches)} communities "
          f"({g.num_orders} orders, fraud rate {g.labels.mean():.3f})")
    res = train_lnn(batches, split, cfg, epochs=args.epochs, verbose=True,
                    seed=args.seed)
    metrics = evaluate_lnn(res.params, cfg, batches, split, 2)
    print(f"test: {metrics}")
    os.makedirs("checkpoints", exist_ok=True)
    save_checkpoint(f"checkpoints/lnn_{args.gnn}.npz", res.params, step=res.best_epoch)
    print(f"checkpoint saved to checkpoints/lnn_{args.gnn}.npz")
    return metrics


def train_arch(args):
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.launch.mesh import make_host_mesh
    from repro.launch.steps import make_train_step
    from repro.models import init_params
    from repro.models.config import InputShape
    from repro.train.checkpoint import save_checkpoint
    from repro.train.optim import adamw

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_host_mesh()
    shape = InputShape("cli_train", args.seq, args.batch, "train")
    fn, _ = make_train_step(cfg, mesh, shape, use_remat=False, lr=args.lr)
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    init_fn, _ = adamw(args.lr)
    opt = init_fn(params)

    rng = np.random.default_rng(args.seed)

    def sample_batch():
        toks = rng.integers(0, cfg.vocab_size, (args.batch, args.seq + 1))
        batch = {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
                 "labels": jnp.asarray(toks[:, 1:], jnp.int32)}
        if cfg.arch_type == "vlm":
            batch["vision"] = jnp.asarray(
                rng.normal(size=(args.batch, cfg.num_vision_tokens, cfg.d_model)),
                jnp.float32)
        if cfg.arch_type == "audio":
            batch["frames"] = jnp.asarray(
                rng.normal(size=(args.batch, min(args.seq, 64), cfg.d_model)),
                jnp.float32)
        return batch

    with mesh:
        for step in range(args.steps):
            t0 = time.time()
            params, opt, aux = fn(params, opt, sample_batch())
            if step % max(args.steps // 20, 1) == 0:
                print(f"step {step}: loss={float(aux['loss']):.4f} "
                      f"gnorm={float(aux['grad_norm']):.3f} "
                      f"{time.time()-t0:.2f}s")
    os.makedirs("checkpoints", exist_ok=True)
    save_checkpoint(f"checkpoints/{args.arch.replace('.', '_')}.npz", params,
                    step=args.steps)
    print(f"final loss {float(aux['loss']):.4f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper", action="store_true", help="train the LNN fraud model")
    ap.add_argument("--gnn", default="gcn", choices=["gcn", "gat", "sage"])
    ap.add_argument("--arch", default=None)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--epochs", type=int, default=40)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--users", type=int, default=600)
    ap.add_argument("--rings", type=int, default=6)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.paper or not args.arch:
        train_paper(args)
    else:
        train_arch(args)


if __name__ == "__main__":
    main()
