"""Streaming serving demo: the Lambda loop closed end-to-end behind the one
typed serving API (``repro.service``).

Replays a synthetic checkout stream through a ``FraudService`` in
``mode="streaming"``, built from a single ``ServiceConfig`` artifact:

  1. INGEST       — each event extends the DDS graph incrementally
                    (no-future-leak invariant held at every prefix);
  2. BATCH LAYER  — the refresh driver re-runs LNN stage 1 when snapshot
                    windows close, pushing versioned, model-stamped entity
                    embeddings into the sharded KV store;
  3. SPEED LAYER  — concurrent checkouts coalesce into fixed-shape
                    micro-batches and score through one jitted stage-2 call;
  4. proves the streamed micro-batched scores equal the monolithic
     ``lnn_forward``, shows the staleness trade-off, the 4-worker sharded
     speed layer (bit-identical scores), a live **model hot-swap**
     mid-stream, and **admission control** under overload.

Run:  PYTHONPATH=src python examples/streaming_serving.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.core import LNNConfig, lnn_forward
from repro.core.graph import pad_graph
from repro.data import SynthConfig, build_communities, generate_event_stream
from repro.service import FraudService, ModelSection, ServiceConfig
from repro.train.loop import train_lnn


def main():
    events, g, split = generate_event_stream(
        SynthConfig(num_users=300, num_rings=5, feature_noise=0.8, seed=1),
        rate_per_s=300.0,
    )
    cfg = LNNConfig(num_gnn_layers=3, hidden_dim=64,
                    feat_dim=g.order_features.shape[1], pos_weight=3.0)

    print("== training a small LNN (offline, on the historical graph) ==")
    comm = build_communities(g, community_size=256, max_deg=24)
    res = train_lnn(comm, split, cfg, epochs=15, patience=5)

    # the whole engine in one serializable artifact
    config = ServiceConfig(
        mode="streaming", model=ModelSection.from_lnn_config(cfg),
    ).replace(engine={"max_batch": 16, "max_wait_s": 0.005},
              store={"num_shards": 4}, refresh={"refresh_every": 1})

    print(f"\n== replaying {len(events)} checkout events through the service ==")
    svc = FraudService(config, params=res.params).build()
    report = svc.replay(events)
    s = report.summary()
    print(f"   scored {s['scored']} checkouts in {s['flushes']} micro-batches "
          f"(mean batch {s['mean_batch']:.1f}; "
          f"{s['size_flushes']} size / {s['deadline_flushes']} deadline flushes)")
    print(f"   latency p50={s['latency_ms']['p50']:.2f}ms "
          f"p95={s['latency_ms']['p95']:.2f}ms p99={s['latency_ms']['p99']:.2f}ms "
          f"(mean service {s['mean_service_ms']:.2f}ms)")
    print(f"   batch layer: {s['refreshes']} refreshes wrote "
          f"{s['entities_written']} versioned embeddings -> "
          f"store size {s['store_size']}")
    risky = sum(1 for r in report.results if r.score > 0.5)
    print(f"   {risky} checkouts flagged risky")

    print("\n== correctness: streamed scores == monolithic forward ==")
    pg = pad_graph(svc.engine.ingester.materialize().coo, max_deg=32)
    full = np.asarray(jax.nn.sigmoid(
        jax.jit(lambda p, gg: lnn_forward(p, cfg, gg))(res.params, pg)))
    scores = report.scores_by_order()
    err = max(abs(scores[ev.order_id] - full[i]) for i, ev in enumerate(events))
    print(f"   max |streamed - monolithic| = {err:.2e}")

    print("\n== staleness: refreshing every 6 windows instead of every 1 ==")
    lazy = FraudService(config.replace(refresh={"refresh_every": 6}),
                        params=res.params).build()
    lazy_rep = lazy.replay(events)
    st = lazy_rep.staleness_summary()
    print(f"   {lazy.engine.refresher.stats['refreshes']} refreshes "
          f"(vs {s['refreshes']}); stale lookups: {st['stale_frac']:.0%}, "
          f"mean staleness {st['mean']:.2f} snapshots, max {st['max']}")
    print(f"   KV fallback stats: {lazy.store.stats['stale_hits']} stale hits, "
          f"{lazy.store.stats['misses']} cold misses")

    print("\n== multi-worker speed layer: 4 key-affine workers ==")
    mw = FraudService(
        config.replace(engine={"max_batch": 16, "num_workers": 4,
                               "service_model_s": 0.004,
                               "steal_threshold": 24}),
        params=res.params).build()
    mw_rep = mw.replay(events)
    ms = mw_rep.summary()
    mw_scores = mw_rep.scores_by_order()
    per_worker = [w["requests"] for w in ms["workers"]]
    print(f"   requests per worker: {per_worker} "
          f"({ms['steals']} steals, {ms['stolen_requests']} requests stolen)")
    print(f"   latency p50={ms['latency_ms']['p50']:.2f}ms "
          f"p99={ms['latency_ms']['p99']:.2f}ms under a 4ms virtual "
          f"service cost per flush")
    bit_identical = all(mw_scores[o] == scores[o] for o in scores)
    print(f"   scores bit-identical to the single-worker engine: "
          f"{bit_identical}")

    print("\n== versioned model hot-swap, mid-stream ==")
    swap = FraudService(config, params=res.params).build().warmup()
    out = []
    half = len(events) // 2
    for ev in events[:half]:
        out.extend(swap.submit(ev))
    v = swap.load_model(jax.tree_util.tree_map(np.asarray, res.params))
    for ev in events[half:]:
        out.extend(swap.submit(ev))
    out.extend(swap.drain())
    swapped = sum(1 for r in out if r.model_version == v)
    same = all(r.score == scores[r.request.tag.order_id] for r in out)
    print(f"   activated v{v} after {half} events: {swapped} checkouts scored "
          f"on the new version, {len(out) - swapped} finished on v0")
    print(f"   identical-weights swap left every score bit-identical: {same}")
    print(f"   model-stale KV reads detected: "
          f"{swap.store.stats['model_stale_reads']}")

    print("\n== admission control: shed vs block under overload ==")
    overload = config.replace(engine={"max_batch": 16, "num_workers": 2,
                                      "service_model_s": 0.05})
    for policy in ("shed", "block"):
        adm = FraudService(
            overload.replace(admission={"max_queue_depth": 8,
                                        "policy": policy}),
            params=res.params).build()
        adm.replay(events)
        a = adm.stats()
        print(f"   policy={policy}: {a.scored} scored, {a.shed} shed, "
              f"{a.blocked} blocked (peak depth {a.queue_depth_peak})")


if __name__ == "__main__":
    main()
