"""Micro-batching scheduler — coalesces concurrent score requests.

The speed layer's stage-2 call is a tiny jitted kernel; dispatch overhead
dominates per-request scoring.  The scheduler queues requests and flushes
them as one fixed-shape batch when either trigger fires:

* **size** — the queue reaches ``max_batch``;
* **deadline** — the oldest queued request has waited ``max_wait_s``
  (virtual seconds), bounding tail latency under light traffic.

Flushed batches are right-padded up to the next power-of-two bucket
(2, 4, ..., max_batch) so the jit cache holds O(log max_batch) shapes
forever — no recompiles under arbitrary traffic, the classic serving-engine
shape-bucketing trick.  Padding rows carry zero features and empty key
lists; their scores are sliced off before results are returned, so batched
scores are bit-identical to unbatched ones (tested).

The queue is guarded by a lock: the multi-worker pool's work stealing
(:meth:`MicroBatcher.take`) and the async refresh thread may drain or grow
the queue between a flush trigger firing and the flush popping the batch.
A flush that loses that race simply emits nothing — it never scores an
empty batch and never inflates the flush counters (regression-tested).
"""
from __future__ import annotations

import threading
import time

import numpy as np

# the canonical typed request/response — repro.service.types is a numpy-only
# leaf module, so this import introduces no package cycle.  ScoredResult is
# the historical streaming name for the service-wide ScoreResponse.
from repro.service.types import ScoreRequest, ScoreResponse
from repro.utils import crashpoint

ScoredResult = ScoreResponse


def bucket_size(n: int, max_batch: int) -> int:
    """Smallest power-of-two >= n, floored at 2, capped at max_batch.

    The floor of 2 is a determinism guarantee, not a perf knob: XLA CPU
    lowers a batch-1 matmul through a gemv path whose reduction order
    differs bitwise from the gemm used at batch >= 2, so singleton flushes
    are padded to bucket 2 — every request's score is then bit-identical
    no matter which flush composition it rode in.  That invariance is what
    makes N-worker replay scores equal single-worker scores exactly
    (``tests/test_stream.py`` replay-parity)."""
    b = 2
    while b < n and b < max_batch:
        b *= 2
    return min(b, max_batch)


class DeferredScore:
    """A score_fn result still in flight (process-backed scorers).

    An inline ``score_fn`` returns ``(probs, staleness[, model_version])``
    synchronously; a process-backed scorer posts the padded batch to its
    owner process and returns one of these instead.  ``wait()`` blocks
    until the reply frame lands and returns the same tuple the inline call
    would have.  :meth:`MicroBatcher.flush` turns a deferred result into a
    :class:`PendingFlush`, which the pool resolves before any result is
    released — delivery order and accounting stay inline-identical while
    several posted flushes overlap in flight across worker processes.
    """

    def __init__(self, wait):
        self._wait = wait

    def wait(self):
        return self._wait()


class PendingFlush:
    """A flush whose scores are still crossing a process boundary.

    Carries everything :meth:`MicroBatcher.flush` had already decided —
    the popped batch, its real row count, the trigger stamp — so
    ``resolve()`` can finish result construction exactly as the inline
    path would have.  Truthiness mirrors a non-empty result list, so the
    worker's per-kind flush accounting is unchanged.
    """

    def __init__(self, batcher, batch, n, now, deferred, t0):
        self.batcher = batcher
        self.batch = batch
        self.n = n
        self.now = now
        self.deferred = deferred
        self.worker = None          # stamped by the worker that flushed
        self._t0 = t0

    def __bool__(self) -> bool:
        return True

    def resolve(self) -> list:
        """Block on the reply and build the ScoredResults (parent side)."""
        probs, staleness, model_version = self.deferred.wait()
        service = time.perf_counter() - self._t0
        out = self.batcher._results(self.batch, self.n, self.now, probs,
                                    staleness, int(model_version), service)
        if self.worker is not None:
            for r in out:
                r.worker = self.worker
        return out


class MicroBatcher:
    """Queue + flush policy for speed-layer micro-batches.

    ``score_fn(features [B, F], key_lists) -> (probs [B], staleness [B])``
    is supplied by the engine; the batcher owns only queueing policy:
    ``submit(request, now)`` enqueues and size-flushes at ``max_batch``,
    ``poll(now)`` deadline-flushes once the oldest request has waited
    ``max_wait_s``, and ``flush(now)`` drains unconditionally.  Flushes are
    right-padded to the next power-of-two bucket (``bucket_size``) so the
    jit cache holds O(log max_batch) shapes.  ``enqueue``/``take`` are the
    policy-free primitives the multi-worker pool composes: enqueue without
    flushing, and atomically steal the oldest queued requests.
    """

    def __init__(self, score_fn, max_batch: int = 16, max_wait_s: float = 0.005,
                 clock=time.monotonic):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.score_fn = score_fn
        self.max_batch = int(max_batch)
        self.max_wait_s = float(max_wait_s)
        # deadline scheduling clock, used whenever a caller does not supply
        # ``now``.  Monotonic by default: a wall-clock (``time.time``) here
        # would let an NTP step fire every deadline at once (clock jumps
        # forward) or starve deadline flushes entirely (clock jumps back).
        # Injectable so tests and the replay harness drive virtual time.
        self.clock = clock
        self._queue: list[ScoreRequest] = []
        self._lock = threading.Lock()
        self.stats = {"flushes": 0, "size_flushes": 0, "deadline_flushes": 0,
                      "forced_flushes": 0, "requests": 0, "padded_rows": 0,
                      "empty_flushes": 0, "stolen": 0}

    def __len__(self) -> int:
        with self._lock:
            return len(self._queue)

    @property
    def oldest_arrival(self) -> float | None:
        with self._lock:
            return self._queue[0].arrival if self._queue else None

    def deadline(self) -> float | None:
        """Virtual time at which the current queue must flush."""
        with self._lock:
            return None if not self._queue \
                else self._queue[0].arrival + self.max_wait_s

    def nth_arrival(self, i: int) -> float | None:
        """Arrival time of the i-th oldest queued request (trigger stamps)."""
        with self._lock:
            return self._queue[i].arrival if i < len(self._queue) else None

    # ------------------------------------------------------------------ queue
    def enqueue(self, request: ScoreRequest) -> None:
        """Append without any flush decision (pool-managed workers)."""
        with self._lock:
            self._queue.append(request)
            self.stats["requests"] += 1

    def take(self, n: int) -> list[ScoreRequest]:
        """Atomically pop up to ``n`` oldest queued requests (work stealing —
        the thief re-enqueues them on another worker)."""
        if n <= 0:
            return []
        with self._lock:
            taken, self._queue = self._queue[:n], self._queue[n:]
            self.stats["stolen"] += len(taken)
        return taken

    def submit(self, request: ScoreRequest,
               now: float | None = None) -> list[ScoredResult]:
        """Enqueue; flush immediately if the size trigger fires.

        When ``now`` is omitted (internal-clock mode) an unstamped request
        (``arrival == 0.0``, the dataclass default) is stamped from the
        same clock — deadline math must never mix clock bases, or a
        wall-clock arrival against a monotonic ``now`` would starve (or
        instantly fire) every deadline flush."""
        if now is None:
            now = self.clock()
            if request.arrival == 0.0:
                request.arrival = now
        self.enqueue(request)
        with self._lock:
            full = len(self._queue) >= self.max_batch
        if not full:
            return []
        out = self.flush(now)
        if out:
            self.stats["size_flushes"] += 1
        return out

    def poll(self, now: float | None = None) -> list[ScoredResult]:
        """Deadline trigger: flush if the oldest request exceeded max_wait.

        The flush is timestamped *at the deadline* (a real engine's timer
        fires then), not at ``now`` — otherwise a request's recorded queue
        wait would stretch to the next arrival under light traffic."""
        if now is None:
            now = self.clock()
        dl = self.deadline()
        if dl is None or now < dl:
            return []
        out = self.flush(dl)
        if out:
            self.stats["deadline_flushes"] += 1
        return out

    # ------------------------------------------------------------------ flush
    def flush(self, now: float | None = None):
        """Score everything queued as one padded fixed-shape batch.

        Returns the ``ScoredResult`` list, or a :class:`PendingFlush` when
        the scorer answered with a :class:`DeferredScore` (process backend
        — the pool resolves it before releasing results).

        The pop is atomic and re-checks emptiness: a concurrent drain (work
        steal, another flush) between the trigger firing and this pop must
        yield an empty no-op, never a zero-row ``score_fn`` call."""
        if now is None:
            now = self.clock()
        with self._lock:
            if not self._queue:
                self.stats["empty_flushes"] += 1
                return []
            batch, self._queue = (self._queue[: self.max_batch],
                                  self._queue[self.max_batch:])
        n = len(batch)
        b = bucket_size(n, self.max_batch)
        feat_dim = batch[0].features.shape[0]
        feats = np.zeros((b, feat_dim), np.float32)
        key_lists: list[list] = [[] for _ in range(b)]
        for i, r in enumerate(batch):
            feats[i] = r.features
            key_lists[i] = list(r.entity_keys)
        self.stats["padded_rows"] += b - n

        crashpoint.fire("flush.before_score")
        t0 = time.perf_counter()
        # scorers may return (probs, staleness) or, when version-aware,
        # (probs, staleness, model_version) — the version whose jit cache
        # served this flush (hot-swap observability) — or a DeferredScore
        # when the batch was posted to a worker process
        out = self.score_fn(feats, key_lists)
        if isinstance(out, DeferredScore):
            return PendingFlush(self, batch, n, now, out, t0)
        service = time.perf_counter() - t0
        probs, staleness = out[0], out[1]
        model_version = int(out[2]) if len(out) > 2 else 0
        return self._results(batch, n, now, probs, staleness, model_version,
                             service)

    def _results(self, batch, n, now, probs, staleness, model_version,
                 service) -> list[ScoredResult]:
        """Post-score half of a flush — shared by the synchronous path and
        :meth:`PendingFlush.resolve` so accounting and result construction
        cannot drift between backends."""
        crashpoint.fire("flush.after_score")
        self.stats["flushes"] += 1
        return [
            ScoredResult(
                request=r,
                score=float(probs[i]),
                staleness=int(staleness[i]),
                queued_s=max(0.0, now - r.arrival),
                service_s=service,
                batch_size=n,
                model_version=model_version,
            )
            for i, r in enumerate(batch)
        ]
