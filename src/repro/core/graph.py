"""Device-friendly graph containers.

TPU/XLA require static shapes, so graphs are stored as *padded in-neighbor
lists* rather than dynamic CSR: for every node a fixed-width row of neighbor
indices plus a mask.  This is the layout consumed by the GNN layers and by
the ``csr_spmm`` / ``edge_softmax`` Pallas kernels.

Node/edge-type vocabularies for the DDS graph live here so every module
agrees on the integer codes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import numpy as np

# ---------------------------------------------------------------------------
# DDS vocabularies (paper Table 2)
# ---------------------------------------------------------------------------

class NodeType:
    ORDER = 0        # effective order_t (carries the label)
    SHADOW = 1       # shadow clone order_t^s (no label, feeds entities)
    ENTITY = 2       # entity_t snapshot vertex
    PAD = 3


class EdgeType:
    SHADOW_TO_ENTITY = 0   # order_t^s -> entity_t   (same snapshot)
    ENTITY_TO_SHADOW = 1   # entity_t -> order_t^s   (same snapshot)
    ENTITY_HIST = 2        # entity_{t-i} -> entity_t (incl. self loop i=0)
    ENTITY_TO_ORDER = 3    # entity_{t-e} -> order_t (the final 1-hop edges)
    NUM = 4


# ---------------------------------------------------------------------------
# Padded graph (pytree) consumed by GNN layers
# ---------------------------------------------------------------------------

class PaddedGraph(NamedTuple):
    """Fixed-shape graph for one community (or a batch of merged communities).

    All arrays are padded to ``num_nodes`` rows and ``max_deg`` neighbor
    columns.  ``nbr_idx`` points at *source* nodes of incoming edges; padded
    slots point at row 0 with ``nbr_mask == 0``.
    """

    features: jax.Array      # [N, F] float — raw features (zeros for entities)
    nbr_idx: jax.Array       # [N, D] int32 — in-neighbor node index
    nbr_mask: jax.Array      # [N, D] float32 — 1 for real edges
    nbr_etype: jax.Array     # [N, D] int32 — EdgeType codes (0 where padded)
    node_type: jax.Array     # [N] int32 — NodeType codes (PAD for padding)
    snapshot: jax.Array      # [N] int32 — snapshot index t (-1 for padding)
    label: jax.Array         # [N] float32 — fraud label (orders only)
    label_mask: jax.Array    # [N] float32 — 1 where label is valid
    # [N] int32 entity-type tower codes (-1 = untyped/non-entity), or None
    # on a homogeneous graph — the trailing default keeps untyped pytrees
    # (and their jit caches) byte-identical to the pre-hetero layout
    tower: jax.Array | None = None

    @property
    def num_nodes(self) -> int:
        return self.features.shape[0]

    @property
    def max_deg(self) -> int:
        return self.nbr_idx.shape[1]


@dataclass
class COOGraph:
    """Host-side (numpy) directed graph in COO form, before padding."""

    num_nodes: int
    src: np.ndarray          # [E] int64
    dst: np.ndarray          # [E] int64
    etype: np.ndarray        # [E] int32
    features: np.ndarray     # [N, F]
    node_type: np.ndarray    # [N]
    snapshot: np.ndarray     # [N]
    label: np.ndarray        # [N]
    label_mask: np.ndarray   # [N]
    # [N] entity-type tower codes (-1 = untyped/non-entity); None on
    # homogeneous graphs (see repro.core.hetero)
    tower: np.ndarray | None = None

    def in_degrees(self) -> np.ndarray:
        deg = np.zeros(self.num_nodes, np.int64)
        np.add.at(deg, self.dst, 1)
        return deg


def pad_graph(
    g: COOGraph,
    num_nodes: int | None = None,
    max_deg: int | None = None,
    deg_cap_policy: str = "recent",
) -> PaddedGraph:
    """Convert a COOGraph to a PaddedGraph.

    If a node's in-degree exceeds ``max_deg`` the excess edges are dropped:
    ``deg_cap_policy='recent'`` keeps edges whose *source snapshot* is most
    recent (matches the DDS intuition that fresh history matters most);
    ``'first'`` keeps arbitrary first-encountered edges.
    """
    n_real = g.num_nodes
    if num_nodes is None:
        num_nodes = n_real
    if num_nodes < n_real:
        raise ValueError(f"num_nodes {num_nodes} < real {n_real}")
    deg = g.in_degrees()
    if max_deg is None:
        max_deg = int(deg.max()) if deg.size else 1
    max_deg = max(int(max_deg), 1)

    nbr_idx = np.zeros((num_nodes, max_deg), np.int32)
    nbr_mask = np.zeros((num_nodes, max_deg), np.float32)
    nbr_etype = np.zeros((num_nodes, max_deg), np.int32)

    # sort edges by dst for grouped fill
    order = np.argsort(g.dst, kind="stable")
    src_s, dst_s, et_s = g.src[order], g.dst[order], g.etype[order]
    starts = np.searchsorted(dst_s, np.arange(num_nodes), side="left")
    ends = np.searchsorted(dst_s, np.arange(num_nodes), side="right")
    snap = g.snapshot
    for v in np.nonzero(ends > starts)[0]:
        s, e = starts[v], ends[v]
        srcs = src_s[s:e]
        ets = et_s[s:e]
        if e - s > max_deg:
            if deg_cap_policy == "recent":
                keep = np.argsort(-snap[srcs], kind="stable")[:max_deg]
            else:
                keep = np.arange(max_deg)
            srcs, ets = srcs[keep], ets[keep]
        k = srcs.size
        nbr_idx[v, :k] = srcs
        nbr_mask[v, :k] = 1.0
        nbr_etype[v, :k] = ets

    feat = np.zeros((num_nodes, g.features.shape[1]), np.float32)
    feat[:n_real] = g.features
    ntype = np.full(num_nodes, NodeType.PAD, np.int32)
    ntype[:n_real] = g.node_type
    snapshot = np.full(num_nodes, -1, np.int32)
    snapshot[:n_real] = g.snapshot
    label = np.zeros(num_nodes, np.float32)
    label[:n_real] = g.label
    label_mask = np.zeros(num_nodes, np.float32)
    label_mask[:n_real] = g.label_mask
    tower = None
    if g.tower is not None:
        tower = np.full(num_nodes, -1, np.int32)
        tower[:n_real] = g.tower

    return PaddedGraph(
        features=feat,
        nbr_idx=nbr_idx,
        nbr_mask=nbr_mask,
        nbr_etype=nbr_etype,
        node_type=ntype,
        snapshot=snapshot,
        label=label,
        label_mask=label_mask,
        tower=tower,
    )
