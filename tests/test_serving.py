"""Lambda serving pipeline: KV store semantics + end-to-end split equivalence."""
import os

import jax
import numpy as np
import pytest

from repro.core import LNNConfig, lnn_init
from repro.serve import KVStore, LambdaPipeline
from repro.serve.kvstore import pack_key


def test_kvstore_roundtrip(tmp_path):
    s = KVStore(dim=8)
    s.put(pack_key(5, 3), np.arange(8.0))
    s.put(pack_key(7, 1), np.ones(8))
    emb, mask = s.lookup_batch([[pack_key(5, 3), pack_key(99, 0)], []], k_max=3)
    assert emb.shape == (2, 3, 8)
    np.testing.assert_array_equal(emb[0, 0], np.arange(8.0))
    assert mask[0].tolist() == [1.0, 0.0, 0.0]
    assert mask[1].sum() == 0
    assert s.stats["misses"] == 1
    path = os.path.join(tmp_path, "store.npz")
    s.save(path)
    s2 = KVStore.load(path)
    assert len(s2) == 2
    np.testing.assert_array_equal(s2.get(pack_key(5, 3)), np.arange(8.0))


def test_pack_key_unique():
    seen = set()
    for e in range(50):
        for t in range(30):
            k = pack_key(e, t)
            assert k not in seen
            seen.add(k)


@pytest.mark.parametrize("gnn_type", ["gcn", "gat"])
def test_lambda_split_equivalence_end_to_end(gnn_type, small_communities):
    """Batch-layer refresh -> KV store -> speed-layer scoring must equal the
    monolithic forward (paper's deployment-correctness claim, LNN(GCN) and
    LNN(GAT) variants)."""
    feat_dim = small_communities[0].graph.features.shape[1]
    cfg = LNNConfig(gnn_type=gnn_type, num_gnn_layers=3, hidden_dim=32,
                    feat_dim=feat_dim)
    params = lnn_init(jax.random.PRNGKey(2), cfg)
    pipe = LambdaPipeline(params, cfg, k_max=16)
    stats = pipe.refresh(small_communities)
    assert stats["entities_written"] > 0
    worst = pipe.score_equivalence_check(small_communities, atol=1e-4)
    assert worst < 1e-4


def test_speed_layer_handles_cold_entities(small_communities):
    """Orders whose entities were never seen before must still score (the
    aggregate is empty -> self-tower only), not crash."""
    feat_dim = small_communities[0].graph.features.shape[1]
    cfg = LNNConfig(num_gnn_layers=3, hidden_dim=32, feat_dim=feat_dim)
    params = lnn_init(jax.random.PRNGKey(0), cfg)
    pipe = LambdaPipeline(params, cfg)
    # no refresh at all: store empty == all entities cold
    out = pipe.score([{"features": np.zeros(feat_dim, np.float32),
                       "entity_keys": [(1, 2), (3, 4)]}])
    assert out.shape == (1,)
    assert np.isfinite(out).all()
