"""Substrate tests: metrics vs naive oracles (hypothesis), optimizer,
checkpointing, padding helpers, GBDT + MLP baselines."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st

from repro.train.metrics import average_precision, roc_auc
from repro.train.optim import adamw, clip_by_global_norm, cosine_schedule
from repro.train.checkpoint import load_checkpoint, save_checkpoint
from repro.utils.padding import pad_axis_to, pad_to_multiple


# ------------------------------------------------------------------- metrics
def _naive_auc(y, s):
    pos = s[y == 1]
    neg = s[y == 0]
    cmp = (pos[:, None] > neg[None, :]).sum() + 0.5 * (pos[:, None] == neg[None, :]).sum()
    return cmp / (len(pos) * len(neg))


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 10_000), st.integers(5, 200), st.booleans())
def test_roc_auc_matches_naive(seed, n, with_ties):
    rng = np.random.default_rng(seed)
    y = rng.integers(0, 2, n)
    if y.sum() == 0:
        y[0] = 1
    if y.sum() == n:
        y[0] = 0
    s = rng.normal(size=n)
    if with_ties:
        s = np.round(s, 1)
    assert abs(roc_auc(y, s) - _naive_auc(y, s)) < 1e-9


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10_000), st.integers(5, 100))
def test_average_precision_properties(seed, n):
    rng = np.random.default_rng(seed)
    y = rng.integers(0, 2, n)
    if y.sum() == 0:
        y[0] = 1
    if y.sum() == n:
        y[0] = 0
    s = rng.normal(size=n)
    ap = average_precision(y, s)
    assert 0.0 <= ap <= 1.0
    # perfect ranking -> AP 1; baseline ~ prevalence
    assert average_precision(y, y.astype(float) + rng.normal(size=n) * 1e-9) > 0.99


def test_metrics_against_known_values():
    y = np.array([0, 0, 1, 1])
    s = np.array([0.1, 0.4, 0.35, 0.8])
    assert abs(roc_auc(y, s) - 0.75) < 1e-12           # sklearn doc example
    assert abs(average_precision(y, s) - 0.8333333333) < 1e-6


# ------------------------------------------------------------------ optimizer
def test_adamw_minimizes_quadratic():
    init_fn, update_fn = adamw(0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = init_fn(params)
    def loss(p):
        return jnp.sum(jnp.square(p["w"] - jnp.asarray([1.0, 1.0])))
    for _ in range(200):
        grads = jax.grad(loss)(params)
        params, state, _ = update_fn(grads, state, params)
    assert float(loss(params)) < 1e-3


def test_clip_by_global_norm():
    g = {"a": jnp.ones(4) * 10.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 20.0) < 1e-5
    cn = float(jnp.sqrt(jnp.sum(jnp.square(clipped["a"]))))
    assert abs(cn - 1.0) < 1e-5


def test_cosine_schedule_shape():
    sch = cosine_schedule(1.0, total_steps=100, warmup_steps=10)
    assert float(sch(0)) < 0.11
    assert abs(float(sch(10)) - 1.0) < 1e-6
    assert float(sch(100)) < 1e-6


# ---------------------------------------------------------------- checkpoints
def test_checkpoint_roundtrip(tmp_path):
    tree = {"layer": {"w": jnp.arange(6.0).reshape(2, 3), "b": jnp.zeros(3)},
            "stack": [jnp.ones((2,)), jnp.full((1,), 7.0)]}
    path = os.path.join(tmp_path, "ck.npz")
    save_checkpoint(path, tree, step=42)
    restored, step = load_checkpoint(path, tree)
    assert step == 42
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    path = os.path.join(tmp_path, "ck.npz")
    save_checkpoint(path, {"w": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        load_checkpoint(path, {"w": jnp.zeros((3, 2))})


# -------------------------------------------------------------------- padding
@given(st.integers(1, 10_000), st.integers(1, 512))
def test_pad_to_multiple(n, m):
    p = pad_to_multiple(n, m)
    assert p >= n and p % m == 0 and p - n < m


def test_pad_axis_to():
    x = np.ones((3, 4))
    y = pad_axis_to(x, 6, axis=0, fill=-1)
    assert y.shape == (6, 4) and (y[3:] == -1).all()


# ------------------------------------------------------------------ baselines
def test_gbdt_learns_separable():
    from repro.baselines import GBDTConfig, train_gbdt

    rng = np.random.default_rng(0)
    x = rng.normal(size=(600, 5))
    y = (x[:, 0] + 0.5 * x[:, 1] > 0).astype(np.float64)
    m = train_gbdt(x[:400], y[:400], GBDTConfig(num_trees=40), x[400:], y[400:])
    assert roc_auc(y[400:], m.predict_proba(x[400:])) > 0.95
    enc = m.leaf_value_features(x[:10])
    assert enc.shape == (10, len(m.trees))


def test_mlp_learns_separable():
    from repro.baselines.mlp import MLPConfig, predict_mlp, train_mlp

    rng = np.random.default_rng(0)
    x = rng.normal(size=(600, 5)).astype(np.float32)
    y = (x[:, 0] > 0).astype(np.float32)
    p = train_mlp(x[:400], y[:400], x[400:], y[400:], MLPConfig(epochs=60))
    assert roc_auc(y[400:], predict_mlp(p, x[400:])) > 0.95
