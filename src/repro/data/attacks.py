"""Named-attack fraud workload over the heterogeneous entity schema.

``synth.py`` generates the paper's homogeneous fraud world (7 untyped
entity columns per order).  This module generates the *heterogeneous*
counterpart: every order links exactly four **type-tagged** entities —
``buyer``, ``merchant``, ``device``, ``payment`` (``core.hetero``) — and
fraud arrives as three named attack patterns, labeled per order so
``benchmarks/streaming_bench.py`` can report recall per attack:

* ``ring`` — fraud rings: a pool of fake buyer accounts sharing a small
  set of devices and stolen payment tokens, bursting for a few snapshots
  (the classic linkage pattern; graph models should dominate here);
* ``burst`` — merchant compromise: many one-off buyers with stolen
  payment tokens hammer ONE merchant inside a 1–2 snapshot window (hub
  concentration on the merchant node);
* ``bin_test`` — BIN/card testing: one buyer+device cycles many fresh
  payment tokens at a single low-friction merchant with tiny amounts and
  high retry counts (feature-visible, graph-confirmable).

Legit traffic mirrors ``synth.py``'s: stable per-buyer entity sets,
popularity-skewed merchant choice, Poisson purchase times, and the same
weakly-predictive raw feature recipes (``RAW_FEATURES``, 12 dims).

Generator knobs and the attack catalog are documented in
``docs/graphs.md``.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.hetero import ENTITY_TYPE_NAMES, tag_entity
from repro.data.synth import NUM_RAW_FEATURES, _fraud_features, _legit_features
from repro.stream.events import CheckoutEvent

#: per-order pattern labels the generator emits ("legit" + these)
ATTACK_NAMES = ("ring", "burst", "bin_test")

_BUYER = ENTITY_TYPE_NAMES.index("buyer")
_MERCHANT = ENTITY_TYPE_NAMES.index("merchant")
_DEVICE = ENTITY_TYPE_NAMES.index("device")
_PAYMENT = ENTITY_TYPE_NAMES.index("payment")


@dataclass
class AttackConfig:
    """Knobs for :func:`generate_attack_stream` (see docs/graphs.md)."""

    num_buyers: int = 300           # legit buyer accounts
    num_merchants: int = 40         # merchant catalog (zipf-ish popularity)
    orders_per_buyer: float = 3.0   # Poisson mean over the whole window
    num_snapshots: int = 30         # one snapshot = one day
    # ring attack
    num_rings: int = 6
    ring_size: int = 8              # fake buyer accounts per ring
    ring_pool: int = 4              # shared devices / payment tokens per ring
    ring_burst_len: int = 4         # snapshots a ring stays active
    orders_per_ring_account: float = 2.5
    # merchant-compromise burst
    num_bursts: int = 3
    burst_orders: int = 30          # stolen-token orders per burst
    burst_window: int = 2           # snapshots the burst spans
    # BIN testing
    num_bin_runs: int = 3
    bin_cards: int = 25             # payment tokens cycled per run
    feature_noise: float = 1.0      # raw-feature class overlap (higher=harder)
    seed: int = 0


def generate_attack_stream(cfg: AttackConfig, rate_per_s: float = 200.0):
    """Generate the heterogeneous named-attack checkout stream.

    Returns ``(events, patterns)``: ``events`` is a list of
    :class:`~repro.stream.events.CheckoutEvent` in event-time order whose
    ``entities`` are type-tagged ``(buyer, merchant, device, payment)``
    ids; ``patterns`` is a same-length array of per-order pattern names
    (``"legit"`` or one of :data:`ATTACK_NAMES`) — evaluation-side truth
    only, never an input.
    """
    rng = np.random.default_rng(cfg.seed)
    counters = [0, 0, 0, 0]

    def new(code: int) -> int:
        counters[code] += 1
        return tag_entity(counters[code] - 1, code)

    merchants = [new(_MERCHANT) for _ in range(cfg.num_merchants)]
    # zipf-ish merchant popularity for legit traffic
    pop = 1.0 / np.arange(1, cfg.num_merchants + 1)
    pop /= pop.sum()

    # (snapshot, entities-tuple, fraud, pattern)
    orders: list[tuple[int, tuple, int, str]] = []

    def emit(t: int, buyer, merchant, device, payment, fraud, pattern):
        orders.append((int(t), (buyer, merchant, device, payment),
                       int(fraud), pattern))

    # --- legit buyers ------------------------------------------------------
    for _ in range(cfg.num_buyers):
        buyer, device, payment = new(_BUYER), new(_DEVICE), new(_PAYMENT)
        n = rng.poisson(cfg.orders_per_buyer)
        for t in np.sort(rng.integers(0, cfg.num_snapshots, n)):
            m = merchants[rng.choice(cfg.num_merchants, p=pop)]
            emit(t, buyer, m, device, payment, 0, "legit")

    # --- fraud rings -------------------------------------------------------
    span = max(cfg.num_snapshots - cfg.ring_burst_len, 1)
    for r in range(cfg.num_rings):
        devices = [new(_DEVICE) for _ in range(cfg.ring_pool)]
        payments = [new(_PAYMENT) for _ in range(cfg.ring_pool)]
        start = int(np.clip(
            round(r * span / max(cfg.num_rings - 1, 1)) + rng.integers(-2, 3),
            0, span))
        for _ in range(cfg.ring_size):
            buyer = new(_BUYER)     # fresh fake account per member
            n = rng.poisson(cfg.orders_per_ring_account)
            ts = start + rng.integers(0, cfg.ring_burst_len, n)
            for t in np.sort(ts):
                t = min(int(t), cfg.num_snapshots - 1)
                m = merchants[rng.integers(cfg.num_merchants)]
                emit(t, buyer, m,
                     devices[rng.integers(cfg.ring_pool)],
                     payments[rng.integers(cfg.ring_pool)], 1, "ring")

    # --- merchant-compromise bursts ---------------------------------------
    for _ in range(cfg.num_bursts):
        m = merchants[rng.integers(cfg.num_merchants)]
        start = int(rng.integers(0, max(cfg.num_snapshots - cfg.burst_window, 1)))
        for _ in range(cfg.burst_orders):
            t = start + int(rng.integers(0, cfg.burst_window))
            # one-off stolen identity per order, merchant is the shared hub
            emit(t, new(_BUYER), m, new(_DEVICE), new(_PAYMENT), 1, "burst")

    # --- BIN testing runs --------------------------------------------------
    for _ in range(cfg.num_bin_runs):
        buyer, device = new(_BUYER), new(_DEVICE)
        m = merchants[rng.integers(cfg.num_merchants)]
        start = int(rng.integers(0, cfg.num_snapshots))
        for _ in range(cfg.bin_cards):
            # card testers move fast: the whole run fits in <= 2 snapshots
            t = min(start + int(rng.integers(0, 2)), cfg.num_snapshots - 1)
            emit(t, buyer, m, device, new(_PAYMENT), 1, "bin_test")

    # --- features ----------------------------------------------------------
    labels = np.asarray([o[2] for o in orders], np.float32)
    patterns = np.asarray([o[3] for o in orders])
    n_ord = len(orders)
    feats = np.zeros((n_ord, NUM_RAW_FEATURES), np.float64)
    past_cb = np.zeros(n_ord)
    legit = labels == 0
    if legit.any():
        feats[legit] = _legit_features(rng, int(legit.sum()), None,
                                       past_cb[legit])
    if (~legit).any():
        feats[~legit] = _fraud_features(rng, int((~legit).sum()), None,
                                        past_cb[~legit], cfg.feature_noise)
    # pattern-specific marginals: BIN tests are tiny-amount / high-retry,
    # bursts skew to large amounts (cash-out before the token dies)
    bin_rows = patterns == "bin_test"
    feats[bin_rows, 0] = rng.normal(0.6, 0.3, int(bin_rows.sum()))
    feats[bin_rows, 8] += rng.poisson(2.0, int(bin_rows.sum()))
    burst_rows = patterns == "burst"
    feats[burst_rows, 0] += rng.normal(0.5, 0.2, int(burst_rows.sum()))

    # z-score with legit-population statistics (a production feature service
    # normalizes against the background distribution)
    mu = feats[legit].mean(0) if legit.any() else feats.mean(0)
    sd = feats[legit].std(0) if legit.any() else feats.std(0)
    feats = ((feats - mu) / np.maximum(sd, 1e-6)).astype(np.float32)

    # --- event-time order + Poisson arrivals -------------------------------
    idx = np.argsort([o[0] for o in orders], kind="stable")
    gaps = rng.exponential(1.0 / rate_per_s, n_ord)
    arrivals = np.cumsum(gaps)
    events = []
    for pos, o in enumerate(idx):
        t, ents, label, _ = orders[o]
        events.append(CheckoutEvent(
            order_id=int(o), snapshot=t, entities=ents,
            features=feats[o], label=float(label),
            arrival=float(arrivals[pos]),
        ))
    return events, patterns[idx]


__all__ = ["ATTACK_NAMES", "AttackConfig", "generate_attack_stream"]
