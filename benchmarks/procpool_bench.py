"""Process-pool benchmark — what real OS-process workers buy and prove.

Two sections, two gates (``experiments/BENCH_procpool.json``, enforced by
``tools/check_bench_schema.py``):

* **parity** — replays one event stream (with a mid-stream hot-swap)
  under ``backend="inline"`` and ``backend="process"`` at N=1 and N=4 and
  compares scores, staleness, model versions, KV value bytes / versions /
  model-versions (stamps are wall-clock and excluded), and store counters.
  ``gates.process_parity_bit_identical`` — the tentpole correctness
  invariant: moving compute into shard processes changes NOTHING about
  the bits.
* **scaling** — wall-clock replay throughput of the process backend at
  N=4 vs N=1 on a CPU-bound stage-2 workload (wide hidden dim, deadline
  flushes sized so every poll fires all four shards at once, children
  pinned single-threaded so the parallelism measured is the topology's,
  not BLAS's).  ``gates.throughput_scales_with_n`` requires >= 2x at N=4
  — evaluated only where the host can physically parallelize
  (``os.cpu_count() >= 4``); on smaller hosts the measured speedup is
  still recorded and ``scaling.limited_by_cores`` marks the gate vacuous.

Run:  PYTHONPATH=src python benchmarks/procpool_bench.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Pin BLAS/XLA to one thread BEFORE jax initializes anywhere: spawn
# children inherit this environment, so each shard process is genuinely
# single-threaded and the N=4 vs N=1 ratio measures process parallelism.
_PIN = {
    "OMP_NUM_THREADS": "1",
    "OPENBLAS_NUM_THREADS": "1",
    "MKL_NUM_THREADS": "1",
    "XLA_FLAGS": "--xla_cpu_multi_thread_eigen=false "
                 "intra_op_parallelism_threads=1",
}
for _k, _v in _PIN.items():
    os.environ.setdefault(_k, _v)


def _make_world(num_users, num_rings, n_events, hidden_dim, seed=7,
                rate_per_s=500.0, num_layers=2, mlp=(16,)):
    import jax

    from repro.core import LNNConfig, lnn_init
    from repro.data import SynthConfig, generate_event_stream

    events, g, _ = generate_event_stream(
        SynthConfig(num_users=num_users, num_rings=num_rings,
                    feature_noise=0.8, seed=seed),
        rate_per_s=rate_per_s)
    cfg = LNNConfig(num_gnn_layers=num_layers, hidden_dim=hidden_dim,
                    feat_dim=g.order_features.shape[1], mlp_dims=mlp)
    params = lnn_init(jax.random.PRNGKey(0), cfg)
    return events[:n_events], cfg, params


def _engine(params, cfg, *, backend, num_workers, max_batch, max_wait_s):
    import warnings

    from repro.stream import EngineConfig, StreamingEngine

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return StreamingEngine(
            params, cfg,
            EngineConfig(max_batch=max_batch, max_wait_s=max_wait_s,
                         num_workers=num_workers, backend=backend))


def _replay_traits(eng, events, swap=None):
    """Drive the stream (optional mid-stream hot-swap) and return the
    comparable bits: per-order score traits + KV state sans stamps."""
    import numpy as np

    out = []
    for i, ev in enumerate(events):
        if swap is not None and i == swap[0]:
            eng.load_model(swap[1], swap[2])
        out.extend(eng.submit(ev))
    out.extend(eng.flush())
    traits = [(r.request.tag.order_id, r.score, r.staleness,
               r.model_version) for r in out]
    kv = {k: (np.asarray(v).tobytes(), ver, mv)
          for shard in eng.store.shard_items()
          for k, v, ver, _st, mv in shard}
    return traits, kv, dict(eng.store.stats)


def run_parity_bench(*, num_users=60, num_rings=3, n_events=120,
                     hidden_dim=16, max_batch=8) -> tuple[dict, bool]:
    import jax

    from repro.core import lnn_init

    events, cfg, params = _make_world(num_users, num_rings, n_events,
                                      hidden_dim)
    params2 = lnn_init(jax.random.PRNGKey(1), cfg)
    swap = (len(events) // 2, params2, 1)

    record, all_identical = {}, True
    for n in (1, 4):
        runs = {}
        for backend in ("inline", "process"):
            eng = _engine(params, cfg, backend=backend, num_workers=n,
                          max_batch=max_batch, max_wait_s=0.005)
            try:
                runs[backend] = _replay_traits(eng, events, swap=swap)
            finally:
                eng.close()
        ti, kvi, sti = runs["inline"]
        tp, kvp, stp = runs["process"]
        same = (ti == tp and kvi == kvp and sti == stp)
        all_identical = all_identical and same
        record[str(n)] = {
            "scores_identical": bool(ti == tp),
            "kv_identical": bool(kvi == kvp),
            "counters_identical": bool(sti == stp),
            "orders": len(ti),
            "kv_entries": len(kvi),
        }
    record["checked_events"] = len(events)
    record["hot_swap_at"] = swap[0]
    return record, all_identical


def run_scaling_bench(*, num_users=300, num_rings=6, n_events=240,
                      hidden_dim=256, max_batch=64,
                      events_per_window=32) -> dict:
    """CPU-bound stage-2 replay, process backend, N=1 vs N=4.

    The arrival rate is chosen so ~``events_per_window`` land inside one
    deadline window and size triggers never fire — every expiry then
    flushes ALL shards in a single ``poll`` pass, which is exactly the
    multi-process overlap path (``WorkerPool._collect``)."""
    max_wait_s = 0.005
    rate = events_per_window / max_wait_s
    events, cfg, params = _make_world(
        num_users, num_rings, n_events, hidden_dim, rate_per_s=rate,
        mlp=(hidden_dim,))

    sweep = []
    for n in (1, 4):
        eng = _engine(params, cfg, backend="process", num_workers=n,
                      max_batch=max_batch, max_wait_s=max_wait_s)
        try:
            eng.warmup()
            t0 = time.perf_counter()
            out = []
            for ev in events:
                out.extend(eng.submit(ev))
            out.extend(eng.flush())
            wall = time.perf_counter() - t0
        finally:
            eng.close()
        assert len(out) == len(events)
        sweep.append({
            "num_workers": n,
            "wall_s": wall,
            "events_per_s": len(events) / wall,
        })

    speedup = sweep[1]["events_per_s"] / sweep[0]["events_per_s"]
    cores = os.cpu_count() or 1
    return {
        "sweep": sweep,
        "speedup_4v1": speedup,
        "cores": cores,
        # a 1-core host cannot exhibit process parallelism; the gate is
        # meaningful (and enforced) only where 4 shards can actually run
        "limited_by_cores": cores < 4,
        "config": {"hidden_dim": hidden_dim, "max_batch": max_batch,
                   "max_wait_s": max_wait_s,
                   "events_per_window": events_per_window,
                   "thread_pin": _PIN},
    }


def main(smoke: bool = False) -> dict:
    if smoke:
        parity, parity_ok = run_parity_bench(n_events=100)
        scaling = run_scaling_bench(num_users=150, num_rings=4,
                                    n_events=160, hidden_dim=128)
    else:
        parity, parity_ok = run_parity_bench(
            num_users=150, num_rings=5, n_events=400, hidden_dim=32)
        scaling = run_scaling_bench(n_events=480, hidden_dim=512)

    scaling_ok = (scaling["limited_by_cores"]
                  or scaling["speedup_4v1"] >= 2.0)
    r = {
        "n_events": parity["checked_events"],
        "parity": parity,
        "scaling": scaling,
        "gates": {
            "process_parity_bit_identical": bool(parity_ok),
            "throughput_scales_with_n": bool(scaling_ok),
        },
    }

    print("\n# Process pool (parity + scaling)")
    for n in ("1", "4"):
        p = parity[n]
        print(f"  parity N={n}: scores={p['scores_identical']} "
              f"kv={p['kv_identical']} counters={p['counters_identical']} "
              f"({p['orders']} orders, {p['kv_entries']} KV entries)")
    for p in scaling["sweep"]:
        print(f"  process N={p['num_workers']}: "
              f"{p['events_per_s']:8.1f} ev/s ({p['wall_s']:.2f}s)")
    lim = " (gate vacuous: <4 cores)" if scaling["limited_by_cores"] else ""
    print(f"  speedup 4v1: {scaling['speedup_4v1']:.2f}x "
          f"on {scaling['cores']} cores{lim}")
    print(f"  gates: {r['gates']}")

    outdir = os.path.join("experiments", "smoke") if smoke else "experiments"
    os.makedirs(outdir, exist_ok=True)
    with open(os.path.join(outdir, "BENCH_procpool.json"), "w") as f:
        json.dump(r, f, indent=1)
    return r


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI smoke (seconds, not minutes)")
    main(smoke=ap.parse_args().smoke)
