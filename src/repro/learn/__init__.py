"""``repro.learn`` — the continuous-learning plane.

Everything before this package *serves* models; nothing produced them.
``repro.learn`` closes the loop on the recovery substrate:

* :class:`WalTrainingTap` / :class:`LabelLog` — committed WAL suffixes
  become labeled training examples (receptive cones reconstructed with
  the incremental DDS builder, delayed-label join, compaction pin);
* :class:`RollingWindowTrainer` / :class:`WindowPolicy` — Morpheus-DFP-
  style rolling windows fine-tune the LNN (local SGD/Adam, no optax) and
  optionally refit the hybrid GBDT head on the tuned embedding;
* :class:`PromotionController` — candidates shadow-score on live traffic
  and promote only on a recall@budget win, with automatic rollback to
  last-good on post-promotion regressions;
* :class:`ContinuousLearner` — the one orchestrator the gateway drives;
* :func:`drifting_attack_stream` — the mid-stream attack-shift workload
  the learning bench proves recall recovery on.

See ``docs/learning.md`` for the tap format, label-join semantics, the
window policy, and the promotion/rollback state diagram.

Exports resolve lazily (PEP 562), same as ``repro.service``.
"""
from __future__ import annotations

__all__ = [
    "ContinuousLearner",
    "FineTuneResult",
    "LabelLog",
    "PromotionController",
    "RollingWindowTrainer",
    "TrainingExample",
    "WalTrainingTap",
    "WindowPolicy",
    "adam",
    "drifting_attack_stream",
    "recall_at_budget",
    "sgd",
]

_HOMES = {
    "ContinuousLearner": "repro.learn.learner",
    "FineTuneResult": "repro.learn.trainer",
    "LabelLog": "repro.learn.tap",
    "PromotionController": "repro.learn.promote",
    "RollingWindowTrainer": "repro.learn.trainer",
    "TrainingExample": "repro.learn.tap",
    "WalTrainingTap": "repro.learn.tap",
    "WindowPolicy": "repro.learn.trainer",
    "adam": "repro.learn.trainer",
    "drifting_attack_stream": "repro.learn.drift",
    "recall_at_budget": "repro.learn.promote",
    "sgd": "repro.learn.trainer",
}


def __getattr__(name: str):
    home = _HOMES.get(name)
    if home is None:
        raise AttributeError(f"module 'repro.learn' has no attribute {name!r}")
    import importlib

    value = getattr(importlib.import_module(home), name)
    globals()[name] = value    # cache: next access skips __getattr__
    return value


def __dir__():
    return sorted(set(globals()) | set(__all__))
