from repro.serve.kvstore import KVStore
from repro.serve.lambda_pipeline import BatchLayer, SpeedLayer, LambdaPipeline

__all__ = ["KVStore", "BatchLayer", "SpeedLayer", "LambdaPipeline"]
