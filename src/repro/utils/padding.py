"""Shape-padding helpers.

TPU/XLA strongly prefer static, hardware-aligned shapes (MXU tiles are
128x128, VPU lanes 8x128).  Everything ragged in this codebase (graph
neighborhoods, vocab tables, head counts) is padded with these helpers so
the padding policy lives in one place.
"""
from __future__ import annotations

import numpy as np


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def pad_to_multiple(n: int, m: int) -> int:
    """Smallest multiple of ``m`` that is >= ``n``."""
    if m <= 0:
        raise ValueError(f"multiple must be positive, got {m}")
    return ceil_div(n, m) * m


def pad_axis_to(x: np.ndarray, size: int, axis: int, fill=0) -> np.ndarray:
    """Pad numpy array ``x`` along ``axis`` up to ``size`` with ``fill``."""
    cur = x.shape[axis]
    if cur > size:
        raise ValueError(f"axis {axis} already {cur} > target {size}")
    if cur == size:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, size - cur)
    return np.pad(x, widths, mode="constant", constant_values=fill)
