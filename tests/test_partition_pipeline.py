"""Partition + data-pipeline invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st

from repro.core.partition import (
    _kmeans_1d,
    power_iteration_clustering,
    refine_partition,
    partition_transactions,
)
from repro.data import SynthConfig, generate_transactions, make_split_masks
from repro.data.pipeline import apply_split_to_batches


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 500), st.integers(20, 120), st.integers(8, 64))
def test_refine_partition_respects_size_cap(seed, n, target):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, n * 2)
    dst = rng.integers(0, n, n * 2)
    coarse = np.zeros(n, np.int32)
    comm = refine_partition(n, src, dst, coarse, target_size=target)
    assert comm.min() >= 0                       # every node assigned
    sizes = np.bincount(comm)
    assert sizes.max() <= target


def test_pic_separates_two_blobs():
    """Two disconnected cliques must land in different PIC clusters."""
    n = 40
    edges = []
    for i in range(20):
        for j in range(i + 1, 20):
            edges.append((i, j))
            edges.append((20 + i, 20 + j))
    src = np.asarray([e[0] for e in edges])
    dst = np.asarray([e[1] for e in edges])
    labels = power_iteration_clustering(n, src, dst, 2, seed=1)
    a, b = labels[:20], labels[20:]
    assert len(set(a.tolist())) == 1
    assert len(set(b.tolist())) == 1
    assert a[0] != b[0]


def test_kmeans_1d_basic():
    x = np.concatenate([np.zeros(10), np.ones(10) * 5])
    lab = _kmeans_1d(x, 2)
    assert len(set(lab[:10].tolist())) == 1 and lab[0] != lab[-1]


def test_partition_covers_all_nodes(small_fraud_dataset):
    g, _, _ = small_fraud_dataset
    comm = partition_transactions(g.num_orders, g.num_entities, g.edges,
                                  community_size=128)
    assert comm.shape[0] == g.num_orders + g.num_entities
    assert (comm >= 0).all()


def test_split_masks_are_time_ordered(small_fraud_dataset):
    g, _, split = small_fraud_dataset
    # every train order is no later than every test order
    assert g.order_snapshot[split == 0].max() <= g.order_snapshot[split == 2].min()
    assert {0, 1, 2} == set(np.unique(split).tolist())


def test_communities_partition_orders(small_fraud_dataset, small_communities):
    g, _, _ = small_fraud_dataset
    seen = np.concatenate([b.global_order_ids for b in small_communities])
    assert len(seen) == len(set(seen.tolist())), "order in two communities"
    # most orders survive (tiny communities are dropped by min_orders)
    assert len(seen) > 0.8 * g.num_orders


def test_apply_split_masks_only_requested_orders(small_fraud_dataset, small_communities):
    g, _, split = small_fraud_dataset
    masked = apply_split_to_batches(small_communities, split, which=2)
    for mb, b in zip(masked, small_communities):
        n_orders = b.global_order_ids.size
        m = np.asarray(mb.graph.label_mask[:n_orders])
        want = (split[b.global_order_ids] == 2).astype(np.float32)
        np.testing.assert_array_equal(m, want * np.asarray(b.graph.label_mask[:n_orders]))


def test_generator_fraud_in_every_split():
    for seed in range(3):
        g, _ = generate_transactions(SynthConfig(num_users=200, num_rings=5, seed=seed))
        split = make_split_masks(g.order_snapshot)
        for w in range(3):
            assert g.labels[split == w].sum() > 0, f"seed {seed} split {w} has no fraud"
            assert (g.labels[split == w] == 0).sum() > 0
