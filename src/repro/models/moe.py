"""Mixture-of-Experts layer: top-2 router with sort-based capacity dispatch.

TPU adaptation notes: GPU MoE kernels (megablocks) use ragged grouped GEMMs;
the TPU-native equivalent here keeps every GEMM dense by materializing a
fixed [E, C, d] expert buffer and routing tokens with *gathers* (cheap,
shardable) rather than one-hot dispatch einsums (which would add
O(T·E·C·d) fake FLOPs and wreck the roofline's useful-compute ratio) or
scatter-adds (slow on TPU).  The only scatters are tiny int32 index builds.

Capacity: C = ceil(k·T/E · capacity_factor); overflowed tokens drop (their
gate mass is lost, standard GShard behaviour).  The router also returns the
load-balancing auxiliary loss from the Switch/Mixtral recipe.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init
from repro.utils.padding import ceil_div


def moe_init(rng, cfg):
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 4)
    e, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff
    return {
        "router": dense_init(ks[0], (d, e), jnp.float32, scale=0.02),
        "w_gate": dense_init(ks[1], (e, d, f), dtype),
        "w_up": dense_init(ks[2], (e, d, f), dtype),
        "w_down": dense_init(ks[3], (e, f, d), dtype),
    }


def _num_groups(t: int) -> int:
    """GShard group count = number of batch shards (group-local dispatch keeps
    the token gather/scatter on-device; only expert compute crosses chips).
    Honors the weight-stationary decode layout's model-axis batch."""
    from repro.dist.sharding import _HINT_CTX, _batch_axes

    mesh = _HINT_CTX["mesh"]
    if mesh is None:
        return 1
    g = 1
    for a in _batch_axes(mesh):
        g *= mesh.shape[a]
    return g if (t % g == 0 and t // g >= 1) else 1


def moe_apply(params, cfg, x, full_capacity: bool = False):
    """x: [T, d] flattened tokens.  Returns (y [T, d], aux_loss scalar).

    Routing is *group-local* (GShard): tokens split into G groups aligned
    with the data shards, capacity and the sort-based dispatch per group, so
    dispatch gathers never cross devices.  The group axis is explicit in
    every einsum (not vmapped) so the partitioner keeps it sharded.

    Expert layout: experts shard over the model axis when divisible
    (expert parallelism); otherwise expert weights are *gathered* over their
    FSDP axis and d_ff shards over the model axis (tensor-parallel experts).
    The explicit weight constraints below stop GSPMD from resolving the
    contraction with activation-sized all-reduces over the FSDP axis
    (observed 40 GB/chip/layer without them).

    ``full_capacity=True`` sizes the expert buffer at k*Tg so no token can
    drop — used for decode (buffer is tiny) and for determinism tests.
    Otherwise C = ceil(k*Tg/E)*cf + 1; overflow drops.
    """
    from repro.dist.sharding import model_axis_size, shard_spec

    t, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    groups = _num_groups(t)
    tg = t // groups
    if full_capacity:
        cap = k * tg
    else:
        cap = int(ceil_div(k * tg, e) * cfg.moe_capacity_factor) + 1

    xg = shard_spec(x.reshape(groups, tg, d), "dp", None, None)    # [G, Tg, d]

    # ---- router ------------------------------------------------------------
    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)                # [G, Tg, k]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # ---- load-balance aux loss (Switch eq. 4), averaged over groups --------
    me = probs.mean(1)                                             # [G, E]
    hits = jax.nn.one_hot(expert_idx, e, dtype=jnp.float32).sum((1, 2)) / (tg * k)
    aux = (e * jnp.sum(me * hits, axis=-1)).mean()

    # ---- slot assignment via per-group sort (small int ops) ----------------
    flat_e = expert_idx.reshape(groups, tg * k)                    # [G, kT]
    order = jnp.argsort(flat_e, axis=-1)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    # offsets[g, e] = #entries with expert < e (0-based segment starts)
    offsets = jnp.concatenate(
        [jnp.zeros((groups, 1), sorted_e.dtype),
         jnp.cumsum(jnp.sum(jax.nn.one_hot(sorted_e, e, dtype=jnp.int32), axis=1),
                    axis=-1)[:, :-1]],
        axis=-1,
    )                                                              # [G, E]
    rank = jnp.arange(tg * k)[None, :] - jnp.take_along_axis(offsets, sorted_e, axis=-1)
    keep = rank < cap
    slot = jnp.where(keep, sorted_e * cap + rank, e * cap)
    tok_of_sorted = order // k                                     # [G, kT]

    # inverse map: which token fills each expert slot (sentinel -> tg)
    inv = jnp.full((groups, e * cap + 1), tg, jnp.int32)
    inv = jax.vmap(lambda i_, s_, t_: i_.at[s_].set(t_, mode="drop"))(
        inv, slot, tok_of_sorted.astype(jnp.int32)
    )
    x_pad = jnp.concatenate([xg, jnp.zeros((groups, 1, d), xg.dtype)], axis=1)
    z = jnp.take_along_axis(x_pad, inv[:, :-1, None], axis=1)      # [G, E*C, d]
    z = z.reshape(groups, e, cap, d)

    # ---- expert layout constraints ------------------------------------------
    mdl = model_axis_size()
    ep = e % mdl == 0 and mdl > 1
    if ep:
        z = shard_spec(z, "dp", "model", None, None)
        wg = shard_spec(params["w_gate"], "model", None, None)
        wu = shard_spec(params["w_up"], "model", None, None)
        wd = shard_spec(params["w_down"], "model", None, None)
    else:
        z = shard_spec(z, "dp", None, None, None)
        wg = shard_spec(params["w_gate"], None, None, "model")
        wu = shard_spec(params["w_up"], None, None, "model")
        wd = shard_spec(params["w_down"], None, "model", None)

    # ---- expert FFN (dense batched GEMMs) -----------------------------------
    # NB: einsum primal outputs stay in the param dtype (bf16) — a
    # preferred_element_type=f32 here makes every backward cotangent
    # all-reduce run in f32, doubling the dominant collective (§Perf B2).
    g_raw = jnp.einsum("gecd,edf->gecf", z, wg)
    g = jax.nn.silu(g_raw.astype(jnp.float32)).astype(z.dtype)
    u = jnp.einsum("gecd,edf->gecf", z, wu)
    y_ec = jnp.einsum("gecf,efd->gecd", g * u, wd)                 # [G, E, C, d]
    y_ec = shard_spec(y_ec, "dp", "model" if ep else None, None, None)

    # ---- combine: per-token gather of its k slots ---------------------------
    slot_of_assign = jax.vmap(
        lambda o_, s_: jnp.zeros((tg * k,), jnp.int32).at[o_].set(s_)
    )(order, jnp.where(keep, slot, e * cap).astype(jnp.int32))     # [G, kT]
    y_flat = jnp.concatenate(
        [y_ec.reshape(groups, e * cap, d),
         jnp.zeros((groups, 1, d), y_ec.dtype)], axis=1)
    contrib = jnp.take_along_axis(y_flat, slot_of_assign[:, :, None], axis=1)
    contrib = contrib.reshape(groups, tg, k, d)
    # combine in the param dtype: an f32 combine makes the y_ec cotangent
    # (the dominant [G,E,C,d] all-reduce) run in f32 — 2x collective bytes
    # for no model benefit (§Perf B3)
    y = jnp.einsum("gtkd,gtk->gtd", contrib, gate_vals.astype(contrib.dtype))
    y = shard_spec(y.astype(x.dtype), "dp", None, None)
    return y.reshape(t, d), aux


def moe_apply_dense_ref(params, cfg, x):
    """O(T·E) oracle: run every expert on every token, weight by the top-k
    gates.  Used by tests to validate the dispatch path (with generous
    capacity there are no drops and the two must match)."""
    t, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    logits = x.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    dense_gates = jnp.zeros((t, e), jnp.float32)
    dense_gates = jax.vmap(lambda g, i, row: row.at[i].set(g))(
        gate_vals, expert_idx, dense_gates
    )
    g = jax.nn.silu(jnp.einsum("td,edf->tef", x, params["w_gate"],
                               preferred_element_type=jnp.float32)).astype(x.dtype)
    u = jnp.einsum("td,edf->tef", x, params["w_up"])
    y_e = jnp.einsum("tef,efd->ted", g * u, params["w_down"])
    return jnp.einsum("ted,te->td", y_e.astype(jnp.float32), dense_gates).astype(x.dtype)
