"""Paper Table 3 reproduction: MLP vs LGB vs LNN(GAT) vs LNN(GCN).

Protocol follows §4.2: time-based 80/10/10 split, LGB trained on raw
checkout features, MLP/LNN on the LGB-encoded features, early stopping on
validation, ROC-AUC + AP on the final 10% of snapshots.  Mean ± std over
seeds.  (Dataset is the synthetic fraud-ring generator — the production
data is proprietary; the reproducible claim is the ORDERING and the
significant LNN-over-LGB gap, see EXPERIMENTS.md §Paper.)
"""
from __future__ import annotations

import json
import time

import numpy as np


def run_table3(seeds=(0, 1, 2), epochs: int = 30, verbose: bool = True):

    from repro.baselines import GBDTConfig, train_gbdt
    from repro.baselines.mlp import MLPConfig, predict_mlp, train_mlp
    from repro.core import LNNConfig
    from repro.data import (SynthConfig, build_communities,
                            generate_transactions, make_split_masks)
    from repro.data.pipeline import standardize_features
    from repro.train.loop import evaluate_lnn, train_lnn
    from repro.train.metrics import binary_metrics

    results: dict[str, list] = {"MLP": [], "LGB": [], "LNN (GAT)": [], "LNN (GCN)": []}
    timings: dict[str, list] = {k: [] for k in results}

    for seed in seeds:
        scfg = SynthConfig(num_users=300, num_rings=6, feature_noise=0.8, seed=seed)
        g, _ = generate_transactions(scfg)
        split = make_split_masks(g.order_snapshot)
        feats, _ = standardize_features(g.order_features, split == 0)

        t0 = time.time()
        gbdt = train_gbdt(feats[split == 0], g.labels[split == 0], GBDTConfig(),
                          feats[split == 1], g.labels[split == 1])
        timings["LGB"].append(time.time() - t0)
        results["LGB"].append(
            binary_metrics(g.labels[split == 2], gbdt.predict_proba(feats[split == 2])))

        # paper §4.2: MLP and LNN consume the LGB-encoded features
        enc = np.concatenate([feats, gbdt.leaf_value_features(feats)], 1)
        mu, sd = enc[split == 0].mean(0), enc[split == 0].std(0) + 1e-6
        enc = ((enc - mu) / sd).astype(np.float32)

        t0 = time.time()
        mlp = train_mlp(enc[split == 0], g.labels[split == 0],
                        enc[split == 1], g.labels[split == 1],
                        MLPConfig(pos_weight=3.0, seed=seed))
        timings["MLP"].append(time.time() - t0)
        results["MLP"].append(
            binary_metrics(g.labels[split == 2], predict_mlp(mlp, enc[split == 2])))

        g.order_features = enc
        batches = build_communities(g, community_size=256, max_deg=24, seed=seed)
        for gnn, name in (("gat", "LNN (GAT)"), ("gcn", "LNN (GCN)")):
            lcfg = LNNConfig(gnn_type=gnn, num_gnn_layers=3, hidden_dim=64,
                             feat_dim=enc.shape[1], pos_weight=3.0)
            t0 = time.time()
            res = train_lnn(batches, split, lcfg, epochs=epochs, patience=6, seed=seed)
            timings[name].append(time.time() - t0)
            m = evaluate_lnn(res.params, lcfg, batches, split, 2)
            results[name].append({k: m[k] for k in ("roc_auc", "average_precision")})
        if verbose:
            print(f"  seed {seed} done")

    table = {}
    for name, ms in results.items():
        auc = np.asarray([m["roc_auc"] for m in ms])
        ap = np.asarray([m["average_precision"] for m in ms])
        table[name] = {
            "roc_auc_mean": float(auc.mean()), "roc_auc_std": float(auc.std()),
            "ap_mean": float(ap.mean()), "ap_std": float(ap.std()),
            "train_seconds": float(np.mean(timings[name])),
        }
    return table


def main(seeds=(0, 1, 2)):
    table = run_table3(seeds)
    print("\n# Table 3 reproduction (synthetic fraud-ring dataset)")
    print(f"{'Model':<12} {'ROC AUC':<18} {'Average Precision':<20} train_s")
    for name in ("MLP", "LGB", "LNN (GAT)", "LNN (GCN)"):
        r = table[name]
        print(f"{name:<12} {r['roc_auc_mean']:.4f}±{r['roc_auc_std']:.4f}     "
              f"{r['ap_mean']:.4f}±{r['ap_std']:.4f}       {r['train_seconds']:.1f}")
    return table


if __name__ == "__main__":
    json.dump(main(), open("experiments/table3.json", "w"), indent=1)
