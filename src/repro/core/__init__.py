"""The paper's primary contribution: DDS graph + Lambda Neural Network."""
from repro.core.graph import COOGraph, EdgeType, NodeType, PaddedGraph, pad_graph
from repro.core.dds import (
    DDSGraph,
    IncrementalDDSBuilder,
    StaticGraph,
    build_dds,
    check_no_future_leak,
)
from repro.core.hetero import (
    ENTITY_TYPE_NAMES,
    entity_type_of,
    is_typed,
    strip_type,
    tag_entity,
    type_code_of,
)
from repro.core.lnn import (
    LNNConfig,
    lnn_forward,
    lnn_init,
    lnn_loss,
    lnn_order_tower,
    lnn_stage1,
    lnn_stage2_batch,
    lnn_stage2_embed,
    lnn_stage2_online,
)
from repro.core.partition import partition_transactions

__all__ = [
    "COOGraph",
    "EdgeType",
    "NodeType",
    "PaddedGraph",
    "pad_graph",
    "DDSGraph",
    "IncrementalDDSBuilder",
    "StaticGraph",
    "build_dds",
    "check_no_future_leak",
    "ENTITY_TYPE_NAMES",
    "entity_type_of",
    "is_typed",
    "strip_type",
    "tag_entity",
    "type_code_of",
    "LNNConfig",
    "lnn_forward",
    "lnn_init",
    "lnn_loss",
    "lnn_order_tower",
    "lnn_stage1",
    "lnn_stage2_batch",
    "lnn_stage2_embed",
    "lnn_stage2_online",
    "partition_transactions",
]
