"""The Lambda architecture (paper §3.3): batch layer + speed layer.

* :class:`BatchLayer` — periodically refreshes entity embeddings: runs LNN
  stage 1 over every community DDS graph (a pjit-able batch job) and writes
  the ``entity_{t-e}`` embeddings into the KV store.
* :class:`SpeedLayer` — online transaction-risk inference: per checkout
  request, fetch the linked entities' embeddings by key (ONE key-value
  lookup per entity — no graph traversal) and run the one-layer-GNN + MLP
  stage-2 scorer.
* :class:`LambdaPipeline` — wires both; ``score_equivalence_check`` proves
  the two-stage path reproduces the monolithic full-graph forward exactly
  (the paper's correctness argument for deploying the split).
"""
from __future__ import annotations

import time
import warnings
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lnn import (
    LNNConfig,
    lnn_forward,
    lnn_stage1,
    lnn_stage2_online,
)
from repro.serve.kvstore import KVStore, pack_key
from repro.service.types import ScoreRequest


@dataclass
class BatchLayer:
    """Periodic batch-layer refresh: ``refresh(batches)`` runs jitted LNN
    stage 1 over each community's padded graph and writes every
    ``(entity, t)`` snapshot embedding into ``store`` under its packed key.

    ``batches`` are community batches (``b.graph`` PaddedGraph + ``b.dds``
    build record) as produced by ``repro.data.build_communities``.
    """

    params: object
    cfg: LNNConfig
    store: KVStore
    model_version: int = 0

    def __post_init__(self):
        self._stage1 = jax.jit(lambda p, g: lnn_stage1(p, self.cfg, g))

    def set_model(self, params, model_version: int) -> None:
        """Swap to a new parameter version: subsequent refreshes compute and
        stamp embeddings under it (stage 1 is jitted over params-as-args, so
        no recompile)."""
        self.params = params
        self.model_version = int(model_version)

    def refresh(self, batches) -> dict:
        """Run stage 1 over all communities, push entity embeddings to the KV
        store.  Returns refresh stats (the paper's 'periodical inference')."""
        t0 = time.time()
        n_written = 0
        for b in batches:
            h = np.asarray(self._stage1(self.params, b.graph))
            # write every entity-snapshot vertex (key = (global entity, t))
            # as ONE batched put: a single store lock/clock acquisition per
            # community instead of one per embedding
            items = list(b.dds.entity_snap_ids.items())
            keys = [pack_key(self._global_entity(b, ent), t)
                    for (ent, t), _ in items]
            n_written += self.store.put_batch(
                keys, (h[nid] for _, nid in items),
                model_version=self.model_version)
        return {"entities_written": n_written, "seconds": time.time() - t0,
                "store_size": len(self.store)}

    @staticmethod
    def _global_entity(b, local_ent: int) -> int:
        # communities keep a local->global entity map when built from a
        # partition; fall back to local ids for single-community graphs
        m = getattr(b, "global_entity_ids", None)
        return int(m[local_ent]) if m is not None else int(local_ent)


@dataclass
class SpeedLayer:
    """Online transaction-risk scorer: ``score(requests)`` maps a list of
    ``{'features': [F], 'entity_keys': [(entity, t_e), ...]}`` dicts to
    fraud probabilities via at most ``k_max`` KV lookups per request plus a
    single stage-2 dispatch.

    The whole online compute (order tower + masked aggregation + last GNN
    layer + MLP head) is one jitted call of ``lnn_stage2_online``; with
    ``cfg.use_pallas`` that call is the fused ``kernels.stage2_score``
    Pallas launch.
    """

    params: object
    cfg: LNNConfig
    store: KVStore
    k_max: int = 8
    model_version: int = 0

    def __post_init__(self):
        self._stage2 = jax.jit(
            lambda p, emb, mask, feats: lnn_stage2_online(
                p, self.cfg, emb, mask, feats
            )
        )

    def set_model(self, params, model_version: int) -> None:
        """Swap to a new parameter version (params are jit arguments, so the
        compiled stage-2 cache is reused across versions)."""
        self.params = params
        self.model_version = int(model_version)

    def score(self, requests: list) -> np.ndarray:
        """requests: typed :class:`~repro.service.types.ScoreRequest`s (the
        legacy ``{'features': [F], 'entity_keys': [(ent, t_e), ...]}`` dicts
        are still accepted).

        Returns fraud probabilities.  This is the checkout-approval hot path:
        K key-value lookups + one fused jit call; no graph database."""
        reqs = [ScoreRequest.from_legacy(r) for r in requests]
        feats = jnp.asarray(np.stack([r.features for r in reqs]))
        key_lists = [
            [pack_key(e, t) for (e, t) in r.entity_keys] for r in reqs
        ]
        emb, mask = self.store.lookup_batch(key_lists, self.k_max)
        logits = self._stage2(self.params, jnp.asarray(emb), jnp.asarray(mask),
                              feats)
        return np.asarray(jax.nn.sigmoid(logits))


@dataclass
class LambdaPipeline:
    """Both Lambda halves wired over one shared ``KVStore``: ``refresh``
    delegates to the :class:`BatchLayer`, ``score`` to the
    :class:`SpeedLayer`, and ``score_equivalence_check`` replays every
    order with history through the real store to bound the two-stage vs
    monolithic score gap.

    .. deprecated::
        ``LambdaPipeline`` is a compatibility shim.  Construct a
        :class:`repro.service.FraudService` with ``mode="batch"`` instead —
        it wraps the same :class:`BatchLayer`/:class:`SpeedLayer` over the
        same store (bit-identical scores, proven in
        ``tests/test_service.py``) and adds the lifecycle, hot-swap, and
        admission-control surface.
    """

    params: object
    cfg: LNNConfig
    k_max: int = 8
    store: KVStore | None = None

    def __post_init__(self):
        warnings.warn(
            "LambdaPipeline is deprecated; use "
            "repro.service.FraudService(mode='batch') — see docs/serving_api.md",
            DeprecationWarning, stacklevel=2,
        )
        if self.store is None:
            self.store = KVStore(self.cfg.hidden_dim)
        self.batch_layer = BatchLayer(self.params, self.cfg, self.store)
        self.speed_layer = SpeedLayer(self.params, self.cfg, self.store, self.k_max)

    def refresh(self, batches):
        return self.batch_layer.refresh(batches)

    def score(self, requests):
        return self.speed_layer.score(requests)

    # ------------------------------------------------------------------ checks
    def score_equivalence_check(self, batches, atol=1e-4) -> float:
        """Max |two-stage online score - monolithic forward score| over all
        orders with history.  Proves the lambda split exact end-to-end
        (through the real KV store, not in-memory shortcuts)."""
        return split_equivalence_check(self.score, self.params, self.cfg,
                                       batches, atol)


def _batch_history_requests(b) -> tuple[list[ScoreRequest], list[int]]:
    """(typed requests, their order rows) for one community batch — the one
    place the speed-layer request construction from ``b.dds.last_hop``
    lives, so the demos/benches and the equivalence check can never drift
    onto different request shapes."""
    requests, rows = [], []
    for o, hops in b.dds.last_hop.items():
        keys = [(BatchLayer._global_entity(b, ent), t) for ent, t, _ in hops]
        requests.append(ScoreRequest(
            features=np.asarray(b.graph.features[o]), entity_keys=keys))
        rows.append(o)
    return requests, rows


def history_requests(batches) -> list[ScoreRequest]:
    """Typed speed-layer requests for every order with history across the
    community batches — what the demos and benchmarks used to hand-build
    from ``b.dds.last_hop`` with dicts."""
    return [r for b in batches for r in _batch_history_requests(b)[0]]


def split_equivalence_check(score_fn, params, cfg: LNNConfig, batches,
                            atol: float = 1e-4) -> float:
    """Max |online score - monolithic forward| over all orders with history,
    for ANY scorer with the speed-layer signature (``score_fn(requests) ->
    probs``) — shared by the legacy pipeline and the ``FraudService``
    facade so both prove the same bound through the same replay."""
    fwd = jax.jit(lambda p, g: lnn_forward(p, cfg, g))
    worst = 0.0
    for b in batches:
        requests, rows = _batch_history_requests(b)
        if not requests:
            continue
        full = np.asarray(jax.nn.sigmoid(fwd(params, b.graph)))
        online = np.asarray(score_fn(requests))
        worst = max(worst, float(np.abs(online - full[rows]).max()))
    if worst > atol:
        raise AssertionError(f"lambda split mismatch: {worst} > {atol}")
    return worst
