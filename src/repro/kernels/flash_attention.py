"""Pallas TPU kernel: flash attention (prefill) with GQA + causal/sliding-window.

Standard online-softmax tiling: grid = (batch, q_heads, q_tiles, k_tiles)
with the k dimension innermost and *sequential* ("arbitrary" dimension
semantics on TPU), carrying the running max / denominator / accumulator in
f32 VMEM scratch across k steps.  The output tile is written once, at the
last k step.

GQA: the k/v BlockSpec index-maps q-head h to kv-head h // (Hq // Hkv), so
no repeated K/V materialization happens — each q head streams the shared
kv head's tiles.

VMEM per program (bq=bk=128, Dh=128, f32 accum):
  q 64 KiB + k 64 KiB + v 64 KiB + acc 64 KiB + m/l 1 KiB  << 16 MiB.
Block sizes are multiples of (8, 128) so all matmuls are MXU-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

from repro.utils.padding import ceil_div

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, out_ref, m_ref, l_ref, acc_ref,
                  *, scale, causal, window, bq, bk, sk, sq):
    j = pl.program_id(3)
    nk = pl.num_programs(3)
    i = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                          # [bq, Dh]
    k = k_ref[0, 0]                          # [bk, Dh]
    v = v_ref[0, 0]                          # [bk, Dh]

    logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    # absolute positions; q rows are aligned to the END of the kv sequence
    qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + (sk - sq)
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask, logits, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.max(logits, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(logits - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        out_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(
            out_ref.dtype
        )


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_k", "interpret")
)
def flash_attention_pallas(q, k, v, causal: bool = True, window: int | None = None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = True):
    b, hq, sq, dh = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    rep = hq // hkv
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    grid = (b, hq, ceil_div(sq, bq), ceil_div(sk, bk))
    scale = dh ** -0.5

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, sk=sk, sq=sq,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, bk, dh), lambda b_, h, i, j, rep=rep: (b_, h // rep, j, 0)),
            pl.BlockSpec((1, 1, bk, dh), lambda b_, h, i, j, rep=rep: (b_, h // rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dh), lambda b_, h, i, j: (b_, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),      # running max  m
            pltpu.VMEM((bq,), jnp.float32),      # denominator  l
            pltpu.VMEM((bq, dh), jnp.float32),   # accumulator
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
