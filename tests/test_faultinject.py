"""The crash-matrix: kill the engine at every registered fault-injection
boundary and prove recovery is *bit-identical* to an uninterrupted run.

For each :data:`repro.utils.crashpoint.CRASH_POINTS` name, ``N=1`` and
``N=4`` workers, with a mid-stream model hot-swap and a mid-stream durable
checkpoint in every scenario:

* the armed boundary actually fires (a sweep entry that never crashes
  would silently test nothing);
* restore + WAL-suffix replay + resumed feed produces the SAME
  ``order_id -> (score, model_version)`` map as the uninterrupted oracle —
  no event lost, none double-scored, duplicates delivered bit-identically;
* the KV store holds the SAME bytes entry-for-entry.

The scenarios place the crash at materially different stream positions
(before/after the checkpoint, before/after the hot-swap, inside the
checkpoint write itself) via per-point hit counts — recovery must be exact
regardless of where the process dies.
"""
import jax
import pytest

from repro.core import LNNConfig, lnn_init
from repro.data import SynthConfig, generate_event_stream
from repro.service import FraudService, ModelSection, ServiceConfig
from repro.utils import crashpoint
from repro.utils.crashpoint import CRASH_POINTS

from faultinject import (
    drive,
    merge_responses,
    run_uninterrupted,
    run_with_crash,
    store_contents,
)

N_EVENTS = 60
SWAP_AT = 25          # hot-swap to version 1 after submitting events[25]
CHECKPOINT_AT = 12    # durable checkpoint after submitting events[12]

#: hit count per point, tuned so the crash lands mid-stream (after the
#: checkpoint where the firing rate allows) rather than on the first event
_HITS = {
    "wal.append.before": 40,   # fires per WAL record (~62 total)
    "wal.append.after": 40,
    "ingest.before": 35,       # fires per submitted event (60 total)
    "ingest.after": 35,
    "flush.before_score": 8,   # fires per micro-batch flush (~15 total)
    "flush.after_score": 8,
    "refresh.before_stage1": 6,   # fires per non-empty refresh window
    "refresh.before_puts": 6,
    "refresh.after": 6,
    "kv.put_batch.before": 5,  # fires per refresh KV write batch
    "kv.put_batch.after": 5,
    "checkpoint.before": 1,    # fires inside the checkpoint at event 12
    "checkpoint.mid": 1,
    "checkpoint.after": 1,
}

#: "worker_kill" is a *shard-process* death the pool absorbs (SIGKILL +
#: restore + exactly-once re-dispatch), not a parent crash the WAL harness
#: recovers from — it gets its own process-backend test below
_PARENT_POINTS = [p for p in CRASH_POINTS if p != "worker_kill"]


@pytest.fixture(scope="module")
def world():
    events, g, _ = generate_event_stream(
        SynthConfig(num_users=40, num_rings=2, feature_noise=0.8, seed=3),
        rate_per_s=500.0)
    events = events[:N_EVENTS]
    cfg = LNNConfig(num_gnn_layers=2, hidden_dim=8,
                    feat_dim=g.order_features.shape[1], mlp_dims=(8,))
    params = lnn_init(jax.random.PRNGKey(0), cfg)
    swap_params = lnn_init(jax.random.PRNGKey(7), cfg)
    return events, cfg, params, swap_params


def _maker(cfg, params, num_workers):
    sc = ServiceConfig(
        mode="streaming", model=ModelSection.from_lnn_config(cfg),
    ).replace(engine={"num_workers": num_workers, "max_batch": 4})
    return lambda: FraudService(sc, params=params).build()


@pytest.fixture(scope="module")
def baselines(world):
    """Uninterrupted oracle (scores + KV bytes) per worker count."""
    events, cfg, params, swap_params = world
    out = {}
    for n in (1, 4):
        out[n] = run_uninterrupted(
            _maker(cfg, params, n), events,
            swap=(SWAP_AT, swap_params, 1))
    return out


def _sweep(world, baselines, tmp_path, point, num_workers):
    events, cfg, params, swap_params = world
    res = run_with_crash(
        _maker(cfg, params, num_workers), events, str(tmp_path), point,
        hit=_HITS[point], swap=(SWAP_AT, swap_params, 1),
        checkpoint_at=CHECKPOINT_AT)
    assert res["crashed"] is not None, \
        f"{point}: armed boundary never fired — the sweep tested nothing"
    assert res["crashed"].point == point
    assert crashpoint.armed() is None
    base_scores, base_store = baselines[num_workers]
    assert set(res["scores"]) == set(base_scores), \
        f"{point}: event lost or invented across crash-restore-replay"
    diverged = [o for o in base_scores if res["scores"][o] != base_scores[o]]
    assert not diverged, \
        f"{point}: {len(diverged)} scores diverged after recovery"
    assert res["store"] == base_store, \
        f"{point}: KV-store bytes diverged after recovery"


@pytest.mark.parametrize("point", _PARENT_POINTS)
def test_crash_matrix_single_worker(world, baselines, tmp_path, point):
    _sweep(world, baselines, tmp_path, point, num_workers=1)


@pytest.mark.parametrize("point", _PARENT_POINTS)
def test_crash_matrix_four_workers(world, baselines, tmp_path, point):
    _sweep(world, baselines, tmp_path, point, num_workers=4)


@pytest.mark.parametrize("num_workers", [1, 4])
def test_worker_kill_process_backend(world, baselines, num_workers):
    """SIGKILL a shard process mid-stream (the ``worker_kill`` crash point
    turns the k-th SCORE post into a kill of its target child).  The pool
    must restore the shard from its last snapshot + put-journal suffix and
    re-dispatch the in-flight flush exactly once: scores AND KV bytes stay
    bit-identical to the inline oracle, with the restart visible in the
    per-worker stats."""
    events, cfg, params, swap_params = world
    sc = ServiceConfig(
        mode="streaming", model=ModelSection.from_lnn_config(cfg),
    ).replace(engine={"num_workers": num_workers, "max_batch": 4},
              workers={"backend": "process"})
    svc = FraudService(sc, params=params).build()
    try:
        crashpoint.arm("worker_kill", hit=8)
        try:
            responses = drive(svc, events, swap=(SWAP_AT, swap_params, 1))
        finally:
            crashpoint.disarm()
        pool = svc.engine.pool
        restarts = sum(row["restarts"] for row in pool.worker_summary())
        assert restarts >= 1, "armed worker_kill never killed a child"
        assert pool.dead_workers() == 0
        base_scores, base_store = baselines[num_workers]
        scores = merge_responses({}, responses)
        assert scores == base_scores, \
            "scores diverged across worker kill + restore"
        assert store_contents(svc.store) == base_store, \
            "KV-store bytes diverged across worker kill + restore"
    finally:
        svc.close()


def test_no_crash_wal_run_matches_oracle(world, baselines, tmp_path):
    """The WAL + checkpoint machinery itself must not perturb scoring:
    an *uninterrupted* WAL-enabled run (with a mid-stream checkpoint and
    hot-swap) is bit-identical to the bare oracle."""
    events, cfg, params, swap_params = world
    res = run_with_crash(
        _maker(cfg, params, 1), events, str(tmp_path),
        # armed point whose hit count is beyond the run -> never fires
        "checkpoint.before", hit=99,
        swap=(SWAP_AT, swap_params, 1), checkpoint_at=CHECKPOINT_AT)
    assert res["crashed"] is None
    base_scores, base_store = baselines[1]
    assert res["scores"] == base_scores
    assert res["store"] == base_store
