"""Paper claim 3: LNN + DDS needs only a 1-hop KV lookup at inference.

Benchmarks the speed layer (KV lookups + stage-2 jit) against the
"monolithic" alternative (re-running the full GNN over the order's whole
community per checkout — what serving without the lambda split would do).
Reports per-request latency and the speedup (the paper's "hundreds of
milliseconds" graph-DB query becomes a key-value fetch).
"""
from __future__ import annotations

import time

import numpy as np


def run_latency(n_requests: int = 200):
    import jax

    from repro.core import LNNConfig, lnn_forward, lnn_init
    from repro.data import SynthConfig, build_communities, generate_transactions, make_split_masks
    from repro.data.pipeline import standardize_features
    from repro.serve import history_requests
    from repro.service import FraudService, ModelSection, ServiceConfig

    scfg = SynthConfig(num_users=300, num_rings=6, feature_noise=0.8, seed=0)
    g, _ = generate_transactions(scfg)
    split = make_split_masks(g.order_snapshot)
    feats, _ = standardize_features(g.order_features, split == 0)
    g.order_features = feats
    batches = build_communities(g, community_size=256, max_deg=24)
    cfg = LNNConfig(num_gnn_layers=3, hidden_dim=64, feat_dim=feats.shape[1])
    params = lnn_init(jax.random.PRNGKey(0), cfg)

    svc = FraudService(
        ServiceConfig(mode="batch", model=ModelSection.from_lnn_config(cfg)),
        params=params).build().warmup()
    refresh_stats = svc.refresh(batches)

    # build request stream from real orders (and remember each owner
    # community for the monolithic comparison)
    requests, owners = [], []
    for b in batches:
        for r in history_requests([b]):
            requests.append(r)
            owners.append(b)
            if len(requests) >= n_requests:
                break
        if len(requests) >= n_requests:
            break

    def score(reqs):
        return np.asarray([resp.score for resp in svc.score(reqs)])

    # --- speed layer (lambda path), single-request latency -----------------
    score(requests[:1])                            # warm the jit
    t0 = time.time()
    for r in requests:
        score([r])
    lam_ms = (time.time() - t0) / len(requests) * 1e3

    # --- batched speed layer ------------------------------------------------
    score(requests)                                # warm the batch-shape jit
    t0 = time.time()
    score(requests)
    lam_batch_ms = (time.time() - t0) / len(requests) * 1e3

    # --- monolithic: full community forward per request ---------------------
    fwd = jax.jit(lambda p, gg: lnn_forward(p, cfg, gg))
    fwd(params, owners[0].graph)                   # warm
    t0 = time.time()
    for b in owners:
        fwd(params, b.graph).block_until_ready()
    mono_ms = (time.time() - t0) / len(owners) * 1e3

    return {
        "refresh_seconds": refresh_stats["seconds"],
        "store_entities": refresh_stats["store_size"],
        "lambda_ms_per_request": lam_ms,
        "lambda_batched_ms_per_request": lam_batch_ms,
        "monolithic_ms_per_request": mono_ms,
        "speedup_single": mono_ms / lam_ms,
        "speedup_batched": mono_ms / lam_batch_ms,
        "n_requests": len(requests),
    }


def main():
    r = run_latency()
    print("\n# Lambda serving latency (paper claim 3)")
    for k, v in r.items():
        print(f"  {k}: {v:.3f}" if isinstance(v, float) else f"  {k}: {v}")
    return r


if __name__ == "__main__":
    main()
