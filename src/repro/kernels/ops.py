"""Jit'd public wrappers for all kernels, with ref-path dispatch.

``use_pallas`` routing policy: on TPU the Pallas path compiles natively; on
CPU (this container) Pallas executes via ``interpret=True``.  Model code
calls these wrappers; the sharded dry-run uses the ref path (XLA ops) so the
lowering is backend-independent.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.csr_spmm import csr_spmm_pallas
from repro.kernels.edge_softmax import edge_softmax_agg_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.gqa_decode import gqa_decode_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas
from repro.kernels.stage2_score import flatten_stage2_params, stage2_score_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def csr_spmm(h, nbr_idx, weights, block_n: int = 128, block_h: int = 128):
    return csr_spmm_pallas(h, nbr_idx, weights, block_n=block_n, block_h=block_h,
                           interpret=_interpret())


def edge_softmax_agg(z, s_src, s_dst, nbr_idx, nbr_mask, etype_bias,
                     block_n: int = 128):
    return edge_softmax_agg_pallas(z, s_src, s_dst, nbr_idx, nbr_mask, etype_bias,
                                   block_n=block_n, interpret=_interpret())


def flash_attention(q, k, v, causal: bool = True, window: int | None = None,
                    block_q: int = 128, block_k: int = 128):
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  block_q=block_q, block_k=block_k,
                                  interpret=_interpret())


def gqa_decode(q, k, v, kv_len=None, window: int | None = None, block_k: int = 512):
    return gqa_decode_pallas(q, k, v, kv_len=kv_len, window=window,
                             block_k=block_k, interpret=_interpret())


def ssd_scan(x, dt, a, b, c, d_skip=None, chunk: int = 128):
    return ssd_scan_pallas(x, dt, a, b, c, d_skip=d_skip, chunk=chunk,
                           interpret=_interpret())


def stage2_score(params, gnn_type, entity_emb, emb_mask, order_feats,
                 block_b: int = 128, slot_type=None):
    """Fused speed-layer scoring: whole online stage-2 path in one launch.

    Takes the full ``lnn_init`` params pytree; the stage-2-relevant leaves
    are flattened into the kernel's argument order here (cheap — slicing and
    one stack, folded away under jit).  Heterogeneous params (``"typed"`` in
    the pytree) select the typed kernel variant: ``slot_type`` is the int32
    ``[B, K]`` entity-type code per slot (-1 = padding/untyped; defaults to
    all -1 when omitted).  Returns logits [B].
    """
    typed = "typed" in params
    flat = flatten_stage2_params(params, gnn_type)
    if typed and slot_type is None:
        slot_type = jnp.full(emb_mask.shape, -1, jnp.int32)
    if not typed:
        slot_type = None
    return stage2_score_pallas(entity_emb, emb_mask, order_feats, flat,
                               gnn_type=gnn_type, block_b=block_b,
                               interpret=_interpret(),
                               slot_type=slot_type, typed=typed)


# re-export oracles for convenience
csr_spmm_ref = _ref.csr_spmm_ref
edge_softmax_agg_ref = _ref.edge_softmax_agg_ref
mha_ref = _ref.mha_ref
gqa_decode_ref = _ref.gqa_decode_ref
ssd_scan_ref = _ref.ssd_scan_ref
ssd_chunked_ref = _ref.ssd_chunked_ref
