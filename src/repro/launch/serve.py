"""Serving launcher.

  * ``--paper``: stand up the lambda fraud-scoring pipeline (batch refresh
    + speed-layer scoring over a simulated checkout request stream) and
    report latency percentiles.
  * ``--arch <id>``: batched token serving for a reduced zoo config:
    prefill a prompt batch, then decode N tokens with the same serve_step
    the dry-run lowers.
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def serve_paper(args):
    import jax

    from repro.core import LNNConfig, lnn_init
    from repro.data import (SynthConfig, build_communities,
                            generate_transactions, make_split_masks)
    from repro.data.pipeline import standardize_features
    from repro.serve import LambdaPipeline
    from repro.serve.lambda_pipeline import BatchLayer

    scfg = SynthConfig(num_users=args.users, num_rings=6, feature_noise=0.8,
                       seed=args.seed)
    g, _ = generate_transactions(scfg)
    split = make_split_masks(g.order_snapshot)
    feats, _ = standardize_features(g.order_features, split == 0)
    g.order_features = feats
    batches = build_communities(g, community_size=256, max_deg=24)
    cfg = LNNConfig(num_gnn_layers=3, hidden_dim=64, feat_dim=feats.shape[1])
    params = lnn_init(jax.random.PRNGKey(args.seed), cfg)

    pipe = LambdaPipeline(params, cfg, k_max=8)
    print("batch layer refresh:", pipe.refresh(batches))
    print("split equivalence:", pipe.score_equivalence_check(batches))

    requests = []
    for b in batches:
        for o, hops in b.dds.last_hop.items():
            keys = [(BatchLayer._global_entity(b, ent), t) for ent, t, _ in hops]
            requests.append({"features": np.asarray(b.graph.features[o]),
                             "entity_keys": keys})
    requests = requests[: args.requests]
    pipe.score(requests[:1])
    lat = []
    for r in requests:
        t0 = time.time()
        pipe.score([r])
        lat.append((time.time() - t0) * 1e3)
    lat = np.asarray(lat)
    print(f"speed layer over {len(requests)} checkouts: "
          f"p50={np.percentile(lat,50):.2f}ms p95={np.percentile(lat,95):.2f}ms "
          f"p99={np.percentile(lat,99):.2f}ms")


def serve_arch(args):
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models import decode_step, init_params
    from repro.models.transformer import prefill

    cfg = get_config(args.arch).reduced()
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    rng = np.random.default_rng(args.seed)
    b, s_pre, max_len = args.batch, args.seq, args.seq + args.tokens
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s_pre)), jnp.int32)
    extra = {}
    if cfg.arch_type == "vlm":
        extra["vision"] = jnp.asarray(
            rng.normal(size=(b, cfg.num_vision_tokens, cfg.d_model)), jnp.float32)
    if cfg.arch_type == "audio":
        extra["frames"] = jnp.asarray(rng.normal(size=(b, 32, cfg.d_model)), jnp.float32)

    t0 = time.time()
    logits, cache = prefill(params, cfg, prompts, max_len, extra)
    print(f"prefill {b}x{s_pre}: {time.time()-t0:.2f}s")

    step = jax.jit(lambda p, t, c: decode_step(p, cfg, t, c))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    generated = [tok]
    t0 = time.time()
    for _ in range(args.tokens):
        logits, cache = step(params, tok, cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        generated.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    print(f"decoded {args.tokens} tokens x {b} seqs in {dt:.2f}s "
          f"({args.tokens*b/dt:.1f} tok/s, {dt/args.tokens*1e3:.1f} ms/step)")
    print("sample ids:", np.asarray(jnp.stack(generated, 1))[0][:16])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper", action="store_true")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--users", type=int, default=400)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.paper or not args.arch:
        serve_paper(args)
    else:
        serve_arch(args)


if __name__ == "__main__":
    main()
