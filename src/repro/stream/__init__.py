"""repro.stream — real-time streaming ingestion + micro-batched speed-layer
serving engine (the closed Lambda loop), with a multi-worker sharded speed
layer (``repro.stream.workers``).  See docs/streaming.md."""
from repro.stream.engine import EngineConfig, ReplayReport, StreamingEngine
from repro.stream.events import CheckoutEvent, events_from_static, order_event_tuples
from repro.stream.ingest import IngestResult, StreamIngester
from repro.stream.microbatch import (
    DeferredScore,
    MicroBatcher,
    PendingFlush,
    ScoredResult,
    ScoreRequest,
)
from repro.stream.procpool import ProcessWorkerPool, ProcStoreView, ShardServer
from repro.stream.refresh import RefreshDriver
from repro.stream.workers import (
    DepthAutoscaler,
    ShardRouter,
    SpeedLayerWorker,
    Stage2Scorer,
    WorkerPool,
)

__all__ = [
    "CheckoutEvent",
    "DeferredScore",
    "DepthAutoscaler",
    "EngineConfig",
    "IngestResult",
    "MicroBatcher",
    "PendingFlush",
    "ProcStoreView",
    "ProcessWorkerPool",
    "RefreshDriver",
    "ReplayReport",
    "ScoreRequest",
    "ScoredResult",
    "ShardRouter",
    "ShardServer",
    "SpeedLayerWorker",
    "Stage2Scorer",
    "StreamIngester",
    "StreamingEngine",
    "WorkerPool",
    "events_from_static",
    "order_event_tuples",
]
