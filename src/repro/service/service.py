"""``FraudService`` — the one serving facade over both Lambda halves.

One class, one explicit lifecycle::

    build() -> warmup() -> serve (score / submit / replay / refresh)
            -> drain() -> close()

constructed from a single :class:`~repro.service.config.ServiceConfig`
artifact plus a parameter pytree.  ``mode="batch"`` wraps the offline
:class:`~repro.serve.lambda_pipeline.BatchLayer` /
:class:`~repro.serve.lambda_pipeline.SpeedLayer` pair over one KV store;
``mode="streaming"`` wraps the event-time
:class:`~repro.stream.engine.StreamingEngine` (and its
:class:`~repro.stream.workers.WorkerPool`) over the same store design.
Scores are **bit-identical** to the legacy entry points — the facade calls
the exact same layers in the exact same order (``tests/test_service.py``).

On top of the legacy paths it adds:

* **versioned model hot-swap** — :meth:`load_model` registers a parameter
  version; in-flight micro-batches finish on the jit cache they captured,
  new flushes score under the new version, and batch-layer KV puts are
  stamped with the model version so post-swap reads of pre-swap embeddings
  are detectable (``store.stats['model_stale_reads']``);
* **admission control** — queue-depth / in-flight caps with a
  shed-vs-block policy (block stalls bounded by
  ``admission.block_max_wait_s`` with a timed-out→shed fallback), accounted
  in :class:`~repro.service.types.ServiceStats`;
* **canary/shadow scoring** — :meth:`enable_shadow` re-scores a sampled
  fraction of admitted traffic under a second registered model version,
  off the response path, tracking |primary − shadow| divergence and
  raising an alert when it breaches a threshold (the HTTP gateway surfaces
  both in ``/metrics``; see ``repro.gateway``).
"""
from __future__ import annotations

import json
import math
import os
import threading
import time

import numpy as np

from repro.serve.kvstore import KVStore
from repro.service.config import ServiceConfig
from repro.service.types import ScoreRequest, ScoreResponse, ServiceStats


class ServiceLifecycleError(RuntimeError):
    """An operation was invoked in a lifecycle state that forbids it."""


#: states in which serving operations (score/submit/refresh/drain) are legal
_SERVABLE = ("built", "ready", "serving", "drained")


class FraudService:
    """One typed serving API for the Lambda fraud detector.

    Parameters
    ----------
    config:
        The :class:`ServiceConfig` artifact (or a dict / JSON produced by
        one — see :meth:`from_artifact`).
    params:
        LNN parameter pytree for the initial model version.  May instead be
        registered later via :meth:`load_model` before :meth:`build`.
    store:
        Optional pre-populated :class:`KVStore`; by default the service
        builds its own from ``config.store``.
    """

    def __init__(self, config: ServiceConfig, params=None,
                 store: KVStore | None = None):
        if isinstance(config, dict):
            config = ServiceConfig.from_dict(config)
        self.config = config
        self.mode = config.mode
        self._external_store = store
        self.store: KVStore | None = store
        self._state = "created"
        self._models: dict[int, object] = {}
        self._model_version = 0
        self._model_swaps = 0
        self._params = None
        # previous active version after a live swap — the rollback target
        # (rollback_model); None until the first post-build activation
        self._last_good: int | None = None
        self.last_rollback: dict | None = None
        self._auto_ckpt: dict | None = None   # enable_auto_checkpoint state
        # crash consistency (enable_wal / checkpoint / restore) — these must
        # exist before the eager load_model below consults them
        self._wal = None
        self._wal_root: str | None = None
        self._applied_seq = 0
        self._replaying = False
        self.last_recovery: dict | None = None
        if params is not None:
            self.load_model(params, version=0)
        # admission + traffic accounting (ServiceStats surface)
        self._acct = {"requests": 0, "scored": 0, "shed": 0, "blocked": 0,
                      "block_timeouts": 0, "rollbacks": 0,
                      "queue_depth_peak": 0, "in_flight_peak": 0}
        self._scores_by_version: dict[int, int] = {}
        # canary/shadow scoring state (enable_shadow); the lock makes the
        # divergence counters tear-free under the gateway's request threads
        self._shadow_lock = threading.Lock()
        self._shadow: dict | None = None
        self._shadow_acc = 0.0
        self._shadow_jits: dict[int, object] = {}
        # mode-specific internals (populated by build)
        self._engine = None          # streaming
        self._autoscaler = None      # streaming (admission.autoscale)
        self._batch_layer = None     # batch
        self._speed_layer = None     # batch

    @classmethod
    def from_artifact(cls, path: str, params=None,
                      store: KVStore | None = None) -> "FraudService":
        """Construct from a saved ``ServiceConfig`` JSON artifact."""
        return cls(ServiceConfig.load(path), params=params, store=store)

    # ------------------------------------------------------------- lifecycle
    @property
    def state(self) -> str:
        return self._state

    def _ensure(self, allowed: tuple, op: str) -> None:
        if self._state not in allowed:
            raise ServiceLifecycleError(
                f"FraudService.{op}() is illegal in state {self._state!r} "
                f"(allowed: {allowed}); lifecycle is "
                "build -> warmup -> serve -> drain -> close"
            )

    def build(self) -> "FraudService":
        """Construct the store and the mode's serving layers.  Requires a
        registered model (constructor ``params`` or :meth:`load_model`)."""
        self._ensure(("created",), "build")
        if self._params is None:
            raise ServiceLifecycleError(
                "build() needs a model: pass params to the constructor or "
                "call load_model() first")
        cfg = self.config
        lnn = cfg.to_lnn_config()
        if self.mode == "streaming":
            from repro.stream.engine import StreamingEngine, _stage1_params

            self._engine = StreamingEngine(
                self._params, lnn, cfg.to_engine_config(),
                store=self._external_store, _via_service=True)
            self._engine.model_version = self._model_version
            self._engine.pool.set_model(self._params, self._model_version)
            self._engine.refresher.set_model(
                _stage1_params(self._params), self._model_version)
            self.store = self._engine.store
            adm = cfg.admission
            if adm.autoscale or adm.adaptive_steal:
                from repro.stream.workers import DepthAutoscaler

                self._autoscaler = DepthAutoscaler(
                    self._engine.pool,
                    min_workers=adm.autoscale_min_workers,
                    max_workers=adm.autoscale_max_workers,
                    high_depth=adm.autoscale_high_depth,
                    low_depth=adm.autoscale_low_depth,
                    sustain=adm.autoscale_sustain,
                    cooldown=adm.autoscale_cooldown,
                    autoscale=adm.autoscale,
                    adaptive_steal=adm.adaptive_steal,
                )
        else:
            from repro.models.hybrid import HybridModel

            if isinstance(self._params, HybridModel):
                raise ServiceLifecycleError(
                    "hybrid GNN->GBDT models serve in mode='streaming' only "
                    "(the booster replaces the online stage-2 head; the "
                    "batch pipeline has no online stage 2)")
            from repro.serve.lambda_pipeline import BatchLayer, SpeedLayer

            if self.store is None:
                s = cfg.store
                self.store = KVStore(
                    lnn.hidden_dim, capacity=s.capacity,
                    ttl_seconds=s.ttl_seconds, num_shards=s.num_shards,
                    shard_by_entity=bool(s.shard_by_entity),
                )
            self._batch_layer = BatchLayer(
                self._params, lnn, self.store,
                model_version=self._model_version)
            self._speed_layer = SpeedLayer(
                self._params, lnn, self.store, cfg.engine.k_max,
                model_version=self._model_version)
        self._state = "built"
        return self

    def warmup(self) -> "FraudService":
        """Compile every hot-path jit shape up front (cold start off the
        measured path)."""
        self._ensure(("built", "ready"), "warmup")
        if self.mode == "streaming":
            self._engine.warmup()
        else:
            import jax.numpy as jnp

            lnn = self.config.to_lnn_config()
            k = self.config.engine.k_max
            # compile the batch-1 stage-2 shape without touching the store
            self._speed_layer._stage2(
                self._params,
                jnp.zeros((1, k, lnn.hidden_dim)), jnp.zeros((1, k)),
                jnp.zeros((1, lnn.feat_dim)),
            )
        self._state = "ready"
        return self

    def drain(self, now: float | None = None) -> list[ScoreResponse]:
        """Barrier: finish outstanding work (streaming: join async refreshes
        and force-flush every worker queue).  The service may keep serving
        afterwards; ``close()`` ends it for good."""
        self._ensure(_SERVABLE, "drain")
        seq = None
        if self._wal is not None and not self._replaying \
                and self.mode == "streaming":
            # a drain force-flushes every queue, changing flush composition
            # — replay must reproduce it at the same point in the stream
            seq = self._wal.append_drain(now)
        out: list[ScoreResponse] = []
        if self.mode == "streaming":
            out = self._engine.flush(now)
            self._engine.refresher.drain()
            self._account_scored(out)
        self._state = "drained"
        if seq is not None:
            self._applied_seq = seq
        return out

    def close(self) -> None:
        """Terminal: no operation is legal afterwards (idempotent)."""
        if self._state == "closed":
            return
        if self.mode == "streaming" and self._engine is not None \
                and self._state in _SERVABLE:
            # never strand queued work on close
            if self._wal is not None:
                self._wal.append_drain(None)
            self._engine.flush()
            self._engine.refresher.drain()
        if self.mode == "streaming" and self._engine is not None:
            # stop worker processes (no-op for the inline backend) even when
            # the service never reached a servable state
            self._engine.close()
        if self._wal is not None:
            self._wal.close()
        self._state = "closed"

    # -------------------------------------------------------------- hot-swap
    def load_model(self, params, version: int | None = None) -> int:
        """Register ``params`` as a model version and activate it.

        In-flight micro-batches finish on the jit cache (and version stamp)
        they captured at flush entry; every later flush scores under the
        new version.  Batch-layer KV puts are stamped with the active model
        version, so reads of embeddings computed by an older model are
        detectable (``store.stats['model_stale_reads']``).  Versions are
        kept in a registry; re-activating an old version reuses its
        still-compiled jit cache.
        """
        if self._state == "closed":
            raise ServiceLifecycleError("load_model() on a closed service")
        if version is None:
            version = (max(self._models) + 1) if self._models else 0
        version = int(version)
        seq = None
        if self._wal is not None and not self._replaying:
            # write-ahead for hot-swaps too: persist the params file, THEN
            # log the swap — a logged swap is always replayable
            rel = self._persist_params(params, version)
            seq = self._wal.append_model(version, rel)
        prev = self._model_version
        self._models[version] = params
        self._params = params
        self._model_version = version
        if self._state != "created":
            self._model_swaps += 1
            if prev != version and prev in self._models:
                # the displaced incumbent becomes the rollback target
                self._last_good = prev
            if self.mode == "streaming":
                self._engine.load_model(params, version)
            else:
                self._batch_layer.set_model(params, version)
                self._speed_layer.set_model(params, version)
        if seq is not None:
            self._applied_seq = seq
        return version

    @property
    def model_version(self) -> int:
        return self._model_version

    @property
    def wal(self):
        """The live :class:`~repro.stream.checkpoint.WriteAheadLog` (None
        before :meth:`enable_wal`) — the continuous-learning plane's
        training tap reads committed suffixes from it (``repro.learn``)."""
        return self._wal

    def model_versions(self) -> tuple:
        """Every registered version, ascending."""
        return tuple(sorted(self._models))

    def model_params(self, version: int | None = None):
        """Registered parameters for ``version`` (default: the active
        version) — the fine-tune warm start for ``repro.learn``."""
        v = self._model_version if version is None else int(version)
        if v not in self._models:
            raise KeyError(
                f"model version {v} is not registered "
                f"(registered: {self.model_versions()})")
        return self._models[v]

    def register_model(self, params, version: int | None = None) -> int:
        """Add ``params`` to the version registry WITHOUT activating them —
        the staging half of a rollout: a registered version can be activated
        later (:meth:`activate_model`) or served as the canary
        (:meth:`enable_shadow`).  Returns the version registered."""
        if self._state == "closed":
            raise ServiceLifecycleError("register_model() on a closed service")
        if version is None:
            version = (max(self._models) + 1) if self._models else 0
        version = int(version)
        if self._wal is not None and not self._replaying:
            # registration has no scoring effect, so it needs no WAL record,
            # but the params must be on disk for checkpoint manifests (and a
            # later logged activate_model) to reference
            self._persist_params(params, version)
        self._models[version] = params
        return version

    def activate_model(self, version: int) -> int:
        """Hot-swap to an already-registered version (the gateway's
        ``POST /admin/model`` body names versions, never raw parameters —
        weights travel via checkpoints, not JSON)."""
        version = int(version)
        if version not in self._models:
            raise KeyError(
                f"model version {version} is not registered "
                f"(registered: {self.model_versions()})")
        return self.load_model(self._models[version], version)

    def register_perturbed(self, from_version: int, scale: float,
                           seed: int = 0, version: int | None = None) -> int:
        """Register a new version derived from ``from_version`` by adding
        deterministic Gaussian noise of ``scale`` to every parameter leaf.

        ``scale=0.0`` clones the weights — the wire-parity tests hot-swap to
        such a clone to prove scores stay bit-identical across a version
        bump; a nonzero scale makes a deliberately-divergent canary that
        must trip the shadow divergence alert.

        Hybrid models perturb their LNN tower only (the GBDT head is
        shared by reference) — ``HybridModel`` is not a JAX pytree, so
        mapping over it whole would collapse it into an object array."""
        from_version = int(from_version)
        if from_version not in self._models:
            raise KeyError(
                f"model version {from_version} is not registered "
                f"(registered: {self.model_versions()})")
        import jax

        from ..models.hybrid import HybridModel

        rng = np.random.default_rng(seed)

        def perturb(leaf):
            a = np.asarray(leaf)
            if scale == 0.0 or not np.issubdtype(a.dtype, np.floating):
                return a
            return (a + scale * rng.standard_normal(a.shape)).astype(a.dtype)

        source = self._models[from_version]
        if isinstance(source, HybridModel):
            import dataclasses

            params = dataclasses.replace(
                source,
                lnn_params=jax.tree_util.tree_map(perturb, source.lnn_params))
        else:
            params = jax.tree_util.tree_map(perturb, source)
        return self.register_model(params, version)

    @property
    def last_good_version(self) -> int | None:
        """The version a :meth:`rollback_model` would return to — the
        incumbent displaced by the most recent live swap (None until a swap
        happens, and cleared by a rollback so two alerts can never
        ping-pong between a bad version and its predecessor)."""
        return self._last_good

    def rollback_model(self, reason: str = "") -> int:
        """Roll the active model back to the last-good version.

        The shared rollback path of the promotion controller
        (``repro.learn.promote``) and the gateway's canary auto-rollback: it
        disables shadow scoring (the alert source), re-activates
        :attr:`last_good_version`, counts the event
        (``ServiceStats.rollbacks``), and records ``last_rollback`` for the
        stats surface.  Raises :class:`ServiceLifecycleError` when no
        last-good version exists."""
        if self._last_good is None or self._last_good not in self._models:
            raise ServiceLifecycleError(
                "rollback_model() needs a last-good version — no live swap "
                "has displaced an incumbent (or it was already rolled back)")
        bad, target = self._model_version, self._last_good
        self.disable_shadow()
        out = self.activate_model(target)
        # activate_model recorded ``bad`` as the displaced incumbent; a
        # rolled-back-from version is NOT a rollback target
        self._last_good = None
        self._acct["rollbacks"] += 1
        self.last_rollback = {"from": bad, "to": target,
                              "reason": str(reason)}
        return out

    # ------------------------------------------------------- shadow (canary)
    def enable_shadow(self, version: int, fraction: float | None = None,
                      threshold: float | None = None,
                      collect_eval: int | None = None,
                      role: str = "canary") -> dict:
        """Start canary/shadow scoring: a sampled ``fraction`` of admitted
        responses is re-scored under registered ``version`` (off the
        response path — callers invoke :meth:`shadow_observe` AFTER the
        primary response is delivered) and |primary − shadow| divergence is
        accumulated; one sample above ``threshold`` raises the alert
        (``shadow['alert_active']``, sticky until shadow is re-enabled).

        Defaults for ``fraction``/``threshold`` come from
        ``config.gateway``.  Returns the initial shadow-state snapshot.

        ``collect_eval``: when set, each sampled response additionally
        appends a ``[label, primary_score, shadow_score]`` triple to a
        bounded eval buffer (``shadow['eval']``, capped at ``collect_eval``
        entries) — the promotion controller's recall@budget evidence.  The
        buffer lives inside the shadow dict, so it rides checkpoint
        manifests and a crash mid-eval resumes the window instead of
        double-counting.  ``role`` labels the shadow's purpose
        (``'canary'`` / ``'candidate'`` / ``'last_good'``) so a restored
        promotion controller can re-attach to the right state.
        """
        if self._state == "closed":
            raise ServiceLifecycleError("enable_shadow() on a closed service")
        version = int(version)
        if version not in self._models:
            raise KeyError(
                f"shadow version {version} is not registered "
                f"(registered: {self.model_versions()})")
        gw = self.config.gateway
        fraction = gw.shadow_fraction if fraction is None else float(fraction)
        if not 0.0 <= fraction <= 1.0:
            raise ValueError("shadow fraction must be in [0, 1]")
        threshold = (gw.shadow_divergence_threshold if threshold is None
                     else float(threshold))
        with self._shadow_lock:
            self._shadow = {
                "version": version, "fraction": fraction,
                "threshold": threshold, "role": str(role), "sampled": 0,
                "divergence_sum": 0.0, "divergence_max": 0.0,
                "last_divergence": 0.0, "alerts": 0, "alert_active": False,
            }
            if collect_eval is not None:
                if int(collect_eval) < 1:
                    raise ValueError("collect_eval must be >= 1 or None")
                self._shadow["eval"] = []
                self._shadow["eval_max"] = int(collect_eval)
            self._shadow_acc = 0.0
            return self._shadow_snapshot()

    def _shadow_snapshot(self) -> dict:
        """Copy of the shadow dict (eval buffer deep-copied) — callers must
        never alias the live mutable state.  Lock held by caller."""
        snap = dict(self._shadow)
        if "eval" in snap:
            snap["eval"] = [list(t) for t in snap["eval"]]
        return snap

    def disable_shadow(self) -> None:
        with self._shadow_lock:
            self._shadow = None

    def shadow_stats(self) -> dict:
        """Snapshot of the divergence counters (empty dict = shadow off)."""
        with self._shadow_lock:
            return self._shadow_snapshot() if self._shadow is not None else {}

    def shadow_observe(self, responses: list) -> int:
        """Feed delivered responses to the shadow scorer.

        Samples admitted responses at the configured fraction (deterministic
        error-accumulator sampling, not RNG — replays sample identically),
        re-scores them in ONE padded stage-2 dispatch under the shadow
        version against the live KV store, and folds |primary − shadow|
        into the divergence counters.  Returns the number sampled.

        In streaming mode the shadow batch is padded to the same pow2
        buckets the speed layer uses, so an identical-weights shadow
        diverges by exactly 0.0 (bit-parity); in batch mode the primary
        path's batch shape differs, so identical weights may diverge at
        float-epsilon scale (~1e-6) — thresholds should sit far above that.
        """
        with self._shadow_lock:
            if self._shadow is None:
                return 0
            version = self._shadow["version"]
            fraction = self._shadow["fraction"]
            picked: list[ScoreResponse] = []
            for r in responses:
                if not r.admitted:
                    continue
                self._shadow_acc += fraction
                if self._shadow_acc >= 1.0 - 1e-12:
                    self._shadow_acc -= 1.0
                    picked.append(r)
        if not picked:
            return 0
        shadow_scores = self._shadow_score([r.request for r in picked], version)
        with self._shadow_lock:
            sh = self._shadow
            if sh is None or sh["version"] != version:
                return 0   # shadow was swapped/disabled mid-scoring
            for r, p in zip(picked, shadow_scores):
                d = abs(float(r.score) - float(p))
                sh["sampled"] += 1
                sh["divergence_sum"] += d
                sh["divergence_max"] = max(sh["divergence_max"], d)
                sh["last_divergence"] = d
                if d > sh["threshold"]:
                    sh["alerts"] += 1
                    sh["alert_active"] = True
                if "eval" in sh and len(sh["eval"]) < sh["eval_max"]:
                    # [label, primary, shadow] — labels ride the request tag
                    # (the CheckoutEvent); tagless batch-mode requests record
                    # NaN, which recall evaluation skips
                    label = getattr(r.request.tag, "label", math.nan)
                    sh["eval"].append(
                        [float(label), float(r.score), float(p)])
        return len(picked)

    def _shadow_score(self, requests: list, version: int) -> np.ndarray:
        """Score ``requests`` under registered ``version`` against the live
        store, replicating the primary path's numerics per mode (streaming:
        versioned snapshot-fallback lookup, pow2 bucket padding, host f64
        sigmoid; batch: exact-key lookup as ``serve.SpeedLayer`` does)."""
        import jax

        from repro.core.hetero import type_code_of
        from repro.core.lnn import lnn_stage2_embed, lnn_stage2_online
        from repro.models.hybrid import HybridModel
        from repro.stream.microbatch import bucket_size

        lnn = self.config.to_lnn_config()
        k = self.config.engine.k_max
        shadow_params = self._models[version]
        hybrid = isinstance(shadow_params, HybridModel)
        jit = self._shadow_jits.get(version)
        if jit is None:
            if hybrid:
                jit = jax.jit(
                    lambda p, emb, mask, feats, st: lnn_stage2_embed(
                        p, lnn, emb, mask, feats, slot_type=st))
            else:
                jit = jax.jit(
                    lambda p, emb, mask, feats, st: lnn_stage2_online(
                        p, lnn, emb, mask, feats, slot_type=st))
            self._shadow_jits[version] = jit
        n = len(requests)
        b = bucket_size(n, max(2, self.config.engine.max_batch))
        feats = np.zeros((b, lnn.feat_dim), np.float32)
        key_lists: list[list] = [[] for _ in range(b)]
        for i, r in enumerate(requests):
            feats[i] = r.features
            key_lists[i] = list(r.entity_keys)
        st = None
        if lnn.entity_types:
            # same per-slot type codes the primary Stage2Scorer derives
            st = np.full((b, k), -1, np.int32)
            for i, keys in enumerate(key_lists):
                for j, (ent, _t) in enumerate(keys[:k]):
                    st[i, j] = type_code_of(ent)
        if self.mode == "streaming":
            # expected_model_version=None: shadow reads must not pollute the
            # production model_stale_reads counter
            emb, mask, _ = self.store.lookup_batch_versioned(key_lists, k)
        else:
            from repro.serve.kvstore import pack_key

            packed = [[pack_key(e, t) for (e, t) in keys] for keys in key_lists]
            emb, mask = self.store.lookup_batch(packed, k)
        if hybrid:
            x = np.asarray(jit(shadow_params.lnn_params, emb, mask, feats, st),
                           np.float32)
            return shadow_params.gbdt.predict_proba(x).astype(np.float32)[:n]
        logits = np.asarray(jit(shadow_params, emb, mask, feats, st),
                            np.float64)
        # host-side f64 sigmoid, matching Stage2Scorer exactly (bit-parity);
        # a strongly-perturbed canary can drive exp to +inf, which saturates
        # to prob 0.0 — well-defined, so the overflow warning is noise
        with np.errstate(over="ignore"):
            probs = (1.0 / (1.0 + np.exp(-logits))).astype(np.float32)
        return probs[:n]

    # ------------------------------------------------------------ batch mode
    def refresh(self, batches) -> dict:
        """Batch-layer refresh over community batches (mode='batch')."""
        self._ensure(_SERVABLE, "refresh")
        self._require_mode("batch", "refresh")
        self._state = "serving"
        return self._batch_layer.refresh(batches)

    def score(self, requests: list) -> list[ScoreResponse]:
        """Score a request list synchronously (mode='batch').

        Accepts typed :class:`ScoreRequest`s (legacy dicts tolerated).
        Admission: with ``max_queue_depth = D`` set, ``shed`` rejects
        requests beyond the first D per call (NaN score,
        ``admitted=False``); ``block`` scores everything in D-sized
        chunks, counting the overflow as blocked.
        """
        self._ensure(_SERVABLE, "score")
        self._require_mode("batch", "score")
        self._state = "serving"
        reqs = [ScoreRequest.from_legacy(r) for r in requests]
        self._acct["requests"] += len(reqs)
        adm = self.config.admission
        cap = adm.max_queue_depth
        shed: list[ScoreRequest] = []
        chunks: list[list[ScoreRequest]]
        if cap is None or len(reqs) <= cap:
            chunks = [reqs] if reqs else []
        elif adm.policy == "shed":
            chunks, shed = [reqs[:cap]], reqs[cap:]
            self._acct["shed"] += len(shed)
        else:  # block: everything scores, in cap-sized waves
            chunks = [reqs[i:i + cap] for i in range(0, len(reqs), cap)]
            self._acct["blocked"] += len(reqs) - cap
        self._acct["queue_depth_peak"] = max(
            self._acct["queue_depth_peak"], len(reqs))
        out: list[ScoreResponse] = []
        for chunk in chunks:
            probs = self._speed_layer.score(chunk)
            out.extend(
                ScoreResponse(request=r, score=float(p),
                              batch_size=len(chunk),
                              model_version=self._model_version)
                for r, p in zip(chunk, probs)
            )
        self._account_scored(out)
        out.extend(
            ScoreResponse(request=r, score=math.nan, admitted=False,
                          model_version=self._model_version)
            for r in shed
        )
        return out

    def score_equivalence_check(self, batches, atol: float = 1e-4) -> float:
        """Two-stage-vs-monolithic bound through the real store
        (mode='batch'); see ``LambdaPipeline.score_equivalence_check``."""
        self._ensure(_SERVABLE, "score_equivalence_check")
        self._require_mode("batch", "score_equivalence_check")
        from repro.serve.lambda_pipeline import split_equivalence_check

        # drive the speed layer directly: an internal verification replay
        # must neither count as served traffic nor be subject to admission
        # shedding (a shed NaN would fail the check spuriously)
        return split_equivalence_check(
            self._speed_layer.score,
            self._params, self.config.to_lnn_config(), batches, atol)

    # -------------------------------------------------------- streaming mode
    def submit(self, event) -> list[ScoreResponse]:
        """Ingest one :class:`~repro.stream.events.CheckoutEvent` and return
        whatever responses completed by its arrival — the legacy engine path
        with the admission controller between ingest and enqueue."""
        self._ensure(_SERVABLE, "submit")
        self._require_mode("streaming", "submit")
        seq = None
        if self._wal is not None and not self._replaying:
            # write-ahead: log before any state mutation, so a crash
            # anywhere inside the apply is repaired by replay, never lost
            seq = self._wal.append_event("submit", event)
        self._state = "serving"
        eng, pool, adm = self._engine, self._engine.pool, self.config.admission
        now = event.arrival
        out = pool.poll(now)
        req = eng.ingest(event)
        self._acct["requests"] += 1
        self._acct["in_flight_peak"] = max(
            self._acct["in_flight_peak"], pool.busy_workers(now))

        if not self._admit(req, pool, adm, now, out):
            self._account_scored(out)
            out.append(ScoreResponse(
                request=req, score=math.nan, admitted=False,
                model_version=self._model_version))
            if seq is not None:
                self._applied_seq = seq
            self._maybe_auto_checkpoint()
            return out
        # peak records the depth the admitted request actually observed
        # (post block-drain), so it never exceeds an enforced cap + 1 frame
        self._acct["queue_depth_peak"] = max(
            self._acct["queue_depth_peak"], len(pool) + 1)
        out.extend(pool.submit(req, now))
        if self._autoscaler is not None:
            # a scale decision drains the queues; those results were scored
            # under the old topology and must reach the caller
            out.extend(self._autoscaler.observe(now))
        self._account_scored(out)
        if seq is not None:
            self._applied_seq = seq
        self._maybe_auto_checkpoint()
        return out

    def _admit(self, req, pool, adm, now: float, out: list) -> bool:
        """Admission decision for one streaming request.  Returns False to
        shed.  Block-policy stalls (forced flushes / busy-worker waits) are
        applied here and counted."""
        if adm.max_queue_depth is not None and len(pool) >= adm.max_queue_depth:
            if adm.policy == "shed":
                self._acct["shed"] += 1
                return False
            # block: the producer stalls while the deepest queue drains.
            # Progress is measured by pool depth, NOT by returned results —
            # the reorder buffer may withhold a flushed batch until earlier
            # sequence numbers complete, so an empty return is routine with
            # multiple workers while the flush itself still freed capacity.
            # The stall is wall-clock-bounded by admission.block_max_wait_s:
            # on timeout (or a wedged queue) the request is shed instead of
            # waiting forever / being admitted over-cap.
            self._acct["blocked"] += 1
            drained, admitted = pool.drain_to_depth(
                adm.max_queue_depth, now, budget_s=adm.block_max_wait_s)
            out.extend(drained)
            if not admitted:
                self._acct["block_timeouts"] += 1
                self._acct["shed"] += 1
                return False
        if adm.max_in_flight is not None \
                and pool.busy_workers(now) >= adm.max_in_flight:
            if adm.policy == "shed":
                self._acct["shed"] += 1
                return False
            self._acct["blocked"] += 1  # admitted, but the stall is visible
        return True

    def ingest(self, event) -> None:
        """Ingest one event into the DDS/batch layer WITHOUT scoring —
        backfill and non-checkout entity activity (the gateway's
        ``POST /v1/ingest``).  Counts toward refresh triggers and KV
        writes but not toward request/score accounting."""
        self._ensure(_SERVABLE, "ingest")
        self._require_mode("streaming", "ingest")
        seq = None
        if self._wal is not None and not self._replaying:
            seq = self._wal.append_event("ingest", event)
        self._state = "serving"
        self._engine.ingest(event)
        if seq is not None:
            self._applied_seq = seq
        self._maybe_auto_checkpoint()

    def replay(self, events, warmup: bool = True):
        """Drive a whole event stream; returns the engine's
        :class:`~repro.stream.engine.ReplayReport` (admission-shed requests
        are accounted in :meth:`stats`, not in the report)."""
        self._ensure(_SERVABLE, "replay")
        self._require_mode("streaming", "replay")
        if warmup:
            # same semantics as the legacy engine replay: compile every
            # bucket shape before the measured loop (idempotent)
            self._engine.warmup()
            if self._state == "built":
                self._state = "ready"
        from repro.stream.engine import ReplayReport

        results: list[ScoreResponse] = []
        for ev in events:
            results.extend(self.submit(ev))
        results.extend(self.drain())
        return ReplayReport(
            results=[r for r in results if r.admitted], engine=self._engine)

    # ---------------------------------------------- crash consistency (WAL)
    def _persist_params(self, params, version: int) -> str:
        """Write one model version under the WAL root (idempotent).
        Returns the root-relative path checkpoint manifests / WAL model
        records reference.  Hybrid models persist as ``save_hybrid``
        artifacts in the same ``.npz`` slot (the ``__hybrid__`` marker
        routes the restore)."""
        from repro.models.hybrid import HybridModel, save_hybrid
        from repro.train.checkpoint import save_checkpoint

        rel = os.path.join("models", f"v{int(version)}.npz")
        path = os.path.join(self._wal_root, rel)
        if not os.path.exists(path):
            if isinstance(params, HybridModel):
                save_hybrid(path, params)
            else:
                save_checkpoint(path, params)
        return rel

    def enable_wal(self, root: str, fsync: bool = False) -> "FraudService":
        """Start write-ahead logging under directory ``root``.

        Must be called on a freshly-built streaming service **before any
        traffic** — recovery without a checkpoint replays the whole log
        against the genesis state, so that state must be reconstructible:
        ``root/service.json`` (the config), ``root/genesis.json`` (active
        version + registry + lifecycle), and every registered version's
        params under ``root/models/`` are persisted here.  From this point
        every ``submit`` / ``ingest`` / ``load_model`` is logged *before*
        it is applied; :meth:`checkpoint` bounds replay time and
        :meth:`restore` rebuilds the exact state after a crash.
        """
        from repro.stream import checkpoint as ckpt

        self._ensure(("built", "ready"), "enable_wal")
        self._require_mode("streaming", "enable_wal")
        if self._wal is not None:
            raise ServiceLifecycleError("enable_wal() called twice")
        if self._engine.ingester.num_events:
            raise ServiceLifecycleError(
                "enable_wal() must run before any traffic — events ingested "
                "pre-WAL would be unrecoverable")
        os.makedirs(root, exist_ok=True)
        self._wal_root = root
        self.config.save(os.path.join(root, "service.json"))
        for v, p in self._models.items():
            self._persist_params(p, v)
        with open(os.path.join(root, "genesis.json"), "w") as f:
            json.dump({"state": self._state,
                       "model_version": self._model_version,
                       "versions": sorted(self._models)}, f)
        self._wal = ckpt.WriteAheadLog(ckpt.wal_path(root), fsync=fsync)
        self._applied_seq = self._wal.last_seq
        return self

    @property
    def applied_seq(self) -> int:
        """Highest WAL seqno whose apply completed (0 = none / WAL off)."""
        return self._applied_seq

    def checkpoint(self, compact: bool = False) -> str:
        """Write one atomic checkpoint of the full streaming state at the
        current ``applied_seq``; with ``compact=True`` also drop the WAL
        prefix the checkpoint covers.  Returns the checkpoint directory.

        Quiesces the async refresh thread first (an in-flight stage 1 is
        mid-effect and has no consistent snapshot) but does NOT flush the
        worker queues — queued requests are checkpointed as queued, so the
        restored run's flush compositions (and hence its bit-exact scores)
        are unchanged."""
        from repro.stream import checkpoint as ckpt

        self._ensure(_SERVABLE, "checkpoint")
        self._require_mode("streaming", "checkpoint")
        if self._wal is None:
            raise ServiceLifecycleError(
                "checkpoint() requires enable_wal() — a checkpoint without "
                "a log cannot bound what replay owes")
        self._engine.refresher.drain()
        path = ckpt.write_checkpoint(self._wal_root, self, self._applied_seq)
        if compact:
            self._wal.compact(self._applied_seq)
        return path

    def enable_auto_checkpoint(self, every_s: float | None = None,
                               every_windows: int | None = None,
                               keep_last: int | None = None,
                               clock=time.monotonic) -> "FraudService":
        """Arm scheduled checkpointing: after each applied event, a
        compacting :meth:`checkpoint` fires once ``every_s`` wall seconds
        have elapsed and/or ``every_windows`` snapshot windows have closed
        since the last one; ``keep_last`` additionally prunes all but the
        newest N ``ckpt-*`` directories (``prune_checkpoints``).

        Long runs stay bounded on disk: the WAL is truncated up to each
        checkpoint's ``applied_seq`` (open training-tap pins clamp the
        truncation — see ``WriteAheadLog.compact``) and old checkpoint
        directories age out.  ``clock`` is injectable for tests.  Cadence
        state is process-local: a restored service re-arms via this call
        (``serve_gateway`` does, from the gateway config)."""
        if self._wal is None:
            raise ServiceLifecycleError(
                "enable_auto_checkpoint() requires enable_wal() first")
        if every_s is None and every_windows is None:
            raise ServiceLifecycleError(
                "enable_auto_checkpoint() needs every_s and/or every_windows")
        if every_s is not None and every_s <= 0:
            raise ValueError("every_s must be > 0 or None")
        if every_windows is not None and every_windows < 1:
            raise ValueError("every_windows must be >= 1 or None")
        if keep_last is not None and keep_last < 1:
            raise ValueError("keep_last must be >= 1 or None")
        self._auto_ckpt = {
            "every_s": every_s, "every_windows": every_windows,
            "keep_last": keep_last, "clock": clock,
            "last_t": clock(),
            "last_windows": self._engine.ingester.stats["windows_closed"],
            "checkpoints": 0, "pruned": 0,
        }
        return self

    def _maybe_auto_checkpoint(self) -> None:
        """Fire the scheduled checkpoint when its cadence is due (called
        after each applied submit/ingest; never during WAL replay)."""
        ac = self._auto_ckpt
        if ac is None or self._replaying or self._wal is None:
            return
        windows = self._engine.ingester.stats["windows_closed"]
        due = (ac["every_s"] is not None
               and ac["clock"]() - ac["last_t"] >= ac["every_s"])
        due = due or (ac["every_windows"] is not None
                      and windows - ac["last_windows"] >= ac["every_windows"])
        if not due:
            return
        self.checkpoint(compact=True)
        ac["last_t"] = ac["clock"]()
        ac["last_windows"] = windows
        ac["checkpoints"] += 1
        if ac["keep_last"] is not None:
            from repro.stream import checkpoint as ckpt

            ac["pruned"] += len(
                ckpt.prune_checkpoints(self._wal_root, ac["keep_last"]))

    @classmethod
    def restore(cls, root: str) -> "FraudService":
        """Rebuild the service from WAL root ``root``: load the newest
        committed checkpoint (if any), then replay the log suffix with
        ``seq > applied_seq`` through the ordinary serving paths —
        **exactly once**: duplicate delivery is suppressed by seqno, and a
        record whose apply the crash interrupted is re-applied in full.

        The restored service keeps logging to the same WAL, so crash →
        restore → crash → restore chains compose.  Recovery details
        (checkpoint used, records replayed, responses produced during
        replay) land in ``self.last_recovery``."""
        import jax

        from repro.core.lnn import lnn_init
        from repro.models.hybrid import is_hybrid_checkpoint, load_hybrid
        from repro.stream import checkpoint as ckpt
        from repro.train.checkpoint import load_checkpoint

        config = ServiceConfig.load(os.path.join(root, "service.json"))
        with open(os.path.join(root, "genesis.json")) as f:
            genesis = json.load(f)
        # params files restore into a like-structured template
        lnn_cfg = config.to_lnn_config()
        template = lnn_init(jax.random.PRNGKey(0), lnn_cfg)

        def _load_params(path):
            if is_hybrid_checkpoint(path):
                return load_hybrid(path, template, lnn_cfg)
            return load_checkpoint(path, template)[0]

        found = ckpt.latest_checkpoint(root)
        if found is not None:
            manifest, arrays = ckpt.read_checkpoint(found)
            registry = {int(v): p for v, p in manifest["models"].items()}
            active = int(manifest["model_version"])
            applied = int(manifest["applied_seq"])
        else:
            manifest = arrays = None
            registry = {int(v): os.path.join("models", f"v{v}.npz")
                        for v in genesis["versions"]}
            active = int(genesis["model_version"])
            applied = 0

        svc = cls(config)
        svc._wal_root = root
        for v in sorted(registry):
            params = _load_params(os.path.join(root, registry[v]))
            svc.register_model(params, v)
        svc._params = svc._models[active]
        svc._model_version = active
        svc.build()
        if manifest is not None:
            ckpt.apply_checkpoint(svc, manifest, arrays)
        else:
            svc._state = genesis["state"]

        wal = ckpt.WriteAheadLog(ckpt.wal_path(root))
        svc._wal = wal
        svc._applied_seq = applied
        svc._replaying = True
        responses: list[ScoreResponse] = []
        replayed = 0
        try:
            for rec in wal.scan(after_seq=applied):
                if rec["kind"] == "model":
                    params = _load_params(os.path.join(root, rec["path"]))
                    svc.load_model(params, rec["version"])
                elif rec["kind"] == "drain":
                    responses.extend(svc.drain(rec["now"]))
                elif rec["kind"] == "submit":
                    responses.extend(svc.submit(ckpt.decode_event(rec)))
                else:
                    svc.ingest(ckpt.decode_event(rec))
                svc._applied_seq = int(rec["seq"])
                replayed += 1
        finally:
            svc._replaying = False
        svc.last_recovery = {
            "checkpoint": found,
            "applied_seq": svc._applied_seq,
            "replayed_records": replayed,
            "events_applied": svc._engine.ingester.num_events,
            "responses": responses,
        }
        return svc

    # ----------------------------------------------------------------- stats
    def _account_scored(self, results: list) -> None:
        """Count delivered scores, split per model version (only admitted
        responses were actually scored by a version's jit cache)."""
        self._acct["scored"] += len(results)
        for r in results:
            v = int(r.model_version)
            self._scores_by_version[v] = self._scores_by_version.get(v, 0) + 1

    def stats(self) -> ServiceStats:
        """One structured snapshot of the whole service.  The gateway's
        ``/v1/stats`` and ``/metrics`` are rendered from this object's
        ``to_dict()`` — every counter here is on the wire."""
        acct = self._acct
        st = ServiceStats(
            mode=self.mode, state=self._state,
            model_version=self._model_version,
            model_versions=self.model_versions(),
            model_swaps=self._model_swaps,
            requests=acct["requests"], scored=acct["scored"],
            shed=acct["shed"], blocked=acct["blocked"],
            block_timeouts=acct["block_timeouts"],
            queue_depth_peak=acct["queue_depth_peak"],
            in_flight_peak=acct["in_flight_peak"],
            scores_by_version=dict(self._scores_by_version),
            shadow=self.shadow_stats(),
            rollbacks=acct["rollbacks"],
            last_good_version=self._last_good,
        )
        if self.store is not None:
            st.store_size = len(self.store)
            st.store_stats = dict(self.store.stats)
            st.model_stale_reads = self.store.stats["model_stale_reads"]
        if self.mode == "streaming" and self._engine is not None:
            pool = self._engine.pool
            st.queue_depth = len(pool)
            st.flushes = pool.stats["flushes"]
            st.refreshes = self._engine.refresher.stats["refreshes"]
            st.entities_written = self._engine.refresher.stats["entities_written"]
            # ONE worker_summary() call: the typed field and the legacy
            # extra entry alias the same tear-free snapshot
            workers = pool.worker_summary()
            st.workers = workers
            st.extra = {"pool": dict(pool.stats), "workers": workers}
            if self._autoscaler is not None:
                st.extra["autoscaler"] = dict(self._autoscaler.stats)
        elif self._batch_layer is not None:
            st.extra = {"speed_k_max": self.config.engine.k_max}
        if self._auto_ckpt is not None:
            st.extra = dict(st.extra or {})
            st.extra["auto_checkpoint"] = {
                "checkpoints": self._auto_ckpt["checkpoints"],
                "pruned": self._auto_ckpt["pruned"]}
        return st

    # ------------------------------------------------------------- internals
    def _require_mode(self, mode: str, op: str) -> None:
        if self.mode != mode:
            raise ServiceLifecycleError(
                f"FraudService.{op}() requires mode={mode!r}; this service "
                f"runs mode={self.mode!r}")

    # quiet passthroughs the benches/tests reach for
    @property
    def engine(self):
        """The wrapped StreamingEngine (streaming mode) — internals access
        for benches and tests; scoring must go through the facade."""
        return self._engine

    def __enter__(self) -> "FraudService":
        if self._state == "created":
            self.build()
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def build_service(config: ServiceConfig, params, *,
                  warmup: bool = False) -> FraudService:
    """One-liner construction: ``build()`` (and optionally ``warmup()``)."""
    svc = FraudService(config, params=params).build()
    return svc.warmup() if warmup else svc


__all__ = ["FraudService", "ServiceLifecycleError", "build_service"]
