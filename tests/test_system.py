"""End-to-end behaviour test for the paper's system: synthetic data ->
partition -> DDS -> short LNN training -> the paper's Table-3 ordering
(LNN beats the tabular baselines on ring-structured fraud)."""
import numpy as np
import pytest

from repro.baselines import GBDTConfig, train_gbdt
from repro.core import LNNConfig
from repro.data import SynthConfig, generate_transactions, build_communities, make_split_masks
from repro.data.pipeline import standardize_features
from repro.train.loop import evaluate_lnn, train_lnn
from repro.train.metrics import binary_metrics


@pytest.mark.slow
def test_lnn_beats_tabular_baseline_on_ring_fraud():
    cfg = SynthConfig(num_users=300, num_rings=6, feature_noise=0.8, seed=0)
    g, _ = generate_transactions(cfg)
    split = make_split_masks(g.order_snapshot)
    feats, _ = standardize_features(g.order_features, split == 0)
    g.order_features = feats

    gbdt = train_gbdt(feats[split == 0], g.labels[split == 0], GBDTConfig(),
                      feats[split == 1], g.labels[split == 1])
    m_gbdt = binary_metrics(g.labels[split == 2], gbdt.predict_proba(feats[split == 2]))

    enc = np.concatenate([feats, gbdt.leaf_value_features(feats)], 1).astype(np.float32)
    mu, sd = enc[split == 0].mean(0), enc[split == 0].std(0) + 1e-6
    g.order_features = ((enc - mu) / sd).astype(np.float32)

    batches = build_communities(g, community_size=256, max_deg=24)
    lcfg = LNNConfig(gnn_type="gcn", num_gnn_layers=3, hidden_dim=64,
                     feat_dim=g.order_features.shape[1], pos_weight=3.0)
    res = train_lnn(batches, split, lcfg, epochs=25, patience=6, seed=0)
    m_lnn = evaluate_lnn(res.params, lcfg, batches, split, 2)

    # the paper's qualitative claim: graph linkage beats tabular-only
    assert m_lnn["roc_auc"] > m_gbdt["roc_auc"]
    assert m_lnn["average_precision"] > m_gbdt["average_precision"]
    assert m_lnn["roc_auc"] > 0.9
