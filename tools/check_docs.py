"""Docs honesty checker (the CI ``docs`` job).

Two guarantees over README.md + docs/*.md:

1. every intra-repo markdown link ``[text](target)`` resolves to a real
   file or directory (anchors and external http(s)/mailto links skipped);
2. every inline code reference to a repo path — ``src/repro/...``,
   ``tests/...``, ``benchmarks/...``, ``examples/...``, ``docs/...``,
   ``tools/...`` — points at an existing file, so renames can't silently
   rot the docs.  ``path::test_name`` pytest selectors are handled (the
   regex stops at the extension).

Exit code 1 with a per-file report when anything is broken.

Run:  python tools/check_docs.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
PATH_RE = re.compile(
    r"\b((?:src/repro|tests|benchmarks|examples|docs|tools)"
    r"/[\w\-./]*\.(?:py|md|yml|json))\b"
)
EXTERNAL = ("http://", "https://", "mailto:", "#")


def md_files() -> list[Path]:
    files = [ROOT / "README.md"]
    files += sorted((ROOT / "docs").glob("*.md"))
    return [f for f in files if f.exists()]


def check_file(md: Path) -> list[str]:
    errors = []
    text = md.read_text(encoding="utf-8")
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(EXTERNAL):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        # leading "/" means repo-root-relative (GitHub-style), not fs-absolute
        resolved = (ROOT / path.lstrip("/")) if path.startswith("/") else (md.parent / path)
        if not resolved.exists():
            errors.append(f"broken link -> {target}")
    for m in PATH_RE.finditer(text):
        if not (ROOT / m.group(1)).exists():
            errors.append(f"missing file reference -> {m.group(1)}")
    return sorted(set(errors))


def main() -> int:
    n_checked, failed = 0, False
    for md in md_files():
        n_checked += 1
        errors = check_file(md)
        if errors:
            failed = True
            rel = md.relative_to(ROOT)
            for e in errors:
                print(f"FAIL {rel}: {e}")
    if failed:
        return 1
    print(f"docs check OK ({n_checked} markdown files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
