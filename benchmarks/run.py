"""Benchmark harness — one section per paper table/figure + framework extras.

  table3    paper Table 3 (MLP / LGB / LNN-GAT / LNN-GCN, ROC-AUC + AP)
  latency   paper claim 3 (lambda 1-hop KV inference vs monolithic GNN)
  streaming serving-engine replay (throughput, p50/p95/p99, staleness curve)
  multiworker sharded speed-layer sweep (latency vs N, queue depth, steals)
  stage2    fused vs unfused speed-layer scoring per micro-batch bucket
  kernels   Pallas-kernel micro-bench (XLA ref timing + v5e roofline projection)
  roofline  aggregated dry-run roofline table (if dry-run records exist)

  gateway   HTTP gateway under open-loop Poisson load (429/503/canary gates)
  recovery  crash recovery (checkpoint write/restore latency, replay-suffix
            cost vs log length, bit-identical recovery gate)
  learning  continuous-learning loop on a drifting attack stream (recall
            recovery + shadow-gated promotion + auto-rollback gates)
  procpool  process-backed worker pool (inline-vs-process replay parity
            gate + N=4 vs N=1 throughput-scaling gate)

``--smoke`` runs only the serving benches (streaming + multiworker + stage2
+ gateway + recovery + learning + procpool) at tiny sizes — seconds, not minutes — then validates the emitted
``BENCH_*.json`` records against their schemas (``tools/check_bench_schema``).
That is the CI ``bench-smoke`` gate: it fails on crash or schema drift.

Prints ``name,us_per_call,derived`` CSV at the end for machine consumption.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def _streaming_rows(csv_rows, stream) -> None:
    for bs, t in stream["throughput"].items():
        csv_rows.append((f"streaming/throughput_{bs}", f"{t['us_per_event']:.1f}",
                         f"{t['events_per_s']:.0f}eps"))
    csv_rows.append(("streaming/microbatch_speedup", "",
                     f"{stream['microbatch_speedup']:.1f}x"))
    for load, pct in stream["latency"].items():
        csv_rows.append((f"streaming/{load}/p99", f"{pct['p99']*1e3:.0f}",
                         f"p50={pct['p50']:.2f}ms,p99={pct['p99']:.2f}ms"))
    for p in stream["multiworker"]["sweep"]:
        pct = p["latency_ms"]
        csv_rows.append((
            f"multiworker/n{p['num_workers']}/p99", f"{pct['p99']*1e3:.0f}",
            f"p50={pct['p50']:.2f}ms,p99={pct['p99']:.2f}ms,"
            f"steal_rate={p['steal_rate']:.3f}",
        ))
    par = stream["multiworker"]["parity"]
    csv_rows.append(("multiworker/parity", "",
                     f"bit_identical={par['bit_identical']}"))
    pb = stream["refresh_put_batch"]
    csv_rows.append(("streaming/put_batch_speedup",
                     f"{pb['put_batch_s']*1e6/max(1, pb['n']):.2f}",
                     f"{pb['speedup']:.1f}x"))
    rf = stream["refresh_scope"]
    csv_rows.append(("refresh/community_local", "",
                     f"nodes_speedup_final={rf['nodes_speedup_final']:.1f}x,"
                     f"sublinear={rf['sublinear']},"
                     f"parity={rf['parity']['bit_identical']}"))


def _hetero_rows(csv_rows, ht) -> None:
    for model, a in ht["auc"].items():
        budgets = ht["recall"][model]
        top = sorted(budgets)[0]
        csv_rows.append((
            f"hetero/{model}/auc", "",
            f"auc={a:.3f},ring@{top}={budgets[top]['ring']:.2f}",
        ))
    csv_rows.append(("hetero/gates", "",
                     ",".join(f"{k}={v}" for k, v in ht["gates"].items())))


def _stage2_rows(csv_rows, s2) -> None:
    for bs, r in s2["per_batch"].items():
        csv_rows.append((f"stage2/fused_b{bs}", f"{r['fused_us']:.1f}",
                         f"speedup={r['speedup']:.2f}x"))


def _recovery_rows(csv_rows, rec) -> None:
    ck, rs = rec["checkpoint"], rec["restore"]
    csv_rows.append(("recovery/checkpoint_write", f"{ck['write_s']*1e6:.0f}",
                     f"size={ck['size_bytes']}B"))
    csv_rows.append((
        "recovery/restore", f"{rs['with_checkpoint_s']*1e6:.0f}",
        f"replayed={rs['replayed_with_checkpoint']},"
        f"genesis_replayed={rs['replayed_genesis']},"
        f"bit_identical={rec['gates']['recovery_bit_identical']}",
    ))


def _learning_rows(csv_rows, lrn) -> None:
    csv_rows.append((
        "learning/recall_recovery", "",
        f"frozen={lrn['frozen_ring_recall']:.3f},"
        f"recovered={lrn['recovered_ring_recall']:.3f},"
        f"promotions={len(lrn['promotions'])},"
        f"rolled_back={lrn['regression']['rolled_back']}",
    ))
    csv_rows.append(("learning/gates", "",
                     ",".join(f"{k}={v}" for k, v in lrn["gates"].items())))


def _procpool_rows(csv_rows, pp) -> None:
    sc = pp["scaling"]
    for p in sc["sweep"]:
        csv_rows.append((
            f"procpool/n{p['num_workers']}",
            f"{p['wall_s']*1e6/max(1, pp['n_events']):.0f}",
            f"{p['events_per_s']:.0f}eps",
        ))
    csv_rows.append((
        "procpool/scaling", "",
        f"speedup_4v1={sc['speedup_4v1']:.2f}x,cores={sc['cores']},"
        f"limited_by_cores={sc['limited_by_cores']}",
    ))
    csv_rows.append(("procpool/gates", "",
                     ",".join(f"{k}={v}" for k, v in pp["gates"].items())))


def _gateway_rows(csv_rows, gwr) -> None:
    for name, s in gwr["scenarios"].items():
        pct = s["latency_ms"]
        csv_rows.append((
            f"gateway/{name}/p99", f"{pct['p99']*1e3:.0f}",
            f"p50={pct['p50']:.2f}ms,p99={pct['p99']:.2f}ms,"
            f"429={s['rejected_429']},503={s['rejected_503']}",
        ))
    csv_rows.append(("gateway/gates", "",
                     ",".join(f"{k}={v}" for k, v in gwr["gates"].items())))


def run_smoke() -> None:
    """The CI bench-smoke gate: serving benches at tiny sizes + schema check."""
    csv_rows = [("name", "us_per_call", "derived")]
    os.makedirs("experiments", exist_ok=True)

    # smoke records land under experiments/smoke/ (never clobbering the
    # curated full-run records); validate exactly what this run wrote
    from benchmarks.streaming_bench import main as streaming_main
    stream = streaming_main(smoke=True)   # writes BENCH_streaming + _multiworker
    _streaming_rows(csv_rows, stream)
    _hetero_rows(csv_rows, stream["hetero"])  # writes BENCH_hetero.json

    from benchmarks.stage2_bench import main as stage2_main
    s2 = stage2_main(smoke=True)          # writes BENCH_stage2.json
    _stage2_rows(csv_rows, s2)

    from benchmarks.gateway_bench import main as gateway_main
    gwr = gateway_main(smoke=True)        # writes BENCH_gateway.json
    _gateway_rows(csv_rows, gwr)

    from benchmarks.recovery_bench import main as recovery_main
    rec = recovery_main(smoke=True)       # writes BENCH_recovery.json
    _recovery_rows(csv_rows, rec)

    from benchmarks.learning_bench import main as learning_main
    lrn = learning_main(smoke=True)       # writes BENCH_learning.json
    _learning_rows(csv_rows, lrn)

    from benchmarks.procpool_bench import main as procpool_main
    pp = procpool_main(smoke=True)        # writes BENCH_procpool.json
    _procpool_rows(csv_rows, pp)

    from tools.check_bench_schema import main as schema_main
    rc = schema_main([os.path.join("experiments", "smoke", name) for name in
                      ("BENCH_streaming.json", "BENCH_stage2.json",
                       "BENCH_multiworker.json", "BENCH_refresh.json",
                       "BENCH_gateway.json", "BENCH_recovery.json",
                       "BENCH_hetero.json", "BENCH_learning.json",
                       "BENCH_procpool.json")])
    if rc != 0:
        raise SystemExit(rc)

    print("\n# CSV")
    for row in csv_rows:
        print(",".join(str(c) for c in row))


def run_full() -> None:
    csv_rows = [("name", "us_per_call", "derived")]
    os.makedirs("experiments", exist_ok=True)

    from benchmarks.table3 import main as table3_main
    seeds = (0, 1, 2) if os.environ.get("BENCH_FULL") else (0, 1)
    table = table3_main(seeds=seeds)
    json.dump(table, open("experiments/table3.json", "w"), indent=1)
    for name, r in table.items():
        csv_rows.append((f"table3/{name.replace(' ', '')}/auc",
                         f"{r['train_seconds']*1e6:.0f}", f"{r['roc_auc_mean']:.4f}"))
        csv_rows.append((f"table3/{name.replace(' ', '')}/ap",
                         f"{r['train_seconds']*1e6:.0f}", f"{r['ap_mean']:.4f}"))

    from benchmarks.latency import main as latency_main
    lat = latency_main()
    json.dump(lat, open("experiments/latency.json", "w"), indent=1)
    csv_rows.append(("latency/lambda_single", f"{lat['lambda_ms_per_request']*1e3:.1f}",
                     f"speedup={lat['speedup_single']:.1f}x"))
    csv_rows.append(("latency/lambda_batched", f"{lat['lambda_batched_ms_per_request']*1e3:.1f}",
                     f"speedup={lat['speedup_batched']:.1f}x"))
    csv_rows.append(("latency/monolithic", f"{lat['monolithic_ms_per_request']*1e3:.1f}", ""))

    from benchmarks.streaming_bench import main as streaming_main
    stream = streaming_main()   # writes BENCH_streaming + BENCH_multiworker
    _streaming_rows(csv_rows, stream)
    _hetero_rows(csv_rows, stream["hetero"])  # writes BENCH_hetero.json

    from benchmarks.stage2_bench import main as stage2_main
    s2 = stage2_main()   # writes experiments/BENCH_stage2.json
    _stage2_rows(csv_rows, s2)

    from benchmarks.gateway_bench import main as gateway_main
    gwr = gateway_main()   # writes experiments/BENCH_gateway.json
    _gateway_rows(csv_rows, gwr)

    from benchmarks.recovery_bench import main as recovery_main
    rec = recovery_main()   # writes experiments/BENCH_recovery.json
    _recovery_rows(csv_rows, rec)

    from benchmarks.learning_bench import main as learning_main
    lrn = learning_main()   # writes experiments/BENCH_learning.json
    _learning_rows(csv_rows, lrn)

    from benchmarks.procpool_bench import main as procpool_main
    pp = procpool_main()   # writes experiments/BENCH_procpool.json
    _procpool_rows(csv_rows, pp)

    from benchmarks.kernels_bench import main as kernels_main
    ker = kernels_main()
    json.dump(ker, open("experiments/kernels.json", "w"), indent=1)
    for r in ker:
        csv_rows.append((f"kernel/{r['name']}", f"{r['us_per_call_cpu_xla']:.1f}",
                         f"v5e_roofline_us={r['v5e_roofline_us']:.2f}"))

    from benchmarks.roofline_table import load_records
    recs = load_records("single")
    ok = [r for r in recs if r.get("status") == "ok"]
    if ok:
        print(f"\n# Roofline: {len(ok)} dry-run records (see EXPERIMENTS.md §Roofline)")
        for r in ok[:5]:
            csv_rows.append((f"roofline/{r['arch']}/{r['shape']}",
                             f"{max(r['t_compute'], r['t_memory'], r['t_collective'])*1e6:.0f}",
                             r["bottleneck"]))

    print("\n# CSV")
    for row in csv_rows:
        print(",".join(str(c) for c in row))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="serving benches only, tiny sizes, schema-checked "
                         "(the CI bench-smoke gate)")
    if ap.parse_args().smoke:
        run_smoke()
    else:
        run_full()


if __name__ == '__main__':
    main()
