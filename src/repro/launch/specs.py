"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

``input_specs(cfg, shape)`` returns the abstract arguments the step function
for that input-shape kind consumes:

  train    -> {'tokens', 'labels', [vision|frames]}
  prefill  -> {'tokens', [vision|frames]}
  decode   -> {'token', 'cache'}   (cache built via jax.eval_shape)

Modality frontends are stubs per the assignment: VLM vision tokens and audio
frames arrive as precomputed d_model embeddings.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig, InputShape
from repro.models.transformer import init_cache

SDS = jax.ShapeDtypeStruct

# frontend stub sizes
AUDIO_FRAMES_TRAIN = 4096        # ~80s of 20ms frames
AUDIO_FRAMES_SERVE = 4096


def _extras_spec(cfg: ArchConfig, batch: int, seq: int):
    dtype = jnp.dtype(cfg.dtype)
    out = {}
    if cfg.arch_type == "vlm":
        out["vision"] = SDS((batch, cfg.num_vision_tokens, cfg.d_model), dtype)
    if cfg.arch_type == "audio":
        out["frames"] = SDS((batch, min(seq, AUDIO_FRAMES_TRAIN), cfg.d_model), dtype)
    return out


def input_specs(cfg: ArchConfig, shape: InputShape) -> dict:
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        batch = {
            "tokens": SDS((b, s), jnp.int32),
            "labels": SDS((b, s), jnp.int32),
        }
        batch.update(_extras_spec(cfg, b, s))
        return {"batch": batch}
    if shape.kind == "prefill":
        batch = {"tokens": SDS((b, s), jnp.int32)}
        batch.update(_extras_spec(cfg, b, s))
        return {"batch": batch}
    if shape.kind == "decode":
        extra_shapes = {}
        if cfg.arch_type == "vlm":
            extra_shapes["vision_len"] = cfg.num_vision_tokens
        if cfg.arch_type == "audio":
            extra_shapes["memory_len"] = AUDIO_FRAMES_SERVE
        cache = jax.eval_shape(lambda: init_cache(cfg, b, s, extra_shapes))
        return {"token": SDS((b,), jnp.int32), "cache": cache}
    raise ValueError(shape.kind)


def supports_shape(cfg: ArchConfig, shape: InputShape) -> tuple[bool, str]:
    """long_500k requires sub-quadratic attention (DESIGN.md skip table)."""
    if shape.name != "long_500k":
        return True, ""
    if cfg.arch_type in ("ssm", "hybrid"):
        return True, ""
    if cfg.window is not None:
        return True, ""   # sliding-window bounds decode work
    return False, (
        f"{cfg.name}: pure full attention — long_500k skipped per DESIGN.md "
        "(no sub-quadratic variant in the baseline)"
    )
