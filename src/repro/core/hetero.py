"""Typed entity keys — the heterogeneous-graph id scheme.

The DDS graph (``core/dds.py``) and every layer above it identify an
entity by a single int64.  Heterogeneous graphs (buyer / merchant /
device / payment nodes, BRIGHT-style) need the *type* to travel with the
id — through the KV store, the WAL, checkpoints, and the shard router —
without changing any wire format.  The scheme is a high-bit tag:

::

    tagged = (type_code + 1) << 40  |  raw_id        (raw_id < 2**40)

* the ``+1`` keeps the all-zero high bits meaning "untagged", so a legacy
  (homogeneous) id is *detectably* untyped — ``KVStore`` configured
  heterogeneous rejects it loudly instead of silently sharding buyer and
  device ids into one keyspace;
* the tagged id still fits ``pack_key``'s 43-bit entity field
  (``MAX_ENTITY = 2**43 - 1``), so packed KV keys, WAL event records
  (plain JSON ints), and checkpoint arrays (int64) all round-trip tagged
  ids bit-exactly with no format change;
* a tagged id is an ordinary int everywhere else — union-find
  communities, rendezvous sharding, and the incremental DDS builder are
  id-agnostic.

``ENTITY_TYPE_NAMES`` is the canonical vocabulary used by
:class:`~repro.core.lnn.LNNConfig` per-type towers and the attack
workload (``repro/data/attacks.py``); the scheme itself supports up to 7
type codes.  See ``docs/graphs.md`` for the schema.
"""
from __future__ import annotations

import numpy as np

#: canonical heterogeneous vocabulary (index = type code)
ENTITY_TYPE_NAMES = ("buyer", "merchant", "device", "payment")

#: bit position of the type tag inside an entity id
TYPE_SHIFT = 40

#: mask of the raw (untyped) id bits
RAW_ID_MASK = (1 << TYPE_SHIFT) - 1

#: largest type code the tag field can carry (tag 0 means "untagged")
MAX_TYPE_CODE = 6


def tag_entity(raw_id: int, type_code: int) -> int:
    """Tag ``raw_id`` with ``type_code`` (index into the type vocabulary).

    Raises ``ValueError`` when the raw id or code is out of range — a
    tagged id must still fit the KV store's 43-bit entity field.
    """
    raw_id, type_code = int(raw_id), int(type_code)
    if not 0 <= raw_id <= RAW_ID_MASK:
        raise ValueError(f"raw entity id {raw_id} out of [0, 2**{TYPE_SHIFT})")
    if not 0 <= type_code <= MAX_TYPE_CODE:
        raise ValueError(f"entity type code {type_code} out of "
                         f"[0, {MAX_TYPE_CODE}]")
    return ((type_code + 1) << TYPE_SHIFT) | raw_id


def is_typed(entity_id: int) -> bool:
    """True when ``entity_id`` carries a type tag (high bits nonzero)."""
    return (int(entity_id) >> TYPE_SHIFT) != 0


def type_code_of(entity_id: int) -> int:
    """Type code of a tagged id; ``-1`` for an untagged (legacy) id."""
    return (int(entity_id) >> TYPE_SHIFT) - 1


def entity_type_of(entity_id: int) -> str | None:
    """Type *name* of a tagged id (``None`` untagged; raises on a code
    outside :data:`ENTITY_TYPE_NAMES` — an id from a different vocabulary)."""
    code = type_code_of(entity_id)
    if code < 0:
        return None
    if code >= len(ENTITY_TYPE_NAMES):
        raise ValueError(
            f"entity id {entity_id} carries type code {code}, outside the "
            f"canonical vocabulary {ENTITY_TYPE_NAMES}")
    return ENTITY_TYPE_NAMES[code]


def strip_type(entity_id: int) -> int:
    """The raw id with the type tag removed."""
    return int(entity_id) & RAW_ID_MASK


def type_codes_array(entity_ids: np.ndarray) -> np.ndarray:
    """Vectorized :func:`type_code_of`: int32 codes, ``-1`` per untagged id."""
    e = np.asarray(entity_ids, np.int64)
    return ((e >> TYPE_SHIFT) - 1).astype(np.int32)


__all__ = [
    "ENTITY_TYPE_NAMES", "TYPE_SHIFT", "RAW_ID_MASK", "MAX_TYPE_CODE",
    "tag_entity", "is_typed", "type_code_of", "entity_type_of",
    "strip_type", "type_codes_array",
]
