"""Streaming serving engine — the closed Lambda loop.

Per checkout event:

  event ──> StreamIngester ──────────────┐ (extends DDS graph, dirty marks)
        │        │ window closed?        │
        │        └─> RefreshDriver ──────┤ (stage 1 on closed windows,
        │                                │  per-shard versioned KV puts)
        └─> entity keys ─> ShardRouter ──┴─> SpeedLayerWorker[i] ─> score
                              (key-affine fan-out, N micro-batch queues,
                               reorder buffer reassembles event order)

Scoring is exact with respect to the paper's monolithic forward: when the
refresh driver runs every closed window, each request's ``(entity, t_e)``
keys hit embeddings whose in-neighborhoods were final at refresh time, so
micro-batched speed-layer scores equal ``lnn_forward`` on the full graph
(stage-equivalence test in ``tests/test_stream.py``).  Lower refresh rates
trade exactness for batch-layer cost; the KV fallback then serves older
snapshots and reports staleness per request.

The engine is a thin façade over :class:`~repro.stream.workers.WorkerPool`:
``num_workers=1`` (default) is behaviorally identical to the original
single-queue engine, ``num_workers=N`` shards the micro-batch queue across
N key-affine workers with private jit caches and work stealing — and the
replayed scores stay bit-identical for any N (replay-parity test).

The engine runs a deterministic discrete-event simulation of an N-server
queue: *virtual* arrival times drive flush triggers and the per-flush
virtual service model, *real* wall time is measured for each jitted flush,
and per-request latency = queue wait + service — so benchmark numbers are
reproducible yet reflect true compute cost.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field

import numpy as np

from repro.core.lnn import LNNConfig
from repro.serve.kvstore import KVStore
from repro.stream.events import CheckoutEvent
from repro.stream.ingest import StreamIngester
from repro.stream.microbatch import ScoredResult, ScoreRequest
from repro.stream.refresh import RefreshDriver
from repro.stream.workers import WorkerPool


def _stage1_params(params):
    """The LNN pytree driving batch-layer refreshes: hybrid models carry it
    under ``.lnn_params`` (the booster only replaces online stage 2)."""
    from repro.models.hybrid import HybridModel

    return params.lnn_params if isinstance(params, HybridModel) else params


@dataclass
class EngineConfig:
    """Knobs for :class:`StreamingEngine` — micro-batching, refresh cadence,
    DDS history, KV store sizing/sharding, and the multi-worker speed layer.
    ``FraudService`` builds one from ``ServiceConfig.to_engine_config()``."""

    k_max: int = 8                  # entity slots per request
    max_batch: int = 16             # micro-batch size trigger (per worker)
    max_wait_s: float = 0.005       # micro-batch deadline trigger (virtual s)
    refresh_every: int = 1          # batch-layer cadence, in closed windows
    community_local: bool = True    # refresh only dirty communities (exact)
    community_size: int = 4096      # node budget per stage-1 refresh launch
    entity_history: str = "all"     # DDS history mode (see core.dds)
    max_history: int | None = 8
    max_deg: int = 32               # padded in-degree for the batch graph
    async_refresh: bool = False     # stage 1 on a background thread
    store_capacity: int | None = None    # KV LRU cap (None = unbounded)
    store_ttl_s: float | None = None     # KV TTL (None = no expiry)
    store_shards: int = 4
    # ------------------------------------------------- multi-worker speed layer
    num_workers: int = 1            # sharded micro-batch queues (1 = classic)
    service_model_s: float = 0.0    # virtual service time per flush (0 = instant)
    steal_threshold: int | None = None   # queue depth that triggers stealing
    # None = auto: entity-affine KV shards (num_shards == num_workers) when
    # num_workers > 1, classic key-spread shards otherwise
    shard_by_entity: bool | None = None
    # "inline" = workers simulated in-process (classic); "process" = each
    # worker is an OS process owning its KV shard and jit cache, scheduling
    # stays in the parent (repro.stream.procpool) — replay bit-identical
    backend: str = "inline"


class StreamingEngine:
    """The closed Lambda loop over a live event stream.

    ``submit(event)`` ingests one :class:`CheckoutEvent` (growing the
    incremental DDS, triggering batch-layer refreshes on window close) and
    returns whatever :class:`ScoredResult` lists completed by the event's
    arrival — in submission order, reassembled by the pool's reorder
    buffer; ``flush()`` force-drains every worker queue and
    ``replay(events)`` drives a whole stream and returns a
    :class:`ReplayReport`.

    Per micro-batch flush a worker makes one versioned KV multi-get and ONE
    jitted stage-2 dispatch (``lnn_stage2_online`` — the fused
    ``kernels.stage2_score`` Pallas launch when ``cfg.use_pallas``); the
    order tower is folded into that call, so the hot path is a single
    fixed-shape kernel per flush, per worker, each worker with its own jit
    cache.
    """

    def __init__(self, params, cfg: LNNConfig, engine_cfg: EngineConfig | None = None,
                 store: KVStore | None = None, _via_service: bool = False):
        if not _via_service:
            # direct construction is the legacy entry point; the facade
            # (repro.service.FraudService, mode="streaming") wraps this
            # engine bit-identically and adds lifecycle/hot-swap/admission
            warnings.warn(
                "constructing StreamingEngine directly is deprecated; use "
                "repro.service.FraudService(mode='streaming') — see "
                "docs/serving_api.md",
                DeprecationWarning, stacklevel=2,
            )
        self.params = params
        self.cfg = cfg
        self.model_version = 0
        self.ecfg = engine_cfg or EngineConfig()
        backend = self.ecfg.backend
        if backend not in ("inline", "process"):
            raise ValueError(
                f"unknown workers backend {backend!r} (inline | process)")
        by_entity = self.ecfg.shard_by_entity
        if by_entity is None:
            by_entity = self.ecfg.num_workers > 1
        store_kwargs = dict(
            capacity=self.ecfg.store_capacity,
            ttl_seconds=self.ecfg.store_ttl_s,
            # entity-affine mode: one KV shard per worker, placed by the
            # same rendezvous hash the router uses (key-affinity)
            num_shards=(self.ecfg.num_workers if by_entity
                        else self.ecfg.store_shards),
            shard_by_entity=by_entity,
            # heterogeneous model => every entity id must carry a type tag;
            # an untagged id in a typed deployment is a caller bug the
            # store rejects loudly (core.hetero.tag_entity)
            require_typed=bool(cfg.entity_types),
        )
        self.ingester = StreamIngester(
            cfg.feat_dim,
            entity_history=self.ecfg.entity_history,
            max_history=self.ecfg.max_history,
        )
        if backend == "process":
            if store is not None:
                raise ValueError(
                    "backend='process' owns its KV shards inside the worker "
                    "processes — an injected store cannot be used")
            from repro.stream.procpool import ProcessWorkerPool

            self.pool = ProcessWorkerPool(
                params, cfg, dict(dim=cfg.hidden_dim, **store_kwargs),
                num_workers=self.ecfg.num_workers,
                k_max=self.ecfg.k_max,
                max_batch=self.ecfg.max_batch,
                max_wait_s=self.ecfg.max_wait_s,
                service_model_s=self.ecfg.service_model_s,
                steal_threshold=self.ecfg.steal_threshold,
            )
            # the parent-side facade over the children's shards: same read/
            # write/checkpoint surface as the inline KVStore
            self.store = self.pool.store
        else:
            self.store = store or KVStore(cfg.hidden_dim, **store_kwargs)
            self.pool = WorkerPool(
                params, cfg, self.store,
                num_workers=self.ecfg.num_workers,
                k_max=self.ecfg.k_max,
                max_batch=self.ecfg.max_batch,
                max_wait_s=self.ecfg.max_wait_s,
                service_model_s=self.ecfg.service_model_s,
                steal_threshold=self.ecfg.steal_threshold,
            )
        self.refresher = RefreshDriver(
            _stage1_params(params), cfg, self.store, self.ingester,
            max_deg=self.ecfg.max_deg,
            refresh_every=self.ecfg.refresh_every,
            async_mode=self.ecfg.async_refresh,
            router=self.pool.router,
            community_local=self.ecfg.community_local,
            community_size=self.ecfg.community_size,
            # process backend: padded stage-1 bins compute in the shard
            # processes, off the serving GIL (bit-identical outputs)
            stage1_executor=(self.pool.refresh_bins
                             if backend == "process" else None),
        )

    # ------------------------------------------------------------- speed layer
    def _score_batch(self, feats: np.ndarray, entity_t_lists: list):
        """[B, F] features + per-row (entity, t_e) lists -> (probs, staleness).

        Worker 0's scorer — one KV multi-get (with snapshot fallback) and
        one jitted stage-2 call, the checkout-approval hot path.  Kept as
        the direct entry the benches and parity tests drive (the scorer's
        model-version stamp is dropped here; results carry it)."""
        probs, staleness, _ = self.pool.workers[0].scorer(feats, entity_t_lists)
        return probs, staleness

    def warmup(self):
        """Compile every micro-batch bucket shape on every worker up front
        (cold-start off the measured path).  Buckets are the pow2 sizes
        floored at 2 and capped at max_batch — exactly what
        ``bucket_size`` can produce."""
        self.pool.warmup()

    # --------------------------------------------------------------- hot-swap
    def load_model(self, params, version: int | None = None) -> int:
        """Versioned model hot-swap: register ``params`` as the active
        version on every speed-layer worker AND the refresh driver.
        In-flight flushes finish on the jit cache they captured at entry;
        every subsequent flush scores under the new version; subsequent
        batch-layer puts are stamped with it (so reads of pre-swap
        embeddings are detectable via ``store.stats['model_stale_reads']``).
        ``params`` may be an ``lnn_init`` pytree or a
        :class:`~repro.models.hybrid.HybridModel` (the refresh driver then
        runs stage 1 with the hybrid's frozen LNN leaves).
        Returns the version activated (default: current + 1)."""
        if version is None:
            version = self.model_version + 1
        self.params = params
        self.model_version = int(version)
        self.pool.set_model(params, self.model_version)
        self.refresher.set_model(_stage1_params(params), self.model_version)
        return self.model_version

    # ----------------------------------------------------------------- events
    def ingest(self, event: CheckoutEvent) -> ScoreRequest:
        """The ingest half of ``submit``: advance the virtual clock is NOT
        done here — callers poll first.  Extends the DDS, fires the refresh
        hook on window close, and returns the typed request ready for the
        pool (the facade's admission controller sits between this and
        ``pool.submit``)."""
        ing = self.ingester.ingest(event)
        if ing.closed_window is not None:
            self.refresher.on_windows_closed(ing.closed_window)
        return ScoreRequest(
            features=np.asarray(event.features, np.float32),
            entity_keys=ing.entity_keys,
            arrival=event.arrival,
            tag=event,
        )

    def submit(self, event: CheckoutEvent) -> list[ScoredResult]:
        """Ingest one event and return any requests whose flush completed by
        its arrival (deadline flushes for older queued requests fire first,
        then work stealing, then this event's own size trigger)."""
        out = self.pool.poll(event.arrival)
        req = self.ingest(event)
        out.extend(self.pool.submit(req, event.arrival))
        return out

    def flush(self, now: float | None = None) -> list[ScoredResult]:
        """Force-drain every worker queue (stream end).  Without an explicit
        ``now`` each residual batch is stamped at its own queue's deadline —
        it would have flushed then anyway, so recorded queue waits match
        the timer semantics instead of collapsing to zero."""
        self.refresher.drain()
        return self.pool.flush(now)

    # ------------------------------------------------------------------ replay
    def replay(self, events, warmup: bool = True) -> "ReplayReport":
        """Drive a whole event stream through ingest -> refresh -> score."""
        if warmup:
            self.warmup()
        results: list[ScoredResult] = []
        for ev in events:
            results.extend(self.submit(ev))
        results.extend(self.flush())
        self.refresher.drain()
        return ReplayReport(results=results, engine=self)

    # --------------------------------------------------------------- lifecycle
    def close(self) -> None:
        """Release backend resources: joins outstanding refreshes and stops
        the worker processes (a no-op for the inline backend)."""
        self.refresher.drain()
        self.pool.shutdown()


@dataclass
class ReplayReport:
    """Outcome of one full stream replay: the admitted per-request results
    plus the engine they ran on, with latency / score / staleness views."""

    results: list
    engine: StreamingEngine
    _lat: np.ndarray | None = field(default=None, repr=False)

    def latencies_s(self) -> np.ndarray:
        """Per-request latency: virtual queue wait + measured service time."""
        if self._lat is None:
            self._lat = np.asarray(
                [r.queued_s + r.service_s for r in self.results], np.float64
            )
        return self._lat

    def percentiles_ms(self) -> dict:
        """p50/p95/p99 + mean, all from the one cached latency pass —
        ``summary`` reads this dict instead of recomputing percentiles and
        the mean through separate paths."""
        lat = self.latencies_s() * 1e3
        if lat.size == 0:
            return {"p50": 0.0, "p95": 0.0, "p99": 0.0, "mean": 0.0}
        p50, p95, p99 = np.percentile(lat, (50, 95, 99))
        return {"p50": float(p50), "p95": float(p95), "p99": float(p99),
                "mean": float(lat.mean())}

    def scores_by_order(self) -> dict:
        return {r.request.tag.order_id: r.score for r in self.results}

    def staleness_summary(self) -> dict:
        s = np.asarray([r.staleness for r in self.results])
        served = s[s >= 0]
        return {
            "mean": float(served.mean()) if served.size else 0.0,
            "max": int(served.max()) if served.size else 0,
            "stale_frac": float((served > 0).mean()) if served.size else 0.0,
        }

    def summary(self) -> dict:
        eng = self.engine
        # ONE latency pass: percentiles_ms() carries the mean too, so the
        # old second walk over latencies_s() for mean_latency_ms is gone
        pct = self.percentiles_ms()
        pool = eng.pool.stats
        service = float(np.mean([r.service_s for r in self.results])) \
            if self.results else 0.0
        return {
            "events": eng.ingester.num_events,
            "scored": len(self.results),
            "num_workers": eng.pool.num_workers,
            "flushes": pool["flushes"],
            "size_flushes": pool["size_flushes"],
            "deadline_flushes": pool["deadline_flushes"],
            "steals": pool["steals"],
            "stolen_requests": pool["stolen_requests"],
            "mean_batch": float(np.mean([r.batch_size for r in self.results]))
            if self.results else 0.0,
            "latency_ms": pct,
            "mean_service_ms": service * 1e3,
            "staleness": self.staleness_summary(),
            "refreshes": eng.refresher.stats["refreshes"],
            "entities_written": eng.refresher.stats["entities_written"],
            "store_size": len(eng.store),
            "store_stats": dict(eng.store.stats),
            "mean_latency_ms": pct["mean"],
            "workers": eng.pool.worker_summary(),
        }
