"""Evaluation metrics for the paper's Table 3: ROC AUC and Average Precision.

Pure numpy, no sklearn dependency.  Semantics match
``sklearn.metrics.roc_auc_score`` and ``sklearn.metrics.average_precision_score``
(step-wise AP, not interpolated), which is what the paper reports.
"""
from __future__ import annotations

import numpy as np


def _validate(y_true: np.ndarray, y_score: np.ndarray):
    y_true = np.asarray(y_true).ravel().astype(np.int64)
    y_score = np.asarray(y_score).ravel().astype(np.float64)
    if y_true.shape != y_score.shape:
        raise ValueError(f"shape mismatch {y_true.shape} vs {y_score.shape}")
    if y_true.size == 0:
        raise ValueError("empty inputs")
    pos = int(y_true.sum())
    if pos == 0 or pos == y_true.size:
        raise ValueError("need both classes present")
    return y_true, y_score


def roc_auc(y_true: np.ndarray, y_score: np.ndarray) -> float:
    """ROC AUC via the Mann-Whitney U statistic with tie correction."""
    y_true, y_score = _validate(y_true, y_score)
    # rank scores (average rank for ties)
    order = np.argsort(y_score, kind="mergesort")
    ranks = np.empty_like(order, dtype=np.float64)
    sorted_scores = y_score[order]
    # average ranks over tie groups
    n = y_score.size
    i = 0
    while i < n:
        j = i
        while j + 1 < n and sorted_scores[j + 1] == sorted_scores[i]:
            j += 1
        ranks[order[i : j + 1]] = 0.5 * (i + j) + 1.0  # 1-based average rank
        i = j + 1
    n_pos = float(y_true.sum())
    n_neg = float(n - n_pos)
    rank_sum_pos = float(ranks[y_true == 1].sum())
    u = rank_sum_pos - n_pos * (n_pos + 1.0) / 2.0
    return u / (n_pos * n_neg)


def average_precision(y_true: np.ndarray, y_score: np.ndarray) -> float:
    """Average precision (area under precision-recall, step interpolation).

    AP = sum_k (R_k - R_{k-1}) * P_k over descending-score thresholds,
    with ties handled by treating equal scores as one threshold.
    """
    y_true, y_score = _validate(y_true, y_score)
    desc = np.argsort(-y_score, kind="mergesort")
    y_sorted = y_true[desc]
    scores_sorted = y_score[desc]
    # cumulative true positives / predicted positives
    tp = np.cumsum(y_sorted)
    fp = np.cumsum(1 - y_sorted)
    # threshold boundaries: last index of each tie group
    distinct = np.where(np.diff(scores_sorted))[0]
    idx = np.concatenate([distinct, [y_sorted.size - 1]])
    tp_at = tp[idx].astype(np.float64)
    fp_at = fp[idx].astype(np.float64)
    precision = tp_at / (tp_at + fp_at)
    recall = tp_at / float(y_true.sum())
    # prepend recall 0
    recall_prev = np.concatenate([[0.0], recall[:-1]])
    return float(np.sum((recall - recall_prev) * precision))


def binary_metrics(y_true: np.ndarray, y_score: np.ndarray) -> dict:
    return {
        "roc_auc": roc_auc(y_true, y_score),
        "average_precision": average_precision(y_true, y_score),
    }
