"""Graph partition — paper §3.2 'Graph Partition'.

The paper partitions the months-long static transaction graph with
Power Iteration Clustering (PIC, Lin & Cohen 2010 — expected partition size
~1e6) and then refines with METIS (Karypis & Kumar) to communities of ~1024
nodes ("the business understanding for a gang of fraudsters"), training in
ClusterGCN flavor on the mini-communities.

Here both stages are implemented directly (no Spark / metis binding):

* ``power_iteration_clustering`` — the PIC algorithm on the normalized
  affinity matrix of the *order-entity bipartite* graph projected to a
  symmetric adjacency; early-stops on the acceleration criterion from the
  paper and 1-D k-means clusters the resulting pseudo-eigenvector.
* ``refine_partition`` — METIS-style size-balanced refinement: connected
  components inside each PIC cluster, then BFS-grown chunks capped at the
  target community size (greedy multilevel coarsening is overkill at our
  synthetic scale; BFS growth preserves locality, which is what ClusterGCN
  needs).
"""
from __future__ import annotations

import numpy as np


def _csr_from_edges(num_nodes: int, src: np.ndarray, dst: np.ndarray):
    """Symmetric CSR adjacency (indices only) from an undirected edge list."""
    s = np.concatenate([src, dst])
    d = np.concatenate([dst, src])
    order = np.argsort(s, kind="stable")
    s, d = s[order], d[order]
    indptr = np.zeros(num_nodes + 1, np.int64)
    np.add.at(indptr, s + 1, 1)
    indptr = np.cumsum(indptr)
    return indptr, d


def power_iteration_clustering(
    num_nodes: int,
    src: np.ndarray,
    dst: np.ndarray,
    num_clusters: int,
    max_iter: int = 50,
    tol: float = 1e-5,
    seed: int = 0,
) -> np.ndarray:
    """PIC (Lin & Cohen 2010): truncated power iteration of W = D^-1 A.

    Returns an int cluster id per node.  Isolated nodes go to cluster 0.
    """
    indptr, indices = _csr_from_edges(num_nodes, src, dst)
    deg = np.diff(indptr).astype(np.float64)
    inv_deg = np.where(deg > 0, 1.0 / np.maximum(deg, 1), 0.0)

    rng = np.random.default_rng(seed)
    v = rng.uniform(0.0, 1.0, num_nodes)
    v /= np.abs(v).sum()

    prev_delta = None
    for _ in range(max_iter):
        # v_new = D^-1 A v  (row-normalized affinity)
        acc = np.zeros(num_nodes)
        # segment sum: acc[i] = sum_j in nbr(i) v[j]
        np.add.at(acc, np.repeat(np.arange(num_nodes), np.diff(indptr)), v[indices])
        v_new = acc * inv_deg
        norm = np.abs(v_new).sum()
        if norm == 0:
            break
        v_new /= norm
        delta = np.abs(v_new - v).max()
        v = v_new
        # acceleration-based early stop (Lin & Cohen §3)
        if prev_delta is not None and abs(prev_delta - delta) < tol / num_nodes:
            break
        prev_delta = delta

    return _kmeans_1d(v, num_clusters, seed=seed)


def _kmeans_1d(x: np.ndarray, k: int, iters: int = 50, seed: int = 0) -> np.ndarray:
    """1-D k-means on the PIC pseudo-eigenvector (exact assignment step)."""
    k = max(1, min(k, np.unique(x).size))
    # init centers at quantiles — deterministic and robust for 1-D
    centers = np.quantile(x, np.linspace(0, 1, k))
    for _ in range(iters):
        assign = np.argmin(np.abs(x[:, None] - centers[None, :]), axis=1)
        new_centers = centers.copy()
        for c in range(k):
            m = assign == c
            if m.any():
                new_centers[c] = x[m].mean()
        if np.allclose(new_centers, centers):
            break
        centers = new_centers
    return np.argmin(np.abs(x[:, None] - centers[None, :]), axis=1).astype(np.int32)


def _connected_components(nodes: np.ndarray, indptr, indices) -> list:
    """Connected components restricted to ``nodes`` (BFS)."""
    nodeset = set(nodes.tolist())
    seen = set()
    comps = []
    for start in nodes.tolist():
        if start in seen:
            continue
        comp = []
        stack = [start]
        seen.add(start)
        while stack:
            u = stack.pop()
            comp.append(u)
            for w in indices[indptr[u] : indptr[u + 1]].tolist():
                if w in nodeset and w not in seen:
                    seen.add(w)
                    stack.append(w)
        comps.append(np.asarray(comp, np.int64))
    return comps


def refine_partition(
    num_nodes: int,
    src: np.ndarray,
    dst: np.ndarray,
    coarse: np.ndarray,
    target_size: int = 1024,
) -> np.ndarray:
    """METIS-style refinement: split each coarse cluster into connected,
    BFS-local chunks of at most ``target_size`` nodes; merge tiny chunks
    greedily up to the target.  Returns a community id per node.
    """
    indptr, indices = _csr_from_edges(num_nodes, src, dst)
    community = np.full(num_nodes, -1, np.int64)
    next_id = 0
    for c in np.unique(coarse):
        nodes = np.nonzero(coarse == c)[0]
        pending: list[np.ndarray] = []
        for comp in _connected_components(nodes, indptr, indices):
            if comp.size <= target_size:
                pending.append(comp)
                continue
            # BFS-grow chunks of target_size to keep locality
            compset = set(comp.tolist())
            seen: set = set()
            for s0 in comp.tolist():
                if s0 in seen:
                    continue
                chunk = []
                queue = [s0]
                seen.add(s0)
                while queue and len(chunk) < target_size:
                    u = queue.pop(0)
                    chunk.append(u)
                    for w in indices[indptr[u] : indptr[u + 1]].tolist():
                        if w in compset and w not in seen:
                            seen.add(w)
                            queue.append(w)
                # anything left in queue returns to the pool via outer loop
                for leftover in queue:
                    seen.discard(leftover)
                pending.append(np.asarray(chunk, np.int64))
        # greedy first-fit merge of small chunks
        pending.sort(key=len, reverse=True)
        merged: list[list] = []
        for chunk in pending:
            placed = False
            for m in merged:
                if len(m) + chunk.size <= target_size:
                    m.extend(chunk.tolist())
                    placed = True
                    break
            if not placed:
                merged.append(chunk.tolist())
        for m in merged:
            community[np.asarray(m, np.int64)] = next_id
            next_id += 1
    # isolated / untouched nodes -> own community buckets of target_size
    rest = np.nonzero(community < 0)[0]
    for i in range(0, rest.size, target_size):
        community[rest[i : i + target_size]] = next_id
        next_id += 1
    return community


# ---------------------------------------------------------------------------
# Streaming refresh communities (connected components, exact)
# ---------------------------------------------------------------------------
#
# The training-time pipeline above (PIC + METIS-style refinement) may CUT
# edges when it caps community size — fine for ClusterGCN mini-batching,
# fatal for the batch-layer's community-local refresh, where a community must
# contain the *entire* GNN receptive field of every node it owns so that
# stage-1 embeddings computed per community are bit-identical to the
# whole-graph run.  Refresh communities are therefore the connected
# components of the order↔entity bipartite graph: no DDS edge ever crosses a
# component (orders link only their own entities; entity-history edges stay
# within one entity), so a component is closed under in-neighborhoods at any
# GNN depth.  Components are labeled canonically by their smallest entity id,
# which makes the incremental assignment comparable against the batch one at
# every stream prefix.


def entity_communities(num_entities: int, edges: np.ndarray) -> np.ndarray:
    """Batch oracle: connected-component community id per entity of the
    accumulated bipartite order↔entity graph.

    ``edges`` is the StaticGraph [E, 2] (order, entity) array.  Returns an
    int64 array of length ``num_entities``: the smallest entity id in each
    entity's component (an entity linked to no order is its own singleton
    community).  ``IncrementalPartitioner.assignment()`` must match this on
    the accumulated transactions at any prefix (property-tested).
    """
    community = np.arange(num_entities, dtype=np.int64)
    if edges.size == 0 or num_entities == 0:
        return community
    # union entities that share an order: group edge list by order id
    order_ids = edges[:, 0].astype(np.int64)
    ent_ids = edges[:, 1].astype(np.int64)
    sort = np.argsort(order_ids, kind="stable")
    order_s, ent_s = order_ids[sort], ent_ids[sort]
    parent = np.arange(num_entities, dtype=np.int64)

    def find(x: int) -> int:
        root = x
        while parent[root] != root:
            root = parent[root]
        while parent[x] != root:            # path compression
            parent[x], x = root, parent[x]
        return root

    start = 0
    for i in range(1, order_s.size + 1):
        if i == order_s.size or order_s[i] != order_s[start]:
            ents = ent_s[start:i]
            r0 = find(int(ents[0]))
            for e in ents[1:]:
                r = find(int(e))
                if r != r0:
                    # union by smaller-root-wins keeps labels canonical-ish;
                    # the final min-label pass below is what actually matters
                    if r < r0:
                        r0, r = r, r0
                    parent[r] = r0
            start = i
    roots = np.fromiter((find(int(e)) for e in range(num_entities)),
                        np.int64, num_entities)
    # label each component by its minimum entity id
    min_of_root: dict = {}
    for e, r in enumerate(roots.tolist()):
        if r not in min_of_root or e < min_of_root[r]:
            min_of_root[r] = e
    return np.fromiter((min_of_root[r] for r in roots.tolist()),
                       np.int64, num_entities)


class IncrementalPartitioner:
    """Streaming connected-component assignment over arriving checkouts.

    Union-find with path compression and union-by-size; every component
    tracks its canonical label (minimum entity id), its member list, and how
    many orders it has absorbed — the bookkeeping the community-local
    refresh driver needs to group dirty ``(entity, t)`` pairs and to
    estimate per-community DDS node counts without touching the full graph.

    ``add_order(entities)`` merges the components of all linked entities
    (the order itself is the merge witness) in O(K·α).  Community ids are
    *canonical, not stable*: when two components merge, the surviving label
    is the smaller of the two minima — callers must resolve
    ``community_of`` at use time, never cache ids across merges.
    ``assignment()`` equals :func:`entity_communities` on the accumulated
    edge list at every prefix (property-tested in
    ``tests/test_refresh_communities.py``).
    """

    def __init__(self):
        self._parent: dict[int, int] = {}
        self._size: dict[int, int] = {}       # component size, by root
        self._min: dict[int, int] = {}        # canonical label, by root
        self._members: dict[int, list] = {}   # entity members, by root
        self._orders: dict[int, int] = {}     # orders absorbed, by root
        self.merges = 0

    def _find(self, e: int) -> int:
        root = e
        while self._parent[root] != root:
            root = self._parent[root]
        while self._parent[e] != root:        # path compression
            self._parent[e], e = root, self._parent[e]
        return root

    def _add_entity(self, e: int) -> int:
        if e not in self._parent:
            self._parent[e] = e
            self._size[e] = 1
            self._min[e] = e
            self._members[e] = [e]
            self._orders[e] = 0
            return e
        return self._find(e)

    def add_order(self, entities) -> int | None:
        """Merge the components of all linked entities; returns the merged
        component's canonical community id (None for entity-less orders,
        which belong to no community and carry no entity embeddings)."""
        ents = [int(e) for e in entities]
        if not ents:
            return None
        r0 = self._add_entity(ents[0])
        for e in ents[1:]:
            r = self._add_entity(e)
            if r == r0:
                continue
            if self._size[r] > self._size[r0]:   # union by size
                r0, r = r, r0
            self._parent[r] = r0
            self._size[r0] += self._size.pop(r)
            self._min[r0] = min(self._min[r0], self._min.pop(r))
            self._members[r0].extend(self._members.pop(r))
            self._orders[r0] += self._orders.pop(r)
            self.merges += 1
        self._orders[r0] += 1
        return self._min[r0]

    def community_of(self, entity: int) -> int:
        """Canonical community id (an entity never seen is its own
        singleton — no state is created for it)."""
        e = int(entity)
        if e not in self._parent:
            return e
        return self._min[self._find(e)]

    def members(self, entity_or_community: int) -> list:
        """All entities in the component containing the given entity (a
        community id IS an entity id — the component's smallest)."""
        e = int(entity_or_community)
        if e not in self._parent:
            return [e]
        return list(self._members[self._find(e)])

    def type_histogram(self, entity_or_community: int) -> dict:
        """Entity-type composition of one community: ``{type_name: count}``.

        Communities are id-agnostic (the union-find never decodes ids), so
        heterogeneous graphs get typed communities for free — this is the
        introspection side: tagged members count under their
        :data:`~repro.core.hetero.ENTITY_TYPE_NAMES` name, untagged ones
        under ``"untyped"``.  A fraud ring shows up here as one community
        whose histogram spans many devices/payments but few buyers.
        """
        from repro.core.hetero import ENTITY_TYPE_NAMES, type_code_of

        hist: dict = {}
        for e in self.members(entity_or_community):
            code = type_code_of(e)
            name = (ENTITY_TYPE_NAMES[code]
                    if 0 <= code < len(ENTITY_TYPE_NAMES) else "untyped")
            hist[name] = hist.get(name, 0) + 1
        return hist

    def order_count(self, entity_or_community: int) -> int:
        """Orders absorbed by the component containing the given entity."""
        e = int(entity_or_community)
        if e not in self._parent:
            return 0
        return self._orders[self._find(e)]

    @property
    def num_communities(self) -> int:
        return len(self._size)

    def assignment(self) -> dict:
        """entity -> canonical community id, for every entity ever seen."""
        return {e: self._min[self._find(e)] for e in self._parent}


def partition_transactions(
    num_orders: int,
    num_entities: int,
    edges: np.ndarray,
    pic_cluster_size: int = 1_000_000,
    community_size: int = 1024,
    seed: int = 0,
) -> np.ndarray:
    """End-to-end partition of the static bipartite graph (paper pipeline).

    Nodes 0..num_orders are orders; entities follow.  Returns a community id
    for every static node; DDS construction then runs per community.
    """
    n = num_orders + num_entities
    src = edges[:, 0].astype(np.int64)
    dst = edges[:, 1].astype(np.int64) + num_orders
    n_pic = max(1, n // max(pic_cluster_size, 1))
    coarse = (
        power_iteration_clustering(n, src, dst, n_pic, seed=seed)
        if n_pic > 1
        else np.zeros(n, np.int32)
    )
    return refine_partition(n, src, dst, coarse, target_size=community_size)
