"""GQA attention with RoPE: train/prefill path + cached decode path.

Physical head padding (``cfg.physical_heads``/``physical_kv_heads``) is a
sharding artifact for the fixed 16-way model axis: padded q heads are real
computed heads whose ``w_o`` rows are zero-initialized; padded kv heads are
*tied replicas* of logical kv heads (what tensor-parallel GQA serving does
physically — each shard pair recomputes the same kv projection).  Logical
model math is unchanged; the duplicated FLOPs show up honestly in the
roofline's MODEL_FLOPS/HLO_FLOPS ratio.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import apply_rope, blockwise_attention, banded_attention, dense_init


def attn_init(rng, cfg, cross: bool = False):
    dtype = jnp.dtype(cfg.dtype)
    hq, hkv, dh, d = cfg.physical_heads, cfg.physical_kv_heads, cfg.head_dim, cfg.d_model
    ks = jax.random.split(rng, 4)
    wk = dense_init(ks[1], (d, cfg.num_kv_heads, dh), dtype)
    wv = dense_init(ks[2], (d, cfg.num_kv_heads, dh), dtype)
    if hkv > cfg.num_kv_heads:
        if hkv % cfg.num_kv_heads == 0:
            # kv tying: tile logical heads to physical (TP replication)
            rep = hkv // cfg.num_kv_heads
            wk = jnp.repeat(wk, rep, axis=1)
            wv = jnp.repeat(wv, rep, axis=1)
        else:
            # ragged pad (e.g. qwen 40 -> 48): zero kv heads; the matching
            # padded q heads have zeroed w_o rows, so they never contribute
            pad = jnp.zeros((d, hkv - cfg.num_kv_heads, dh), dtype)
            wk = jnp.concatenate([wk, pad], axis=1)
            wv = jnp.concatenate([wv, pad], axis=1)
    wq = dense_init(ks[0], (d, cfg.num_heads, dh), dtype)
    wo = dense_init(ks[3], (hq * dh, d), dtype)
    if hq > cfg.num_heads:
        pad = jnp.zeros((d, hq - cfg.num_heads, dh), dtype)
        wq = jnp.concatenate([wq, pad], axis=1)
        # zero the wo rows of padded heads so they contribute nothing
        wo = wo.reshape(hq, dh, d).at[cfg.num_heads :].set(0.0).reshape(hq * dh, d)
    p = {
        "wq": wq.reshape(d, hq * dh),
        "wk": wk.reshape(d, hkv * dh),
        "wv": wv.reshape(d, hkv * dh),
        "wo": wo,
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * dh,), dtype)
        p["bk"] = jnp.zeros((hkv * dh,), dtype)
        p["bv"] = jnp.zeros((hkv * dh,), dtype)
    return p


def _project_qkv(params, cfg, x, kv_x=None):
    hq, hkv, dh = cfg.physical_heads, cfg.physical_kv_heads, cfg.head_dim
    b, s, _ = x.shape
    src = x if kv_x is None else kv_x
    sk = src.shape[1]
    q = x @ params["wq"]
    k = src @ params["wk"]
    v = src @ params["wv"]
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = q.reshape(b, s, hq, dh).transpose(0, 2, 1, 3)
    k = k.reshape(b, sk, hkv, dh).transpose(0, 2, 1, 3)
    v = v.reshape(b, sk, hkv, dh).transpose(0, 2, 1, 3)
    return q, k, v


def attn_apply(params, cfg, x, *, kv_x=None, causal=True, use_rope=True,
               attn_impl: str = "blockwise", block_k: int = 512):
    """Full-sequence attention (train / prefill).  x: [B, S, d].

    ``kv_x`` switches to cross-attention (no RoPE on kv side conventions of
    mllama/seamless: we apply RoPE to q only when kv_x is given).
    ``attn_impl``: 'blockwise' (XLA flash) | 'banded' (SWA-only, beyond-paper).
    """
    b, s, d = x.shape
    q, k, v = _project_qkv(params, cfg, x, kv_x)
    if use_rope:
        pos = jnp.arange(s)
        q = apply_rope(q, pos[None, None, :], cfg.rope_theta)
        if kv_x is None:
            k = apply_rope(k, pos[None, None, :], cfg.rope_theta)
    bk = min(block_k, k.shape[2])
    if attn_impl == "banded" and cfg.window is not None and kv_x is None:
        out = banded_attention(q, k, v, window=cfg.window, block_k=bk)
    else:
        out = blockwise_attention(
            q, k, v, causal=causal and kv_x is None,
            window=cfg.window if kv_x is None else None, block_k=bk,
        )
    out = out.transpose(0, 2, 1, 3).reshape(b, s, -1)
    return out @ params["wo"], (k, v)


def attn_decode(params, cfg, x1, cache, pos, *, cross: bool = False):
    """Single-token decode.  x1: [B, 1, d]; cache: dict(k, v) with
    k/v: [B, Hkv, S_max, Dh]; pos: [] int32 current position.

    For cross-attention the cache holds the (static) encoder/vision K/V and
    is not updated.  Returns (out [B, 1, d], new_cache).
    """
    hq, hkv, dh = cfg.physical_heads, cfg.physical_kv_heads, cfg.head_dim
    b = x1.shape[0]
    q = (x1 @ params["wq"])
    if cfg.qkv_bias:
        q = q + params["bq"]
    q = q.reshape(b, 1, hq, dh).transpose(0, 2, 1, 3)       # [B, Hq, 1, Dh]
    if not cross:
        q = apply_rope(q, jnp.full((1, 1, 1), pos), cfg.rope_theta)
        k1 = (x1 @ params["wk"])
        v1 = (x1 @ params["wv"])
        if cfg.qkv_bias:
            k1 = k1 + params["bk"]
            v1 = v1 + params["bv"]
        k1 = k1.reshape(b, 1, hkv, dh).transpose(0, 2, 1, 3)
        k1 = apply_rope(k1, jnp.full((1, 1, 1), pos), cfg.rope_theta)
        v1 = v1.reshape(b, 1, hkv, dh).transpose(0, 2, 1, 3)
        cache_len = cache["k"].shape[2]
        ring = bool(cfg.ring_kv_cache and cfg.window and cache_len <= cfg.window)
        write_pos = pos % cache_len if ring else pos
        k = jax.lax.dynamic_update_slice(cache["k"], k1.astype(cache["k"].dtype),
                                         (0, 0, write_pos, 0))
        v = jax.lax.dynamic_update_slice(cache["v"], v1.astype(cache["v"].dtype),
                                         (0, 0, write_pos, 0))
        cache = {"k": k, "v": v}
        kv_len = jnp.minimum(pos + 1, cache_len) if ring else pos + 1
    else:
        ring = False
        k, v = cache["k"], cache["v"]
        kv_len = k.shape[2]

    # online-softmax over the cache (XLA path of the gqa_decode kernel)
    rep = hq // hkv
    qg = q.reshape(b, hkv, rep, dh)
    logits = jnp.einsum("bgrd,bgsd->bgrs", qg, k,
                        preferred_element_type=jnp.float32) * (dh ** -0.5)
    spos = jnp.arange(k.shape[2])
    valid = spos[None, :] < kv_len if not cross else jnp.ones((1, k.shape[2]), bool)
    if cfg.window is not None and not cross and not ring:
        valid = valid & (spos[None, :] >= kv_len - cfg.window)
    # ring cache: the buffer holds exactly the last `window` positions (the
    # write above already evicted the oldest), so all valid slots attend —
    # slot order differs from time order but softmax is permutation-invariant
    # and RoPE was applied at absolute positions before the write.
    logits = jnp.where(valid[:, None, None, :] if valid.ndim == 2 else valid,
                       logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bgrs,bgsd->bgrd", p.astype(v.dtype), v)
    out = out.reshape(b, 1, hq * dh)
    return out @ params["wo"], cache
