import os
import sys

# NOTE: deliberately no XLA_FLAGS here — tests must see the real 1-CPU
# backend; only launch/dryrun.py creates the 512 placeholder devices.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import pytest


@pytest.fixture(scope="session")
def small_fraud_dataset():
    """A small synthetic fraud graph shared across tests."""
    from repro.data import SynthConfig, generate_transactions, make_split_masks
    from repro.data.pipeline import standardize_features

    cfg = SynthConfig(num_users=150, num_rings=4, feature_noise=0.8, seed=7)
    g, etypes = generate_transactions(cfg)
    split = make_split_masks(g.order_snapshot)
    feats, _ = standardize_features(g.order_features, split == 0)
    g.order_features = feats
    return g, etypes, split


@pytest.fixture(scope="session")
def small_communities(small_fraud_dataset):
    from repro.data import build_communities

    g, _, _ = small_fraud_dataset
    return build_communities(g, community_size=128, max_deg=16)
