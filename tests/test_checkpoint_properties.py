"""Property-based coverage (hypothesis) for the checkpoint round-trip:

For ANY stream prefix length, ANY crash position, ANY checkpoint position
at or before the crash, and with/without a mid-stream model hot-swap,
``restore + WAL-suffix replay + resumed feed`` must equal the
uninterrupted run bit-for-bit — scores by order AND KV-store bytes.

The crash here is the harshest one the WAL contract admits: the process
dies *between* events with the service object simply abandoned, so the
recovery has exactly the durable artifacts (checkpoint dirs + log) to work
from — extending the ``test_dds_properties.py`` randomized-invariant
pattern up to the full serving stack.
"""
import functools
import shutil
import tempfile

import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st

import jax

from repro.core import LNNConfig, lnn_init
from repro.data import SynthConfig, generate_event_stream
from repro.service import FraudService, ModelSection, ServiceConfig

from faultinject import (drive, merge_responses, run_uninterrupted,
                         store_contents)

MAX_EVENTS = 32


@functools.lru_cache(maxsize=None)
def _world():
    events, g, _ = generate_event_stream(
        SynthConfig(num_users=30, num_rings=2, feature_noise=0.8, seed=9),
        rate_per_s=500.0)
    cfg = LNNConfig(num_gnn_layers=2, hidden_dim=8,
                    feat_dim=g.order_features.shape[1], mlp_dims=(8,))
    params = lnn_init(jax.random.PRNGKey(0), cfg)
    swap_params = lnn_init(jax.random.PRNGKey(3), cfg)
    return tuple(events[:MAX_EVENTS]), cfg, params, swap_params


def _build(cfg, params):
    sc = ServiceConfig(
        mode="streaming", model=ModelSection.from_lnn_config(cfg),
    ).replace(engine={"num_workers": 1, "max_batch": 4})
    return FraudService(sc, params=params).build()


@functools.lru_cache(maxsize=None)
def _baseline(n: int, use_swap: bool):
    events, cfg, params, swap_params = _world()
    swap = (n // 2, swap_params, 1) if use_swap else None
    return run_uninterrupted(lambda: _build(cfg, params), events[:n],
                             swap=swap)


@settings(max_examples=8, deadline=None)
@given(
    n=st.integers(6, MAX_EVENTS),
    crash_at=st.integers(0, MAX_EVENTS),
    ckpt_at=st.integers(0, MAX_EVENTS),
    use_ckpt=st.booleans(),
    use_swap=st.booleans(),
)
def test_crash_restore_replay_equals_uninterrupted(
        n, crash_at, ckpt_at, use_ckpt, use_swap):
    events, cfg, params, swap_params = _world()
    evs = list(events[:n])
    crash_at = min(crash_at, n)
    swap = (n // 2, swap_params, 1) if use_swap else None
    checkpoint_at = min(ckpt_at, max(crash_at - 1, 0)) if use_ckpt else None
    base_scores, base_store = _baseline(n, use_swap)

    root = tempfile.mkdtemp()
    try:
        svc = _build(cfg, params).enable_wal(root)
        delivered: list = []
        for i in range(crash_at):
            delivered.extend(svc.submit(evs[i]))
            if swap is not None and i == swap[0]:
                svc.load_model(swap[1], version=swap[2])
            if checkpoint_at is not None and i == checkpoint_at:
                svc.checkpoint()
        # the crash: the service object is abandoned with queues full

        svc2 = FraudService.restore(root)
        merged = merge_responses({}, delivered)
        merge_responses(merged, svc2.last_recovery["responses"])
        resume = svc2.engine.ingester.num_events
        # every fully-submitted event was durably logged before its apply
        assert resume == crash_at
        if swap is not None and resume > swap[0] and svc2.model_version < 1:
            svc2.load_model(swap_params, version=1)
        resumed = drive(
            svc2, evs, start=resume,
            swap=swap if (swap is not None and resume <= swap[0]) else None)
        merge_responses(merged, resumed)

        assert merged == base_scores
        assert store_contents(svc2.store) == base_store
    finally:
        shutil.rmtree(root)
