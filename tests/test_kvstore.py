"""KV store semantics: key packing guards, persistence, mask semantics,
versioning, TTL/LRU eviction, sharding, and the snapshot-fallback lookup."""
import os

import numpy as np
import pytest

from repro.serve.kvstore import (
    MAX_ENTITY,
    MAX_SNAPSHOT,
    KVStore,
    pack_key,
    unpack_key,
)


# ---------------------------------------------------------------- pack_key
def test_pack_key_roundtrip_and_uniqueness():
    seen = set()
    for e in (0, 1, 17, 12345, MAX_ENTITY):
        for t in (0, 1, 29, MAX_SNAPSHOT):
            k = pack_key(e, t)
            assert unpack_key(k) == (e, t)
            assert k not in seen
            seen.add(k)


def test_pack_key_guards_collision_domain():
    # snapshot 2^20 used to silently bleed into the entity bits:
    # pack_key(0, 2^20) == pack_key(1, 0) before the guard
    with pytest.raises(ValueError):
        pack_key(0, MAX_SNAPSHOT + 1)
    with pytest.raises(ValueError):
        pack_key(-1, 0)
    with pytest.raises(ValueError):
        pack_key(0, -1)
    with pytest.raises(ValueError):
        pack_key(MAX_ENTITY + 1, 0)


# ------------------------------------------------------------- persistence
def test_save_load_roundtrip_with_versions(tmp_path):
    s = KVStore(dim=4)
    s.put(pack_key(5, 3), np.arange(4.0), version=7)
    s.put(pack_key(9, 1), np.ones(4), version=8)
    path = os.path.join(tmp_path, "store.npz")
    s.save(path)
    s2 = KVStore.load(path)
    assert len(s2) == 2
    np.testing.assert_array_equal(s2.get(pack_key(5, 3)), np.arange(4.0))
    assert s2.get(pack_key(5, 3)).dtype == np.float32
    assert s2.version_of(pack_key(5, 3)) == 7
    assert s2.version_of(pack_key(9, 1)) == 8


def test_save_load_empty_store_preserves_float32(tmp_path):
    s = KVStore(dim=6)
    path = os.path.join(tmp_path, "empty.npz")
    s.save(path)
    with np.load(path) as data:
        assert data["values"].dtype == np.float32   # was float64 pre-fix
        assert data["values"].shape == (0, 6)
    s2 = KVStore.load(path)
    assert len(s2) == 0
    emb, mask = s2.lookup_batch([[pack_key(1, 1)]], k_max=2)
    assert emb.dtype == np.float32 and mask.sum() == 0


# ------------------------------------------------------------ mask semantics
def test_lookup_batch_cold_entity_mask_semantics():
    s = KVStore(dim=3)
    s.put(pack_key(1, 2), np.full(3, 2.0))
    emb, mask = s.lookup_batch(
        [[pack_key(1, 2), pack_key(42, 0)], [], [pack_key(7, 7)]], k_max=2
    )
    assert emb.shape == (3, 2, 3) and mask.shape == (3, 2)
    np.testing.assert_array_equal(mask, [[1, 0], [0, 0], [0, 0]])
    np.testing.assert_array_equal(emb[0, 0], np.full(3, 2.0))
    assert emb[0, 1].sum() == 0 and emb[2].sum() == 0   # cold rows stay zero
    assert s.stats["misses"] == 2


def test_lookup_batch_truncates_to_k_max():
    s = KVStore(dim=2)
    for t in range(5):
        s.put(pack_key(1, t), np.full(2, float(t)))
    emb, mask = s.lookup_batch([[pack_key(1, t) for t in range(5)]], k_max=3)
    assert mask.sum() == 3
    np.testing.assert_array_equal(emb[0, :, 0], [0, 1, 2])


# --------------------------------------------------------------- versioning
def test_versioned_put_overwrites_and_tracks():
    s = KVStore(dim=2)
    k = pack_key(3, 1)
    s.put(k, np.zeros(2), version=1)
    s.put(k, np.ones(2), version=2)
    assert len(s) == 1
    val, ver, stamp = s.get_entry(k)
    np.testing.assert_array_equal(val, np.ones(2))
    assert ver == 2 and stamp > 0


def test_lookup_versioned_snapshot_fallback_reports_staleness():
    s = KVStore(dim=2)
    s.put(pack_key(1, 3), np.full(2, 3.0), version=1)
    s.put(pack_key(1, 5), np.full(2, 5.0), version=2)
    emb, mask, stale = s.lookup_batch_versioned(
        [[(1, 5), (1, 4), (2, 9)]], k_max=3
    )
    # exact hit
    assert mask[0, 0] == 1 and stale[0, 0] == 0 and emb[0, 0, 0] == 5.0
    # (1, 4) missing -> falls back to snapshot 3, one snapshot stale
    assert mask[0, 1] == 1 and stale[0, 1] == 1 and emb[0, 1, 0] == 3.0
    # cold entity stays masked with sentinel staleness
    assert mask[0, 2] == 0 and stale[0, 2] == -1
    assert s.stats["stale_hits"] == 1


# ----------------------------------------------------------------- eviction
def test_lru_eviction_respects_capacity_and_recency():
    s = KVStore(dim=1, capacity=2)
    s.put(pack_key(1, 0), [1.0])
    s.put(pack_key(2, 0), [2.0])
    s.get(pack_key(1, 0))            # touch 1 -> 2 becomes LRU
    s.put(pack_key(3, 0), [3.0])     # evicts 2
    assert len(s) == 2
    assert s.get(pack_key(2, 0)) is None
    assert s.get(pack_key(1, 0)) is not None
    assert s.stats["evictions"] == 1
    # eviction also drops the snapshot-fallback index
    assert s.latest_snapshot(2, 10) is None


def test_ttl_expiry_with_injected_clock():
    now = [100.0]
    s = KVStore(dim=1, ttl_seconds=10.0, clock=lambda: now[0])
    s.put(pack_key(1, 0), [1.0])
    now[0] = 105.0
    assert s.get(pack_key(1, 0)) is not None
    now[0] = 111.0
    assert s.get(pack_key(1, 0)) is None
    assert s.stats["expired"] == 1 and len(s) == 0


# ------------------------------------------------------------------ sharding
def test_sharded_store_spreads_and_serves_identically():
    s1 = KVStore(dim=2, num_shards=1)
    s8 = KVStore(dim=2, num_shards=8)
    rng = np.random.default_rng(0)
    keys = [pack_key(e, t) for e in range(40) for t in range(3)]
    for k in keys:
        v = rng.normal(size=2)
        s1.put(k, v)
        s8.put(k, v)
    assert len(s1) == len(s8) == len(keys)
    occupied = sum(1 for sh in s8._shards if len(sh))
    assert occupied >= 6          # hash actually spreads keys
    emb1, m1 = s1.lookup_batch([keys[:5]], k_max=5)
    emb8, m8 = s8.lookup_batch([keys[:5]], k_max=5)
    np.testing.assert_array_equal(emb1, emb8)
    np.testing.assert_array_equal(m1, m8)


# ----------------------------------------------------------------- put_batch
def test_put_batch_matches_put_loop():
    """One batched write must leave the store byte-for-byte equivalent to
    the per-entry loop: same values, versions, model stamps, LRU order per
    shard, and fallback index."""
    rng = np.random.default_rng(3)
    keys = [pack_key(e, t) for e in range(30) for t in range(2)]
    vals = rng.normal(size=(len(keys), 4)).astype(np.float32)
    loop = KVStore(dim=4, num_shards=4)
    for k, v in zip(keys, vals):
        loop.put(k, v, version=5, model_version=2)
    batch = KVStore(dim=4, num_shards=4)
    n = batch.put_batch(keys, vals, version=5, model_version=2)
    assert n == len(keys)
    assert len(batch) == len(loop)
    assert batch.stats["puts"] == loop.stats["puts"] == len(keys)
    for shard_b, shard_l in zip(batch._shards, loop._shards):
        assert list(shard_b.keys()) == list(shard_l.keys())   # LRU order
    for k, v in zip(keys, vals):
        np.testing.assert_array_equal(batch.get(k), v)
        assert batch.version_of(k) == 5
    assert batch._snaps == loop._snaps
    emb_b, _, st_b = batch.lookup_batch_versioned([[(0, 5)]], k_max=1)
    emb_l, _, st_l = loop.lookup_batch_versioned([[(0, 5)]], k_max=1)
    np.testing.assert_array_equal(emb_b, emb_l)
    np.testing.assert_array_equal(st_b, st_l)


def test_put_batch_enforces_capacity_per_shard():
    s = KVStore(dim=1, capacity=4, num_shards=2)
    keys = [pack_key(e, 0) for e in range(20)]
    s.put_batch(keys, [np.full(1, float(e)) for e in range(20)])
    cap = max(1, s.capacity // s.num_shards)
    assert all(len(shard) <= cap for shard in s._shards)
    assert len(s) <= s.capacity
    assert s.stats["evictions"] == 20 - len(s)


# ----------------------------------------------------------- model versions
def test_model_version_stamp_roundtrip(tmp_path):
    s = KVStore(dim=2)
    s.put(pack_key(1, 0), np.zeros(2), version=1, model_version=3)
    s.put_batch([pack_key(2, 0)], [np.ones(2)], version=1, model_version=4)
    path = os.path.join(tmp_path, "mv.npz")
    s.save(path)
    s2 = KVStore.load(path)
    stamps = {k: s2._shards[s2.shard_of(k)][k].model_version for k in s2.keys()}
    assert stamps == {pack_key(1, 0): 3, pack_key(2, 0): 4}


def test_lookup_versioned_counts_model_stale_reads():
    """After a hot-swap, reads of embeddings written by an older model are
    detectable: expected_model_version flags every mismatched slot."""
    s = KVStore(dim=2)
    s.put(pack_key(1, 0), np.zeros(2), model_version=0)
    s.put(pack_key(2, 0), np.ones(2), model_version=1)
    s.lookup_batch_versioned([[(1, 0), (2, 0)]], k_max=2)
    assert s.stats["model_stale_reads"] == 0      # no expectation, no count
    s.lookup_batch_versioned([[(1, 0), (2, 0)]], k_max=2,
                             expected_model_version=1)
    assert s.stats["model_stale_reads"] == 1      # only the v0 slot
    s.lookup_batch_versioned([[(1, 0)]], k_max=1, expected_model_version=0)
    assert s.stats["model_stale_reads"] == 1      # matching reads stay silent
