"""The continuous-learning loop: tap → rolling trainer → promotion.

:class:`ContinuousLearner` wires the three learn-plane pieces onto one
:class:`~repro.service.FraudService` according to its
``config.learn`` section, and exposes the single :meth:`step` the
gateway's ``POST /admin/train`` (and the smoke example's driving loop)
calls: poll the WAL tap, feed the rolling-window trainer, fine-tune when
the window advances and the controller is idle, submit the candidate,
and tick the promotion state machine.

The learner holds **no** training state the service doesn't: the tap's
cursor is recoverable from the WAL, and the promotion evidence lives in
the checkpointed shadow dict — after a crash/restore,
``ContinuousLearner(service)`` re-attaches mid-eval
(:meth:`PromotionController.attach`).
"""
from __future__ import annotations

from repro.learn.promote import PromotionController
from repro.learn.tap import LabelLog, WalTrainingTap
from repro.learn.trainer import RollingWindowTrainer, WindowPolicy

__all__ = ["ContinuousLearner"]


class ContinuousLearner:
    """Orchestrates WAL-tap → fine-tune → shadow-gated promotion.

    Requires a streaming service with an enabled WAL (the tap's source).
    ``section`` defaults to ``service.config.learn``; ``label_log`` is
    shared with whoever records delayed outcomes (the gateway, a test).
    """

    def __init__(self, service, section=None, *,
                 label_log: LabelLog | None = None):
        section = service.config.learn if section is None else section
        if service.wal is None:
            raise RuntimeError(
                "ContinuousLearner needs an enabled WAL — call "
                "service.enable_wal(root) before attaching the learn plane")
        self.service = service
        self.section = section
        cfg = service.config.to_lnn_config()
        eng = service.config.engine
        self.label_log = label_log if label_log is not None else LabelLog()
        self.tap = WalTrainingTap(
            service.wal, cfg.feat_dim, label_log=self.label_log,
            label_latency_s=section.label_latency_s,
            include_ingest=section.include_ingest,
            entity_history=eng.entity_history, max_history=eng.max_history)
        self.trainer = RollingWindowTrainer(
            cfg,
            WindowPolicy(min_window=section.min_window,
                         max_window=section.max_window,
                         stride=section.stride, dedup=section.dedup),
            optimizer=section.optimizer, lr=section.lr, steps=section.steps,
            head=section.head, gbdt_trees=section.gbdt_trees,
            k_max=eng.k_max, max_deg=eng.max_deg,
            entity_history=eng.entity_history, max_history=eng.max_history,
            in_process=section.train_in_process)
        self.controller = PromotionController.attach(
            service,
            promote_margin=section.promote_margin,
            min_eval=section.min_eval, min_eval_pos=section.min_eval_pos,
            eval_budget=section.eval_budget, eval_max=section.eval_max,
            shadow_fraction=section.shadow_fraction,
            rollback_margin=section.rollback_margin,
            watch_min_eval=section.watch_min_eval,
            watch_divergence_threshold=section.watch_divergence_threshold)
        self.fires = 0
        self.last_result = None      # last FineTuneResult summary

    # ------------------------------------------------------------------ step
    def step(self, now: float | None = None, force: bool = False) -> dict:
        """One learn tick: poll the tap, maybe fine-tune + submit, tick the
        promotion controller.  ``force=True`` fires a fine-tune regardless
        of the window policy (the ``POST /admin/train`` escape hatch) as
        long as any examples are buffered.  Returns a summary dict."""
        examples = self.tap.poll(now)
        self.trainer.extend(examples)
        trained = None
        can_fire = self.controller.state == "idle" \
            and (self.trainer.ready()
                 or (force and self.trainer.stats["examples"] > 0))
        if can_fire:
            warm = self.service.model_params()
            from repro.models.hybrid import HybridModel

            if isinstance(warm, HybridModel):
                warm = warm.lnn_params     # fine-tune from the embedded LNN
            result = self.trainer.train(warm)
            self.fires += 1
            trained = {"window": result.window, "steps": result.steps,
                       "head": result.head, "loss": result.losses[-1]}
            self.last_result = trained
            trained["candidate"] = self.controller.submit_candidate(
                result.model)
        decision = self.controller.step()
        return {"examples": len(examples), "trained": trained,
                "decision": decision, "state": self.controller.state}

    def stats(self) -> dict:
        """One JSON-able snapshot for ``GET /v1/learn/stats``."""
        return {
            "state": self.controller.state,
            "candidate_version": self.controller.candidate_version,
            "fires": self.fires,
            "tap": {**self.tap.stats, "cursor": self.tap.cursor,
                    "pending": self.tap.pending,
                    "labels_recorded": self.label_log.recorded},
            "trainer": dict(self.trainer.stats),
            "promotion": dict(self.controller.stats),
            "last_result": self.last_result,
            "last_decision": self.controller.last_decision,
            "last_rollback": self.service.last_rollback,
        }

    def close(self) -> None:
        """Release the tap's WAL pin."""
        self.tap.close()
