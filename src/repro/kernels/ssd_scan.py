"""Pallas TPU kernel: Mamba2 SSD chunked scan (state-space duality).

TPU adaptation of the Mamba2 GPU kernel (arXiv:2405.21060): the SSD
decomposition splits the sequence into chunks; within a chunk the recurrence
is evaluated as a small causal "attention" (dense matmuls — MXU-friendly),
and a [N, P] state matrix is carried *sequentially across chunk grid steps*
in VMEM scratch — exactly where a GPU implementation would use an
inter-block carry.  This keeps every op a dense matmul on (chunk, N, P)
tiles, no scan over single timesteps.

Grid = (batch, heads, num_chunks), chunks innermost/sequential.

Per-program VMEM (chunk Q=128, N=128, P=64, f32):
  x (Q,P) 32 KiB + b,c (Q,N) 2x64 KiB + decay (Q,Q) 64 KiB
  + state (N,P) 32 KiB + out (Q,P) 32 KiB  << 16 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, out_ref, state_ref, *, chunk):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0].astype(jnp.float32)        # [Q, P]
    dt = dt_ref[0, :, 0].astype(jnp.float32)      # [Q]
    a = a_ref[0]                                  # scalar decay rate (this head)
    b = b_ref[0].astype(jnp.float32)              # [Q, N]
    c = c_ref[0].astype(jnp.float32)              # [Q, N]

    seg = dt * a                                   # [Q] log-decay increments
    cum = jnp.cumsum(seg)                          # inclusive
    total = cum[-1]

    # ---- intra-chunk: causal decay-weighted attention ----------------------
    scores = jnp.dot(c, b.T, preferred_element_type=jnp.float32)      # [Q, Q]
    li = cum[:, None]
    lj = cum[None, :]
    iota_i = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    iota_j = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    causal = iota_j <= iota_i
    decay = jnp.exp(jnp.clip(li - lj, -60.0, 0.0))
    w = jnp.where(causal, scores * decay, 0.0)
    y_intra = jnp.dot(w * dt[None, :], x, preferred_element_type=jnp.float32)

    # ---- inter-chunk: contribution of the carried state --------------------
    state = state_ref[...]                         # [N, P]
    y_inter = jnp.exp(jnp.clip(cum, -60.0, 0.0))[:, None] * jnp.dot(
        c, state, preferred_element_type=jnp.float32
    )
    out_ref[0, :, 0] = (y_intra + y_inter).astype(out_ref.dtype)

    # ---- state update -------------------------------------------------------
    dec_state = jnp.exp(jnp.clip(total - cum, -60.0, 0.0)) * dt       # [Q]
    new_state = jnp.dot((b * dec_state[:, None]).T, x,
                        preferred_element_type=jnp.float32)           # [N, P]
    state_ref[...] = state * jnp.exp(jnp.clip(total, -60.0, 0.0)) + new_state


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(x, dt, a, b, c, d_skip=None, chunk: int = 128,
                    interpret: bool = True):
    """x: [B,S,H,P]; dt: [B,S,H]; a: [H]; b,c: [B,S,N].  Returns [B,S,H,P]."""
    B, S, H, P = x.shape
    N = b.shape[-1]
    assert S % chunk == 0, f"seq {S} not divisible by chunk {chunk}"
    nc = S // chunk
    grid = (B, H, nc)

    y = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b_, h, k_: (b_, k_, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b_, h, k_: (b_, k_, h)),
            pl.BlockSpec((1,), lambda b_, h, k_: (h,)),
            pl.BlockSpec((1, chunk, N), lambda b_, h, k_: (b_, k_, 0)),
            pl.BlockSpec((1, chunk, N), lambda b_, h, k_: (b_, k_, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, P), lambda b_, h, k_: (b_, k_, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, H, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, dt, a, b, c)
    if d_skip is not None:
        y = y + (x.astype(jnp.float32) * d_skip[None, None, :, None]).astype(y.dtype)
    return y
