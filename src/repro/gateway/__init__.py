"""``repro.gateway`` — the HTTP serving gateway over ``repro.service``.

The wire protocol the typed facade was missing: a dependency-free
(stdlib ``http.server`` + JSON) front-end exposing score/ingest/health/
stats endpoints, ``/metrics`` in the Prometheus text format, an admin
surface for model hot-swap + canary/shadow scoring, and real socket-level
backpressure (admission shed → ``429 Retry-After``, timed-out block
stall → ``503``).

* :class:`FraudGateway` — binds ``config.gateway.host:port`` over one
  built :class:`~repro.service.FraudService`; context-manager lifecycle;
* :func:`serve_gateway` — one-liner boot (build + warmup + start);
* :class:`MetricsRegistry` / :class:`Counter` / :class:`Gauge` /
  :class:`Histogram` — the Prometheus-style telemetry primitives
  (``repro.gateway.telemetry``) the gateway records into.

See ``docs/gateway.md`` for the endpoint table and curl examples.

Exports resolve lazily (PEP 562), matching ``repro.service``: importing
the package does not start a server or drag jax in.
"""
from __future__ import annotations

__all__ = [
    "Counter",
    "FraudGateway",
    "Gauge",
    "GatewayError",
    "Histogram",
    "MetricsRegistry",
    "serve_gateway",
]

_HOMES = {
    "FraudGateway": "repro.gateway.server",
    "GatewayError": "repro.gateway.server",
    "serve_gateway": "repro.gateway.server",
    "Counter": "repro.gateway.telemetry",
    "Gauge": "repro.gateway.telemetry",
    "Histogram": "repro.gateway.telemetry",
    "MetricsRegistry": "repro.gateway.telemetry",
}


def __getattr__(name: str):
    home = _HOMES.get(name)
    if home is None:
        raise AttributeError(f"module 'repro.gateway' has no attribute {name!r}")
    import importlib

    value = getattr(importlib.import_module(home), name)
    globals()[name] = value    # cache: next access skips __getattr__
    return value


def __dir__():
    return sorted(set(globals()) | set(__all__))
