"""qwen1.5-32b — dense decoder with QKV bias [hf:Qwen/Qwen1.5-0.5B family].

64L, d_model=5120, 40 heads (head_dim 128), kv=40 (MHA), d_ff=27392,
vocab=152064, attention QKV projections carry biases.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-32b",
    arch_type="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,
    head_dim=128,
    d_ff=27392,
    vocab_size=152064,
    qkv_bias=True,
    source="[hf:Qwen/Qwen1.5-0.5B]",
)
