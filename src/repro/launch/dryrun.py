import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax locks the device
# count on first backend initialization.  This module is the ONLY place the
# 512 placeholder devices exist; tests/benches see the real 1-CPU backend.

"""Multi-pod dry-run driver.

For every (architecture x input-shape x mesh) combination:
  lower the sharded step with ShapeDtypeStruct inputs, compile it, and emit
  memory_analysis + cost_analysis + the collective schedule into a JSON
  record under experiments/dryrun/.  A compile failure here is a sharding
  bug in the framework.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-2b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all            # 40 baselines
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh multi
"""
import argparse
import json
import time
import traceback


from repro.configs import CLI_ALIASES, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import analyze
from repro.launch.specs import supports_shape
from repro.launch.steps import make_step
from repro.models.config import INPUT_SHAPES

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _memory_stats(compiled):
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return None
    out = {}
    for key in ("argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes",
                "alias_size_in_bytes"):
        val = getattr(ma, key, None)
        if val is not None:
            out[key] = int(val)
    if not out:
        out = {"repr": str(ma)}
    return out


def _cost_tuple(compiled, cfg=None):
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    hlo = compiled.as_text()
    from repro.launch.roofline import collective_bytes
    coll = collective_bytes(hlo)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": coll,
    }


def _lin_combine(base, deltas, weights):
    """base + sum_g weights[g] * deltas[g] applied to the cost dicts."""
    out = {
        "flops": base["flops"],
        "bytes": base["bytes"],
        "coll": {
            "bytes": dict(base["coll"]["bytes"]),
            "counts": dict(base["coll"]["counts"]),
        },
    }
    for g, d in deltas.items():
        w = weights[g]
        out["flops"] += w * d["flops"]
        out["bytes"] += w * d["bytes"]
        for k in out["coll"]["bytes"]:
            out["coll"]["bytes"][k] += w * d["coll"]["bytes"][k]
            out["coll"]["counts"][k] += w * d["coll"]["counts"][k]
    return out


def _extrapolated_cost(arch, shape, mesh, cfg, *, attn_impl, serve_mode):
    """Exact cost accounting: compile 1-unit and 2-unit UNROLLED variants
    (loop-free HLO, so HloCostAnalysis and the collective parser are exact)
    and extend affinely to the real unit counts."""
    dims = cfg.unit_dims()
    base_counts = {name: 1 for name, _ in dims}
    kw = dict(unroll=True)
    if shape.kind == "train":
        kw["attn_impl"] = attn_impl
    elif shape.kind == "prefill":
        kw.update(attn_impl=attn_impl, mode=serve_mode)
    else:
        kw["mode"] = serve_mode

    def compile_counts(counts):
        c = cfg.with_unit_counts(counts)
        with mesh:
            fn, args = make_step(c, mesh, shape, **kw)
            return _cost_tuple(fn.lower(*args).compile())

    base = compile_counts(base_counts)
    deltas, weights = {}, {}
    for name, real in dims:
        counts = dict(base_counts)
        counts[name] = 2
        var = compile_counts(counts)
        deltas[name] = {
            "flops": var["flops"] - base["flops"],
            "bytes": var["bytes"] - base["bytes"],
            "coll": {
                "bytes": {k: var["coll"]["bytes"][k] - base["coll"]["bytes"][k]
                          for k in var["coll"]["bytes"]},
                "counts": {k: var["coll"]["counts"][k] - base["coll"]["counts"][k]
                           for k in var["coll"]["counts"]},
            },
        }
        weights[name] = real - 1
    return _lin_combine(base, deltas, weights)


def run_one(arch: str, shape_name: str, mesh_kind: str, *, attn_impl="blockwise",
            serve_mode: str = "serve", save: bool = True, tag: str = "",
            extrapolate: bool = True, cfg_overrides: dict | None = None):
    import dataclasses

    shape = INPUT_SHAPES[shape_name]
    if mesh_kind == "multi":
        mesh = make_production_mesh(multi_pod=True)
    elif "x" in mesh_kind:
        mesh = make_production_mesh(layout=mesh_kind)
    else:
        mesh = make_production_mesh()
    chips = mesh.size
    cfg = get_config(arch).with_padding(mesh.shape["model"])
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    from repro.launch.steps import resolve_serve_mode
    serve_mode = resolve_serve_mode(cfg, mesh, serve_mode)
    ok, why = supports_shape(cfg, shape)
    if not ok:
        print(f"SKIP  {arch} x {shape_name} x {mesh_kind}: {why}")
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
               "status": "skip", "reason": why}
        if save:
            os.makedirs(OUT_DIR, exist_ok=True)
            safe = arch.replace(".", "_").replace("/", "_")
            with open(os.path.join(OUT_DIR, f"{safe}__{shape_name}__{mesh_kind}.json"), "w") as f:
                json.dump(rec, f, indent=1)
        return rec

    kw = {}
    if shape.kind == "train":
        kw["attn_impl"] = attn_impl
    elif shape.kind == "prefill":
        kw.update(attn_impl=attn_impl, mode=serve_mode)
    else:
        kw["mode"] = serve_mode

    # 1) the production artifact: full depth, scan-over-layers
    t0 = time.time()
    with mesh:
        fn, args = make_step(cfg, mesh, shape, **kw)
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    mem = _memory_stats(compiled)
    hlo = compiled.as_text()

    # 2) exact cost accounting via unrolled small variants
    if extrapolate:
        cost = _extrapolated_cost(arch, shape, mesh, cfg,
                                  attn_impl=attn_impl, serve_mode=serve_mode)
        cost_dict = {"flops": cost["flops"], "bytes accessed": cost["bytes"]}
        coll_override = cost["coll"]
    else:
        cost_dict = compiled.cost_analysis()
        if isinstance(cost_dict, list):
            cost_dict = cost_dict[0]
        coll_override = None

    rec = analyze(cfg, shape, mesh_kind, chips, cost_dict, hlo,
                  memory_stats=mem, coll_override=coll_override,
                  note=f"attn={attn_impl} mode={serve_mode}"
                       f"{(' ' + tag) if tag else ''}")
    print(f"OK    {arch} x {shape_name} x {mesh_kind}: "
          f"lower {t_lower:.1f}s compile {t_compile:.1f}s | "
          f"Tc={rec.t_compute*1e3:.2f}ms Tm={rec.t_memory*1e3:.2f}ms "
          f"Tcoll={rec.t_collective*1e3:.2f}ms -> {rec.bottleneck} "
          f"useful={rec.useful_ratio:.2f}")
    result = json.loads(rec.to_json())
    result.update({"status": "ok", "t_lower_s": t_lower, "t_compile_s": t_compile})
    if save:
        os.makedirs(OUT_DIR, exist_ok=True)
        safe = arch.replace(".", "_").replace("/", "_")
        suffix = f"_{tag}" if tag else ""
        path = os.path.join(OUT_DIR, f"{safe}__{shape_name}__{mesh_kind}{suffix}.json")
        with open(path, "w") as f:
            json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="CLI id, e.g. granite-3-2b")
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--attn", default="blockwise", choices=["blockwise", "banded"])
    ap.add_argument("--serve-mode", default="serve", choices=["serve", "serve_tp", "serve_auto", "serve_ws", "train"])
    ap.add_argument("--tag", default="")
    ap.add_argument("--no-extrapolate", action="store_true")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]  # or "32x8" etc.
    archs = list(CLI_ALIASES) if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) else [args.shape]

    failures = []
    for mesh_kind in meshes:
        for arch in archs:
            for shape in shapes:
                try:
                    run_one(arch, shape, mesh_kind, attn_impl=args.attn,
                            serve_mode=args.serve_mode, tag=args.tag,
                            extrapolate=not args.no_extrapolate)
                except Exception as e:
                    failures.append((arch, shape, mesh_kind, repr(e)))
                    print(f"FAIL  {arch} x {shape} x {mesh_kind}: {e}")
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} failures:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nall dry-runs passed")


if __name__ == "__main__":
    main()
