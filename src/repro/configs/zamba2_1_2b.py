"""zamba2-1.2b — hybrid Mamba2 + shared attention blocks [arXiv:2411.15242].

38 Mamba2 blocks (d_model=2048, ssm_state=64) with ONE shared
attention+MLP block (32 q heads / 32 kv heads, head_dim 64, d_ff=8192)
applied every 6 mamba blocks; its parameters are shared across all
applications (the Zamba trick).  vocab=32000.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    arch_type="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    attn_every=6,
    source="[arXiv:2411.15242]",
)
