"""Sharded step builders: train_step / prefill_step / serve_step.

Each builder returns (jitted_fn, abstract_args, in_shardings, out_shardings)
ready for ``.lower(...)`` in the dry-run or for real execution in the
launchers.  Params/optimizer state are passed as ShapeDtypeStructs in the
dry-run — nothing is allocated.
"""
from __future__ import annotations


import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.sharding import (
    batch_sharding,
    cache_sharding,
    enable_sharding_hints,
    param_sharding,
)
from repro.launch.specs import input_specs
from repro.models.config import ArchConfig, InputShape
from repro.models.transformer import (
    decode_step,
    forward_train,
    init_params,
    prefill,
)
from repro.train.optim import adamw, cosine_schedule


def abstract_params(cfg: ArchConfig):
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


def resolve_serve_mode(cfg: ArchConfig, mesh, mode: str) -> str:
    """Resolve 'serve_auto' against the FULL-depth config.  Must happen once,
    up front: the dry-run's 1-layer cost variants would otherwise re-decide
    with a tiny model and silently flip the weight layout."""
    if mode != "serve_auto":
        return mode
    from repro.dist.sharding import _fits_tp_only

    return "serve_tp" if _fits_tp_only(mesh, abstract_params(cfg)) else "serve"



def abstract_opt_state(cfg: ArchConfig, params_spec):
    init_fn, _ = adamw(1e-4)
    return jax.eval_shape(init_fn, params_spec)


def _opt_sharding(mesh, opt_spec, p_shard):
    """Optimizer moments share the param shardings; step is replicated."""
    return type(opt_spec)(
        step=NamedSharding(mesh, P()),
        mu=jax.tree_util.tree_map(lambda s, x: s, p_shard, opt_spec.mu),
        nu=jax.tree_util.tree_map(lambda s, x: s, p_shard, opt_spec.nu),
    )


def make_train_step(cfg: ArchConfig, mesh, shape: InputShape, *,
                    use_remat: bool = True, attn_impl: str = "blockwise",
                    lr: float = 3e-4, unroll: bool = False):
    enable_sharding_hints(mesh)
    init_fn, update_fn = adamw(cosine_schedule(lr, 10_000, 500), weight_decay=0.1)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: forward_train(p, cfg, batch, use_remat=use_remat,
                                    attn_impl=attn_impl, unroll=unroll)
        )(params)
        params, opt_state, aux = update_fn(grads, opt_state, params)
        return params, opt_state, {"loss": loss, **aux}

    p_spec = abstract_params(cfg)
    o_spec = abstract_opt_state(cfg, p_spec)
    specs = input_specs(cfg, shape)
    p_shard = param_sharding(mesh, p_spec, mode="train")
    o_shard = _opt_sharding(mesh, o_spec, p_shard)
    b_shard = batch_sharding(mesh, specs["batch"])
    out_shard = (p_shard, o_shard,
                 {"loss": NamedSharding(mesh, P()),
                  "grad_norm": NamedSharding(mesh, P()),
                  "lr": NamedSharding(mesh, P())})
    fn = jax.jit(
        train_step,
        in_shardings=(p_shard, o_shard, b_shard),
        out_shardings=out_shard,
        donate_argnums=(0, 1),
    )
    args = (p_spec, o_spec, specs["batch"])
    return fn, args


def make_prefill_step(cfg: ArchConfig, mesh, shape: InputShape, *,
                      attn_impl: str = "blockwise", mode: str = "serve",
                      unroll: bool = False):
    enable_sharding_hints(mesh)

    def prefill_step(params, batch):
        tokens = batch["tokens"]
        extra = {k: v for k, v in batch.items() if k != "tokens"}
        logits, cache = prefill(params, cfg, tokens, shape.seq_len, extra,
                                attn_impl=attn_impl, unroll=unroll)
        return logits, cache

    p_spec = abstract_params(cfg)
    specs = input_specs(cfg, shape)
    p_shard = param_sharding(mesh, p_spec, mode=mode)
    b_shard = batch_sharding(mesh, specs["batch"])
    cache_spec = jax.eval_shape(
        lambda p, b: prefill_step(p, b)[1], p_spec, specs["batch"]
    )
    out_shard = (
        batch_sharding(mesh, jax.eval_shape(lambda p, b: prefill_step(p, b)[0],
                                            p_spec, specs["batch"])),
        cache_sharding(mesh, cache_spec),
    )
    fn = jax.jit(prefill_step, in_shardings=(p_shard, b_shard),
                 out_shardings=out_shard)
    return fn, (p_spec, specs["batch"])


def make_serve_step(cfg: ArchConfig, mesh, shape: InputShape, *,
                    mode: str = "serve", unroll: bool = False):
    """mode 'serve_ws': weight-stationary decode — weights keep the train
    (data, model) layout and are never gathered; the decode BATCH shards
    over the model axis instead, so every d-contraction partial-sums
    single-token activations (KBs) rather than all-gathering weights (GBs).
    Requires global_batch %% model_axis == 0."""
    ws = mode == "serve_ws" and shape.global_batch % mesh.shape["model"] == 0
    enable_sharding_hints(mesh, batch_axes=("model",) if ws else None)
    if mode == "serve_ws":
        mode = "train"   # weights stay in the FSDP+TP train layout, ungathered

    def serve_step(params, token, cache):
        return decode_step(params, cfg, token, cache, unroll=unroll)

    p_spec = abstract_params(cfg)
    specs = input_specs(cfg, shape)
    p_shard = param_sharding(mesh, p_spec, mode=mode)
    t_shard = batch_sharding(mesh, specs["token"])
    c_shard = cache_sharding(mesh, specs["cache"])
    logits_spec = jax.eval_shape(serve_step, p_spec, specs["token"], specs["cache"])
    out_shard = (batch_sharding(mesh, logits_spec[0]), c_shard)
    fn = jax.jit(serve_step, in_shardings=(p_shard, t_shard, c_shard),
                 out_shardings=out_shard, donate_argnums=(2,))
    return fn, (p_spec, specs["token"], specs["cache"])


def make_step(cfg: ArchConfig, mesh, shape: InputShape, **kw):
    if shape.kind == "train":
        return make_train_step(cfg, mesh, shape, **kw)
    if shape.kind == "prefill":
        return make_prefill_step(cfg, mesh, shape, **kw)
    return make_serve_step(cfg, mesh, shape, **kw)
