"""Heterogeneous serving demo: typed entities + the hybrid GNN->GBDT head.

Walks the full multi-entity-type story end to end on a streaming
:class:`FraudService` (see ``docs/graphs.md`` for the entity-type schema
and the attack catalog):

  1. TYPED STREAM — ``repro.data.attacks`` emits checkouts whose entities
     are type-tagged ``(buyer, merchant, device, payment)`` ids
     (``core.hetero.tag_entity``); ``ModelSection.entity_types`` switches
     the whole stack — builder, KV keyspace, per-type entity towers — into
     heterogeneous mode from ONE config field;
  2. REPLAY       — the service ingests the stream; the speed layer scores
     with per-type towers (fused Pallas path included);
  3. HYBRID       — freeze the GNN, read back snapshot-versioned
     embeddings, train a GBDT on them (``models.hybrid``), then
     ``register_model`` / ``activate_model`` the hybrid as a normal model
     version — a hot-swap, not a special case;
  4. CHECKPOINT   — WAL + checkpoint persist the hybrid (GBDT trees ride
     inside the npz); ``FraudService.restore`` brings back a service whose
     scores match bit-for-bit;
  5. REJECTION    — an untagged entity id aimed at a heterogeneous
     keyspace fails loudly at the KV boundary, never silently mis-shards.

Run:  PYTHONPATH=src python examples/hetero_serving.py [--smoke]
"""
import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.core import ENTITY_TYPE_NAMES, LNNConfig, lnn_init, lnn_stage2_embed
from repro.core.hetero import type_code_of
from repro.data.attacks import AttackConfig, generate_attack_stream
from repro.models.hybrid import train_hybrid
from repro.service import FraudService, ModelSection, ServiceConfig
from repro.stream.events import CheckoutEvent


def main(smoke: bool = False):
    acfg = (AttackConfig(num_buyers=60, num_merchants=12, num_rings=2,
                         ring_size=5, num_bursts=1, burst_orders=10,
                         num_bin_runs=1, bin_cards=8, num_snapshots=10)
            if smoke else AttackConfig())
    events, patterns = generate_attack_stream(acfg)
    frac = float(np.mean([ev.label for ev in events]))
    print(f"== typed attack stream: {len(events)} events, "
          f"fraud={frac:.2f}, patterns={sorted(set(map(str, patterns)))} ==")

    cfg = LNNConfig(num_gnn_layers=2, hidden_dim=32,
                    feat_dim=events[0].features.shape[0], pos_weight=3.0,
                    entity_types=ENTITY_TYPE_NAMES)
    params = lnn_init(jax.random.PRNGKey(0), cfg)
    config = ServiceConfig(
        mode="streaming", model=ModelSection.from_lnn_config(cfg),
    ).replace(engine={"max_batch": 8})
    print(f"   model.entity_types={config.model.entity_types} "
          "(one field flips the stack heterogeneous)")

    root = tempfile.mkdtemp(prefix="hetero_svc_")
    svc = FraudService(config, params).build().enable_wal(root)
    half = len(events) // 2
    rep = svc.replay(events[:half])
    print(f"\n== replayed {half} typed events (per-type towers on the "
          f"speed layer); {len(rep.scores_by_order())} scored ==")

    # --- hybrid head: frozen GNN embedding -> GBDT -------------------------
    eng = svc.engine
    done = events[:half]
    key_lists = [eng.ingester.builder.entity_keys(ev.entities, ev.snapshot)
                 for ev in done]
    k_max = svc.config.engine.k_max
    emb, mask, _ = svc.store.lookup_batch_versioned(key_lists, k_max)
    # slot -> entity-type codes, straight from the tagged ids
    st = np.full((len(done), k_max), -1, np.int32)
    for i, keys in enumerate(key_lists):
        for j, (ent, _t) in enumerate(keys[:k_max]):
            st[i, j] = type_code_of(int(ent))
    feats = np.stack([ev.features for ev in done]).astype(np.float32)
    x = np.asarray(lnn_stage2_embed(params, cfg, emb, mask, feats,
                                    slot_type=st), np.float32)
    y = np.asarray([ev.label for ev in done])
    hybrid = train_hybrid(params, cfg, x, y)
    v = svc.register_model(hybrid, version=1)
    svc.activate_model(v)
    print(f"\n== hybrid registered+activated as v{v} "
          f"(gbdt over {x.shape[1]}-dim frozen embeddings) ==")

    # --- crash consistency: typed keys + GBDT survive checkpoint/restore ---
    svc.checkpoint()   # snapshot the service right after the hybrid swap
    tail = events[half:]
    n_tail = len(svc.replay(tail, warmup=False).scores_by_order())
    print(f"   tail scored by the hybrid: {n_tail} orders, "
          f"active version={svc.model_version}")
    # restore = checkpoint + WAL-suffix replay, so svc2 lands in exactly
    # svc's state; identical probe traffic must then score bit-identically
    svc2 = FraudService.restore(root)
    probes = [CheckoutEvent(order_id=50_000 + i, snapshot=acfg.num_snapshots,
                            entities=ev.entities, features=ev.features,
                            label=ev.label, arrival=tail[-1].arrival + 1.0 + i)
              for i, ev in enumerate(tail[-8:])]
    s1 = svc.replay(probes, warmup=False).scores_by_order()
    s2 = svc2.replay(probes, warmup=False).scores_by_order()
    same = set(s1) == set(s2) and all(s2[o] == s1[o] for o in s1)
    print(f"\n== restore from {root}: probe scores bit-identical={same}, "
          f"version={svc2.model_version} ==")
    assert same, "restore must reproduce the typed+hybrid run bit-for-bit"

    # --- untagged ids fail loudly at the KV boundary -----------------------
    # the store was built with require_typed=True (because
    # model.entity_types is non-empty): a legacy raw id can't silently
    # mis-shard into the heterogeneous keyspace
    from repro.serve.kvstore import pack_key
    try:
        pack_key(7, 0, require_typed=True)   # raw id, no type tag
        raise AssertionError("untagged ids must be rejected")
    except ValueError as e:
        print(f"\n== untagged id rejected loudly at the KV boundary ==\n   {e}")

    svc.close()
    svc2.close()
    print("\ndone — typed stream served, hybrid swapped, restore verified")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny sizes for CI")
    main(ap.parse_args().smoke)
