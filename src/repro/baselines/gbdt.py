"""Histogram gradient-boosted decision trees (binary logloss) in numpy.

Stand-in for LightGBM (paper §4.2's LGB baseline) — same algorithmic family:
quantile feature binning, second-order (grad/hess) histogram split finding,
depth-wise growth, shrinkage, L2 leaf regularization.

Also provides the paper's feature-encoding trick: "we use the encoded
features from an existing LightGBM" — ``leaf_value_features`` maps each
sample to its per-tree leaf values (n_trees-dim dense encoding), which then
feed the MLP and LNN models.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class GBDTConfig:
    num_trees: int = 60
    max_depth: int = 4
    learning_rate: float = 0.15
    num_bins: int = 32
    min_child_weight: float = 1.0
    reg_lambda: float = 1.0
    min_gain: float = 1e-6


@dataclass
class _Tree:
    # flat arrays indexed by node id; leaves have feature == -1
    feature: np.ndarray
    threshold_bin: np.ndarray
    left: np.ndarray
    right: np.ndarray
    value: np.ndarray

    def predict_bins(self, xb: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Returns (leaf_value, leaf_index) per sample for binned input."""
        n = xb.shape[0]
        node = np.zeros(n, np.int64)
        active = self.feature[node] >= 0
        while active.any():
            f = self.feature[node[active]]
            thr = self.threshold_bin[node[active]]
            go_left = xb[active, f] <= thr
            nxt = np.where(go_left, self.left[node[active]], self.right[node[active]])
            node[active] = nxt
            active = self.feature[node] >= 0
        return self.value[node], node


@dataclass
class GBDTModel:
    cfg: GBDTConfig
    bin_edges: list = field(default_factory=list)   # per feature
    trees: list = field(default_factory=list)
    base_score: float = 0.0

    # ---------------------------------------------------------------- utils
    def bin_data(self, x: np.ndarray) -> np.ndarray:
        xb = np.empty(x.shape, np.int32)
        for j, edges in enumerate(self.bin_edges):
            xb[:, j] = np.searchsorted(edges, x[:, j], side="left")
        return xb

    def raw_predict(self, x: np.ndarray) -> np.ndarray:
        xb = self.bin_data(x)
        out = np.full(x.shape[0], self.base_score)
        for t in self.trees:
            out += t.predict_bins(xb)[0]
        return out

    def predict_proba(self, x: np.ndarray) -> np.ndarray:
        return 1.0 / (1.0 + np.exp(-self.raw_predict(x)))

    def leaf_value_features(self, x: np.ndarray) -> np.ndarray:
        """Per-tree leaf values — the dense 'LGB-encoded' feature vector."""
        xb = self.bin_data(x)
        cols = [t.predict_bins(xb)[0] for t in self.trees]
        return np.stack(cols, axis=1).astype(np.float32)


def _fit_tree(xb, grad, hess, cfg: GBDTConfig, num_bins_per_feat):
    n, d = xb.shape
    feature = [-1]
    thr = [0]
    left = [-1]
    right = [-1]
    value = [0.0]
    # frontier: (node_id, sample_idx, depth)
    frontier = [(0, np.arange(n), 0)]
    while frontier:
        nid, idx, depth = frontier.pop()
        g_sum = grad[idx].sum()
        h_sum = hess[idx].sum()
        value[nid] = -g_sum / (h_sum + cfg.reg_lambda)
        if depth >= cfg.max_depth or idx.size < 2:
            continue
        parent_score = g_sum * g_sum / (h_sum + cfg.reg_lambda)
        best = (cfg.min_gain, -1, -1)  # (gain, feat, bin)
        for f in range(d):
            nb = num_bins_per_feat[f]
            gh = np.zeros((nb, 2))
            np.add.at(gh, xb[idx, f], np.stack([grad[idx], hess[idx]], 1))
            gl = np.cumsum(gh[:, 0])
            hl = np.cumsum(gh[:, 1])
            gr = g_sum - gl
            hr = h_sum - hl
            ok = (hl >= cfg.min_child_weight) & (hr >= cfg.min_child_weight)
            gain = np.where(
                ok,
                gl * gl / (hl + cfg.reg_lambda)
                + gr * gr / (hr + cfg.reg_lambda)
                - parent_score,
                -np.inf,
            )
            b = int(np.argmax(gain))
            if gain[b] > best[0]:
                best = (float(gain[b]), f, b)
        if best[1] < 0:
            continue
        _, f, b = best
        go_left = xb[idx, f] <= b
        l_id, r_id = len(feature), len(feature) + 1
        feature += [-1, -1]
        thr += [0, 0]
        left += [-1, -1]
        right += [-1, -1]
        value += [0.0, 0.0]
        feature[nid], thr[nid], left[nid], right[nid] = f, b, l_id, r_id
        frontier.append((l_id, idx[go_left], depth + 1))
        frontier.append((r_id, idx[~go_left], depth + 1))
    return _Tree(
        feature=np.asarray(feature, np.int64),
        threshold_bin=np.asarray(thr, np.int64),
        left=np.asarray(left, np.int64),
        right=np.asarray(right, np.int64),
        value=np.asarray(value, np.float64),
    )


def train_gbdt(
    x: np.ndarray,
    y: np.ndarray,
    cfg: GBDTConfig = GBDTConfig(),
    x_val: np.ndarray | None = None,
    y_val: np.ndarray | None = None,
    early_stop_rounds: int = 10,
) -> GBDTModel:
    """Fit with optional early stopping on validation logloss."""
    y = y.astype(np.float64)
    model = GBDTModel(cfg=cfg)
    # quantile bin edges
    for j in range(x.shape[1]):
        qs = np.quantile(x[:, j], np.linspace(0, 1, cfg.num_bins + 1)[1:-1])
        model.bin_edges.append(np.unique(qs))
    xb = model.bin_data(x)
    num_bins_per_feat = [len(e) + 1 for e in model.bin_edges]

    p0 = np.clip(y.mean(), 1e-6, 1 - 1e-6)
    model.base_score = float(np.log(p0 / (1 - p0)))
    raw = np.full(x.shape[0], model.base_score)
    raw_val = None
    if x_val is not None:
        xb_val = model.bin_data(x_val)
        raw_val = np.full(x_val.shape[0], model.base_score)
    best_loss, best_ntrees, stall = np.inf, 0, 0

    for _ in range(cfg.num_trees):
        p = 1.0 / (1.0 + np.exp(-raw))
        grad = p - y
        hess = np.maximum(p * (1 - p), 1e-12)
        tree = _fit_tree(xb, grad, hess, cfg, num_bins_per_feat)
        tree.value *= cfg.learning_rate
        model.trees.append(tree)
        raw += tree.predict_bins(xb)[0]
        if raw_val is not None:
            raw_val += tree.predict_bins(xb_val)[0]
            pv = np.clip(1.0 / (1.0 + np.exp(-raw_val)), 1e-9, 1 - 1e-9)
            loss = -(y_val * np.log(pv) + (1 - y_val) * np.log(1 - pv)).mean()
            if loss < best_loss - 1e-7:
                best_loss, best_ntrees, stall = loss, len(model.trees), 0
            else:
                stall += 1
                if stall >= early_stop_rounds:
                    model.trees = model.trees[:best_ntrees]
                    break
    return model
