"""Sharding resolver + roofline extraction unit tests (no 512-device init —
these test the pure logic on the real 1-CPU backend)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import resolve_spec
from repro.launch.roofline import (
    _shape_bytes,
    collective_bytes,
    model_flops,
    active_param_count,
)
from repro.configs import get_config
from repro.models.config import INPUT_SHAPES


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_resolve_spec_drops_nondivisible():
    mesh = FakeMesh({"data": 16, "model": 16})
    # divisible: kept
    assert resolve_spec(mesh, (64, 32), P("data", "model")) == P("data", "model")
    # non-divisible dim: dropped (replicated)
    assert resolve_spec(mesh, (56, 32), P("data", "model")) == P(None, "model")
    # leading stack dims get None padding
    assert resolve_spec(mesh, (4, 64, 32), P("data", "model")) == P(None, "data", "model")
    # tuple axes multiply
    mesh2 = FakeMesh({"pod": 2, "data": 16, "model": 16})
    assert resolve_spec(mesh2, (64,), P(("pod", "data"))) == P(("pod", "data"))
    assert resolve_spec(mesh2, (48,), P(("pod", "data"))) == P(None)


def test_shape_bytes_parser():
    assert _shape_bytes("bf16[16,4096,512]{2,1,0}") == 16 * 4096 * 512 * 2
    assert _shape_bytes("f32[8]") == 32
    assert _shape_bytes("(f32[2,2]{1,0}, s32[4]{0})") == 16 + 16
    assert _shape_bytes("pred[7]") == 7


def test_collective_bytes_parses_hlo_snippets():
    hlo = """
  %ag = f32[16,128]{1,0} all-gather(f32[1,128]{1,0} %x), replica_groups={}
  %ar.1 = bf16[64]{0} all-reduce(bf16[64]{0} %y), to_apply=%sum
  %a2a = f32[8,8]{1,0} all-to-all(f32[8,8]{1,0} %z), dimensions={0}
  %cp = u32[4]{0} collective-permute(u32[4]{0} %w), source_target_pairs={{0,1}}
  %rs = f32[2,64]{1,0} reduce-scatter(f32[16,64]{1,0} %v), dimensions={0}
"""
    out = collective_bytes(hlo)
    assert out["bytes"]["all-gather"] == 16 * 128 * 4
    assert out["bytes"]["all-reduce"] == 64 * 2
    assert out["bytes"]["all-to-all"] == 8 * 8 * 4
    assert out["bytes"]["collective-permute"] == 16
    assert out["bytes"]["reduce-scatter"] == 2 * 64 * 4
    assert out["counts"]["all-gather"] == 1


def test_collective_bytes_skips_async_done_pairs():
    hlo = """
  %ag-start = f32[128]{0} all-gather-start(f32[8]{0} %x)
  %ag-done = f32[128]{0} all-gather-done(f32[128]{0} %ag-start)
"""
    out = collective_bytes(hlo)
    assert out["counts"]["all-gather"] == 1


def test_active_params_sane():
    """Active-parameter estimates should be within ~20% of the advertised
    sizes (they exclude frontend stubs and fine structure)."""
    approx = {
        "mamba2-370m": 0.37e9,
        "granite-3-2b": 2.5e9,
        "yi-34b": 34e9,
        "olmo-1b": 1.2e9,
        "qwen1.5-32b": 32e9,
        "mixtral-8x22b": 39e9,    # active ~39B of 141B total
        "phi3.5-moe-42b-a6.6b": 6.6e9,
    }
    for arch, want in approx.items():
        got = active_param_count(get_config(arch))
        assert 0.6 * want < got < 1.6 * want, f"{arch}: {got/1e9:.2f}B vs {want/1e9:.2f}B"


def test_model_flops_kinds():
    cfg = get_config("olmo-1b")
    n = active_param_count(cfg)
    t4k = INPUT_SHAPES["train_4k"]
    assert model_flops(cfg, t4k) == pytest.approx(6 * n * 256 * 4096)
    dec = INPUT_SHAPES["decode_32k"]
    assert model_flops(cfg, dec) == pytest.approx(2 * n * 128)


def test_sharded_train_step_single_device(small_fraud_dataset):
    """The sharded train-step builder must also run on a real 1x1 mesh (the
    degenerate production config) — executes one real step on CPU."""
    from repro.launch.mesh import make_host_mesh
    from repro.launch.steps import make_train_step
    from repro.models.config import InputShape
    from repro.configs import get_config
    from repro.models import init_params
    from repro.train.optim import adamw

    cfg = get_config("olmo-1b").reduced()
    mesh = make_host_mesh()
    shape = InputShape("tiny_train", 16, 2, "train")
    fn, args = make_train_step(cfg, mesh, shape, use_remat=False)
    params = init_params(jax.random.PRNGKey(0), cfg)
    init_fn, _ = adamw(1e-3)
    opt = init_fn(params)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32)}
    with mesh:
        params2, opt2, aux = fn(params, opt, batch)
    assert np.isfinite(float(aux["loss"]))
