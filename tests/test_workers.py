"""Multi-worker sharded speed layer: router key-affinity, rendezvous
minimal movement, explicit-reshard-only semantics, work stealing, the
reorder collector, and per-worker flush independence."""
import numpy as np
import pytest

from repro.dist.sharding import rendezvous_shard, splitmix64, stable_shard
from repro.serve.kvstore import KVStore, entity_shard, pack_key
from repro.stream import (
    MicroBatcher,
    ScoreRequest,
    ShardRouter,
    WorkerPool,
)
from repro.stream.workers import SpeedLayerWorker, _ReorderBuffer


# ------------------------------------------------------------------ hashing
def test_splitmix64_avalanches_consecutive_keys():
    outs = {splitmix64(i) for i in range(1000)}
    assert len(outs) == 1000
    # avalanche: consecutive inputs land in different 32-bit high halves
    highs = {splitmix64(i) >> 32 for i in range(1000)}
    assert len(highs) > 990


def test_stable_and_rendezvous_shards_cover_all_buckets():
    for n in (2, 3, 8):
        assert {stable_shard(k, n) for k in range(500)} == set(range(n))
        assert {rendezvous_shard(k, n) for k in range(500)} == set(range(n))


def test_rendezvous_minimal_movement():
    """Growing n -> n+1 moves only keys that land on the NEW shard — no key
    migrates between surviving shards (the property that makes explicit
    resharding cheap for warm workers)."""
    keys = range(2000)
    for n in (1, 2, 4, 7):
        before = {k: rendezvous_shard(k, n) for k in keys}
        after = {k: rendezvous_shard(k, n + 1) for k in keys}
        moved = [k for k in keys if before[k] != after[k]]
        assert all(after[k] == n for k in moved)
        # roughly 1/(n+1) of keys move (loose bounds, fixed key set)
        frac = len(moved) / len(before)
        assert 0.3 / (n + 1) < frac < 2.5 / (n + 1)


# ------------------------------------------------------------------- router
def test_router_matches_entity_affine_store():
    """The affinity contract: a request routed to worker w only ever needs
    KV reads for its primary entity from shard w of an entity-affine store
    with num_shards == num_workers."""
    n = 4
    router = ShardRouter(n)
    store = KVStore(dim=2, num_shards=n, shard_by_entity=True)
    for ent in range(200):
        w = router.worker_of(ent)
        for t in (0, 3, 17):
            assert store.shard_of(pack_key(ent, t)) == w
        assert entity_shard(ent, n) == w


def test_router_routes_by_primary_entity_and_pins_cold_requests():
    router = ShardRouter(3)
    keys = [(42, 5), (99, 2)]
    assert router.route(keys) == router.worker_of(42)
    assert router.route([]) == 0


def test_router_worker_count_changes_only_via_reshard():
    router = ShardRouter(2)
    with pytest.raises(AttributeError):
        router.num_workers = 5
    assert router.num_workers == 2 and router.epoch == 0
    before = {e: router.worker_of(e) for e in range(100)}
    epoch = router.reshard(3)
    assert epoch == 1 and router.num_workers == 3
    after = {e: router.worker_of(e) for e in range(100)}
    moved = [e for e in before if before[e] != after[e]]
    assert moved, "resharding 2 -> 3 must move some entities"
    assert all(after[e] == 2 for e in moved)   # rendezvous: all to new worker
    with pytest.raises(ValueError):
        router.reshard(0)


# ----------------------------------------------------- router property tests
def _router_property_tests():
    pytest.importorskip("hypothesis", reason="hypothesis not installed")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=200, deadline=None)
    @given(entity=st.integers(min_value=0, max_value=2**40),
           n=st.integers(min_value=1, max_value=16))
    def affinity_is_instance_independent(entity, n):
        """route() is a pure function of (entity, worker count): any two
        routers with the same count agree — affinity never drifts with
        router lifetime, construction order, or prior traffic."""
        a, b = ShardRouter(n), ShardRouter(n)
        assert a.worker_of(entity) == b.worker_of(entity)
        assert a.worker_of(entity) == entity_shard(entity, n)
        assert 0 <= a.worker_of(entity) < n

    @settings(max_examples=100, deadline=None)
    @given(entities=st.lists(st.integers(min_value=0, max_value=2**40),
                             min_size=1, max_size=50),
           n=st.integers(min_value=1, max_value=8),
           grow=st.integers(min_value=1, max_value=4))
    def mapping_changes_only_through_reshard(entities, n, grow):
        """Without reshard() the mapping is frozen; after reshard(n + grow)
        it equals a fresh router's at the new count, and every moved entity
        lands on one of the added workers (rendezvous minimal movement)."""
        router = ShardRouter(n)
        before = [router.worker_of(e) for e in entities]
        # re-querying never changes anything (no hidden rebalancing)
        assert [router.worker_of(e) for e in entities] == before
        router.reshard(n + grow)
        fresh = ShardRouter(n + grow)
        after = [router.worker_of(e) for e in entities]
        assert after == [fresh.worker_of(e) for e in entities]
        for b, a in zip(before, after):
            assert a == b or a >= n

    affinity_is_instance_independent()
    mapping_changes_only_through_reshard()


def test_router_affinity_properties():
    _router_property_tests()


# ---------------------------------------------------------- reorder buffer
def _result(seq, score=0.5):
    from repro.stream.microbatch import ScoredResult

    req = ScoreRequest(features=np.zeros(2, np.float32), entity_keys=[],
                       arrival=0.0, seq=seq)
    return ScoredResult(request=req, score=score, staleness=-1,
                        queued_s=0.0, service_s=0.0, batch_size=1)


def test_reorder_buffer_releases_in_submission_order():
    rb = _ReorderBuffer()
    rb.add([_result(2), _result(1)])
    assert rb.release() == []                 # seq 0 still missing
    rb.add([_result(0)])
    out = rb.release()
    assert [r.request.seq for r in out] == [0, 1, 2]
    rb.add([_result(3)])
    assert [r.request.seq for r in rb.release()] == [3]
    assert len(rb) == 0


# ------------------------------------------------------------ worker/steal
def _const_score_fn(feats, key_lists):
    return np.full(feats.shape[0], 0.5), np.zeros(feats.shape[0], np.int32)


def _req(arrival, seq=-1, feat_dim=4, keys=()):
    return ScoreRequest(features=np.zeros(feat_dim, np.float32),
                        entity_keys=list(keys), arrival=arrival, seq=seq)


def test_worker_defers_flush_while_virtually_busy():
    """With a virtual service model, a size-triggered flush opens a service
    window; the next flush waits for it, so the queue backs up past
    max_batch — the condition work stealing exists for."""
    w = SpeedLayerWorker(0, _const_score_fn, max_batch=2, max_wait_s=10.0,
                         service_model_s=1.0)
    for i in range(6):
        w.enqueue(_req(arrival=0.1 * i, seq=i))
    out = w.pump(now=0.5)
    assert len(out) == 2                      # first batch served...
    assert w.busy_until == pytest.approx(1.1)  # trigger 0.1 + service 1.0
    assert len(w) == 4                        # ...rest deferred (backed up)
    out = w.pump(now=0.6)
    assert out == []                          # still busy
    out = w.pump(now=1.2)
    assert len(out) == 2 and len(w) == 2      # freed: one more batch
    assert w.stats["max_queue_depth"] == 6


def test_pool_steals_from_backed_up_shard():
    """An idle worker with an empty queue takes the oldest half of a
    backed-up victim's queue and serves it."""
    pool = WorkerPool.__new__(WorkerPool)   # bypass jit-scorer construction
    pool.router = ShardRouter(2)
    pool.max_batch = 2
    pool.steal_threshold = 3
    pool.workers = [
        SpeedLayerWorker(0, _const_score_fn, max_batch=2, max_wait_s=10.0,
                         service_model_s=5.0),
        SpeedLayerWorker(1, _const_score_fn, max_batch=2, max_wait_s=10.0,
                         service_model_s=5.0),
    ]
    pool._reorder = _ReorderBuffer()
    pool._seq = 0
    pool.pool_stats = {"steals": 0, "stolen_requests": 0, "routed": 0}
    victim, thief = pool.workers
    for i in range(6):
        victim.enqueue(_req(arrival=0.01 * i, seq=i))
    victim.busy_until = 100.0                 # victim stuck mid-service
    out = pool.poll(now=1.0)
    assert pool.pool_stats["steals"] == 1
    assert pool.pool_stats["stolen_requests"] == 3   # half of 6
    assert thief.stats["stolen_in"] == 3
    assert victim.stats["stolen_out"] == 3
    # thief size-flushed the first stolen batch immediately, in seq order
    assert [r.request.seq for r in out] == [0, 1]
    assert all(r.worker == 1 for r in out)
    assert len(victim) == 3 and len(thief) == 1
    # stamps floor at the steal time: the work could not have been served
    # before it reached the thief, so waits are not backdated to the
    # victim's long-missed triggers
    assert all(r.queued_s == pytest.approx(1.0 - r.request.arrival) for r in out)


def test_pool_does_not_steal_below_threshold():
    pool = WorkerPool.__new__(WorkerPool)
    pool.router = ShardRouter(2)
    pool.max_batch = 4
    pool.steal_threshold = 8
    pool.workers = [
        SpeedLayerWorker(0, _const_score_fn, max_batch=4, max_wait_s=10.0,
                         service_model_s=5.0),
        SpeedLayerWorker(1, _const_score_fn, max_batch=4, max_wait_s=10.0,
                         service_model_s=5.0),
    ]
    pool._reorder = _ReorderBuffer()
    pool._seq = 0
    pool.pool_stats = {"steals": 0, "stolen_requests": 0, "routed": 0}
    victim = pool.workers[0]
    for i in range(5):
        victim.enqueue(_req(arrival=0.01 * i, seq=i))
    victim.busy_until = 100.0
    pool.poll(now=1.0)
    assert pool.pool_stats["steals"] == 0 and len(victim) == 5


# ------------------------------------------------- microbatcher primitives
def test_take_steals_oldest_requests_atomically():
    mb = MicroBatcher(_const_score_fn, max_batch=8, max_wait_s=10.0)
    for i in range(5):
        mb.enqueue(_req(arrival=0.1 * i, seq=i))
    stolen = mb.take(2)
    assert [r.seq for r in stolen] == [0, 1]
    assert len(mb) == 3 and mb.stats["stolen"] == 2
    assert mb.oldest_arrival == pytest.approx(0.2)
    assert mb.take(0) == []
    assert len(mb.take(99)) == 3 and len(mb) == 0
