"""Minimal pure-JAX optimizer stack (no optax in this environment).

Provides AdamW with decoupled weight decay, global-norm gradient clipping and
a warmup+cosine LR schedule — the standard training substrate for both the
GNN (paper) models and the transformer zoo.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jnp.ndarray  # int32 scalar
    mu: any  # first moment pytree
    nu: any  # second moment pytree


def cosine_schedule(
    base_lr: float,
    total_steps: int,
    warmup_steps: int = 0,
    final_frac: float = 0.0,
) -> Callable[[jnp.ndarray], jnp.ndarray]:
    """Linear warmup to ``base_lr`` then cosine decay to ``final_frac*base_lr``."""

    def schedule(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.where(
            warmup_steps > 0, jnp.minimum(step / jnp.maximum(warmup_steps, 1), 1.0), 1.0
        )
        progress = jnp.clip(
            (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * progress))
        decay = final_frac + (1.0 - final_frac) * cos
        return base_lr * warm * decay

    return schedule


def clip_by_global_norm(grads, max_norm: float):
    """Clip a gradient pytree to a maximum global L2 norm; returns (grads, norm)."""
    leaves = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-12))
    return jax.tree_util.tree_map(lambda g: (g * scale).astype(g.dtype), grads), gnorm


def adamw(
    learning_rate: float | Callable = 1e-3,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    clip_norm: float | None = 1.0,
):
    """Returns (init_fn, update_fn) in the optax convention.

    ``update_fn(grads, state, params) -> (new_params, new_state, aux)``.
    Weight decay is decoupled (applied to params directly, not to moments)
    and skipped for 1-D leaves (biases, layernorm scales) — standard practice.
    """
    lr_fn = learning_rate if callable(learning_rate) else (lambda _: learning_rate)

    def init_fn(params) -> OptState:
        zeros = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return OptState(step=jnp.zeros((), jnp.int32), mu=zeros, nu=jax.tree_util.tree_map(jnp.copy, zeros))

    def update_fn(grads, state: OptState, params):
        if clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        else:
            leaves = jax.tree_util.tree_leaves(grads)
            gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
        step = state.step + 1
        lr = lr_fn(step)
        b1t = 1.0 - jnp.power(jnp.float32(b1), step.astype(jnp.float32))
        b2t = 1.0 - jnp.power(jnp.float32(b2), step.astype(jnp.float32))

        def upd(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m = b1 * m + (1.0 - b1) * g32
            v = b2 * v + (1.0 - b2) * jnp.square(g32)
            mhat = m / b1t
            vhat = v / b2t
            delta = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay and p.ndim >= 2:
                delta = delta + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state.mu)
        flat_v = treedef.flatten_up_to(state.nu)
        out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        aux = {"grad_norm": gnorm, "lr": lr}
        return new_p, OptState(step=step, mu=new_m, nu=new_v), aux

    return init_fn, update_fn
