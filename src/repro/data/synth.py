"""Synthetic e-commerce transaction generator with fraud rings.

The production dataset in the paper is proprietary (months of e-commerce
checkouts with chargeback labels).  This generator reproduces the structure
the paper exploits, so that its qualitative claims are testable:

* bipartite order↔entity graph over 7 entity types (shipping address, email,
  IP, device id, contact phone, payment token, account) — paper §3.2;
* **legitimate users**: stable personal entity sets, occasional shared IPs,
  Poisson purchase times spread over all snapshots;
* **fraud rings**: a small pool of shared entities (stolen payment tokens,
  common devices/IPs) reused by many fake accounts, bursty activity within a
  short snapshot window — the "gang of ~1000" business intuition;
* **raw tabular features** that are *weakly* predictive on their own (heavy
  class overlap) plus a delayed past-chargeback-count velocity feature —
  the graph linkage is where most of the signal lives, which is exactly the
  regime where LNN should beat LGB/MLP (paper Table 3).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.dds import StaticGraph

ENTITY_TYPES = ("ship_addr", "email", "ip", "device", "phone", "pay_token", "account")
NUM_ENTITY_TYPES = len(ENTITY_TYPES)
RAW_FEATURES = (
    "amount_log", "item_count", "hour_sin", "hour_cos", "account_age",
    "addr_match", "past_chargebacks", "session_len", "num_payment_retries",
    "basket_entropy", "is_guest", "shipping_speed",
)
NUM_RAW_FEATURES = len(RAW_FEATURES)


@dataclass
class SynthConfig:
    num_users: int = 400
    num_rings: int = 8
    ring_size: int = 8              # fraudster accounts per ring
    orders_per_user: float = 3.0    # Poisson mean over the whole window
    orders_per_fraudster: float = 2.5
    num_snapshots: int = 30         # paper: one snapshot = one day
    ring_burst_len: int = 4         # snapshots a ring stays active
    ring_entity_pool: int = 6       # shared entities per type inside a ring
    lone_fraudster_frac: float = 0.012  # background stolen-card fraud, per-order rate
    shared_ip_frac: float = 0.08    # legit users occasionally share IPs
    chargeback_delay: int = 3       # snapshots before a fraud label is visible
    feature_noise: float = 1.0      # raw-feature class overlap (higher=harder)
    seed: int = 0


def _legit_features(rng, n, t, past_cb):
    hour = rng.uniform(0, 24, n)
    return np.stack(
        [
            rng.normal(3.2, 0.9, n),                       # amount_log
            rng.poisson(2.0, n).astype(np.float64),        # item_count
            np.sin(2 * np.pi * hour / 24),
            np.cos(2 * np.pi * hour / 24),
            rng.gamma(4.0, 90.0, n),                       # account_age (days)
            (rng.uniform(size=n) < 0.9).astype(np.float64),  # addr_match
            past_cb,
            rng.gamma(2.0, 120.0, n),                      # session_len
            rng.poisson(0.1, n).astype(np.float64),
            rng.uniform(0.2, 1.0, n),                      # basket_entropy
            (rng.uniform(size=n) < 0.15).astype(np.float64),
            rng.integers(1, 4, n).astype(np.float64),
        ],
        axis=1,
    )


def _fraud_features(rng, n, t, past_cb, noise):
    """Deliberately heavy class overlap: each marginal shift is small (scaled
    down by ``noise``), so tabular models reach paper-level but not perfect
    scores; most of the remaining signal lives in the *graph linkage*."""
    s = 1.0 / max(noise, 1e-6)
    hour = (rng.uniform(0, 24, n) + rng.normal(2.0 * s, 5.0, n)) % 24
    return np.stack(
        [
            rng.normal(3.2 + 0.18 * s, 0.9, n),
            rng.poisson(2.0 + 0.25 * s, n).astype(np.float64),
            np.sin(2 * np.pi * hour / 24),
            np.cos(2 * np.pi * hour / 24),
            rng.gamma(4.0 - 1.2 * s, 90.0 - 20.0 * s, n),  # slightly younger
            (rng.uniform(size=n) < 0.9 - 0.12 * s).astype(np.float64),
            past_cb,
            rng.gamma(2.0 - 0.3 * s, 120.0 - 15.0 * s, n),
            rng.poisson(0.1 + 0.15 * s, n).astype(np.float64),
            rng.uniform(0.2 - 0.1 * s, 1.0 - 0.05 * s, n),
            (rng.uniform(size=n) < 0.15 + 0.1 * s).astype(np.float64),
            rng.integers(1, 4, n).astype(np.float64) + (rng.uniform(size=n) < 0.3 * s),
        ],
        axis=1,
    )


def generate_transactions(cfg: SynthConfig) -> tuple[StaticGraph, np.ndarray]:
    """Returns (static_graph, entity_type[num_entities])."""
    rng = np.random.default_rng(cfg.seed)
    next_entity = 0
    entity_type: list[int] = []

    def new_entity(et: int) -> int:
        nonlocal next_entity
        entity_type.append(et)
        nid = next_entity
        next_entity += 1
        return nid

    # shared legit IP pool (cafes, offices, NAT)
    shared_ips = [new_entity(ENTITY_TYPES.index("ip")) for _ in range(max(2, cfg.num_users // 25))]

    # --- legit users -------------------------------------------------------
    user_entities = []
    for _ in range(cfg.num_users):
        ents = {et: new_entity(i) for i, et in enumerate(ENTITY_TYPES)}
        if rng.uniform() < cfg.shared_ip_frac:
            ents["ip"] = shared_ips[rng.integers(len(shared_ips))]
        user_entities.append(ents)

    # --- fraud rings --------------------------------------------------------
    rings = []
    for _ in range(cfg.num_rings):
        pool = {
            et: [new_entity(i) for _ in range(cfg.ring_entity_pool)]
            for i, et in enumerate(ENTITY_TYPES)
            if et in ("ip", "device", "pay_token", "ship_addr")
        }
        accounts = []
        for _ in range(cfg.ring_size):
            # each fake account has its own email/phone/account id but draws
            # ip/device/pay_token/ship_addr from the shared ring pool
            ents = {}
            for i, et in enumerate(ENTITY_TYPES):
                if et in pool:
                    ents[et] = pool[et][rng.integers(len(pool[et]))]
                else:
                    ents[et] = new_entity(i)
            accounts.append(ents)
        rings.append(accounts)

    # stratify ring activity windows over the whole timeline (with jitter) so
    # every evaluation split sees some ring activity — fraud never "stops" in
    # production either
    ring_starts = []
    span = max(cfg.num_snapshots - cfg.ring_burst_len, 1)
    for r in range(cfg.num_rings):
        base = int(round(r * span / max(cfg.num_rings - 1, 1)))
        jitter = int(rng.integers(-2, 3))
        ring_starts.append(int(np.clip(base + jitter, 0, span)))
    rings = list(zip(rings, ring_starts))

    # --- emit orders --------------------------------------------------------
    rows_edges: list[tuple[int, int]] = []
    order_snapshot: list[int] = []
    order_is_fraud: list[int] = []
    order_owner: list[tuple[str, int]] = []  # ('legit', user) | ('ring', ring)

    def emit(ents: dict, t: int, fraud: int, owner):
        o = len(order_snapshot)
        order_snapshot.append(t)
        order_is_fraud.append(fraud)
        order_owner.append(owner)
        for et_name, eid in ents.items():
            # entities occasionally rotate (new IPs when travelling etc.)
            rows_edges.append((o, eid))
        return o

    for u, ents in enumerate(user_entities):
        n = rng.poisson(cfg.orders_per_user)
        for t in np.sort(rng.integers(0, cfg.num_snapshots, n)):
            e = dict(ents)
            if rng.uniform() < 0.1:  # mobile IP churn
                e["ip"] = shared_ips[rng.integers(len(shared_ips))]
            emit(e, int(t), 0, ("legit", u))

    for r, (accounts, start) in enumerate(rings):
        for a, ents in enumerate(accounts):
            n = rng.poisson(cfg.orders_per_fraudster)
            ts = start + rng.integers(0, cfg.ring_burst_len, n)
            for t in np.sort(ts):
                t = int(min(t, cfg.num_snapshots - 1))
                emit(dict(ents), t, 1, ("ring", r))

    # background lone fraudsters: fresh entities every time, spread uniformly
    # over *all* snapshots — opportunistic stolen-card fraud with no ring
    # structure (keeps every time split populated with positives and bounds
    # how much the graph alone can achieve)
    n_lone = rng.poisson(cfg.lone_fraudster_frac * cfg.num_users * cfg.orders_per_user)
    for t in rng.integers(0, cfg.num_snapshots, max(n_lone, cfg.num_snapshots // 10)):
        ents = {et: new_entity(i) for i, et in enumerate(ENTITY_TYPES)}
        emit(ents, int(t), 1, ("lone", -1))

    n_ord = len(order_snapshot)
    order_snapshot = np.asarray(order_snapshot, np.int64)
    labels = np.asarray(order_is_fraud, np.float32)

    # --- features (past_chargebacks needs account history with delay) -------
    # account id per order = the 'account' entity
    edges = np.asarray(rows_edges, np.int64)
    account_of = np.full(n_ord, -1, np.int64)
    acct_idx = ENTITY_TYPES.index("account")
    for o, eid in rows_edges:
        if entity_type[eid] == acct_idx:
            account_of[o] = eid
    features = np.zeros((n_ord, NUM_RAW_FEATURES), np.float64)
    # delayed chargeback counts per account
    order_by_time = np.argsort(order_snapshot, kind="stable")
    cb_count: dict[int, list[tuple[int, int]]] = {}
    past_cb = np.zeros(n_ord)
    for o in order_by_time:
        acct = account_of[o]
        t = order_snapshot[o]
        hist = cb_count.get(acct, [])
        past_cb[o] = sum(1 for (tt, y) in hist if y and tt + cfg.chargeback_delay <= t)
        hist.append((t, order_is_fraud[o]))
        cb_count[acct] = hist

    legit_mask = labels == 0
    n_legit = int(legit_mask.sum())
    n_fraud = n_ord - n_legit
    if n_legit:
        features[legit_mask] = _legit_features(rng, n_legit, None, past_cb[legit_mask])
    if n_fraud:
        features[~legit_mask] = _fraud_features(
            rng, n_fraud, None, past_cb[~legit_mask], cfg.feature_noise
        )

    g = StaticGraph(
        num_orders=n_ord,
        num_entities=next_entity,
        edges=edges,
        order_snapshot=order_snapshot,
        order_features=features.astype(np.float32),
        labels=labels,
        entity_type=np.asarray(entity_type, np.int32),
        num_snapshots=cfg.num_snapshots,
    )
    return g, np.asarray(entity_type, np.int32)


def generate_event_stream(
    cfg: SynthConfig,
    rate_per_s: float = 200.0,
    standardize: bool = True,
):
    """Synthetic checkout *stream* for the serving engine: the same fraud
    world as ``generate_transactions``, replayed in event-time order with
    Poisson arrivals.

    Features are z-scored with train-split statistics (time-based split, no
    leakage) when ``standardize`` — what a production feature service would
    emit.  Returns (events, static_graph, split).
    """
    from repro.data.pipeline import make_split_masks, standardize_features
    from repro.stream.events import events_from_static

    g, _ = generate_transactions(cfg)
    split = make_split_masks(g.order_snapshot)
    if standardize:
        feats, _ = standardize_features(g.order_features, split == 0)
        g.order_features = feats
    events = events_from_static(g, rate_per_s=rate_per_s, seed=cfg.seed)
    return events, g, split
