"""Pure-jnp oracles for every Pallas kernel.

These are the semantic ground truth: each kernel's test sweeps shapes/dtypes
and asserts allclose against the function here.  They are also the execution
path used on CPU and inside the sharded dry-run lowering (``use_pallas=False``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Graph kernels (paper's batch layer)
# ---------------------------------------------------------------------------

def csr_spmm_ref(h, nbr_idx, weights):
    """out[i] = sum_d weights[i, d] * h[nbr_idx[i, d]].

    h: [N, H]; nbr_idx: [N, D] int32; weights: [N, D]."""
    msgs = jnp.take(h, nbr_idx, axis=0)                # [N, D, H]
    return jnp.einsum("ndh,nd->nh", msgs, weights.astype(h.dtype))


def edge_softmax_agg_ref(z, s_src, s_dst, nbr_idx, nbr_mask, etype_bias):
    """GAT-style masked neighbor softmax + weighted aggregation.

    z: [N, H] transformed states; s_src/s_dst: [N] attention halves;
    nbr_idx/nbr_mask/etype_bias: [N, D].  Returns [N, H].
    """
    logits = jnp.take(s_src, nbr_idx, axis=0) + s_dst[:, None] + etype_bias
    logits = jax.nn.leaky_relu(logits, 0.2)
    logits = jnp.where(nbr_mask > 0, logits, -1e9)
    attn = jax.nn.softmax(logits, axis=-1) * nbr_mask
    msgs = jnp.take(z, nbr_idx, axis=0)
    return jnp.einsum("ndh,nd->nh", msgs, attn.astype(z.dtype))


# ---------------------------------------------------------------------------
# Attention kernels (transformer zoo)
# ---------------------------------------------------------------------------

def mha_ref(q, k, v, causal=True, window=None, scale=None):
    """Full O(S^2) GQA attention oracle.

    q: [B, Hq, Sq, Dh]; k/v: [B, Hkv, Sk, Dh]; Hq % Hkv == 0.
    ``window``: sliding-window size (keys within [i-window+1, i]).
    For cross/prefix attention set causal=False.
    """
    b, hq, sq, dh = q.shape
    hkv = k.shape[1]
    rep = hq // hkv
    if scale is None:
        scale = dh ** -0.5
    kk = jnp.repeat(k, rep, axis=1)
    vv = jnp.repeat(v, rep, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, kk).astype(jnp.float32) * scale
    sk = k.shape[2]
    qpos = jnp.arange(sq)[:, None] + (sk - sq)  # align ends (prefill/full)
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv)


def gqa_decode_ref(q, k, v, kv_len=None, window=None):
    """Single-token decode attention oracle.

    q: [B, Hq, Dh]; k/v: [B, Hkv, S, Dh] (the cache); kv_len: [B] valid
    lengths (None = full).  ``window``: only the last ``window`` valid
    positions attend.  Returns [B, Hq, Dh].
    """
    b, hq, dh = q.shape
    hkv, s = k.shape[1], k.shape[2]
    rep = hq // hkv
    kk = jnp.repeat(k, rep, axis=1)
    vv = jnp.repeat(v, rep, axis=1)
    logits = jnp.einsum("bhd,bhsd->bhs", q, kk).astype(jnp.float32) * (dh ** -0.5)
    pos = jnp.arange(s)[None, :]
    valid = jnp.ones((b, s), bool) if kv_len is None else pos < kv_len[:, None]
    if window is not None:
        lo = (s if kv_len is None else kv_len[:, None]) - window
        valid &= pos >= lo
    logits = jnp.where(valid[:, None, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhs,bhsd->bhd", p, vv)


# ---------------------------------------------------------------------------
# Mamba2 SSD (state-space duality) scan
# ---------------------------------------------------------------------------

def ssd_scan_ref(x, dt, a, b, c, d_skip=None):
    """Sequential SSD recurrence oracle (Mamba2, arXiv 2405.21060 eq. SSD).

    x:  [B, S, H, P]   per-head inputs
    dt: [B, S, H]      softplus-activated step sizes (>0)
    a:  [H]            negative state decay rates (A = -exp(a_log))
    b:  [B, S, N]      input projection (shared across heads, G=1 group)
    c:  [B, S, N]      output projection
    d_skip: [H] or None — skip connection weight
    Returns y: [B, S, H, P].

    Recurrence per head h:  S_t = exp(dt_t * a_h) * S_{t-1} + dt_t * (b_t ⊗ x_t)
                            y_t = S_t^T c_t   with S in R^{N x P}
    """
    B, S, H, P = x.shape
    N = b.shape[-1]

    def step(state, inp):
        xt, dtt, bt, ct = inp  # [B,H,P], [B,H], [B,N], [B,N]
        decay = jnp.exp(dtt * a[None, :])            # [B,H]
        upd = jnp.einsum("bn,bhp,bh->bhnp", bt, xt, dtt)
        state = state * decay[..., None, None] + upd
        yt = jnp.einsum("bhnp,bn->bhp", state, ct)
        return state, yt

    state0 = jnp.zeros((B, H, N, P), jnp.float32)
    xs = (
        jnp.moveaxis(x, 1, 0).astype(jnp.float32),
        jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
        jnp.moveaxis(b, 1, 0).astype(jnp.float32),
        jnp.moveaxis(c, 1, 0).astype(jnp.float32),
    )
    _, ys = jax.lax.scan(step, state0, xs)
    y = jnp.moveaxis(ys, 0, 1)                        # [B,S,H,P]
    if d_skip is not None:
        y = y + x.astype(jnp.float32) * d_skip[None, None, :, None]
    return y.astype(x.dtype)


def ssd_chunked_ref(x, dt, a, b, c, d_skip=None, chunk: int = 64,
                    compute_dtype=jnp.float32):
    """Chunk-parallel SSD evaluation (the algorithm the Pallas kernel uses),
    in pure jnp — mathematically identical to ``ssd_scan_ref``; used to test
    the chunked decomposition independent of Pallas.

    ``compute_dtype`` controls the big intra-chunk tensors (the [Q,Q,H]
    decay/weight blocks) — bf16 halves their HBM traffic (§Perf iteration
    for the memory-bound SSM training shapes); state math stays f32.
    """
    B, S, H, P = x.shape
    N = b.shape[-1]
    assert S % chunk == 0
    nc = S // chunk
    cd = compute_dtype
    xc = x.reshape(B, nc, chunk, H, P).astype(cd)
    dtc = dt.reshape(B, nc, chunk, H).astype(jnp.float32)
    bc = b.reshape(B, nc, chunk, N).astype(cd)
    cc = c.reshape(B, nc, chunk, N).astype(cd)

    # cumulative log-decay within each chunk: l[t] = sum_{u<=t} dt_u * a
    seg = dtc * a[None, None, None, :]               # [B,nc,Q,H]
    cum = jnp.cumsum(seg, axis=2)                     # inclusive
    total = cum[:, :, -1]                             # [B,nc,H]

    # intra-chunk (causal "attention" with decay weights):
    # y_intra[t] = sum_{u<=t} c_t·b_u * exp(cum[t]-cum[u]) * dt_u * x_u
    scores = jnp.einsum("bkin,bkjn->bkij", cc, bc,
                        preferred_element_type=jnp.float32)   # [B,nc,Q,Q]
    li = cum[:, :, :, None, :]                        # t index
    lj = cum[:, :, None, :, :]                        # u index
    decay = jnp.exp(jnp.clip(li - lj, -60.0, 0.0)).astype(cd)  # [B,nc,Q,Q,H]
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    w = scores.astype(cd)[..., None] * decay * causal[None, None, :, :, None]
    y_intra = jnp.einsum("bkijh,bkjh,bkjhp->bkihp", w, dtc.astype(cd), xc,
                         preferred_element_type=jnp.float32)

    # chunk states: S_k = sum_u exp(total - cum[u]) dt_u (b_u ⊗ x_u)
    dec_state = jnp.exp(jnp.clip(total[:, :, None] - cum, -60.0, 0.0))  # [B,nc,Q,H]
    s_chunk = jnp.einsum("bkjn,bkjh,bkjhp->bkhnp", bc.astype(jnp.float32),
                         dec_state * dtc, xc.astype(jnp.float32))

    # inter-chunk scan over k: state carried with decay exp(total)
    def scan_fn(carry, inp):
        s_k, tot_k = inp                              # [B,H,N,P], [B,H]
        new = carry * jnp.exp(jnp.clip(tot_k, -60.0, 0.0))[..., None, None] + s_k
        return new, carry                             # emit state *before* chunk

    _, prev_states = jax.lax.scan(
        scan_fn,
        jnp.zeros((B, H, N, P), jnp.float32),
        (jnp.moveaxis(s_chunk, 1, 0), jnp.moveaxis(total, 1, 0)),
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)     # [B,nc,H,N,P]

    # inter-chunk contribution: y_inter[t] = (exp(cum[t]) * c_t) · S_prev
    y_inter = jnp.einsum(
        "bkin,bkih,bkhnp->bkihp", cc.astype(jnp.float32),
        jnp.exp(jnp.clip(cum, -60.0, 0.0)), prev_states
    )
    y = (y_intra.astype(jnp.float32) + y_inter).reshape(B, S, H, P)
    if d_skip is not None:
        y = y + x.astype(jnp.float32) * d_skip[None, None, :, None]
    return y.astype(x.dtype)
