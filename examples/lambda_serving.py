"""Lambda serving demo: the paper's production architecture.

Trains a small LNN, then:
  1. BATCH LAYER — periodic stage-1 refresh pushes entity embeddings into
     the key-value store;
  2. SPEED LAYER — simulated checkout stream scored online with one KV
     lookup per linked entity (no graph traversal);
  3. proves the two-stage scores equal the monolithic GNN forward, and
     reports the latency gap.

Run:  PYTHONPATH=src python examples/lambda_serving.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import LNNConfig
from repro.data import (SynthConfig, build_communities, generate_transactions,
                        make_split_masks)
from repro.data.pipeline import standardize_features
from repro.serve import LambdaPipeline
from repro.serve.lambda_pipeline import BatchLayer
from repro.train.loop import train_lnn


def main():
    g, _ = generate_transactions(SynthConfig(num_users=300, num_rings=5,
                                             feature_noise=0.8, seed=1))
    split = make_split_masks(g.order_snapshot)
    feats, _ = standardize_features(g.order_features, split == 0)
    g.order_features = feats
    batches = build_communities(g, community_size=256, max_deg=24)
    cfg = LNNConfig(num_gnn_layers=3, hidden_dim=64, feat_dim=feats.shape[1],
                    pos_weight=3.0)
    print("== training a small LNN ==")
    res = train_lnn(batches, split, cfg, epochs=15, patience=5)

    pipe = LambdaPipeline(res.params, cfg, k_max=8)

    print("\n== batch layer: periodic entity-embedding refresh ==")
    stats = pipe.refresh(batches)
    print(f"   wrote {stats['entities_written']} entity embeddings "
          f"in {stats['seconds']:.2f}s -> KV store size {stats['store_size']}")

    print("\n== correctness: two-stage == monolithic ==")
    worst = pipe.score_equivalence_check(batches)
    print(f"   max |online - full forward| = {worst:.2e}")

    print("\n== speed layer: scoring a checkout stream ==")
    requests = []
    for b in batches:
        for o, hops in b.dds.last_hop.items():
            keys = [(BatchLayer._global_entity(b, ent), t) for ent, t, _ in hops]
            requests.append({"features": np.asarray(b.graph.features[o]),
                             "entity_keys": keys})
    requests = requests[:300]
    pipe.score(requests[:1])   # warm jit
    lat = []
    risky = 0
    for r in requests:
        t0 = time.time()
        p = pipe.score([r])[0]
        lat.append((time.time() - t0) * 1e3)
        risky += p > 0.5
    lat = np.asarray(lat)
    print(f"   {len(requests)} checkouts, {risky} flagged risky")
    print(f"   latency p50={np.percentile(lat, 50):.2f}ms "
          f"p95={np.percentile(lat, 95):.2f}ms p99={np.percentile(lat, 99):.2f}ms")
    print(f"   KV store stats: {pipe.store.stats}")


if __name__ == "__main__":
    main()
