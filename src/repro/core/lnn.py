"""Lambda Neural Network (LNN) — paper §3.3.

A deep GNN split in two stages at the ``entity_{t-e}`` cut:

* **stage 1** (batch layer): input projection + all GNN layers except the
  last, run over the whole DDS community graph.  Its output rows for entity
  vertices are the embeddings that production would periodically refresh and
  push to a key-value store.
* **stage 2** (speed layer): the final GNN layer restricted to the
  ``entity_{t-e} -> order_t`` final-hop edges, concatenated with the raw
  order features, followed by an MLP scorer — exactly the computation an
  online checkout approval performs after KV lookups.

``lnn_forward = stage2 ∘ stage1`` end-to-end for training; the split is
exact because effective orders have *only* final-hop in-edges in a DDS graph
(verified by ``core.dds.check_no_future_leak`` and the stage-equivalence
test).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core.graph import EdgeType, NodeType, PaddedGraph
from repro.core.layers import LAYER_REGISTRY, _glorot, weighted_gather_sum


@dataclass(frozen=True)
class LNNConfig:
    """Hyperparameters of the Lambda Neural Network (see module docstring).

    ``entity_types`` opts into heterogeneous per-type entity towers: a
    non-empty tuple of type names (canonically
    :data:`repro.core.hetero.ENTITY_TYPE_NAMES`) adds a per-type input
    embedding to stage 1 and per-type weight blocks to stage 2.  Empty
    (the default) keeps the homogeneous model — parameters, pytree
    structure, and numerics all bit-identical to the pre-hetero layout.
    """

    gnn_type: str = "gcn"            # 'gcn' | 'gat' | 'sage'
    num_gnn_layers: int = 3          # total GNN layers (>= 2: stage1 has L-1)
    hidden_dim: int = 64
    mlp_dims: tuple = (64, 32)
    feat_dim: int = 16               # raw checkout feature width
    use_pallas: bool = False
    pos_weight: float = 1.0          # BCE positive-class weight (fraud is rare)
    entity_types: tuple = ()         # () = homogeneous; e.g. hetero.ENTITY_TYPE_NAMES

    def __post_init__(self):
        if self.num_gnn_layers < 2:
            raise ValueError("LNN needs >= 2 GNN layers (stage1 >= 1, stage2 == 1)")
        if self.gnn_type not in LAYER_REGISTRY:
            raise ValueError(f"unknown gnn_type {self.gnn_type}")
        object.__setattr__(self, "entity_types", tuple(self.entity_types))


def lnn_init(rng, cfg: LNNConfig):
    """Initialize an LNN parameter pytree for ``cfg``.

    The homogeneous layout (and its PRNG key schedule) is untouched by the
    heterogeneous extension: typed parameters draw from *extra* keys
    appended after the base split, and the ``"typed"`` subtree exists only
    when ``cfg.entity_types`` is non-empty.
    """
    init_fn, _ = LAYER_REGISTRY[cfg.gnn_type]
    n_base = cfg.num_gnn_layers + len(cfg.mlp_dims) + 3
    n_types = len(cfg.entity_types)
    keys = jax.random.split(rng, n_base)
    params = {
        "input": {
            "w": _glorot(keys[0], (cfg.feat_dim, cfg.hidden_dim)),
            "b": jnp.zeros((cfg.hidden_dim,)),
        },
        # small learned embedding per node type so entities (zero features)
        # are distinguishable from shadows at the input
        "type_emb": 0.02 * jax.random.normal(keys[1], (4, cfg.hidden_dim)),
        "gnn": [
            init_fn(keys[2 + i], cfg.hidden_dim, cfg.hidden_dim)
            for i in range(cfg.num_gnn_layers - 1)
        ],
        "last": init_fn(keys[1 + cfg.num_gnn_layers], cfg.hidden_dim, cfg.hidden_dim),
        "mlp": [],
    }
    dims = (cfg.hidden_dim + cfg.feat_dim,) + tuple(cfg.mlp_dims) + (1,)
    for i in range(len(dims) - 1):
        params["mlp"].append(
            {
                "w": _glorot(keys[2 + cfg.num_gnn_layers + i], (dims[i], dims[i + 1])),
                "b": jnp.zeros((dims[i + 1],)),
            }
        )
    if n_types:
        # independent key stream (fold_in, not a wider base split) so the
        # homogeneous leaves stay bit-identical to an untyped init
        emb_key, tower_rng = jax.random.split(jax.random.fold_in(rng, n_base))
        tower_keys = jax.random.split(tower_rng, n_types)
        params["typed"] = {
            # stage-1 additive input embedding per entity type
            "entity_type_emb": 0.02 * jax.random.normal(
                emb_key, (n_types, cfg.hidden_dim)),
            # stage-2 per-type weight blocks (type-partitioned residual
            # towers over the KV-fetched entity embeddings)
            "tower_w": jnp.stack([
                _glorot(tower_keys[t], (cfg.hidden_dim, cfg.hidden_dim))
                for t in range(n_types)
            ]),
            "tower_b": jnp.zeros((n_types, cfg.hidden_dim)),
        }
    return params


def _apply_towers(params, x, codes):
    """Per-type entity tower: rows whose type code is ``t`` are replaced by
    ``relu(x @ tower_w[t] + tower_b[t])``; rows with code ``-1`` (orders,
    shadows, untyped entities, padding) pass through unchanged.

    One static Python loop over T <= 7 types, each a masked select over a
    single dense matmul — the same formulation the batch, online, and fused
    Pallas paths all use, so the three stay numerically aligned.
    """
    tw, tb = params["typed"]["tower_w"], params["typed"]["tower_b"]
    out = x
    for t in range(tw.shape[0]):
        out = jnp.where((codes == t)[..., None],
                        jax.nn.relu(x @ tw[t] + tb[t]), out)
    return out


# ---------------------------------------------------------------------------
# Stage 1 — batch layer
# ---------------------------------------------------------------------------

def lnn_stage1(params, cfg: LNNConfig, graph: PaddedGraph):
    """Input proj + first L-1 GNN layers.  Returns hidden states [N, H].

    The final-hop ``entity_{t-e} -> order_t`` edges are *masked out* here:
    per the paper they are consumed only by the last (speed-layer) GNN
    layer.  This is what makes the split exact — an order's stage-1 state
    depends only on its own raw features (see ``lnn_order_tower``), so the
    online path needs nothing but KV lookups of entity embeddings.
    """
    _, apply_fn = LAYER_REGISTRY[cfg.gnn_type]
    stage1_graph = graph._replace(
        nbr_mask=graph.nbr_mask * (graph.nbr_etype != EdgeType.ENTITY_TO_ORDER)
    )
    h = graph.features @ params["input"]["w"] + params["input"]["b"]
    h = h + params["type_emb"][graph.node_type]
    if "typed" in params and graph.tower is not None:
        # heterogeneous input: typed entity-snapshot vertices additionally
        # receive their per-entity-type embedding (tower < 0 rows add zero)
        emb = params["typed"]["entity_type_emb"]
        h = h + (graph.tower >= 0)[:, None] * emb[jnp.clip(graph.tower, 0)]
    h = jax.nn.relu(h)
    for layer in params["gnn"]:
        h = apply_fn(layer, h, stage1_graph, cfg.use_pallas)
    return h


def lnn_order_tower(params, cfg: LNNConfig, order_feats):
    """Stage-1 state of an *order* node, computed locally from raw features.

    Because stage 1 masks final-hop edges, an order aggregates nothing in
    stage 1; each GNN layer reduces to its self-transform.  This is the
    cheap online recomputation the speed layer performs per checkout.
    """
    h = order_feats @ params["input"]["w"] + params["input"]["b"]
    h = h + params["type_emb"][NodeType.ORDER]
    h = jax.nn.relu(h)
    for layer in params["gnn"]:
        # all three layer types share the self-transform form
        h = jax.nn.relu(h @ layer["w_self"] + layer["b"])
    return h


# ---------------------------------------------------------------------------
# Stage 2 — speed layer
# ---------------------------------------------------------------------------

def _last_layer_combine(params, cfg: LNNConfig, agg, self_h):
    """Final GNN layer math shared by the batch and online paths.

    ``agg`` is the (already weighted) neighbor aggregate in *input* space,
    ``self_h`` the node's own hidden state.
    """
    p = params["last"]
    if cfg.gnn_type == "gcn":
        # orders only receive ENTITY_TO_ORDER edges; use that etype's weight
        out = self_h @ p["w_self"] + agg @ p["w_nbr"][EdgeType.ENTITY_TO_ORDER]
    elif cfg.gnn_type == "sage":
        out = self_h @ p["w_self"] + agg @ p["w_nbr"]
    else:  # gat: agg is already in z-space (post-W); self term below
        out = agg + self_h @ p["w_self"]
    return jax.nn.relu(out + p["b"])


def _mlp(params, x):
    for i, layer in enumerate(params["mlp"]):
        x = x @ layer["w"] + layer["b"]
        if i + 1 < len(params["mlp"]):
            x = jax.nn.relu(x)
    return x[..., 0]


def _final_hop_aggregate(params, cfg: LNNConfig, h, graph: PaddedGraph):
    """Neighbor aggregate of the last layer, restricted to final-hop edges."""
    w_fin = graph.nbr_mask * (graph.nbr_etype == EdgeType.ENTITY_TO_ORDER)
    if cfg.gnn_type == "gcn" or cfg.gnn_type == "sage":
        cnt = jnp.maximum(w_fin.sum(-1, keepdims=True), 1.0)
        return weighted_gather_sum(h, graph.nbr_idx, w_fin / cnt, cfg.use_pallas)
    # gat
    p = params["last"]
    z = h @ p["w"]
    s_dst = z @ p["a_dst"]
    logits = jnp.take(z @ p["a_src"], graph.nbr_idx, axis=0) + s_dst[:, None]
    logits = logits + p["a_et"][graph.nbr_etype]
    logits = jax.nn.leaky_relu(logits, 0.2)
    logits = jnp.where(w_fin > 0, logits, -1e9)
    attn = jax.nn.softmax(logits, axis=-1) * w_fin
    msgs = jnp.take(z, graph.nbr_idx, axis=0)
    return jnp.einsum("ndh,nd->nh", msgs, attn)


def lnn_stage2_batch(params, cfg: LNNConfig, h, graph: PaddedGraph):
    """Speed-layer computation over the whole padded graph (training path).

    Returns logits [N]; only rows with node_type == ORDER are meaningful.
    """
    if "typed" in params and graph.tower is not None:
        # heterogeneous stage 2: per-type towers over entity rows before
        # the final-hop aggregation (order/shadow rows pass through)
        h = _apply_towers(params, h, graph.tower)
    agg = _final_hop_aggregate(params, cfg, h, graph)
    self_h = h
    g_out = _last_layer_combine(params, cfg, agg, self_h)
    x = jnp.concatenate([g_out, graph.features], axis=-1)
    return _mlp(params, x)


def lnn_stage2_embed(params, cfg: LNNConfig, entity_emb, emb_mask, order_feats,
                     order_h=None, slot_type=None):
    """Online stage-2 *embedding*: everything up to (but excluding) the MLP
    head — the last GNN layer's output concatenated with the raw checkout
    features, ``[B, H + F]``.

    This is the representation the hybrid GNN→GBDT head
    (``repro.models.hybrid``) feeds to its booster; the pure-MLP scorer is
    exactly ``_mlp`` over the same tensor, so factoring it out changes no
    numerics.  ``slot_type``: optional [B, K] int type codes per entity
    slot (-1 = untyped/padding) — applies the per-type towers of a
    heterogeneous model before aggregation.
    """
    if order_h is None:
        order_h = lnn_order_tower(params, cfg, order_feats)
    if "typed" in params and slot_type is not None:
        entity_emb = _apply_towers(params, entity_emb, slot_type)
    if cfg.gnn_type in ("gcn", "sage"):
        cnt = jnp.maximum(emb_mask.sum(-1, keepdims=True), 1.0)
        agg = jnp.einsum("bkh,bk->bh", entity_emb, emb_mask / cnt)
    else:  # gat
        p = params["last"]
        z = entity_emb @ p["w"]
        logits = z @ p["a_src"] + ((order_h @ p["w"]) @ p["a_dst"])[:, None]
        logits = logits + p["a_et"][EdgeType.ENTITY_TO_ORDER]
        logits = jax.nn.leaky_relu(logits, 0.2)
        logits = jnp.where(emb_mask > 0, logits, -1e9)
        attn = jax.nn.softmax(logits, axis=-1) * emb_mask
        agg = jnp.einsum("bkh,bk->bh", z, attn)
    g_out = _last_layer_combine(params, cfg, agg, order_h)
    return jnp.concatenate([g_out, order_feats], axis=-1)


def lnn_stage2_online(params, cfg: LNNConfig, entity_emb, emb_mask, order_feats,
                      order_h=None, slot_type=None):
    """Online scoring path: KV-fetched entity embeddings -> risk logit.

    entity_emb: [B, K, H] stage-1 embeddings of the ≤K linked effective
    entities (zero rows where absent); emb_mask: [B, K]; order_feats: [B, F]
    raw checkout features; order_h: [B, H] the order's own stage-1 hidden
    state — optional, recomputed from ``order_feats`` when omitted (always
    valid: stage 1 masks final-hop edges, so an order's stage-1 state is a
    pure function of its own raw features, see ``lnn_order_tower``).
    ``slot_type``: optional [B, K] int entity-type codes (heterogeneous
    models; -1 = padding/untyped slot).

    With ``cfg.use_pallas`` the whole path — tower, masked aggregation,
    last-layer combine, MLP logit — runs as ONE fused Pallas launch
    (``kernels.stage2_score``; interpret mode on CPU).  The tower is then
    always recomputed inside the kernel, so a supplied ``order_h`` is
    ignored on that path.
    """
    if cfg.use_pallas:
        from repro.kernels.ops import stage2_score

        return stage2_score(params, cfg.gnn_type, entity_emb, emb_mask,
                            order_feats, slot_type=slot_type)
    x = lnn_stage2_embed(params, cfg, entity_emb, emb_mask, order_feats,
                         order_h=order_h, slot_type=slot_type)
    return _mlp(params, x)


# ---------------------------------------------------------------------------
# End-to-end
# ---------------------------------------------------------------------------

def lnn_forward(params, cfg: LNNConfig, graph: PaddedGraph):
    """Full forward (training): stage2 ∘ stage1.  Logits [N]."""
    h = lnn_stage1(params, cfg, graph)
    return lnn_stage2_batch(params, cfg, h, graph)


def lnn_loss(params, cfg: LNNConfig, graph: PaddedGraph):
    """Masked weighted BCE over effective orders."""
    logits = lnn_forward(params, cfg, graph)
    is_order = (graph.node_type == NodeType.ORDER).astype(jnp.float32)
    mask = graph.label_mask * is_order
    y = graph.label
    logp = jax.nn.log_sigmoid(logits)
    lognp = jax.nn.log_sigmoid(-logits)
    per = -(cfg.pos_weight * y * logp + (1.0 - y) * lognp)
    return (per * mask).sum() / jnp.maximum(mask.sum(), 1.0)
