"""Streaming serving engine — the closed Lambda loop.

Per checkout event:

  event ──> StreamIngester ──────────────┐ (extends DDS graph, dirty marks)
        │        │ window closed?        │
        │        └─> RefreshDriver ──────┤ (stage 1 on closed windows,
        │                                │  versioned KV puts)
        └─> entity keys ─> MicroBatcher ─┴─> speed-layer stage 2 ─> score

Scoring is exact with respect to the paper's monolithic forward: when the
refresh driver runs every closed window, each request's ``(entity, t_e)``
keys hit embeddings whose in-neighborhoods were final at refresh time, so
micro-batched speed-layer scores equal ``lnn_forward`` on the full graph
(stage-equivalence test in ``tests/test_stream.py``).  Lower refresh rates
trade exactness for batch-layer cost; the KV fallback then serves older
snapshots and reports staleness per request.

The engine runs a deterministic discrete-event simulation of a single-server
queue: *virtual* arrival times drive flush triggers, *real* wall time is
measured for each jitted flush, and per-request latency = queue wait +
service — so benchmark numbers are reproducible yet reflect true compute
cost.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import numpy as np

from repro.core.lnn import LNNConfig, lnn_stage2_online
from repro.serve.kvstore import KVStore
from repro.stream.events import CheckoutEvent
from repro.stream.ingest import StreamIngester
from repro.stream.microbatch import MicroBatcher, ScoredResult, ScoreRequest
from repro.stream.refresh import RefreshDriver


@dataclass
class EngineConfig:
    k_max: int = 8                  # entity slots per request
    max_batch: int = 16             # micro-batch size trigger
    max_wait_s: float = 0.005       # micro-batch deadline trigger (virtual s)
    refresh_every: int = 1          # batch-layer cadence, in closed windows
    entity_history: str = "all"     # DDS history mode (see core.dds)
    max_history: int | None = 8
    max_deg: int = 32               # padded in-degree for the batch graph
    async_refresh: bool = False     # stage 1 on a background thread
    store_capacity: int | None = None    # KV LRU cap (None = unbounded)
    store_ttl_s: float | None = None     # KV TTL (None = no expiry)
    store_shards: int = 4


class StreamingEngine:
    """The closed Lambda loop over a live event stream.

    ``submit(event)`` ingests one :class:`CheckoutEvent` (growing the
    incremental DDS, triggering batch-layer refreshes on window close) and
    returns whatever :class:`ScoredResult` lists the event's arrival flushed
    out of the micro-batch queue; ``flush()`` force-drains the queue and
    ``replay(events)`` drives a whole stream and returns a
    :class:`ReplayReport`.

    Per micro-batch flush the speed layer makes one versioned KV multi-get
    and ONE jitted stage-2 dispatch (``lnn_stage2_online`` — the fused
    ``kernels.stage2_score`` Pallas launch when ``cfg.use_pallas``); the
    order tower is folded into that call, so the hot path is a single
    fixed-shape kernel per flush.
    """

    def __init__(self, params, cfg: LNNConfig, engine_cfg: EngineConfig | None = None,
                 store: KVStore | None = None):
        self.params = params
        self.cfg = cfg
        self.ecfg = engine_cfg or EngineConfig()
        self.store = store or KVStore(
            cfg.hidden_dim,
            capacity=self.ecfg.store_capacity,
            ttl_seconds=self.ecfg.store_ttl_s,
            num_shards=self.ecfg.store_shards,
        )
        self.ingester = StreamIngester(
            cfg.feat_dim,
            entity_history=self.ecfg.entity_history,
            max_history=self.ecfg.max_history,
        )
        self.refresher = RefreshDriver(
            params, cfg, self.store, self.ingester,
            max_deg=self.ecfg.max_deg,
            refresh_every=self.ecfg.refresh_every,
            async_mode=self.ecfg.async_refresh,
        )
        self.batcher = MicroBatcher(
            self._score_batch,
            max_batch=self.ecfg.max_batch,
            max_wait_s=self.ecfg.max_wait_s,
        )
        self._stage2 = jax.jit(
            lambda p, emb, mask, feats: lnn_stage2_online(
                p, self.cfg, emb, mask, feats
            )
        )

    # ------------------------------------------------------------- speed layer
    def _score_batch(self, feats: np.ndarray, entity_t_lists: list):
        """[B, F] features + per-row (entity, t_e) lists -> (probs, staleness).

        One KV multi-get (with snapshot fallback) and one jitted stage-2
        call (tower folded in) — the checkout-approval hot path."""
        emb, mask, stale = self.store.lookup_batch_versioned(
            entity_t_lists, self.ecfg.k_max
        )
        f = np.ascontiguousarray(feats, np.float32)
        logits = self._stage2(self.params, emb, mask, f)
        probs = np.asarray(jax.nn.sigmoid(logits))
        return probs, stale.max(axis=1)

    def warmup(self):
        """Compile every micro-batch bucket shape up front (cold-start off
        the measured path).  Buckets are the pow2 sizes capped at max_batch
        — exactly what ``bucket_size`` can produce, including a
        non-power-of-two max_batch itself."""
        from repro.stream.microbatch import bucket_size

        feat_dim = self.cfg.feat_dim
        buckets = sorted({bucket_size(n, self.ecfg.max_batch)
                          for n in range(1, self.ecfg.max_batch + 1)})
        for b in buckets:
            self._score_batch(np.zeros((b, feat_dim), np.float32),
                              [[] for _ in range(b)])

    # ----------------------------------------------------------------- events
    def submit(self, event: CheckoutEvent) -> list[ScoredResult]:
        """Ingest one event and return any requests whose flush it triggered
        (deadline flushes for older queued requests fire first)."""
        out = self.batcher.poll(event.arrival)
        ing = self.ingester.ingest(event)
        if ing.closed_window is not None:
            self.refresher.on_windows_closed(ing.closed_window)
        req = ScoreRequest(
            features=np.asarray(event.features, np.float32),
            entity_keys=ing.entity_keys,
            arrival=event.arrival,
            tag=event,
        )
        out.extend(self.batcher.submit(req, event.arrival))
        return out

    def flush(self, now: float | None = None) -> list[ScoredResult]:
        """Force-drain the queue (stream end).  Without an explicit ``now``
        the flush is stamped at the queue's deadline — the residual batch
        would have flushed then anyway, so its recorded queue waits match
        the timer semantics instead of collapsing to zero."""
        self.refresher.drain()
        if now is None:
            now = self.batcher.deadline() or 0.0
        return self.batcher.flush(now)

    # ------------------------------------------------------------------ replay
    def replay(self, events, warmup: bool = True) -> "ReplayReport":
        """Drive a whole event stream through ingest -> refresh -> score."""
        if warmup:
            self.warmup()
        results: list[ScoredResult] = []
        for ev in events:
            results.extend(self.submit(ev))
        results.extend(self.flush())
        self.refresher.drain()
        return ReplayReport(results=results, engine=self)


@dataclass
class ReplayReport:
    results: list
    engine: StreamingEngine
    _lat: np.ndarray | None = field(default=None, repr=False)

    def latencies_s(self) -> np.ndarray:
        """Per-request latency: virtual queue wait + measured service time."""
        if self._lat is None:
            self._lat = np.asarray(
                [r.queued_s + r.service_s for r in self.results], np.float64
            )
        return self._lat

    def percentiles_ms(self) -> dict:
        lat = self.latencies_s() * 1e3
        if lat.size == 0:
            return {"p50": 0.0, "p95": 0.0, "p99": 0.0}
        return {
            "p50": float(np.percentile(lat, 50)),
            "p95": float(np.percentile(lat, 95)),
            "p99": float(np.percentile(lat, 99)),
        }

    def scores_by_order(self) -> dict:
        return {r.request.tag.order_id: r.score for r in self.results}

    def staleness_summary(self) -> dict:
        s = np.asarray([r.staleness for r in self.results])
        served = s[s >= 0]
        return {
            "mean": float(served.mean()) if served.size else 0.0,
            "max": int(served.max()) if served.size else 0,
            "stale_frac": float((served > 0).mean()) if served.size else 0.0,
        }

    def summary(self) -> dict:
        eng = self.engine
        lat = self.latencies_s()
        service = float(np.mean([r.service_s for r in self.results])) \
            if self.results else 0.0
        return {
            "events": eng.ingester.num_events,
            "scored": len(self.results),
            "flushes": eng.batcher.stats["flushes"],
            "size_flushes": eng.batcher.stats["size_flushes"],
            "deadline_flushes": eng.batcher.stats["deadline_flushes"],
            "mean_batch": float(np.mean([r.batch_size for r in self.results]))
            if self.results else 0.0,
            "latency_ms": self.percentiles_ms(),
            "mean_service_ms": service * 1e3,
            "staleness": self.staleness_summary(),
            "refreshes": eng.refresher.stats["refreshes"],
            "entities_written": eng.refresher.stats["entities_written"],
            "store_size": len(eng.store),
            "store_stats": dict(eng.store.stats),
            "mean_latency_ms": float(lat.mean() * 1e3) if lat.size else 0.0,
        }
