"""Pallas TPU kernel: fused online stage-2 scoring (the speed-layer hot path).

``lnn_stage2_online`` is the computation every streamed checkout request
crosses after its KV lookups.  The unfused path issues four separate
dispatches per micro-batch — order tower, masked aggregation, last-layer
combine, MLP head — each reading/writing HBM.  This kernel performs the
whole thing in ONE launch over a padded micro-batch:

    tower    h = relu(feats @ W_in + b_in + type_emb[ORDER])
                 then (L-1) x  relu(h @ W_self_l + b_l)        (stage-1 self
                                                                transforms)
    agg      a = masked mean (gcn/sage) or masked attention (gat)
                 over the KV-fetched entity embeddings          (final hop)
    combine  g = relu(h @ W_self + a @ W_nbr + b)               (last GNN layer)
    logit    y = MLP([g ; feats])                               (risk head)

The ``[g ; feats]`` concatenation is folded into the MLP's first layer by
splitting its weight row-wise (``w0[:H]`` / ``w0[H:]``), so no concat ever
materialises.  Layer counts are static per config, so the tower and MLP
loops unroll at trace time; the entity-slot aggregation strip-mines over the
fixed width K exactly like ``csr_spmm.py`` does over the neighbor width.

Block sizing follows ``stream.microbatch.bucket_size``: the batch dimension
tiles in power-of-two blocks (capped at ``block_b``), so every micro-batch
bucket the scheduler can emit (1, 2, 4, ..., max_batch) maps to one grid
step with zero re-padding.  Weights are tiny (H <= 256) and ride along
whole in VMEM.

VMEM budget per program (defaults bb=128, K=8, H=64, F=16, f32):
    emb tile   bb x K x H = 128*8*64*4 = 256 KiB
    weights    ~(F*H + L*H^2 + (H+F)*m0 + ...) * 4 ~= 100 KiB
    activations bb x H few copies      ~= 100 KiB          << 16 MiB VMEM

Like the other kernels in this package the same ``pallas_call`` runs in
interpret mode on CPU (the tier-1 correctness oracle) and compiles natively
on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.utils.padding import ceil_div


def _bucket_block(b: int, cap: int) -> int:
    """Next power-of-two >= b, capped — mirrors ``stream.microbatch.bucket_size``."""
    p = 1
    while p < b and p < cap:
        p *= 2
    return min(p, cap)


def _make_stage2_kernel(gnn_type: str, n_tower: int, n_mlp_extra: int,
                        typed: bool = False, n_types: int = 0):
    """Build the kernel body for a static (gnn_type, depth) configuration.

    ``typed``: heterogeneous variant — a fourth data input carries per-slot
    entity-type codes and two extra weight refs carry the type-partitioned
    tower blocks ``[T, H, H]`` / ``[T, H]``, applied to each entity slot
    before aggregation (code -1 = padding/untyped slot, passthrough).  The
    untyped kernel signature and body are byte-identical to the pre-hetero
    version — bit-parity gates on homogeneous configs see the same launch.
    """

    def kernel(*refs):
        if typed:
            emb_ref, mask_ref, feats_ref, st_ref = refs[0:4]
            woff = 4
        else:
            emb_ref, mask_ref, feats_ref = refs[0:3]
            woff = 3
        w_in_ref, b_in_ref, type_ref, tw_ref, tb_ref = refs[woff:woff + 5]
        if typed:
            ttw_ref, ttb_ref = refs[woff + 5:woff + 7]
            rest = refs[woff + 7:]
        else:
            rest = refs[woff + 5:]
        if gnn_type == "gat":
            (w_self_ref, b_last_ref, w_gat_ref,
             a_src_ref, a_dst_ref, a_et_ref) = rest[0:6]
            mlp_refs = rest[6:-1]
        else:
            w_self_ref, w_nbr_ref, b_last_ref = rest[0:3]
            mlp_refs = rest[3:-1]
        out_ref = refs[-1]

        emb = emb_ref[...].astype(jnp.float32)      # [bb, K, H]
        mask = mask_ref[...].astype(jnp.float32)    # [bb, K]
        feats = feats_ref[...].astype(jnp.float32)  # [bb, F]
        bb, K, H = emb.shape

        # ---- per-type entity towers (heterogeneous models only) ----
        if typed:
            st = st_ref[...]                        # [bb, K] int32 codes
            ttw = ttw_ref[...]                      # [T, H, H]
            ttb = ttb_ref[...]                      # [T, H]
            emb0 = emb
            for t in range(n_types):
                tr = jnp.maximum(
                    emb0.reshape(bb * K, H) @ ttw[t] + ttb[t], 0.0
                ).reshape(bb, K, H)
                emb = jnp.where((st == t)[..., None], tr, emb)

        # ---- order tower: input projection + stage-1 self transforms ----
        h = feats @ w_in_ref[...] + b_in_ref[...] + type_ref[...]
        h = jnp.maximum(h, 0.0)
        for li in range(n_tower):
            h = jnp.maximum(h @ tw_ref[li] + tb_ref[li], 0.0)

        # ---- masked aggregation over the K entity slots ----
        if gnn_type in ("gcn", "sage"):
            cnt = jnp.maximum(mask.sum(-1, keepdims=True), 1.0)
            wght = mask / cnt                        # [bb, K]

            def body(k, acc):
                rows = jax.lax.dynamic_index_in_dim(emb, k, axis=1, keepdims=False)
                wk = jax.lax.dynamic_index_in_dim(wght, k, axis=1, keepdims=False)
                return acc + rows * wk[:, None]

            agg = jax.lax.fori_loop(0, K, body, jnp.zeros((bb, H), jnp.float32))
            g = h @ w_self_ref[...] + agg @ w_nbr_ref[...]
        else:  # gat: attention over the slots in z-space
            w = w_gat_ref[...]
            z = (emb.reshape(bb * K, H) @ w).reshape(bb, K, H)
            s_dst = (h @ w) @ a_dst_ref[...]                          # [bb, 1]
            s_src = (z.reshape(bb * K, H) @ a_src_ref[...]).reshape(bb, K)
            logits = s_src + s_dst + a_et_ref[0, 0]
            logits = jnp.where(logits >= 0, logits, 0.2 * logits)     # leaky relu
            logits = jnp.where(mask > 0, logits, -1e9)
            m = jnp.max(logits, axis=-1, keepdims=True)
            e = jnp.exp(logits - m)
            attn = (e / jnp.sum(e, axis=-1, keepdims=True)) * mask    # [bb, K]

            def body(k, acc):
                rows = jax.lax.dynamic_index_in_dim(z, k, axis=1, keepdims=False)
                ak = jax.lax.dynamic_index_in_dim(attn, k, axis=1, keepdims=False)
                return acc + rows * ak[:, None]

            agg = jax.lax.fori_loop(0, K, body, jnp.zeros((bb, H), jnp.float32))
            g = agg + h @ w_self_ref[...]
        g = jnp.maximum(g + b_last_ref[...], 0.0)

        # ---- risk head: MLP([g ; feats]) with the concat pre-split ----
        w0g_ref, w0f_ref, b0_ref = mlp_refs[0:3]
        y = g @ w0g_ref[...] + feats @ w0f_ref[...] + b0_ref[...]
        for i in range(n_mlp_extra):
            wi_ref = mlp_refs[3 + 2 * i]
            bi_ref = mlp_refs[4 + 2 * i]
            y = jnp.maximum(y, 0.0) @ wi_ref[...] + bi_ref[...]
        out_ref[...] = y[:, 0].astype(out_ref.dtype)

    return kernel


def flatten_stage2_params(params, gnn_type: str):
    """Extract the stage-2-relevant leaves of an ``lnn_init`` pytree in the
    kernel's positional argument order.

    Stage-1 self-transform layers stack into ``[L-1, H, H]`` (hidden width is
    constant), biases/embedding rows become ``[1, H]`` so every ref is >= 2-D,
    and the MLP's first weight splits at row H into the ``g_out`` block and
    the raw-feature block.
    """
    from repro.core.graph import EdgeType, NodeType

    h = params["last"]["w_self"].shape[0]
    flat = [
        params["input"]["w"],
        params["input"]["b"][None, :],
        params["type_emb"][NodeType.ORDER][None, :],
        jnp.stack([lyr["w_self"] for lyr in params["gnn"]]),
        jnp.stack([lyr["b"] for lyr in params["gnn"]]),
    ]
    if "typed" in params:
        # Heterogeneous models: per-type entity tower blocks ride along
        # right after the stage-1 stacks (order is part of the kernel ABI).
        flat += [params["typed"]["tower_w"], params["typed"]["tower_b"]]
    p = params["last"]
    if gnn_type == "gcn":
        flat += [p["w_self"], p["w_nbr"][EdgeType.ENTITY_TO_ORDER], p["b"][None, :]]
    elif gnn_type == "sage":
        flat += [p["w_self"], p["w_nbr"], p["b"][None, :]]
    elif gnn_type == "gat":
        flat += [p["w_self"], p["b"][None, :], p["w"],
                 p["a_src"][:, None], p["a_dst"][:, None],
                 p["a_et"][EdgeType.ENTITY_TO_ORDER][None, None]]
    else:
        raise ValueError(f"unknown gnn_type {gnn_type}")
    mlp = params["mlp"]
    w0 = mlp[0]["w"]
    flat += [w0[:h], w0[h:], mlp[0]["b"][None, :]]
    for layer in mlp[1:]:
        flat += [layer["w"], layer["b"][None, :]]
    return tuple(flat)


@functools.partial(
    jax.jit, static_argnames=("gnn_type", "block_b", "interpret", "typed"))
def stage2_score_pallas(entity_emb, emb_mask, order_feats, flat,
                        gnn_type: str = "gcn", block_b: int = 128,
                        interpret: bool = True, slot_type=None,
                        typed: bool = False):
    """Fused online stage-2 scoring: ``(emb [B,K,H], mask [B,K], feats [B,F])
    -> logits [B]``.  ``flat`` comes from :func:`flatten_stage2_params`.

    ``typed=True`` selects the heterogeneous kernel variant: ``slot_type``
    (int32 ``[B, K]`` entity-type codes, -1 for padding/untyped slots) rides
    as a fourth data input and ``flat`` carries the two extra tower refs.
    With ``typed=False`` the call is byte-identical to the homogeneous
    kernel — same inputs, same trace, same jit cache key.
    """
    b, k, hdim = entity_emb.shape
    f = order_feats.shape[1]
    bb = _bucket_block(b, block_b)
    grid = (ceil_div(b, bb),)

    n_tower = flat[3].shape[0]
    n_typed = 2 if typed else 0
    n_types = flat[5].shape[0] if typed else 0
    n_fixed = (11 if gnn_type == "gat" else 8) + n_typed
    n_mlp_extra = (len(flat) - n_fixed - 3) // 2

    def _full(a):
        nd = a.ndim
        return pl.BlockSpec(a.shape, lambda i, _nd=nd: (0,) * _nd)

    in_specs = [
        pl.BlockSpec((bb, k, hdim), lambda i: (i, 0, 0)),
        pl.BlockSpec((bb, k), lambda i: (i, 0)),
        pl.BlockSpec((bb, f), lambda i: (i, 0)),
    ]
    data = [entity_emb, emb_mask, order_feats]
    if typed:
        in_specs.append(pl.BlockSpec((bb, k), lambda i: (i, 0)))
        data.append(slot_type)
    in_specs += [_full(a) for a in flat]

    return pl.pallas_call(
        _make_stage2_kernel(gnn_type, n_tower, n_mlp_extra,
                            typed=typed, n_types=n_types),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=interpret,
    )(*data, *flat)
