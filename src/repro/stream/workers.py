"""Multi-worker sharded speed layer — ``repro.stream.workers``.

One micro-batch queue on one worker caps the speed layer at a single jit
dispatch stream; the serving tier, not the model, is the scaling bottleneck
(BRIGHT, arXiv 2205.13084).  This module shards that queue:

* :class:`ShardRouter` — key-affine routing: an event's primary entity maps
  to a worker by the SAME rendezvous hash the KV store uses for
  ``shard_by_entity`` placement (``serve.kvstore.entity_shard``, built on
  ``dist.sharding.rendezvous_shard``), so a request always lands on the
  worker that owns its entity's KV shard.  The worker count is fixed at
  construction and changes ONLY through an explicit :meth:`reshard` —
  never silently (property-tested).
* :class:`SpeedLayerWorker` — one shard's server: its own
  :class:`~repro.stream.microbatch.MicroBatcher` (independent size/deadline
  triggers) and its own :class:`Stage2Scorer` with a private jit cache
  (production workers are separate processes; private caches keep the
  simulation honest about per-worker warmup).
* :class:`WorkerPool` — fans submissions out through the router, pumps every
  worker's triggers on each virtual-clock advance, steals work from a
  backed-up shard into idle workers, and reassembles flushed scores in
  submission order through a reorder buffer.

Determinism: all queueing decisions run on the virtual clock (arrival
times), service occupancy is modeled by the configurable virtual
``service_model_s`` (0 = infinitely fast workers, the single-worker
default), and per-row scores are invariant to flush composition (pow2
buckets floored at 2 — see ``microbatch.bucket_size``).  Hence an N-worker
replay produces **bit-identical** scores to the single-worker engine for
any N and any flush interleaving (``tests/test_stream.py`` replay-parity).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.hetero import type_code_of
from repro.core.lnn import LNNConfig, lnn_stage2_embed, lnn_stage2_online
from repro.models.hybrid import HybridModel
from repro.serve.kvstore import KVStore, entity_shard
from repro.stream.microbatch import (
    MicroBatcher,
    PendingFlush,
    ScoredResult,
    ScoreRequest,
    bucket_size,
)


class ShardRouter:
    """Key-affine entity -> worker map (rendezvous placement).

    ``worker_of(entity) == KVStore(shard_by_entity=True).shard_of(key)``
    for every snapshot key of that entity, provided the store's
    ``num_shards`` equals the router's worker count — the pool constructs
    its store that way, so shard ownership and request routing agree by
    construction.

    The mapping is a pure function of (entity, num_workers): two routers
    with the same worker count agree on every entity, and the worker count
    is immutable except through :meth:`reshard` (which bumps ``epoch`` so
    observers can notice).  Growing N -> N+1 moves only ~1/(N+1) of the
    entities, all of them onto the new worker — the rendezvous minimal-
    movement property (property-tested in ``tests/test_workers.py``).
    """

    def __init__(self, num_workers: int):
        if num_workers < 1:
            raise ValueError("num_workers must be >= 1")
        self._num_workers = int(num_workers)
        self._epoch = 0

    @property
    def num_workers(self) -> int:
        return self._num_workers

    @property
    def epoch(self) -> int:
        """Bumped on every explicit reshard (observers cache against it)."""
        return self._epoch

    def worker_of(self, entity: int) -> int:
        return entity_shard(int(entity), self._num_workers)

    def route(self, entity_keys: list) -> int:
        """Worker for one request: the shard of its primary (first) entity
        key.  A request's other entities may live on other shards — their
        lookups are cross-shard reads, exactly like a remote KV fetch — but
        the *primary* entity's embedding is always shard-local.  Requests
        with no history (cold start, empty key list) carry no KV reads to
        co-locate; they pin to worker 0."""
        if not entity_keys:
            return 0
        return self.worker_of(entity_keys[0][0])

    def reshard(self, num_workers: int) -> int:
        """The ONLY way to change the worker count.  Returns the new epoch.

        On a live pool call :meth:`WorkerPool.reshard` instead — it drains
        the queues and migrates the worker list and the entity-affine KV
        shards together with the router (the pool guards against a router
        resharded out from under it)."""
        if num_workers < 1:
            raise ValueError("num_workers must be >= 1")
        self._num_workers = int(num_workers)
        self._epoch += 1
        return self._epoch


class Stage2Scorer:
    """The speed-layer scoring callable for one worker: one versioned KV
    multi-get (snapshot fallback + staleness) and ONE jitted stage-2
    dispatch (the fused Pallas launch when ``cfg.use_pallas``).  Each
    worker owns its own instance, hence its own jit caches.

    The jit cache is **version-aware**: :meth:`set_model` registers a new
    parameter version under its own ``jax.jit`` wrapper, so swapping back
    to a previously-served version reuses its still-compiled cache, and a
    flush that already entered ``__call__`` finishes on the (params,
    version, jit) triple it captured at entry — in-flight micro-batches
    complete on the old model, the next flush scores on the new one.
    """

    def __init__(self, params, cfg: LNNConfig, store: KVStore, k_max: int,
                 model_version: int = 0):
        self.cfg = cfg
        self.store = store
        self.k_max = int(k_max)
        self._typed = bool(cfg.entity_types)
        self._jits: dict[int, object] = {}
        self.set_model(params, model_version)

    def set_model(self, params, model_version: int) -> None:
        """Activate a parameter version.  New flushes score under it; the
        per-version jit wrapper keeps every version's compiled cache warm.

        ``params`` may be a plain ``lnn_init`` pytree (MLP risk head) or a
        :class:`~repro.models.hybrid.HybridModel` (GNN embedding -> GBDT):
        the hybrid's jit covers the fused embedding only, the booster runs
        on host like the MLP path's sigmoid."""
        version = int(model_version)
        hybrid = isinstance(params, HybridModel)
        if version not in self._jits:
            cfg = self.cfg
            if hybrid:
                self._jits[version] = jax.jit(
                    lambda p, emb, mask, feats, st: lnn_stage2_embed(
                        p, cfg, emb, mask, feats, slot_type=st)
                )
            else:
                self._jits[version] = jax.jit(
                    lambda p, emb, mask, feats, st: lnn_stage2_online(
                        p, cfg, emb, mask, feats, slot_type=st)
                )
        # assign the tuple last-to-first so a concurrent flush reading
        # (params, version, jit) at entry never pairs new params with an
        # old version stamp
        self._hybrid = hybrid
        self._stage2 = self._jits[version]
        self.model_version = version
        self.params = params

    def _slot_types(self, entity_t_lists: list) -> np.ndarray:
        """Per-slot entity-type codes ``[B, k_max]`` (-1 = empty/untagged),
        aligned with the KV lookup's slot order (pair j -> slot j)."""
        st = np.full((len(entity_t_lists), self.k_max), -1, np.int32)
        for i, pairs in enumerate(entity_t_lists):
            for j, (ent, _t) in enumerate(pairs[: self.k_max]):
                st[i, j] = type_code_of(ent)
        return st

    def __call__(self, feats: np.ndarray, entity_t_lists: list):
        # capture the active model ONCE per flush: an in-flight micro-batch
        # finishes on the version it started with even if set_model lands
        # mid-flush (async refresh thread / live hot-swap)
        params, version, stage2, hybrid = (
            self.params, self.model_version, self._stage2, self._hybrid)
        emb, mask, stale = self.store.lookup_batch_versioned(
            entity_t_lists, self.k_max, expected_model_version=version
        )
        return self._score(params, version, stage2, hybrid,
                           feats, entity_t_lists, emb, mask, stale)

    def score_slots(self, feats: np.ndarray, entity_t_lists: list,
                    emb: np.ndarray, mask: np.ndarray, stale: np.ndarray):
        """Score a batch whose KV slots were already resolved — the shard
        process path: the parent pre-reads cross-shard slots from their
        owners and the owner process fills its local slots, then calls
        this with the merged ``(emb, mask, stale)``.  Numerically identical
        to ``__call__`` by construction (same ``_score`` tail)."""
        params, version, stage2, hybrid = (
            self.params, self.model_version, self._stage2, self._hybrid)
        return self._score(params, version, stage2, hybrid,
                           feats, entity_t_lists, emb, mask, stale)

    def _score(self, params, version, stage2, hybrid, feats,
               entity_t_lists, emb, mask, stale):
        f = np.ascontiguousarray(feats, np.float32)
        st = self._slot_types(entity_t_lists) if self._typed else None
        if hybrid:
            # one jit dispatch for the fused embedding, booster on host —
            # numpy trees are element-deterministic, replay parity holds
            x = np.asarray(stage2(params.lnn_params, emb, mask, f, st),
                           np.float32)
            probs = params.gbdt.predict_proba(x).astype(np.float32)
            return probs, stale.max(axis=1), version
        logits = np.asarray(stage2(params, emb, mask, f, st), np.float64)
        # host-side f64 sigmoid, NOT jax.nn.sigmoid: XLA CPU's vectorized
        # exp rounds differently per array length (bucket 2 vs 4 diverge by
        # 1 ulp), while numpy ufuncs are element-deterministic for any
        # shape — required for the bit-exact replay-parity guarantee
        probs = (1.0 / (1.0 + np.exp(-logits))).astype(np.float32)
        return probs, stale.max(axis=1), version

    def warmup(self, max_batch: int):
        """Compile every pow2 bucket shape this worker's batcher can emit."""
        buckets = sorted({bucket_size(n, max_batch)
                          for n in range(1, max_batch + 1)})
        for b in buckets:
            self(np.zeros((b, self.cfg.feat_dim), np.float32),
                 [[] for _ in range(b)])


class SpeedLayerWorker:
    """One shard of the speed layer: a private micro-batch queue with
    independent size/deadline flush triggers, a private jit cache, and a
    virtual single-server occupancy model.

    ``service_model_s`` is the *virtual* seconds one flush occupies the
    worker (0 = flushes are instantaneous, matching the single-worker
    engine).  While a flush's virtual service window is open the worker
    defers further flushes, its queue backs up past ``max_batch``, and the
    pool's work stealing can move the overflow to an idle worker — all on
    the virtual clock, so replays stay deterministic on any host.
    """

    def __init__(self, wid: int, scorer: Stage2Scorer,
                 max_batch: int = 16, max_wait_s: float = 0.005,
                 service_model_s: float = 0.0):
        self.wid = int(wid)
        self.scorer = scorer
        self.batcher = MicroBatcher(scorer, max_batch=max_batch,
                                    max_wait_s=max_wait_s)
        self.service_model_s = float(service_model_s)
        self.busy_until = 0.0
        # stamps never fall below this: stolen work reached this worker at
        # the steal time, so its recorded waits must not be backdated to
        # the victim's original (long-missed) triggers
        self.stamp_floor = 0.0
        self.stats = {"stolen_in": 0, "stolen_out": 0,
                      "max_queue_depth": 0, "depth_sum": 0,
                      "depth_samples": 0, "restarts": 0}

    def __len__(self) -> int:
        return len(self.batcher)

    def free(self, now: float) -> bool:
        return now >= self.busy_until

    def enqueue(self, req: ScoreRequest) -> None:
        self.batcher.enqueue(req)
        d = len(self.batcher)
        self.stats["max_queue_depth"] = max(self.stats["max_queue_depth"], d)

    def sample_depth(self) -> None:
        """Record queue depth for the bench's mean-depth counter."""
        self.stats["depth_sum"] += len(self.batcher)
        self.stats["depth_samples"] += 1

    def _flush_at(self, trigger: float, kind: str) -> list[ScoredResult]:
        """Serve one flush whose trigger fired at virtual time ``trigger``:
        the flush is stamped when the worker actually gets to it (the
        trigger, the end of the previous flush's service window, or the
        moment stolen work arrived — whichever is latest)."""
        stamp = max(trigger, self.busy_until, self.stamp_floor)
        out = self.batcher.flush(stamp)
        if out:
            self.batcher.stats[kind] += 1
            if isinstance(out, PendingFlush):
                # process backend: the batch is in flight to this worker's
                # shard process; the pool resolves it before any release
                out.worker = self.wid
                out = [out]
            else:
                for r in out:
                    r.worker = self.wid
            if self.service_model_s > 0.0:
                self.busy_until = stamp + self.service_model_s
        return out

    def pump(self, now: float) -> list[ScoredResult]:
        """Run every flush whose trigger has fired and whose service window
        the worker can open by ``now`` — size triggers first (they fired
        earlier, when the queue filled), then the deadline trigger."""
        out: list[ScoredResult] = []
        while len(self.batcher) >= self.batcher.max_batch and self.free(now):
            trigger = self.batcher.nth_arrival(self.batcher.max_batch - 1)
            if trigger is None:      # raced away (steal) — queue re-checked
                break
            out.extend(self._flush_at(trigger, "size_flushes"))
        dl = self.batcher.deadline()
        if dl is not None and now >= dl and self.free(now):
            out.extend(self._flush_at(dl, "deadline_flushes"))
        return out

    def drain(self, now: float | None = None) -> list[ScoredResult]:
        """Force-flush everything queued (stream end).  Without an explicit
        ``now`` each residual batch is stamped at its own deadline — it
        would have flushed then anyway (timer semantics)."""
        out: list[ScoredResult] = []
        while len(self.batcher):
            dl = self.batcher.deadline()
            stamp = now if now is not None else (dl or 0.0)
            out.extend(self._flush_at(stamp, "deadline_flushes"))
        return out


class _ReorderBuffer:
    """Reassemble flushed results in submission (event) order.

    Workers flush independently, so scores surface out of order; the buffer
    holds them until the contiguous prefix of submission sequence numbers
    is complete — the result collector of the fan-out/fan-in topology."""

    def __init__(self):
        self._next = 0
        self._held: dict[int, ScoredResult] = {}
        self.max_held = 0

    def add(self, results: list[ScoredResult]) -> None:
        for r in results:
            self._held[r.request.seq] = r
        self.max_held = max(self.max_held, len(self._held))

    def release(self) -> list[ScoredResult]:
        out = []
        while self._next in self._held:
            out.append(self._held.pop(self._next))
            self._next += 1
        return out

    def __len__(self) -> int:
        return len(self._held)


class WorkerPool:
    """N key-affine speed-layer workers behind one submission interface.

    ``submit(request, now)`` routes by primary entity, pumps every worker's
    flush triggers at the new virtual time, runs the work-stealing pass,
    and returns whatever scored results completed *in submission order*
    (later results are held in the reorder buffer until their turn).

    Work stealing: when a shard's queue backs up past ``steal_threshold``
    requests (only possible when ``service_model_s`` > 0 keeps its worker
    busy), an idle worker with an empty queue takes the oldest half of the
    victim's queue and serves it — affinity is traded away only under
    pressure, and only explicitly (counted in ``stats["steals"]``).

    With ``num_workers=1`` the pool degenerates to exactly the single
    MicroBatcher engine: same triggers, same stamps, same scores.
    """

    def __init__(self, params, cfg: LNNConfig, store: KVStore,
                 num_workers: int = 1, k_max: int = 8,
                 max_batch: int = 16, max_wait_s: float = 0.005,
                 service_model_s: float = 0.0,
                 steal_threshold: int | None = None):
        self.router = ShardRouter(num_workers)
        self.store = store
        self.max_batch = int(max_batch)
        self.steal_threshold = steal_threshold
        self.workers = [
            SpeedLayerWorker(
                w,
                Stage2Scorer(params, cfg, store, k_max),
                max_batch=max_batch,
                max_wait_s=max_wait_s,
                service_model_s=service_model_s,
            )
            for w in range(num_workers)
        ]
        self._reorder = _ReorderBuffer()
        self._seq = 0
        self.pool_stats = {"steals": 0, "stolen_requests": 0, "routed": 0}

    @property
    def num_workers(self) -> int:
        return self.router.num_workers

    def __len__(self) -> int:
        return sum(len(w) for w in self.workers)

    # ------------------------------------------------------------------ pump
    def _collect(self, results: list) -> list[ScoredResult]:
        """Resolve any in-flight process flushes before results enter the
        reorder buffer.  Inline flushes are already ScoredResults, so this
        is the identity for the in-process backend; the process backend's
        parallelism comes from several posted flushes resolving here
        together after one pump pass — delivery order, checkpoint state,
        and accounting stay inline-identical."""
        if not any(isinstance(r, PendingFlush) for r in results):
            return results
        out: list[ScoredResult] = []
        for r in results:
            out.extend(r.resolve() if isinstance(r, PendingFlush) else [r])
        return out

    def poll(self, now: float) -> list[ScoredResult]:
        """Advance the virtual clock: fire every due trigger, then let idle
        workers steal from backed-up shards."""
        results: list[ScoredResult] = []
        for w in self.workers:
            results.extend(w.pump(now))
        results.extend(self._steal_pass(now))
        self._reorder.add(self._collect(results))
        return self._reorder.release()

    def submit(self, request: ScoreRequest, now: float) -> list[ScoredResult]:
        """Route and enqueue one request, firing only the target worker's
        own triggers.  Callers advance the virtual clock with ``poll(now)``
        before submitting (the engine does exactly that), so other workers'
        due flushes have already fired — repeating the full sweep here
        would be a per-event no-op."""
        if self.router.num_workers != len(self.workers):
            raise RuntimeError(
                f"router has {self.router.num_workers} workers but the pool "
                f"has {len(self.workers)} — the router was resharded without "
                "the pool; use WorkerPool.reshard(n)"
            )
        request.seq = self._seq
        self._seq += 1
        w = self.workers[self.router.route(request.entity_keys)]
        w.enqueue(request)
        self.pool_stats["routed"] += 1
        results = w.pump(now)
        for worker in self.workers:
            worker.sample_depth()
        self._reorder.add(self._collect(results))
        return self._reorder.release()

    def _steal_pass(self, now: float) -> list[ScoredResult]:
        if self.steal_threshold is None:
            return []
        out: list[ScoredResult] = []
        for thief in self.workers:
            if not thief.free(now) or len(thief) > 0:
                continue
            # deterministic victim choice: deepest queue, lowest wid wins ties
            victim = max(
                (w for w in self.workers if w is not thief),
                key=lambda w: (len(w), -w.wid),
                default=None,
            )
            if victim is None or len(victim) < self.steal_threshold:
                continue
            stolen = victim.batcher.take(len(victim) // 2)
            if not stolen:
                continue
            victim.stats["stolen_out"] += len(stolen)
            thief.stats["stolen_in"] += len(stolen)
            self.pool_stats["steals"] += 1
            self.pool_stats["stolen_requests"] += len(stolen)
            # the work only reached the thief now: flushes of it must not be
            # backdated to the victim's long-missed triggers
            thief.stamp_floor = max(thief.stamp_floor, now)
            for r in stolen:
                thief.enqueue(r)
            out.extend(thief.pump(now))
        return out

    # --------------------------------------------------------------- reshard
    def reshard(self, num_workers: int) -> list[ScoredResult]:
        """Atomically change the worker count on a live pool.

        Drains every queue first (returned in submission order — those
        scores were produced under the old topology), then moves the
        router, the entity-affine KV shards, and the worker list together,
        so the affinity contract ``worker_of(entity) == store.shard_of``
        holds before and after.  New workers start with fresh jit caches —
        a genuinely cold process, as in production."""
        out = self.flush()
        self.router.reshard(num_workers)
        if getattr(self.store, "shard_by_entity", False):
            self.store.reshard(num_workers)
        tmpl = self.workers[0]
        self.workers = [
            SpeedLayerWorker(
                w,
                Stage2Scorer(tmpl.scorer.params, tmpl.scorer.cfg,
                             self.store, tmpl.scorer.k_max,
                             model_version=tmpl.scorer.model_version),
                max_batch=tmpl.batcher.max_batch,
                max_wait_s=tmpl.batcher.max_wait_s,
                service_model_s=tmpl.service_model_s,
            )
            for w in range(num_workers)
        ]
        return out

    # ------------------------------------------------------------- hot-swap
    def set_model(self, params, model_version: int) -> None:
        """Activate a parameter version on every worker.  Flushes already
        executing finish on the version they captured at entry; every
        subsequent flush (on any worker) scores under the new one."""
        for w in self.workers:
            w.scorer.set_model(params, model_version)

    # ------------------------------------------------------------ admission
    def busy_workers(self, now: float) -> int:
        """Workers whose virtual service window is open at ``now`` — the
        admission controller's in-flight count."""
        return sum(1 for w in self.workers if not w.free(now))

    def force_flush_deepest(self, now: float) -> list[ScoredResult]:
        """Flush one batch off the deepest queue at virtual time ``now`` —
        the admission controller's block policy: the producer stalls while
        the most backed-up worker drains a batch.  Returns completed
        results in submission order (empty if every queue is empty)."""
        victim = max(self.workers, key=lambda w: (len(w), -w.wid))
        if len(victim) == 0:
            return []
        results = victim._flush_at(now, "forced_flushes")
        self._reorder.add(self._collect(results))
        return self._reorder.release()

    def drain_to_depth(self, max_depth: int, now: float,
                       budget_s: float | None = None,
                       clock=time.monotonic) -> tuple[list[ScoredResult], bool]:
        """Bounded block-admission wait: force-flush the deepest queue until
        total depth drops below ``max_depth`` or the wall-clock ``budget_s``
        runs out.

        Returns ``(results, admitted)``.  ``admitted`` is False exactly when
        the stall timed out — the budget expired, or a flush pass freed no
        capacity (wedged queue) while a finite budget was set.  With
        ``budget_s=None`` the legacy semantics hold: a no-progress pass
        stops the stall and the caller admits over-cap (that unbounded/
        over-cap behavior is the bug ``admission.block_max_wait_s`` bounds —
        see ``tests/test_service.py::test_block_admission_bounded_wait``).
        """
        results: list[ScoredResult] = []
        deadline = None if budget_s is None else clock() + budget_s
        while len(self) >= max_depth:
            if deadline is not None and clock() >= deadline:
                return results, False
            before = len(self)
            results.extend(self.force_flush_deepest(now))
            if len(self) >= before:
                # nothing freed (every queue empty, or the flush raced away):
                # legacy mode admits over-cap; a bounded stall sheds instead
                return results, deadline is None
        return results, True

    # ----------------------------------------------------------------- drain
    def flush(self, now: float | None = None) -> list[ScoredResult]:
        """Drain every worker's queue (stream end) and the reorder buffer."""
        results: list[ScoredResult] = []
        for w in self.workers:
            results.extend(w.drain(now))
        self._reorder.add(self._collect(results))
        out = self._reorder.release()
        assert len(self._reorder) == 0, "reorder buffer retained results"
        return out

    def warmup(self) -> None:
        for w in self.workers:
            w.scorer.warmup(w.batcher.max_batch)

    def shutdown(self) -> None:
        """Release backend resources.  The inline pool holds none; the
        process backend overrides this to stop its shard processes and
        unlink shared memory (``FraudService.close`` calls it)."""

    # ----------------------------------------------------------------- stats
    @property
    def stats(self) -> dict:
        """Aggregated MicroBatcher counters across workers (the single-
        worker engine's ``batcher.stats`` shape, so reports don't care
        how many workers ran) plus pool-level routing/steal counters."""
        agg: dict = {}
        for w in self.workers:
            for k, v in w.batcher.stats.items():
                agg[k] = agg.get(k, 0) + v
        agg.update(self.pool_stats)
        agg["reorder_max_held"] = self._reorder.max_held
        return agg

    def worker_summary(self) -> list[dict]:
        out = []
        for w in self.workers:
            s = w.batcher.stats
            mean_depth = (w.stats["depth_sum"] / w.stats["depth_samples"]
                          if w.stats["depth_samples"] else 0.0)
            out.append({
                "worker": w.wid,
                "requests": s["requests"],
                "flushes": s["flushes"],
                "size_flushes": s["size_flushes"],
                "deadline_flushes": s["deadline_flushes"],
                "stolen_in": w.stats["stolen_in"],
                "stolen_out": w.stats["stolen_out"],
                "max_queue_depth": w.stats["max_queue_depth"],
                "mean_queue_depth": mean_depth,
                "queue_depth": len(w),
                "restarts": w.stats.get("restarts", 0),
                "alive": True,
            })
        return out


class DepthAutoscaler:
    """Queue-depth-driven pool sizing + adaptive steal threshold.

    Observes total queued depth once per submission (virtual-clock
    telemetry, so replays are deterministic) and applies classic
    watermark-with-hysteresis control:

    * mean depth per worker above ``high_depth`` for ``sustain``
      consecutive observations -> grow by one worker
      (``WorkerPool.reshard``), up to ``max_workers``;
    * below ``low_depth`` for ``sustain`` observations -> shrink by one,
      down to ``min_workers``;
    * after any reshard, ``cooldown`` observations pass before another
      decision — reshard drains the queues, so depth right after a scale
      event says nothing about steady state.

    With ``adaptive_steal`` the pool's ``steal_threshold`` is re-derived
    each observation from a rolling depth window: twice the rolling mean
    depth per worker, floored at ``max_batch`` — backed-up shards shed
    work sooner under sustained pressure, and stealing quiets down when
    queues are shallow.  All state is plain counters + a bounded window,
    exposed via ``state_dict``/``load_state`` so checkpoints capture it
    and replay reproduces every scale decision bit-identically.
    """

    WINDOW = 32

    def __init__(self, pool: WorkerPool, *, min_workers: int = 1,
                 max_workers: int = 8, high_depth: float = 8.0,
                 low_depth: float = 1.0, sustain: int = 16,
                 cooldown: int = 64, autoscale: bool = True,
                 adaptive_steal: bool = False):
        if not 1 <= min_workers <= max_workers:
            raise ValueError("need 1 <= min_workers <= max_workers")
        if low_depth >= high_depth:
            raise ValueError("low_depth must be < high_depth")
        self.pool = pool
        self.min_workers = int(min_workers)
        self.max_workers = int(max_workers)
        self.high_depth = float(high_depth)
        self.low_depth = float(low_depth)
        self.sustain = max(1, int(sustain))
        self.cooldown = max(0, int(cooldown))
        self.autoscale = bool(autoscale)
        self.adaptive_steal = bool(adaptive_steal)
        self._above = 0
        self._below = 0
        self._cool = 0
        self._window: list[int] = []
        self.stats = {"scale_ups": 0, "scale_downs": 0, "observations": 0}

    def observe(self, now: float) -> list[ScoredResult]:
        """One control step.  Returns results drained by a reshard (they
        were scored under the old topology and must reach the caller)."""
        pool = self.pool
        depth = len(pool)
        n = pool.num_workers
        self.stats["observations"] += 1
        self._window.append(depth)
        if len(self._window) > self.WINDOW:
            self._window.pop(0)
        if self.adaptive_steal:
            mean = sum(self._window) / len(self._window)
            pool.steal_threshold = max(
                pool.max_batch, int(2.0 * mean / max(1, n)))
        if not self.autoscale:
            return []
        if self._cool > 0:
            self._cool -= 1
            return []
        per_worker = depth / max(1, n)
        self._above = self._above + 1 if per_worker > self.high_depth else 0
        self._below = self._below + 1 if per_worker < self.low_depth else 0
        target = n
        if self._above >= self.sustain and n < self.max_workers:
            target = n + 1
            self.stats["scale_ups"] += 1
        elif self._below >= self.sustain and n > self.min_workers:
            target = n - 1
            self.stats["scale_downs"] += 1
        if target == n:
            return []
        self._above = self._below = 0
        self._cool = self.cooldown
        return pool.reshard(target)

    # ----------------------------------------------------------- durability
    def state_dict(self) -> dict:
        """Control state for the checkpoint manifest — restoring it makes
        WAL-replayed traffic reproduce every scale decision exactly."""
        return {"above": self._above, "below": self._below,
                "cool": self._cool, "window": list(self._window),
                "stats": dict(self.stats)}

    def load_state(self, d: dict) -> None:
        self._above = int(d.get("above", 0))
        self._below = int(d.get("below", 0))
        self._cool = int(d.get("cool", 0))
        self._window = [int(x) for x in d.get("window", [])]
        self.stats.update(d.get("stats", {}))
