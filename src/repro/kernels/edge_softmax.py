"""Pallas TPU kernel: GAT edge softmax + weighted aggregation.

    logits[i,d] = leaky_relu(s_src[nbr_idx[i,d]] + s_dst[i] + etype_bias[i,d])
    attn        = softmax over valid d  (masked by nbr_mask)
    out[i, :]   = sum_d attn[i,d] * z[nbr_idx[i,d], :]

One grid step owns a node tile and the full feature width (GNN hidden dims
here are <= 256, so the z gather target fits VMEM whole; the node dimension
is the tiled axis).  Softmax runs in f32 with the usual max-subtraction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.utils.padding import ceil_div


def _edge_softmax_kernel(z_ref, ssrc_ref, sdst_ref, idx_ref, mask_ref, bias_ref, out_ref):
    z = z_ref[...]                   # [N, H]
    idx = idx_ref[...]               # [bn, D]
    mask = mask_ref[...]             # [bn, D]
    bn, D = idx.shape

    logits = (
        jnp.take(ssrc_ref[...], idx, axis=0)
        + sdst_ref[...][:, None]
        + bias_ref[...]
    ).astype(jnp.float32)
    logits = jnp.where(logits >= 0, logits, 0.2 * logits)          # leaky relu
    logits = jnp.where(mask > 0, logits, -1e9)
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    attn = (e / jnp.sum(e, axis=-1, keepdims=True)) * mask

    acc = jnp.zeros((bn, z.shape[1]), jnp.float32)

    def body(d, acc):
        rows = jnp.take(z, idx[:, d], axis=0)
        return acc + rows.astype(jnp.float32) * attn[:, d][:, None]

    acc = jax.lax.fori_loop(0, D, body, acc)
    out_ref[...] = acc.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def edge_softmax_agg_pallas(z, s_src, s_dst, nbr_idx, nbr_mask, etype_bias,
                            block_n: int = 128, interpret: bool = True):
    n, feat = z.shape
    _, d = nbr_idx.shape
    bn = min(block_n, n)
    grid = (ceil_div(n, bn),)
    return pl.pallas_call(
        _edge_softmax_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, feat), lambda i: (0, 0)),   # z (full)
            pl.BlockSpec((n,), lambda i: (0,)),          # s_src (full, gathered)
            pl.BlockSpec((bn,), lambda i: (i,)),         # s_dst tile
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bn, feat), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, feat), z.dtype),
        interpret=interpret,
    )(z, s_src, s_dst, nbr_idx, nbr_mask, etype_bias)
