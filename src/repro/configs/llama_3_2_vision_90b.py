"""llama-3.2-vision-90b — VLM with cross-attention image layers
[hf:meta-llama/Llama-3.2-11B-Vision, scaled per assignment].

100L total (80 self + 20 cross-attn in 5-layer superblocks), d_model=8192,
64 q heads (head_dim 128), 8 kv heads, d_ff=28672, vocab=128256.
The ViT/projector frontend is a stub: ``input_specs`` provides pre-projected
patch embeddings (num_vision_tokens x d_model).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b",
    arch_type="vlm",
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    cross_attn_every=5,
    num_vision_tokens=1601,   # 1 tile x (40x40 patches + 1 cls), mllama
    rope_theta=500_000.0,
    source="[hf:meta-llama/Llama-3.2-11B-Vision]",
)
