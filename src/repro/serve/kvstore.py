"""Key-value embedding store — the paper's "distributed key-value store"
(production would be Couchbase/Redis; here an in-memory dict with an
npz-backed persistence path and the same access pattern: batched point
lookups by entity key).

Keys are (entity_id, snapshot) pairs packed into int64; values are stage-1
entity embeddings.  ``lookup_batch`` returns a dense [B, K, H] tensor plus
mask — exactly the speed-layer input.
"""
from __future__ import annotations

import os
import time

import numpy as np


def pack_key(entity: int, snapshot: int) -> int:
    return (int(entity) << 20) | (int(snapshot) & 0xFFFFF)


class KVStore:
    def __init__(self, dim: int):
        self.dim = dim
        self._data: dict[int, np.ndarray] = {}
        self.stats = {"puts": 0, "gets": 0, "misses": 0}

    def put(self, key: int, value: np.ndarray):
        self._data[key] = np.asarray(value, np.float32)
        self.stats["puts"] += 1

    def put_batch(self, keys, values):
        for k, v in zip(keys, values):
            self.put(int(k), v)

    def get(self, key: int):
        self.stats["gets"] += 1
        v = self._data.get(int(key))
        if v is None:
            self.stats["misses"] += 1
        return v

    def lookup_batch(self, key_lists: list, k_max: int):
        """key_lists: per request, a list of entity keys (<= k_max used).

        Returns (emb [B, K, H] float32, mask [B, K]) with zero rows for
        missing keys — cold entities contribute nothing, matching the DDS
        semantics for orders without history."""
        b = len(key_lists)
        emb = np.zeros((b, k_max, self.dim), np.float32)
        mask = np.zeros((b, k_max), np.float32)
        for i, keys in enumerate(key_lists):
            for j, key in enumerate(keys[:k_max]):
                v = self.get(key)
                if v is not None:
                    emb[i, j] = v
                    mask[i, j] = 1.0
        return emb, mask

    def __len__(self):
        return len(self._data)

    # ------------------------------------------------------------- persistence
    def save(self, path: str):
        keys = np.asarray(list(self._data.keys()), np.int64)
        vals = np.stack(list(self._data.values())) if self._data else np.zeros((0, self.dim))
        np.savez(path, keys=keys, values=vals, dim=self.dim)

    @classmethod
    def load(cls, path: str) -> "KVStore":
        with np.load(path) as data:
            store = cls(int(data["dim"]))
            for k, v in zip(data["keys"], data["values"]):
                store._data[int(k)] = v
        return store
