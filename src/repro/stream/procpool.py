"""Process-backed speed layer — ``repro.stream.procpool``.

The inline :class:`~repro.stream.workers.WorkerPool` simulates N workers
inside one interpreter: private jit caches, but one GIL and one address
space.  This module makes the workers real OS processes:

* each :class:`SpeedLayerWorker`'s *compute* (the stage-2 jit dispatch and
  its KV shard) lives in its own spawned process with its own jit cache;
* the parent keeps ALL scheduling — queues, flush triggers, work stealing,
  the reorder buffer, the virtual clock — byte-for-byte identical to the
  inline pool, so replay parity is a property of the compute protocol, not
  of scheduler luck;
* feature payloads travel through a per-child shared-memory ``<f4`` ring
  buffer; control goes over a pickle-free framed pipe protocol (u32
  header length + JSON header + raw binary sections);
* cross-shard KV reads are explicit owner-process READ frames, resolved by
  the parent *before* a SCORE is posted, in the inline lookup's per-owner
  order — per-shard LRU recency and counter sums stay inline-identical.

Topology (one parent, N shard processes)::

    parent: router ─ queues ─ steal ─ reorder ─ virtual clock
       │ READ/PUT/LOAD/REFRESH/SET_MODEL/SNAPSHOT frames (pipe)
       │ SCORE feats ───────────────── shm ring ──────────────┐
       └─> child w: KVStore shard w + Stage2Scorer jit cache <┘

Determinism: XLA on one host compiles the same HLO to the same code, and
every reduction the scorer runs is fixed-shape (pow2 buckets), so a child
process's scores are bit-identical to the parent's inline scores for the
same inputs — the property ``tests/test_procpool.py`` locks in for N=1 and
N=4, across hot-swaps, checkpoint/restore, and a SIGKILLed worker.

Failure model: a dead child is detected by the liveness sweep at the top
of every :meth:`ProcessWorkerPool.poll` (and by any post/wait hitting the
broken pipe).  Recovery respawns the process, replays the model chain,
restores the shard from the parent's put-journal (reset to a LOAD of the
last SNAPSHOT sweep, then the puts since), and re-posts any in-flight
SCORE frame exactly once.  Lost with the process: that shard's LRU
touches and read counters since the last snapshot (documented in
docs/processes.md).
"""
from __future__ import annotations

import json
import os
import shutil
import signal
import struct
import tempfile
import threading
from collections import OrderedDict
from contextlib import contextmanager
from multiprocessing import get_context, shared_memory

import numpy as np

from repro.serve.kvstore import (
    SNAPSHOT_BITS,
    KVStore,
    _reject_untagged,
    entity_shard,
    stable_shard,
)
from repro.stream.microbatch import DeferredScore
from repro.stream.workers import SpeedLayerWorker, Stage2Scorer, WorkerPool
from repro.utils import crashpoint

DEFAULT_RING_BYTES = 1 << 20


# ------------------------------------------------------------------ framing
def pack_frame(header: dict, sections=()) -> bytes:
    """``u32 header-length | JSON header | raw section bytes``.

    ``sections`` is an ordered list of ``(name, ndarray)``; their dtype and
    shape descriptors are appended to the header under ``"sections"`` so
    the receiver can slice the binary tail without pickling anything.
    """
    header = dict(header)
    secs = [(name, np.ascontiguousarray(arr)) for name, arr in sections]
    header["sections"] = [
        {"name": name, "dtype": arr.dtype.str, "shape": list(arr.shape)}
        for name, arr in secs
    ]
    hj = json.dumps(header).encode("utf-8")
    return b"".join([struct.pack("<I", len(hj)), hj]
                    + [arr.tobytes() for _, arr in secs])


def unpack_frame(buf: bytes) -> tuple[dict, dict]:
    """Inverse of :func:`pack_frame`: ``(header, {name: array})``.

    Arrays are zero-copy read-only views into ``buf`` — copy before
    mutating or before the frame buffer must be released.
    """
    (hl,) = struct.unpack_from("<I", buf, 0)
    header = json.loads(bytes(buf[4:4 + hl]).decode("utf-8"))
    off = 4 + hl
    out: dict[str, np.ndarray] = {}
    for sec in header.pop("sections", []):
        dt = np.dtype(sec["dtype"])
        n = int(np.prod(sec["shape"], dtype=np.int64)) * dt.itemsize
        out[sec["name"]] = np.frombuffer(
            buf, dtype=dt, count=n // dt.itemsize if dt.itemsize else 0,
            offset=off).reshape(sec["shape"])
        off += n
    return header, out


class ShmRing:
    """FIFO region allocator over one SharedMemory block.

    The parent allocates a contiguous region per SCORE's ``<f4`` feature
    matrix and frees it when that message's reply arrives; because a child
    answers its pipe FIFO, regions free in allocation order and the
    classic head-chases-tail ring layout holds.  ``alloc`` returns None
    when the payload cannot fit — the caller falls back to shipping the
    features inline in the frame, so the ring size is a fast path, never a
    correctness bound.
    """

    def __init__(self, nbytes: int = DEFAULT_RING_BYTES, name: str | None = None):
        if name is None:
            self.shm = shared_memory.SharedMemory(create=True, size=int(nbytes))
        else:
            self.shm = shared_memory.SharedMemory(name=name)
        self.capacity = self.shm.size
        self._live: OrderedDict[int, tuple[int, int]] = OrderedDict()
        self._head = 0

    def alloc(self, msg_id: int, nbytes: int) -> int | None:
        nbytes = int(nbytes)
        if nbytes > self.capacity:
            return None
        if not self._live:
            off = 0
        else:
            tail = next(iter(self._live.values()))[0]
            if self._head >= tail:
                if self._head + nbytes <= self.capacity:
                    off = self._head
                elif nbytes <= tail:
                    off = 0
                else:
                    return None
            elif self._head + nbytes <= tail:
                off = self._head
            else:
                return None
        self._live[msg_id] = (off, nbytes)
        self._head = off + nbytes
        return off

    def write(self, off: int, arr: np.ndarray) -> None:
        self.shm.buf[off:off + arr.nbytes] = arr.tobytes()

    def free(self, msg_id) -> None:
        self._live.pop(msg_id, None)

    def destroy(self) -> None:
        try:
            self.shm.close()
            self.shm.unlink()
        except (FileNotFoundError, OSError):  # already gone (child unlinked)
            pass


# ------------------------------------------------------------ child server
def _load_model_file(path: str, cfg):
    """Load a model npz the way the service restore path does — hybrid
    checkpoints carry their own marker, plain ones restore into an
    ``lnn_init`` template."""
    import jax

    from repro.core.lnn import lnn_init
    from repro.models.hybrid import is_hybrid_checkpoint, load_hybrid
    from repro.train.checkpoint import load_checkpoint

    template = lnn_init(jax.random.PRNGKey(0), cfg)
    if is_hybrid_checkpoint(path):
        return load_hybrid(path, template, cfg)
    return load_checkpoint(path, template)[0]


def _stage1_params_of(params):
    from repro.models.hybrid import HybridModel

    return params.lnn_params if isinstance(params, HybridModel) else params


class ShardServer:
    """Child-side command executor for one shard process.

    Owns the child's :class:`KVStore` (built with the SAME constructor
    arguments as the inline store — a child only ever receives keys it
    owns, which all land in its own local shard, so per-shard capacity and
    LRU semantics match the inline layout exactly) and its
    :class:`Stage2Scorer` with per-version jit caches.

    Deliberately process-agnostic: ``handle(header, sections)`` maps one
    request frame to one reply frame, so unit tests drive the full command
    surface in-parent (coverage) while ``_worker_main`` is only the recv
    loop around it.
    """

    def __init__(self, wid: int, cfg, store_cfg: dict, k_max: int,
                 max_batch: int, model_path: str, model_version: int,
                 shm_buf=None):
        self.wid = int(wid)
        self.cfg = cfg
        self.max_batch = int(max_batch)
        self.shm_buf = shm_buf
        self.store = KVStore(**store_cfg)
        params = _load_model_file(model_path, cfg)
        self.scorer = Stage2Scorer(params, cfg, self.store, k_max,
                                   model_version=int(model_version))
        self._params_by_version = {int(model_version): params}
        self._stage1_jits: dict[int, object] = {}

    # ---------------------------------------------------------------- dispatch
    def handle(self, header: dict, sections: dict) -> tuple[dict, list]:
        cmd = header.get("cmd")
        reply = {"id": header.get("id"), "ok": 1}
        try:
            fn = getattr(self, f"_cmd_{cmd}", None)
            if fn is None:
                raise ValueError(f"unknown command {cmd!r}")
            secs = fn(header, sections, reply) or []
        except Exception as e:  # noqa: BLE001 — child must reply, not die
            return {"id": header.get("id"), "error": f"{type(e).__name__}: {e}"}, []
        return reply, secs

    # ---------------------------------------------------------------- commands
    def _feats_of(self, header, sections):
        if "shm_off" in header:
            off = int(header["shm_off"])
            rows, cols = header["shm_shape"]
            n = rows * cols * 4
            # copy: the parent reclaims the ring region once our reply lands
            return np.frombuffer(self.shm_buf, dtype="<f4", count=rows * cols,
                                 offset=off).reshape(rows, cols).copy()
        return np.asarray(sections["feats"], np.float32)

    def _cmd_score(self, header, sections, reply):
        version = int(header["version"])
        if self.scorer.model_version != version:
            self.scorer.set_model(self._params_by_version[version], version)
        key_lists = header["keys"]
        feats = self._feats_of(header, sections)
        k_max = self.scorer.k_max
        b = len(key_lists)
        emb = np.zeros((b, k_max, self.store.dim), np.float32)
        mask = np.zeros((b, k_max), np.float32)
        stale = np.full((b, k_max), -1, np.int32)
        remote = {(int(i), int(j)): (r, int(has), int(st))
                  for r, (i, j, has, st) in enumerate(header.get("remote", []))}
        remote_emb = sections.get("remote_emb")
        for i, pairs in enumerate(key_lists):
            for j, (ent, t) in enumerate(pairs[:k_max]):
                hit = remote.get((i, j))
                if hit is not None:
                    r, has, st = hit
                    if has:
                        emb[i, j] = remote_emb[r]
                        mask[i, j] = 1.0
                        stale[i, j] = st
                    continue
                v, s = self.store.lookup_versioned_one(
                    int(ent), int(t), expected_model_version=version)
                if v is not None:
                    emb[i, j] = v
                    mask[i, j] = 1.0
                    stale[i, j] = s
        probs, stale_max, ver = self.scorer.score_slots(
            feats, key_lists, emb, mask, stale)
        reply["version"] = int(ver)
        return [("probs", np.asarray(probs, np.float32)),
                ("stale", np.asarray(stale_max, np.int32))]

    def _cmd_read(self, header, sections, reply):
        expected = header.get("version")
        pairs = header["pairs"]
        emb = np.zeros((len(pairs), self.store.dim), np.float32)
        has = np.zeros(len(pairs), np.int8)
        stale = np.full(len(pairs), -1, np.int32)
        for r, (ent, t) in enumerate(pairs):
            v, s = self.store.lookup_versioned_one(
                int(ent), int(t), expected_model_version=expected)
            if v is not None:
                emb[r] = v
                has[r] = 1
                stale[r] = s
        return [("emb", emb), ("has", has), ("stale", stale)]

    def _cmd_put(self, header, sections, reply):
        n = self.store.put_batch(
            np.asarray(sections["keys"], np.int64),
            np.asarray(sections["values"], np.float32),
            version=int(header["pver"]),
            model_version=int(header["model_version"]),
            stamp=float(header["stamp"]),
        )
        reply["n"] = n

    def _cmd_load(self, header, sections, reply):
        s = int(header["shard"])
        keys = np.asarray(sections["keys"], np.int64)
        vals = np.asarray(sections["values"], np.float32)
        vers = np.asarray(sections["versions"], np.int64)
        stamps = np.asarray(sections["stamps"], np.float64)
        mvs = np.asarray(sections["model_versions"], np.int64)
        items = [(int(keys[i]), vals[i], int(vers[i]), float(stamps[i]),
                  int(mvs[i])) for i in range(len(keys))]
        shards = [[] for _ in range(self.store.num_shards)]
        shards[s] = items
        self.store.load_items(shards)
        reply["n"] = len(items)

    def _cmd_snapshot(self, header, sections, reply):
        shards = self.store.shard_items()
        ks, vs, vers, stamps, mvs = [], [], [], [], []
        shard_off = [0]
        for items in shards:
            for k, v, ver, st, mv in items:
                ks.append(int(k))
                vs.append(np.asarray(v, np.float32))
                vers.append(int(ver))
                stamps.append(float(st))
                mvs.append(int(mv))
            shard_off.append(len(ks))
        reply["shard_off"] = shard_off
        reply["stats"] = dict(self.store.stats)
        reply["len"] = len(self.store)
        vals = (np.stack(vs) if vs
                else np.zeros((0, self.store.dim), np.float32))
        return [("keys", np.asarray(ks, np.int64)), ("values", vals),
                ("versions", np.asarray(vers, np.int64)),
                ("stamps", np.asarray(stamps, np.float64)),
                ("model_versions", np.asarray(mvs, np.int64))]

    def _cmd_stats(self, header, sections, reply):
        reply["stats"] = dict(self.store.stats)
        reply["len"] = len(self.store)

    def _cmd_set_model(self, header, sections, reply):
        version = int(header["version"])
        if version not in self._params_by_version:
            self._params_by_version[version] = _load_model_file(
                header["path"], self.cfg)
        self.scorer.set_model(self._params_by_version[version], version)

    def _cmd_warmup(self, header, sections, reply):
        self.scorer.warmup(self.max_batch)

    def _cmd_refresh(self, header, sections, reply):
        import jax

        from repro.core.graph import PaddedGraph
        from repro.core.lnn import lnn_stage1

        version = int(header["version"])
        params = _stage1_params_of(self._params_by_version[version])
        jit = self._stage1_jits.get(version)
        if jit is None:
            cfg = self.cfg
            jit = self._stage1_jits[version] = jax.jit(
                lambda p, g: lnn_stage1(p, cfg, g))
        pg = PaddedGraph(**{name: sections[name] for name in header["fields"]})
        h = np.asarray(jit(params, pg), np.float32)
        return [("h", h)]

    def _cmd_ping(self, header, sections, reply):
        reply["wid"] = self.wid

    def _cmd_stop(self, header, sections, reply):
        reply["stopped"] = 1


def _worker_main(conn, shm_name, init: dict) -> None:  # pragma: no cover
    """Child entry point: one ShardServer behind a framed recv loop.

    Excluded from coverage: this function executes only inside the spawned
    shard process, which the parent's tracer cannot see — its body is one
    recv loop around :meth:`ShardServer.handle`, and the command surface
    itself is covered in-parent by ``tests/test_procpool.py``."""
    # NOTE on the resource tracker: Python <= 3.12 registers the segment on
    # ATTACH too (bpo-38119), but spawn children share the parent's tracker
    # process and its name cache is a set — the duplicate registration
    # collapses, and the parent's unlink() clears the single entry.  No
    # child-side unregister needed (it would double-remove and spam
    # KeyErrors from the tracker).
    shm = shared_memory.SharedMemory(name=shm_name) if shm_name else None
    server = ShardServer(
        init["wid"], init["cfg"], init["store_cfg"], init["k_max"],
        init["max_batch"], init["model_path"], init["model_version"],
        shm_buf=shm.buf if shm is not None else None,
    )
    try:
        while True:
            try:
                buf = conn.recv_bytes()
            except (EOFError, OSError):
                break
            header, sections = unpack_frame(buf)
            rh, rs = server.handle(header, sections)
            conn.send_bytes(pack_frame(rh, rs))
            if rh.get("stopped"):
                break
    finally:
        # drop the buffer views before closing the mapping, then close but
        # do NOT unlink — the parent owns the segment's lifetime
        del server
        if shm is not None:
            shm.close()
        conn.close()


# ------------------------------------------------------------- parent side
class WorkerDied(RuntimeError):
    """A shard process exited (crash or SIGKILL) under an in-flight frame."""

    def __init__(self, wid: int):
        super().__init__(f"shard process {wid} died")
        self.wid = wid


class ChildError(RuntimeError):
    """A shard process answered a frame with an error reply."""


@contextmanager
def _patched_env(env: dict | None):
    """Temporarily patch os.environ around a spawn — the child inherits the
    patched environment (thread pinning for the scaling bench) while the
    parent's is restored immediately."""
    if not env:
        yield
        return
    saved = {k: os.environ.get(k) for k in env}
    os.environ.update({k: str(v) for k, v in env.items()})
    try:
        yield
    finally:
        for k, v in saved.items():
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v


class _ChildHandle:
    """Parent-side endpoint for one shard process: the pipe, the shm ring,
    and a msg-id demultiplexer (a reply for a message another thread is
    waiting on is stashed, not dropped — the serving thread and the async
    refresh thread share each child)."""

    def __init__(self, wid: int, ctx, init: dict, ring_bytes: int,
                 child_env: dict | None):
        self.wid = int(wid)
        self.ring = ShmRing(ring_bytes)
        parent_conn, child_conn = ctx.Pipe()
        with _patched_env(child_env):
            self.proc = ctx.Process(
                target=_worker_main,
                args=(child_conn, self.ring.shm.name, init),
                daemon=True,
                name=f"repro-shard-{wid}",
            )
            self.proc.start()
        child_conn.close()
        self.conn = parent_conn
        self._lock = threading.Lock()
        self._stash: dict[int, tuple[dict, dict]] = {}
        self._count = 0

    def alive(self) -> bool:
        return self.proc.is_alive()

    def post(self, header: dict, sections=(), feats: np.ndarray | None = None) -> int:
        """Send one frame; large ``<f4`` payloads ride the shm ring (inline
        fallback when the ring is momentarily full).  Returns the msg id."""
        with self._lock:
            msg_id = self._count
            self._count += 1
            header = dict(header)
            header["id"] = msg_id
            secs = list(sections)
            if feats is not None:
                feats = np.ascontiguousarray(feats, "<f4")
                off = self.ring.alloc(msg_id, feats.nbytes)
                if off is None:
                    secs.append(("feats", feats))
                else:
                    self.ring.write(off, feats)
                    header["shm_off"] = off
                    header["shm_shape"] = list(feats.shape)
            buf = pack_frame(header, secs)
            try:
                self.conn.send_bytes(buf)
            except (BrokenPipeError, OSError):
                self.ring.free(msg_id)
                raise WorkerDied(self.wid) from None
            return msg_id

    def wait(self, msg_id: int) -> tuple[dict, dict]:
        """Block for the reply to ``msg_id``; replies to other messages are
        stashed for their waiters.  Frees the ring region of whichever
        message each arriving reply answers."""
        while True:
            with self._lock:
                if msg_id in self._stash:
                    h, s = self._stash.pop(msg_id)
                    break
                try:
                    buf = self.conn.recv_bytes()
                except (EOFError, OSError):
                    raise WorkerDied(self.wid) from None
                h, s = unpack_frame(buf)
                self.ring.free(h.get("id"))
                if h.get("id") == msg_id:
                    break
                self._stash[h["id"]] = (h, s)
        if "error" in h:
            raise ChildError(f"shard process {self.wid}: {h['error']}")
        return h, s

    def request(self, header: dict, sections=()) -> tuple[dict, dict]:
        return self.wait(self.post(header, sections))

    def destroy(self, stop: bool = False, timeout: float = 5.0) -> None:
        """Tear down: optionally a polite STOP, then join/terminate, close
        the pipe, and unlink the ring segment."""
        if stop and self.proc.is_alive():
            try:
                self.request({"cmd": "stop"})
            except (WorkerDied, ChildError):
                pass
        try:
            self.conn.close()
        except OSError:
            pass
        self.proc.join(timeout=timeout)
        if self.proc.is_alive():
            self.proc.terminate()
            self.proc.join(timeout=timeout)
        self.ring.destroy()


class ProcStoreView:
    """Parent-side facade over the children's KV shards.

    Implements the slice of the :class:`KVStore` surface the parent needs —
    versioned batch lookups (shadow scoring), batched puts (refresh feeds,
    WAL replay), length/stats, and the checkpoint state-transfer trio
    ``shard_items``/``load_items``/``restore_stats`` — by translating each
    call into owner-routed frames.  Counter sums equal the inline store's
    because every logical operation executes exactly once at its owner.
    """

    def __init__(self, pool: "ProcessWorkerPool", dim: int,
                 capacity: int | None = None, ttl_seconds: float | None = None,
                 num_shards: int = 1, shard_by_entity: bool = False,
                 require_typed: bool = False):
        self.pool = pool
        self.dim = int(dim)
        self.capacity = capacity
        self.ttl_seconds = ttl_seconds
        self.num_shards = int(num_shards)
        self.shard_by_entity = bool(shard_by_entity)
        self.require_typed = bool(require_typed)
        # parent-held counter base: merged stats = base + sum(child stats).
        # restore_stats() folds a checkpointed dict into the base so the
        # merged view equals the restored counters exactly.
        self._stats_base = {k: 0 for k in KVStore(1).stats}

    # --------------------------------------------------------------- placement
    def shard_of(self, key: int) -> int:
        if self.shard_by_entity:
            return entity_shard(int(key) >> SNAPSHOT_BITS, self.num_shards,
                                require_typed=self.require_typed)
        if self.require_typed:
            _reject_untagged(int(key) >> SNAPSHOT_BITS)
        return stable_shard(key, self.num_shards)

    # ------------------------------------------------------------------- reads
    def lookup_batch_versioned(self, entity_t_lists: list, k_max: int,
                               expected_model_version: int | None = None):
        b = len(entity_t_lists)
        emb = np.zeros((b, k_max, self.dim), np.float32)
        mask = np.zeros((b, k_max), np.float32)
        stale = np.full((b, k_max), -1, np.int32)
        per_owner: dict[int, list] = {}
        for i, pairs in enumerate(entity_t_lists):
            for j, (ent, t) in enumerate(pairs[:k_max]):
                if self.require_typed:
                    _reject_untagged(int(ent))
                per_owner.setdefault(self.pool.owner_of(int(ent)), []).append(
                    (i, j, int(ent), int(t)))
        for o in sorted(per_owner):
            plist = per_owner[o]
            e, has, st = self.pool.read_pairs(
                o, [[ent, t] for _, _, ent, t in plist], expected_model_version)
            for r, (i, j, _, _) in enumerate(plist):
                if has[r]:
                    emb[i, j] = e[r]
                    mask[i, j] = 1.0
                    stale[i, j] = st[r]
        return emb, mask, stale

    def lookup_versioned_one(self, ent: int, t_e: int,
                             expected_model_version: int | None = None):
        if self.require_typed:
            _reject_untagged(int(ent))
        e, has, st = self.pool.read_pairs(
            self.pool.owner_of(int(ent)), [[int(ent), int(t_e)]],
            expected_model_version)
        return (e[0] if has[0] else None), int(st[0])

    # ------------------------------------------------------------------ writes
    def put_batch(self, keys, values, version: int = 0,
                  model_version: int = 0, stamp: float | None = None) -> int:
        import time

        keys = [int(k) for k in keys]
        vals = [np.asarray(v, np.float32) for v in values]
        crashpoint.fire("kv.put_batch.before")
        stamp = time.time() if stamp is None else float(stamp)
        groups: dict[int, list[int]] = {}
        for idx, k in enumerate(keys):
            self.shard_of(k)  # typed-keyspace validation, same as inline
            ent = k >> SNAPSHOT_BITS
            groups.setdefault(self.pool.owner_of(ent), []).append(idx)
        for o in sorted(groups):
            idxs = groups[o]
            self.pool.put_group(
                o, np.asarray([keys[i] for i in idxs], np.int64),
                (np.stack([vals[i] for i in idxs]) if idxs
                 else np.zeros((0, self.dim), np.float32)),
                int(version), int(model_version), stamp)
        crashpoint.fire("kv.put_batch.after")
        return len(keys)

    def put(self, key: int, value, version: int = 0, model_version: int = 0):
        self.put_batch([key], [value], version=version,
                       model_version=model_version)

    # ----------------------------------------------------------- introspection
    def __len__(self) -> int:
        return self.pool.store_len()

    @property
    def stats(self) -> dict:
        merged = dict(self._stats_base)
        for k, v in self.pool.child_stats_sum().items():
            merged[k] = merged.get(k, 0) + v
        return merged

    def keys(self) -> list[int]:
        return [k for shard in self.shard_items() for (k, *_rest) in shard]

    # ------------------------------------------------------- state transfer
    def shard_items(self) -> list[list[tuple]]:
        """SNAPSHOT sweep over every child, merged into the logical shard
        layout (child w's local shard s feeds logical shard s — nonowned
        local shards are empty by construction).  Also resets each child's
        put-journal to a LOAD of this snapshot, keeping recovery replay
        bounded."""
        out: list[list[tuple]] = [[] for _ in range(self.num_shards)]
        for items_by_shard in self.pool.snapshot_children():
            for s, items in enumerate(items_by_shard):
                out[s].extend(items)
        return out

    def load_items(self, shards_items: list[list[tuple]]) -> None:
        if len(shards_items) != self.num_shards:
            raise ValueError(
                f"load_items got {len(shards_items)} shards for a "
                f"{self.num_shards}-shard store")
        for s, items in enumerate(shards_items):
            self.pool.load_shard(self.pool.owner_of_shard(s), s, items)

    def restore_stats(self, stats: dict) -> None:
        sums = self.pool.child_stats_sum()
        base = dict(self._stats_base)
        for k, v in stats.items():
            base[k] = v - sums.get(k, 0)
        self._stats_base = base


class ProcessWorkerPool(WorkerPool):
    """The inline :class:`WorkerPool` with its compute plane moved into
    real processes.  Scheduling (queues, triggers, stealing, reorder,
    virtual clock) is inherited unchanged; each worker's ``score_fn`` is
    replaced by one that posts a SCORE frame to its shard process and
    returns a :class:`DeferredScore` — the pool's ``_collect`` resolves
    all of a pump pass's in-flight flushes together, which is where the
    multi-process parallelism comes from.
    """

    def __init__(self, params, cfg, store_cfg: dict, num_workers: int = 1,
                 k_max: int = 8, max_batch: int = 16, max_wait_s: float = 0.005,
                 service_model_s: float = 0.0, steal_threshold: int | None = None,
                 model_version: int = 0, ring_bytes: int = DEFAULT_RING_BYTES,
                 child_env: dict | None = None):
        store_cfg = dict(store_cfg)
        if num_workers > 1:
            if not store_cfg.get("shard_by_entity"):
                raise ValueError(
                    "the process backend needs shard_by_entity=True for "
                    "num_workers > 1 — shard ownership is what makes each "
                    "child's KV reads local")
            if store_cfg.get("num_shards") != num_workers:
                raise ValueError(
                    "process backend: store num_shards must equal "
                    f"num_workers (got {store_cfg.get('num_shards')} vs "
                    f"{num_workers})")
        self._ctx = get_context("spawn")
        self._cfg = cfg
        self._k_max = int(k_max)
        self._max_batch = int(max_batch)
        self._store_cfg = store_cfg
        self._ring_bytes = int(ring_bytes)
        self._child_env = child_env
        self._model_dir = tempfile.mkdtemp(prefix="repro-procpool-")
        self._model_paths: dict[int, str] = {}
        self._model_order: list[int] = []
        self._model_version = int(model_version)
        self._save_model(params, model_version)
        self._closed = False
        self._journal: dict[int, list] = {}
        self._children: list[_ChildHandle] = [
            self._spawn_child(w) for w in range(num_workers)]
        store = ProcStoreView(self, **store_cfg)
        super().__init__(params, cfg, store, num_workers=num_workers,
                         k_max=k_max, max_batch=max_batch,
                         max_wait_s=max_wait_s,
                         service_model_s=service_model_s,
                         steal_threshold=steal_threshold)
        self._attach_score_fns()

    # ----------------------------------------------------------- child plumbing
    def _save_model(self, params, version: int) -> str:
        from repro.models.hybrid import HybridModel, save_hybrid
        from repro.train.checkpoint import save_checkpoint

        version = int(version)
        if version not in self._model_paths:
            path = os.path.join(self._model_dir, f"v{version}.npz")
            if isinstance(params, HybridModel):
                save_hybrid(path, params)
            else:
                save_checkpoint(path, params)
            self._model_paths[version] = path
            self._model_order.append(version)
        return self._model_paths[version]

    def _spawn_child(self, wid: int) -> _ChildHandle:
        first = self._model_order[0]
        init = {
            "wid": wid,
            "cfg": self._cfg,
            "store_cfg": self._store_cfg,
            "k_max": self._k_max,
            "max_batch": self._max_batch,
            "model_path": self._model_paths[first],
            "model_version": first,
        }
        self._journal.setdefault(wid, [])
        return _ChildHandle(wid, self._ctx, init, self._ring_bytes,
                            self._child_env)

    def _replay_model_chain(self, wid: int) -> None:
        """Bring a fresh child's model registry to the pool's: every version
        ever registered, activating the current one last."""
        child = self._children[wid]
        for v in self._model_order[1:]:
            child.request({"cmd": "set_model", "version": v,
                           "path": self._model_paths[v]})
        if self._model_version != self._model_order[-1]:
            # a rollback re-activated an older version: make it current
            child.request({"cmd": "set_model", "version": self._model_version,
                           "path": self._model_paths[self._model_version]})

    def _replay_journal(self, wid: int) -> None:
        child = self._children[wid]
        for header, sections in self._journal[wid]:
            child.request(dict(header), sections)

    def _restart_child(self, wid: int) -> None:
        """Respawn a dead shard process and restore its state: model chain,
        then the put-journal (last snapshot LOAD + puts since).  In-flight
        SCORE frames are re-posted by their waiters — exactly once, since
        cross-shard reads were resolved before the original post."""
        self._children[wid].destroy()
        self._children[wid] = self._spawn_child(wid)
        self._replay_model_chain(wid)
        self._replay_journal(wid)
        workers = getattr(self, "workers", None)
        if workers is not None and wid < len(workers):
            workers[wid].stats["restarts"] += 1

    def _request(self, wid: int, header: dict, sections=()) -> tuple[dict, dict]:
        """Synchronous round-trip with one restart-and-retry on child death."""
        if self._closed:
            raise RuntimeError(
                "ProcessWorkerPool is shut down — no shard process to ask")
        try:
            return self._children[wid].request(dict(header), sections)
        except WorkerDied:
            self._restart_child(wid)
            return self._children[wid].request(dict(header), sections)

    # ------------------------------------------------------------- owner routing
    def owner_of(self, entity: int) -> int:
        n = len(self._children)
        return 0 if n == 1 else entity_shard(int(entity), n)

    def owner_of_shard(self, shard: int) -> int:
        return 0 if len(self._children) == 1 else int(shard)

    # --------------------------------------------------------------- store ops
    def read_pairs(self, wid: int, pairs: list,
                   expected_model_version: int | None):
        h, s = self._request(wid, {"cmd": "read", "pairs": pairs,
                                   "version": expected_model_version})
        return s["emb"], s["has"], s["stale"]

    def put_group(self, wid: int, keys: np.ndarray, values: np.ndarray,
                  version: int, model_version: int, stamp: float) -> None:
        header = {"cmd": "put", "pver": version,
                  "model_version": model_version, "stamp": stamp}
        sections = [("keys", keys), ("values", values)]
        self._request(wid, header, sections)
        self._journal[wid].append((header, sections))

    def load_shard(self, wid: int, shard: int, items: list) -> None:
        keys = np.asarray([k for k, *_r in items], np.int64)
        vals = (np.stack([np.asarray(v, np.float32) for _, v, *_r in items])
                if items else np.zeros((0, self.store.dim), np.float32))
        header = {"cmd": "load", "shard": int(shard)}
        sections = [
            ("keys", keys), ("values", vals),
            ("versions", np.asarray([ver for _, _, ver, _, _ in items], np.int64)),
            ("stamps", np.asarray([st for _, _, _, st, _ in items], np.float64)),
            ("model_versions", np.asarray([mv for *_r, mv in items], np.int64)),
        ]
        self._request(wid, header, sections)
        self._journal[wid].append((header, sections))

    def snapshot_children(self) -> list[list[list[tuple]]]:
        """One SNAPSHOT round-trip per child; returns each child's local
        shard item lists and resets its journal to an equivalent LOAD."""
        out = []
        for wid in range(len(self._children)):
            h, s = self._request(wid, {"cmd": "snapshot"})
            off = h["shard_off"]
            keys, vals = s["keys"], s["values"]
            vers, stamps, mvs = s["versions"], s["stamps"], s["model_versions"]
            shards = []
            journal = []
            for ls in range(len(off) - 1):
                lo, hi = int(off[ls]), int(off[ls + 1])
                shards.append([
                    (int(keys[i]), np.array(vals[i]), int(vers[i]),
                     float(stamps[i]), int(mvs[i])) for i in range(lo, hi)])
                if hi > lo:
                    journal.append((
                        {"cmd": "load", "shard": ls},
                        [("keys", np.array(keys[lo:hi])),
                         ("values", np.array(vals[lo:hi])),
                         ("versions", np.array(vers[lo:hi])),
                         ("stamps", np.array(stamps[lo:hi])),
                         ("model_versions", np.array(mvs[lo:hi]))]))
            self._journal[wid] = journal
            out.append(shards)
        return out

    def child_stats_sum(self) -> dict:
        if self._closed:
            return dict(self._final_stats)
        agg: dict = {}
        for wid in range(len(self._children)):
            h, _ = self._request(wid, {"cmd": "stats"})
            for k, v in h["stats"].items():
                agg[k] = agg.get(k, 0) + v
        return agg

    def store_len(self) -> int:
        if self._closed:
            return self._final_len
        total = 0
        for wid in range(len(self._children)):
            h, _ = self._request(wid, {"cmd": "stats"})
            total += int(h["len"])
        return total

    # ------------------------------------------------------------------ scoring
    def _attach_score_fns(self) -> None:
        for w in self.workers:
            w.batcher.score_fn = self._make_score_fn(w.wid)

    def _make_score_fn(self, wid: int):
        def score_fn(feats, key_lists):
            return self._score_via_child(wid, feats, key_lists)
        return score_fn

    def _resolve_remote(self, wid: int, key_lists: list, version: int):
        """Pre-resolve every slot NOT owned by the scoring child via READ
        frames to its owner, in the inline lookup's (i, j) order per owner
        — counters and LRU recency land exactly where the inline store
        would put them, once."""
        n = len(self._children)
        remote: list[list[int]] = []
        rows: list[np.ndarray] = []
        if n > 1:
            per_owner: dict[int, list] = {}
            for i, pairs in enumerate(key_lists):
                for j, (ent, t) in enumerate(pairs[:self._k_max]):
                    o = self.owner_of(ent)
                    if o != wid:
                        per_owner.setdefault(o, []).append((i, j, ent, t))
            for o in sorted(per_owner):
                plist = per_owner[o]
                emb, has, stale = self.read_pairs(
                    o, [[e, t] for _, _, e, t in plist], version)
                for r, (i, j, _, _) in enumerate(plist):
                    remote.append([i, j, int(has[r]), int(stale[r])])
                    rows.append(np.asarray(emb[r], np.float32))
        remote_emb = (np.stack(rows) if rows
                      else np.zeros((0, self.store.dim), np.float32))
        return remote, remote_emb

    def _score_via_child(self, wid: int, feats, key_lists) -> DeferredScore:
        version = self._model_version
        kl = [[[int(e), int(t)] for e, t in pairs] for pairs in key_lists]
        remote, remote_emb = self._resolve_remote(wid, kl, version)
        header = {"cmd": "score", "version": version, "keys": kl,
                  "remote": remote}
        secs = [("remote_emb", remote_emb)] if len(remote_emb) else []
        feats = np.ascontiguousarray(feats, "<f4")
        # the fault-injection harness arms "worker_kill": the k-th SCORE
        # post becomes a SIGKILL of the target shard process, and the
        # recovery path below must still deliver this flush exactly once
        try:
            crashpoint.fire("worker_kill")
        except crashpoint.SimulatedCrash:
            self.kill_worker(wid)
        try:
            handle = self._children[wid]
            msg_id = handle.post(header, secs, feats=feats)
        except WorkerDied:
            self._restart_child(wid)
            handle = self._children[wid]
            msg_id = handle.post(header, secs, feats=feats)
        return DeferredScore(
            lambda: self._await_score(wid, handle, msg_id, header, secs, feats))

    def _await_score(self, wid, handle, msg_id, header, secs, feats):
        for _ in range(2):
            if self._children[wid] is not handle:
                # the child this flush was posted to died and was replaced:
                # re-dispatch the saved frame once on the restored process
                handle = self._children[wid]
                msg_id = handle.post(header, secs, feats=feats)
            try:
                h, s = handle.wait(msg_id)
                return (np.asarray(s["probs"], np.float32),
                        np.asarray(s["stale"], np.int32), int(h["version"]))
            except WorkerDied:
                self._restart_child(wid)
        raise RuntimeError(f"shard process {wid} died twice on one flush")

    def kill_worker(self, wid: int) -> None:
        """SIGKILL one shard process (fault-injection harness)."""
        p = self._children[wid].proc
        if p.is_alive() and p.pid is not None:
            os.kill(p.pid, signal.SIGKILL)
        p.join()

    # ---------------------------------------------------------------- liveness
    def dead_workers(self) -> int:
        return sum(1 for c in self._children if not c.alive())

    def ping(self) -> list[int]:
        """Round-trip heartbeat: wids that answered a PING frame."""
        ok = []
        for wid, c in enumerate(self._children):
            if not c.alive():
                continue
            try:
                c.request({"cmd": "ping"})
                ok.append(wid)
            except (WorkerDied, ChildError):
                pass
        return ok

    def check_workers(self) -> int:
        """Heartbeat sweep: restart any dead child (shard restored from the
        last snapshot + put-journal suffix).  Returns restarts performed."""
        if self._closed:
            return 0
        n = 0
        for wid, c in enumerate(self._children):
            if not c.alive():
                self._restart_child(wid)
                n += 1
        return n

    def poll(self, now: float):
        self.check_workers()
        return super().poll(now)

    # ------------------------------------------------------------- lifecycle
    def set_model(self, params, model_version: int) -> None:
        version = int(model_version)
        path = self._save_model(params, version)
        for wid in range(len(self._children)):
            self._request(wid, {"cmd": "set_model", "version": version,
                                "path": path})
        self._model_version = version
        super().set_model(params, version)

    def warmup(self) -> None:
        posts = [(c, c.post({"cmd": "warmup"})) for c in self._children]
        for c, mid in posts:
            c.wait(mid)

    def refresh_bins(self, pgs: list, entity_hints: list,
                     model_version: int) -> list[np.ndarray]:
        """Stage-1 executor for :class:`RefreshDriver`: each padded bin is
        posted to the shard process owning the bin's first dirty entity and
        all bins compute concurrently — the batch layer comes off the
        serving GIL.  Pure compute: any child gives bit-identical ``h``."""
        n = len(self._children)
        jobs = []
        for pg, ent in zip(pgs, entity_hints):
            wid = 0 if n == 1 else entity_shard(int(ent), n)
            secs = [(name, np.asarray(v))
                    for name, v in pg._asdict().items() if v is not None]
            header = {"cmd": "refresh", "version": int(model_version),
                      "fields": [name for name, _ in secs]}
            jobs.append((wid, header, secs))
        posts = []
        for wid, header, secs in jobs:
            try:
                c = self._children[wid]
                posts.append((c, c.post(dict(header), secs)))
            except WorkerDied:
                self._restart_child(wid)
                c = self._children[wid]
                posts.append((c, c.post(dict(header), secs)))
        out = []
        for (c, mid), (wid, header, secs) in zip(posts, jobs):
            try:
                _, s = c.wait(mid)
            except WorkerDied:
                self._restart_child(wid)
                _, s = self._request(wid, header, secs)
            out.append(np.asarray(s["h"], np.float32))
        return out

    def reshard(self, num_workers: int):
        """Drain, snapshot every shard, respawn the topology at the new
        width, and re-place all entries under the new rendezvous layout —
        the process backend's equivalent of the inline pool's atomic
        router+store+workers migration."""
        num_workers = int(num_workers)
        if num_workers < 1:
            raise ValueError("num_workers must be >= 1")
        if num_workers > 1 and not self.store.shard_by_entity:
            raise ValueError(
                "process backend reshard to >1 workers requires "
                "shard_by_entity=True")
        out = self.flush()
        items = [it for shard in self.store.shard_items() for it in shard]
        for c in self._children:
            c.destroy(stop=True)
        if self.store.shard_by_entity:
            self._store_cfg["num_shards"] = num_workers
            self.store.num_shards = num_workers
        self._journal = {}
        self._children = [self._spawn_child(w) for w in range(num_workers)]
        for w in range(num_workers):
            self._replay_model_chain(w)
        self.router.reshard(num_workers)
        tmpl = self.workers[0]
        self.workers = [
            SpeedLayerWorker(
                w,
                Stage2Scorer(tmpl.scorer.params, tmpl.scorer.cfg, self.store,
                             tmpl.scorer.k_max,
                             model_version=tmpl.scorer.model_version),
                max_batch=tmpl.batcher.max_batch,
                max_wait_s=tmpl.batcher.max_wait_s,
                service_model_s=tmpl.service_model_s,
            )
            for w in range(num_workers)
        ]
        self._attach_score_fns()
        new_shards: list[list] = [[] for _ in range(self.store.num_shards)]
        for it in items:
            new_shards[self.store.shard_of(it[0])].append(it)
        self.store.load_items(new_shards)
        return out

    def shutdown(self) -> None:
        """Stop every shard process, unlink shared memory, drop the model
        spool.  Idempotent — the service calls it from ``close()`` and
        tests call it directly.  Store size and stats are cached first so
        post-close summaries (ReplayReport, final ServiceStats) still
        render without reaching for a dead child."""
        if self._closed:
            return
        try:
            self._final_stats = self.child_stats_sum()
            self._final_len = self.store_len()
        except (WorkerDied, ChildError, OSError):
            # a child died during teardown: freeze whatever we know
            self._final_stats = getattr(self, "_final_stats", {})
            self._final_len = getattr(self, "_final_len", 0)
        self._closed = True
        for c in self._children:
            c.destroy(stop=True)
        shutil.rmtree(self._model_dir, ignore_errors=True)

    # ------------------------------------------------------------------- stats
    def worker_summary(self) -> list[dict]:
        out = super().worker_summary()
        for row in out:
            row["alive"] = (not self._closed
                            and self._children[row["worker"]].alive())
        return out


__all__ = [
    "ChildError",
    "DEFAULT_RING_BYTES",
    "ProcStoreView",
    "ProcessWorkerPool",
    "ShardServer",
    "ShmRing",
    "WorkerDied",
    "pack_frame",
    "unpack_frame",
]
