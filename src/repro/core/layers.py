"""Edge-type-aware GNN layers on PaddedGraph (GCN / GAT / SAGE).

All layers consume the padded in-neighbor layout from ``core.graph`` and are
pure functions ``apply(params, h, graph) -> h'``.  The neighbor aggregation
is the paper's hot loop; it routes through ``kernels.ops.csr_spmm`` /
``kernels.ops.edge_softmax`` (Pallas, TPU) when ``use_pallas=True`` and
through the jnp reference path otherwise (CPU, dry-run lowering).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.graph import EdgeType, PaddedGraph


def _glorot(rng, shape, dtype=jnp.float32):
    fan_in, fan_out = shape[-2], shape[-1]
    scale = jnp.sqrt(2.0 / (fan_in + fan_out))
    return jax.random.normal(rng, shape, dtype) * scale


# ---------------------------------------------------------------------------
# Aggregation primitives
# ---------------------------------------------------------------------------

def weighted_gather_sum(h, nbr_idx, weights, use_pallas: bool = False):
    """out[i] = sum_d weights[i, d] * h[nbr_idx[i, d]]  — the SpMM core.

    h: [N, H]; nbr_idx: [N, D] int32; weights: [N, D] float.
    """
    if use_pallas:
        from repro.kernels.ops import csr_spmm

        return csr_spmm(h, nbr_idx, weights)
    msgs = jnp.take(h, nbr_idx, axis=0)  # [N, D, H]
    return jnp.einsum("ndh,nd->nh", msgs, weights.astype(h.dtype))


def per_etype_mean(h, graph: PaddedGraph, use_pallas: bool = False):
    """Mean-aggregate neighbor states separately per edge type.

    Returns [NUM_ETYPES, N, H]."""
    outs = []
    for e in range(EdgeType.NUM):
        w = graph.nbr_mask * (graph.nbr_etype == e)
        cnt = jnp.maximum(w.sum(-1, keepdims=True), 1.0)
        outs.append(weighted_gather_sum(h, graph.nbr_idx, w / cnt, use_pallas))
    return jnp.stack(outs)


# ---------------------------------------------------------------------------
# GCN
# ---------------------------------------------------------------------------

def gcn_init(rng, in_dim: int, out_dim: int):
    ks = jax.random.split(rng, EdgeType.NUM + 1)
    return {
        "w_self": _glorot(ks[0], (in_dim, out_dim)),
        "w_nbr": jnp.stack([_glorot(k, (in_dim, out_dim)) for k in ks[1:]]),  # [E, in, out]
        "b": jnp.zeros((out_dim,)),
    }


def gcn_apply(params, h, graph: PaddedGraph, use_pallas: bool = False):
    agg = per_etype_mean(h, graph, use_pallas)          # [E, N, in]
    out = h @ params["w_self"]
    out = out + jnp.einsum("enh,eho->no", agg, params["w_nbr"])
    return jax.nn.relu(out + params["b"])


# ---------------------------------------------------------------------------
# GAT (single-head GATv1 with edge-type bias, masked neighbor softmax)
# ---------------------------------------------------------------------------

def gat_init(rng, in_dim: int, out_dim: int):
    ks = jax.random.split(rng, 4)
    return {
        "w": _glorot(ks[0], (in_dim, out_dim)),
        "w_self": _glorot(ks[1], (in_dim, out_dim)),
        "a_src": _glorot(ks[2], (out_dim, 1))[:, 0],
        "a_dst": _glorot(ks[3], (out_dim, 1))[:, 0],
        "a_et": jnp.zeros((EdgeType.NUM,)),
        "b": jnp.zeros((out_dim,)),
    }


def gat_apply(params, h, graph: PaddedGraph, use_pallas: bool = False):
    z = h @ params["w"]                                  # [N, H]
    s_dst = z @ params["a_dst"]                          # [N]
    s_src = z @ params["a_src"]                          # [N]
    if use_pallas:
        from repro.kernels.ops import edge_softmax_agg

        agg = edge_softmax_agg(
            z, s_src, s_dst, graph.nbr_idx, graph.nbr_mask,
            params["a_et"][graph.nbr_etype],
        )
    else:
        logits = (
            jnp.take(s_src, graph.nbr_idx, axis=0)
            + s_dst[:, None]
            + params["a_et"][graph.nbr_etype]
        )
        logits = jax.nn.leaky_relu(logits, 0.2)
        logits = jnp.where(graph.nbr_mask > 0, logits, -1e9)
        attn = jax.nn.softmax(logits, axis=-1) * graph.nbr_mask
        msgs = jnp.take(z, graph.nbr_idx, axis=0)        # [N, D, H]
        agg = jnp.einsum("ndh,nd->nh", msgs, attn)
    out = agg + h @ params["w_self"]
    return jax.nn.relu(out + params["b"])


# ---------------------------------------------------------------------------
# GraphSAGE (mean aggregator) — extra baseline beyond the paper's GCN/GAT
# ---------------------------------------------------------------------------

def sage_init(rng, in_dim: int, out_dim: int):
    ks = jax.random.split(rng, 2)
    return {
        "w_self": _glorot(ks[0], (in_dim, out_dim)),
        "w_nbr": _glorot(ks[1], (in_dim, out_dim)),
        "b": jnp.zeros((out_dim,)),
    }


def sage_apply(params, h, graph: PaddedGraph, use_pallas: bool = False):
    w = graph.nbr_mask
    cnt = jnp.maximum(w.sum(-1, keepdims=True), 1.0)
    agg = weighted_gather_sum(h, graph.nbr_idx, w / cnt, use_pallas)
    out = h @ params["w_self"] + agg @ params["w_nbr"]
    return jax.nn.relu(out + params["b"])


LAYER_REGISTRY = {
    "gcn": (gcn_init, gcn_apply),
    "gat": (gat_init, gat_apply),
    "sage": (sage_init, sage_apply),
}
