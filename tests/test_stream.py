"""Streaming serving engine: incremental DDS equivalence, micro-batch flush
policy, and the headline stage-equivalence claim — micro-batched speed-layer
scores match the monolithic ``lnn_forward`` on the same event stream."""
import jax
import numpy as np
import pytest

from repro.core import LNNConfig, lnn_forward, lnn_init
from repro.core.dds import IncrementalDDSBuilder, build_dds, check_no_future_leak
from repro.core.graph import pad_graph
from repro.data import SynthConfig, generate_event_stream
from repro.stream import (
    CheckoutEvent,
    EngineConfig,
    MicroBatcher,
    ScoreRequest,
    StreamingEngine,
    events_from_static,
)


@pytest.fixture(scope="module")
def stream_world():
    events, g, split = generate_event_stream(
        SynthConfig(num_users=80, num_rings=3, feature_noise=0.8, seed=5),
        rate_per_s=500.0,
    )
    cfg = LNNConfig(num_gnn_layers=3, hidden_dim=32,
                    feat_dim=g.order_features.shape[1])
    params = lnn_init(jax.random.PRNGKey(0), cfg)
    return events, g, cfg, params


# ------------------------------------------------------- incremental DDS
@pytest.mark.parametrize("history,max_history",
                         [("all", None), ("all", 4), ("consecutive", None)])
def test_incremental_dds_matches_batch_build(stream_world, history, max_history):
    """The streaming ingest path must produce the exact padded graph the
    offline ``build_dds`` produces on the same transactions."""
    events, g, _, _ = stream_world
    b = IncrementalDDSBuilder(g.order_features.shape[1], history, max_history)
    for ev in events:
        b.add_order(ev.entities, ev.snapshot, ev.features, ev.label)
    inc = b.build()
    check_no_future_leak(inc)
    ref = build_dds(b.to_static(), history, max_history)
    pg_i = pad_graph(inc.coo, max_deg=16)
    pg_r = pad_graph(ref.coo, max_deg=16)
    for f in pg_i._fields:
        np.testing.assert_array_equal(getattr(pg_i, f), getattr(pg_r, f))
    assert inc.entity_snap_ids == ref.entity_snap_ids
    assert inc.last_hop == ref.last_hop


def test_incremental_builder_rejects_event_time_regression():
    b = IncrementalDDSBuilder(feat_dim=2)
    b.add_order([1], 3, np.zeros(2))
    with pytest.raises(ValueError):
        b.add_order([1], 2, np.zeros(2))


def test_entity_keys_strictly_past():
    b = IncrementalDDSBuilder(feat_dim=2)
    b.add_order([7], 1, np.zeros(2))
    b.add_order([7], 3, np.zeros(2))
    # same-snapshot activity never feeds the key list (no leak)
    assert b.entity_keys([7], 3) == [(7, 1)]
    assert b.entity_keys([7], 4) == [(7, 3)]
    assert b.entity_keys([7], 1) == []
    assert b.entity_keys([99], 5) == []     # cold entity


# ------------------------------------------------------- micro-batcher
def _const_score_fn(feats, key_lists):
    return np.full(feats.shape[0], 0.5), np.zeros(feats.shape[0], np.int32)


def _req(arrival, feat_dim=4):
    return ScoreRequest(features=np.zeros(feat_dim, np.float32),
                        entity_keys=[], arrival=arrival)


def test_microbatch_size_trigger():
    mb = MicroBatcher(_const_score_fn, max_batch=4, max_wait_s=10.0)
    out = []
    for i in range(3):
        out += mb.submit(_req(arrival=0.001 * i), now=0.001 * i)
    assert out == [] and len(mb) == 3
    out += mb.submit(_req(arrival=0.003), now=0.003)
    assert len(out) == 4 and len(mb) == 0
    assert mb.stats["size_flushes"] == 1
    assert all(r.batch_size == 4 for r in out)


def test_microbatch_deadline_trigger():
    mb = MicroBatcher(_const_score_fn, max_batch=64, max_wait_s=0.005)
    mb.submit(_req(arrival=1.000), now=1.000)
    assert mb.poll(now=1.004) == []                 # deadline not reached
    out = mb.poll(now=1.0051)
    assert len(out) == 1
    assert mb.stats["deadline_flushes"] == 1
    # flush is stamped at the deadline (timer semantics), so the recorded
    # wait is exactly max_wait even though the poll came later
    assert out[0].queued_s == pytest.approx(0.005)


def test_microbatch_padding_matches_unpadded_scores(stream_world):
    """Bucket padding must not perturb real rows' scores."""
    events, g, cfg, params = stream_world
    eng = StreamingEngine(params, cfg, EngineConfig(max_batch=8))
    eng.warmup()
    # fill the store so lookups return real embeddings
    for ev in events:
        eng.submit(ev)
    eng.flush()
    reqs = [r for r in (eng.ingester.builder.entity_keys(ev.entities, ev.snapshot)
                        for ev in events[-5:])]
    feats = np.stack([ev.features for ev in events[-5:]]).astype(np.float32)
    # batch of 5 pads to bucket 8; score one-by-one (bucket 1) as reference
    p5, _ = eng._score_batch(feats, reqs)
    p1 = np.concatenate(
        [eng._score_batch(feats[i:i + 1], [reqs[i]])[0] for i in range(5)]
    )
    np.testing.assert_allclose(p5, p1, atol=1e-6)


# ------------------------------------------- engine: the headline claim
def test_streaming_scores_match_monolithic_forward(stream_world):
    """Acceptance: replay ingest -> refresh -> micro-batched scoring equals
    the monolithic full-graph ``lnn_forward`` on the same events (fp tol)."""
    events, g, cfg, params = stream_world
    eng = StreamingEngine(params, cfg,
                          EngineConfig(max_batch=8, refresh_every=1, max_deg=32))
    report = eng.replay(events)
    assert len(report.results) == len(events)

    pg = pad_graph(eng.ingester.materialize().coo, max_deg=32)
    full = np.asarray(jax.nn.sigmoid(
        jax.jit(lambda p, gg: lnn_forward(p, cfg, gg))(params, pg)
    ))
    scores = report.scores_by_order()
    # builder order id == position in the event stream (arrival order)
    err = max(
        abs(scores[ev.order_id] - full[i]) for i, ev in enumerate(events)
    )
    assert err < 1e-4, err
    # refresh-every-window keeps the speed layer perfectly fresh
    assert report.staleness_summary()["max"] == 0
    assert eng.store.stats["misses"] == 0


def test_streaming_staleness_grows_with_refresh_interval(stream_world):
    events, g, cfg, params = stream_world
    fresh = StreamingEngine(params, cfg, EngineConfig(max_batch=8, refresh_every=1))
    lazy = StreamingEngine(params, cfg, EngineConfig(max_batch=8, refresh_every=6))
    s_fresh = fresh.replay(events).staleness_summary()
    s_lazy = lazy.replay(events).staleness_summary()
    assert s_fresh["stale_frac"] == 0.0
    assert s_lazy["stale_frac"] > 0.0
    assert lazy.refresher.stats["refreshes"] < fresh.refresher.stats["refreshes"]


def test_async_refresh_drains_and_scores_everything(stream_world):
    events, g, cfg, params = stream_world
    eng = StreamingEngine(params, cfg,
                          EngineConfig(max_batch=8, async_refresh=True))
    report = eng.replay(events)
    assert len(report.results) == len(events)
    assert eng.refresher.stats["refreshes"] > 0


def test_streaming_fused_stage2_matches_unfused(stream_world):
    """Flipping ``LNNConfig.use_pallas`` swaps the speed layer onto the fused
    Pallas stage-2 kernel (interpret mode on CPU); every replayed score must
    be identical to the unfused engine's, across all bucket shapes."""
    import dataclasses

    events, g, cfg, params = stream_world
    evs = events[:60]
    ref = StreamingEngine(params, cfg, EngineConfig(max_batch=8))
    s_ref = ref.replay(evs).scores_by_order()
    fused = StreamingEngine(params, dataclasses.replace(cfg, use_pallas=True),
                            EngineConfig(max_batch=8))
    s_fused = fused.replay(evs).scores_by_order()
    assert set(s_fused) == set(s_ref)
    err = max(abs(s_fused[o] - s_ref[o]) for o in s_ref)
    assert err < 1e-5, err


def test_engine_cold_start_scores_without_history():
    """First-ever events (empty store, no history) must score, not crash."""
    cfg = LNNConfig(num_gnn_layers=2, hidden_dim=16, feat_dim=4)
    params = lnn_init(jax.random.PRNGKey(1), cfg)
    eng = StreamingEngine(params, cfg, EngineConfig(max_batch=2, max_wait_s=0.001))
    evs = [CheckoutEvent(order_id=i, snapshot=0, entities=(i, 100 + i),
                         features=np.zeros(4, np.float32), label=0.0,
                         arrival=0.001 * i) for i in range(3)]
    out = []
    for ev in evs:
        out += eng.submit(ev)
    out += eng.flush()
    assert len(out) == 3
    assert all(np.isfinite(r.score) for r in out)
    assert all(r.staleness == -1 for r in out)      # nothing served from KV
