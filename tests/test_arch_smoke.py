"""Per-assigned-architecture smoke tests (deliverable f).

Each instantiates the REDUCED variant of the same family (<=2 layers or
superblocks, d_model<=256, <=4 experts) and runs one forward/train step on
CPU asserting output shapes and no NaNs, plus the prefill->decode
consistency check that guards the serving path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CLI_ALIASES, get_config
from repro.models import decode_step, forward, forward_train, init_params
from repro.models.transformer import prefill

ARCHS = sorted(CLI_ALIASES)
RNG = np.random.default_rng(3)


def _batch(cfg, b=2, s=24):
    batch = {
        "tokens": jnp.asarray(RNG.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
        "labels": jnp.asarray(RNG.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
    }
    if cfg.arch_type == "vlm":
        batch["vision"] = jnp.asarray(
            RNG.normal(size=(b, cfg.num_vision_tokens, cfg.d_model)), jnp.float32)
    if cfg.arch_type == "audio":
        batch["frames"] = jnp.asarray(RNG.normal(size=(b, 16, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_train_step(arch):
    cfg = get_config(arch).reduced()
    assert cfg.d_model <= 256 and (not cfg.num_experts or cfg.num_experts <= 4)
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    loss, grads = jax.value_and_grad(
        lambda p: forward_train(p, cfg, batch, use_remat=False))(params)
    assert np.isfinite(float(loss))
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in leaves)
    assert sum(float(jnp.abs(g).sum()) for g in leaves) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_forward_shapes(arch):
    cfg = get_config(arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    extra = {k: v for k, v in batch.items() if k not in ("tokens", "labels")}
    logits, _, _ = forward(params, cfg, batch["tokens"], extra, use_remat=False)
    assert logits.shape == (2, 24, cfg.physical_vocab)
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    """decode_step must continue exactly where the full forward would be —
    the transformer analogue of the paper's lambda-split equivalence."""
    cfg = get_config(arch).reduced()
    params = init_params(jax.random.PRNGKey(1), cfg)
    b, s_pre, n_dec, max_len = 2, 12, 3, 24
    tokens = jnp.asarray(RNG.integers(0, cfg.vocab_size, (b, s_pre + n_dec)), jnp.int32)
    extra = {}
    if cfg.arch_type == "vlm":
        extra["vision"] = jnp.asarray(
            RNG.normal(size=(b, cfg.num_vision_tokens, cfg.d_model)), jnp.float32)
    if cfg.arch_type == "audio":
        extra["frames"] = jnp.asarray(RNG.normal(size=(b, 16, cfg.d_model)), jnp.float32)
    full, _, _ = forward(params, cfg, tokens, extra, use_remat=False)
    last, cache = prefill(params, cfg, tokens[:, :s_pre], max_len, extra)
    np.testing.assert_allclose(np.asarray(last), np.asarray(full[:, s_pre - 1]),
                               atol=1e-4)
    for i in range(n_dec):
        lg, cache = decode_step(params, cfg, tokens[:, s_pre + i], cache)
        np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, s_pre + i]),
                                   atol=1e-4)


def test_exact_assigned_configs():
    """The full (non-reduced) configs must match the assignment table."""
    table = {
        "mamba2-370m": dict(num_layers=48, d_model=1024, vocab_size=50280, ssm_state=128),
        "granite-3-2b": dict(num_layers=40, d_model=2048, num_heads=32,
                             num_kv_heads=8, d_ff=8192, vocab_size=49155),
        "llama-3.2-vision-90b": dict(num_layers=100, d_model=8192, num_heads=64,
                                     num_kv_heads=8, d_ff=28672, vocab_size=128256),
        "yi-34b": dict(num_layers=60, d_model=7168, num_heads=56, num_kv_heads=8,
                       d_ff=20480, vocab_size=64000),
        "phi3.5-moe-42b-a6.6b": dict(num_layers=32, d_model=4096, num_heads=32,
                                     num_kv_heads=8, d_ff=6400, vocab_size=32064,
                                     num_experts=16, experts_per_token=2),
        "olmo-1b": dict(num_layers=16, d_model=2048, num_heads=16, num_kv_heads=16,
                        d_ff=8192, vocab_size=50304, nonparametric_ln=True),
        "zamba2-1.2b": dict(num_layers=38, d_model=2048, num_heads=32,
                            num_kv_heads=32, d_ff=8192, vocab_size=32000, ssm_state=64),
        "seamless-m4t-medium": dict(num_layers=12, d_model=1024, num_heads=16,
                                    num_kv_heads=16, d_ff=4096, vocab_size=256206,
                                    encdec=True),
        "mixtral-8x22b": dict(num_layers=56, d_model=6144, num_heads=48,
                              num_kv_heads=8, d_ff=16384, vocab_size=32768,
                              num_experts=8, experts_per_token=2, window=4096),
        "qwen1.5-32b": dict(num_layers=64, d_model=5120, num_heads=40,
                            num_kv_heads=40, d_ff=27392, vocab_size=152064,
                            qkv_bias=True),
    }
    for arch, want in table.items():
        cfg = get_config(arch)
        for k, v in want.items():
            assert getattr(cfg, k) == v, f"{arch}.{k}: {getattr(cfg, k)} != {v}"
        assert cfg.source, f"{arch} missing citation"


def test_ring_kv_cache_matches_full_cache():
    """SWA ring-buffer cache (beyond-paper): decode with a window-sized ring
    buffer must equal decode with the full-length cache once RoPE is applied
    at absolute positions before the write."""
    import dataclasses

    base = get_config("mixtral-8x22b").reduced()
    w = 8
    cfg_full = dataclasses.replace(base, window=w, ring_kv_cache=False)
    cfg_ring = dataclasses.replace(base, window=w, ring_kv_cache=True)
    params = init_params(jax.random.PRNGKey(0), cfg_full)
    from repro.models import init_cache

    b, steps, max_len = 2, 20, 32
    tokens = RNG.integers(0, base.vocab_size, (b, steps))
    cache_f = init_cache(cfg_full, b, max_len)
    cache_r = init_cache(cfg_ring, b, max_len)
    assert jax.tree_util.tree_leaves(cache_r["decoder"])[0].shape[-2] == w
    for i in range(steps):
        t = jnp.asarray(tokens[:, i], jnp.int32)
        lf, cache_f = decode_step(params, cfg_full, t, cache_f)
        lr, cache_r = decode_step(params, cfg_ring, t, cache_r)
        np.testing.assert_allclose(np.asarray(lf), np.asarray(lr), atol=1e-4)
