"""Community-local incremental batch-layer refresh: the bit-identical
parity ladder.

1. incremental community assignment (union-find over arriving checkouts)
   matches the batch connected-component oracle at every stream prefix;
2. ``IncrementalDDSBuilder.build_subgraph`` over a component-closed entity
   set is bit-identical to slicing the padded full ``build()`` graph;
3. community-local stage-1 embeddings equal the whole-graph run bit-for-bit
   for every dirty key, for all three GNN types;
4. end-to-end replay parity: community-local vs whole-graph refresh writes
   the SAME bytes to the KV store and yields the SAME scores and staleness
   counters, across worker counts and mid-stream model hot-swaps.
"""
import jax
import numpy as np
import pytest

from repro.core import LNNConfig, lnn_init
from repro.core.dds import IncrementalDDSBuilder, check_no_future_leak
from repro.core.graph import pad_graph
from repro.core.lnn import lnn_stage1
from repro.core.partition import IncrementalPartitioner, entity_communities
from repro.data import SynthConfig, generate_event_stream
from repro.service import FraudService, ModelSection, ServiceConfig


@pytest.fixture(scope="module")
def stream_world():
    events, g, split = generate_event_stream(
        SynthConfig(num_users=70, num_rings=3, feature_noise=0.8, seed=11),
        rate_per_s=500.0,
    )
    cfg = LNNConfig(num_gnn_layers=3, hidden_dim=32,
                    feat_dim=g.order_features.shape[1])
    params = lnn_init(jax.random.PRNGKey(0), cfg)
    return events, g, cfg, params


def _service(params, cfg, *, community_local, community_size=4096,
             num_workers=1, refresh_every=1, async_refresh=False):
    sc = ServiceConfig(
        mode="streaming", model=ModelSection.from_lnn_config(cfg),
    ).replace(
        engine={"num_workers": num_workers},
        refresh={"community_local": community_local,
                 "community_size": community_size,
                 "refresh_every": refresh_every,
                 "async_refresh": async_refresh},
    )
    return FraudService(sc, params=params).build()


def _store_contents(store) -> dict:
    """key -> (bytes, version stamps) for every entry in every shard."""
    return {
        k: (e.value.tobytes(), e.model_version)
        for shard in store._shards for k, e in shard.items()
    }


# ----------------------------------------------------- community assignment
def _random_order_stream(rng, num_orders, num_entities, k_max=4):
    orders = []
    for _ in range(num_orders):
        k = int(rng.integers(1, k_max + 1))
        orders.append(tuple(int(e) for e in
                            rng.choice(num_entities, size=k, replace=False)))
    return orders


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_incremental_partition_matches_batch_oracle(seed):
    """Property: at EVERY prefix of a random order stream, the incremental
    union-find assignment equals the batch connected-component labeling of
    the accumulated edge list."""
    rng = np.random.default_rng(seed)
    num_entities = 40
    orders = _random_order_stream(rng, num_orders=60, num_entities=num_entities)
    part = IncrementalPartitioner()
    edges: list = []
    check_at = {1, 2, 7, 23, 59}
    for i, ents in enumerate(orders):
        part.add_order(ents)
        edges.extend((i, e) for e in ents)
        if i not in check_at:
            continue
        batch = entity_communities(num_entities,
                                   np.asarray(edges, np.int64))
        inc = part.assignment()
        for e, cid in inc.items():
            assert cid == batch[e], (i, e)
        # members are consistent with the assignment
        for e in inc:
            assert sorted(part.members(e)) == sorted(
                e2 for e2, c2 in inc.items() if c2 == inc[e])


def test_incremental_partition_on_real_stream(stream_world):
    events, g, _, _ = stream_world
    part = IncrementalPartitioner()
    for ev in events:
        part.add_order(ev.entities)
    batch = entity_communities(g.num_entities, g.edges)
    inc = part.assignment()
    for e, cid in inc.items():
        assert cid == batch[e]
    # order counts sum to the orders that link >= 1 entity
    roots = {part.community_of(e) for e in inc}
    assert sum(part.order_count(c) for c in roots) == \
        len({int(o) for o in g.edges[:, 0]})


def test_partitioner_unseen_entity_is_singleton():
    part = IncrementalPartitioner()
    assert part.community_of(123) == 123
    assert part.members(123) == [123]
    assert part.order_count(123) == 0


# ------------------------------------------------------- subgraph slicing
def _ingest_all(events, feat_dim, history="all", max_history=8):
    b = IncrementalDDSBuilder(feat_dim, history, max_history)
    part = IncrementalPartitioner()
    for ev in events:
        b.add_order(ev.entities, ev.snapshot, ev.features, ev.label)
        part.add_order(ev.entities)
    return b, part


@pytest.mark.parametrize("history,max_history",
                         [("all", None), ("all", 4), ("consecutive", None)])
def test_build_subgraph_is_sliced_full_build(stream_world, history, max_history):
    """Padded subgraph rows must equal the padded full-graph rows for the
    corresponding global nodes, modulo local->global id remapping."""
    events, g, _, _ = stream_world
    b, part = _ingest_all(events, g.order_features.shape[1], history, max_history)
    full = b.build()
    pg_full = pad_graph(full.coo, max_deg=16)
    communities = sorted({part.community_of(e) for e in part.assignment()})
    # a couple of single communities plus one multi-community union
    picks = [[communities[0]], [communities[-1]], communities[1:4]]
    for pick in picks:
        ents = set()
        for c in pick:
            ents.update(part.members(c))
        sub = b.build_subgraph(ents)
        check_no_future_leak(sub)
        pg_sub = pad_graph(sub.coo, max_deg=16)
        n_sub = sub.num_orders
        # local -> global node id map
        sub_orders = sorted({o for e in ents for o in b._entity_orders.get(e, ())})
        gid = np.zeros(sub.coo.num_nodes, np.int64)
        for lo, o in enumerate(sub_orders):
            gid[lo] = o
            gid[n_sub + lo] = full.num_orders + o
        for (ent, t), nid in sub.entity_snap_ids.items():
            gid[nid] = full.entity_snap_ids[(ent, t)]
        np.testing.assert_array_equal(pg_sub.features[:sub.coo.num_nodes],
                                      pg_full.features[gid])
        np.testing.assert_array_equal(pg_sub.node_type[:sub.coo.num_nodes],
                                      pg_full.node_type[gid])
        np.testing.assert_array_equal(pg_sub.snapshot[:sub.coo.num_nodes],
                                      pg_full.snapshot[gid])
        np.testing.assert_array_equal(pg_sub.label[:sub.coo.num_nodes],
                                      pg_full.label[gid])
        # in-neighbor rows: same mask/etypes, and sources map to the same
        # global nodes slot-for-slot (per-destination edge order preserved)
        sub_n = sub.coo.num_nodes
        np.testing.assert_array_equal(pg_sub.nbr_mask[:sub_n],
                                      pg_full.nbr_mask[gid])
        np.testing.assert_array_equal(pg_sub.nbr_etype[:sub_n],
                                      pg_full.nbr_etype[gid])
        mask = pg_sub.nbr_mask[:sub_n].astype(bool)
        np.testing.assert_array_equal(
            np.asarray(gid[pg_sub.nbr_idx[:sub_n]])[mask],
            np.asarray(pg_full.nbr_idx[gid])[mask])


def test_build_subgraph_rejects_unclosed_entity_set(stream_world):
    events, g, _, _ = stream_world
    b, part = _ingest_all(events, g.order_features.shape[1])
    # the seeded stream (every checkout links a user to >= 1 counterparty
    # entity) is guaranteed to contain a multi-entity order — assert that
    # seeding invariant so this test can never silently degrade to a no-op
    multi = [ev for ev in events if len(ev.entities) >= 2]
    assert multi, "seeded stream must contain a multi-entity order"
    # take one such order and withhold one of its entities
    ev = multi[0]
    ents = set(part.members(part.community_of(ev.entities[0])))
    ents.discard(int(ev.entities[1]))
    with pytest.raises(ValueError, match="component-closed"):
        b.build_subgraph(ents)


@pytest.mark.parametrize("gnn_type", ["gcn", "sage", "gat"])
def test_community_stage1_bit_identical(stream_world, gnn_type):
    """The tentpole invariant at the model level: stage-1 rows computed on
    a pow2-padded community subgraph equal the whole-graph rows bitwise,
    for every entity snapshot of the community, for all GNN types."""
    events, g, _, _ = stream_world
    cfg = LNNConfig(gnn_type=gnn_type, num_gnn_layers=3, hidden_dim=32,
                    feat_dim=g.order_features.shape[1])
    params = lnn_init(jax.random.PRNGKey(1), cfg)
    b, part = _ingest_all(events, g.order_features.shape[1])
    full = b.build()

    def pow2(n, f=64):
        while f < n:
            f *= 2
        return f

    pg_full = pad_graph(full.coo, num_nodes=pow2(full.coo.num_nodes), max_deg=32)
    h_full = np.asarray(jax.jit(
        lambda p, gr: lnn_stage1(p, cfg, gr))(params, pg_full))
    communities = sorted({part.community_of(e) for e in part.assignment()})
    for c in communities[:5]:
        sub = b.build_subgraph(part.members(c))
        pg_sub = pad_graph(sub.coo, num_nodes=pow2(sub.coo.num_nodes), max_deg=32)
        h_sub = np.asarray(jax.jit(
            lambda p, gr: lnn_stage1(p, cfg, gr))(params, pg_sub))
        for pair, nid in sub.entity_snap_ids.items():
            np.testing.assert_array_equal(
                h_sub[nid], h_full[full.entity_snap_ids[pair]],
                err_msg=f"{gnn_type} {pair}")


# --------------------------------------------------------- end-to-end parity
@pytest.mark.parametrize("num_workers", [1, 4])
def test_refresh_parity_community_vs_full(stream_world, num_workers):
    """Community-local refresh must write bit-identical embeddings for
    every dirty key, and replayed scores + staleness counters must match
    the whole-graph refresh exactly — the acceptance invariant."""
    events, _, cfg, params = stream_world
    svc_f = _service(params, cfg, community_local=False,
                     num_workers=num_workers)
    svc_c = _service(params, cfg, community_local=True, community_size=512,
                     num_workers=num_workers)
    rep_f = svc_f.replay(events)
    rep_c = svc_c.replay(events)
    s_f, s_c = rep_f.scores_by_order(), rep_c.scores_by_order()
    assert set(s_f) == set(s_c)
    assert all(s_c[o] == s_f[o] for o in s_f), "scores diverged"
    assert rep_f.staleness_summary() == rep_c.staleness_summary()
    cf = _store_contents(svc_f.engine.store)
    cc = _store_contents(svc_c.engine.store)
    assert set(cf) == set(cc), "different key sets written"
    assert cf == cc, "stored embedding bytes diverged"
    rf = svc_f.engine.refresher.stats
    rc = svc_c.engine.refresher.stats
    assert rf["refreshes"] == rc["refreshes"]
    assert rf["entities_written"] == rc["entities_written"]
    assert rf["per_shard_written"] == rc["per_shard_written"]
    # ... and the community path actually did less stage-1 padding work
    assert rc["nodes_padded"] < rf["nodes_padded"]


@pytest.mark.parametrize("community_size", [1, 256])
def test_refresh_parity_tiny_bins(stream_world, community_size):
    """Degenerate bin budgets (every community its own launch) stay exact."""
    events, _, cfg, params = stream_world
    evs = events[:120]
    svc_f = _service(params, cfg, community_local=False)
    svc_c = _service(params, cfg, community_local=True,
                     community_size=community_size)
    s_f = svc_f.replay(evs).scores_by_order()
    s_c = svc_c.replay(evs).scores_by_order()
    assert set(s_f) == set(s_c) and all(s_c[o] == s_f[o] for o in s_f)
    assert _store_contents(svc_f.engine.store) == \
        _store_contents(svc_c.engine.store)


def test_refresh_parity_with_hot_swap_mid_stream(stream_world):
    """Mid-stream model hot-swap: both refresh scopes must swap at the same
    event boundary and keep writing identical bytes + version stamps."""
    events, _, cfg, params = stream_world
    params_b = lnn_init(jax.random.PRNGKey(9), cfg)
    half = len(events) // 2

    def run(community_local):
        svc = _service(params, cfg, community_local=community_local,
                       community_size=512)
        out = []
        for ev in events[:half]:
            out.extend(svc.submit(ev))
        svc.load_model(params_b)
        for ev in events[half:]:
            out.extend(svc.submit(ev))
        out.extend(svc.drain())
        return {r.request.tag.order_id: r.score for r in out}, svc

    s_f, svc_f = run(False)
    s_c, svc_c = run(True)
    assert set(s_f) == set(s_c) and all(s_c[o] == s_f[o] for o in s_f)
    cf = _store_contents(svc_f.engine.store)
    cc = _store_contents(svc_c.engine.store)
    assert cf == cc
    # both stamped some writes with the new model version
    assert any(mv == 1 for _, mv in cf.values())


@pytest.mark.parametrize("refresh_every", [2, 4])
def test_refresh_parity_lazy_cadence(stream_world, refresh_every):
    """Stale serving (refresh_every > 1) keeps byte parity too — the scope
    of a refresh changes what is recomputed, never what is written."""
    events, _, cfg, params = stream_world
    svc_f = _service(params, cfg, community_local=False,
                     refresh_every=refresh_every)
    svc_c = _service(params, cfg, community_local=True, community_size=512,
                     refresh_every=refresh_every)
    rep_f = svc_f.replay(events)
    rep_c = svc_c.replay(events)
    s_f, s_c = rep_f.scores_by_order(), rep_c.scores_by_order()
    assert set(s_f) == set(s_c) and all(s_c[o] == s_f[o] for o in s_f)
    assert rep_f.staleness_summary() == rep_c.staleness_summary()
    assert _store_contents(svc_f.engine.store) == \
        _store_contents(svc_c.engine.store)


def test_async_community_refresh_parity(stream_world):
    """Async community-local refresh drains to the same store bytes as the
    sync whole-graph path (snapshots happen on the calling thread)."""
    events, _, cfg, params = stream_world
    evs = events[:150]
    svc_f = _service(params, cfg, community_local=False)
    svc_a = _service(params, cfg, community_local=True, community_size=512,
                     async_refresh=True)
    s_f = svc_f.replay(evs).scores_by_order()
    rep_a = svc_a.replay(evs)
    svc_a.drain()
    assert _store_contents(svc_f.engine.store) == \
        _store_contents(svc_a.engine.store)
    assert len(rep_a.results) == len(evs)
