"""Directed Dynamic Snapshot (DDS) graph construction — paper §3.2.

Transforms a static bipartite order↔entity transaction graph into a directed
snapshot graph in which information flows strictly from the past:

1. ``order_t``     — effective order vertex, carries the label.
2. ``order_t^s``   — shadow clone; exchanges messages with same-snapshot
                     entities so *future* orders can see it as history, while
                     the effective order itself never feeds the graph.
3. ``entity_t``    — entity snapshot vertex, one per (entity, active snapshot).
4. Edges (paper Table 2):
   * ``order_t^s <-> entity_t``         (same snapshot, both directions)
   * ``entity_{t-i} -> entity_t``       (history + self-loop)
   * ``entity_{t-e} -> order_t``        (one edge per linked entity, from the
                                         entity's latest *strictly past*
                                         active snapshot — the only edges
                                         needed at online inference)

The construction guarantees the **no-future-leak invariant**: every directed
edge (u→v) satisfies snapshot(u) <= snapshot(v), and the only edges *into* an
effective order come from snapshots strictly in its past or — for the
same-snapshot entity state — only via entity self-history that itself never
saw the order.  Property-tested in ``tests/test_dds_properties.py``.
"""
from __future__ import annotations

from bisect import bisect_left
from dataclasses import dataclass, field

import numpy as np

from repro.core.graph import COOGraph, EdgeType, NodeType
from repro.core.hetero import type_codes_array


def _tower_codes(n_nodes: int, entity_snap_ids: dict) -> np.ndarray | None:
    """Per-node entity-type tower codes for a materialized DDS graph.

    Returns ``None`` when no entity id carries a :mod:`repro.core.hetero`
    type tag — homogeneous graphs keep the exact pre-hetero COO layout
    (``tower=None``), which is what the bit-parity gates compare.
    Otherwise an int32 [n_nodes] array: the type code at each entity-
    snapshot vertex, ``-1`` for orders, shadows, and untagged entities.
    """
    if not entity_snap_ids:
        return None
    ents = np.fromiter((pair[0] for pair in entity_snap_ids),
                       np.int64, len(entity_snap_ids))
    codes = type_codes_array(ents)
    if not (codes >= 0).any():
        return None
    tower = np.full(n_nodes, -1, np.int32)
    nids = np.fromiter(entity_snap_ids.values(), np.int64, len(entity_snap_ids))
    tower[nids] = codes
    return tower


@dataclass
class StaticGraph:
    """Host-side static transaction graph (paper §3.2 'Static Graph').

    ``edges`` is an [E, 2] int64 array of (order_id, entity_id); each order
    links at most one entity per entity *type* (shipping address, email, IP,
    device, phone, payment token, account — paper lists 7).
    """

    num_orders: int
    num_entities: int
    edges: np.ndarray              # [E, 2] (order, entity)
    order_snapshot: np.ndarray     # [n_ord] int — snapshot index of checkout
    order_features: np.ndarray     # [n_ord, F] float32 — raw checkout features
    labels: np.ndarray             # [n_ord] {0,1} — unauthenticated chargeback
    entity_type: np.ndarray | None = None   # [num_entities] int — optional
    num_snapshots: int = field(default=0)

    def __post_init__(self):
        if self.num_snapshots == 0:
            self.num_snapshots = int(self.order_snapshot.max()) + 1 if self.num_orders else 0


@dataclass
class DDSGraph:
    """The DDS graph plus bookkeeping to map back to static ids."""

    coo: COOGraph
    # node-id layout: [0, n_ord) effective orders; [n_ord, 2*n_ord) shadows;
    # [2*n_ord, 2*n_ord + num_entity_snap_nodes) entity-snapshot vertices.
    num_orders: int
    entity_snap_ids: dict          # (entity, t) -> node id
    # the final-hop table (speed-layer input): for each order, the entity
    # snapshot node ids feeding its ENTITY_TO_ORDER edges
    last_hop: dict                 # order id -> list[(entity, t_e, node_id)]

    @property
    def shadow_offset(self) -> int:
        return self.num_orders


def build_dds(
    g: StaticGraph,
    entity_history: str = "all",
    max_history: int | None = None,
) -> DDSGraph:
    """Build the DDS graph from a static transaction graph.

    entity_history:
      * ``'all'``          — edge from every past active snapshot (paper default:
                             "entity_t may be connected with a bunch of
                             entity_{t-i}"), optionally capped at
                             ``max_history`` most recent.
      * ``'consecutive'``  — edge only from the previous active snapshot
                             (information still flows transitively; cheaper).
    Always adds the self-loop ``entity_t -> entity_t``.
    """
    if entity_history not in ("all", "consecutive"):
        raise ValueError(entity_history)
    n_ord = g.num_orders

    # --- which (entity, t) pairs are active (linked to >= 1 order in t) ----
    order_of_edge = g.edges[:, 0]
    entity_of_edge = g.edges[:, 1]
    t_of_edge = g.order_snapshot[order_of_edge]

    # lexicographic unique over (entity, t) rows — same sorted order as the
    # old ent*(S+1)+t integer keys, but safe for tagged 43-bit entity ids
    # whose key product could overflow int64 at large snapshot counts
    pairs = np.stack([entity_of_edge.astype(np.int64),
                      t_of_edge.astype(np.int64)], axis=1)
    uniq_pairs = np.unique(pairs, axis=0) if pairs.size \
        else pairs.reshape(0, 2)
    uniq_entity, uniq_t = uniq_pairs[:, 0], uniq_pairs[:, 1]
    entity_snap_ids: dict = {}
    for i, (ent, t) in enumerate(zip(uniq_entity.tolist(), uniq_t.tolist())):
        entity_snap_ids[(ent, t)] = 2 * n_ord + i
    n_nodes = 2 * n_ord + len(entity_snap_ids)

    # active snapshots per entity, sorted ascending
    active: dict = {}
    for ent, t in zip(uniq_entity.tolist(), uniq_t.tolist()):
        active.setdefault(ent, []).append(t)
    for ent in active:
        active[ent].sort()

    src, dst, et = [], [], []

    # --- shadow <-> entity (same snapshot) --------------------------------
    for o, ent, t in zip(order_of_edge.tolist(), entity_of_edge.tolist(), t_of_edge.tolist()):
        e_node = entity_snap_ids[(ent, t)]
        s_node = n_ord + o  # shadow clone of order o
        src.append(s_node); dst.append(e_node); et.append(EdgeType.SHADOW_TO_ENTITY)
        src.append(e_node); dst.append(s_node); et.append(EdgeType.ENTITY_TO_SHADOW)

    # --- entity history (entity_{t-i} -> entity_t, incl. self loop) -------
    for ent, snaps in active.items():
        for j, t in enumerate(snaps):
            cur = entity_snap_ids[(ent, t)]
            src.append(cur); dst.append(cur); et.append(EdgeType.ENTITY_HIST)  # self-loop
            if entity_history == "consecutive":
                past = snaps[j - 1 : j] if j > 0 else []
            else:
                past = snaps[:j]
                if max_history is not None:
                    past = past[-max_history:]
            for tp in past:
                src.append(entity_snap_ids[(ent, tp)]); dst.append(cur); et.append(EdgeType.ENTITY_HIST)

    # --- effective entity -> order (the final 1-hop edges) ----------------
    last_hop: dict = {}
    for o, ent, t in zip(order_of_edge.tolist(), entity_of_edge.tolist(), t_of_edge.tolist()):
        snaps = active[ent]
        # latest active snapshot strictly before t  (paper: 0 <= t-e < t)
        idx = np.searchsorted(snaps, t) - 1
        if idx < 0:
            continue  # cold entity: no history before this order
        t_e = snaps[idx]
        e_node = entity_snap_ids[(ent, t_e)]
        src.append(e_node); dst.append(o); et.append(EdgeType.ENTITY_TO_ORDER)
        last_hop.setdefault(o, []).append((ent, t_e, e_node))

    # --- node tables -------------------------------------------------------
    F = g.order_features.shape[1]
    features = np.zeros((n_nodes, F), np.float32)
    features[:n_ord] = g.order_features
    features[n_ord : 2 * n_ord] = g.order_features  # shadows share raw features
    # entity features are zero per paper §4.2 ("initial features set to zero")

    node_type = np.full(n_nodes, NodeType.ENTITY, np.int32)
    node_type[:n_ord] = NodeType.ORDER
    node_type[n_ord : 2 * n_ord] = NodeType.SHADOW

    snapshot = np.zeros(n_nodes, np.int32)
    snapshot[:n_ord] = g.order_snapshot
    snapshot[n_ord : 2 * n_ord] = g.order_snapshot
    for (ent, t), nid in entity_snap_ids.items():
        snapshot[nid] = t

    label = np.zeros(n_nodes, np.float32)
    label[:n_ord] = g.labels
    label_mask = np.zeros(n_nodes, np.float32)
    label_mask[:n_ord] = 1.0  # only effective orders are supervised

    coo = COOGraph(
        num_nodes=n_nodes,
        src=np.asarray(src, np.int64),
        dst=np.asarray(dst, np.int64),
        etype=np.asarray(et, np.int32),
        features=features,
        node_type=node_type,
        snapshot=snapshot,
        label=label,
        label_mask=label_mask,
        tower=_tower_codes(n_nodes, entity_snap_ids),
    )
    return DDSGraph(coo=coo, num_orders=n_ord, entity_snap_ids=entity_snap_ids, last_hop=last_hop)


class IncrementalDDSBuilder:
    """Event-time incremental DDS construction — the streaming ingest path.

    ``add_order`` appends one checkout event (events must arrive in
    non-decreasing snapshot order, the event-time contract); the builder
    maintains per-entity active-snapshot lists, the final-hop table, and the
    typed edge lists incrementally, so per-event cost is O(K · history) with
    no global rebuild.  ``entity_keys`` answers the speed-layer question —
    "which ``(entity, t_e)`` KV keys feed this checkout?" — in
    O(K log S) without materializing anything.

    ``build()`` materializes a :class:`DDSGraph` whose padded form is
    bit-identical to ``build_dds`` on the equivalent accumulated
    :class:`StaticGraph` (same per-destination edge order, same node-id
    layout: entity-snapshot ids assigned in sorted ``(entity, t)`` order).
    The no-future-leak invariants hold by construction *at every prefix*:
    a node's in-neighborhood is final the moment its snapshot closes, which
    is exactly what lets the batch layer refresh embeddings incrementally
    (see ``repro.stream.refresh``).
    """

    def __init__(
        self,
        feat_dim: int,
        entity_history: str = "all",
        max_history: int | None = None,
    ):
        if entity_history not in ("all", "consecutive"):
            raise ValueError(entity_history)
        self.feat_dim = int(feat_dim)
        self.entity_history = entity_history
        self.max_history = max_history
        # accumulated static-graph state
        self._order_snapshot: list[int] = []
        self._order_features: list[np.ndarray] = []
        self._labels: list[float] = []
        self._order_entities: list[tuple] = []      # per order, linked entities
        self._active: dict[int, list[int]] = {}     # entity -> sorted snapshots
        self._entity_orders: dict[int, list[int]] = {}  # entity -> order ids
        self._pair_seq: list[tuple] = []            # (ent, t) in activation order
        # typed symbolic edge lists; entity-snap nodes are (ent, t) tuples,
        # orders are ints, shadows are ('s', order)
        self._shadow_edges: list[tuple] = []        # (order, ent, t) both dirs
        self._hist_edges: list[tuple] = []          # (ent, t_src, t_dst)
        self._final_edges: list[tuple] = []         # (ent, t_e, order)

    # ------------------------------------------------------------------ state
    @property
    def num_orders(self) -> int:
        return len(self._order_snapshot)

    @property
    def current_snapshot(self) -> int:
        return self._order_snapshot[-1] if self._order_snapshot else -1

    def entity_keys(self, entities, t: int) -> list:
        """Speed-layer key list: latest *strictly past* active snapshot per
        linked entity (cold entities contribute nothing)."""
        keys = []
        for ent in entities:
            snaps = self._active.get(int(ent))
            if not snaps:
                continue
            idx = bisect_left(snaps, t) - 1
            if idx >= 0:
                keys.append((int(ent), snaps[idx]))
        return keys

    # ----------------------------------------------------------------- ingest
    def add_order(self, entities, snapshot: int, features, label: float = 0.0) -> int:
        """Append one checkout.  Returns the new order id (arrival order).

        Raises on a snapshot regression — event-time ordering is the
        invariant that makes incremental construction leak-free.
        """
        t = int(snapshot)
        if t < self.current_snapshot:
            raise ValueError(
                f"event-time regression: snapshot {t} after {self.current_snapshot}"
            )
        o = self.num_orders
        feats = np.asarray(features, np.float32)
        if feats.shape != (self.feat_dim,):
            raise ValueError(f"features shape {feats.shape} != ({self.feat_dim},)")
        entities = [int(e) for e in entities]
        self._order_snapshot.append(t)
        self._order_features.append(feats)
        self._labels.append(float(label))
        self._order_entities.append(tuple(entities))

        for ent in entities:
            self._entity_orders.setdefault(ent, []).append(o)
            snaps = self._active.setdefault(ent, [])
            # final-hop edge from the latest strictly-past active snapshot.
            # Computed before (ent, t) activates, but t itself is excluded
            # either way — matches build_dds exactly.
            idx = bisect_left(snaps, t) - 1
            if idx >= 0:
                self._final_edges.append((ent, snaps[idx], o))
            # activate (ent, t) on first touch: history edges are final here
            # because every past snapshot of ent is already closed
            if not snaps or snaps[-1] != t:
                if self.entity_history == "consecutive":
                    past = snaps[-1:]
                else:
                    past = snaps if self.max_history is None else snaps[-self.max_history:]
                self._hist_edges.append((ent, t, t))        # self-loop first
                for tp in past:
                    self._hist_edges.append((ent, tp, t))
                snaps.append(t)
                self._pair_seq.append((ent, t))
            self._shadow_edges.append((o, ent, t))
        return o

    # ------------------------------------------------------------ materialize
    def to_static(self, num_snapshots: int = 0) -> StaticGraph:
        """The accumulated transactions as a StaticGraph (orders in arrival
        order) — ``build_dds(to_static())`` is the batch-path oracle the
        equivalence tests compare against."""
        edges = [
            (o, e) for o, ents in enumerate(self._order_entities) for e in ents
        ]
        num_entities = 1 + max((e for _, e in edges), default=-1)
        return StaticGraph(
            num_orders=self.num_orders,
            num_entities=num_entities,
            edges=np.asarray(edges, np.int64).reshape(-1, 2),
            order_snapshot=np.asarray(self._order_snapshot, np.int64),
            order_features=np.stack(self._order_features)
            if self._order_features
            else np.zeros((0, self.feat_dim), np.float32),
            labels=np.asarray(self._labels, np.float32),
            num_snapshots=num_snapshots,
        )

    def build(self) -> DDSGraph:
        """Materialize the accumulated DDS graph.

        Node ids: [0, n_ord) orders, [n_ord, 2*n_ord) shadows, then entity-snapshot
        vertices in sorted (entity, t) order — the ``build_dds`` layout.
        Per-destination edge order also matches ``build_dds`` (shadow edges
        in event order, history self-loop before ascending past, final-hop
        in event order), so ``pad_graph`` output is identical.
        """
        n_ord = self.num_orders
        entity_snap_ids = {
            pair: 2 * n_ord + i for i, pair in enumerate(sorted(self._pair_seq))
        }
        src, dst, et = [], [], []
        for o, ent, t in self._shadow_edges:
            e_node = entity_snap_ids[(ent, t)]
            src.append(n_ord + o); dst.append(e_node); et.append(EdgeType.SHADOW_TO_ENTITY)
            src.append(e_node); dst.append(n_ord + o); et.append(EdgeType.ENTITY_TO_SHADOW)
        for ent, t_src, t_dst in self._hist_edges:
            src.append(entity_snap_ids[(ent, t_src)])
            dst.append(entity_snap_ids[(ent, t_dst)])
            et.append(EdgeType.ENTITY_HIST)
        last_hop: dict = {}
        for ent, t_e, o in self._final_edges:
            e_node = entity_snap_ids[(ent, t_e)]
            src.append(e_node); dst.append(o); et.append(EdgeType.ENTITY_TO_ORDER)
            last_hop.setdefault(o, []).append((ent, t_e, e_node))

        n_nodes = 2 * n_ord + len(entity_snap_ids)
        features = np.zeros((n_nodes, self.feat_dim), np.float32)
        if n_ord:
            of = np.stack(self._order_features)
            features[:n_ord] = of
            features[n_ord : 2 * n_ord] = of
        node_type = np.full(n_nodes, NodeType.ENTITY, np.int32)
        node_type[:n_ord] = NodeType.ORDER
        node_type[n_ord : 2 * n_ord] = NodeType.SHADOW
        snapshot = np.zeros(n_nodes, np.int32)
        snapshot[:n_ord] = self._order_snapshot
        snapshot[n_ord : 2 * n_ord] = self._order_snapshot
        for (ent, t), nid in entity_snap_ids.items():
            snapshot[nid] = t
        label = np.zeros(n_nodes, np.float32)
        label[:n_ord] = self._labels
        label_mask = np.zeros(n_nodes, np.float32)
        label_mask[:n_ord] = 1.0
        coo = COOGraph(
            num_nodes=n_nodes,
            src=np.asarray(src, np.int64),
            dst=np.asarray(dst, np.int64),
            etype=np.asarray(et, np.int32),
            features=features,
            node_type=node_type,
            snapshot=snapshot,
            label=label,
            label_mask=label_mask,
            tower=_tower_codes(n_nodes, entity_snap_ids),
        )
        dds = DDSGraph(coo=coo, num_orders=n_ord, entity_snap_ids=entity_snap_ids,
                       last_hop=last_hop)
        return dds

    def build_subgraph(self, entities) -> DDSGraph:
        """Materialize the DDS subgraph induced by a **component-closed**
        entity set — the community-local batch-layer input.

        ``entities`` must be a union of connected components of the
        order↔entity graph (see ``core.partition.IncrementalPartitioner``);
        an order linking both an in-set and an out-of-set entity raises
        ``ValueError``, because such a cut would silently drop in-edges and
        break the bit-identical refresh guarantee.  Closure means NO DDS
        edge crosses the subgraph boundary, so every included node keeps
        its full in-neighborhood at any GNN depth.

        Cost is O(touched orders + touched pairs) — never O(total stream).

        Local node-id layout mirrors ``build()``: [0, n_sub) selected
        orders in arrival order, then shadows, then entity snapshots in
        sorted (entity, t) order; per-destination edge order also matches
        (shadow edges in event order, history self-loop before ascending
        past, final-hop in event order).  ``pad_graph`` rows of this
        subgraph are therefore bit-identical to the corresponding rows of
        the padded full ``build()`` graph modulo the local→global id
        remapping (sliced-build parity test), which is what makes
        community-local stage-1 embeddings equal the whole-graph ones
        bit-for-bit.
        """
        ents = {int(e) for e in entities}
        touched = sorted({o for e in ents
                          for o in self._entity_orders.get(e, ())})
        for o in touched:
            for e2 in self._order_entities[o]:
                if e2 not in ents:
                    raise ValueError(
                        f"entity set is not component-closed: order {o} links "
                        f"entity {e2} outside the set"
                    )
        n_sub = len(touched)
        order_local = {o: i for i, o in enumerate(touched)}
        pairs = sorted((e, t) for e in ents for t in self._active.get(e, ()))
        entity_snap_ids = {p: 2 * n_sub + i for i, p in enumerate(pairs)}

        src, dst, et = [], [], []
        # shadow <-> entity, in event order (ascending order id, per-order
        # entity order preserved) — matches the filtered _shadow_edges list
        for o in touched:
            t = self._order_snapshot[o]
            s_node = n_sub + order_local[o]
            for ent in self._order_entities[o]:
                e_node = entity_snap_ids[(ent, t)]
                src.append(s_node); dst.append(e_node); et.append(EdgeType.SHADOW_TO_ENTITY)
                src.append(e_node); dst.append(s_node); et.append(EdgeType.ENTITY_TO_SHADOW)
        # entity history: reconstruct each activation's edges from the
        # active-snapshot list (the state at activation time was the strict
        # prefix, so snaps[:j] reproduces _hist_edges exactly); only
        # per-destination order matters to pad_graph, so iterating entities
        # sorted rather than in global activation order is equivalent
        for ent in sorted(ents):
            snaps = self._active.get(ent, [])
            for j, t in enumerate(snaps):
                cur = entity_snap_ids[(ent, t)]
                src.append(cur); dst.append(cur); et.append(EdgeType.ENTITY_HIST)
                if self.entity_history == "consecutive":
                    past = snaps[j - 1 : j] if j > 0 else []
                else:
                    past = snaps[:j]
                    if self.max_history is not None:
                        past = past[-self.max_history:]
                for tp in past:
                    src.append(entity_snap_ids[(ent, tp)]); dst.append(cur)
                    et.append(EdgeType.ENTITY_HIST)
        # final hop: latest strictly-past active snapshot per linked entity.
        # Recomputing against the *current* active list is exact — snapshots
        # activated after the order are never strictly before it
        last_hop: dict = {}
        for o in touched:
            t = self._order_snapshot[o]
            lo = order_local[o]
            for ent in self._order_entities[o]:
                snaps = self._active[ent]
                idx = bisect_left(snaps, t) - 1
                if idx < 0:
                    continue
                t_e = snaps[idx]
                e_node = entity_snap_ids[(ent, t_e)]
                src.append(e_node); dst.append(lo); et.append(EdgeType.ENTITY_TO_ORDER)
                last_hop.setdefault(lo, []).append((ent, t_e, e_node))

        n_nodes = 2 * n_sub + len(entity_snap_ids)
        features = np.zeros((n_nodes, self.feat_dim), np.float32)
        node_type = np.full(n_nodes, NodeType.ENTITY, np.int32)
        node_type[:n_sub] = NodeType.ORDER
        node_type[n_sub : 2 * n_sub] = NodeType.SHADOW
        snapshot = np.zeros(n_nodes, np.int32)
        label = np.zeros(n_nodes, np.float32)
        label_mask = np.zeros(n_nodes, np.float32)
        label_mask[:n_sub] = 1.0
        for o in touched:
            lo = order_local[o]
            features[lo] = self._order_features[o]
            features[n_sub + lo] = self._order_features[o]
            snapshot[lo] = snapshot[n_sub + lo] = self._order_snapshot[o]
            label[lo] = self._labels[o]
        for (ent, t), nid in entity_snap_ids.items():
            snapshot[nid] = t
        coo = COOGraph(
            num_nodes=n_nodes,
            src=np.asarray(src, np.int64),
            dst=np.asarray(dst, np.int64),
            etype=np.asarray(et, np.int32),
            features=features,
            node_type=node_type,
            snapshot=snapshot,
            label=label,
            label_mask=label_mask,
            tower=_tower_codes(n_nodes, entity_snap_ids),
        )
        return DDSGraph(coo=coo, num_orders=n_sub,
                        entity_snap_ids=entity_snap_ids, last_hop=last_hop)


def check_no_future_leak(dds: DDSGraph) -> None:
    """Assert the DDS invariants (used by property tests):

    1. every edge u->v has snapshot(u) <= snapshot(v);
    2. edges into an effective ORDER come only from strictly-past entity
       snapshots (EdgeType.ENTITY_TO_ORDER with snapshot(u) < snapshot(v));
    3. effective ORDER vertices have no outgoing edges (labels never leak);
    4. same-snapshot edges only connect shadows and entities.
    """
    coo = dds.coo
    s_snap = coo.snapshot[coo.src]
    d_snap = coo.snapshot[coo.dst]
    if not np.all(s_snap <= d_snap):
        raise AssertionError("edge from future snapshot found")
    into_order = coo.node_type[coo.dst] == NodeType.ORDER
    if into_order.any():
        if not np.all(coo.etype[into_order] == EdgeType.ENTITY_TO_ORDER):
            raise AssertionError("non-final-hop edge into effective order")
        if not np.all(s_snap[into_order] < d_snap[into_order]):
            raise AssertionError("same/future-snapshot edge into effective order")
    from_order = coo.node_type[coo.src] == NodeType.ORDER
    if from_order.any():
        raise AssertionError("effective order has outgoing edge (label leak)")
    same = s_snap == d_snap
    if same.any():
        ok_types = np.isin(
            coo.etype[same],
            [EdgeType.SHADOW_TO_ENTITY, EdgeType.ENTITY_TO_SHADOW, EdgeType.ENTITY_HIST],
        )
        if not np.all(ok_types):
            raise AssertionError("same-snapshot edge of illegal type")
