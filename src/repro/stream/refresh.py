"""Async batch-layer refresh driver — the periodic half of the Lambda loop.

Re-runs LNN stage 1 over the accumulated DDS graph and pushes **only the
dirty** entity-snapshot embeddings (those whose windows closed since the
last run) into the KV store with a monotonically increasing refresh
version.  Correctness hinges on the DDS invariant: an ``entity_t`` vertex's
in-neighborhood is final once snapshot ``t`` closes, so its stage-1
embedding computed from the *partial* stream equals the one the full batch
graph would produce — refreshing incrementally loses nothing.

Worker-aware fan-out: when the engine runs a sharded speed layer, the
driver groups each refresh's puts by the router's entity -> worker map and
writes shard by shard (``stats["per_shard_written"]``).  With an
entity-affine store each group touches exactly one KV shard — the write
pattern a real deployment has, where every worker's KV shard is refreshed
by its own feed from the batch layer.  The refresh version is global (one
batch-layer run is one version, however many shards it fans out to), and
within a group writes stay sorted, so the fan-out is deterministic.

Staleness model: an entity key requested as ``(e, t_e)`` but served from an
older stored snapshot ``t' < t_e`` is ``t_e - t'`` snapshots stale (the KV
store tracks this, see ``lookup_batch_versioned``).  Refreshing every
closed window keeps staleness at zero; refreshing every N windows trades
freshness for batch-layer cost — ``benchmarks/streaming_bench.py`` plots
that curve.

``async_mode=True`` runs stage 1 on a single background worker thread (the
batch layer is off the scoring hot path in production); ``drain()`` joins
outstanding work.  Tests use the default synchronous mode.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor

import jax
import numpy as np

from repro.core.graph import pad_graph
from repro.core.lnn import LNNConfig, lnn_stage1
from repro.serve.kvstore import KVStore, pack_key
from repro.stream.ingest import StreamIngester


def _pow2_at_least(n: int, floor: int = 64) -> int:
    b = floor
    while b < n:
        b *= 2
    return b


class RefreshDriver:
    def __init__(
        self,
        params,
        cfg: LNNConfig,
        store: KVStore,
        ingester: StreamIngester,
        max_deg: int = 32,
        refresh_every: int = 1,
        async_mode: bool = False,
        router=None,
    ):
        self.params = params
        self.cfg = cfg
        self.store = store
        self.ingester = ingester
        self.max_deg = max_deg
        self.refresh_every = max(1, int(refresh_every))
        # anything with worker_of(entity) -> int (stream.workers.ShardRouter);
        # None = single feed, no fan-out grouping
        self.router = router
        self.version = 0
        self.model_version = 0
        self._stage1 = jax.jit(lambda p, g: lnn_stage1(p, self.cfg, g))
        self._windows_since_refresh = 0
        self._lock = threading.Lock()
        self._pool = ThreadPoolExecutor(max_workers=1) if async_mode else None
        self._inflight = []
        self.stats = {"refreshes": 0, "entities_written": 0, "seconds": 0.0,
                      "last_budget": 0, "per_shard_written": {}}

    # --------------------------------------------------------------- hot-swap
    def set_model(self, params, model_version: int) -> None:
        """Swap to a new parameter version: refreshes *started* after this
        call compute with it and stamp their KV puts with it (an async
        refresh already snapshotted keeps the params it captured)."""
        with self._lock:
            self.params = params
            self.model_version = int(model_version)

    # ----------------------------------------------------------------- policy
    def on_windows_closed(self, closed_window) -> bool:
        """Called by the engine when event time advances past one or more
        snapshots; ``closed_window`` is the (first, last) closed range.
        Triggers a refresh once ``refresh_every`` windows have closed.
        Returns True if a refresh was started (sync: already finished)."""
        if closed_window is None:
            return False
        first, last = closed_window
        self._windows_since_refresh += last - first + 1
        if self._windows_since_refresh < self.refresh_every:
            return False
        self._windows_since_refresh = 0
        up_to = last
        if self._pool is None:
            self.refresh(up_to)
        else:
            # snapshot the ingester state AND the active model on the
            # calling thread (both keep mutating under new events /
            # hot-swaps); only stage 1 + puts go async
            pending, dds = self._snapshot_graph(up_to)
            params, model_version = self.params, self.model_version
            if pending:
                self._inflight.append(
                    self._pool.submit(self._run, pending, dds,
                                      params, model_version))
        return True

    def drain(self):
        """Join outstanding async refreshes (replay-end barrier)."""
        for f in self._inflight:
            f.result()
        self._inflight.clear()

    # ------------------------------------------------------------------- work
    def _snapshot_graph(self, up_to_snapshot: int):
        pending = self.ingester.take_refreshable(up_to_snapshot)
        return (pending, self.ingester.materialize() if pending else None)

    def refresh(self, up_to_snapshot: int) -> dict:
        """Run stage 1 over the accumulated graph; write embeddings for the
        dirty (entity, t) pairs with t <= up_to_snapshot, versioned."""
        pending, dds = self._snapshot_graph(up_to_snapshot)
        if not pending:
            return {"entities_written": 0, "seconds": 0.0}
        return self._run(pending, dds, self.params, self.model_version)

    def _shard_groups(self, pending) -> list[tuple[int, list]]:
        """Group dirty (entity, t) pairs by owning speed-layer shard, shard
        order ascending, sorted within each group — the deterministic
        per-shard write feeds of one batch-layer run."""
        if self.router is None:
            return [(0, sorted(pending))]
        groups: dict[int, list] = {}
        for pair in pending:
            groups.setdefault(self.router.worker_of(pair[0]), []).append(pair)
        return [(s, sorted(groups[s])) for s in sorted(groups)]

    def _run(self, pending, dds, params, model_version: int) -> dict:
        t0 = time.time()
        # pad to a power-of-two node budget so jit recompiles O(log N) times
        # over an unbounded stream, not once per event window
        budget = _pow2_at_least(dds.coo.num_nodes)
        pg = pad_graph(dds.coo, num_nodes=budget, max_deg=self.max_deg)
        h = np.asarray(self._stage1(params, pg))
        groups = self._shard_groups(pending)
        with self._lock:
            self.version += 1
            written = 0
            for shard, pairs in groups:
                # one batched put per shard feed: a single store lock
                # acquisition per group instead of one per embedding
                resolved = [(pack_key(ent, t), dds.entity_snap_ids[(ent, t)])
                            for ent, t in pairs
                            if (ent, t) in dds.entity_snap_ids]
                shard_written = self.store.put_batch(
                    [k for k, _ in resolved],
                    (h[nid] for _, nid in resolved),
                    version=self.version, model_version=model_version,
                ) if resolved else 0
                per = self.stats["per_shard_written"]
                per[shard] = per.get(shard, 0) + shard_written
                written += shard_written
        dt = time.time() - t0
        self.stats["refreshes"] += 1
        self.stats["entities_written"] += written
        self.stats["seconds"] += dt
        self.stats["last_budget"] = budget
        return {"entities_written": written, "seconds": dt, "version": self.version,
                "shards_touched": len(groups)}
