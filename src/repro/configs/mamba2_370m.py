"""mamba2-370m — SSD (state-space duality), attention-free [arXiv:2405.21060].

48L, d_model=1024, d_ff=0 (the Mamba2 block subsumes the MLP), vocab=50280,
ssm_state N=128; expand=2 -> d_inner=2048, headdim P=64 -> 32 SSM heads.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-370m",
    arch_type="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    head_dim=0,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    conv_kernel=4,
    source="[arXiv:2405.21060]",
)
