"""seamless-m4t-medium — encoder-decoder, multimodal [arXiv:2308.11596].

12L per side, d_model=1024, 16 heads (head_dim 64, MHA), d_ff=4096 (gelu),
vocab=256206 (text).  The mel-spectrogram + conformer audio frontend is a
stub: ``input_specs`` provides precomputed frame embeddings.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    arch_type="audio",
    num_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    encdec=True,
    ffn_type="gelu",
    source="[arXiv:2308.11596]",
)
