"""The paper's own model: LNN on DDS graphs (fraud detection).

Not part of the transformer zoo; exposes the LNNConfig used by the paper
reproduction benchmarks and examples.
"""
from repro.core.lnn import LNNConfig

CONFIG = LNNConfig(
    gnn_type="gcn",
    num_gnn_layers=3,
    hidden_dim=64,
    mlp_dims=(64, 32),
    feat_dim=48,          # 12 raw + 36 GBDT-encoded (paper §4.2 encoding)
    pos_weight=3.0,
)
