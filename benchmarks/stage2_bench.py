"""Stage-2 speed-layer scoring: fused vs unfused latency per batch size.

Three variants of the online scoring path, timed per micro-batch bucket:

* ``unfused`` — the pre-fusion serving path: two jitted dispatches per
  flush (order tower, then aggregation + combine + MLP), as ``SpeedLayer``
  shipped before the fused kernel landed;
* ``fused``   — ONE jitted dispatch of the whole online path
  (``lnn_stage2_online`` with the tower folded in).  On CPU this is the
  XLA rendering of the fusion and is what the serving engine now runs per
  flush; on TPU the same call site lowers to the Pallas launch;
* ``pallas_interpret`` — the fused Pallas kernel executed through the
  interpreter.  On this CPU container that is a *correctness vehicle, not
  a perf number* (the interpreter adds orders of magnitude of overhead —
  see docs/kernels.md); reported so regressions in kernel dispatch
  structure are visible.

For each batch size we also report the fused launch's arithmetic intensity
and projected v5e time from the roofline model (``launch/mesh.py``) — the
number the Pallas kernel is designed to approach on hardware.

Writes ``experiments/BENCH_stage2.json``; wired into ``benchmarks/run.py``.
``--smoke`` shrinks batch sizes and iteration counts to CI-smoke scale.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

BATCH_SIZES = (1, 2, 4, 8, 16, 32, 64, 128)


def _time(fn, *args, iters=50, repeats=5):
    """Best-of-``repeats`` mean over ``iters`` calls (us) — the min filters
    out scheduler noise on a shared CPU container."""
    import jax

    for _ in range(3):                     # compile + cache warm
        out = fn(*args)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / iters * 1e6)
    return best


def _roofline(b, k, h, f, mlp_dims):
    """FLOPs / HBM bytes for one fused stage-2 launch."""
    dims = (h + f,) + tuple(mlp_dims) + (1,)
    mlp_flops = 2 * sum(dims[i] * dims[i + 1] for i in range(len(dims) - 1))
    flops = b * (
        2 * f * h                 # input projection
        + 2 * 2 * h * h           # two tower self-transforms (L=3)
        + 2 * k * h               # masked aggregation
        + 2 * 2 * h * h           # last-layer combine (self + nbr matmul)
        + mlp_flops
    )
    param_bytes = 4 * (f * h + 2 * h * h + 2 * h * h
                       + sum(dims[i] * dims[i + 1] for i in range(len(dims) - 1)))
    io_bytes = 4 * b * (k * h + k + f + 1)
    return flops, param_bytes + io_bytes


def main(batch_sizes=BATCH_SIZES, iters=100, smoke: bool = False):
    # smoke runs shrink sizes AND land in experiments/smoke/ so a local
    # `run.py --smoke` can never clobber the curated full-run records
    outdir = os.path.join("experiments", "smoke") if smoke else "experiments"
    if smoke:
        batch_sizes, iters = (1, 4, 16), 5
    import jax
    import jax.numpy as jnp

    from repro.core import LNNConfig, lnn_init, lnn_order_tower, lnn_stage2_online
    from repro.kernels import ops
    from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16

    cfg = LNNConfig(gnn_type="gcn", num_gnn_layers=3, hidden_dim=64,
                    mlp_dims=(64, 32), feat_dim=16)
    params = lnn_init(jax.random.PRNGKey(0), cfg)
    k = 8
    rng = np.random.default_rng(0)

    # the pre-fusion serving path: two dispatches per flush
    tower_jit = jax.jit(lambda p, f: lnn_order_tower(p, cfg, f))
    stage2_jit = jax.jit(
        lambda p, e, m, f, t: lnn_stage2_online(p, cfg, e, m, f, t))

    def unfused(p, e, m, f):
        return stage2_jit(p, e, m, f, tower_jit(p, f))

    fused_jit = jax.jit(lambda p, e, m, f: lnn_stage2_online(p, cfg, e, m, f))

    per_batch = {}
    for b in batch_sizes:
        mask = jnp.asarray((rng.uniform(size=(b, k)) < 0.7), jnp.float32)
        emb = jnp.asarray(rng.normal(size=(b, k, cfg.hidden_dim)),
                          jnp.float32) * mask[:, :, None]
        feats = jnp.asarray(rng.normal(size=(b, cfg.feat_dim)), jnp.float32)

        un_us = _time(unfused, params, emb, mask, feats, iters=iters)
        fu_us = _time(fused_jit, params, emb, mask, feats, iters=iters)
        pl_us = _time(
            lambda p, e, m, f: ops.stage2_score(p, cfg.gnn_type, e, m, f),
            params, emb, mask, feats, iters=max(3, iters // 10))

        flops, bytes_ = _roofline(b, k, cfg.hidden_dim, cfg.feat_dim, cfg.mlp_dims)
        per_batch[str(b)] = {
            "unfused_us": un_us,
            "fused_us": fu_us,
            "pallas_interpret_us": pl_us,
            "speedup": un_us / fu_us,
            "gflops": flops / 1e9,
            "arith_intensity": flops / max(bytes_, 1),
            "v5e_roofline_us": max(flops / PEAK_FLOPS_BF16, bytes_ / HBM_BW) * 1e6,
        }

    out = {
        "config": {"gnn_type": cfg.gnn_type, "hidden_dim": cfg.hidden_dim,
                   "feat_dim": cfg.feat_dim, "mlp_dims": list(cfg.mlp_dims),
                   "k_max": k, "backend": jax.default_backend()},
        "per_batch": per_batch,
        "speedup_at_32": per_batch.get("32", {}).get("speedup"),
        "note": ("'fused' is the single-dispatch online path (the Pallas "
                 "launch on TPU, its XLA rendering on CPU); "
                 "'pallas_interpret_us' is the interpreter-executed kernel — "
                 "a correctness vehicle, not a perf number (docs/kernels.md)."),
    }
    os.makedirs(outdir, exist_ok=True)
    json.dump(out, open(os.path.join(outdir, "BENCH_stage2.json"), "w"), indent=1)

    print("\n# Stage-2 scoring: fused (1 dispatch) vs unfused (2 dispatches)")
    print(f"{'batch':>6} {'unfused_us':>11} {'fused_us':>9} {'speedup':>8} "
          f"{'interp_us':>10} {'v5e_us':>8}")
    for b, r in per_batch.items():
        print(f"{b:>6} {r['unfused_us']:>11.1f} {r['fused_us']:>9.1f} "
              f"{r['speedup']:>7.2f}x {r['pallas_interpret_us']:>10.0f} "
              f"{r['v5e_roofline_us']:>8.2f}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI smoke (seconds, not minutes)")
    main(smoke=ap.parse_args().smoke)
