"""Unit coverage for ``repro.stream.checkpoint``: the write-ahead log
(seqnos, CRC, torn-tail repair, compaction), the atomic checkpoint commit
protocol, the service-level lifecycle rules, and the gateway route.

The end-to-end bit-identity guarantee lives in ``test_faultinject.py`` —
this module pins the mechanisms that guarantee rests on.
"""
import json
import os

import jax
import numpy as np
import pytest

from repro.core import LNNConfig, lnn_init
from repro.data import SynthConfig, generate_event_stream
from repro.service import (FraudService, ModelSection, ServiceConfig,
                           ServiceLifecycleError)
from repro.stream.checkpoint import (CheckpointError, WriteAheadLog,
                                     decode_event, encode_event,
                                     latest_checkpoint, list_checkpoints,
                                     read_checkpoint, wal_path)
from repro.stream.events import CheckoutEvent
from repro.utils import crashpoint
from repro.utils.crashpoint import SimulatedCrash


def _ev(i, snapshot=0, feats=(0.5, -0.25)):
    return CheckoutEvent(order_id=i, snapshot=snapshot,
                         entities=(i % 3, 10 + i % 2),
                         features=np.asarray(feats, np.float32),
                         label=float(i % 2), arrival=0.001 * i)


# ------------------------------------------------------------------ WAL core
def test_wal_append_scan_roundtrip(tmp_path):
    wal = WriteAheadLog(str(tmp_path / "wal.jsonl"))
    seqs = [wal.append_event("submit", _ev(i)) for i in range(5)]
    seqs.append(wal.append_model(1, "models/v1.npz"))
    seqs.append(wal.append_drain(0.125))
    assert seqs == list(range(1, 8))
    recs = list(wal.scan())
    assert [r["seq"] for r in recs] == seqs
    assert [r["kind"] for r in recs] == ["submit"] * 5 + ["model", "drain"]
    assert recs[5]["version"] == 1 and recs[5]["path"] == "models/v1.npz"
    assert recs[6]["now"] == 0.125
    # scan(after_seq) yields only the strict suffix
    assert [r["seq"] for r in wal.scan(after_seq=5)] == [6, 7]
    wal.close()


def test_event_codec_is_bit_exact():
    """Features survive the JSON trip bit-for-bit — including values that
    decimal round-tripping would corrupt (subnormals, -0.0, 1/3)."""
    feats = np.asarray([np.float32(1e-42), np.float32(-0.0),
                        np.float32(1.0) / np.float32(3.0),
                        np.float32(3.4e38)], np.float32)
    ev = CheckoutEvent(order_id=7, snapshot=3, entities=(2, 5, 9),
                      features=feats, label=1.0, arrival=0.75)
    back = decode_event(encode_event(ev))
    assert back.order_id == 7 and back.snapshot == 3
    assert back.entities == (2, 5, 9)
    assert back.features.tobytes() == feats.tobytes()
    assert back.label == 1.0 and back.arrival == 0.75


def test_wal_truncates_torn_tail(tmp_path):
    path = str(tmp_path / "wal.jsonl")
    wal = WriteAheadLog(path)
    for i in range(5):
        wal.append_event("submit", _ev(i))
    wal.close()
    with open(path, "a", encoding="utf-8") as f:
        f.write('{"seq":6,"kind":"submit","order')   # the crash mid-write
    wal2 = WriteAheadLog(path)
    assert wal2.last_seq == 5
    assert len(list(wal2.scan())) == 5
    # the repaired log appends cleanly where the torn record would have been
    assert wal2.append_event("submit", _ev(5)) == 6
    assert [r["seq"] for r in wal2.scan()] == [1, 2, 3, 4, 5, 6]
    wal2.close()


def test_wal_rejects_interior_corruption(tmp_path):
    path = str(tmp_path / "wal.jsonl")
    wal = WriteAheadLog(path)
    for i in range(5):
        wal.append_event("submit", _ev(i))
    wal.close()
    lines = open(path, encoding="utf-8").read().splitlines()
    lines[2] = lines[2][:10] + "X" + lines[2][11:]   # flip a byte mid-log
    with open(path, "w", encoding="utf-8") as f:
        f.write("\n".join(lines) + "\n")
    with pytest.raises(CheckpointError, match="interior corruption"):
        WriteAheadLog(path)


def test_wal_crc_catches_field_tampering(tmp_path):
    """A syntactically valid line whose payload was edited fails its CRC —
    at the tail it is repaired away like any torn record."""
    path = str(tmp_path / "wal.jsonl")
    wal = WriteAheadLog(path)
    for i in range(3):
        wal.append_event("submit", _ev(i))
    wal.close()
    lines = open(path, encoding="utf-8").read().splitlines()
    rec = json.loads(lines[-1])
    rec["label"] = 1.0 - rec["label"]   # tamper, keep the stale crc
    lines[-1] = json.dumps(rec, separators=(",", ":"))
    with open(path, "w", encoding="utf-8") as f:
        f.write("\n".join(lines) + "\n")
    wal2 = WriteAheadLog(path)
    assert wal2.last_seq == 2
    wal2.close()


def test_wal_compaction_preserves_suffix(tmp_path):
    path = str(tmp_path / "wal.jsonl")
    wal = WriteAheadLog(path)
    for i in range(10):
        wal.append_event("submit", _ev(i))
    assert wal.compact(upto_seq=6) == 6
    assert wal.first_seq == 7 and wal.last_seq == 10
    assert [r["seq"] for r in wal.scan()] == [7, 8, 9, 10]
    # appends continue past compaction, and a reopen sees a coherent log
    assert wal.append_event("submit", _ev(10)) == 11
    wal.close()
    wal2 = WriteAheadLog(path)
    assert (wal2.first_seq, wal2.last_seq) == (7, 11)
    assert wal2.compact(upto_seq=3) == 0   # nothing to drop
    wal2.close()


def test_wal_rejects_unknown_event_kind(tmp_path):
    wal = WriteAheadLog(str(tmp_path / "wal.jsonl"))
    with pytest.raises(ValueError, match="unknown event record kind"):
        wal.append_event("mystery", _ev(0))
    wal.close()


# ------------------------------------------------------------- crash points
def test_crashpoint_arm_fire_disarm():
    crashpoint.arm("ingest.before", hit=2)
    crashpoint.fire("ingest.before")          # hit 1: survives
    crashpoint.fire("ingest.after")           # different point: ignored
    with pytest.raises(SimulatedCrash) as exc:
        crashpoint.fire("ingest.before")      # hit 2: dies
    assert exc.value.point == "ingest.before"
    # auto-disarmed before raising: recovery code can't re-trip it
    assert crashpoint.armed() is None
    crashpoint.fire("ingest.before")


def test_crashpoint_rejects_unknown_name():
    with pytest.raises(ValueError):
        crashpoint.arm("not.a.boundary")
    with pytest.raises(ValueError):
        crashpoint.arm("ingest.before", hit=0)
    crashpoint.disarm()


# ------------------------------------------------- service + checkpoint dirs
@pytest.fixture(scope="module")
def tiny_world():
    events, g, _ = generate_event_stream(
        SynthConfig(num_users=30, num_rings=2, feature_noise=0.8, seed=5),
        rate_per_s=500.0)
    cfg = LNNConfig(num_gnn_layers=2, hidden_dim=8,
                    feat_dim=g.order_features.shape[1], mlp_dims=(8,))
    params = lnn_init(jax.random.PRNGKey(0), cfg)
    return events[:24], cfg, params


def _build(cfg, params, mode="streaming"):
    sc = ServiceConfig(
        mode=mode, model=ModelSection.from_lnn_config(cfg),
    ).replace(engine={"num_workers": 1, "max_batch": 4})
    return FraudService(sc, params=params).build()


def test_enable_wal_lifecycle_rules(tiny_world, tmp_path):
    events, cfg, params = tiny_world
    svc = _build(cfg, params)
    with pytest.raises(ServiceLifecycleError, match="requires enable_wal"):
        svc.checkpoint()
    svc.enable_wal(str(tmp_path / "a"))
    with pytest.raises(ServiceLifecycleError, match="called twice"):
        svc.enable_wal(str(tmp_path / "b"))
    # a service that already saw traffic cannot start a log mid-history —
    # through the facade the state gate refuses; events smuggled past the
    # facade (direct engine access) trip the ingested-events gate
    late = _build(cfg, params)
    late.submit(events[0])
    with pytest.raises(ServiceLifecycleError, match="illegal in state"):
        late.enable_wal(str(tmp_path / "c"))
    smuggled = _build(cfg, params)
    smuggled.engine.ingest(events[0])
    with pytest.raises(ServiceLifecycleError, match="before any traffic"):
        smuggled.enable_wal(str(tmp_path / "c"))


def test_checkpoint_commit_is_atomic_and_idempotent(tiny_world, tmp_path):
    events, cfg, params = tiny_world
    root = str(tmp_path)
    svc = _build(cfg, params).enable_wal(root)
    for ev in events[:8]:
        svc.submit(ev)
    # a crash between state.npz and manifest.json leaves NO visible
    # checkpoint — only the .tmp staging dir, which the next writer cleans
    crashpoint.arm("checkpoint.mid")
    with pytest.raises(SimulatedCrash):
        svc.checkpoint()
    assert latest_checkpoint(root) is None
    staged = [d for d in os.listdir(os.path.join(root, "checkpoints"))
              if d.endswith(".tmp")]
    assert staged, "interrupted write should leave its staging dir"

    path = svc.checkpoint()
    assert latest_checkpoint(root) == path
    assert not any(d.endswith(".tmp")
                   for d in os.listdir(os.path.join(root, "checkpoints")))
    # same applied_seq -> same committed checkpoint, not a duplicate
    assert svc.checkpoint() == path
    manifest, arrays = read_checkpoint(path)
    assert manifest["applied_seq"] == svc.applied_seq
    assert manifest["events_logged"] == 8
    # malformed names / manifest-less dirs never shadow a real checkpoint
    os.makedirs(os.path.join(root, "checkpoints", "ckpt-garbage"))
    os.makedirs(os.path.join(root, "checkpoints", "ckpt-999999999999"))
    assert list_checkpoints(root) == [path]

    for ev in events[8:16]:
        svc.submit(ev)
    later = svc.checkpoint(compact=True)
    assert latest_checkpoint(root) == later
    # compaction dropped the covered prefix but kept the log coherent
    assert svc._wal.first_seq == svc.applied_seq + 1


def test_restore_without_checkpoint_replays_genesis(tiny_world, tmp_path):
    events, cfg, params = tiny_world
    root = str(tmp_path)
    svc = _build(cfg, params).enable_wal(root)
    for ev in events[:10]:
        svc.submit(ev)
    seen = svc.applied_seq
    svc2 = FraudService.restore(root)
    assert svc2.last_recovery["checkpoint"] is None
    assert svc2.last_recovery["replayed_records"] == seen
    assert svc2.applied_seq == seen
    assert svc2.engine.ingester.num_events == 10


def test_restore_keeps_logging_so_recoveries_chain(tiny_world, tmp_path):
    """crash -> restore -> crash -> restore composes: the restored service
    appends to the same WAL, so a second recovery sees the full history."""
    events, cfg, params = tiny_world
    root = str(tmp_path)
    svc = _build(cfg, params).enable_wal(root)
    for ev in events[:6]:
        svc.submit(ev)
    svc2 = FraudService.restore(root)
    for ev in events[6:12]:
        svc2.submit(ev)
    svc3 = FraudService.restore(root)
    assert svc3.engine.ingester.num_events == 12
    assert svc3.applied_seq == svc2.applied_seq
    # and the WAL on disk is one continuous validated history
    wal = WriteAheadLog(wal_path(root))
    assert wal.last_seq >= 12
    wal.close()


@pytest.mark.parametrize("backend", ["inline", "process"])
def test_checkpoint_restore_roundtrip_backends(tiny_world, tmp_path, backend):
    """Mid-stream checkpoint → abandon → restore → finish: merged scores
    and KV bytes equal an uninterrupted run, for BOTH worker backends.
    With backend='process' the checkpoint gathers shard state out of the
    worker processes and restore re-seeds a fresh set of them."""
    from faultinject import merge_responses, store_contents

    events, cfg, params = tiny_world

    def build():
        sc = ServiceConfig(
            mode="streaming", model=ModelSection.from_lnn_config(cfg),
        ).replace(engine={"num_workers": 2, "max_batch": 4},
                  workers={"backend": backend})
        return FraudService(sc, params=params).build()

    oracle = build()
    try:
        base = []
        for ev in events:
            base.extend(oracle.submit(ev))
        base.extend(oracle.drain())
        base_scores = merge_responses({}, base)
        base_store = store_contents(oracle.store)
    finally:
        oracle.close()

    root = str(tmp_path / "root")
    svc = build().enable_wal(root)
    delivered = []
    for ev in events[:12]:
        delivered.extend(svc.submit(ev))
    svc.checkpoint()
    for ev in events[12:16]:
        delivered.extend(svc.submit(ev))
    # abandon mid-stream (the crash): no flush, no drain — just release
    # the child processes and the WAL handle the restore will reopen
    svc.engine.pool.shutdown()
    svc._wal.close()

    svc2 = FraudService.restore(root)
    try:
        merged = merge_responses({}, delivered)
        merge_responses(merged, svc2.last_recovery["responses"])
        resume = svc2.engine.ingester.num_events
        assert resume == 16
        rest = []
        for ev in events[resume:]:
            rest.extend(svc2.submit(ev))
        rest.extend(svc2.drain())
        merge_responses(merged, rest)
        assert merged == base_scores, \
            f"{backend}: scores diverged across checkpoint/restore"
        assert store_contents(svc2.store) == base_store, \
            f"{backend}: KV bytes diverged across checkpoint/restore"
    finally:
        svc2.close()


def test_restore_rejects_future_format(tiny_world, tmp_path):
    events, cfg, params = tiny_world
    root = str(tmp_path)
    svc = _build(cfg, params).enable_wal(root)
    svc.submit(events[0])
    path = svc.checkpoint()
    mpath = os.path.join(path, "manifest.json")
    manifest = json.load(open(mpath))
    manifest["format"] = 999
    json.dump(manifest, open(mpath, "w"))
    with pytest.raises(CheckpointError, match="format"):
        FraudService.restore(root)


# ------------------------------------------------------------------- gateway
def test_gateway_checkpoint_route_and_boot(tiny_world, tmp_path):
    import urllib.error
    import urllib.request

    from repro.gateway import serve_gateway

    events, cfg, params = tiny_world
    root = str(tmp_path / "gw")
    sc = ServiceConfig(
        mode="streaming", model=ModelSection.from_lnn_config(cfg),
    ).replace(engine={"num_workers": 1, "max_batch": 4},
              gateway={"checkpoint_dir": root})

    def post(port, path, body):
        req = urllib.request.Request(
            f"http://127.0.0.1:{port}{path}", data=json.dumps(body).encode(),
            headers={"Content-Type": "application/json"})
        try:
            with urllib.request.urlopen(req) as r:
                return r.status, json.loads(r.read())
        except urllib.error.HTTPError as e:
            return e.code, json.loads(e.read())

    gw = serve_gateway(sc, params, warmup=False)
    try:
        assert gw.service.applied_seq == 0          # fresh boot enabled WAL
        for ev in events[:6]:
            post(gw.port, "/v1/score", {"event": {
                "order_id": ev.order_id, "snapshot": ev.snapshot,
                "entities": list(ev.entities),
                "features": ev.features.tolist(),
                "label": ev.label, "arrival": ev.arrival}})
        status, payload = post(gw.port, "/admin/checkpoint", {"compact": True})
        assert status == 200 and payload["compacted"]
        assert payload["applied_seq"] == 6
        assert latest_checkpoint(root) == payload["checkpoint"]
    finally:
        gw.close()   # service object abandoned: the simulated crash

    gw2 = serve_gateway(sc, None, warmup=False)     # reboot -> restore path
    try:
        svc = gw2.service
        assert svc.last_recovery is not None
        assert svc.engine.ingester.num_events == 6
    finally:
        gw2.close()

    # without a checkpoint_dir the route must refuse, not 500
    plain = serve_gateway(sc.replace(gateway={"checkpoint_dir": None}),
                          params, warmup=False)
    try:
        status, payload = post(plain.port, "/admin/checkpoint", {})
        assert status == 409 and "enable_wal" in payload["error"]
    finally:
        plain.close()
