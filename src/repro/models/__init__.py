from repro.models.config import INPUT_SHAPES, ArchConfig, InputShape
from repro.models.transformer import (
    decode_step,
    forward,
    forward_train,
    init_cache,
    init_params,
)

__all__ = [
    "INPUT_SHAPES",
    "ArchConfig",
    "InputShape",
    "decode_step",
    "forward",
    "forward_train",
    "init_cache",
    "init_params",
]
