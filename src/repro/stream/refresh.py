"""Async batch-layer refresh driver — the periodic half of the Lambda loop.

Re-runs LNN stage 1 and pushes **only the dirty** entity-snapshot embeddings
(those whose windows closed since the last run) into the KV store with a
monotonically increasing refresh version.  Correctness hinges on the DDS
invariant: an ``entity_t`` vertex's in-neighborhood is final once snapshot
``t`` closes, so its stage-1 embedding computed from the *partial* stream
equals the one the full batch graph would produce — refreshing incrementally
loses nothing.

Community-local mode (the default): instead of padding and re-running
stage 1 over the **entire accumulated DDS graph** — O(total stream) work per
refresh, the unbounded-stream bottleneck — the driver groups dirty
``(entity, t)`` pairs by their connected component of the order↔entity graph
(``StreamIngester.take_refreshable_by_community``), bin-packs those
components into node budgets of at most ``community_size``, materializes
each bin with ``IncrementalDDSBuilder.build_subgraph``, and runs stage 1 per
bin.  Components are closed under DDS in-neighborhoods at any GNN depth, so
every per-community embedding is **bit-identical** to the whole-graph run
(parity-tested in ``tests/test_refresh_communities.py``); refresh cost
scales with the communities that changed, not with stream length
(``benchmarks/streaming_bench.py::run_refresh_bench`` plots the curve).
Each bin is padded to a power-of-two node budget so the stage-1 jit cache
stays O(log max-community) warm as individual communities grow.

Worker-aware fan-out: when the engine runs a sharded speed layer, the
driver groups each refresh's puts by the router's entity -> worker map and
writes shard by shard (``stats["per_shard_written"]``).  With an
entity-affine store each group touches exactly one KV shard — the write
pattern a real deployment has, where every worker's KV shard is refreshed
by its own feed from the batch layer.  The refresh version is global (one
batch-layer run is one version, however many shards it fans out to), and
within a group writes stay sorted, so the fan-out is deterministic.

Staleness model: an entity key requested as ``(e, t_e)`` but served from an
older stored snapshot ``t' < t_e`` is ``t_e - t'`` snapshots stale (the KV
store tracks this, see ``lookup_batch_versioned``).  Refreshing every
closed window keeps staleness at zero; refreshing every N windows trades
freshness for batch-layer cost — ``benchmarks/streaming_bench.py`` plots
that curve.

``async_mode=True`` runs stage 1 on a single background worker thread (the
batch layer is off the scoring hot path in production); ``drain()`` joins
outstanding work, and completed futures are pruned on every window-close
hook so the in-flight list stays bounded over an unbounded stream.  Tests
use the default synchronous mode.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor

import jax
import numpy as np

from repro.core.graph import pad_graph
from repro.core.lnn import LNNConfig, lnn_stage1
from repro.serve.kvstore import KVStore, pack_key
from repro.stream.ingest import StreamIngester
from repro.utils import crashpoint


def _pow2_at_least(n: int, floor: int = 64) -> int:
    b = floor
    while b < n:
        b *= 2
    return b


class RefreshDriver:
    """The batch layer on a timer: when ingest closes snapshot windows, runs
    stage 1 over the affected (community-local by default) subgraph and
    writes the refreshed entity embeddings to the KV store as versioned,
    model-stamped puts — sharded to match the speed layer's key-affine
    routing."""

    def __init__(
        self,
        params,
        cfg: LNNConfig,
        store: KVStore,
        ingester: StreamIngester,
        max_deg: int = 32,
        refresh_every: int = 1,
        async_mode: bool = False,
        router=None,
        community_local: bool = True,
        community_size: int = 4096,
        stage1_executor=None,
    ):
        self.params = params
        self.cfg = cfg
        self.store = store
        self.ingester = ingester
        self.max_deg = max_deg
        self.refresh_every = max(1, int(refresh_every))
        # anything with worker_of(entity) -> int (stream.workers.ShardRouter);
        # None = single feed, no fan-out grouping
        self.router = router
        self.community_local = bool(community_local)
        self.community_size = max(1, int(community_size))
        self.version = 0
        self.model_version = 0
        # optional off-GIL stage-1 backend:
        # ``executor(padded_graphs, entity_hints, model_version) -> [h]``
        # (the process pool's refresh_bins — each padded bin computes in the
        # shard process owning the bin's first dirty entity).  None = the
        # inline jit below.  Padding, bin-packing, and row gathering stay
        # here either way, so executor outputs are bit-identical by the
        # same argument as scoring (pure fixed-shape compute).
        self.stage1_executor = stage1_executor
        self._stage1 = jax.jit(lambda p, g: lnn_stage1(p, self.cfg, g))
        self._windows_since_refresh = 0
        self._lock = threading.Lock()
        self._pool = ThreadPoolExecutor(max_workers=1) if async_mode else None
        self._inflight = []
        # budget_history holds one int per refresh — the per-refresh
        # padded-node cost curve the scope bench plots.  Bounded: over an
        # unbounded stream only the most recent window of refreshes is
        # kept, so the stats dict can never grow without limit
        self.stats = {"refreshes": 0, "entities_written": 0, "seconds": 0.0,
                      "last_budget": 0, "per_shard_written": {},
                      "nodes_padded": 0, "communities_refreshed": 0,
                      "stage1_launches": 0,
                      "budget_history": deque(maxlen=4096)}

    # --------------------------------------------------------------- hot-swap
    def set_model(self, params, model_version: int) -> None:
        """Swap to a new parameter version: refreshes *started* after this
        call compute with it and stamp their KV puts with it (an async
        refresh already snapshotted keeps the params it captured)."""
        with self._lock:
            self.params = params
            self.model_version = int(model_version)

    def _snapshot_model(self):
        """(params, model_version) as one atomic pair — a concurrent
        ``set_model`` can never mix new params with an old version stamp."""
        with self._lock:
            return self.params, self.model_version

    # ----------------------------------------------------------------- policy
    def on_windows_closed(self, closed_window) -> bool:
        """Called by the engine when event time advances past one or more
        snapshots; ``closed_window`` is the (first, last) closed range.
        Triggers a refresh once ``refresh_every`` windows have closed.
        Returns True if a refresh was started (sync: already finished)."""
        if closed_window is None:
            return False
        first, last = closed_window
        self._windows_since_refresh += last - first + 1
        if self._windows_since_refresh < self.refresh_every:
            return False
        # carry the overshoot: a sparse snapshot jump (+5 windows with
        # refresh_every=2) leaves a remainder of 1, so the NEXT close fires
        # after 1 more window, keeping long-run cadence at refresh_every
        self._windows_since_refresh %= self.refresh_every
        up_to = last
        if self._pool is None:
            self.refresh(up_to)
        else:
            # prune completed futures first — over an unbounded stream the
            # in-flight list must stay bounded between drains
            self._inflight = [f for f in self._inflight if not f.done()]
            # snapshot the ingester state AND the active model on the
            # calling thread (both keep mutating under new events /
            # hot-swaps); only stage 1 + puts go async
            params, model_version = self._snapshot_model()
            pending, work, n_comms = self._snapshot_graph(up_to)
            if pending:
                self._inflight.append(
                    self._pool.submit(self._run, pending, work, n_comms,
                                      params, model_version))
        return True

    def drain(self):
        """Join outstanding async refreshes (replay-end barrier)."""
        for f in self._inflight:
            f.result()
        self._inflight.clear()

    # ------------------------------------------------------------------- work
    def _snapshot_graph(self, up_to_snapshot: int):
        """Drain dirty pairs and materialize the batch-layer input on the
        calling thread (the builder keeps mutating under new events).

        Returns ``(pending, work, n_communities)`` where ``work`` is the
        full accumulated :class:`DDSGraph` (whole-graph mode) or a list of
        ``(subgraph, pairs)`` community bins (community-local mode)."""
        if not self.community_local:
            pending = self.ingester.take_refreshable(up_to_snapshot)
            return pending, (self.ingester.materialize() if pending else None), 0
        groups = self.ingester.take_refreshable_by_community(up_to_snapshot)
        if not groups:
            return [], None, 0
        pending = sorted(p for _, pairs in groups for p in pairs)
        work = [(self.ingester.materialize_communities(cids), pairs)
                for cids, pairs in self._pack_bins(groups)]
        return pending, work, len(groups)

    def _pack_bins(self, groups) -> list:
        """Greedily pack dirty communities (ascending id — deterministic)
        into bins of at most ``community_size`` DDS nodes; a community
        bigger than the budget forms its own bin.  Fewer stage-1 launches
        for many small communities, one pow2-padded launch per bin."""
        bins: list = []
        cur_cids: list = []
        cur_pairs: list = []
        cur_nodes = 0
        for cid, pairs in groups:
            nodes = self.ingester.community_node_count(cid)
            if cur_cids and cur_nodes + nodes > self.community_size:
                bins.append((cur_cids, cur_pairs))
                cur_cids, cur_pairs, cur_nodes = [], [], 0
            cur_cids.append(cid)
            cur_pairs.extend(pairs)
            cur_nodes += nodes
        if cur_cids:
            bins.append((cur_cids, cur_pairs))
        return bins

    def refresh(self, up_to_snapshot: int) -> dict:
        """Run stage 1 over the dirty communities (or the whole accumulated
        graph with ``community_local=False``); write embeddings for the
        dirty (entity, t) pairs with t <= up_to_snapshot, versioned."""
        params, model_version = self._snapshot_model()
        pending, work, n_comms = self._snapshot_graph(up_to_snapshot)
        if not pending:
            return {"entities_written": 0, "seconds": 0.0}
        return self._run(pending, work, n_comms, params, model_version)

    def _shard_groups(self, pending) -> list[tuple[int, list]]:
        """Group dirty (entity, t) pairs by owning speed-layer shard, shard
        order ascending, sorted within each group — the deterministic
        per-shard write feeds of one batch-layer run."""
        if self.router is None:
            return [(0, sorted(pending))]
        groups: dict[int, list] = {}
        for pair in pending:
            groups.setdefault(self.router.worker_of(pair[0]), []).append(pair)
        return [(s, sorted(groups[s])) for s in sorted(groups)]

    def _run_stage1(self, pgs: list, entity_hints: list, params,
                    model_version: int) -> list[np.ndarray]:
        """One stage-1 forward per padded graph: via the executor (shard
        processes, off the serving GIL) when one is attached, else the
        inline jit — identical outputs either way."""
        if self.stage1_executor is not None:
            return self.stage1_executor(pgs, entity_hints, int(model_version))
        return [np.asarray(self._stage1(params, pg)) for pg in pgs]

    def _stage1_embeddings(self, params, model_version, pending,
                           work) -> tuple[dict, int, int]:
        """Run stage 1 over ``work`` and gather the dirty pairs' rows.

        Returns ``({(ent, t): row}, nodes_padded, launches)``.  Each padded
        graph gets a power-of-two node budget so the jit cache holds
        O(log N) shapes over an unbounded stream, not one per refresh.
        Two passes: pad every bin first, then launch them all through
        ``_run_stage1`` — an executor sees the whole refresh at once and
        can overlap the bins across shard processes."""
        emb: dict = {}
        if isinstance(work, list):          # community-local bins
            pgs, hints, total = [], [], 0
            for sub, pairs in work:
                budget = _pow2_at_least(sub.coo.num_nodes)
                pgs.append(pad_graph(sub.coo, num_nodes=budget,
                                     max_deg=self.max_deg))
                # dispatch hint: the bin's first dirty entity — community-
                # local bins land on the shard process owning their entities
                hints.append(pairs[0][0] if pairs else 0)
                total += budget
            hs = self._run_stage1(pgs, hints, params, model_version)
            for h, (sub, pairs) in zip(hs, work):
                for ent, t in pairs:
                    nid = sub.entity_snap_ids.get((ent, t))
                    if nid is not None:
                        emb[(ent, t)] = h[nid]
            return emb, total, len(work)
        dds = work                           # whole-graph path
        budget = _pow2_at_least(dds.coo.num_nodes)
        pg = pad_graph(dds.coo, num_nodes=budget, max_deg=self.max_deg)
        hint = pending[0][0] if pending else 0
        h = self._run_stage1([pg], [hint], params, model_version)[0]
        for ent, t in pending:
            nid = dds.entity_snap_ids.get((ent, t))
            if nid is not None:
                emb[(ent, t)] = h[nid]
        return emb, budget, 1

    def _run(self, pending, work, n_comms: int, params,
             model_version: int) -> dict:
        crashpoint.fire("refresh.before_stage1")
        t0 = time.monotonic()
        emb, nodes_padded, launches = self._stage1_embeddings(
            params, model_version, pending, work)
        groups = self._shard_groups(pending)
        crashpoint.fire("refresh.before_puts")
        with self._lock:
            self.version += 1
            written = 0
            for shard, pairs in groups:
                # one batched put per shard feed: a single store lock
                # acquisition per group instead of one per embedding
                resolved = [(pack_key(ent, t), emb[(ent, t)])
                            for ent, t in pairs if (ent, t) in emb]
                shard_written = self.store.put_batch(
                    [k for k, _ in resolved],
                    (v for _, v in resolved),
                    version=self.version, model_version=model_version,
                ) if resolved else 0
                per = self.stats["per_shard_written"]
                per[shard] = per.get(shard, 0) + shard_written
                written += shard_written
            # stats are read-modify-writes shared with concurrent sync
            # callers — they stay under the same lock as the puts
            dt = time.monotonic() - t0
            self.stats["refreshes"] += 1
            self.stats["entities_written"] += written
            self.stats["seconds"] += dt
            self.stats["last_budget"] = nodes_padded
            self.stats["nodes_padded"] += nodes_padded
            self.stats["communities_refreshed"] += n_comms
            self.stats["stage1_launches"] += launches
            self.stats["budget_history"].append(nodes_padded)
        crashpoint.fire("refresh.after")
        return {"entities_written": written, "seconds": dt, "version": self.version,
                "shards_touched": len(groups), "nodes_padded": nodes_padded,
                "communities": n_comms, "stage1_launches": launches}
