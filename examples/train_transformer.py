"""Train a transformer-zoo architecture on CPU with the sharded train step.

Uses the SAME ``make_train_step`` the 512-chip dry-run lowers, on the
degenerate 1x1 host mesh — demonstrating that the distribution code path is
one codebase from laptop to pod.  Trains a reduced olmo-1b on a synthetic
copy-task (so the loss visibly collapses) for a few hundred steps.

Run:  PYTHONPATH=src python examples/train_transformer.py [--arch olmo-1b]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_train_step
from repro.models import init_params
from repro.models.config import InputShape
from repro.train.checkpoint import save_checkpoint
from repro.train.optim import adamw


def make_copy_batch(rng, cfg, batch, seq):
    """Copy task: second half of the sequence repeats the first half —
    a tiny model can learn it quickly, making training progress visible."""
    half = seq // 2
    first = rng.integers(4, cfg.vocab_size, (batch, half))
    toks = np.concatenate([first, first], axis=1)
    labels = np.full_like(toks, -1)
    labels[:, half:] = toks[:, half:]          # supervise only the copy half
    return {"tokens": jnp.asarray(toks[:, :seq], jnp.int32),
            "labels": jnp.asarray(np.roll(labels, -1, 1)[:, :seq], jnp.int32)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    mesh = make_host_mesh()
    shape = InputShape("copy_train", args.seq, args.batch, "train")
    step_fn, _ = make_train_step(cfg, mesh, shape, use_remat=False, lr=1e-3)

    params = init_params(jax.random.PRNGKey(0), cfg)
    n = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"{cfg.name} (reduced): {n/1e6:.1f}M params, copy-task, "
          f"{args.steps} steps on {jax.default_backend()}")
    init_fn, _ = adamw(1e-3)
    opt = init_fn(params)
    rng = np.random.default_rng(0)

    t_start = time.time()
    with mesh:
        for step in range(args.steps):
            batch = make_copy_batch(rng, cfg, args.batch, args.seq)
            params, opt, aux = step_fn(params, opt, batch)
            if step % 25 == 0 or step == args.steps - 1:
                print(f"step {step:4d}  loss={float(aux['loss']):.4f}  "
                      f"lr={float(aux['lr']):.2e}  "
                      f"({(time.time()-t_start)/(step+1):.2f}s/step)")
    os.makedirs("checkpoints", exist_ok=True)
    save_checkpoint("checkpoints/copy_task.npz", params, step=args.steps)
    final = float(aux["loss"])
    print(f"\nfinal loss {final:.4f} "
          f"({'learned the copy task' if final < 1.0 else 'still descending'})")


if __name__ == "__main__":
    main()
