"""Mamba2 (SSD) block: train/prefill path + O(1)-state decode path.

Follows arXiv:2405.21060: in_proj -> (gate z, conv branch [x|B|C], dt),
depthwise causal conv1d, SSD scan over heads, gated RMSNorm, out_proj.
The SSD scan routes through the chunked XLA path (``ssd_chunked_ref``) or
the Pallas kernel (``kernels.ops.ssd_scan``); decode keeps a
(conv_state, ssm_state) cache — the SSM analogue of a KV cache, except it
is O(1) in sequence length (why long_500k is trivial for SSM archs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ref import ssd_chunked_ref, ssd_scan_ref
from repro.models.common import dense_init, rmsnorm


def mamba_init(rng, cfg):
    dtype = jnp.dtype(cfg.dtype)
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    ks = jax.random.split(rng, 5)
    conv_width = di + 2 * n
    return {
        "w_in": dense_init(ks[0], (d, 2 * di + 2 * n + h), dtype),
        "conv_w": dense_init(ks[1], (cfg.conv_kernel, conv_width), dtype, scale=0.5),
        "conv_b": jnp.zeros((conv_width,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 8.0, h)).astype(jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm_scale": jnp.zeros((di,), dtype),
        "w_out": dense_init(ks[4], (di, d), dtype),
    }


def _split_proj(cfg, proj):
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    conv_in = proj[..., di : 2 * di + 2 * n]
    dt = proj[..., 2 * di + 2 * n :]
    return z, conv_in, dt


def _causal_conv(params, conv_in, conv_state=None):
    """Depthwise causal conv1d.  conv_in: [B, S, W].  Returns (y, new_state)
    where state is the last (K-1) inputs for decode."""
    k = params["conv_w"].shape[0]
    if conv_state is None:
        pad = jnp.zeros((conv_in.shape[0], k - 1, conv_in.shape[2]), conv_in.dtype)
    else:
        pad = conv_state
    xp = jnp.concatenate([pad, conv_in], axis=1)           # [B, S+K-1, W]
    y = sum(
        xp[:, i : i + conv_in.shape[1]] * params["conv_w"][i][None, None, :]
        for i in range(k)
    )
    y = jax.nn.silu((y + params["conv_b"]).astype(jnp.float32)).astype(conv_in.dtype)
    return y, xp[:, -(k - 1) :]


def mamba_apply(params, cfg, x, *, use_pallas=False, return_state=False):
    """Full-sequence path.  x: [B, S, d] -> [B, S, d]."""
    b, s, _ = x.shape
    di, n, h, p = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    proj = x @ params["w_in"]
    z, conv_in, dt = _split_proj(cfg, proj)
    conv_out, conv_state = _causal_conv(params, conv_in)
    xs = conv_out[..., :di].reshape(b, s, h, p)
    bmat = conv_out[..., di : di + n]
    cmat = conv_out[..., di + n :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["a_log"])
    if use_pallas and s % 128 == 0:
        from repro.kernels.ops import ssd_scan

        y = ssd_scan(xs, dt, a, bmat, cmat, params["d_skip"], chunk=128)
    else:
        chunk = cfg.ssd_chunk if s % cfg.ssd_chunk == 0 else (s if s < 64 else 1)
        if s % max(chunk, 1) == 0 and chunk > 1:
            y = ssd_chunked_ref(xs, dt, a, bmat, cmat, params["d_skip"], chunk=chunk,
                                compute_dtype=jnp.dtype(cfg.ssd_compute_dtype))
        else:
            y = ssd_scan_ref(xs, dt, a, bmat, cmat, params["d_skip"])
    y = y.reshape(b, s, di)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                params["norm_scale"])
    out = y @ params["w_out"]
    if not return_state:
        return out, None
    # final ssm state for the decode cache (recompute via sequential scan carry)
    ssm_state = _final_state(xs, dt, a, bmat)
    return out, {"conv": conv_state, "ssm": ssm_state}


def _final_state(xs, dt, a, bmat):
    """Final SSD state [B, H, N, P] after the whole sequence."""
    def step(state, inp):
        xt, dtt, bt = inp
        decay = jnp.exp(dtt * a[None, :])
        upd = jnp.einsum("bn,bhp,bh->bhnp", bt, xt, dtt)
        return state * decay[..., None, None] + upd, None

    b, s, h, p = xs.shape
    n = bmat.shape[-1]
    state0 = jnp.zeros((b, h, n, p), jnp.float32)
    state, _ = jax.lax.scan(
        step, state0,
        (jnp.moveaxis(xs, 1, 0).astype(jnp.float32),
         jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
         jnp.moveaxis(bmat, 1, 0).astype(jnp.float32)),
    )
    return state


def mamba_decode(params, cfg, x1, cache):
    """Single-token step.  x1: [B, 1, d]; cache: {conv [B,K-1,W], ssm [B,H,N,P]}."""
    b = x1.shape[0]
    di, n, h, p = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    proj = x1 @ params["w_in"]                               # [B, 1, ...]
    z, conv_in, dt = _split_proj(cfg, proj)
    conv_out, conv_state = _causal_conv(params, conv_in, cache["conv"])
    xs = conv_out[:, 0, :di].reshape(b, h, p)
    bmat = conv_out[:, 0, di : di + n]
    cmat = conv_out[:, 0, di + n :]
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])  # [B, H]
    a = -jnp.exp(params["a_log"])
    decay = jnp.exp(dt * a[None, :])                         # [B, H]
    ssm = cache["ssm"] * decay[..., None, None] + jnp.einsum(
        "bn,bhp,bh->bhnp", bmat.astype(jnp.float32), xs.astype(jnp.float32), dt
    )
    y = jnp.einsum("bhnp,bn->bhp", ssm, cmat.astype(jnp.float32))
    y = y + xs.astype(jnp.float32) * params["d_skip"][None, :, None]
    y = y.reshape(b, 1, di).astype(x1.dtype)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                params["norm_scale"])
    return y @ params["w_out"], {"conv": conv_state, "ssm": ssm}
