from repro.train.metrics import roc_auc, average_precision, binary_metrics
from repro.train.optim import adamw, cosine_schedule, clip_by_global_norm, OptState
from repro.train.checkpoint import save_checkpoint, load_checkpoint

__all__ = [
    "roc_auc",
    "average_precision",
    "binary_metrics",
    "adamw",
    "cosine_schedule",
    "clip_by_global_norm",
    "OptState",
    "save_checkpoint",
    "load_checkpoint",
]
