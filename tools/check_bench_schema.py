"""Benchmark-record schema gate (the CI ``bench-smoke`` job).

Validates the structure of the emitted ``experiments/BENCH_*.json`` records
so a refactor can't silently drop a metric (schema drift) or ship a
benchmark that crashes only on full runs.  Checks presence and type of
every load-bearing field; numeric fields must be finite numbers.  The
multiworker record's ``parity.bit_identical`` flag is asserted True — the
replay-parity invariant is a gate, not a statistic.

Run:  python tools/check_bench_schema.py [paths...]
Default paths: experiments/BENCH_streaming.json, BENCH_stage2.json,
BENCH_multiworker.json.  Exit 1 with a per-record report on any violation.
"""
from __future__ import annotations

import json
import math
import sys
from pathlib import Path

DEFAULT_RECORDS = [
    "experiments/BENCH_streaming.json",
    "experiments/BENCH_stage2.json",
    "experiments/BENCH_multiworker.json",
    "experiments/BENCH_refresh.json",
    "experiments/BENCH_gateway.json",
    "experiments/BENCH_recovery.json",
    "experiments/BENCH_hetero.json",
    "experiments/BENCH_learning.json",
    "experiments/BENCH_procpool.json",
]

PCTS = ("p50", "p95", "p99")


def _num(x) -> bool:
    return isinstance(x, (int, float)) and not isinstance(x, bool) \
        and math.isfinite(x)


def _require(errors, cond: bool, msg: str) -> None:
    if not cond:
        errors.append(msg)


def check_streaming(d: dict) -> list[str]:
    e: list[str] = []
    _require(e, _num(d.get("n_events")), "n_events: finite number required")
    thr = d.get("throughput")
    _require(e, isinstance(thr, dict) and thr, "throughput: non-empty dict")
    for name, t in (thr or {}).items():
        for k in ("events_per_s", "us_per_event"):
            _require(e, _num(t.get(k)), f"throughput[{name}].{k}: number")
    _require(e, _num(d.get("microbatch_speedup")), "microbatch_speedup: number")
    lat = d.get("latency")
    _require(e, isinstance(lat, dict) and lat, "latency: non-empty dict")
    for name, rec in (lat or {}).items():
        for k in PCTS:
            _require(e, _num(rec.get(k)), f"latency[{name}].{k}: number")
    curve = d.get("staleness_curve")
    _require(e, isinstance(curve, list) and curve, "staleness_curve: non-empty list")
    for i, p in enumerate(curve or []):
        for k in ("refresh_every", "staleness_mean", "stale_frac"):
            _require(e, _num(p.get(k)), f"staleness_curve[{i}].{k}: number")
    pb = d.get("refresh_put_batch") or {}
    for k in ("n", "loop_put_s", "put_batch_s", "speedup"):
        _require(e, _num(pb.get(k)), f"refresh_put_batch.{k}: number")
    return e


def check_stage2(d: dict) -> list[str]:
    e: list[str] = []
    _require(e, isinstance(d.get("config"), dict), "config: dict required")
    per = d.get("per_batch")
    _require(e, isinstance(per, dict) and per, "per_batch: non-empty dict")
    for b, r in (per or {}).items():
        for k in ("unfused_us", "fused_us", "pallas_interpret_us", "speedup",
                  "gflops", "arith_intensity", "v5e_roofline_us"):
            _require(e, _num(r.get(k)), f"per_batch[{b}].{k}: number")
    _require(e, isinstance(d.get("note"), str), "note: string required")
    return e


def check_multiworker(d: dict) -> list[str]:
    e: list[str] = []
    _require(e, _num(d.get("n_events")), "n_events: finite number required")
    cfg = d.get("config") or {}
    for k in ("service_model_s", "steal_threshold", "max_batch"):
        _require(e, _num(cfg.get(k)), f"config.{k}: number")
    sweep = d.get("sweep")
    _require(e, isinstance(sweep, list) and sweep, "sweep: non-empty list")
    for i, p in enumerate(sweep or []):
        for k in ("num_workers", "events_per_s_wall", "mean_latency_ms",
                  "steals", "stolen_requests", "steal_rate",
                  "max_queue_depth", "mean_queue_depth"):
            _require(e, _num(p.get(k)), f"sweep[{i}].{k}: number")
        lat = p.get("latency_ms") or {}
        for k in PCTS:
            _require(e, _num(lat.get(k)), f"sweep[{i}].latency_ms.{k}: number")
        _require(e, isinstance(p.get("per_worker_requests"), list),
                 f"sweep[{i}].per_worker_requests: list")
    par = d.get("parity") or {}
    _require(e, par.get("bit_identical") is True,
             "parity.bit_identical: must be True (replay-parity gate)")
    _require(e, _num(par.get("checked_events")), "parity.checked_events: number")
    return e


def check_refresh(d: dict) -> list[str]:
    e: list[str] = []
    _require(e, _num(d.get("n_events")), "n_events: finite number required")
    cfg = d.get("config") or {}
    for k in ("num_cohorts", "refresh_every", "community_size"):
        _require(e, _num(cfg.get(k)), f"config.{k}: number")
    modes = d.get("modes") or {}
    for name in ("full", "community"):
        m = modes.get(name)
        _require(e, isinstance(m, dict), f"modes.{name}: dict required")
        for k in ("refreshes", "entities_written", "stage1_seconds",
                  "replay_wall_s", "nodes_padded_total", "stage1_launches",
                  "final_refresh_nodes", "growth"):
            _require(e, _num((m or {}).get(k)), f"modes.{name}.{k}: number")
        curve = (m or {}).get("curve")
        _require(e, isinstance(curve, list) and curve,
                 f"modes.{name}.curve: non-empty list")
        for i, p in enumerate(curve or []):
            for k in ("refresh", "padded_nodes"):
                _require(e, _num(p.get(k)), f"modes.{name}.curve[{i}].{k}: number")
    for k in ("nodes_speedup_total", "nodes_speedup_final"):
        _require(e, _num(d.get(k)), f"{k}: number")
    # both invariants are gates, not statistics: community-local refresh
    # must replay bit-identically AND scale sublinearly vs the full path
    par = d.get("parity") or {}
    _require(e, par.get("bit_identical") is True,
             "parity.bit_identical: must be True (refresh-scope exactness gate)")
    _require(e, d.get("sublinear") is True,
             "sublinear: must be True (community-local cost must not track "
             "stream length)")
    return e


def check_gateway(d: dict) -> list[str]:
    e: list[str] = []
    _require(e, _num(d.get("n_events")), "n_events: finite number required")
    cfg = d.get("config") or {}
    for k in ("num_clients", "nominal_rate", "overload_rate"):
        _require(e, _num(cfg.get(k)), f"config.{k}: number")
    scen = d.get("scenarios") or {}
    for name in ("nominal", "shed", "block"):
        s = scen.get(name)
        _require(e, isinstance(s, dict), f"scenarios.{name}: dict required")
        for k in ("sent", "wall_s", "throughput_eps", "ok",
                  "rejected_429", "rejected_503"):
            _require(e, _num((s or {}).get(k)), f"scenarios.{name}.{k}: number")
        lat = (s or {}).get("latency_ms") or {}
        for k in PCTS:
            _require(e, _num(lat.get(k)), f"scenarios.{name}.latency_ms.{k}: number")
    _require(e, _num((scen.get("shed") or {}).get("shed_rate")),
             "scenarios.shed.shed_rate: number")
    can = d.get("canary") or {}
    for k in ("sampled", "alerts", "divergence_max"):
        _require(e, _num(can.get(k)), f"canary.{k}: number")
    # backpressure must reach the socket, and the perturbed canary must
    # alert in the scraped /metrics — gates, not statistics
    gates = d.get("gates") or {}
    for k in ("shed_maps_to_429", "block_maps_to_503", "divergence_alert"):
        _require(e, gates.get(k) is True,
                 f"gates.{k}: must be True (socket-level backpressure / "
                 "canary-alert gate)")
    return e


def check_recovery(d: dict) -> list[str]:
    e: list[str] = []
    _require(e, _num(d.get("n_events")), "n_events: finite number required")
    cfg = d.get("config") or {}
    for k in ("num_workers", "max_batch", "checkpoint_at"):
        _require(e, _num(cfg.get(k)), f"config.{k}: number")
    ck = d.get("checkpoint") or {}
    for k in ("write_s", "size_bytes", "applied_seq"):
        _require(e, _num(ck.get(k)), f"checkpoint.{k}: number")
    curve = d.get("replay_curve")
    _require(e, isinstance(curve, list) and curve,
             "replay_curve: non-empty list")
    for i, p in enumerate(curve or []):
        for k in ("events_fed", "log_records", "replayed_records",
                  "restore_s"):
            _require(e, _num(p.get(k)), f"replay_curve[{i}].{k}: number")
    rs = d.get("restore") or {}
    for k in ("with_checkpoint_s", "genesis_s", "replayed_with_checkpoint",
              "replayed_genesis"):
        _require(e, _num(rs.get(k)), f"restore.{k}: number")
    # crash-restore-replay must reproduce the uninterrupted run bit-for-bit
    # — the whole point of the subsystem is a gate, not a statistic
    gates = d.get("gates") or {}
    _require(e, gates.get("recovery_bit_identical") is True,
             "gates.recovery_bit_identical: must be True "
             "(crash-recovery exactness gate)")
    return e


def check_hetero(d: dict) -> list[str]:
    e: list[str] = []
    _require(e, _num(d.get("n_events")), "n_events: finite number required")
    cfg = d.get("config") or {}
    for k in ("num_buyers", "num_merchants", "num_rings", "num_bursts",
              "num_bin_runs", "num_snapshots", "hidden_dim", "gbdt_trees",
              "train_frac"):
        _require(e, _num(cfg.get(k)), f"config.{k}: number")
    _require(e, isinstance(cfg.get("entity_types"), list) and cfg.get("entity_types"),
             "config.entity_types: non-empty list")
    att = d.get("attacks") or {}
    for k in ("ring", "burst", "bin_test", "legit"):
        _require(e, _num(att.get(k)), f"attacks.{k}: number")
    for k in ("test_events", "test_fraud"):
        _require(e, _num(d.get(k)), f"{k}: number")
    recall = d.get("recall")
    _require(e, isinstance(recall, dict) and recall, "recall: non-empty dict")
    for model, budgets in (recall or {}).items():
        _require(e, isinstance(budgets, dict) and budgets,
                 f"recall[{model}]: non-empty dict")
        for b, per_attack in (budgets or {}).items():
            # the per-attack recall curve is the whole point of the named
            # workload — every attack pattern must appear at every budget
            for k in ("ring", "burst", "bin_test"):
                _require(e, _num((per_attack or {}).get(k)),
                         f"recall[{model}][{b}].{k}: number")
    auc = d.get("auc") or {}
    for model in ("mlp_raw", "gbdt_raw", "hybrid"):
        _require(e, _num(auc.get(model)), f"auc.{model}: number")
    # the hybrid head must exploit the typed linkage the raw-feature MLP
    # can't see, and typed replay must stay deterministic — gates, not stats
    gates = d.get("gates") or {}
    _require(e, gates.get("hybrid_beats_mlp_on_rings") is True,
             "gates.hybrid_beats_mlp_on_rings: must be True "
             "(hybrid ring-recall gate)")
    _require(e, gates.get("typed_replay_parity") is True,
             "gates.typed_replay_parity: must be True "
             "(typed replay-parity gate)")
    return e


def check_learning(d: dict) -> list[str]:
    e: list[str] = []
    _require(e, _num(d.get("n_events")), "n_events: finite number required")
    for k in ("split", "budget", "min_lift"):
        _require(e, _num(d.get(k)), f"{k}: number")
    cfg = d.get("config") or {}
    for k in ("steps", "min_window", "max_window", "stride", "min_eval",
              "promote_margin"):
        _require(e, _num(cfg.get(k)), f"config.{k}: number")
    for k in ("frozen_ring_recall", "recovered_ring_recall"):
        _require(e, _num(d.get(k)), f"{k}: number")
    curve = d.get("recall_curve")
    _require(e, isinstance(curve, list) and curve,
             "recall_curve: non-empty list")
    for i, p in enumerate(curve or []):
        for k in ("start", "n"):
            _require(e, _num(p.get(k)), f"recall_curve[{i}].{k}: number")
        _require(e, p.get("phase") in ("A", "B"),
                 f"recall_curve[{i}].phase: 'A' or 'B'")
        _require(e, isinstance(p.get("model_versions"), list),
                 f"recall_curve[{i}].model_versions: list")
    proms = d.get("promotions")
    _require(e, isinstance(proms, list) and proms,
             "promotions: non-empty list (the loop must actually promote)")
    for i, p in enumerate(proms or []):
        for k in ("event_index", "candidate", "incumbent",
                  "candidate_recall", "incumbent_recall", "n_eval"):
            _require(e, _num(p.get(k)), f"promotions[{i}].{k}: number")
    reg = d.get("regression") or {}
    for k in ("bad_version", "restored_version"):
        _require(e, _num(reg.get(k)), f"regression.{k}: number")
    # the two closed-loop invariants are gates, not statistics: a post-drift
    # fine-tune must recover ring recall, and the promotion that shipped it
    # must have been shadow-gated with the injected regression rolled back
    gates = d.get("gates") or {}
    _require(e, gates.get("finetuned_recovers_recall") is True,
             "gates.finetuned_recovers_recall: must be True "
             "(drift-recovery gate)")
    _require(e, gates.get("promotion_shadow_gated") is True,
             "gates.promotion_shadow_gated: must be True "
             "(shadow-gated promotion / auto-rollback gate)")
    return e


def check_procpool(d: dict) -> list[str]:
    e: list[str] = []
    _require(e, _num(d.get("n_events")), "n_events: finite number required")
    par = d.get("parity") or {}
    _require(e, _num(par.get("checked_events")), "parity.checked_events: number")
    _require(e, _num(par.get("hot_swap_at")), "parity.hot_swap_at: number")
    for n in ("1", "4"):
        rec = par.get(n) or {}
        for k in ("scores_identical", "kv_identical", "counters_identical"):
            _require(e, isinstance(rec.get(k), bool),
                     f"parity[{n}].{k}: bool required")
        for k in ("orders", "kv_entries"):
            _require(e, _num(rec.get(k)), f"parity[{n}].{k}: number")
    sc = d.get("scaling") or {}
    sweep = sc.get("sweep")
    _require(e, isinstance(sweep, list) and len(sweep) == 2,
             "scaling.sweep: list of the N=1 and N=4 runs")
    for i, p in enumerate(sweep or []):
        for k in ("num_workers", "wall_s", "events_per_s"):
            _require(e, _num(p.get(k)), f"scaling.sweep[{i}].{k}: number")
    for k in ("speedup_4v1", "cores"):
        _require(e, _num(sc.get(k)), f"scaling.{k}: number")
    _require(e, isinstance(sc.get("limited_by_cores"), bool),
             "scaling.limited_by_cores: bool required")
    # the two process-plane invariants are gates, not statistics: the
    # process backend must replay bit-identically to inline, and four
    # shard processes must actually buy >= 2x where the host has cores
    # to run them (limited_by_cores records when that is unmeasurable)
    gates = d.get("gates") or {}
    _require(e, gates.get("process_parity_bit_identical") is True,
             "gates.process_parity_bit_identical: must be True "
             "(process-vs-inline replay-parity gate)")
    _require(e, gates.get("throughput_scales_with_n") is True,
             "gates.throughput_scales_with_n: must be True "
             "(N=4 >= 2x N=1 scaling gate)")
    return e


CHECKERS = {
    "BENCH_streaming.json": check_streaming,
    "BENCH_stage2.json": check_stage2,
    "BENCH_multiworker.json": check_multiworker,
    "BENCH_refresh.json": check_refresh,
    "BENCH_gateway.json": check_gateway,
    "BENCH_recovery.json": check_recovery,
    "BENCH_hetero.json": check_hetero,
    "BENCH_learning.json": check_learning,
    "BENCH_procpool.json": check_procpool,
}


def main(argv: list[str]) -> int:
    paths = argv or DEFAULT_RECORDS
    failed = False
    for rel in paths:
        # resolve against CWD, like the benches that write the records —
        # the gate must inspect what the run just produced, never a stale
        # copy at some other root
        path = Path(rel)
        checker = CHECKERS.get(path.name)
        if checker is None:
            print(f"FAIL {rel}: no schema registered for {path.name}")
            failed = True
            continue
        if not path.exists():
            print(f"FAIL {rel}: record missing (bench did not emit it)")
            failed = True
            continue
        try:
            record = json.loads(path.read_text())
        except json.JSONDecodeError as exc:
            print(f"FAIL {rel}: invalid JSON ({exc})")
            failed = True
            continue
        errors = checker(record)
        for err in errors:
            print(f"FAIL {rel}: {err}")
        failed |= bool(errors)
    if failed:
        return 1
    print(f"bench schema OK ({len(paths)} records)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
