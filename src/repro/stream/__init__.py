"""repro.stream — real-time streaming ingestion + micro-batched speed-layer
serving engine (the closed Lambda loop).  See docs/streaming.md."""
from repro.stream.engine import EngineConfig, ReplayReport, StreamingEngine
from repro.stream.events import CheckoutEvent, events_from_static, order_event_tuples
from repro.stream.ingest import IngestResult, StreamIngester
from repro.stream.microbatch import MicroBatcher, ScoredResult, ScoreRequest
from repro.stream.refresh import RefreshDriver

__all__ = [
    "CheckoutEvent",
    "EngineConfig",
    "IngestResult",
    "MicroBatcher",
    "RefreshDriver",
    "ReplayReport",
    "ScoreRequest",
    "ScoredResult",
    "StreamIngester",
    "StreamingEngine",
    "events_from_static",
    "order_event_tuples",
]
