"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret=True)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.csr_spmm import csr_spmm_pallas
from repro.kernels.edge_softmax import edge_softmax_agg_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.gqa_decode import gqa_decode_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas

RNG = np.random.default_rng(42)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,deg,h", [(64, 4, 32), (200, 12, 96), (257, 7, 130), (128, 24, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_csr_spmm(n, deg, h, dtype):
    x = jnp.asarray(RNG.normal(size=(n, h)), dtype)
    idx = jnp.asarray(RNG.integers(0, n, (n, deg)), jnp.int32)
    w = jnp.asarray(RNG.uniform(0, 1, (n, deg)) * (RNG.uniform(size=(n, deg)) < 0.7),
                    jnp.float32)
    out = csr_spmm_pallas(x, idx, w, interpret=True)
    ref = ops.csr_spmm_ref(x, idx, w)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref, np.float32),
                               **_tol(dtype))


@pytest.mark.parametrize("n,deg,h", [(64, 6, 32), (150, 16, 64), (96, 3, 128)])
def test_edge_softmax(n, deg, h):
    z = jnp.asarray(RNG.normal(size=(n, h)), jnp.float32)
    ss = jnp.asarray(RNG.normal(size=n), jnp.float32)
    sd = jnp.asarray(RNG.normal(size=n), jnp.float32)
    idx = jnp.asarray(RNG.integers(0, n, (n, deg)), jnp.int32)
    mask = jnp.asarray((RNG.uniform(size=(n, deg)) < 0.6).astype(np.float32))
    bias = jnp.asarray(RNG.normal(size=(n, deg)) * 0.1, jnp.float32)
    out = edge_softmax_agg_pallas(z, ss, sd, idx, mask, bias, interpret=True)
    ref = ops.edge_softmax_agg_ref(z, ss, sd, idx, mask, bias)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2), (6, 1)])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 96), (False, None)])
def test_flash_attention(hq, hkv, causal, window):
    b, s, dh = 2, 256, 64
    q = jnp.asarray(RNG.normal(size=(b, hq, s, dh)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, hkv, s, dh)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, hkv, s, dh)), jnp.float32)
    out = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 block_q=64, block_k=64, interpret=True)
    ref = ops.mha_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    b, hq, hkv, s, dh = 1, 4, 2, 128, 64
    q = jnp.asarray(RNG.normal(size=(b, hq, s, dh)), dtype)
    k = jnp.asarray(RNG.normal(size=(b, hkv, s, dh)), dtype)
    v = jnp.asarray(RNG.normal(size=(b, hkv, s, dh)), dtype)
    out = flash_attention_pallas(q, k, v, block_q=64, block_k=64, interpret=True)
    ref = ops.mha_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref, np.float32),
                               **_tol(dtype))


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("hq,hkv,s", [(8, 2, 640), (4, 4, 256), (16, 2, 1024)])
@pytest.mark.parametrize("window", [None, 128])
def test_gqa_decode(hq, hkv, s, window):
    b, dh = 3, 64
    q = jnp.asarray(RNG.normal(size=(b, hq, dh)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, hkv, s, dh)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, hkv, s, dh)), jnp.float32)
    kl = jnp.asarray(RNG.integers(1, s + 1, b), jnp.int32)
    out = gqa_decode_pallas(q, k, v, kv_len=kl, window=window, block_k=128,
                            interpret=True)
    ref = ops.gqa_decode_ref(q, k, v, kv_len=kl, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("s,chunk", [(128, 64), (256, 128), (512, 128)])
@pytest.mark.parametrize("h,p,n", [(4, 64, 32), (2, 32, 64)])
def test_ssd_scan(s, chunk, h, p, n):
    b = 2
    x = jnp.asarray(RNG.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (b, s, h)), jnp.float32)
    a = jnp.asarray(-RNG.uniform(0.5, 2.0, h), jnp.float32)
    bb = jnp.asarray(RNG.normal(size=(b, s, n)), jnp.float32)
    cc = jnp.asarray(RNG.normal(size=(b, s, n)), jnp.float32)
    dd = jnp.asarray(RNG.normal(size=h), jnp.float32)
    out = ssd_scan_pallas(x, dt, a, bb, cc, dd, chunk=chunk, interpret=True)
    ref = ops.ssd_scan_ref(x, dt, a, bb, cc, dd)
    scale = float(jnp.abs(ref).max())
    np.testing.assert_allclose(np.asarray(out) / scale, np.asarray(ref) / scale,
                               atol=3e-5)


def test_ssd_chunked_ref_matches_sequential():
    b, s, h, p, n = 2, 192, 3, 16, 24
    x = jnp.asarray(RNG.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.3, (b, s, h)), jnp.float32)
    a = jnp.asarray(-RNG.uniform(0.2, 3.0, h), jnp.float32)
    bb = jnp.asarray(RNG.normal(size=(b, s, n)), jnp.float32)
    cc = jnp.asarray(RNG.normal(size=(b, s, n)), jnp.float32)
    out = ops.ssd_chunked_ref(x, dt, a, bb, cc, chunk=64)
    ref = ops.ssd_scan_ref(x, dt, a, bb, cc)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=1e-3)
