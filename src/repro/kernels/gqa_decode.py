"""Pallas TPU kernel: single-token GQA decode attention (flash-decoding).

The speed-layer analogue of the paper's lambda split: the KV cache is the
precomputed batch artifact, the kernel performs the per-request online step.

    out[b, hq, :] = softmax(q[b, hq] · K[b, kv(hq)] / sqrt(D)) @ V[b, kv(hq)]

Grid = (batch, kv_heads, kv_tiles); the kv dimension is innermost and
sequential, carrying running max / denom / accumulator per q-head-group in
VMEM scratch (classic flash-decoding).  All q heads sharing one kv head are
processed together as a [rep, Dh] block so the kv tile is streamed once —
the GQA bandwidth saving is structural, not a copy.

``kv_len`` masks the ragged cache tail; ``window`` implements sliding-window
decode (only the last ``window`` valid positions attend) for SWA archs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

from repro.utils.padding import ceil_div

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, out_ref, m_ref, l_ref, acc_ref,
                   *, scale, bk, window):
    j = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                      # [rep, Dh] — q heads of this kv group
    k = k_ref[0, 0]                      # [bk, Dh]
    v = v_ref[0, 0]                      # [bk, Dh]
    kv_len = len_ref[0]

    logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [rep, bk]
    pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    valid = pos < kv_len
    if window is not None:
        valid &= pos >= kv_len - window
    logits = jnp.where(valid, logits, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1))
    p = jnp.exp(logits - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        out_ref[0, 0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "block_k", "interpret"))
def gqa_decode_pallas(q, k, v, kv_len=None, window: int | None = None,
                      block_k: int = 512, interpret: bool = True):
    b, hq, dh = q.shape
    hkv, s = k.shape[1], k.shape[2]
    rep = hq // hkv
    bk = min(block_k, s)
    if kv_len is None:
        kv_len = jnp.full((b,), s, jnp.int32)
    q4 = q.reshape(b, hkv, rep, dh)
    grid = (b, hkv, ceil_div(s, bk))
    scale = dh ** -0.5

    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, bk=bk, window=window),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b_, h, j: (b_,)),
            pl.BlockSpec((1, 1, rep, dh), lambda b_, h, j: (b_, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, dh), lambda b_, h, j: (b_, h, j, 0)),
            pl.BlockSpec((1, 1, bk, dh), lambda b_, h, j: (b_, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, dh), lambda b_, h, j: (b_, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, rep, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((rep,), jnp.float32),
            pltpu.VMEM((rep,), jnp.float32),
            pltpu.VMEM((rep, dh), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(kv_len.astype(jnp.int32), q4, k, v)
    return out.reshape(b, hq, dh)
