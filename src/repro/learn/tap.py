"""WAL training tap — committed log suffixes as a training-data stream.

The write-ahead log (PR 7) already records every scored checkout in
arrival order, bit-exactly.  That makes it the one place training data
can come from without a second ingestion path: the tap re-reads committed
``submit``/``ingest`` records, reconstructs each order's **receptive
cone** with its own :class:`~repro.core.dds.IncrementalDDSBuilder`
(mirroring the serving ingest exactly: ``entity_keys`` is computed
*before* ``add_order``, so the cone is strictly past), and emits
:class:`TrainingExample` rows.

**Delayed-label join.**  Fraud outcomes arrive hours after checkout
(chargebacks, manual review).  :class:`LabelLog` is the authoritative
outcome store keyed by order id; the tap holds each example *pending*
until either its label lands in the log or its ``label_latency_s`` window
expires, at which point the example is finalized with the event-time
label (the generator's ground truth in this repo; a weak/heuristic label
in production).  ``label_latency_s=0`` short-circuits the join: event
labels are final at ingest.

**Compaction interlock.**  The tap holds a :meth:`WriteAheadLog.pin` at
its scan cursor, so a concurrent ``compact()`` (e.g. the scheduled
checkpointer) can never delete records the tap has not consumed yet —
the pin clamps the truncation point
(``tests/test_learn.py::test_compact_respects_pins``).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import numpy as np

from repro.core.dds import IncrementalDDSBuilder
from repro.stream.checkpoint import WriteAheadLog, decode_event

__all__ = ["LabelLog", "TrainingExample", "WalTrainingTap"]


@dataclass(frozen=True)
class TrainingExample:
    """One labeled checkout, ready for a rolling-window fine-tune.

    ``entity_keys`` is the strictly-past receptive cone — the same
    ``(entity, snapshot)`` KV keys the speed layer would have fetched for
    this order, reconstructed from the tap's own builder state at the
    moment the record was read.  ``label`` is the *joined* outcome (see
    :class:`LabelLog`); ``label_source`` records where it came from
    (``"event"`` or ``"label_log"``).
    """

    order_id: int               # source order id (-1 for live traffic)
    snapshot: int               # event-time snapshot
    entities: tuple             # linked (possibly type-tagged) entity ids
    features: np.ndarray        # [F] raw checkout features
    label: float                # joined outcome
    arrival: float              # virtual arrival time, seconds
    seq: int                    # WAL seqno of the source record
    entity_keys: tuple = ()     # strictly-past ((entity, t), ...) cone
    label_source: str = "event"


class LabelLog:
    """Authoritative delayed-outcome store, keyed by order id.

    ``record`` registers a confirmed outcome (chargeback, manual-review
    verdict); the tap consults :meth:`get` when an example's label-latency
    window closes.  Later records for the same order overwrite earlier
    ones — the freshest verdict wins.
    """

    def __init__(self):
        self._labels: dict[int, float] = {}
        self.recorded = 0

    def record(self, order_id: int, label: float) -> None:
        """Register the confirmed outcome for ``order_id``."""
        self._labels[int(order_id)] = float(label)
        self.recorded += 1

    def get(self, order_id: int) -> float | None:
        """The recorded outcome, or None if no verdict has landed."""
        return self._labels.get(int(order_id))

    def __len__(self) -> int:
        return len(self._labels)


@dataclass
class _Pending:
    example: TrainingExample = None  # label still provisional
    deadline: float = 0.0            # arrival + label_latency_s


class WalTrainingTap:
    """Incremental reader: WAL records → labeled :class:`TrainingExample` s.

    ``poll(now)`` consumes every committed record past the cursor, feeds
    the internal DDS builder (receptive-cone reconstruction), and returns
    the examples whose labels are *final* — immediately when
    ``label_latency_s == 0``, otherwise once the label-log join resolves
    or the latency window expires.  ``now`` defaults to the latest arrival
    seen, so virtual-time streams drive the join without a wall clock.

    The tap pins the WAL at its cursor for its whole lifetime; call
    :meth:`close` (or use as a context manager) to release the pin and let
    compaction advance past consumed records.
    """

    def __init__(self, wal: WriteAheadLog, feat_dim: int, *,
                 label_log: LabelLog | None = None,
                 label_latency_s: float = 0.0,
                 include_ingest: bool = True,
                 entity_history: str = "all",
                 max_history: int | None = None,
                 start_after_seq: int = 0):
        if label_latency_s < 0:
            raise ValueError("label_latency_s must be >= 0")
        self.wal = wal
        self.label_log = label_log if label_log is not None else LabelLog()
        self.label_latency_s = float(label_latency_s)
        self.include_ingest = bool(include_ingest)
        self.builder = IncrementalDDSBuilder(
            feat_dim=int(feat_dim), entity_history=entity_history,
            max_history=max_history)
        self._cursor = int(start_after_seq)
        self._pin = wal.pin(self._cursor)
        self._pending: list[_Pending] = []   # arrival order
        self._now = 0.0
        self.stats = {"records": 0, "skipped": 0, "examples": 0,
                      "label_joins": 0, "label_defaults": 0}

    # ------------------------------------------------------------------ poll
    @property
    def cursor(self) -> int:
        """Last WAL seqno consumed (the pin sits here)."""
        return self._cursor

    @property
    def pending(self) -> int:
        """Examples read but still awaiting their label-latency window."""
        return len(self._pending)

    def poll(self, now: float | None = None) -> list[TrainingExample]:
        """Consume new WAL records; return label-final examples in order."""
        for rec in self.wal.scan(after_seq=self._cursor):
            self._cursor = int(rec["seq"])
            self.stats["records"] += 1
            kind = rec.get("kind")
            if kind == "submit" or (kind == "ingest" and self.include_ingest):
                ev = decode_event(rec)
                self._now = max(self._now, float(ev.arrival))
                # mirror StreamIngester.ingest: cone BEFORE add_order,
                # so the keys are strictly past (no self-leak)
                keys = self.builder.entity_keys(ev.entities, ev.snapshot)
                self.builder.add_order(
                    ev.entities, ev.snapshot, ev.features, ev.label)
                ex = TrainingExample(
                    order_id=int(ev.order_id), snapshot=int(ev.snapshot),
                    entities=tuple(ev.entities), features=ev.features,
                    label=float(ev.label), arrival=float(ev.arrival),
                    seq=int(rec["seq"]), entity_keys=tuple(keys))
                self._pending.append(_Pending(
                    example=ex, deadline=ex.arrival + self.label_latency_s))
            else:
                self.stats["skipped"] += 1
        self.wal.move_pin(self._pin, self._cursor)
        return self._resolve(self._now if now is None else float(now))

    def _resolve(self, now: float) -> list[TrainingExample]:
        """Finalize pending examples: joined label beats the event label;
        a pending example is released early the moment its verdict lands,
        or at window expiry with the event-time label as fallback."""
        out, still = [], []
        for p in self._pending:
            ex = p.example
            verdict = self.label_log.get(ex.order_id)
            if verdict is not None:
                out.append(dataclasses.replace(
                    ex, label=float(verdict), label_source="label_log"))
                self.stats["label_joins"] += 1
            elif now >= p.deadline:
                out.append(ex)          # event label stands
                self.stats["label_defaults"] += 1
            else:
                still.append(p)
        self._pending = still
        self.stats["examples"] += len(out)
        return out

    # ----------------------------------------------------------------- close
    def close(self) -> None:
        """Release the compaction pin (idempotent)."""
        self.wal.unpin(self._pin)

    def __enter__(self) -> "WalTrainingTap":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
