from repro.utils.padding import pad_to_multiple, pad_axis_to, ceil_div
from repro.utils.tree import tree_size, tree_bytes, tree_zeros_like, tree_map_with_path

__all__ = [
    "pad_to_multiple",
    "pad_axis_to",
    "ceil_div",
    "tree_size",
    "tree_bytes",
    "tree_zeros_like",
    "tree_map_with_path",
]
