"""MLP baseline (paper Table 3 row 1) on the GBDT-encoded checkout features."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.train.optim import adamw


@dataclass(frozen=True)
class MLPConfig:
    hidden_dims: tuple = (64, 32)
    lr: float = 1e-3
    epochs: int = 200
    batch_size: int = 512
    pos_weight: float = 1.0
    patience: int = 20
    seed: int = 0


def mlp_init(rng, in_dim: int, cfg: MLPConfig):
    dims = (in_dim,) + tuple(cfg.hidden_dims) + (1,)
    keys = jax.random.split(rng, len(dims))
    params = []
    for i in range(len(dims) - 1):
        scale = jnp.sqrt(2.0 / dims[i])
        params.append(
            {
                "w": scale * jax.random.normal(keys[i], (dims[i], dims[i + 1])),
                "b": jnp.zeros((dims[i + 1],)),
            }
        )
    return params


def mlp_forward(params, x):
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i + 1 < len(params):
            x = jax.nn.relu(x)
    return x[..., 0]


def _bce(params, x, y, pos_weight):
    logits = mlp_forward(params, x)
    logp = jax.nn.log_sigmoid(logits)
    lognp = jax.nn.log_sigmoid(-logits)
    return -(pos_weight * y * logp + (1 - y) * lognp).mean()


def train_mlp(
    x: np.ndarray,
    y: np.ndarray,
    x_val: np.ndarray,
    y_val: np.ndarray,
    cfg: MLPConfig = MLPConfig(),
):
    """Mini-batch AdamW training with early stopping on val loss."""
    rng = jax.random.PRNGKey(cfg.seed)
    params = mlp_init(rng, x.shape[1], cfg)
    init_fn, update_fn = adamw(cfg.lr, weight_decay=1e-4)
    state = init_fn(params)

    @jax.jit
    def step(params, state, xb, yb):
        loss, grads = jax.value_and_grad(_bce)(params, xb, yb, cfg.pos_weight)
        params, state, aux = update_fn(grads, state, params)
        return params, state, loss

    val_loss_fn = jax.jit(lambda p: _bce(p, x_val, y_val, cfg.pos_weight))

    n = x.shape[0]
    best_val, best_params, stall = np.inf, params, 0
    perm_rng = np.random.default_rng(cfg.seed)
    for _ in range(cfg.epochs):
        perm = perm_rng.permutation(n)
        for i in range(0, n, cfg.batch_size):
            sl = perm[i : i + cfg.batch_size]
            params, state, _ = step(params, state, x[sl], y[sl])
        vl = float(val_loss_fn(params))
        if vl < best_val - 1e-6:
            best_val, best_params, stall = vl, params, 0
        else:
            stall += 1
            if stall >= cfg.patience:
                break
    return best_params


def predict_mlp(params, x: np.ndarray) -> np.ndarray:
    return np.asarray(jax.nn.sigmoid(mlp_forward(params, jnp.asarray(x))))
