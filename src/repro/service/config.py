"""``ServiceConfig`` — one serializable config tree for every serving path.

Before ``repro.service`` existed, each entry point re-assembled
``params + LNNConfig + EngineConfig + KVStore kwargs`` by hand: the batch
pipeline took (cfg, k_max, store), the streaming engine took (cfg,
EngineConfig, store), and every benchmark wired its own variant.
``ServiceConfig`` subsumes all of them in seven sections:

* :class:`ModelSection`     — the LNN itself (mirrors ``LNNConfig``);
* :class:`EngineSection`    — speed-layer scheduling: micro-batch triggers,
  worker count, virtual service model, DDS ingest knobs;
* :class:`StoreSection`     — KV store: capacity / TTL / sharding;
* :class:`RefreshSection`   — batch-layer cadence and threading;
* :class:`AdmissionSection` — overload policy: queue-depth / in-flight caps
  with shed-vs-block and a bounded block wait;
* :class:`GatewaySection`   — the HTTP front-end (``repro.gateway``): bind
  address, body limits, 429 Retry-After hint, canary/shadow defaults,
  scheduled-checkpoint cadence, canary auto-rollback;
* :class:`LearnSection`     — the continuous-learning plane
  (``repro.learn``): WAL-tap label join, rolling-window trainer, and
  shadow-gated promotion knobs.

The tree round-trips through ``to_dict``/``from_dict`` and JSON
(``to_json``/``from_json``, ``save``/``load``), with **unknown-key
rejection** at every level — a typo'd artifact fails loudly at load time,
never as a silently-defaulted knob.  One JSON artifact is enough to rebuild
the exact service anywhere (params travel separately as a checkpoint).
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field

from repro.core.lnn import LNNConfig


def _section_from_dict(cls, d: dict, path: str):
    """Build a section dataclass from a plain dict, rejecting unknown keys
    (``path`` names the offending subtree in the error)."""
    if not isinstance(d, dict):
        raise TypeError(f"{path}: expected a dict, got {type(d).__name__}")
    names = {f.name for f in dataclasses.fields(cls)}
    unknown = sorted(set(d) - names)
    if unknown:
        raise ValueError(
            f"unknown key(s) {unknown} in {path} — valid keys: {sorted(names)}"
        )
    return cls(**d)


@dataclass(frozen=True)
class ModelSection:
    """The LNN model — field-for-field mirror of ``core.lnn.LNNConfig`` so
    a service artifact fully determines the architecture."""

    gnn_type: str = "gcn"            # 'gcn' | 'gat' | 'sage'
    num_gnn_layers: int = 3
    hidden_dim: int = 64
    mlp_dims: tuple = (64, 32)
    feat_dim: int = 16
    use_pallas: bool = False
    pos_weight: float = 1.0
    # heterogeneous vocabulary (e.g. core.hetero.ENTITY_TYPE_NAMES); empty =
    # homogeneous model, no per-type towers, untagged entity ids accepted
    entity_types: tuple = ()

    def __post_init__(self):
        # JSON round-trips tuples as lists; normalize back
        object.__setattr__(self, "mlp_dims", tuple(self.mlp_dims))
        object.__setattr__(self, "entity_types",
                           tuple(str(t) for t in self.entity_types))

    def to_lnn_config(self) -> LNNConfig:
        return LNNConfig(**dataclasses.asdict(self))

    @classmethod
    def from_lnn_config(cls, cfg: LNNConfig) -> "ModelSection":
        return cls(**dataclasses.asdict(cfg))


@dataclass(frozen=True)
class EngineSection:
    """Speed-layer scheduling + ingest knobs (the old ``EngineConfig``)."""

    k_max: int = 8                  # entity slots per request
    max_batch: int = 16             # micro-batch size trigger (per worker)
    max_wait_s: float = 0.005       # micro-batch deadline trigger (virtual s)
    entity_history: str = "all"     # DDS history mode (see core.dds)
    max_history: int | None = 8
    max_deg: int = 32               # padded in-degree for the batch graph
    num_workers: int = 1            # sharded micro-batch queues (1 = classic)
    service_model_s: float = 0.0    # virtual service time per flush
    steal_threshold: int | None = None   # queue depth that triggers stealing

    def __post_init__(self):
        if self.num_workers < 1:
            raise ValueError("engine.num_workers must be >= 1")
        if self.max_batch < 1:
            raise ValueError("engine.max_batch must be >= 1")


@dataclass(frozen=True)
class WorkersSection:
    """Speed-layer worker *backend* — how workers are realized, orthogonal
    to how many there are (``engine.num_workers``).

    * ``backend="inline"`` (default) — workers simulated inside the serving
      process: private jit caches, shared GIL and address space.  Zero
      startup cost, the right choice for tests, replay analysis, and
      latency-bound single-core deployments.
    * ``backend="process"`` — each worker is a real OS process owning its
      KV shard and jit cache (``repro.stream.procpool``); scheduling stays
      in the parent, feature payloads ride shared-memory rings, and replay
      scores stay **bit-identical** to inline.  Refresh stage-1 bins and
      (with ``learn.train_in_process``) fine-tunes also move off the
      serving GIL.  See docs/processes.md for the decision table.
    * ``ring_bytes`` — per-worker shared-memory ring capacity for SCORE
      feature payloads (oversized batches fall back to in-frame copies).
    """

    backend: str = "inline"         # 'inline' | 'process'
    ring_bytes: int = 1 << 20       # shm ring capacity per worker process

    def __post_init__(self):
        if self.backend not in ("inline", "process"):
            raise ValueError(
                f"workers.backend must be 'inline' or 'process', "
                f"got {self.backend!r}")
        if self.ring_bytes < 4096:
            raise ValueError("workers.ring_bytes must be >= 4096")


@dataclass(frozen=True)
class StoreSection:
    """KV store bounds and layout."""

    capacity: int | None = None          # LRU cap (None = unbounded)
    ttl_seconds: float | None = None     # lazy expiry (None = no expiry)
    num_shards: int = 4                  # shard-by-key count
    # None = auto: entity-affine shards (num_shards == num_workers) when
    # the engine runs multiple workers, classic key-spread otherwise
    shard_by_entity: bool | None = None


@dataclass(frozen=True)
class RefreshSection:
    """Batch-layer cadence and scope.

    ``community_local=True`` (default) re-runs stage 1 only over the
    connected components of the order↔entity graph that contain dirty
    ``(entity, t)`` pairs — bit-identical to the whole-graph refresh but
    O(dirty communities) instead of O(total stream) per run (see
    ``repro.stream.refresh``).  ``community_size`` is the node budget per
    stage-1 launch: dirty communities are bin-packed up to it, and each bin
    is padded to a power-of-two so jit caches stay warm as communities
    grow.
    """

    refresh_every: int = 1          # closed windows per refresh (1 = exact)
    async_refresh: bool = False     # stage 1 on a background thread
    community_local: bool = True    # refresh only dirty communities (exact)
    community_size: int = 4096      # node budget per stage-1 refresh launch

    def __post_init__(self):
        if self.refresh_every < 1:
            raise ValueError("refresh.refresh_every must be >= 1")
        if self.community_size < 1:
            raise ValueError("refresh.community_size must be >= 1")


@dataclass(frozen=True)
class AdmissionSection:
    """Overload policy.  ``None`` caps disable the corresponding check.

    * ``max_queue_depth`` — total queued requests across workers a new
      request may observe; at the cap, ``shed`` rejects it (NaN score,
      ``admitted=False``) while ``block`` force-flushes the deepest queue
      until there is room (the producer stalls — backpressure).
    * ``max_in_flight`` — concurrently busy workers (open virtual service
      windows); at the cap, ``shed`` rejects, ``block`` admits but counts
      the stall.
    * ``block_max_wait_s`` — wall-clock bound on one block-policy stall.
      ``None`` keeps the legacy unbounded wait (the producer stalls until
      force-flushing frees capacity, and is admitted over-cap if it never
      does); a finite value times the stall out and **sheds** the request
      instead (counted in ``ServiceStats.block_timeouts``), which the HTTP
      gateway maps to ``503 Service Unavailable``.
    """

    max_queue_depth: int | None = None
    max_in_flight: int | None = None
    policy: str = "shed"            # 'shed' | 'block'
    block_max_wait_s: float | None = None   # wall bound on a block stall
    # ---------------------------------------- queue-depth autoscaling
    # watermark-with-hysteresis control over the worker count (and the
    # steal threshold) driven by observed queue depth — see
    # repro.stream.workers.DepthAutoscaler.  Both backends support it;
    # the process backend reshards by respawning shard processes and
    # re-placing KV entries under the new rendezvous layout.
    autoscale: bool = False         # grow/shrink workers via pool.reshard
    autoscale_min_workers: int = 1
    autoscale_max_workers: int = 8
    autoscale_high_depth: float = 8.0    # mean depth/worker that arms growth
    autoscale_low_depth: float = 1.0     # mean depth/worker that arms shrink
    autoscale_sustain: int = 16     # consecutive observations before acting
    autoscale_cooldown: int = 64    # observations ignored after a reshard
    adaptive_steal: bool = False    # re-derive steal_threshold from depth

    def __post_init__(self):
        if self.policy not in ("shed", "block"):
            raise ValueError(
                f"admission.policy must be 'shed' or 'block', got {self.policy!r}"
            )
        for name in ("max_queue_depth", "max_in_flight"):
            v = getattr(self, name)
            if v is not None and v < 1:
                raise ValueError(f"admission.{name} must be >= 1 or None")
        if self.block_max_wait_s is not None and self.block_max_wait_s < 0:
            raise ValueError("admission.block_max_wait_s must be >= 0 or None")
        if not 1 <= self.autoscale_min_workers <= self.autoscale_max_workers:
            raise ValueError(
                "need 1 <= admission.autoscale_min_workers <= "
                "admission.autoscale_max_workers")
        if self.autoscale_low_depth >= self.autoscale_high_depth:
            raise ValueError(
                "admission.autoscale_low_depth must be < autoscale_high_depth")
        if self.autoscale_sustain < 1:
            raise ValueError("admission.autoscale_sustain must be >= 1")
        if self.autoscale_cooldown < 0:
            raise ValueError("admission.autoscale_cooldown must be >= 0")


@dataclass(frozen=True)
class GatewaySection:
    """HTTP front-end (``repro.gateway``) knobs.

    * ``host`` / ``port`` — bind address; port 0 asks the kernel for an
      ephemeral port (tests, CI smoke) which ``FraudGateway.port`` reports.
    * ``retry_after_s`` — the hint sent in the ``Retry-After`` header of a
      ``429`` shed response (seconds, rendered at millisecond precision).
    * ``max_body_bytes`` — request bodies above this are refused with
      ``413`` before JSON parsing (socket-level overload protection).
    * ``shadow_fraction`` / ``shadow_divergence_threshold`` — canary
      defaults: the fraction of scored traffic re-scored off the response
      path by the shadow model version, and the |primary − shadow| score
      gap that trips the divergence alert (``POST /admin/model`` with
      ``role="canary"`` may override both per activation).
    * ``latency_buckets`` — upper bounds (seconds) of the Prometheus
      request-latency histogram.
    * ``checkpoint_dir`` — when set, the gateway boots crash-consistent:
      ``serve_gateway`` restores the service from this directory if a
      durable state exists there (``FraudService.restore``), otherwise
      builds fresh and enables the write-ahead log under it
      (``enable_wal``).  ``POST /admin/checkpoint`` writes checkpoints
      into the same directory.
    * ``checkpoint_every_s`` / ``checkpoint_every_windows`` /
      ``checkpoint_keep_last`` — scheduled-checkpoint cadence wired into
      ``FraudService.enable_auto_checkpoint`` at boot (requires
      ``checkpoint_dir``): write a compacting checkpoint after this many
      wall seconds and/or closed snapshot windows, retaining only the
      newest ``checkpoint_keep_last`` ``ckpt-*`` directories.
    * ``auto_rollback`` — when True, a sticky shadow-divergence alert
      observed after canary scoring triggers an automatic
      ``FraudService.rollback_model`` to the last-good version (counted
      in ``rollbacks_total``) instead of page-only alerting.
    """

    host: str = "127.0.0.1"
    port: int = 0                   # 0 = ephemeral (kernel-assigned)
    retry_after_s: float = 0.05     # 429 Retry-After hint
    max_body_bytes: int = 1 << 20   # 413 above this
    shadow_fraction: float = 0.0    # default canary sampling fraction
    shadow_divergence_threshold: float = 0.25
    latency_buckets: tuple = (0.001, 0.0025, 0.005, 0.01, 0.025,
                              0.05, 0.1, 0.25, 1.0)
    checkpoint_dir: str | None = None   # durable WAL + checkpoint root
    checkpoint_every_s: float | None = None      # scheduled-ckpt wall cadence
    checkpoint_every_windows: int | None = None  # ...and/or closed-window cadence
    checkpoint_keep_last: int | None = None      # retention: keep newest N
    auto_rollback: bool = False     # sticky shadow alert -> rollback_model()

    def __post_init__(self):
        object.__setattr__(self, "latency_buckets",
                           tuple(float(b) for b in self.latency_buckets))
        if not 0 <= self.port <= 65535:
            raise ValueError("gateway.port must be in [0, 65535]")
        if not 0.0 <= self.shadow_fraction <= 1.0:
            raise ValueError("gateway.shadow_fraction must be in [0, 1]")
        if self.shadow_divergence_threshold < 0:
            raise ValueError("gateway.shadow_divergence_threshold must be >= 0")
        if self.max_body_bytes < 1:
            raise ValueError("gateway.max_body_bytes must be >= 1")
        if self.retry_after_s < 0:
            raise ValueError("gateway.retry_after_s must be >= 0")
        if list(self.latency_buckets) != sorted(set(self.latency_buckets)):
            raise ValueError("gateway.latency_buckets must be strictly increasing")
        if self.checkpoint_every_s is not None and self.checkpoint_every_s <= 0:
            raise ValueError("gateway.checkpoint_every_s must be > 0 or None")
        if self.checkpoint_every_windows is not None \
                and self.checkpoint_every_windows < 1:
            raise ValueError(
                "gateway.checkpoint_every_windows must be >= 1 or None")
        if self.checkpoint_keep_last is not None and self.checkpoint_keep_last < 1:
            raise ValueError("gateway.checkpoint_keep_last must be >= 1 or None")


@dataclass(frozen=True)
class LearnSection:
    """Continuous-learning plane (``repro.learn``) knobs.

    The WAL training tap, rolling-window trainer, and shadow-gated
    promotion controller are configured here; ``enabled=True`` makes
    ``serve_gateway`` attach a :class:`~repro.learn.ContinuousLearner`
    (which needs ``gateway.checkpoint_dir`` for the WAL tap) and exposes
    ``POST /admin/train`` + ``GET /v1/learn/stats``.

    Window policy (Morpheus-DFP-style rolling window): a fine-tune fires
    once ``min_window`` new labeled examples accumulated; it trains on the
    newest ``max_window`` examples (per-window dedup by order id when
    ``dedup``), then the window advances by ``stride`` examples.

    Promotion: each candidate registers as a canary
    (``FraudService.enable_shadow``) sampled at ``shadow_fraction``; after
    ``min_eval`` labeled shadow samples (with at least ``min_eval_pos``
    positives), the candidate promotes only when its recall@``eval_budget``
    beats the incumbent's by ``promote_margin``.  Post-promotion, the
    displaced incumbent keeps shadow-scoring as the watch reference:
    divergence alerts or a recall drop of ``rollback_margin`` (after
    ``watch_min_eval`` labeled samples) auto-roll back to last-good.
    """

    enabled: bool = False
    # WAL tap / delayed-label join
    label_latency_s: float = 0.0    # 0 = event labels are final at ingest
    include_ingest: bool = True     # backfill events become examples too
    # rolling-window trainer
    min_window: int = 32            # new examples that arm a fine-tune
    max_window: int = 256           # newest examples per training window
    stride: int = 32                # examples consumed per window advance
    dedup: bool = True              # per-window dedup by order id
    optimizer: str = "adam"         # 'sgd' | 'adam' (repro.learn.trainer)
    lr: float = 5e-3
    steps: int = 40                 # optimizer steps per fine-tune
    head: str = "mlp"               # 'mlp' | 'hybrid' (GBDT head retrain)
    gbdt_trees: int = 25            # booster size for head='hybrid'
    # run each fine-tune in a dedicated trainer process (off the serving
    # GIL): the window ships as an npz, candidate params come back as an
    # npz blob through the normal register/promotion path.  Deterministic:
    # the child runs the same _train_window on the same bytes.
    train_in_process: bool = False
    # promotion controller
    shadow_fraction: float = 1.0    # canary sampling during candidate eval
    promote_margin: float = 0.02    # candidate recall must beat incumbent by
    min_eval: int = 32              # labeled shadow samples before a verdict
    min_eval_pos: int = 3           # ...of which positives
    eval_budget: float = 0.15       # review-budget fraction for recall@budget
    eval_max: int = 4096            # eval-buffer cap (bounded memory)
    rollback_margin: float = 0.05   # post-promotion recall drop that rolls back
    watch_min_eval: int = 32        # labeled watch samples before rollback check
    watch_divergence_threshold: float = 5.0   # watch-phase alert threshold

    def __post_init__(self):
        if self.optimizer not in ("sgd", "adam"):
            raise ValueError(
                f"learn.optimizer must be 'sgd' or 'adam', got {self.optimizer!r}")
        if self.head not in ("mlp", "hybrid"):
            raise ValueError(
                f"learn.head must be 'mlp' or 'hybrid', got {self.head!r}")
        for name in ("min_window", "max_window", "stride", "steps",
                     "gbdt_trees", "min_eval", "min_eval_pos", "eval_max",
                     "watch_min_eval"):
            if getattr(self, name) < 1:
                raise ValueError(f"learn.{name} must be >= 1")
        if self.max_window < self.min_window:
            raise ValueError("learn.max_window must be >= learn.min_window")
        if self.stride > self.max_window:
            raise ValueError("learn.stride must be <= learn.max_window")
        if self.label_latency_s < 0:
            raise ValueError("learn.label_latency_s must be >= 0")
        if not 0.0 < self.shadow_fraction <= 1.0:
            raise ValueError("learn.shadow_fraction must be in (0, 1]")
        if not 0.0 < self.eval_budget <= 1.0:
            raise ValueError("learn.eval_budget must be in (0, 1]")
        if self.lr <= 0:
            raise ValueError("learn.lr must be > 0")
        for name in ("promote_margin", "rollback_margin",
                     "watch_divergence_threshold"):
            if getattr(self, name) < 0:
                raise ValueError(f"learn.{name} must be >= 0")


_SECTIONS = {
    "model": ModelSection,
    "engine": EngineSection,
    "workers": WorkersSection,
    "store": StoreSection,
    "refresh": RefreshSection,
    "admission": AdmissionSection,
    "gateway": GatewaySection,
    "learn": LearnSection,
}


@dataclass(frozen=True)
class ServiceConfig:
    """The one artifact every serving entry point is constructed from."""

    mode: str = "streaming"         # 'batch' | 'streaming'
    model: ModelSection = field(default_factory=ModelSection)
    engine: EngineSection = field(default_factory=EngineSection)
    workers: WorkersSection = field(default_factory=WorkersSection)
    store: StoreSection = field(default_factory=StoreSection)
    refresh: RefreshSection = field(default_factory=RefreshSection)
    admission: AdmissionSection = field(default_factory=AdmissionSection)
    gateway: GatewaySection = field(default_factory=GatewaySection)
    learn: LearnSection = field(default_factory=LearnSection)

    def __post_init__(self):
        if self.mode not in ("batch", "streaming"):
            raise ValueError(f"mode must be 'batch' or 'streaming', got {self.mode!r}")

    # ------------------------------------------------------------- conversion
    def to_lnn_config(self) -> LNNConfig:
        return self.model.to_lnn_config()

    def to_engine_config(self):
        """The legacy ``repro.stream.EngineConfig`` equivalent (shim paths
        and the engine the streaming facade wraps are built from this)."""
        from repro.stream.engine import EngineConfig

        e, s, r = self.engine, self.store, self.refresh
        return EngineConfig(
            k_max=e.k_max, max_batch=e.max_batch, max_wait_s=e.max_wait_s,
            refresh_every=r.refresh_every, community_local=r.community_local,
            community_size=r.community_size, entity_history=e.entity_history,
            max_history=e.max_history, max_deg=e.max_deg,
            async_refresh=r.async_refresh, store_capacity=s.capacity,
            store_ttl_s=s.ttl_seconds, store_shards=s.num_shards,
            num_workers=e.num_workers, service_model_s=e.service_model_s,
            steal_threshold=e.steal_threshold, shard_by_entity=s.shard_by_entity,
            backend=self.workers.backend,
        )

    # ---------------------------------------------------------- serialization
    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "ServiceConfig":
        if not isinstance(d, dict):
            raise TypeError(f"ServiceConfig: expected a dict, got {type(d).__name__}")
        unknown = sorted(set(d) - set(_SECTIONS) - {"mode"})
        if unknown:
            raise ValueError(
                f"unknown key(s) {unknown} in ServiceConfig — valid keys: "
                f"{['mode', *sorted(_SECTIONS)]}"
            )
        sections = {
            name: _section_from_dict(sec_cls, d.get(name, {}), f"ServiceConfig.{name}")
            for name, sec_cls in _SECTIONS.items()
        }
        return cls(mode=d.get("mode", "streaming"), **sections)

    def to_json(self, indent: int = 1) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_json(cls, text: str) -> "ServiceConfig":
        return cls.from_dict(json.loads(text))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    @classmethod
    def load(cls, path: str) -> "ServiceConfig":
        with open(path) as f:
            return cls.from_json(f.read())

    # -------------------------------------------------------------- ergonomics
    def replace(self, **kwargs) -> "ServiceConfig":
        """``dataclasses.replace`` convenience accepting section dicts too:
        ``cfg.replace(engine={"num_workers": 4})`` rebuilds only the named
        section fields (unknown keys rejected as in ``from_dict``)."""
        resolved = {}
        for k, v in kwargs.items():
            if k in _SECTIONS and isinstance(v, dict):
                cur = getattr(self, k)
                merged = {**dataclasses.asdict(cur), **v}
                resolved[k] = _section_from_dict(
                    _SECTIONS[k], merged, f"ServiceConfig.{k}")
            else:
                resolved[k] = v
        return dataclasses.replace(self, **resolved)
